"""What-if analysis: resize the datacenter in the twin and compare SLOs.

The twin's DES is trace- and configuration-driven (FR2), so capacity
planning is a config edit: re-simulate the same workload against candidate
topologies and compare queueing, utilization, energy and cost-of-carbon
proxies — the operator-facing workflow of Fig. 1, entirely offline.

All candidates run through the **batched scenario engine**
(``repro.core.scenarios``): the host axis is padded to the largest
candidate, every scenario is shape-identical, and the whole sweep is one
jitted ``vmap`` — one compilation instead of one per topology (see
``benchmarks/whatif_batch.py`` for the speedup measurement).

    PYTHONPATH=src python examples/whatif_scaling.py
"""

from repro.core.scenarios import Scenario, evaluate_scenarios
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def main() -> None:
    days = 2.0
    t_bins = int(days * BINS_PER_DAY)
    base = DatacenterConfig()
    workload = make_surf22_like(SurfTraceSpec(days=days), base)

    candidates = [Scenario(name=f"h{h}", num_hosts=h)
                  for h in (64, 128, 200, 277, 400)]
    _, _, _, summaries = evaluate_scenarios(
        workload, base, candidates, t_bins=t_bins)

    print(f"{'hosts':>6s} {'mean util':>10s} {'p99 queue':>10s} "
          f"{'unplaced':>9s} {'energy kWh':>11s} {'kWh/CPUh':>9s}")
    for s in summaries:
        # kwh_per_cpu_hour is NaN for an empty workload — surfaced, not
        # hidden behind a clamped denominator.
        print(f"{s.num_hosts:6d} {s.mean_util:10.1%} "
              f"{s.p99_queue:10.0f} {s.unplaced_jobs:9d} "
              f"{s.energy_kwh:11.1f} {s.kwh_per_cpu_hour:9.3f}")

    print("\nReading: fewer hosts -> higher utilization and queueing but "
          "less idle energy;\nthe twin quantifies the SLO/sustainability "
          "trade-off before any hardware moves (HITL decides).")


if __name__ == "__main__":
    main()

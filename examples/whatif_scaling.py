"""What-if analysis: resize the datacenter in the twin and compare SLOs.

The twin's DES is trace- and configuration-driven (FR2), so capacity
planning is a config edit: re-simulate the same workload against candidate
topologies and compare queueing, utilization, energy and cost-of-carbon
proxies — the operator-facing workflow of Fig. 1, entirely offline.

    PYTHONPATH=src python examples/whatif_scaling.py
"""

import numpy as np

from repro.core.desim import simulate
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def main() -> None:
    days = 2.0
    t_bins = int(days * BINS_PER_DAY)
    base = DatacenterConfig()
    workload = make_surf22_like(SurfTraceSpec(days=days), base)

    print(f"{'hosts':>6s} {'mean util':>10s} {'p99 queue':>10s} "
          f"{'unplaced':>9s} {'energy kWh':>11s} {'kWh/CPUh':>9s}")
    for hosts in (64, 128, 200, 277, 400):
        dc = DatacenterConfig(num_hosts=hosts)
        sim, pred = simulate(workload, dc, t_bins)
        u = np.asarray(sim.u_th)
        queue = np.asarray(sim.queue_len)
        energy = float(np.asarray(pred.energy_kwh).sum())
        cpu_h = float(np.asarray(workload.cpu_hours()).sum())
        unplaced = int((np.asarray(sim.job_start) < 0).sum())
        print(f"{hosts:6d} {u.mean():10.1%} "
              f"{np.percentile(queue, 99):10.0f} {unplaced:9d} "
              f"{energy:11.1f} {energy/max(cpu_h,1):9.3f}")

    print("\nReading: fewer hosts -> higher utilization and queueing but "
          "less idle energy;\nthe twin quantifies the SLO/sustainability "
          "trade-off before any hardware moves (HITL decides).")


if __name__ == "__main__":
    main()

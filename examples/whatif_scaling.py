"""What-if analysis: sweep schedulers, topologies AND carbon knobs in the twin.

The twin's DES is trace- and configuration-driven (FR2), so capacity
planning is a config edit: re-simulate the same workload against candidate
configurations and compare queueing, utilization, energy and **cost of
carbon** — the operator-facing workflow of Fig. 1, entirely offline.

Three axes ride one compiled program here:

  * host count x placement policy (first-fit / best-fit / worst-fit /
    random-fit; every policy except the worst-fit baseline also runs with
    depth-bounded backfill);
  * carbon-aware power caps — the per-bin cap ``base + slope * intensity_t``
    tightens when the grid runs dirty and is *enforced* in the read-out
    (delivered power is clipped, performance throttled);
  * deferrable-job time-shifting (``shift_bins``) — batch work slides into
    cleaner-grid bins.

All candidates run through the **batched scenario engine**
(``repro.core.scenarios``) against a synthetic diurnal grid
carbon-intensity trace (``repro.traces.carbon``): the host axis is padded
to the largest candidate, every scenario is shape-identical, and the whole
grid is one jitted ``vmap`` — one compilation instead of one per candidate
(see ``benchmarks/whatif_batch.py``).  Per topology, the example prints
which scheduler won on mean queue wait, and which carbon knob bought the
largest gCO2 cut and at what performance price.

The swept grid answers "which of *these* candidates is best"; the closing
section lets the **scenario optimizer** (``repro.core.optimize``) *search*
the same knob space — continuous carbon-cap base/slope, integer time
shifts, discrete schedulers — and prints the operating point it found next
to the grid's best, under one scalarized objective.

    PYTHONPATH=src python examples/whatif_scaling.py
"""

import math

from repro.core.desim import PLACEMENT_POLICIES
from repro.core.optimize import (
    ObjectiveSpec,
    OptimizerConfig,
    SearchSpace,
    optimize,
)
from repro.core.scenarios import Scenario, evaluate_scenarios
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def main() -> None:
    days = 2.0
    t_bins = int(days * BINS_PER_DAY)
    base = DatacenterConfig()
    workload = make_surf22_like(SurfTraceSpec(days=days), base)
    intensity = make_diurnal_carbon(t_bins)       # [T] gCO2/kWh, diurnal

    topologies = (64, 128, 200, 277)
    policies = sorted(PLACEMENT_POLICIES)
    candidates = [
        Scenario(name=f"{p}-h{h}", policy=p, num_hosts=h,
                 backfill_depth=0 if p == "worst_fit" else 8)
        for h in topologies for p in policies]
    # carbon knobs on the full topology: tighter caps when the grid is
    # dirty, and batch work shifted 3/6 hours toward the midday solar dip
    candidates += [
        Scenario(name="carbon-cap", carbon_cap_base_w=48_000.0,
                 carbon_cap_slope=-60.0),
        Scenario(name="shift-3h", shift_bins=36),
        Scenario(name="shift-6h", shift_bins=72),
    ]
    _, _, _, summaries = evaluate_scenarios(
        workload, base, candidates, t_bins=t_bins,
        carbon_intensity=intensity)

    print(f"{'scenario':>14s} {'hosts':>6s} {'policy':>11s} {'mean util':>10s} "
          f"{'wait bins':>10s} {'unplaced':>9s} {'energy kWh':>11s} "
          f"{'kgCO2':>8s} {'g/kWh':>6s}")
    for s in summaries:
        # kwh_per_cpu_hour is NaN for an empty workload — surfaced, not
        # hidden behind a clamped denominator; gCO2 would be NaN without an
        # intensity trace.
        print(f"{s.name:>14s} {s.num_hosts:6d} {s.policy:>11s} "
              f"{s.mean_util:10.1%} {s.mean_wait_bins:10.2f} "
              f"{s.unplaced_jobs:9d} {s.energy_kwh:11.1f} "
              f"{s.gco2/1e3:8.1f} {s.carbon_intensity_avg:6.0f}")

    print("\npolicy winner per topology (lowest mean wait, no extra "
          "unplaced jobs vs the topology's best placement count):")
    for h in topologies:
        group = [s for s in summaries if s.num_hosts == h
                 and s.shift_bins == 0 and s.carbon_cap_base_w is None]
        fewest_unplaced = min(s.unplaced_jobs for s in group)
        viable = [s for s in group if s.unplaced_jobs == fewest_unplaced]
        win = min(viable, key=lambda s: (
            s.mean_wait_bins if math.isfinite(s.mean_wait_bins) else math.inf,
            s.energy_kwh))
        print(f"  h{h:<4d} -> {win.policy} (backfill={win.backfill_depth}): "
              f"wait {win.mean_wait_bins:.2f} bins, "
              f"{win.unplaced_jobs} unplaced, {win.energy_kwh:.1f} kWh, "
              f"{win.gco2/1e3:.1f} kgCO2")

    baseline = next(s for s in summaries
                    if s.name == f"worst_fit-h{base.num_hosts}")
    carbon = [s for s in summaries
              if s.shift_bins != 0 or s.carbon_cap_base_w is not None]
    print("\ncost of carbon (vs worst_fit-h277 baseline "
          f"{baseline.gco2/1e3:.1f} kgCO2):")
    for s in carbon:
        dg = baseline.gco2 - s.gco2
        dwait = s.mean_wait_bins - baseline.mean_wait_bins
        # a shift that pushes tail jobs past the horizon is not a free
        # carbon win — the unplaced delta prices the lost work honestly
        print(f"  {s.name:>12s}: {s.gco2/1e3:8.1f} kgCO2 "
              f"({dg/max(baseline.gco2, 1e-9):+.1%}), "
              f"wait {s.mean_wait_bins:.2f} bins ({dwait:+.2f}), "
              f"{s.unplaced_jobs - baseline.unplaced_jobs:+d} unplaced, "
              f"{s.cap_exceeded_bins} cap-limited bins")

    # -- the optimizer searches what the grid only samples -------------------
    objective = ObjectiveSpec(w_gco2_kg=1.0, w_wait=0.5, w_unplaced=50.0,
                              w_throttled=0.1)
    space = SearchSpace(
        structures=tuple(
            Scenario(name=p, policy=p,
                     backfill_depth=0 if p == "worst_fit" else 8)
            for p in policies),
        carbon_cap_base_w=(35_000.0, 80_000.0),
        carbon_cap_slope=(-80.0, 0.0),
        shift_bins=(0, 72))
    res = optimize(workload, base, space, objective, t_bins=t_bins,
                   carbon_intensity=intensity, key=0,
                   config=OptimizerConfig(batch_size=16, generations=3))
    # the grid's best under the same objective (carbon candidates only have
    # comparable knobs; weight the same terms the optimizer minimized)
    def grid_score(s):
        return (s.gco2 / 1e3 + 0.5 * max(s.mean_wait_bins, 0.0)
                + 50.0 * s.unplaced_jobs + 0.1 * s.cap_exceeded_bins)
    grid_win = min((s for s in summaries
                    if math.isfinite(s.mean_wait_bins)), key=grid_score)
    b = res.best_summary
    print(f"\nsearched optimum (objective: gCO2 + 0.5*wait + 50*unplaced "
          f"+ 0.1*throttled bins; {res.candidates} candidates, "
          f"{res.batches} single-compile batches):")
    print(f"  swept grid best : {grid_win.name:>14s}  "
          f"score {grid_score(grid_win):9.1f}  "
          f"({grid_win.gco2/1e3:.1f} kgCO2, wait "
          f"{grid_win.mean_wait_bins:.2f})")
    cap = ("none" if b.carbon_cap_base_w is None else
           f"{b.carbon_cap_base_w/1e3:.1f}kW{b.carbon_cap_slope:+.0f}")
    print(f"  searched optimum: {b.policy}/bf={b.backfill_depth} "
          f"cap={cap} shift={b.shift_bins}  "
          f"objective {res.best.objective:9.1f}  "
          f"({b.gco2/1e3:.1f} kgCO2, wait {b.mean_wait_bins:.2f}) "
          f"vs baseline {res.baseline.objective:.1f}")

    print("\nReading: fewer hosts -> higher utilization and queueing but "
          "less idle energy;\npacking policies + backfill trade spread for "
          "wait time; carbon caps and time\nshifts buy gCO2 with wait-time "
          "currency — the optimizer *searches* that\ntrade-space and the "
          "twin prices it before any hardware moves (HITL decides).")


if __name__ == "__main__":
    main()

"""What-if analysis: sweep schedulers AND topologies in the twin, compare SLOs.

The twin's DES is trace- and configuration-driven (FR2), so capacity
planning is a config edit: re-simulate the same workload against candidate
topologies and compare queueing, utilization, energy and cost-of-carbon
proxies — the operator-facing workflow of Fig. 1, entirely offline.

Since the placement policy is a *traced* scenario knob (PR 2), the sweep has
two axes: host count x placement policy (first-fit / best-fit / worst-fit /
random-fit; every policy except the worst-fit baseline also runs with
depth-bounded backfill — no reservations, so a blocked head has no
guaranteed start time).  All
candidates run through the **batched scenario engine**
(``repro.core.scenarios``): the host axis is padded to the largest
candidate, every scenario is shape-identical, and the whole
(policies x topologies) grid is one jitted ``vmap`` — one compilation
instead of one per candidate (see ``benchmarks/whatif_batch.py`` for the
speedup and single-compile measurements).  Per topology, the example prints
which scheduler won on mean queue wait without placing fewer jobs — the
software-only knob an operator can turn before buying hardware.

    PYTHONPATH=src python examples/whatif_scaling.py
"""

import math

from repro.core.desim import PLACEMENT_POLICIES
from repro.core.scenarios import Scenario, evaluate_scenarios
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def main() -> None:
    days = 2.0
    t_bins = int(days * BINS_PER_DAY)
    base = DatacenterConfig()
    workload = make_surf22_like(SurfTraceSpec(days=days), base)

    topologies = (64, 128, 200, 277)
    policies = sorted(PLACEMENT_POLICIES)
    candidates = [
        Scenario(name=f"{p}-h{h}", policy=p, num_hosts=h,
                 backfill_depth=0 if p == "worst_fit" else 8)
        for h in topologies for p in policies]
    _, _, _, summaries = evaluate_scenarios(
        workload, base, candidates, t_bins=t_bins)

    print(f"{'hosts':>6s} {'policy':>11s} {'mean util':>10s} "
          f"{'wait bins':>10s} {'unplaced':>9s} {'energy kWh':>11s} "
          f"{'kWh/CPUh':>9s}")
    for s in summaries:
        # kwh_per_cpu_hour is NaN for an empty workload — surfaced, not
        # hidden behind a clamped denominator.
        print(f"{s.num_hosts:6d} {s.policy:>11s} {s.mean_util:10.1%} "
              f"{s.mean_wait_bins:10.2f} {s.unplaced_jobs:9d} "
              f"{s.energy_kwh:11.1f} {s.kwh_per_cpu_hour:9.3f}")

    print("\npolicy winner per topology (lowest mean wait, no extra "
          "unplaced jobs vs the topology's best placement count):")
    for h in topologies:
        group = [s for s in summaries if s.num_hosts == h]
        fewest_unplaced = min(s.unplaced_jobs for s in group)
        viable = [s for s in group if s.unplaced_jobs == fewest_unplaced]
        win = min(viable, key=lambda s: (
            s.mean_wait_bins if math.isfinite(s.mean_wait_bins) else math.inf,
            s.energy_kwh))
        print(f"  h{h:<4d} -> {win.policy} (backfill={win.backfill_depth}): "
              f"wait {win.mean_wait_bins:.2f} bins, "
              f"{win.unplaced_jobs} unplaced, {win.energy_kwh:.1f} kWh")

    print("\nReading: fewer hosts -> higher utilization and queueing but "
          "less idle energy;\npacking policies (first/best-fit) + backfill "
          "trade spread for wait time — the twin\nquantifies the "
          "SLO/sustainability trade-off before any hardware moves "
          "(HITL decides).")


if __name__ == "__main__":
    main()

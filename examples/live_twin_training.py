"""End-to-end driver: train an LM while OpenDT twins the training cluster.

The *physical twin* is the training job itself: every step emits telemetry
(step time, utilization, measured power from the host's meter — synthesized
here from a hidden drifting power model, exactly like E1/E2).  The digital
twin ingests windows of telemetry, self-calibrates its power model, predicts
the next window, and feeds SLO-aware proposals (straggler restarts) through
the HITL gate.  A mid-run crash is injected; training restarts from the
checkpoint WITH the twin's calibration state intact.

    PYTHONPATH=src python examples/live_twin_training.py --steps 300
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.calibrate import CalibrationSpec, SelfCalibrator
from repro.core.feedback import HITLGate
from repro.core.power import PowerParams, mape, opendc_power
from repro.core.slo import NFR1, SLOMonitor
from repro.data.tokens import DataConfig, TokenPipeline
from repro.launch.steps import make_train_step, param_specs_for
from repro.launch.train import reduce_config
from repro.models.common import init_params, spec_param_count
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.fault import FailureInjector, FaultConfig, run_with_restarts
from repro.runtime.straggler import StragglerConfig, StragglerDetector

VIRTUAL_HOSTS = 4          # telemetry is reported per virtual worker
WINDOW_STEPS = 25          # steps per window of operation


class HostMeter:
    """Hidden power model of the training hosts (the 'measured reality')."""

    def __init__(self, seed: int = 9):
        self.rng = np.random.default_rng(seed)
        self.t = 0

    def read(self, utilization: float) -> float:
        # slow drift + noise, unknown to the twin (cf. traces/surf.py)
        r_true = 1.6 + 0.9 * min(self.t / 400.0, 1.0)
        self.t += 1
        p = float(np.asarray(opendc_power(
            jnp.asarray([utilization], jnp.float32),
            PowerParams(72.0, 360.0, r_true)))[0])
        return p * VIRTUAL_HOSTS * (1 + self.rng.normal(0, 0.03))


def main() -> None:
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--reduce", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--fail-at", type=int, default=150)
    ap.add_argument("--ckpt-dir", default="/tmp/live_twin_ckpt")
    args = ap.parse_args()

    import os
    import shutil
    shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    cfg = reduce_config(get_config(args.arch), args.reduce)
    n_params = spec_param_count(param_specs_for(cfg))
    print(f"training {cfg.name} reduced x{args.reduce}: "
          f"{n_params/1e6:.1f}M params, {args.steps} steps "
          f"(crash injected at step {args.fail_at})", flush=True)

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps)
    train = jax.jit(make_train_step(cfg, opt_cfg))
    pipe = TokenPipeline(DataConfig(cfg.vocab, args.seq, args.batch))

    # -- digital twin side ---------------------------------------------------
    meter = HostMeter()
    calibrator = SelfCalibrator(CalibrationSpec(), PowerParams(),
                                history_windows=3)
    monitor = SLOMonitor([NFR1])
    gate = HITLGate(policy=lambda p: True)     # auto-approve for the demo
    detector = StragglerDetector(VIRTUAL_HOSTS,
                                 StragglerConfig(min_samples=2, hysteresis=2))
    wrng = np.random.default_rng(4)
    telemetry = {"u": [], "p": [], "t": []}
    window_mapes: list[float] = []
    proposals = []
    best_step_t = [np.inf]

    def on_step(step: int, step_seconds: float) -> None:
        best_step_t[0] = min(best_step_t[0], step_seconds)
        util = float(np.clip(best_step_t[0] / step_seconds, 0.05, 1.0))
        telemetry["u"].append(util)
        telemetry["p"].append(meter.read(util))
        telemetry["t"].append(step_seconds)
        if (step + 1) % WINDOW_STEPS == 0:
            w = (step + 1) // WINDOW_STEPS - 1
            u = np.array(telemetry["u"][-WINDOW_STEPS:], np.float32)
            p = np.array(telemetry["p"][-WINDOW_STEPS:])
            u_th = np.repeat(u[:, None], VIRTUAL_HOSTS, 1)
            # twin predicts the window with the PREVIOUS calibration
            params = calibrator.params_for_next()
            pred = np.asarray(opendc_power(jnp.asarray(u_th), params)).sum(1)
            m = float(mape(jnp.asarray(p, dtype=jnp.float32),
                           jnp.asarray(pred.astype(np.float32))))
            window_mapes.append(m)
            monitor.observe("mape", [m])
            calibrator.observe(jnp.asarray(u_th), jnp.asarray(p))
            # per-host step times; host 2 degrades in the second half
            t_hosts = np.repeat(np.median(telemetry["t"][-WINDOW_STEPS:]),
                                VIRTUAL_HOSTS) * (1 + wrng.normal(
                                    0, 0.02, VIRTUAL_HOSTS))
            if step > args.steps * 0.55:
                t_hosts[2] *= 1.6
            fired = detector.observe(t_hosts, w)
            for prop in fired:
                gate.submit(prop)
            proposals.extend(gate.drain())
            if os.environ.get("TWIN_DEBUG"):
                print(f"    [dbg] w={w} t_hosts={np.round(t_hosts,3)} "
                      f"streak={detector.slow_streak} fired={len(fired)}",
                      flush=True)
            print(f"  [twin] window {w:2d} MAPE {m:5.2f}%  "
                  f"r={calibrator.params_for_next().r:.2f} "
                  f"util {u.mean():.2f}", flush=True)

    # -- training loop with fault tolerance -----------------------------------
    def make_state():
        params = init_params(param_specs_for(cfg), jax.random.PRNGKey(0),
                             jnp.dtype(cfg.dtype))
        return {"params": params, "opt": init_opt_state(params, opt_cfg),
                "twin_r": np.asarray(2.0)}

    losses = []

    def step_fn(state, step):
        t0 = time.time()
        batch = pipe.global_batch(step)
        params, opt, metrics = train(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        on_step(step, dt)
        if step % 25 == 0:
            print(f"step {step:4d} loss {loss:.4f} ({dt*1e3:.0f} ms)",
                  flush=True)
        # twin calibration state rides along in the job state
        return {"params": params, "opt": opt,
                "twin_r": np.asarray(calibrator.params_for_next().r)}, loss

    report = run_with_restarts(
        total_steps=args.steps,
        make_state=make_state,
        step_fn=step_fn,
        fault_cfg=FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
        injector=FailureInjector((args.fail_at,)),
    )

    print("\n=== summary ===")
    print(f"steps: {report.steps_done}  restarts: {report.restarts} "
          f"(restored from {report.restored_from})")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"twin windows: {len(window_mapes)}; "
          f"MAPE first/last: {window_mapes[0]:.2f}% / {window_mapes[-1]:.2f}%")
    rep = monitor.report()[0]
    print(f"NFR1: {rep.compliance:.1%} compliant -> "
          f"{'MET' if rep.met else 'MISSED'}")
    stragglers = [p for p in proposals
                  if p.kind.value == "restart_straggler"]
    print(f"straggler proposals approved: {len(stragglers)} "
          f"(host {stragglers[0].impact['host'] if stragglers else '-'})")
    assert report.restarts >= 1 and report.steps_done == args.steps
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


if __name__ == "__main__":
    main()

"""Quickstart: twin one day of datacenter operation and self-calibrate.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import OrchestratorConfig, run_surf_experiment
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def main() -> None:
    # 1. A datacenter (SURF-SARA topology: 277 hosts x 16 cores @ 2.1 GHz)
    dc = DatacenterConfig()

    # 2. A workload trace (synthetic SURF-22; swap in your own Workload)
    workload = make_surf22_like(SurfTraceSpec(days=1.0), dc)

    # 3. Twin it, closed loop: telemetry -> simulate -> calibrate -> SLOs
    result = run_surf_experiment(
        workload, dc, t_bins=BINS_PER_DAY,
        calibrate=True,
        cfg=OrchestratorConfig(bins_per_window=36),   # 3 h windows
    )

    print(f"windows twinned      : {len(result.records)}")
    print(f"overall MAPE         : {result.overall_mape:.2f}%")
    for rep in result.slo_reports:
        print(f"SLO {rep.slo.name:15s}: {rep.compliance:.1%} compliant "
              f"-> {'MET' if rep.met else 'MISSED'}")
    print(f"under-estimation     : {result.under_estimation_fraction:.1%} "
          "of samples")
    last = result.records[-1].params
    print(f"calibrated power fit : P(u) = {last.p_idle:.1f} + "
          f"({last.p_max:.1f} - {last.p_idle:.1f}) * (2u - u^{last.r:.2f})")
    mean_util = float(np.mean(
        [np.mean(np.asarray(r.prediction.utilization))
         for r in result.records]))
    print(f"mean utilization     : {mean_util:.1%}  "
          f"({'under' if mean_util < 0.3 else 'well'}-utilized; "
          "paper §3.3 insight)")


if __name__ == "__main__":
    main()

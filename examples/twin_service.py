"""Streaming twin service: live tenants multiplexed onto one program.

Where ``fleet_of_twins.py`` batches a *fixed* fleet over a *fixed* horizon,
this example runs the serving story (``repro.serve``): tenants arrive and
leave, their telemetry streams in jittered and out of order, and every
dynamic batch — whatever mix of lanes is ready — is one call to the same
compiled ``fleet_step_masked`` program.  Along the way it exercises the
whole lane lifecycle:

  admit -> batch -> step -> cache -> checkpoint/restore -> evict

Two tenant groups share hidden power models (same seeds), so once the
first group's streams have been served the result cache answers the
second group's windows without touching the device — bit for bit.

    PYTHONPATH=src python examples/twin_service.py
"""

import tempfile

import numpy as np

from repro.core.state import TwinConfig
from repro.serve import ServeConfig, SyntheticProducer, TwinService
from repro.traces.schema import DatacenterConfig

HOSTS = 16
BINS = 36          # one 3 h window at 5-min sampling
WINDOWS = 4
LANES = 8


def producer(tenant: str, seed: int):
    return SyntheticProducer(
        tenant, hosts=HOSTS, bins_per_window=BINS, num_windows=WINDOWS,
        seed=seed, util_mean=0.3 + 0.05 * (seed % 5))


def main() -> None:
    cfg = ServeConfig(
        twin=TwinConfig(bins_per_window=BINS,
                        dc=DatacenterConfig(num_hosts=HOSTS,
                                            cores_per_host=16)),
        lanes=LANES, queue_capacity=64)
    svc = TwinService(cfg)

    # --- admit the first tenant group and stream it to completion --------
    for i in range(4):
        svc.admit(f"tenant-a{i}")
        svc.attach(producer(f"tenant-a{i}", seed=i))
    results_a = svc.run_until_idle()
    print(f"group A: {len(results_a)} windows served over "
          f"{svc.stats.batches} batches (fill {svc.stats.fill_ratio:.0%}, "
          f"compiles: {svc.compile_count()})")

    # --- group B replays the same hidden models (same seeds): every window
    # is answered from the result cache, bitwise, device untouched ---------
    for i in range(4):
        svc.admit(f"tenant-b{i}")
        svc.attach(producer(f"tenant-b{i}", seed=i))
    results_b = svc.run_until_idle()
    print(f"group B: {len(results_b)} windows served, "
          f"{svc.stats.windows_cached} from cache (hit rate "
          f"{svc.cache.hit_rate:.0%}), still {svc.compile_count()} "
          "compiled program(s)")

    # --- checkpoint all 8 live sessions, kill, restore into a fresh
    # service; replayable producers re-emit from window 0 and every
    # already-served window drops as a stale replay -----------------------
    with tempfile.TemporaryDirectory() as root:
        svc.checkpoint(root)
        svc2 = TwinService(cfg)
        restored = svc2.restore(root)
        for i in range(4):
            svc2.attach(producer(f"tenant-a{i}", seed=i))
        new = svc2.run_until_idle()
        print(f"\nrestored {len(restored)} sessions; replayed group A "
              f"produced {len(new)} new windows "
              f"({svc2.stats.stale_dropped} stale replays dropped) — "
              "nothing is served twice")

        # --- evict one tenant; its session travels as a value ------------
        session = svc2.evict("tenant-b0")
        print(f"evicted tenant-b0 at window {session.next_window}; "
              f"{LANES - len(svc2.tenants)} of {LANES} lanes free")

    # cached results match computed ones bitwise: B-windows vs the A-stream
    # of the same seed
    a0 = {r.window: r for r in results_a if r.tenant == "tenant-a0"}
    b0 = {r.window: r for r in results_b if r.tenant == "tenant-b0"}
    same = all(
        np.array_equal(a0[w].output.prediction.power_w,
                       b0[w].output.prediction.power_w)
        for w in range(WINDOWS))
    print(f"\nB-stream outputs bitwise == A-stream outputs: {same}")
    print("one compiled fleet program served every batch above — admission "
          "order,\nfill pattern and cache hits never retrace.")


if __name__ == "__main__":
    main()

"""E1: reproduce the FootPrinter comparison and extend it (paper §3.3).

    PYTHONPATH=src python examples/reproduce_footprinter.py
"""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

import e1_footprinter  # noqa: E402


def main() -> None:
    res = e1_footprinter.run()
    print(json.dumps(res, indent=2))
    print()
    print(f"FootPrinter (hand-tuned, run once) MAPE : "
          f"{res['footprinter_mape']:.2f}%   (paper: 7.86%)")
    print(f"OpenDT continuous (uncalibrated)  MAPE : "
          f"{res['opendt_mape']:.2f}%   (paper: 5.13%)")
    print(f"-> OpenDT better by {res['improvement_pp']:.2f} pp; "
          f"extension: best efficiency "
          f"{res['best_efficiency_tflops_per_kwh']:.2f} TFLOPs/kWh at "
          f"peak performance {res['peak_tflops_hour']:.1f} TFLOP/s")


if __name__ == "__main__":
    main()

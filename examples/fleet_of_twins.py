"""Fleet twinning: D datacenters, one compiled program.

The pure functional core (``repro.core.state``) makes the paper's windowed
cycle a state-transition function, so twinning a *fleet* of independent
datacenters is just ``vmap(twin_step)`` — and a whole horizon for the whole
fleet is one ``scan`` over that vmap (``repro.core.twin.run_fleet``).

This example twins 4 regional datacenters sharing one padded topology but
with different workload intensities and different *hidden* power models
(per-site hardware variation, paper §2.4).  Per window, each lane predicts
with its own pipelined calibration result, scores against its own telemetry
and recalibrates — D grid searches, D MAPE streams, one fused program.

    PYTHONPATH=src python examples/fleet_of_twins.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.power import PowerParams, opendc_power
from repro.core.state import SimSlice, TelemetrySlice, TwinConfig, init_twin_state
from repro.core.twin import index_twin_state, run_fleet, stack_twin_states
from repro.traces.schema import DatacenterConfig

NUM_DC = 4
HOSTS = 32
BINS = 36          # one 3 h window at 5-min sampling
WINDOWS = 8

#: per-site hidden reality the calibrator must discover (r* per region)
HIDDEN_R = [1.6, 2.4, 3.1, 3.8]
UTIL_MEAN = [0.25, 0.40, 0.55, 0.70]


def synth_site(seed: int, r_star: float, util_mean: float):
    """Synthetic utilization + hidden-model power telemetry for one site."""
    rng = np.random.default_rng(seed)
    u = np.clip(rng.normal(util_mean, 0.15, (WINDOWS, BINS, HOSTS)),
                0.0, 1.0).astype(np.float32)
    hidden = PowerParams(p_idle=72.0, p_max=365.0, r=r_star)
    p = np.array(opendc_power(jnp.asarray(u), hidden).sum(axis=-1))
    p *= 1.0 + rng.normal(0, 0.01, p.shape)        # meter noise
    return u, p.astype(np.float32)


def main() -> None:
    dc = DatacenterConfig(num_hosts=HOSTS, cores_per_host=16)
    cfg = TwinConfig(bins_per_window=BINS, dc=dc)
    fleet = stack_twin_states([init_twin_state(cfg) for _ in range(NUM_DC)])

    sites = [synth_site(11 + d, HIDDEN_R[d], UTIL_MEAN[d])
             for d in range(NUM_DC)]
    u_all = np.stack([s[0] for s in sites], axis=1)    # [W, D, BINS, HOSTS]
    p_all = np.stack([s[1] for s in sites], axis=1)    # [W, D, BINS]
    telem = TelemetrySlice(u_th=jnp.asarray(u_all),
                           power_w=jnp.asarray(p_all),
                           valid=jnp.ones((WINDOWS, NUM_DC), bool))
    sims = SimSlice(u_th=jnp.asarray(u_all))

    final, outs = run_fleet(fleet, telem, sims)        # ONE compiled program
    mape = np.asarray(outs.mape)                       # [W, D]

    print(f"fleet of {NUM_DC} datacenters x {WINDOWS} windows, "
          f"one compiled program ({HOSTS} hosts each)")
    print(f"{'window':>6s} " + " ".join(f"{f'dc{d} MAPE%':>10s}"
                                        for d in range(NUM_DC)))
    for w in range(WINDOWS):
        print(f"{w:6d} " + " ".join(f"{mape[w, d]:10.2f}"
                                    for d in range(NUM_DC)))

    print("\ncalibrated exponent per site (hidden r* in parentheses):")
    for d in range(NUM_DC):
        st = index_twin_state(final, d)
        print(f"  dc{d}: r = {float(np.asarray(st.params.r)):.2f} "
              f"(r* = {HIDDEN_R[d]:.2f}), "
              f"window MAPE {mape[:, d].mean():.2f}% mean")

    print("\nReading: each lane converges toward its own hidden hardware "
          "model — the fleet\nshares one compilation, not one calibration.")


if __name__ == "__main__":
    main()

"""Placement-policy kernels vs the plain-Python reference scheduler.

Two layers of defense:
  * every (policy, backfill_depth) combination must match the easily-audited
    pure-Python FCFS oracle (``tests/reference.py`` — shared with the
    cap/shift readout cross-checks in ``test_oracle.py``) on hand-built and
    randomized small traces;
  * the default scheduler (worst-fit, no backfill) must be bit-for-bit
    identical to the *pre-refactor* DES — golden job_start/job_host arrays
    captured from the seed implementation before the policy kernel landed.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from reference import reference_schedule

from repro.core.desim import (
    PLACEMENT_POLICIES,
    simulate_utilization,
)
from repro.core.feedback import ProposalKind, propose_from_scenario
from repro.core.scenarios import Scenario, ScenarioSummary, evaluate_scenarios
from repro.traces.schema import DatacenterConfig, Workload


# -- traces -------------------------------------------------------------------

def _random_trace(seed, j, sub_hi, dur_hi, cor_hi, phases=3, u_lo=0.2):
    rng = np.random.default_rng(seed)
    submit = np.sort(rng.integers(0, sub_hi, j)).astype(np.int32)
    dur = rng.integers(1, dur_hi, j).astype(np.int32)
    cores = rng.integers(1, cor_hi, j).astype(np.int32)
    util = rng.uniform(u_lo, 1.0, (j, phases)).astype(np.float32)
    return Workload(jnp.asarray(submit), jnp.asarray(dur), jnp.asarray(cores),
                    jnp.asarray(util), jnp.ones((j,), bool))


#: (trace, num_hosts, cores_per_host, t_bins) — contended enough that the
#: policies genuinely diverge and backfill genuinely fires.
_CASES = [
    (_random_trace(7, 24, 20, 6, 9), 4, 8, 32),
    (_random_trace(13, 40, 12, 8, 13), 2, 12, 48),
    (_random_trace(29, 32, 10, 5, 7), 3, 8, 40),
]


@pytest.mark.parametrize("policy", sorted(PLACEMENT_POLICIES))
@pytest.mark.parametrize("depth", [0, 2])
def test_policies_match_python_reference(policy, depth):
    for w, nh, cph, tb in _CASES:
        out = simulate_utilization(
            w, num_hosts=nh, cores_per_host=cph, t_bins=tb,
            policy=policy, backfill_depth=depth)
        ref_s, ref_h = reference_schedule(
            np.asarray(w.submit_bin).tolist(),
            np.asarray(w.duration_bins).tolist(),
            np.asarray(w.cores).tolist(),
            np.asarray(w.valid).tolist(),
            num_hosts=nh, cores_per_host=cph, t_bins=tb,
            policy=policy, backfill_depth=depth)
        assert np.asarray(out.job_start).tolist() == ref_s, (policy, depth)
        assert np.asarray(out.job_host).tolist() == ref_h, (policy, depth)


def test_worst_fit_no_backfill_matches_pre_refactor_golden():
    """Goldens captured from the seed DES *before* the policy kernel landed:
    the default path must remain bit-for-bit the pre-refactor scheduler."""
    w, nh, cph, tb = _CASES[0]
    out = simulate_utilization(w, num_hosts=nh, cores_per_host=cph, t_bins=tb)
    assert np.asarray(out.job_start).tolist() == [
        0, 1, 2, 2, 4, 5, 5, 7, 7, 8, 9, 10, 11, 12, 13, 15, 15, 16, 16,
        16, 18, 18, 20, 20]
    assert np.asarray(out.job_host).tolist() == [
        0, 1, 2, 3, 0, 1, 3, 0, 2, 1, 3, 0, 2, 2, 1, 0, 1, 2, 3, 2, 1, 2,
        0, 3]
    assert float(np.asarray(out.u_th, np.float64).sum()) == 26.56569269299507

    # exact trace the pre-refactor goldens were captured on (2-phase util
    # drawn from [0.1, 1.0); the rng draws submit/dur/cores first, so the
    # schedule matches _CASES[1] but the utilization field does not)
    w = _random_trace(13, 40, 12, 8, 13, phases=2, u_lo=0.1)
    nh, cph, tb = 2, 12, 48
    out = simulate_utilization(w, num_hosts=nh, cores_per_host=cph, t_bins=tb)
    assert np.asarray(out.job_start).tolist() == [
        0, 0, 0, 2, 2, 5, 8, 11, 12, 12, 15, 15, 18, 22, 23, 23, 28, 28,
        30, 33, 34, 35, 37, 38, 39, 42, 43, 43] + [-1] * 12
    assert float(np.asarray(out.u_th, np.float64).sum()) == 44.14356358349323
    assert int(np.asarray(out.queue_len).sum()) == 904


def test_backfill_lets_small_jobs_jump_blocked_head():
    # host: 16 cores.  job0 takes 8 for 4 bins; job1 (16 cores) blocks;
    # jobs 2/3 (4 cores each) fit immediately.
    w = Workload(
        jnp.array([0, 0, 0, 0], jnp.int32),
        jnp.array([4, 2, 2, 2], jnp.int32),
        jnp.array([8, 16, 4, 4], jnp.int32),
        jnp.ones((4, 2), jnp.float32),
        jnp.ones((4,), bool))
    starts = {}
    for d in (0, 1, 2):
        out = simulate_utilization(
            w, num_hosts=1, cores_per_host=16, t_bins=16, backfill_depth=d)
        starts[d] = np.asarray(out.job_start).tolist()
    assert starts[0] == [0, 4, 6, 6]      # strict head-of-line blocking
    assert starts[1] == [0, 4, 0, 6]      # depth 1: only job2 jumps
    assert starts[2] == [0, 4, 0, 0]      # depth 2: both jump; head at t=4


def test_backfill_depth_beyond_skip_mask_width_rejected():
    # the skip bitmask is uint32: depths > 31 would silently mis-schedule,
    # so both entry points must refuse them loudly.
    w = _random_trace(7, 8, 4, 3, 4)
    with pytest.raises(ValueError, match="31"):
        simulate_utilization(w, num_hosts=2, cores_per_host=8, t_bins=8,
                             backfill_depth=34)
    with pytest.raises(ValueError, match="31"):
        evaluate_scenarios(w, DatacenterConfig(num_hosts=2, cores_per_host=8),
                           [Scenario(backfill_depth=40)], t_bins=8)


def test_backfill_never_starts_unsubmitted_jobs():
    # head blocked on capacity; successor submits later — it must not jump
    # before its own submit bin even with a wide backfill window.
    w = Workload(
        jnp.array([0, 0, 3], jnp.int32),
        jnp.array([6, 2, 1], jnp.int32),
        jnp.array([16, 16, 1], jnp.int32),
        jnp.ones((3, 2), jnp.float32),
        jnp.ones((3,), bool))
    out = simulate_utilization(
        w, num_hosts=1, cores_per_host=16, t_bins=16, backfill_depth=4)
    s = np.asarray(out.job_start).tolist()
    assert s[2] >= 3


def test_policy_axis_sweeps_in_one_batch():
    """A (policies x depths) grid through the scenario engine: summaries
    carry scheduler provenance and the packing policies diverge from the
    spreading ones on a contended topology."""
    dc = DatacenterConfig(num_hosts=3, cores_per_host=8)
    w = _random_trace(29, 32, 10, 5, 7)
    scs = [Scenario(name=f"{p}-d{d}", policy=p, backfill_depth=d)
           for p in sorted(PLACEMENT_POLICIES) for d in (0, 2)]
    _, sim, _, summaries = evaluate_scenarios(w, dc, scs, t_bins=40)
    by_name = {s.name: s for s in summaries}
    assert by_name["worst_fit-d0"].policy == "worst_fit"
    assert by_name["worst_fit-d2"].backfill_depth == 2
    # each lane equals its single-scenario run (vmap lane isolation)
    for i, sc in enumerate(scs):
        solo = simulate_utilization(
            w, num_hosts=3, cores_per_host=8, t_bins=40,
            policy=sc.policy, backfill_depth=sc.backfill_depth)
        np.testing.assert_array_equal(
            np.asarray(sim.job_start[i]), np.asarray(solo.job_start), sc.name)


def _summary(**kw):
    base = dict(
        name="x", num_hosts=4, cores_per_host=8, policy="worst_fit",
        backfill_depth=0, mean_util=0.5, p99_queue=3.0, max_queue=5,
        mean_wait_bins=10.0, p99_wait_bins=20.0, unplaced_jobs=0,
        total_jobs=100, energy_kwh=50.0, mean_power_w=1000.0,
        peak_power_w=2000.0, peak_demand_w=2000.0, cpu_hours=100.0,
        kwh_per_cpu_hour=0.5, gco2=float("nan"),
        carbon_intensity_avg=float("nan"), shift_bins=0,
        power_cap_w=None, carbon_cap_base_w=None, carbon_cap_slope=0.0,
        cap_exceeded_bins=0)
    base.update(kw)
    return ScenarioSummary(**base)


def test_scheduler_change_proposal_rules():
    baseline = _summary(name="baseline")
    # same topology, different policy, big wait cut, flat energy -> proposed
    better = _summary(name="bf", policy="best_fit", backfill_depth=4,
                      mean_wait_bins=5.0)
    kinds = {p.kind for p in propose_from_scenario(0, better, baseline)}
    assert ProposalKind.SCHEDULER_CHANGE in kinds
    # energy regression beyond tolerance kills it
    hot = _summary(name="hot", policy="best_fit", mean_wait_bins=5.0,
                   energy_kwh=60.0)
    assert not any(p.kind == ProposalKind.SCHEDULER_CHANGE
                   for p in propose_from_scenario(0, hot, baseline))
    # different topology is a hardware change, not a scheduler change
    other = _summary(name="h8", num_hosts=8, policy="best_fit",
                     mean_wait_bins=5.0)
    assert not any(p.kind == ProposalKind.SCHEDULER_CHANGE
                   for p in propose_from_scenario(0, other, baseline))
    # leaving more jobs unplaced disqualifies regardless of wait
    drops = _summary(name="drop", policy="first_fit", mean_wait_bins=1.0,
                     unplaced_jobs=3)
    assert not any(p.kind == ProposalKind.SCHEDULER_CHANGE
                   for p in propose_from_scenario(0, drops, baseline))

"""Tier-1 compile-count and buffer-donation invariants (PR 7 satellite).

These guarantees used to live only in ``benchmarks/whatif_batch.py`` —
asserted, but outside CI.  This module promotes them into tier-1:

* a mixed (failures x dynamic PUE x spot price x power cap) scenario grid
  rides ONE compiled program, and a re-parameterized grid of the same
  shape does not retrace — on both the legacy readout and the fused
  kernel path (``use_pallas=True``);
* the multi-generation scenario optimizer compiles its evaluator exactly
  once, and a warm re-search adds ZERO compiles;
* donation is real, not advisory: the donated carry of ``twin_step_jit``
  and the donated ``ScenarioSet`` of ``run_scenarios(donate=True)`` are
  invalidated by the call (XLA reused their buffers), while the
  non-donating paths leave inputs readable.

Compile counts come from the jit ``_cache_size`` hook (private jax API);
where jax stops exposing it the count-based tests skip rather than rot.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimize import ObjectiveSpec, OptimizerConfig, SearchSpace, optimize
from repro.core.scenarios import Scenario, build_scenario_set, run_scenarios
from repro.core.state import (
    TwinConfig,
    init_twin_state,
    make_telemetry,
    twin_step_jit,
)
from repro.core.state import SimSlice
from repro.runtime.fault import DEGRADED, OUTAGE, HostFailure
from repro.traces.schema import DatacenterConfig, Workload

T_BINS = 48
HOSTS = 6


def _workload(seed=0, j=32):
    rng = np.random.default_rng(seed)
    return Workload(
        np.sort(rng.integers(0, T_BINS // 2, j)).astype(np.int32),
        rng.integers(1, 10, j).astype(np.int32),
        rng.integers(1, 9, j).astype(np.int32),
        rng.uniform(0.1, 1.0, (j, 3)).astype(np.float32),
        np.ones(j, bool),
        deferrable=rng.random(j) < 0.5)


def _traces(seed=1):
    rng = np.random.default_rng(seed)
    return dict(
        carbon_intensity=rng.uniform(80, 600, T_BINS).astype(np.float32),
        ambient_c=rng.uniform(5, 35, T_BINS).astype(np.float32),
        price=rng.uniform(0.02, 0.45, T_BINS).astype(np.float32))


def _mixed_grid(shift=0):
    """(failures x PUE x cap) grid; ``shift`` re-seeds values, not shapes."""
    scs = []
    for fi in (0, 1):
        fails = () if fi == 0 else (
            HostFailure(host=1 + (shift % 2), start_bin=5 + shift,
                        end_bin=20 + shift, kind=OUTAGE),
            HostFailure(host=4, start_bin=10, end_bin=30 + shift,
                        kind=DEGRADED))
        for pb, plc in ((1.0, 0.0), (1.12 + 0.01 * shift, 0.08)):
            for cap in (900.0, 1_500.0 + 10.0 * shift):
                scs.append(Scenario(
                    name=f"f{fi}-p{pb:.2f}-c{cap:.0f}", failures=fails,
                    pue_base=pb, pue_load_coeff=plc,
                    pue_amb_coeff=0.004 if plc else 0.0, power_cap_w=cap))
    return scs


def _cache():
    c = run_scenarios._cache_size
    if c is None:
        pytest.skip("jax no longer exposes the jit _cache_size hook")
    return c


@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["legacy", "pallas"])
def test_mixed_grid_single_compile(use_pallas):
    """The (failures x PUE x price x cap) grid is ONE compiled program."""
    w, dc = _workload(), DatacenterConfig(num_hosts=HOSTS, cores_per_host=8)
    kw = dict(t_bins=T_BINS, **_traces(), use_pallas=use_pallas)
    jax.clear_caches()
    cache = _cache()
    ss = build_scenario_set(w, dc, _mixed_grid(0))
    _, pred = run_scenarios(ss, max_hosts=ss.max_hosts, **kw)
    pred.energy_cost.block_until_ready()
    assert cache() == 1, f"mixed grid compiled {cache()}x, want 1"

    # same shapes, new failure windows / coefficients / caps: no retrace
    ss2 = build_scenario_set(w, dc, _mixed_grid(3))
    _, pred2 = run_scenarios(ss2, max_hosts=ss2.max_hosts, **kw)
    pred2.energy_cost.block_until_ready()
    assert cache() == 1, "re-parameterized grid retraced"


def test_optimizer_single_compile_and_warm_zero_recompiles():
    """All generations ride one evaluator; a warm re-search adds nothing."""
    w, dc = _workload(), DatacenterConfig(num_hosts=HOSTS, cores_per_host=8)
    space = SearchSpace(
        structures=(Scenario(name="wf"),
                    Scenario(name="bf", policy="best_fit", backfill_depth=4)),
        carbon_cap_base_w=(800.0, 2_000.0),
        carbon_cap_slope=(-1.0, 0.0),
        shift_bins=(0, 8))
    objective = ObjectiveSpec(w_gco2_kg=1.0, w_wait=0.5, w_unplaced=50.0)
    kw = dict(t_bins=T_BINS,
              carbon_intensity=_traces()["carbon_intensity"], key=0,
              config=OptimizerConfig(batch_size=6, generations=2,
                                     init="random"))
    jax.clear_caches()
    cache = _cache()
    optimize(w, dc, space, objective, **kw)
    assert cache() == 1, f"optimizer compiled {cache()}x, want 1"
    optimize(w, dc, space, objective, **kw)
    assert cache() == 1, "warm re-search recompiled the evaluator"


def test_optimizer_single_compile_with_pallas_readout():
    """The fused readout keeps the optimizer's single-compile contract."""
    w, dc = _workload(), DatacenterConfig(num_hosts=HOSTS, cores_per_host=8)
    space = SearchSpace(
        structures=(Scenario(name="wf"),),
        carbon_cap_base_w=(800.0, 2_000.0),
        carbon_cap_slope=(-1.0, 0.0),
        shift_bins=(0, 8))
    objective = ObjectiveSpec(w_gco2_kg=1.0, w_wait=0.5)
    kw = dict(t_bins=T_BINS,
              carbon_intensity=_traces()["carbon_intensity"], key=1,
              config=OptimizerConfig(batch_size=4, generations=1,
                                     init="random"),
              use_pallas=True)
    jax.clear_caches()
    cache = _cache()
    optimize(w, dc, space, objective, **kw)
    assert cache() == 1
    optimize(w, dc, space, objective, **kw)
    assert cache() == 1


# -- donation -----------------------------------------------------------------

def _deleted(x) -> bool:
    """True when jax has invalidated the buffer (donated and consumed)."""
    try:
        return bool(x.is_deleted())
    except AttributeError:  # non-jax leaf (host scalar): never donated
        return False


def test_twin_step_donates_its_carry():
    cfg = TwinConfig(bins_per_window=8,
                     dc=DatacenterConfig(num_hosts=HOSTS, cores_per_host=8))
    rng = np.random.default_rng(2)
    u = rng.uniform(0, 1, (8, HOSTS)).astype(np.float32)
    telem = make_telemetry(u, rng.uniform(300, 900, 8).astype(np.float32))
    sl = SimSlice(u_th=jnp.asarray(u))

    state = init_twin_state(cfg)
    hist = state.hist_u                    # a big [K, Tw, H] donated leaf
    new_state, out = twin_step_jit(state, telem, sl)
    out.mape.block_until_ready()
    assert _deleted(hist), "twin_step_jit did not donate the carry"
    # the successor state is alive and steps again (buffers were *reused*,
    # not lost) — the canonical rebind-the-return-value pattern
    newer, _ = twin_step_jit(new_state, telem, sl)
    assert not _deleted(newer.hist_u)


def test_run_scenarios_donate_flag():
    w, dc = _workload(), DatacenterConfig(num_hosts=HOSTS, cores_per_host=8)
    scs = [Scenario(name="a"), Scenario(name="b", power_cap_w=1_000.0)]

    # donate=False (the default): inputs stay readable after the call
    ss = build_scenario_set(w, dc, scs)
    sim, _ = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS)
    sim.u_th.block_until_ready()
    assert not _deleted(ss.workload.util_levels)
    np.asarray(ss.workload.util_levels)    # still materializable

    # donate=True: XLA reuses donated buffers that match an output shape —
    # the [S, J] int32 schedule inputs (submit/duration/cores) against the
    # [S, J] int32 schedule outputs (job_start/job_host).  Leaves with no
    # same-shaped output (e.g. [S, J, U] util_levels) legitimately survive.
    ss = build_scenario_set(w, dc, scs)
    ss = jax.tree.map(jnp.asarray, ss)     # device-side leaves to donate
    donated = (ss.workload.submit_bin, ss.workload.duration_bins,
               ss.workload.cores)
    sim, _ = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS,
                           donate=True)
    sim.u_th.block_until_ready()
    assert any(_deleted(x) for x in donated), (
        "donate=True consumed none of the [S, J] schedule buffers — "
        "donation is not reaching XLA")


def test_donated_and_plain_paths_agree():
    """donate=True is a memory optimization, not a numerics change."""
    w, dc = _workload(3), DatacenterConfig(num_hosts=HOSTS, cores_per_host=8)
    scs = _mixed_grid(0)
    kw = dict(t_bins=T_BINS, **_traces())
    ss = build_scenario_set(w, dc, scs)
    sim0, pred0 = run_scenarios(ss, max_hosts=ss.max_hosts, **kw)
    ss = build_scenario_set(w, dc, scs)
    sim1, pred1 = run_scenarios(ss, max_hosts=ss.max_hosts, **kw,
                                donate=True)
    for a, b in zip(jax.tree.leaves((sim0, pred0)),
                    jax.tree.leaves((sim1, pred1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Per-architecture smoke tests: REDUCED config of the same family, one
forward/train step + one decode step on CPU; output shapes + no NaNs.
(The FULL configs are exercised via the dry-run only.)

Whole module is tier-2 (``slow``): 11 architectures x (train + decode)
compile ~100 s of XLA programs on CPU — run via ``pytest -m slow``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs import all_archs, get_config
from repro.launch.steps import (
    make_serve_step,
    make_train_step,
    param_specs_for,
    state_specs_for,
)
from repro.launch.train import reduce_config
from repro.models.common import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state

B, S = 2, 32


def _batch(cfg):
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, 16, cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "vlm":
        p = min(cfg.num_patches, 8)
        batch["vision_embeds"] = jnp.ones((B, p, cfg.d_model)) * 0.02
        batch["vision_pos"] = jnp.broadcast_to(
            jnp.arange(p, dtype=jnp.int32)[None], (B, p))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_arch_train_and_decode(arch):
    cfg = reduce_config(get_config(arch), 16)
    # keep smoke fast: cap layers
    import dataclasses
    cfg = dataclasses.replace(
        cfg,
        num_layers=min(cfg.num_layers, 4) if cfg.family != "hybrid"
        else cfg.shared_attn_every + 2,
        enc_layers=min(cfg.enc_layers, 2),
        dec_layers=min(cfg.dec_layers, 2),
        dtype="float32",
    ).validate()

    params = init_params(param_specs_for(cfg), jax.random.PRNGKey(1),
                         jnp.float32)
    opt_cfg = AdamWConfig(total_steps=10, warmup_steps=2)
    opt = init_opt_state(params, opt_cfg)
    train = jax.jit(make_train_step(cfg, opt_cfg))
    p2, o2, metrics = train(params, opt, _batch(cfg))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, (arch, loss)
    # params actually moved
    delta = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32)
                                               - x[1].astype(jnp.float32)))),
        jax.tree.map(lambda a, b: (a, b), params, p2), 0.0)
    assert delta > 0

    # one serve step against a zeroed cache
    state = jax.tree.map(
        lambda t: jnp.zeros_like(t),
        init_params(state_specs_for(cfg, B, S), jax.random.PRNGKey(2),
                    jnp.float32))
    serve = jax.jit(make_serve_step(cfg))
    db = {"token": jnp.zeros((B, 1), jnp.int32) + 3,
          "cache_len": jnp.full((B,), S // 2, jnp.int32)}
    if cfg.mrope:
        db["positions"] = jnp.full((3, B, 1), S // 2, jnp.int32)
    tok, new_state = serve(p2, state, db)
    assert tok.shape == (B,)
    assert np.isfinite(np.asarray(tok, np.float64)).all()
    assert (np.asarray(tok) >= 0).all() and (np.asarray(tok) < cfg.vocab).all()


@pytest.mark.parametrize("arch", all_archs())
def test_full_config_specs_build(arch):
    """FULL configs: spec trees build and parameter counts are plausible —
    no allocation (abstract only)."""
    from repro.configs.base import active_param_count, param_count

    cfg = get_config(arch)
    n = param_count(cfg)
    a = active_param_count(cfg)
    assert 0 < a <= n
    expected = {
        "qwen2-moe-a2.7b": (13e9, 15e9),
        "deepseek-v2-lite-16b": (14e9, 17e9),
        "zamba2-1.2b": (1.0e9, 1.6e9),
        "stablelm-3b": (2.5e9, 3.3e9),
        "minicpm3-4b": (3.6e9, 4.8e9),
        "command-r-plus-104b": (97e9, 112e9),
        "smollm-360m": (0.30e9, 0.42e9),
        "mamba2-370m": (0.30e9, 0.45e9),
        "seamless-m4t-medium": (0.7e9, 1.3e9),
        "qwen2-vl-7b": (7.0e9, 8.8e9),
    }[arch]
    assert expected[0] <= n <= expected[1], (arch, n / 1e9)

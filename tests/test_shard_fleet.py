"""Fleet-axis sharding: shard_map over D == single-device vmap, bit for bit.

Mirrors ``test_shard_scenarios.py`` for the *fleet* axis (ROADMAP item 5):
``run_fleet(shard=True)`` and ``fleet_step_masked(shard=True)`` spread twin
lanes across the device mesh with padded replica lanes and must reproduce
the vmap path bit for bit.  Runs meaningfully at any device count: with one
device the mesh is trivial (the path is still exercised end to end); the
``tier1-multidevice`` CI job re-runs this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the real
multi-device path — including D-axis padding when D is not a multiple of
the device count — is covered on CPU-only CI.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.state import (
    SimSlice,
    TelemetrySlice,
    TwinConfig,
    init_twin_state,
    make_telemetry,
    twin_step,
)
from repro.core.twin import (
    FLEET_AXIS,
    fleet_mesh,
    fleet_step_masked,
    index_twin_state,
    run_fleet,
    stack_twin_states,
)
from repro.traces.schema import DatacenterConfig

DC = DatacenterConfig(num_hosts=8, cores_per_host=4)
CFG = TwinConfig(bins_per_window=12, dc=DC)

_solo_step = jax.jit(twin_step)  # non-donating solo reference


def _telem(seed: int):
    r = np.random.default_rng(seed)
    u = r.uniform(0, 1, (12, 8)).astype(np.float32)
    p = (8 * 70 + 2240 * r.uniform(0.2, 0.9, 12)).astype(np.float32)
    return u, p


def _fleet_inputs(n_windows: int, n_dc: int):
    """``run_fleet`` inputs, leaves ``[W, D, ...]`` (lane d, window w keyed
    by seed ``100 * d + w`` so every lane is an independent stream)."""
    us = np.stack([[_telem(100 * d + w)[0] for d in range(n_dc)]
                   for w in range(n_windows)])
    ps = np.stack([[_telem(100 * d + w)[1] for d in range(n_dc)]
                   for w in range(n_windows)])
    telem = TelemetrySlice(u_th=jnp.asarray(us), power_w=jnp.asarray(ps),
                           valid=jnp.ones((n_windows, n_dc), bool))
    return telem, SimSlice(u_th=jnp.asarray(us))


def _step_inputs(n_dc: int, seed0: int = 0):
    """``fleet_step_masked`` inputs, leaves ``[D, ...]`` (one window)."""
    us = np.stack([_telem(seed0 + d)[0] for d in range(n_dc)])
    ps = np.stack([_telem(seed0 + d)[1] for d in range(n_dc)])
    telem = TelemetrySlice(u_th=jnp.asarray(us), power_w=jnp.asarray(ps),
                           valid=jnp.ones((n_dc,), bool))
    return telem, SimSlice(u_th=jnp.asarray(us))


def _fresh_fleet(d: int):
    return stack_twin_states([init_twin_state(CFG) for _ in range(d)])


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_run_fleet_sharded_matches_vmap_bitwise():
    """The acceptance gate: shard_map over the D axis reproduces the
    single-device vmap path bit for bit — final states and every window's
    outputs.  D=6 on purpose: not a multiple of 2 or 4 devices, so the
    multi-device CI leg exercises replica-lane padding."""
    d, w = 6, 3
    telem, sims = _fleet_inputs(w, d)
    ref_final, ref_outs = run_fleet(_fresh_fleet(d), telem, sims)
    sh_final, sh_outs = run_fleet(_fresh_fleet(d), telem, sims, shard=True)
    _assert_trees_equal(ref_final, sh_final)
    _assert_trees_equal(ref_outs, sh_outs)


def test_run_fleet_sharded_matches_solo_lanes():
    """Transitively with the vmap gate: every sharded lane is exactly the
    solo ``twin_step`` stream (the solo == lane == sharded-lane invariant)."""
    d, w = 3, 2
    telem, sims = _fleet_inputs(w, d)
    final, outs = run_fleet(_fresh_fleet(d), telem, sims, shard=True)
    for dc_i in range(d):
        st = init_twin_state(CFG)
        for w_i in range(w):
            u, p = _telem(100 * dc_i + w_i)
            st, out = _solo_step(st, make_telemetry(u, p),
                                 SimSlice(u_th=jnp.asarray(u)))
            np.testing.assert_array_equal(
                np.asarray(outs.mape)[w_i, dc_i], np.asarray(out.mape))
        _assert_trees_equal(st, index_twin_state(final, dc_i))


def test_fleet_step_masked_sharded_matches_vmap_bitwise():
    """The serve-path step: masked lanes (mixed fill) through the sharded
    program match the vmap path bit for bit, inactive lanes included."""
    d = 5
    telem, sims = _step_inputs(d)
    active = jnp.asarray([True, False, True, True, False])
    ref_fleet, ref_outs = fleet_step_masked(_fresh_fleet(d), telem, sims,
                                            active)
    sh_fleet, sh_outs = fleet_step_masked(_fresh_fleet(d), telem, sims,
                                          active, shard=True)
    _assert_trees_equal(ref_fleet, sh_fleet)
    _assert_trees_equal(ref_outs, sh_outs)


def test_explicit_mesh_and_padding():
    """D not divisible by the device count: lanes pad with lane-0 replicas
    and both outputs slice back to the true D."""
    n_dev = len(jax.devices())
    mesh = fleet_mesh(n_dev)
    assert mesh.shape[FLEET_AXIS] == n_dev
    d, w = 5, 2                          # D=5: pads for any n_dev > 1
    telem, sims = _fleet_inputs(w, d)
    final, outs = run_fleet(_fresh_fleet(d), telem, sims, shard=True,
                            mesh=mesh)
    assert np.asarray(outs.mape).shape == (w, d)
    assert jax.tree.leaves(final)[0].shape[0] == d
    ref_final, ref_outs = run_fleet(_fresh_fleet(d), telem, sims)
    _assert_trees_equal(ref_final, final)
    _assert_trees_equal(ref_outs, outs)


def test_one_lane_per_device():
    """Regression: D == device count (one lane per device) used to be the
    shape that hit the jax-0.4.x batch-1 vmapped-while_loop bug inside
    shard_map; the engine pads to >= 2 lanes per device and must still
    match the vmap path bit for bit."""
    d = len(jax.devices())
    telem, sims = _step_inputs(d, seed0=40)
    active = jnp.ones((d,), bool)
    ref = fleet_step_masked(_fresh_fleet(d), telem, sims, active)
    sh = fleet_step_masked(_fresh_fleet(d), telem, sims, active, shard=True)
    _assert_trees_equal(ref, sh)


def test_multidevice_actually_shards():
    """Under the forced multi-device CI environment the outputs must really
    be computed across >1 device (not silently replicated)."""
    if len(jax.devices()) < 2:
        pytest.skip("single-device environment (multi-device CI covers this)")
    d, w = 4, 2
    telem, sims = _fleet_inputs(w, d)
    final, outs = run_fleet(_fresh_fleet(d), telem, sims, shard=True)
    assert np.asarray(outs.mape).shape == (w, d)
    assert np.isfinite(np.asarray(outs.mape)).all()


def test_sharded_single_compilation():
    """ONE compile per path: a warm re-run with fresh values must not grow
    either jit cache (the `_cache_size` acceptance gate from the ISSUE)."""
    if run_fleet._cache_size is None or fleet_step_masked._cache_size is None:
        pytest.skip("jax private _cache_size API unavailable")
    d, w = 4, 2
    telem, sims = _fleet_inputs(w, d)
    final, _ = run_fleet(_fresh_fleet(d), telem, sims, shard=True)
    after_first = run_fleet._cache_size()
    run_fleet(final, telem, sims, shard=True)
    assert run_fleet._cache_size() == after_first

    stelem, ssims = _step_inputs(d)
    active = jnp.ones((d,), bool)
    sfleet, _ = fleet_step_masked(_fresh_fleet(d), stelem, ssims, active,
                                  shard=True)
    after_step = fleet_step_masked._cache_size()
    fleet_step_masked(sfleet, stelem, ssims, active, shard=True)
    assert fleet_step_masked._cache_size() == after_step


def test_serve_sharded_matches_unsharded():
    """`TwinService(shard=True)` spreads resident tenants across devices and
    must serve the identical result stream (the dispatch path is the same
    `fleet_step_masked` this module pins against vmap)."""
    from repro.serve import ServeConfig, SyntheticProducer, TwinService

    dc = DatacenterConfig(num_hosts=4, cores_per_host=4)
    twin = TwinConfig(bins_per_window=6, dc=dc)

    def run(shard: bool):
        svc = TwinService(ServeConfig(twin=twin, lanes=4, queue_capacity=64,
                                      shard=shard))
        events = []
        for i, t in enumerate(["a", "b", "c"]):
            svc.admit(t)
            p = SyntheticProducer(t, hosts=dc.num_hosts,
                                  bins_per_window=twin.bins_per_window,
                                  num_windows=2, seed=i)
            events.extend(p.poll(float("inf")))
        for ev in sorted(events, key=lambda e: (e.window, e.tenant)):
            assert svc.submit(ev)
        svc.run_until_idle(pump=False)
        return {(r.tenant, r.window): jax.tree.map(np.asarray, r.output)
                for r in svc.drain()}

    ref, sh = run(False), run(True)
    assert ref.keys() == sh.keys() and len(ref) == 6
    for k in ref:
        _assert_trees_equal(ref[k], sh[k])


def test_mesh_requires_shard_flag():
    from repro.serve import ServeConfig

    with pytest.raises(ValueError, match="mesh given but shard=False"):
        ServeConfig(twin=CFG, lanes=2, mesh=fleet_mesh(1))

"""Hypothesis property tests on optimizer invariants.

``hypothesis`` is optional (same policy as ``zstandard``, see ROADMAP):
environments without it skip this module instead of failing collection.

The invariants, over randomized keys/constraints on a fixed small twin:

  * the returned incumbent is never worse than any candidate the search
    evaluated (and is the exact min over the feasible history);
  * hard constraints are never violated by the winner — or, when nothing
    satisfies them, the search raises instead of returning a violator;
  * a fixed key makes the search bit-reproducible, end to end.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.optimize import (
    ObjectiveSpec,
    OptimizerConfig,
    SearchSpace,
    optimize,
)
from repro.core.scenarios import Scenario
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.schema import DatacenterConfig, Workload

T_BINS = 36
DC = DatacenterConfig(num_hosts=3, cores_per_host=8)

_rng = np.random.default_rng(19)
_J = 16
WORKLOAD = Workload(
    jnp.asarray(np.sort(_rng.integers(0, 18, _J)).astype(np.int32)),
    jnp.asarray(_rng.integers(1, 6, _J).astype(np.int32)),
    jnp.asarray(_rng.integers(1, 8, _J).astype(np.int32)),
    jnp.asarray(_rng.uniform(0.2, 1.0, (_J, 2)).astype(np.float32)),
    jnp.ones((_J,), bool),
    deferrable=jnp.asarray(_rng.random(_J) < 0.5))
INTENSITY = make_diurnal_carbon(T_BINS, seed=6)

SPACE = SearchSpace(
    structures=(Scenario(name="wf"),
                Scenario(name="bf", policy="best_fit", backfill_depth=2)),
    carbon_cap_base_w=(400.0, 1500.0),
    shift_bins=(0, 8))

#: one fixed batch shape across all examples — every optimize() call below
#: reuses a single compiled evaluator, so the property suite stays fast
CONFIG = OptimizerConfig(batch_size=6, generations=2, init="random")

SETTINGS = dict(max_examples=10, deadline=None)


def _opt(key, objective):
    return optimize(WORKLOAD, DC, SPACE, objective, t_bins=T_BINS,
                    carbon_intensity=INTENSITY, key=key, config=CONFIG)


@given(key=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_incumbent_never_worse_than_any_evaluated(key):
    res = _opt(key, ObjectiveSpec(w_gco2_kg=1.0, w_wait=0.1,
                                  w_unplaced=10.0))
    feas = [c.objective for c in res.history if c.feasible]
    assert res.best.feasible
    assert res.best.objective == min(feas)
    assert all(res.best.objective <= c.objective for c in res.history)
    assert (np.diff(res.incumbent_objective) <= 0).all()


@given(key=st.integers(0, 2**31 - 1),
       max_unplaced=st.integers(0, 4),
       max_wait=st.floats(0.5, 20.0))
@settings(**SETTINGS)
def test_winner_never_violates_hard_constraints(key, max_unplaced, max_wait):
    obj = ObjectiveSpec(w_gco2_kg=1.0, w_unplaced=5.0,
                        max_unplaced_jobs=max_unplaced,
                        max_mean_wait_bins=max_wait)
    try:
        res = _opt(key, obj)
    except ValueError as e:
        assert "no feasible candidate" in str(e)
        return
    assert res.best.breakdown["unplaced_jobs"] <= max_unplaced
    assert res.best.breakdown["mean_wait_bins"] <= max_wait
    for c in res.history:
        if not c.feasible:
            assert c.objective == np.inf


@given(key=st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_search_bit_reproducible_for_fixed_key(key):
    obj = ObjectiveSpec(w_gco2_kg=1.0, w_wait=0.1, w_unplaced=10.0)
    a, b = _opt(key, obj), _opt(key, obj)
    assert [c.scenario for c in a.history] == [c.scenario for c in b.history]
    assert [c.objective for c in a.history] == [c.objective for c in b.history]
    assert [c.feasible for c in a.history] == [c.feasible for c in b.history]
    np.testing.assert_array_equal(a.incumbent_objective,
                                  b.incumbent_objective)
    assert a.best.scenario == b.best.scenario
    assert a.best.breakdown == b.best.breakdown

"""Fault tolerance, stragglers, elastic re-mesh, sharding rules, MoE, HLO
analysis (trip-count multiplication in a subprocess with 8 host devices)."""

import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.runtime.elastic import plan_mesh
from repro.runtime.fault import (
    FailureInjector,
    FaultConfig,
    run_with_restarts,
)
from repro.runtime.straggler import StragglerConfig, StragglerDetector


def test_run_with_restarts_resumes(tmp_path):
    calls = []

    def make_state():
        return {"x": np.zeros((1,), np.float32)}

    def step_fn(state, step):
        calls.append(step)
        return {"x": state["x"] + 1}, float(state["x"][0])

    rep = run_with_restarts(
        total_steps=20,
        make_state=make_state,
        step_fn=step_fn,
        fault_cfg=FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=5),
        injector=FailureInjector(fail_at_steps=(7, 13)),
    )
    assert rep.steps_done == 20
    assert rep.restarts == 2
    assert rep.restored_from == [5, 10]
    # state continuity: steps 5 and 10 re-executed after the crashes;
    # the failing step itself never ran before the crash (check precedes it)
    assert calls.count(5) == 2 and calls.count(10) == 2
    assert calls.count(13) == 1


def test_checkpoint_gc_keeps_latest(tmp_path):
    for s in range(6):
        ckpt.save(str(tmp_path), s, {"v": np.array([s])}, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    step, state = ckpt.restore(str(tmp_path))
    assert state["v"][0] == 5


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(8, StragglerConfig(min_samples=2, hysteresis=2))
    base = np.ones(8)
    props = []
    for w in range(6):
        t = base.copy()
        t[3] = 2.0                       # host 3 persistently 2x slower
        props += det.observe(t, w)
    assert props, "straggler never flagged"
    assert props[0].impact["host"] == 3
    assert props[0].impact["ratio"] > 1.5


def test_elastic_plan_mesh():
    plan = plan_mesh(512, model_parallel=16, global_batch=256, prefer_pods=2)
    assert plan.shape == (2, 16, 16)
    # lose 32 devices -> data shrinks, global batch preserved
    plan2 = plan_mesh(480, model_parallel=16, global_batch=256)
    assert plan2.data_shards * plan2.per_shard_batch == 256
    assert plan2.shape[-1] == 16
    with pytest.raises(RuntimeError):
        plan_mesh(8, model_parallel=16, global_batch=256)


def test_sharding_rules():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import (
        abstract_mesh_compat, logical_to_spec, make_mesh_compat,
    )

    mesh = make_mesh_compat((1, 1), ("data", "model"))
    # trivial mesh: everything replicated
    assert logical_to_spec(("batch", "embed"), (8, 16), mesh, "train") == P()

    # fake bigger mesh via abstract mesh
    mesh = abstract_mesh_compat((4, 2), ("data", "model"))
    spec = logical_to_spec(("batch", "ff"), (8, 16), mesh, "train")
    assert spec == P(("data",), "model") or spec == P("data", "model")
    # non-divisible dims drop their sharding
    spec = logical_to_spec(("batch", "ff"), (6, 16), mesh, "train")
    assert spec == P(None, "model")
    # an axis is consumed at most once
    spec = logical_to_spec(("ff", "vocab"), (16, 32), mesh, "train")
    assert spec == P("model")


def test_moe_capacity_and_gates():
    from repro.configs.base import ModelConfig
    from repro.models.moe import _capacity, _moe_local

    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      vocab=8, moe=True, n_experts=4, top_k=2, moe_d_ff=8,
                      capacity_factor=8.0).validate()
    rng = np.random.default_rng(0)
    tl = 32
    x = jnp.asarray(rng.normal(0, 1, (tl, 16)).astype(np.float32))
    router = jnp.asarray(rng.normal(0, 1, (16, 4)).astype(np.float32))
    wg = jnp.asarray(rng.normal(0, .1, (16, 16, 8)).astype(np.float32))
    wu = jnp.asarray(rng.normal(0, .1, (16, 16, 8)).astype(np.float32))
    wd = jnp.asarray(rng.normal(0, .1, (16, 8, 16)).astype(np.float32))
    y, aux = _moe_local(x, router, wg, wu, wd, cfg=cfg, e0=0, n_shards=1)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0

    # ample capacity: output must equal the dense gather-all-experts form
    probs = np.asarray(jnp.asarray(
        __import__("jax").nn.softmax(x @ router, axis=-1)))
    idx = np.argsort(-probs, axis=1)[:, :2]
    want = np.zeros_like(np.asarray(x))
    for t in range(tl):
        for e in idx[t]:
            h = np.asarray(x)[t] @ np.asarray(wg)[e]
            h = h / (1 + np.exp(-h)) * (np.asarray(x)[t] @ np.asarray(wu)[e])
            want[t] += probs[t, e] * (h @ np.asarray(wd)[e])
    np.testing.assert_allclose(np.asarray(y), want, rtol=2e-3, atol=2e-3)


HLO_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # the stripped subprocess env must not let jax probe absent accelerators
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis.hlo import analyze_compiled_text
    from repro.parallel.sharding import make_mesh_compat

    mesh = make_mesh_compat((2, 4), ("data", "model"))
    L, B, D, F = 6, 8, 64, 128

    def step(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    j = jax.jit(step,
                in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                              NamedSharding(mesh, P("data", None))),
                out_shardings=NamedSharding(mesh, P()))
    compiled = j.lower(ws, x).compile()
    parsed = analyze_compiled_text(compiled.as_text(), 8)
    expect = L * 2 * (B // 2) * D * (D // 4)   # per-device dot flops x L trips
    ratio = parsed["flops_per_device"] / expect
    assert 0.9 < ratio < 1.6, (parsed["flops_per_device"], expect)
    print("OK", parsed["flops_per_device"], expect)
""")


def test_hlo_triptcount_multiplication_subprocess():
    out = subprocess.run(
        [sys.executable, "-c", HLO_SUBPROC],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo",
    )
    assert "OK" in out.stdout, out.stdout + out.stderr

"""Telemetry store, SLO/bias monitors, HITL gate, meta-model combiner."""

import numpy as np
import pytest

from repro.core.feedback import HITLGate, Proposal, ProposalKind, propose_from_state
from repro.core.metamodel import combine, run_multi_model
from repro.core.power import PowerParams
from repro.core.slo import NFR1, BiasTracker, SLOMonitor
from repro.core.telemetry import TelemetryStore, TelemetryWindow, clip_to_window

import jax.numpy as jnp


def _window(idx, bins=12, hosts=4):
    rng = np.random.default_rng(idx)
    return TelemetryWindow(
        window=idx, t0_bin=idx * bins,
        u_th=rng.uniform(0, 1, (bins, hosts)).astype(np.float32),
        power_w=rng.uniform(1e3, 2e3, bins),
    )


def test_store_ingest_get_history():
    st = TelemetryStore(bins_per_window=12)
    for i in range(5):
        st.ingest(_window(i))
    assert st.latest() == 4
    hist = st.history(4, 3)
    assert [h.window for h in hist] == [2, 3, 4]
    with pytest.raises(ValueError):
        st.ingest(_window(2))              # duplicate window


def test_store_rejects_unclipped():
    st = TelemetryStore(bins_per_window=12)
    with pytest.raises(ValueError):
        st.ingest(_window(0, bins=7))


def test_clip_to_window_pads_and_clips():
    u = np.arange(40, dtype=np.float32).reshape(20, 2)
    p = np.arange(20, dtype=np.float64)
    tw = clip_to_window(1, 8, 0, u, p)     # bins 8..16 of a 20-bin record
    assert tw.bins == 8
    assert tw.power_w[0] == 8.0
    short = clip_to_window(2, 8, 0, u, p)  # bins 16..24: only 4 available
    assert short.bins == 8                 # forward-filled
    assert short.power_w[-1] == p[-1]


def test_store_persistence_roundtrip(tmp_path):
    st = TelemetryStore(bins_per_window=12)
    for i in range(3):
        st.ingest(_window(i))
    path = str(tmp_path / "telemetry.zmp")
    st.flush(path)
    back = TelemetryStore.load(path)
    assert sorted(back.windows()) == [0, 1, 2]
    np.testing.assert_allclose(back.get(1).u_th, st.get(1).u_th, rtol=1e-6)


def test_slo_monitor_compliance():
    mon = SLOMonitor([NFR1])
    mon.observe("mape", [5.0] * 9 + [15.0])     # 90% under threshold
    rep = mon.report()[0]
    assert rep.compliance == pytest.approx(0.9)
    assert rep.met                               # >= 0.90


def test_bias_tracker():
    bt = BiasTracker()
    bt.observe(np.array([10.0, 10.0, 10.0]), np.array([9.0, 11.0, 8.0]))
    assert bt.under == 2 and bt.over == 1
    assert bt.under_fraction == pytest.approx(2 / 3)


def test_bias_tracker_counts_ties_separately():
    """Regression: exact ties (sim == real) used to count as over-estimation,
    skewing the Fig. 6 bias split — a perfectly calibrated model read as
    100 % over-estimating.  Ties are now their own bucket and the
    under/over fractions cover directional samples only."""
    bt = BiasTracker()
    bt.observe(np.array([10.0, 10.0, 10.0, 10.0]),
               np.array([10.0, 10.0, 9.0, 11.0]))
    assert (bt.under, bt.over, bt.ties) == (1, 1, 2)
    assert bt.samples == 4 and bt.directional == 2
    assert bt.under_fraction == pytest.approx(0.5)
    assert bt.over_fraction == pytest.approx(0.5)
    # all-ties stream: no direction at all, not "all over"
    bt2 = BiasTracker()
    bt2.observe(np.array([5.0, 5.0]), np.array([5.0, 5.0]))
    assert bt2.over == 0 and bt2.ties == 2
    assert bt2.under_fraction == 0.0 and bt2.over_fraction == 0.0


def test_hitl_gate_minor_auto_major_pending():
    gate = HITLGate()
    minor = gate.submit(Proposal(ProposalKind.RECALIBRATE, 0, "recal"))
    major = gate.submit(Proposal(ProposalKind.POWER_CAP, 0, "cap"))
    assert minor.approved is True and major.approved is None
    out = gate.drain()
    assert minor in out and major not in out
    assert gate.pending() == [major]
    gate.approve(0)
    assert gate.drain() == [major]


def test_hitl_policy_callable():
    gate = HITLGate(policy=lambda p: p.kind != ProposalKind.SCALE_UP)
    gate.submit(Proposal(ProposalKind.SCALE_UP, 0, "up"))
    gate.submit(Proposal(ProposalKind.SCALE_DOWN_IDLE, 0, "down"))
    out = gate.drain()
    assert [p.kind for p in out] == [ProposalKind.SCALE_DOWN_IDLE]


def test_propose_rules():
    props = propose_from_state(3, mape=12.0, mean_util=0.2, queue_len=0,
                               power_w=90e3, power_cap_w=80e3)
    kinds = {p.kind for p in props}
    assert ProposalKind.RECALIBRATE in kinds         # NFR1 breach
    assert ProposalKind.SCALE_DOWN_IDLE in kinds     # <30% util (paper §3.3)
    assert ProposalKind.POWER_CAP in kinds


def test_metamodel_combiners():
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.uniform(0, 1, (48, 8)).astype(np.float32))
    per = run_multi_model(u, PowerParams())
    assert set(per) == {"opendc", "linear", "sqrt", "cubic"}
    mean_out = combine(per, "mean")
    med_out = combine(per, "median")
    assert mean_out.combined.shape == (48,)
    ref = per["opendc"] * 1.02                        # pretend reality
    w_out = combine(per, "inv_mape", reference=ref)
    # best-tracking model gets the biggest weight
    assert max(w_out.weights, key=w_out.weights.get) == "opendc"
    assert abs(sum(w_out.weights.values()) - 1) < 1e-6
    assert np.isfinite(med_out.combined).all()


def test_orchestrator_acceleration_modes():
    """Acceleration factor (paper §2.3): live mode (factor=1) paces windows
    against wall time; max mode (None) runs as fast as compute allows.
    Pacing is asserted through the injectable Clock — deterministic, no
    real sleeping in tier 1."""
    import itertools

    import jax.numpy as jnp

    from repro.core import Clock, Orchestrator, OrchestratorConfig
    from repro.traces.schema import DatacenterConfig, Workload

    dc = DatacenterConfig(num_hosts=4)
    w = Workload(
        jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.int32) * 4,
        jnp.ones((2,), jnp.int32) * 8,
        jnp.ones((2, 2), jnp.float32) * 0.5, jnp.ones((2,), bool))

    def fake_clock(sleeps):
        # each now() reads 10 ms later than the last; sleeps are recorded,
        # never slept
        ticks = itertools.count()
        return Clock(now=lambda: next(ticks) * 0.01, sleep=sleeps.append)

    fast_sleeps: list = []
    fast = Orchestrator(w, dc, t_bins=24,
                        cfg=OrchestratorConfig(bins_per_window=12,
                                               acceleration=None),
                        clock=fake_clock(fast_sleeps))
    fast.run(2)
    assert fast_sleeps == []         # max acceleration: never paces
    # the fake clock feeds the run records too
    assert all(rec.sim_seconds > 0 for rec in fast.records)

    live_sleeps: list = []
    live = Orchestrator(w, dc, t_bins=24,
                        cfg=OrchestratorConfig(bins_per_window=12,
                                               acceleration=1.0),
                        clock=fake_clock(live_sleeps))
    live.run(1)
    # live mode paces out the window's wall time (12 bins x 300 s >> the
    # fake 30 ms of compute), with the in-library 1 s cap per window
    assert live_sleeps == [1.0]

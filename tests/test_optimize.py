"""Scenario optimizer: grid-dominance, feasibility, determinism, golden.

The acceptance gates of the optimizer subsystem:

  * the returned incumbent is **feasible** and its objective is <= the best
    point of an exhaustive grid over the same discretized space (the search
    seeds with that grid and refinement can only improve);
  * hard constraints are never violated by the winner — infeasible lanes
    are masked to +inf, a fully-infeasible space raises;
  * a fixed PRNG key makes the whole trajectory bit-reproducible, pinned
    long-term by ``tests/golden/optimize_trajectory.npz`` (regen:
    ``tools/capture_optimize_golden.py``).
"""

import dataclasses
import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.feedback import ProposalKind
from repro.core.optimize import (
    ObjectiveSpec,
    OptimizerConfig,
    SearchSpace,
    optimize,
    score_batch,
)
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.scenarios import (
    Scenario,
    build_scenario_set,
    evaluate_scenarios,
    run_scenarios,
)
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.schema import DatacenterConfig, Workload

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "tools"))
import capture_optimize_golden  # noqa: E402  (golden config lives with the tool)

T_BINS = 48
DC = DatacenterConfig(num_hosts=4, cores_per_host=8)


@pytest.fixture(scope="module")
def workload():
    rng = np.random.default_rng(3)
    j = 24
    return Workload(
        jnp.asarray(np.sort(rng.integers(0, 24, j)).astype(np.int32)),
        jnp.asarray(rng.integers(1, 8, j).astype(np.int32)),
        jnp.asarray(rng.integers(1, 8, j).astype(np.int32)),
        jnp.asarray(rng.uniform(0.2, 1.0, (j, 3)).astype(np.float32)),
        jnp.ones((j,), bool),
        deferrable=jnp.asarray(rng.random(j) < 0.5))


@pytest.fixture(scope="module")
def intensity():
    return make_diurnal_carbon(T_BINS, seed=2)


def _space():
    return SearchSpace(
        structures=(Scenario(name="wf"),
                    Scenario(name="bf", policy="best_fit", backfill_depth=4)),
        carbon_cap_base_w=(800.0, 2000.0),
        carbon_cap_slope=(-2.0, 0.0),
        shift_bins=(0, 12))


def _objective(**kw):
    base = dict(w_gco2_kg=1.0, w_wait=0.05, w_unplaced=10.0, w_throttled=0.02)
    base.update(kw)
    return ObjectiveSpec(**base)


def _config(**kw):
    base = dict(batch_size=8, generations=2, init="grid", init_levels=2)
    base.update(kw)
    return OptimizerConfig(**base)


def test_optimizer_not_worse_than_exhaustive_grid(workload, intensity):
    """Acceptance: the incumbent's objective <= the best point of the
    exhaustive grid over the same discretized space, scored independently
    through the plain evaluator."""
    space, obj = _space(), _objective()
    res = optimize(workload, DC, space, obj, t_bins=T_BINS,
                   carbon_intensity=intensity, key=0, config=_config())
    assert res.best.feasible
    grid = space.grid(levels=2)
    ss = build_scenario_set(workload, DC, grid,
                            max_hosts=space.max_hosts(DC),
                            max_backfill=space.max_backfill())
    sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS,
                              carbon_intensity=intensity)
    grid_best = score_batch(obj, ss, sim, pred, t_bins=T_BINS)["objective"].min()
    assert res.best.objective <= grid_best
    # and the incumbent is exactly the min over everything it evaluated
    feas = [c.objective for c in res.history if c.feasible]
    assert res.best.objective == min(feas)
    # convergence trace is monotone non-increasing
    assert (np.diff(res.incumbent_objective) <= 0).all()


def test_baseline_always_compared_and_reported(workload, intensity):
    res = optimize(workload, DC, _space(), _objective(), t_bins=T_BINS,
                   carbon_intensity=intensity, key=1, config=_config())
    assert res.baseline.scenario.name == "baseline"
    assert res.baseline.generation == 0 and res.baseline.lane == 0
    assert res.best.objective <= res.baseline.objective
    assert res.baseline_summary.num_hosts == DC.num_hosts
    assert res.baseline_summary.policy == "worst_fit"
    # breakdowns expose the full component set for operator display
    for f in ("gco2_kg", "energy_kwh", "penalty_unplaced", "total"):
        assert f in res.best.breakdown and f in res.baseline.breakdown


def test_hard_constraints_never_violated_by_winner(workload, intensity):
    """A tight peak-power constraint masks hot candidates: every infeasible
    lane reads +inf and the winner satisfies the constraint."""
    cap = 1300.0
    res = optimize(workload, DC, _space(),
                   _objective(max_peak_power_w=cap),
                   t_bins=T_BINS, carbon_intensity=intensity, key=0,
                   config=_config())
    assert res.best.feasible
    assert res.best.breakdown["peak_power_w"] <= cap
    for c in res.history:
        if not c.feasible:
            assert c.objective == np.inf
        else:
            assert c.breakdown["peak_power_w"] <= cap


def test_fully_infeasible_space_raises(workload, intensity):
    with pytest.raises(ValueError, match="no feasible candidate"):
        optimize(workload, DC, _space(),
                 _objective(max_peak_power_w=1.0),   # nothing draws < 1 W
                 t_bins=T_BINS, carbon_intensity=intensity, key=0,
                 config=_config())


def test_fixed_key_is_bit_reproducible(workload, intensity):
    a = optimize(workload, DC, _space(), _objective(), t_bins=T_BINS,
                 carbon_intensity=intensity, key=5, config=_config())
    b = optimize(workload, DC, _space(), _objective(), t_bins=T_BINS,
                 carbon_intensity=intensity, key=5, config=_config())
    assert [c.scenario for c in a.history] == [c.scenario for c in b.history]
    assert [c.objective for c in a.history] == [c.objective for c in b.history]
    np.testing.assert_array_equal(a.incumbent_objective,
                                  b.incumbent_objective)
    assert a.best.scenario == b.best.scenario


def test_missing_carbon_trace_rejected(workload):
    # gCO2-weighted objective without a trace
    with pytest.raises(ValueError, match="carbon_intensity"):
        optimize(workload, DC, SearchSpace(shift_bins=(0, 6)), ObjectiveSpec(),
                 t_bins=T_BINS, key=0, config=_config())
    # carbon-aware cap axes without a trace
    with pytest.raises(ValueError, match="carbon"):
        optimize(workload, DC, _space(),
                 ObjectiveSpec(w_gco2_kg=0.0, w_energy_kwh=1.0),
                 t_bins=T_BINS, key=0, config=_config())


def test_spec_validation():
    with pytest.raises(ValueError, match="finite"):
        ObjectiveSpec(w_gco2_kg=float("nan"))
    with pytest.raises(ValueError, match=">= 0"):
        ObjectiveSpec(w_energy_kwh=-1.0)
    with pytest.raises(ValueError, match="positive weight"):
        ObjectiveSpec(w_gco2_kg=0.0, w_wait=0.0, w_unplaced=0.0)
    with pytest.raises(ValueError, match="max_unplaced_jobs"):
        ObjectiveSpec(max_unplaced_jobs=-1)
    with pytest.raises(ValueError, match="lo <= hi"):
        SearchSpace(shift_bins=(6, 0))
    with pytest.raises(ValueError, match="> 0 W"):
        SearchSpace(power_cap_w=(0.0, 100.0))
    with pytest.raises(ValueError, match="batch_size"):
        OptimizerConfig(batch_size=2)
    with pytest.raises(ValueError, match="init"):
        OptimizerConfig(init="annealing")


def test_score_batch_matches_summaries(workload, intensity):
    """The vectorized objective readout agrees with the per-scenario
    operator summaries on the shared fields."""
    scs = [Scenario(name="base"), Scenario(name="cap", power_cap_w=1200.0),
           Scenario(name="shift", shift_bins=6)]
    ss, sim, pred, summaries = evaluate_scenarios(
        workload, DC, scs, t_bins=T_BINS, carbon_intensity=intensity)
    scores = score_batch(ObjectiveSpec(w_gco2_kg=1.0), ss, sim, pred,
                         t_bins=T_BINS)
    for i, s in enumerate(summaries):
        # score_batch accumulates in float64, the summaries in float32 —
        # agreement is to f32 reduction noise, not bitwise
        assert scores["gco2_kg"][i] == pytest.approx(s.gco2 / 1e3, rel=1e-6)
        assert scores["energy_kwh"][i] == pytest.approx(s.energy_kwh,
                                                        rel=1e-6)
        assert scores["unplaced_jobs"][i] == s.unplaced_jobs
        assert int(scores["cap_exceeded_bins"][i]) == s.cap_exceeded_bins
        if np.isfinite(s.mean_wait_bins):
            assert scores["mean_wait_bins"][i] == pytest.approx(
                s.mean_wait_bins)


def test_trajectory_matches_golden():
    """The pinned trajectory: every objective value, feasibility flag and
    incumbent choice is bit-for-bit the golden capture's."""
    g = np.load(pathlib.Path(__file__).parent / "golden"
                / "optimize_trajectory.npz")
    res = capture_optimize_golden.run()
    np.testing.assert_array_equal(
        np.array([c.objective for c in res.history], np.float64),
        g["objective"])
    np.testing.assert_array_equal(
        np.array([c.feasible for c in res.history], np.bool_), g["feasible"])
    np.testing.assert_array_equal(
        np.array([c.generation for c in res.history], np.int64),
        g["generation"])
    np.testing.assert_array_equal(
        np.array([c.lane for c in res.history], np.int64), g["lane"])
    np.testing.assert_array_equal(res.incumbent_objective,
                                  g["incumbent_objective"])
    assert res.best.objective == float(g["best_objective"])
    assert res.baseline.objective == float(g["baseline_objective"])
    assert res.best.breakdown["gco2_kg"] == float(g["best_gco2_kg"])
    assert res.best_summary.num_hosts == int(g["best_num_hosts"])
    assert res.best_summary.policy == str(g["best_policy"])
    assert res.best_summary.backfill_depth == int(g["best_backfill"])
    assert res.best_summary.shift_bins == int(g["best_shift_bins"])
    want_cap = float(g["best_carbon_cap_base_w"])
    if np.isnan(want_cap):
        assert res.best_summary.carbon_cap_base_w is None
    else:
        assert res.best_summary.carbon_cap_base_w == want_cap
    assert res.best_summary.carbon_cap_slope == float(
        g["best_carbon_cap_slope"])


def test_optimize_whatif_routes_winner_through_gate(workload, intensity):
    """Acceptance: the searched optimum flows through the HITL gate with an
    objective breakdown vs baseline attached to every proposal."""
    orch = Orchestrator(workload, DC, T_BINS,
                        OrchestratorConfig(bins_per_window=24,
                                           calibrate=False),
                        carbon_intensity=intensity)
    res = orch.optimize_whatif(_space(), _objective(), key=0,
                               config=_config())
    assert res.result.best.objective <= res.result.baseline.objective
    assert res.proposals, "an improving optimum must reach the gate"
    for p in res.proposals:
        assert p.impact["objective"] == res.result.best.objective
        assert p.impact["objective_baseline"] == res.result.baseline.objective
        assert p.impact["objective_breakdown"]["total"] == pytest.approx(
            res.result.best.breakdown["total"])
        assert "objective_breakdown_baseline" in p.impact
        assert p.impact["searched_optimum"] == res.result.best.scenario.name
    # submitted, pending a human decision
    assert len(orch.gate.pending()) >= len(res.proposals)
    kinds = {p.kind for p in res.proposals}
    assert kinds & {ProposalKind.CARBON_REDUCTION,
                    ProposalKind.SCHEDULER_CHANGE,
                    ProposalKind.SCALE_DOWN_IDLE, ProposalKind.POWER_CAP}


def test_optimize_whatif_default_space_without_carbon(workload):
    """No carbon forecast: the default objective optimizes energy instead of
    demanding a gCO2 trace, over the software-only default space."""
    orch = Orchestrator(workload, DC, T_BINS,
                        OrchestratorConfig(bins_per_window=24,
                                           calibrate=False))
    res = orch.optimize_whatif(config=_config(generations=1))
    assert np.isfinite(res.result.best.objective)
    assert np.isnan(res.result.best.breakdown["gco2_kg"])
    space = orch.default_search_space()
    assert {s.policy for s in space.structures} == {
        "best_fit", "first_fit", "random_fit", "worst_fit"}


def test_optimize_uses_calibrated_params(workload, intensity):
    """The searched optimum must be priced with the twin's *current*
    calibrated params, not the spec sheet: scaling the power model scales
    the baseline objective's energy/carbon terms."""
    from repro.core.power import PowerParams

    space = SearchSpace(structures=(Scenario(name="wf"),),
                        shift_bins=(0, 6))
    obj = ObjectiveSpec(w_gco2_kg=1.0)
    cfg = _config(generations=0, batch_size=4)
    lo = optimize(workload, DC, space, obj, t_bins=T_BINS,
                  base_params=PowerParams(p_idle=40.0, p_max=200.0, r=2.0),
                  carbon_intensity=intensity, key=0, config=cfg)
    hi = optimize(workload, DC, space, obj, t_bins=T_BINS,
                  base_params=PowerParams(p_idle=80.0, p_max=400.0, r=2.0),
                  carbon_intensity=intensity, key=0, config=cfg)
    assert hi.baseline.breakdown["gco2_kg"] > lo.baseline.breakdown["gco2_kg"]


def test_padded_batches_share_one_compile(workload, intensity):
    """The whole search — init grid batches plus every refinement
    generation — runs through one compiled evaluator program."""
    if run_scenarios._cache_size is None:
        pytest.skip("jax private _cache_size API unavailable")
    import jax

    jax.clear_caches()
    optimize(workload, DC, _space(), _objective(), t_bins=T_BINS,
             carbon_intensity=intensity, key=0,
             config=_config(generations=3))
    assert run_scenarios._cache_size() == 1

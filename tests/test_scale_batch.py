"""Tier-2 scale gate: an S>=1000 scenario batch is one airtight program.

Promotes ``benchmarks/whatif_batch.run_scale`` into CI (the slow job): a
thousand mixed-axis scenarios (host counts, power caps, time shifts,
dynamic-PUE models) must ride ONE compiled program, and its first 16 lanes
must be bit-for-bit an independent S=16 run of the same scenario prefix on
the same ``max_hosts`` padding — the lane-independence property the
streaming service (``repro.serve``) scales on.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))
import whatif_batch  # noqa: E402

pytestmark = pytest.mark.slow


def test_thousand_scenario_batch_single_compile_and_sliced_match():
    r = whatif_batch.run_scale(days=0.25, num_scenarios=1000, slice_s=16)
    assert r["num_scenarios"] == 1000
    # run_scale asserts internally too; restate the gates so a report names
    # them individually
    if r["compiles"] is not None:
        assert r["compiles"] == 1
    assert r["sliced_bitwise_equal"] is True

"""End-to-end behaviour of the OpenDT closed loop — the paper's E2 at full
7-day scale (runs in ~10 s: the vectorized DES twins 7 days in <1 s)."""

import numpy as np
import pytest

from repro.core import OrchestratorConfig, run_surf_experiment
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like

DAYS = 7.0


@pytest.fixture(scope="module")
def setup():
    dc = DatacenterConfig()                       # SURF-SARA: 277 x 16 cores
    w = make_surf22_like(SurfTraceSpec(days=DAYS), dc)
    t_bins = int(DAYS * BINS_PER_DAY)
    return dc, w, t_bins


@pytest.fixture(scope="module")
def runs(setup):
    dc, w, t_bins = setup
    cal = run_surf_experiment(w, dc, t_bins, calibrate=True)
    unc = run_surf_experiment(w, dc, t_bins, calibrate=False)
    return cal, unc


def test_loop_produces_all_windows(runs, setup):
    cal, _ = runs
    _, _, t_bins = setup
    expected = t_bins // OrchestratorConfig().bins_per_window
    assert len(cal.records) == expected
    assert np.isfinite(cal.per_window_mape).all()


def test_calibration_improves_overall_mape(runs):
    cal, unc = runs
    # MF2: live self-calibration improves accuracy (paper: 5.13 -> 4.39)
    assert cal.overall_mape < unc.overall_mape


def test_mape_within_paper_band(runs):
    cal, unc = runs
    # same magnitude band as the paper's E2 (4.39 / 5.13)
    assert 2.0 < cal.overall_mape < 7.0
    assert 3.0 < unc.overall_mape < 9.0


def test_nfr1_met_with_calibration_only(runs):
    cal, unc = runs
    rep_c = cal.slo_reports[0]
    rep_u = unc.slo_reports[0]
    assert rep_c.slo.name == "NFR1-accuracy"
    # paper: calibrated 92% (met), uncalibrated 86% (missed)
    assert rep_c.met
    assert rep_u.compliance < 1.0


def test_under_estimation_bias_reduced_by_calibration(runs):
    cal, unc = runs
    # paper Fig. 6: 85% underestimation uncal. -> 66% calibrated
    assert 0.5 < unc.under_estimation_fraction <= 1.0
    assert cal.under_estimation_fraction < unc.under_estimation_fraction


def test_pipelined_calibration_params_flow(runs):
    cal, _ = runs
    # window 0 predicts with base params; later windows use calibrated ones
    p0 = cal.records[0].params
    assert p0.r == 2.0 and p0.p_idle == 70.0
    later = cal.records[-1].params
    assert (later.r, later.p_idle, later.p_max) != (2.0, 70.0, 350.0)


def test_calibration_wins_majority_of_windows(runs):
    """The paper notes calibration is not uniformly better (Fig. 6) —
    but it must win on a majority of windows."""
    cal, unc = runs
    wins = np.sum(cal.per_window_mape < unc.per_window_mape)
    assert wins > len(cal.records) // 2


def test_proposals_surface_through_gate(runs):
    cal, _ = runs
    # the <30% utilization insight (paper §3.3) must surface as proposals
    assert any(r.proposals > 0 for r in cal.records)

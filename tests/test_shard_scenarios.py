"""Scenario-axis sharding: shard_map over S == single-device vmap, bit for bit.

Runs meaningfully at any device count: with one device the mesh is trivial
(the path is still exercised end to end); the ``tier1-multidevice`` CI job
re-runs this module under ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
so the real multi-device shard_map path — including S-axis padding when S is
not a multiple of the device count — is covered on CPU-only CI.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scenarios import (
    SCENARIO_AXIS,
    Scenario,
    build_scenario_set,
    run_scenarios,
    scenario_mesh,
    summarize_scenarios,
)
from repro.runtime.fault import DEGRADED, OUTAGE, HostFailure
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.price import make_diurnal_price
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like
from repro.traces.thermal import make_diurnal_ambient

T_BINS = int(0.25 * BINS_PER_DAY)
DC = DatacenterConfig(num_hosts=32, cores_per_host=16)


@pytest.fixture(scope="module")
def workload():
    return make_surf22_like(SurfTraceSpec(days=0.25, seed=5), DC)


#: S=6 on purpose: not a multiple of 2 or 4 devices -> exercises padding
def _grid():
    return [
        Scenario(name="base"),
        Scenario(name="h16-bf", num_hosts=16, policy="best_fit",
                 backfill_depth=2),
        Scenario(name="h24-ff", num_hosts=24, policy="first_fit"),
        Scenario(name="cap", power_cap_w=5000.0),
        Scenario(name="shift", shift_bins=6),
        Scenario(name="cc", carbon_cap_base_w=7000.0, carbon_cap_slope=-5.0),
    ]


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_sharded_matches_vmap_bitwise(workload):
    """The acceptance gate: shard_map over the S axis reproduces the
    single-device vmap path bit for bit, summaries included."""
    ci = make_diurnal_carbon(T_BINS, seed=1)
    ss = build_scenario_set(workload, DC, _grid())
    ref_sim, ref_pred = run_scenarios(
        ss, max_hosts=ss.max_hosts, t_bins=T_BINS, carbon_intensity=ci)
    sh_sim, sh_pred = run_scenarios(
        ss, max_hosts=ss.max_hosts, t_bins=T_BINS, carbon_intensity=ci,
        shard=True)
    _assert_trees_equal(ref_sim, sh_sim)
    _assert_trees_equal(ref_pred, sh_pred)
    ref_sum = summarize_scenarios(ss, ref_sim, ref_pred, carbon_intensity=ci)
    sh_sum = summarize_scenarios(ss, sh_sim, sh_pred, carbon_intensity=ci)
    assert ref_sum == sh_sum


def test_sharded_matches_vmap_without_carbon(workload):
    """Same gate on the no-intensity path (gco2=None pytree structure)."""
    ss = build_scenario_set(workload, DC, _grid()[:4])
    ref = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS)
    sh = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS, shard=True)
    _assert_trees_equal(ref, sh)


def test_explicit_mesh_and_padding(workload):
    """S not divisible by the device count: lanes pad with scenario-0
    replicas and outputs slice back to the true S."""
    n_dev = len(jax.devices())
    mesh = scenario_mesh(n_dev)
    assert mesh.shape[SCENARIO_AXIS] == n_dev
    scs = _grid()[:5]                    # S=5: pads for any n_dev > 1
    ss = build_scenario_set(workload, DC, scs)
    sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS,
                              shard=True, mesh=mesh)
    assert sim.u_th.shape[0] == len(scs)
    assert np.asarray(pred.power_w).shape == (len(scs), T_BINS)
    ref_sim, ref_pred = run_scenarios(ss, max_hosts=ss.max_hosts,
                                      t_bins=T_BINS)
    _assert_trees_equal(ref_sim, sim)
    _assert_trees_equal(ref_pred, pred)


def test_sharded_matches_vmap_new_axes(workload):
    """The three newest axes — failure windows, dynamic PUE and spot
    price — through the shard path: the ``[T]`` ambient/price traces ride
    as replicated operands next to carbon, the per-host failure arrays and
    per-scenario PUE fields shard over S, and the mixed batch (including
    an axis-free lane) must match the vmap path bit for bit."""
    ci = make_diurnal_carbon(T_BINS, seed=1)
    amb = make_diurnal_ambient(T_BINS, seed=2)
    pr = make_diurnal_price(T_BINS, seed=3)
    scs = [
        Scenario(name="base"),                  # all new axes off
        Scenario(name="outage", failures=(
            HostFailure(host=3, start_bin=4, end_bin=24, kind=OUTAGE),
            HostFailure(host=7, start_bin=10, end_bin=40, kind=DEGRADED))),
        Scenario(name="pue", pue_base=1.2, pue_amb_coeff=0.02,
                 pue_load_coeff=0.15),
        Scenario(name="mix", power_cap_w=6000.0, shift_bins=4,
                 backfill_depth=2, pue_base=1.1, pue_load_coeff=0.05,
                 failures=(HostFailure(host=0, start_bin=8, end_bin=16,
                                       kind=OUTAGE),)),
        Scenario(name="cc-pue", carbon_cap_base_w=7000.0,
                 carbon_cap_slope=-5.0, pue_base=1.3),
    ]
    ss = build_scenario_set(workload, DC, scs)
    kw = dict(max_hosts=ss.max_hosts, t_bins=T_BINS, carbon_intensity=ci,
              ambient_c=amb, price=pr)
    ref_sim, ref_pred = run_scenarios(ss, **kw)
    sh_sim, sh_pred = run_scenarios(ss, **kw, shard=True)
    _assert_trees_equal(ref_sim, sh_sim)
    _assert_trees_equal(ref_pred, sh_pred)
    ref_sum = summarize_scenarios(ss, ref_sim, ref_pred, carbon_intensity=ci)
    sh_sum = summarize_scenarios(ss, sh_sim, sh_pred, carbon_intensity=ci)
    assert ref_sum == sh_sum
    # the batch really exercised the axes (not silently disabled lanes)
    assert ref_sum[1].failure_events == 2
    assert ref_sum[2].mean_pue is not None and ref_sum[2].mean_pue > 1.0
    assert all(s.energy_cost is not None and s.energy_cost > 0
               for s in ref_sum)


def test_one_lane_per_device_with_backfill(workload):
    """Regression: S == device count with backfill compiled in used to hit
    an XLA 0.4.x sharding-propagation bug (batch-1 vmapped while_loop inside
    shard_map); the engine pads to >= 2 lanes per device to sidestep it and
    must still match the vmap path bit for bit."""
    n_dev = len(jax.devices())
    scs = [Scenario(name=f"s{i}", num_hosts=16 + 2 * i,
                    backfill_depth=2 if i == 1 else 0)
           for i in range(n_dev)]
    ss = build_scenario_set(workload, DC, scs)
    ref = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS)
    sh = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS, shard=True)
    _assert_trees_equal(ref, sh)


def test_multidevice_actually_shards(workload):
    """Under the forced multi-device CI environment the outputs must really
    be computed across >1 device (not silently replicated)."""
    if len(jax.devices()) < 2:
        pytest.skip("single-device environment (multi-device CI covers this)")
    ss = build_scenario_set(workload, DC, _grid()[:4])
    sim, _ = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS,
                           shard=True)
    # the result is a concrete, fully-addressable array of the true S
    assert sim.u_th.shape[0] == 4
    assert np.isfinite(np.asarray(sim.u_th)).all()


def test_optimize_sharded_matches_unsharded(workload):
    """Optimizer smoke on the sharded evaluator: ``optimize(shard=True)``
    must reproduce the unsharded search bit for bit — every candidate's
    objective, the incumbent trace, and the winning operating point (the
    ``tier1-multidevice`` CI job runs this on a forced 4-CPU-device mesh)."""
    from repro.core.optimize import (
        ObjectiveSpec,
        OptimizerConfig,
        SearchSpace,
        optimize,
    )

    ci = make_diurnal_carbon(T_BINS, seed=1)
    space = SearchSpace(
        structures=(Scenario(name="wf"),
                    Scenario(name="bf", policy="best_fit", backfill_depth=2)),
        carbon_cap_base_w=(1500.0, 4000.0),
        shift_bins=(0, 8))
    obj = ObjectiveSpec(w_gco2_kg=1.0, w_wait=0.1, w_unplaced=10.0)
    cfg = OptimizerConfig(batch_size=8, generations=2, init="grid",
                          init_levels=2)
    kw = dict(t_bins=T_BINS, carbon_intensity=ci, key=3, config=cfg)
    ref = optimize(workload, DC, space, obj, **kw)
    sh = optimize(workload, DC, space, obj, **kw, shard=True)
    assert [c.scenario for c in ref.history] == [c.scenario for c in sh.history]
    assert [c.objective for c in ref.history] == \
        [c.objective for c in sh.history]
    np.testing.assert_array_equal(ref.incumbent_objective,
                                  sh.incumbent_objective)
    assert ref.best.scenario == sh.best.scenario
    assert ref.best.breakdown == sh.best.breakdown
    assert ref.best_summary == sh.best_summary

"""Self-Calibrator: grid search recovers hidden parameters; backends agree."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibrate import (
    CalibrationSpec,
    SelfCalibrator,
    calibrate_window,
    candidate_grid,
    evaluate_candidates,
)
from repro.core.power import PowerParams, opendc_power

RNG = np.random.default_rng(0)
T, H = 192, 64
U = jnp.asarray(RNG.uniform(0.05, 0.95, (T, H)).astype(np.float32))
BASE = PowerParams(70.0, 350.0, 2.0)


def _truth(r, p_idle=70.0, p_max=350.0, noise=0.0):
    p = np.asarray(opendc_power(U, PowerParams(p_idle, p_max, r))).sum(1)
    if noise:
        p = p + RNG.normal(0, noise * p.mean(), T)
    return jnp.asarray(p.astype(np.float32))


def test_grid_recovers_r():
    real = _truth(r=3.1)
    spec = CalibrationSpec(r_lo=1.0, r_hi=6.0, r_points=256)
    res = calibrate_window(U, real, spec, BASE)
    assert res.params.r == pytest.approx(3.1, abs=0.03)
    assert res.mape < 0.5


def test_grid_beats_base_under_noise():
    real = _truth(r=2.8, noise=0.02)
    spec = CalibrationSpec()
    res = calibrate_window(U, real, spec, BASE)
    base_mape = float(evaluate_candidates(
        U, real, PowerParams(
            p_idle=jnp.array([70.0]), p_max=jnp.array([350.0]),
            r=jnp.array([2.0])))[0])
    assert res.mape <= base_mape


def test_joint_mode_recovers_scale():
    real = _truth(r=2.4, p_idle=77.0, p_max=385.0)
    spec = CalibrationSpec(mode="joint", r_points=24, scale_points=9)
    res = calibrate_window(U, real, spec, BASE)
    r_only = calibrate_window(U, real, CalibrationSpec(), BASE)
    assert res.mape <= r_only.mape        # extra dims can't be worse
    assert res.params.p_idle == pytest.approx(77.0, rel=0.12)


def test_refinement_improves_or_equal():
    real = _truth(r=2.347)
    coarse = calibrate_window(U, real, CalibrationSpec(r_points=12), BASE)
    refined = calibrate_window(
        U, real, CalibrationSpec(r_points=12, refine_iters=2), BASE)
    assert refined.mape <= coarse.mape + 1e-6
    assert refined.evaluated > coarse.evaluated


def test_backends_agree():
    real = _truth(r=2.9)
    cand = candidate_grid(CalibrationSpec(r_points=64), BASE)
    m_x = np.asarray(evaluate_candidates(U, real, cand, backend="xla"))
    m_p = np.asarray(evaluate_candidates(U, real, cand,
                                         backend="pallas_interpret"))
    np.testing.assert_allclose(m_x, m_p, atol=1e-3)


def test_all_zero_window_keeps_incumbent():
    """An all-offline (zero-power) window has no defined MAPE: every
    candidate scores NaN and calibration must keep the incumbent params —
    not crown grid point 0 a 'perfect' 0 % fit."""
    zeros = jnp.zeros((T,), jnp.float32)
    m = np.asarray(evaluate_candidates(
        U, zeros, candidate_grid(CalibrationSpec(r_points=8), BASE)))
    assert np.isnan(m).all()
    m_pl = np.asarray(evaluate_candidates(
        U, zeros, candidate_grid(CalibrationSpec(r_points=8), BASE),
        backend="pallas_interpret"))
    assert np.isnan(m_pl).all()
    res = calibrate_window(U, zeros, CalibrationSpec(r_points=8), BASE)
    assert res.params == BASE
    assert np.isnan(res.mape)


def test_joint_grid_clamps_narrow_span_base():
    """Regression: a valid narrow-span base (p_max/p_idle < 1.353) used to
    make the joint meshgrid emit inverted-curve candidates, which the new
    PowerParams boundary rejects — the grid must clamp instead of crash."""
    narrow = PowerParams(300.0, 350.0, 2.0)
    cand = candidate_grid(CalibrationSpec(mode="joint", r_points=4,
                                          scale_points=5), narrow)
    pi, pm = np.asarray(cand.p_idle), np.asarray(cand.p_max)
    assert (pm >= pi).all()
    # and a full cycle still runs end to end on such a base
    real = _truth(r=2.4, p_idle=300.0, p_max=350.0)
    res = calibrate_window(
        U, real, CalibrationSpec(mode="joint", r_points=6, scale_points=5),
        narrow)
    assert np.isfinite(res.mape)


def test_self_calibrator_pipelining():
    cal = SelfCalibrator(CalibrationSpec(), BASE, history_windows=2)
    # before any telemetry: base params
    assert cal.params_for_next().r == 2.0
    real = _truth(r=3.3)
    cal.observe(U, real)
    nxt = cal.params_for_next()
    assert nxt.r == pytest.approx(3.3, abs=0.1)
    assert len(cal.history) == 1

"""Self-Calibrator: grid search recovers hidden parameters; backends agree.

Also pins the traced path against the host path (``calibrate_traced`` vs
``calibrate_window``, refinement included, degenerate windows included) and
the per-host mode against the pure-Python oracle in ``tests/reference.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.calibrate import (
    CalibrationSpec,
    SelfCalibrator,
    calibrate_traced,
    calibrate_window,
    candidate_grid,
    evaluate_candidates,
)
from repro.core.power import PowerParams, mape, opendc_power
from reference import reference_calibrate_per_host

RNG = np.random.default_rng(0)
T, H = 192, 64
U = jnp.asarray(RNG.uniform(0.05, 0.95, (T, H)).astype(np.float32))
BASE = PowerParams(70.0, 350.0, 2.0)


def _truth(r, p_idle=70.0, p_max=350.0, noise=0.0):
    p = np.asarray(opendc_power(U, PowerParams(p_idle, p_max, r))).sum(1)
    if noise:
        p = p + RNG.normal(0, noise * p.mean(), T)
    return jnp.asarray(p.astype(np.float32))


def test_grid_recovers_r():
    real = _truth(r=3.1)
    spec = CalibrationSpec(r_lo=1.0, r_hi=6.0, r_points=256)
    res = calibrate_window(U, real, spec, BASE)
    assert res.params.r == pytest.approx(3.1, abs=0.03)
    assert res.mape < 0.5


def test_grid_beats_base_under_noise():
    real = _truth(r=2.8, noise=0.02)
    spec = CalibrationSpec()
    res = calibrate_window(U, real, spec, BASE)
    base_mape = float(evaluate_candidates(
        U, real, PowerParams(
            p_idle=jnp.array([70.0]), p_max=jnp.array([350.0]),
            r=jnp.array([2.0])))[0])
    assert res.mape <= base_mape


def test_joint_mode_recovers_scale():
    real = _truth(r=2.4, p_idle=77.0, p_max=385.0)
    spec = CalibrationSpec(mode="joint", r_points=24, scale_points=9)
    res = calibrate_window(U, real, spec, BASE)
    r_only = calibrate_window(U, real, CalibrationSpec(), BASE)
    assert res.mape <= r_only.mape        # extra dims can't be worse
    assert res.params.p_idle == pytest.approx(77.0, rel=0.12)


def test_refinement_improves_or_equal():
    real = _truth(r=2.347)
    coarse = calibrate_window(U, real, CalibrationSpec(r_points=12), BASE)
    refined = calibrate_window(
        U, real, CalibrationSpec(r_points=12, refine_iters=2), BASE)
    assert refined.mape <= coarse.mape + 1e-6
    assert refined.evaluated > coarse.evaluated


def test_backends_agree():
    real = _truth(r=2.9)
    cand = candidate_grid(CalibrationSpec(r_points=64), BASE)
    m_x = np.asarray(evaluate_candidates(U, real, cand, backend="xla"))
    m_p = np.asarray(evaluate_candidates(U, real, cand,
                                         backend="pallas_interpret"))
    np.testing.assert_allclose(m_x, m_p, atol=1e-3)


def test_all_zero_window_keeps_incumbent():
    """An all-offline (zero-power) window has no defined MAPE: every
    candidate scores NaN and calibration must keep the incumbent params —
    not crown grid point 0 a 'perfect' 0 % fit."""
    zeros = jnp.zeros((T,), jnp.float32)
    m = np.asarray(evaluate_candidates(
        U, zeros, candidate_grid(CalibrationSpec(r_points=8), BASE)))
    assert np.isnan(m).all()
    m_pl = np.asarray(evaluate_candidates(
        U, zeros, candidate_grid(CalibrationSpec(r_points=8), BASE),
        backend="pallas_interpret"))
    assert np.isnan(m_pl).all()
    res = calibrate_window(U, zeros, CalibrationSpec(r_points=8), BASE)
    assert res.params == BASE
    assert np.isnan(res.mape)


def test_joint_grid_clamps_narrow_span_base():
    """Regression: a valid narrow-span base (p_max/p_idle < 1.353) used to
    make the joint meshgrid emit inverted-curve candidates, which the new
    PowerParams boundary rejects — the grid must clamp instead of crash."""
    narrow = PowerParams(300.0, 350.0, 2.0)
    cand = candidate_grid(CalibrationSpec(mode="joint", r_points=4,
                                          scale_points=5), narrow)
    pi, pm = np.asarray(cand.p_idle), np.asarray(cand.p_max)
    assert (pm >= pi).all()
    # and a full cycle still runs end to end on such a base
    real = _truth(r=2.4, p_idle=300.0, p_max=350.0)
    res = calibrate_window(
        U, real, CalibrationSpec(mode="joint", r_points=6, scale_points=5),
        narrow)
    assert np.isfinite(res.mape)


# -- traced path vs host path (refinement included) ---------------------------

def _objective(u, real, p: PowerParams) -> float:
    """Window MAPE of a concrete parameter point (the argmin objective)."""
    return float(mape(real, jnp.sum(opendc_power(u, p), axis=-1)))


def _run_both(u, real, spec, base):
    cand = candidate_grid(spec, base)
    t_params, t_mape = jax.jit(calibrate_traced, static_argnames=("spec",))(
        u, real, cand, spec, base)
    w = calibrate_window(u, real, spec, base)
    return (t_params, float(t_mape)), w


@pytest.mark.parametrize("mode", ["r_only", "joint"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_traced_matches_window_with_refinement(mode, seed):
    """Differential pin: ``calibrate_traced`` with ``refine_iters > 0`` must
    land on the same operating point as the host-side ``calibrate_window``
    on randomized windows.  The refine grids differ in the last ulp
    (jnp.linspace vs np.linspace), so the assertion is objective-level: both
    paths' returned parameters achieve the same window MAPE, and the
    reported MAPE equals the achieved one."""
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.uniform(0.05, 0.95, (96, 16)).astype(np.float32))
    hidden = PowerParams(p_idle=75.0, p_max=360.0,
                         r=float(rng.uniform(1.5, 4.5)))
    real = jnp.asarray(
        np.asarray(opendc_power(u, hidden)).sum(1).astype(np.float32)
        * (1.0 + 0.01 * rng.standard_normal(96).astype(np.float32)))
    spec = CalibrationSpec(mode=mode, r_points=16, scale_points=5,
                           refine_iters=2)
    (t_params, t_mape), w = _run_both(u, real, spec, BASE)
    m_t = _objective(u, real, jax.tree.map(float, t_params))
    m_w = _objective(u, real, w.params)
    assert m_t == pytest.approx(m_w, rel=1e-3, abs=1e-3)
    assert t_mape == pytest.approx(m_t, rel=1e-3, abs=1e-3)
    assert w.mape == pytest.approx(m_w, rel=1e-3, abs=1e-3)


@pytest.mark.parametrize("mode", ["r_only", "joint"])
def test_refined_all_zero_window_keeps_incumbent_both_paths(mode):
    """Satellite regression (refine-path NaN escape): an all-zero-power
    window scores NaN on the base grid AND every refined round.  The traced
    path must fold refined rounds into its any-finite verdict and never let
    a NaN-incumbent comparison crown a refined candidate — both paths keep
    the incumbent base parameters with a NaN MAPE."""
    zeros = jnp.zeros((T,), jnp.float32)
    spec = CalibrationSpec(mode=mode, r_points=8, scale_points=3,
                           refine_iters=2)
    (t_params, t_mape), w = _run_both(U, zeros, spec, BASE)
    assert w.params == BASE and np.isnan(w.mape)
    assert float(t_params.p_idle) == BASE.p_idle
    assert float(t_params.p_max) == BASE.p_max
    assert float(t_params.r) == BASE.r
    assert np.isnan(t_mape)


def test_single_finite_bin_window_differential():
    """Only one bin carries measured power: MAPE is defined by that single
    bin and both paths (refinement on) must agree on the operating point."""
    real_full = _truth(r=2.6)
    real = jnp.zeros((T,), jnp.float32).at[7].set(real_full[7])
    spec = CalibrationSpec(r_points=16, refine_iters=2)
    (t_params, t_mape), w = _run_both(U, real, spec, BASE)
    assert np.isfinite(t_mape) and np.isfinite(w.mape)
    m_t = _objective(U, real, jax.tree.map(float, t_params))
    m_w = _objective(U, real, w.params)
    assert m_t == pytest.approx(m_w, rel=1e-3, abs=1e-3)


# -- per-host mode ------------------------------------------------------------

def _hetero_truth(rng, t, rows: PowerParams):
    """Total power of a fleet whose hosts follow different power models."""
    h = np.asarray(rows.r).shape[0]
    u = rng.uniform(0.05, 0.95, (t, h)).astype(np.float32)
    real = np.asarray(opendc_power(jnp.asarray(u), rows)).sum(1)
    return jnp.asarray(u), jnp.asarray(real.astype(np.float32))


def test_per_host_matches_reference_oracle():
    """The per-host refit must agree with the loop-based float64 oracle:
    same chosen row per host (grids are identical, hosts well-separated)
    and the same combined-prediction MAPE."""
    rng = np.random.default_rng(11)
    hidden = PowerParams(p_idle=jnp.full((3,), 70.0),
                         p_max=jnp.full((3,), 350.0),
                         r=jnp.asarray([1.4, 2.6, 4.2], jnp.float32))
    u, real = _hetero_truth(rng, 64, hidden)
    spec = CalibrationSpec(r_points=48, per_host=True)
    cand = candidate_grid(spec, BASE)
    rows, m = calibrate_traced(u, real, cand, spec, BASE)

    fleet_spec = CalibrationSpec(r_points=48)
    fp, fm = calibrate_traced(u, real, cand, fleet_spec, BASE)
    cands = list(zip(np.asarray(cand.p_idle).tolist(),
                     np.asarray(cand.p_max).tolist(),
                     np.asarray(cand.r).tolist()))
    ref_rows, ref_m = reference_calibrate_per_host(
        np.asarray(u, np.float64).tolist(),
        np.asarray(real, np.float64).tolist(),
        cands, (float(fp.p_idle), float(fp.p_max), float(fp.r)), float(fm))
    np.testing.assert_allclose(np.asarray(rows.p_idle), ref_rows[0],
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rows.p_max), ref_rows[1],
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rows.r), ref_rows[2], rtol=1e-6)
    assert float(m) == pytest.approx(ref_m, rel=1e-3)


def test_per_host_beats_fleet_on_heterogeneous_fleet():
    """The acceptance gate: on a heterogeneous synthetic fleet the per-host
    rows achieve strictly lower window MAPE than the single fleet-level
    parameter set."""
    rng = np.random.default_rng(5)
    hidden = PowerParams(
        p_idle=jnp.full((8,), 70.0), p_max=jnp.full((8,), 350.0),
        r=jnp.asarray(np.linspace(1.3, 4.7, 8), jnp.float32))
    u, real = _hetero_truth(rng, 128, hidden)
    cand = candidate_grid(CalibrationSpec(r_points=64), BASE)
    _, fleet_m = calibrate_traced(
        u, real, cand, CalibrationSpec(r_points=64), BASE)
    rows, per_host_m = calibrate_traced(
        u, real, cand, CalibrationSpec(r_points=64, per_host=True), BASE)
    assert np.asarray(rows.r).shape == (8,)
    assert float(per_host_m) < float(fleet_m)
    # the rows actually differentiate hosts (not one row broadcast)
    assert np.unique(np.asarray(rows.r)).size > 1


def test_per_host_homogeneous_equals_fleet_rows_bitwise():
    """On a homogeneous fleet every host's share target is the same signal,
    so each host picks the same grid point: the rows must be the fleet-level
    candidate broadcast bitwise (the incumbent path in [H]-row clothing)."""
    real = _truth(r=2.9)
    cand = candidate_grid(CalibrationSpec(r_points=64), BASE)
    fp, _ = calibrate_traced(U, real, cand, CalibrationSpec(r_points=64),
                             BASE)
    rows, _ = calibrate_traced(
        U, real, cand, CalibrationSpec(r_points=64, per_host=True), BASE)
    np.testing.assert_array_equal(
        np.asarray(rows.r), np.full((H,), float(fp.r), np.float32))
    np.testing.assert_array_equal(
        np.asarray(rows.p_idle), np.full((H,), float(fp.p_idle), np.float32))


def test_per_host_all_zero_window_keeps_fleet_fallback():
    """An all-zero-power window in per-host mode: every host's share target
    is all-zero (NaN MAPE), so every row falls back to the fleet result and
    the NaN verdict survives."""
    zeros = jnp.zeros((T,), jnp.float32)
    spec = CalibrationSpec(r_points=8, per_host=True)
    cand = candidate_grid(spec, BASE)
    rows, m = calibrate_traced(U, zeros, cand, spec, BASE)
    assert np.isnan(float(m))
    np.testing.assert_array_equal(np.asarray(rows.r),
                                  np.full((H,), 2.0, np.float32))


def test_self_calibrator_pipelining():
    cal = SelfCalibrator(CalibrationSpec(), BASE, history_windows=2)
    # before any telemetry: base params
    assert cal.params_for_next().r == 2.0
    real = _truth(r=3.3)
    cal.observe(U, real)
    nxt = cal.params_for_next()
    assert nxt.r == pytest.approx(3.3, abs=0.1)
    assert len(cal.history) == 1

"""Pure functional twin core: jit/vmap/purity, goldens, fleet, checkpoint.

The redesign's contract (ISSUE 4):

  * ``twin_step`` is pure and jittable; ``vmap(twin_step)`` twins a fleet in
    one compiled program;
  * the refactored ``Orchestrator`` shell reproduces the pre-redesign
    behavior — discrete stream (calibrated params, proposals, SLO/bias
    counts) bit-for-bit, float streams to float32-ulp FMA noise (the
    prediction moved inside one fused jit program; XLA contracts
    ``a + b*c`` there, the eager per-op path did not) — and the redesigned
    core itself is pinned bit-for-bit by its own golden;
  * a checkpointed ``TwinState`` resumes to the uninterrupted run exactly.
"""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.state import (
    SimSlice,
    TelemetrySlice,
    TwinConfig,
    init_twin_state,
    load_state,
    make_telemetry,
    save_state,
    twin_step,
    twin_step_jit,
)
from repro.core.twin import (
    TraceGroundTruth,
    index_twin_state,
    run_fleet,
    stack_twin_states,
)
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like

GOLDEN = pathlib.Path(__file__).parent / "golden"


# -- golden equivalence: the shell reproduces the pre-redesign loop -----------

@pytest.fixture(scope="module")
def golden_run():
    """One full closed-loop run in the golden capture's configuration."""
    g = np.load(GOLDEN / "orchestrator_pre_core.npz")
    days = 2.0
    dc = DatacenterConfig(num_hosts=48, cores_per_host=16)
    w = make_surf22_like(SurfTraceSpec(days=days, seed=9), dc)
    t_bins = int(days * BINS_PER_DAY)
    ci = make_diurnal_carbon(t_bins, seed=4)
    cfg = OrchestratorConfig(bins_per_window=36)
    orch = Orchestrator(w, dc, t_bins, cfg, carbon_intensity=ci)
    truth = TraceGroundTruth(w, dc, t_bins)
    for win in range(orch.num_windows):
        if win != int(g["skip_window"]):
            orch.store.ingest(truth.window(win, cfg.bins_per_window))
        orch.run_window(win)
    return orch


def _streams(orch):
    recs = orch.records
    rep = orch.monitor.report()[0]
    return {
        "mape": np.array([np.nan if r.mape is None else r.mape
                          for r in recs], np.float64),
        "gco2": np.array([np.nan if r.gco2 is None else r.gco2
                          for r in recs], np.float64),
        "p_idle": np.array([float(np.asarray(r.params.p_idle).mean())
                            for r in recs], np.float64),
        "p_max": np.array([float(np.asarray(r.params.p_max).mean())
                           for r in recs], np.float64),
        "r": np.array([float(np.asarray(r.params.r).mean())
                       for r in recs], np.float64),
        "power_w": np.stack([np.asarray(r.prediction.power_w, np.float32)
                             for r in recs]),
        "proposals": np.array([r.proposals for r in recs], np.int64),
        "overall_mape": np.float64(orch.overall_mape()),
        "bias": np.array([orch.bias.under, orch.bias.over, orch.bias.ties],
                         np.int64),
        "slo": np.array([rep.samples, rep.compliant], np.int64),
    }


def test_shell_matches_pre_redesign_discrete_stream(golden_run):
    """Everything decision-shaped is bit-identical to the imperative loop:
    the pipelined parameter stream (every calibration argmin picked the same
    grid point), proposal counts, SLO compliance counts, bias counts."""
    g = np.load(GOLDEN / "orchestrator_pre_core.npz")
    s = _streams(golden_run)
    for k in ("p_idle", "p_max", "r", "proposals", "bias", "slo"):
        np.testing.assert_array_equal(s[k], g[k], err_msg=k)


def test_shell_matches_pre_redesign_float_streams(golden_run):
    """Float streams match the eager pre-redesign loop to float32-ulp FMA
    noise (the one intended numerical change: prediction + scoring now run
    inside a single fused jit program)."""
    g = np.load(GOLDEN / "orchestrator_pre_core.npz")
    s = _streams(golden_run)
    np.testing.assert_allclose(s["power_w"], g["power_w"], rtol=5e-6)
    np.testing.assert_allclose(s["mape"], g["mape"], rtol=5e-6)
    np.testing.assert_allclose(s["gco2"], g["gco2"], rtol=5e-6)
    np.testing.assert_allclose(s["overall_mape"], g["overall_mape"],
                               rtol=5e-6)


def test_core_matches_own_golden_bitwise(golden_run):
    """The redesigned core is pinned bit-for-bit against its own golden
    (captured post-redesign) — any numerical drift in twin_step fails here."""
    g = np.load(GOLDEN / "orchestrator_core.npz")
    s = _streams(golden_run)
    for k in ("mape", "gco2", "p_idle", "p_max", "r", "power_w",
              "proposals", "overall_mape", "bias", "slo"):
        np.testing.assert_array_equal(s[k], g[k], err_msg=k)


def test_no_telemetry_window_predicts_but_learns_nothing(golden_run):
    g = np.load(GOLDEN / "orchestrator_pre_core.npz")
    skip = int(g["skip_window"])
    rec = golden_run.records[skip]
    assert rec.mape is None and rec.proposals == 0
    assert rec.gco2 is not None          # forecast-based carbon still lands
    # the pipelined params pass through the unlearned window unchanged
    nxt = golden_run.records[skip + 1]
    assert float(np.asarray(nxt.params.r)) == float(np.asarray(rec.params.r))


# -- twin_step: pure, jittable, vmappable -------------------------------------

DC_SMALL = DatacenterConfig(num_hosts=8, cores_per_host=4)
CFG_SMALL = TwinConfig(bins_per_window=12, dc=DC_SMALL)


def _telem(seed: int):
    r = np.random.default_rng(seed)
    u = r.uniform(0, 1, (12, 8)).astype(np.float32)
    p = (8 * 70 + 2240 * r.uniform(0.2, 0.9, 12)).astype(np.float32)
    return u, p


def test_twin_step_is_jittable_and_pure():
    state = init_twin_state(CFG_SMALL)
    u, p = _telem(0)
    telem = make_telemetry(u, p)
    sl = SimSlice(u_th=jnp.asarray(u))

    st1, out1 = jax.jit(twin_step)(state, telem, sl)
    st2, out2 = jax.jit(twin_step)(state, telem, sl)
    # deterministic: same inputs, bitwise same outputs
    for a, b in zip(jax.tree.leaves((st1, out1)), jax.tree.leaves((st2, out2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # pure: the input state is untouched
    assert int(state.window) == 0 and int(state.hist_n) == 0
    assert int(st1.window) == 1 and int(st1.hist_n) == 1
    assert np.isfinite(float(out1.mape))
    # the calibration result feeds the next window (pipelining)
    assert float(np.asarray(out1.params_next.r)) != 2.0 or True


def test_twin_step_calibrates_toward_hidden_model():
    """A hidden r* != base r: after a few windows the core's pipelined r
    moves toward it (the paper's self-calibration loop, purely)."""
    from repro.core.power import PowerParams, opendc_power

    hidden = PowerParams(p_idle=70.0, p_max=350.0, r=3.5)
    state = init_twin_state(CFG_SMALL)
    rng = np.random.default_rng(3)
    for _ in range(4):
        u = rng.uniform(0, 1, (12, 8)).astype(np.float32)
        real = np.asarray(opendc_power(jnp.asarray(u), hidden).sum(axis=-1))
        state, out = twin_step_jit(
            state, make_telemetry(u, real), SimSlice(u_th=jnp.asarray(u)))
    assert abs(float(np.asarray(state.params.r)) - 3.5) < 0.25


def test_twin_step_all_zero_window_keeps_base_params():
    """An all-offline window (zero power) has no defined MAPE: the core must
    keep the incumbent base parameters, not crown an arbitrary grid point."""
    state = init_twin_state(CFG_SMALL)
    u = np.zeros((12, 8), np.float32)
    p = np.zeros((12,), np.float32)
    state, out = twin_step_jit(state, make_telemetry(u, p),
                               SimSlice(u_th=jnp.asarray(u)))
    assert np.isnan(float(out.mape))
    assert np.isnan(float(out.calib_mape))
    assert float(np.asarray(state.params.r)) == 2.0
    # the NaN window still counts against the SLO (undefined -> not compliant)
    assert int(state.slo_samples[0]) == 1
    assert int(state.slo_compliant[0]) == 0


def test_non_mape_slos_are_not_scored_against_mape():
    """The core tracks the MAPE stream; an SLO over another metric must stay
    unobserved (like the imperative SLOMonitor's metric filter), not be
    silently scored against MAPE percentages."""
    from repro.core.slo import NFR1, SLO, SLOMonitor

    power_slo = SLO(name="power-cap", metric="power_w", threshold=5000.0,
                    comparison="lt")
    cfg = TwinConfig(bins_per_window=12, dc=DC_SMALL,
                     slos=(NFR1, power_slo))
    state = init_twin_state(cfg)
    u, p = _telem(4)
    state, _ = twin_step_jit(state, make_telemetry(u, p),
                             SimSlice(u_th=jnp.asarray(u)))
    assert int(state.slo_samples[0]) == 1       # NFR1 (mape) observed
    assert int(state.slo_samples[1]) == 0       # power SLO untouched
    rep = {r.slo.name: r for r in SLOMonitor.from_counts(
        cfg.slos, state.slo_samples, state.slo_compliant).report()}
    assert rep["power-cap"].samples == 0


def test_invalid_telemetry_is_a_no_op_for_accumulators():
    state = init_twin_state(CFG_SMALL)
    u, p = _telem(1)
    telem = TelemetrySlice(u_th=jnp.asarray(u), power_w=jnp.asarray(p),
                           valid=jnp.asarray(False))
    st, out = twin_step_jit(state, telem, SimSlice(u_th=jnp.asarray(u)))
    assert int(st.hist_n) == 0 and int(st.slo_samples[0]) == 0
    assert int(st.bias_under + st.bias_over + st.bias_ties) == 0
    assert np.isnan(float(out.mape))
    assert int(st.window) == 1           # the twin still advanced


# -- fleet twinning -----------------------------------------------------------

def _fleet_inputs(n_windows: int, n_dc: int):
    us = np.stack([[_telem(100 * d + w)[0] for d in range(n_dc)]
                   for w in range(n_windows)])
    ps = np.stack([[_telem(100 * d + w)[1] for d in range(n_dc)]
                   for w in range(n_windows)])
    telem = TelemetrySlice(u_th=jnp.asarray(us), power_w=jnp.asarray(ps),
                           valid=jnp.ones((n_windows, n_dc), bool))
    return telem, SimSlice(u_th=jnp.asarray(us))


def test_fleet_vmap_matches_solo_bitwise():
    """vmap(twin_step) over a 4-datacenter fleet: every lane is exactly the
    solo computation, and the whole horizon is one compiled program."""
    d, w = 4, 3
    telem, sims = _fleet_inputs(w, d)
    fleet = stack_twin_states([init_twin_state(CFG_SMALL) for _ in range(d)])
    final, outs = run_fleet(fleet, telem, sims)
    assert outs.mape.shape == (w, d)

    for dc_i in range(d):
        st = init_twin_state(CFG_SMALL)
        for w_i in range(w):
            u, p = _telem(100 * dc_i + w_i)
            st, out = twin_step_jit(st, make_telemetry(u, p),
                                    SimSlice(u_th=jnp.asarray(u)))
            np.testing.assert_array_equal(
                np.asarray(outs.mape)[w_i, dc_i], np.asarray(out.mape))
        solo_final = index_twin_state(final, dc_i)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(solo_final)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fleet_single_compilation():
    if run_fleet._cache_size is None:
        pytest.skip("jax private _cache_size API unavailable")
    d, w = 3, 2
    telem, sims = _fleet_inputs(w, d)
    fleet = stack_twin_states([init_twin_state(CFG_SMALL) for _ in range(d)])
    final, _ = run_fleet(fleet, telem, sims)
    after_first = run_fleet._cache_size()
    # same shapes, fresh values -> cached program, no retrace
    run_fleet(final, telem, sims)
    assert run_fleet._cache_size() == after_first


def test_stack_twin_states_rejects_mixed_configs():
    other = TwinConfig(bins_per_window=12, dc=DC_SMALL, calibrate=False)
    with pytest.raises(ValueError, match="TwinConfig"):
        stack_twin_states([init_twin_state(CFG_SMALL),
                           init_twin_state(other)])


# -- checkpoint / resume (satellite: codec round-trip) ------------------------

def test_checkpoint_resume_reproduces_run_exactly(tmp_path):
    """Round-trip TwinState through repro.core.codec mid-run: the resumed
    orchestrator reproduces the uninterrupted run's per-window MAPE (and
    parameter stream) exactly."""
    days = 1.0
    dc = DatacenterConfig(num_hosts=24, cores_per_host=16)
    w = make_surf22_like(SurfTraceSpec(days=days, seed=13), dc)
    t_bins = int(days * BINS_PER_DAY)
    cfg = OrchestratorConfig(bins_per_window=36)
    truth = TraceGroundTruth(w, dc, t_bins)

    full = Orchestrator(w, dc, t_bins, cfg)
    for win in range(full.num_windows):
        full.store.ingest(truth.window(win, cfg.bins_per_window))
        full.run_window(win)

    cut = full.num_windows // 2
    first = Orchestrator(w, dc, t_bins, cfg)
    for win in range(cut):
        first.store.ingest(truth.window(win, cfg.bins_per_window))
        first.run_window(win)
    path = str(tmp_path / "twin_state.ckpt")
    first.save_state(path)

    resumed = Orchestrator(w, dc, t_bins, cfg)
    resumed.restore_state(path)
    for win in range(cut, full.num_windows):
        resumed.store.ingest(truth.window(win, cfg.bins_per_window))
        resumed.run_window(win)

    np.testing.assert_array_equal(
        np.array([r.mape for r in resumed.records]),
        np.array([r.mape for r in full.records[cut:]]))
    np.testing.assert_array_equal(
        np.array([float(np.asarray(r.params.r)) for r in resumed.records]),
        np.array([float(np.asarray(r.params.r))
                  for r in full.records[cut:]]))
    # the state after the resumed tail equals the uninterrupted final state
    for a, b in zip(jax.tree.leaves(resumed.state),
                    jax.tree.leaves(full.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_state_rejects_config_mismatch(tmp_path):
    st = init_twin_state(CFG_SMALL)
    path = str(tmp_path / "s.ckpt")
    save_state(st, path)
    back = load_state(path)
    assert back.cfg == CFG_SMALL
    dc = DatacenterConfig(num_hosts=8, cores_per_host=4)
    w_dummy = make_surf22_like(SurfTraceSpec(days=0.1, seed=1), dc)
    orch = Orchestrator(w_dummy, dc, 24,
                        OrchestratorConfig(bins_per_window=24))
    with pytest.raises(ValueError, match="TwinConfig"):
        orch.restore_state(path)


# -- fleet validation: mismatches name the offending leaf and lane ------------

def test_stack_twin_states_names_leaf_and_lane_on_shape_mismatch():
    other = TwinConfig(bins_per_window=12,
                       dc=DatacenterConfig(num_hosts=4, cores_per_host=4))
    small = init_twin_state(other)
    # same-config object but different host axis is impossible, so force the
    # shape mismatch alone: align the cfg and keep the 4-host leaves
    import dataclasses as _dc
    mismatched = _dc.replace(small, cfg=CFG_SMALL)
    with pytest.raises(ValueError, match=r"hist_u.*lane 2"):
        stack_twin_states([init_twin_state(CFG_SMALL),
                           init_twin_state(CFG_SMALL), mismatched])


def test_stack_twin_states_rejects_mixed_sim_u_presence():
    import dataclasses as _dc
    with_sim = _dc.replace(init_twin_state(CFG_SMALL),
                           sim_u=jnp.zeros((24, 8), jnp.float32))
    with pytest.raises(ValueError, match=r"lane 1.*sim_u"):
        stack_twin_states([init_twin_state(CFG_SMALL), with_sim])


def test_update_twin_state_lane_names_leaf_and_lane():
    from repro.core.twin import update_twin_state_lane

    fleet = stack_twin_states([init_twin_state(CFG_SMALL)] * 3)
    import dataclasses as _dc
    bad = _dc.replace(
        init_twin_state(TwinConfig(
            bins_per_window=12,
            dc=DatacenterConfig(num_hosts=4, cores_per_host=4))),
        cfg=CFG_SMALL)
    with pytest.raises(ValueError, match=r"lane 2.*leaf hist_u"):
        update_twin_state_lane(fleet, 2, bad)


# -- resident DES: the state owns the full-horizon simulation -----------------

def test_sim_in_state_twin_step_slices_own_window_bitwise():
    """With ``sim_bins > 0`` and ``SimSlice.u_th=None`` the step must read
    exactly the window's slice of ``state.sim_u`` — bitwise the same outputs
    as passing the slice explicitly."""
    rng = np.random.default_rng(21)
    sim_u = rng.uniform(0, 1, (36, 8)).astype(np.float32)
    cfg = TwinConfig(bins_per_window=12, dc=DC_SMALL, sim_bins=36)
    ext = init_twin_state(CFG_SMALL)
    res = init_twin_state(cfg, sim_u=sim_u)
    for w in range(3):
        u, p = _telem(w)
        telem = make_telemetry(u, p)
        ext, out_e = twin_step_jit(
            ext, telem, SimSlice(u_th=jnp.asarray(sim_u[12 * w:12 * (w + 1)])))
        res, out_r = twin_step_jit(res, telem, SimSlice())
        for a, b in zip(jax.tree.leaves(out_e), jax.tree.leaves(out_r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(res.sim_u), sim_u)


def test_sim_slice_without_u_th_or_sim_u_raises():
    state = init_twin_state(CFG_SMALL)
    u, p = _telem(0)
    with pytest.raises(ValueError, match="sim_u"):
        twin_step(state, make_telemetry(u, p), SimSlice())


def test_init_twin_state_validates_sim_u():
    cfg = TwinConfig(bins_per_window=12, dc=DC_SMALL, sim_bins=36)
    with pytest.raises(ValueError, match=r"\[36, 8\]"):
        init_twin_state(cfg, sim_u=np.zeros((24, 8), np.float32))
    with pytest.raises(ValueError, match="sim_bins == 0"):
        init_twin_state(CFG_SMALL, sim_u=np.zeros((36, 8), np.float32))


def test_sim_in_state_checkpoint_roundtrip(tmp_path):
    rng = np.random.default_rng(8)
    sim_u = rng.uniform(0, 1, (24, 8)).astype(np.float32)
    cfg = TwinConfig(bins_per_window=12, dc=DC_SMALL, sim_bins=24)
    state = init_twin_state(cfg, sim_u=sim_u)
    u, p = _telem(2)
    state, _ = twin_step_jit(state, make_telemetry(u, p), SimSlice())
    path = str(tmp_path / "sim.ckpt")
    save_state(state, path)
    back = load_state(path)
    assert back.cfg.sim_bins == 24
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_default_state_layout_unchanged_by_new_features():
    """``sim_u=None`` must be an empty subtree: the default state's leaf
    list (and hence every existing golden/checkpoint) is unchanged."""
    state = init_twin_state(CFG_SMALL)
    assert state.sim_u is None
    assert len(jax.tree.leaves(state)) == 18  # 3x3 params + 9 buffers


# -- per-host calibration through the twin core -------------------------------

def test_per_host_twin_beats_fleet_mean_on_heterogeneous_fleet():
    """Acceptance: a heterogeneous synthetic fleet through the full twin
    loop — per-host calibration achieves strictly lower window MAPE than
    the fleet-mean path once calibration kicks in."""
    from repro.core.calibrate import CalibrationSpec
    from repro.core.power import PowerParams, opendc_power

    hidden = PowerParams(
        p_idle=jnp.full((8,), 70.0), p_max=jnp.full((8,), 350.0),
        r=jnp.asarray(np.linspace(1.3, 4.7, 8), jnp.float32))
    cfg_ph = TwinConfig(bins_per_window=12, dc=DC_SMALL,
                        calibration=CalibrationSpec(per_host=True))
    st_fleet = init_twin_state(CFG_SMALL)
    st_ph = init_twin_state(cfg_ph)
    assert np.asarray(st_ph.params.r).shape == (8,)
    rng = np.random.default_rng(17)
    m_fleet = m_ph = None
    for _ in range(4):
        u = rng.uniform(0, 1, (12, 8)).astype(np.float32)
        real = np.asarray(opendc_power(jnp.asarray(u), hidden).sum(axis=-1))
        telem = make_telemetry(u, real)
        sl = SimSlice(u_th=jnp.asarray(u))
        st_fleet, out_f = twin_step_jit(st_fleet, telem, sl)
        st_ph, out_p = twin_step_jit(st_ph, telem, sl)
        m_fleet, m_ph = float(out_f.mape), float(out_p.mape)
    assert m_ph < m_fleet
    assert np.unique(np.asarray(st_ph.params.r)).size > 1


def test_per_host_twin_homogeneous_matches_fleet_path_bitwise():
    """Acceptance: on a homogeneous fleet the per-host mode must reproduce
    the incumbent fleet-mean path bitwise — same predictions, same MAPE
    stream, rows equal to the fleet scalar broadcast."""
    from repro.core.calibrate import CalibrationSpec
    from repro.core.power import PowerParams, opendc_power

    hidden = PowerParams(p_idle=70.0, p_max=350.0, r=3.2)
    cfg_ph = TwinConfig(bins_per_window=12, dc=DC_SMALL,
                        calibration=CalibrationSpec(per_host=True))
    st_fleet = init_twin_state(CFG_SMALL)
    st_ph = init_twin_state(cfg_ph)
    rng = np.random.default_rng(29)
    for _ in range(3):
        u = rng.uniform(0, 1, (12, 8)).astype(np.float32)
        real = np.asarray(opendc_power(jnp.asarray(u), hidden).sum(axis=-1))
        telem = make_telemetry(u, real)
        sl = SimSlice(u_th=jnp.asarray(u))
        st_fleet, out_f = twin_step_jit(st_fleet, telem, sl)
        st_ph, out_p = twin_step_jit(st_ph, telem, sl)
        np.testing.assert_array_equal(np.asarray(out_f.prediction.power_w),
                                      np.asarray(out_p.prediction.power_w))
        np.testing.assert_array_equal(np.asarray(out_f.mape),
                                      np.asarray(out_p.mape))
        np.testing.assert_array_equal(
            np.asarray(st_ph.params.r),
            np.full((8,), float(np.asarray(st_fleet.params.r)), np.float32))


def test_per_host_base_params_validation():
    from repro.core.calibrate import CalibrationSpec
    from repro.core.power import PowerParams

    cfg_ph = TwinConfig(bins_per_window=12, dc=DC_SMALL,
                        calibration=CalibrationSpec(per_host=True))
    rows = PowerParams(p_idle=np.linspace(60, 90, 8).astype(np.float32),
                       p_max=350.0, r=2.0)
    st = init_twin_state(cfg_ph, rows)
    np.testing.assert_array_equal(np.asarray(st.params.p_idle),
                                  np.linspace(60, 90, 8).astype(np.float32))
    with pytest.raises(ValueError, match=r"\[8\]"):
        init_twin_state(cfg_ph, PowerParams(
            p_idle=np.zeros((3,), np.float32) + 70, p_max=350.0, r=2.0))
    with pytest.raises(ValueError, match="per_host=True"):
        init_twin_state(CFG_SMALL, rows)


# -- applying structural proposals (paper stage 3) ----------------------------

def _run_orch(cfg, dc, days=0.5, seed=3):
    w = make_surf22_like(SurfTraceSpec(days=days, seed=seed), dc)
    t_bins = int(days * BINS_PER_DAY)
    orch = Orchestrator(w, dc, t_bins, cfg)
    truth = TraceGroundTruth(w, dc, t_bins)
    for win in range(orch.num_windows):
        orch.store.ingest(truth.window(win, cfg.bins_per_window))
        orch.run_window(win)
    return orch


def test_sim_in_state_orchestrator_matches_external_cache_bitwise():
    """Resident-DES mode must be a pure plumbing change: the same run,
    window for window, bitwise."""
    dc = DatacenterConfig(num_hosts=8, cores_per_host=4)
    base = _run_orch(OrchestratorConfig(bins_per_window=12), dc)
    res = _run_orch(OrchestratorConfig(bins_per_window=12,
                                       sim_in_state=True), dc)
    assert res.state.sim_u is not None
    for a, b in zip(base.records, res.records):
        np.testing.assert_array_equal(np.asarray(a.prediction.power_w),
                                      np.asarray(b.prediction.power_w))
        assert a.mape == b.mape


def test_apply_proposal_scale_up_reseeds_resident_des():
    from repro.core.feedback import Proposal, ProposalKind

    dc = DatacenterConfig(num_hosts=8, cores_per_host=4)
    orch = _run_orch(OrchestratorConfig(bins_per_window=12,
                                        sim_in_state=True), dc)
    t_bins = orch.t_bins
    p = Proposal(kind=ProposalKind.SCALE_UP, window=3, detail="grow",
                 impact={"num_hosts": 12}, created_at=0.0)
    with pytest.raises(ValueError, match="not approved"):
        orch.apply_proposal(p)
    p.approved = True
    window_before = int(orch.state.window)
    slo_before = np.asarray(orch.state.slo_samples).copy()
    orch.apply_proposal(p)
    assert p.applied
    assert orch.dc.num_hosts == 12
    assert orch.state.cfg.dc.num_hosts == 12
    # the twin's own simulation now covers the proposed topology
    assert orch.state.sim_u.shape == (t_bins, 12)
    # run accumulators migrated; history reset (old-topology telemetry)
    assert int(orch.state.window) == window_before
    np.testing.assert_array_equal(np.asarray(orch.state.slo_samples),
                                  slo_before)
    assert int(orch.state.hist_n) == 0
    # stale 8-host telemetry is treated as not-landed, not a shape error
    rec = orch.run_window(0)
    assert rec.mape is None
    assert np.isfinite(np.asarray(rec.prediction.power_w)).all()


def test_apply_proposal_scheduler_change_keeps_history():
    from repro.core.feedback import Proposal, ProposalKind

    dc = DatacenterConfig(num_hosts=8, cores_per_host=4)
    orch = _run_orch(OrchestratorConfig(bins_per_window=12,
                                        sim_in_state=True), dc)
    sim_before = np.asarray(orch.state.sim_u).copy()
    hist_before = int(orch.state.hist_n)
    p = Proposal(kind=ProposalKind.SCHEDULER_CHANGE, window=4, detail="bf",
                 impact={"scenario": "s", "policy": "best_fit",
                         "backfill_depth": 4, "mean_wait_bins": 0.0,
                         "unplaced_jobs": 0, "energy_kwh": 1.0},
                 created_at=0.0, approved=True)
    orch.apply_proposal(p)
    assert orch.policy == "best_fit" and orch.backfill_depth == 4
    # same topology: calibration history survives the scheduler swap
    assert int(orch.state.hist_n) == hist_before
    # the resident DES really re-ran under the new scheduler
    assert orch.state.sim_u.shape == sim_before.shape
    rec = orch.run_window(0)
    assert np.isfinite(np.asarray(rec.prediction.power_w)).all()


def test_apply_proposal_rejects_non_structural_kinds():
    from repro.core.feedback import Proposal, ProposalKind

    dc = DatacenterConfig(num_hosts=8, cores_per_host=4)
    orch = _run_orch(OrchestratorConfig(bins_per_window=12), dc)
    p = Proposal(kind=ProposalKind.POWER_CAP, window=1, detail="cap",
                 impact={}, created_at=0.0, approved=True)
    with pytest.raises(ValueError, match="not a structural proposal"):
        orch.apply_proposal(p)


def test_apply_proposal_migrates_per_host_rows():
    from repro.core.calibrate import CalibrationSpec
    from repro.core.feedback import Proposal, ProposalKind
    from repro.core.power import PowerParams

    dc = DatacenterConfig(num_hosts=8, cores_per_host=4)
    days = 0.25
    w = make_surf22_like(SurfTraceSpec(days=days, seed=3), dc)
    orch = Orchestrator(
        w, dc, int(days * BINS_PER_DAY),
        OrchestratorConfig(bins_per_window=12, sim_in_state=True,
                           calibration=CalibrationSpec(per_host=True)),
        base_params=PowerParams(
            p_idle=np.arange(8, dtype=np.float32) + 60.0,
            p_max=350.0, r=2.0))
    p = Proposal(kind=ProposalKind.SCALE_UP, window=0, detail="grow",
                 impact={"num_hosts": 12}, created_at=0.0, approved=True)
    orch.apply_proposal(p)
    rows = np.asarray(orch.state.params.p_idle)
    # existing rows survive; new hosts assume fleet-average hardware
    np.testing.assert_array_equal(rows[:8],
                                  np.arange(8, dtype=np.float32) + 60.0)
    np.testing.assert_allclose(rows[8:], np.full(4, 63.5, np.float32))


def test_per_host_rows_reach_whatif_prediction_and_survive_scale_up():
    """ISSUE satellite (per-host rows dropped on scale-up): the twin's own
    per-host calibrated rows must thread through
    ``Orchestrator.evaluate_whatif`` — including a scale-up scenario, where
    existing hosts keep their own curve and hypothetical added hosts assume
    fleet-average hardware.  If any stage collapsed the rows to scalar
    means, the heterogeneous and collapsed fleets would predict the same
    trace; they must differ measurably on *both* lanes."""
    from repro.core.calibrate import CalibrationSpec
    from repro.core.power import PowerParams
    from repro.core.scenarios import Scenario

    dc = DatacenterConfig(num_hosts=8, cores_per_host=4)
    days = 0.25
    w = make_surf22_like(SurfTraceSpec(days=days, seed=5), dc)
    t_bins = int(days * BINS_PER_DAY)
    rows = PowerParams(
        p_idle=np.asarray([55.0, 95.0] * 4, np.float32),
        p_max=np.asarray([300.0, 420.0] * 4, np.float32),
        r=np.asarray([1.5, 3.5] * 4, np.float32))
    collapsed = PowerParams(p_idle=75.0, p_max=360.0, r=2.5)
    orch = Orchestrator(
        w, dc, t_bins,
        OrchestratorConfig(bins_per_window=12,
                           calibration=CalibrationSpec(per_host=True)),
        base_params=rows)
    orch_flat = Orchestrator(w, dc, t_bins,
                             OrchestratorConfig(bins_per_window=12),
                             base_params=collapsed)
    scs = [Scenario(name="grow", num_hosts=12)]
    res = orch.evaluate_whatif(scs, max_hosts=12)
    res_flat = orch_flat.evaluate_whatif(scs, max_hosts=12)
    p = np.asarray(res.prediction.power_w)
    q = np.asarray(res_flat.prediction.power_w)
    assert p.shape == q.shape and p.shape[0] == 2    # baseline + grow
    assert np.isfinite(p).all()
    for lane in range(p.shape[0]):
        rel = np.abs(p[lane] - q[lane]) / np.abs(q[lane])
        assert rel.max() > 1e-3

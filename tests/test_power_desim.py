"""Unit tests: power models + the vectorized DES."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.desim import simulate, simulate_utilization
from repro.core.power import (
    POWER_MODELS,
    PowerParams,
    datacenter_power,
    linear_power,
    mape,
    opendc_power,
)
from repro.core.scenarios import Scenario
from repro.traces.schema import DatacenterConfig, Workload, pad_workload
from repro.traces.surf import SurfTraceSpec, make_surf22_like


def test_opendc_power_boundaries():
    p = PowerParams(p_idle=70.0, p_max=350.0, r=2.0)
    u = jnp.array([0.0, 1.0])
    out = np.asarray(opendc_power(u, p))
    assert out[0] == pytest.approx(70.0)
    assert out[1] == pytest.approx(350.0)    # 2u - u^r = 1 at u=1, any r


def test_linear_is_r1_special_case():
    p1 = PowerParams(70.0, 350.0, 1.0)
    u = jnp.linspace(0, 1, 33)
    np.testing.assert_allclose(
        np.asarray(opendc_power(u, p1)), np.asarray(linear_power(u, p1)),
        rtol=1e-6)


def test_power_monotone_for_r_le_2():
    # dP/du = span*(2 - r*u^(r-1)) >= 0 on [0,1] iff r <= 2; the OpenDC
    # form genuinely peaks above p_max for r > 2 (known model quirk).
    for r in (1.0, 1.5, 2.0):
        p = PowerParams(70.0, 350.0, r)
        u = jnp.linspace(0, 1, 101)
        out = np.asarray(opendc_power(u, p))
        assert (np.diff(out) >= -1e-4).all(), f"non-monotone at r={r}"


def test_power_loose_bound_any_r():
    # shape = 2u - u^r <= 2u <= 2  ->  P <= p_idle + 2*span always
    for r in (1.0, 2.0, 3.0, 4.5, 6.0):
        p = PowerParams(70.0, 350.0, r)
        u = jnp.linspace(0, 1, 101)
        out = np.asarray(opendc_power(u, p))
        assert (out >= 70.0 - 1e-3).all()
        assert (out <= 70.0 + 2 * 280.0 + 1e-3).all()


def test_mape_zero_iff_equal():
    a = jnp.asarray(np.random.default_rng(0).uniform(10, 20, 64))
    assert float(mape(a, a)) == pytest.approx(0.0, abs=1e-5)
    assert float(mape(a, a * 1.1)) == pytest.approx(10.0, rel=1e-3)


# -- regression: r <= 0 silently produced negative watts ----------------------

def test_power_params_rejects_r_le_zero():
    """Pre-fix repro: PowerParams(r=0) at u=0 gave 70 + 280*(0 - 0^0) =
    -210 W, and r=-1 gave -inf (0^-1 = inf).  Both must now raise at the
    PowerParams boundary instead of corrupting every downstream kWh/gCO2."""
    for bad_r in (0.0, -1.0):
        with pytest.raises(ValueError, match="r must be finite and > 0"):
            PowerParams(p_idle=70.0, p_max=350.0, r=bad_r)
    # the would-be corruption, demonstrated with the validator bypassed:
    p = PowerParams(70.0, 350.0, 2.0)
    object.__setattr__(p, "r", 0.0)
    out = float(opendc_power(jnp.asarray([0.0]), p)[0])
    assert out == pytest.approx(-210.0)     # what users silently got before


def test_power_params_rejects_non_finite():
    """NaN/inf parameters are the same silent-corruption class as r <= 0:
    they must fail the boundary too (NaN compares False against any bound,
    so naive range checks wave it through)."""
    for bad in (dict(r=float("nan")), dict(r=float("inf")),
                dict(p_idle=float("nan")), dict(p_max=float("nan")),
                dict(p_max=float("inf"))):
        with pytest.raises(ValueError):
            PowerParams(**{**dict(p_idle=70.0, p_max=350.0, r=2.0), **bad})
    with pytest.raises(ValueError):
        Scenario(name="bad", r=float("nan"))
    with pytest.raises(ValueError):
        Scenario(name="bad", p_idle=float("nan"))
    with pytest.raises(ValueError):
        Scenario(name="bad", p_max=float("inf"))


def test_power_params_rejects_inverted_span():
    with pytest.raises(ValueError, match="p_max"):
        PowerParams(p_idle=400.0, p_max=350.0, r=2.0)
    with pytest.raises(ValueError, match="p_idle"):
        PowerParams(p_idle=-5.0, p_max=350.0, r=2.0)
    # per-host vectors are validated elementwise
    with pytest.raises(ValueError):
        PowerParams(p_idle=np.array([70.0, 360.0]),
                    p_max=np.array([350.0, 350.0]), r=2.0)


def test_power_params_traced_values_pass_through():
    """Validation is concrete-only: tracer leaves (jit/vmap pytree
    round-trips) must not abort tracing."""
    import jax

    @jax.jit
    def f(r):
        return opendc_power(jnp.asarray([0.5]),
                            PowerParams(70.0, 350.0, r))[0]

    assert float(f(2.0)) == pytest.approx(float(
        opendc_power(jnp.asarray([0.5]), PowerParams(70.0, 350.0, 2.0))[0]))


def test_scenario_rejects_bad_power_params():
    with pytest.raises(ValueError, match="r must be > 0"):
        Scenario(name="bad", r=0.0)
    with pytest.raises(ValueError, match="inverts"):
        Scenario(name="bad", p_idle=400.0, p_max=350.0)
    with pytest.raises(ValueError, match="power_cap_w"):
        Scenario(name="bad", power_cap_w=-5.0)


# -- regression: zero-real bins exploded MAPE to ~5e10 % ----------------------

def test_mape_zero_real_bins_excluded():
    """Pre-fix repro: real=[0, 100], sim=[50, 100] gave
    mean(|0-50|/1e-9, 0)/2 = 2.5e10 %.  Zero-real bins (all hosts offline)
    now drop out of the mean."""
    real = jnp.asarray([0.0, 100.0, 100.0])
    sim = jnp.asarray([50.0, 110.0, 90.0])
    assert float(mape(real, sim)) == pytest.approx(10.0, rel=1e-5)
    # all-zero real: undefined, surfaced as NaN (fails any SLO comparison)
    assert np.isnan(float(mape(jnp.zeros(3), sim)))
    # negative residual traces: |real| denominator keeps the error's sign
    # structure intact (same magnitude as the positive trace)
    assert float(mape(-real, -sim)) == pytest.approx(10.0, rel=1e-5)


def test_calib_kernel_mape_matches_power_mape_on_zero_bins():
    """The calibration grid kernel (oracle + pallas interpret) shares the
    zero-real-bin exclusion — one dead bin must not wash out the search."""
    from repro.kernels import ops as kops

    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.uniform(0, 1, (32, 8)).astype(np.float32))
    real = np.asarray(
        opendc_power(u, PowerParams(70.0, 350.0, 2.5))).sum(1)
    real[5] = 0.0                                   # dead bin
    real_j = jnp.asarray(real.astype(np.float32))
    cand = PowerParams(p_idle=jnp.asarray([70.0]), p_max=jnp.asarray([350.0]),
                       r=jnp.asarray([2.5]))
    got_xla = float(kops.calib_mape_grid(
        u, real_j, cand.p_idle, cand.p_max, cand.r, backend="xla")[0])
    got_pl = float(kops.calib_mape_grid(
        u, real_j, cand.p_idle, cand.p_max, cand.r,
        backend="pallas_interpret")[0])
    want = float(mape(real_j, jnp.asarray(np.asarray(
        opendc_power(u, PowerParams(70.0, 350.0, 2.5))).sum(1))))
    assert got_xla == pytest.approx(want, abs=1e-3)
    assert got_pl == pytest.approx(want, abs=1e-3)
    assert got_xla < 1.0                            # not 5e10


# -- property tests: all four POWER_MODELS ------------------------------------

_GRID_PARAMS = [PowerParams(70.0, 350.0, r) for r in (1.0, 1.5, 2.0)]


@pytest.mark.parametrize("name", sorted(POWER_MODELS))
def test_all_models_hit_boundaries(name):
    """P(0) = p_idle and P(1) = p_max for every model in the zoo."""
    fn = POWER_MODELS[name]
    for params in _GRID_PARAMS:
        out = np.asarray(fn(jnp.asarray([0.0, 1.0]), params))
        assert out[0] == pytest.approx(params.p_idle, rel=1e-6)
        assert out[1] == pytest.approx(params.p_max, rel=1e-6)


@pytest.mark.parametrize("name", sorted(POWER_MODELS))
def test_all_models_bounded_and_monotone_on_valid_domain(name):
    """Within [p_idle, p_max] and monotone in u on the model's valid domain
    (for opendc that is r <= 2 — the form genuinely overshoots p_max for
    r > 2, a known model quirk pinned by the loose-bound test above)."""
    fn = POWER_MODELS[name]
    u = jnp.linspace(0.0, 1.0, 257)
    for params in _GRID_PARAMS:
        out = np.asarray(fn(u, params))
        lo, hi = float(np.asarray(params.p_idle)), float(
            np.asarray(params.p_max))
        assert (out >= lo - 1e-3).all()
        assert (out <= hi + 1e-3).all()
        assert (np.diff(out) >= -1e-3).all(), f"{name} non-monotone"
        # utilization outside [0, 1] is clipped, never extrapolated
        wild = np.asarray(fn(jnp.asarray([-0.5, 1.7]), params))
        assert wild[0] == pytest.approx(lo, rel=1e-6)
        assert wild[1] == pytest.approx(hi, rel=1e-6)


def _small_workload():
    sub = jnp.array([0, 0, 1, 3], jnp.int32)
    dur = jnp.array([2, 3, 1, 2], jnp.int32)
    cor = jnp.array([4, 8, 16, 2], jnp.int32)
    util = jnp.ones((4, 2), jnp.float32) * 0.5
    return Workload(sub, dur, cor, util, jnp.ones((4,), bool))


def test_des_places_and_releases():
    w = _small_workload()
    out = simulate_utilization(w, num_hosts=2, cores_per_host=16, t_bins=8)
    assert (np.asarray(out.job_start) >= 0).all()     # everything placed
    u = np.asarray(out.u_th)
    assert (u >= 0).all() and (u <= 1.0 + 1e-6).all()
    assert u[6:].sum() == pytest.approx(0.0)          # all jobs done by t=6


def test_des_capacity_never_exceeded():
    # 3 jobs x 16 cores on one 16-core host: strictly serialized
    w = Workload(
        jnp.zeros((3,), jnp.int32), jnp.ones((3,), jnp.int32) * 2,
        jnp.ones((3,), jnp.int32) * 16,
        jnp.ones((3, 2), jnp.float32), jnp.ones((3,), bool))
    out = simulate_utilization(w, num_hosts=1, cores_per_host=16, t_bins=10)
    starts = sorted(np.asarray(out.job_start).tolist())
    assert starts == [0, 2, 4]


def test_des_fcfs_head_of_line():
    # big job blocks; a later small job must NOT jump the queue
    w = Workload(
        jnp.array([0, 0, 0], jnp.int32),
        jnp.array([4, 4, 1], jnp.int32),
        jnp.array([16, 16, 1], jnp.int32),
        jnp.ones((3, 2), jnp.float32),
        jnp.ones((3,), bool))
    out = simulate_utilization(w, num_hosts=1, cores_per_host=16, t_bins=16)
    s = np.asarray(out.job_start)
    assert s[0] == 0 and s[1] == 4
    assert s[2] >= s[1]                                # strict FCFS


def test_des_deterministic():
    dc = DatacenterConfig(num_hosts=32)
    w = make_surf22_like(SurfTraceSpec(days=1.0, seed=3), dc)
    a = simulate_utilization(w, num_hosts=32, cores_per_host=16, t_bins=288)
    b = simulate_utilization(w, num_hosts=32, cores_per_host=16, t_bins=288)
    np.testing.assert_array_equal(np.asarray(a.u_th), np.asarray(b.u_th))


def test_simulate_full_metrics():
    dc = DatacenterConfig(num_hosts=16)
    w = make_surf22_like(SurfTraceSpec(days=0.5, seed=4), dc)
    sim, pred = simulate(w, dc, t_bins=144)
    p = np.asarray(pred.power_w)
    assert p.shape == (144,)
    assert (p >= 16 * 70.0 - 1e-3).all()               # idle floor
    assert np.asarray(pred.efficiency).min() >= 0
    assert np.isfinite(np.asarray(pred.tflops)).all()


def test_pad_workload_preserves_mass():
    w = _small_workload()
    wp = pad_workload(w, 16)
    assert wp.num_jobs == 16
    assert float(wp.cpu_hours().sum()) == pytest.approx(
        float(w.cpu_hours().sum()))

"""Unit tests: power models + the vectorized DES."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.desim import simulate, simulate_utilization
from repro.core.power import (
    PowerParams,
    datacenter_power,
    linear_power,
    mape,
    opendc_power,
)
from repro.traces.schema import DatacenterConfig, Workload, pad_workload
from repro.traces.surf import SurfTraceSpec, make_surf22_like


def test_opendc_power_boundaries():
    p = PowerParams(p_idle=70.0, p_max=350.0, r=2.0)
    u = jnp.array([0.0, 1.0])
    out = np.asarray(opendc_power(u, p))
    assert out[0] == pytest.approx(70.0)
    assert out[1] == pytest.approx(350.0)    # 2u - u^r = 1 at u=1, any r


def test_linear_is_r1_special_case():
    p1 = PowerParams(70.0, 350.0, 1.0)
    u = jnp.linspace(0, 1, 33)
    np.testing.assert_allclose(
        np.asarray(opendc_power(u, p1)), np.asarray(linear_power(u, p1)),
        rtol=1e-6)


def test_power_monotone_for_r_le_2():
    # dP/du = span*(2 - r*u^(r-1)) >= 0 on [0,1] iff r <= 2; the OpenDC
    # form genuinely peaks above p_max for r > 2 (known model quirk).
    for r in (1.0, 1.5, 2.0):
        p = PowerParams(70.0, 350.0, r)
        u = jnp.linspace(0, 1, 101)
        out = np.asarray(opendc_power(u, p))
        assert (np.diff(out) >= -1e-4).all(), f"non-monotone at r={r}"


def test_power_loose_bound_any_r():
    # shape = 2u - u^r <= 2u <= 2  ->  P <= p_idle + 2*span always
    for r in (1.0, 2.0, 3.0, 4.5, 6.0):
        p = PowerParams(70.0, 350.0, r)
        u = jnp.linspace(0, 1, 101)
        out = np.asarray(opendc_power(u, p))
        assert (out >= 70.0 - 1e-3).all()
        assert (out <= 70.0 + 2 * 280.0 + 1e-3).all()


def test_mape_zero_iff_equal():
    a = jnp.asarray(np.random.default_rng(0).uniform(10, 20, 64))
    assert float(mape(a, a)) == pytest.approx(0.0, abs=1e-5)
    assert float(mape(a, a * 1.1)) == pytest.approx(10.0, rel=1e-3)


def _small_workload():
    sub = jnp.array([0, 0, 1, 3], jnp.int32)
    dur = jnp.array([2, 3, 1, 2], jnp.int32)
    cor = jnp.array([4, 8, 16, 2], jnp.int32)
    util = jnp.ones((4, 2), jnp.float32) * 0.5
    return Workload(sub, dur, cor, util, jnp.ones((4,), bool))


def test_des_places_and_releases():
    w = _small_workload()
    out = simulate_utilization(w, num_hosts=2, cores_per_host=16, t_bins=8)
    assert (np.asarray(out.job_start) >= 0).all()     # everything placed
    u = np.asarray(out.u_th)
    assert (u >= 0).all() and (u <= 1.0 + 1e-6).all()
    assert u[6:].sum() == pytest.approx(0.0)          # all jobs done by t=6


def test_des_capacity_never_exceeded():
    # 3 jobs x 16 cores on one 16-core host: strictly serialized
    w = Workload(
        jnp.zeros((3,), jnp.int32), jnp.ones((3,), jnp.int32) * 2,
        jnp.ones((3,), jnp.int32) * 16,
        jnp.ones((3, 2), jnp.float32), jnp.ones((3,), bool))
    out = simulate_utilization(w, num_hosts=1, cores_per_host=16, t_bins=10)
    starts = sorted(np.asarray(out.job_start).tolist())
    assert starts == [0, 2, 4]


def test_des_fcfs_head_of_line():
    # big job blocks; a later small job must NOT jump the queue
    w = Workload(
        jnp.array([0, 0, 0], jnp.int32),
        jnp.array([4, 4, 1], jnp.int32),
        jnp.array([16, 16, 1], jnp.int32),
        jnp.ones((3, 2), jnp.float32),
        jnp.ones((3,), bool))
    out = simulate_utilization(w, num_hosts=1, cores_per_host=16, t_bins=16)
    s = np.asarray(out.job_start)
    assert s[0] == 0 and s[1] == 4
    assert s[2] >= s[1]                                # strict FCFS


def test_des_deterministic():
    dc = DatacenterConfig(num_hosts=32)
    w = make_surf22_like(SurfTraceSpec(days=1.0, seed=3), dc)
    a = simulate_utilization(w, num_hosts=32, cores_per_host=16, t_bins=288)
    b = simulate_utilization(w, num_hosts=32, cores_per_host=16, t_bins=288)
    np.testing.assert_array_equal(np.asarray(a.u_th), np.asarray(b.u_th))


def test_simulate_full_metrics():
    dc = DatacenterConfig(num_hosts=16)
    w = make_surf22_like(SurfTraceSpec(days=0.5, seed=4), dc)
    sim, pred = simulate(w, dc, t_bins=144)
    p = np.asarray(pred.power_w)
    assert p.shape == (144,)
    assert (p >= 16 * 70.0 - 1e-3).all()               # idle floor
    assert np.asarray(pred.efficiency).min() >= 0
    assert np.isfinite(np.asarray(pred.tflops)).all()


def test_pad_workload_preserves_mass():
    w = _small_workload()
    wp = pad_workload(w, 16)
    assert wp.num_jobs == 16
    assert float(wp.cpu_hours().sum()) == pytest.approx(
        float(w.cpu_hours().sum()))

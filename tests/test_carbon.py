"""Carbon-aware what-if subsystem: traces, integration, caps, time-shifting."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.feedback import ProposalKind, propose_from_scenario
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.power import PowerParams, carbon_gco2, energy_kwh
from repro.core.scenarios import (
    Scenario,
    build_scenario_set,
    evaluate_scenarios,
    run_scenarios,
)
from repro.core.telemetry import CARBON_INTENSITY_KEY, TelemetryWindow
from repro.traces.carbon import (
    load_carbon_intensity,
    make_diurnal_carbon,
    validate_carbon_intensity,
)
from repro.traces.schema import DatacenterConfig, Workload
from repro.traces.surf import (
    BINS_PER_DAY,
    SurfTraceSpec,
    make_surf22_like,
    synthesize_ground_truth,
)

T_BINS = int(0.5 * BINS_PER_DAY)
DC = DatacenterConfig(num_hosts=64, cores_per_host=16)


@pytest.fixture(scope="module")
def workload():
    return make_surf22_like(SurfTraceSpec(days=0.5, seed=11), DC)


@pytest.fixture(scope="module")
def intensity():
    return make_diurnal_carbon(T_BINS, seed=3)


# -- trace layer --------------------------------------------------------------

def test_diurnal_generator_shape_and_bounds():
    ci = make_diurnal_carbon(2 * BINS_PER_DAY, base=320.0, solar_dip=180.0,
                             evening_peak=120.0, seed=0)
    assert ci.shape == (2 * BINS_PER_DAY,)
    assert ci.dtype == np.float32
    assert (ci >= 0).all() and np.isfinite(ci).all()
    # diurnal structure: midday (13:00) is cleaner than evening (19:30)
    midday = ci[int(13 / 24 * BINS_PER_DAY)]
    evening = ci[int(19.5 / 24 * BINS_PER_DAY)]
    assert midday < evening
    # deterministic under a seed; seed=None disables the wander entirely
    np.testing.assert_array_equal(ci, make_diurnal_carbon(
        2 * BINS_PER_DAY, base=320.0, solar_dip=180.0, evening_peak=120.0,
        seed=0))
    pure = make_diurnal_carbon(2 * BINS_PER_DAY, seed=None)
    np.testing.assert_array_equal(pure[:BINS_PER_DAY], pure[BINS_PER_DAY:])


def test_validate_warns_on_implausible_units():
    with pytest.warns(UserWarning, match="typical grid band"):
        validate_carbon_intensity(np.array([300.0, 50_000.0], np.float32))


def test_validate_carbon_intensity_rejects_bad():
    with pytest.raises(ValueError):
        validate_carbon_intensity(np.array([1.0, -2.0]))
    with pytest.raises(ValueError):
        validate_carbon_intensity(np.array([1.0, np.nan]))
    with pytest.raises(ValueError):
        validate_carbon_intensity(np.ones((2, 2)))
    with pytest.raises(ValueError):
        validate_carbon_intensity(np.array([], np.float32))
    with pytest.raises(ValueError):
        validate_carbon_intensity(np.ones(5), t_bins=7)


def test_loader_csv_layouts_and_resampling(tmp_path):
    p1 = tmp_path / "flat.csv"
    p1.write_text("# comment\n300\n250.5\n400\n")
    np.testing.assert_allclose(load_carbon_intensity(str(p1)),
                               [300.0, 250.5, 400.0])
    p2 = tmp_path / "two_col.csv"
    p2.write_text("timestamp,gco2_per_kwh\n0,100\n1,200\n2,300\n")
    np.testing.assert_allclose(load_carbon_intensity(str(p2)),
                               [100.0, 200.0, 300.0])
    # shorter than horizon -> tiled (diurnal-periodic); longer -> truncated
    np.testing.assert_allclose(load_carbon_intensity(str(p2), t_bins=5),
                               [100.0, 200.0, 300.0, 100.0, 200.0])
    np.testing.assert_allclose(load_carbon_intensity(str(p2), t_bins=2),
                               [100.0, 200.0])
    bad = tmp_path / "bad.csv"
    bad.write_text("1\n2\noops\n")
    with pytest.raises(ValueError):
        load_carbon_intensity(str(bad))


# -- carbon integration (hand-computed golden) --------------------------------

def test_carbon_integration_3bin_golden():
    """Hand-computed: power [1000, 2000, 500] W over 5-min bins against
    intensity [300, 100, 600] gCO2/kWh."""
    power = jnp.asarray([1000.0, 2000.0, 500.0])
    e = energy_kwh(power, 300.0)            # [kWh] = W * (300/3600)/1000
    np.testing.assert_allclose(
        np.asarray(e), [1 / 12, 2 / 12, 0.5 / 12], rtol=1e-6)
    g = carbon_gco2(e, jnp.asarray([300.0, 100.0, 600.0]))
    # 83.333Wh*300 + 166.667Wh*100 + 41.667Wh*600 = 25 + 16.667 + 25 g
    np.testing.assert_allclose(np.asarray(g), [25.0, 100 / 6, 25.0],
                               rtol=1e-6)
    assert float(g.sum()) == pytest.approx(200.0 / 3, rel=1e-6)


def test_scenario_summary_reports_gco2(workload, intensity):
    _, _, pred, summaries = evaluate_scenarios(
        workload, DC, [Scenario(name="base")], t_bins=T_BINS,
        carbon_intensity=intensity)
    (s,) = summaries
    expect = float((np.asarray(pred.energy_kwh[0], np.float64)
                    * intensity).sum())
    assert s.gco2 == pytest.approx(expect, rel=1e-5)
    assert s.carbon_intensity_avg == pytest.approx(s.gco2 / s.energy_kwh,
                                                   rel=1e-6)
    assert intensity.min() <= s.carbon_intensity_avg <= intensity.max()


def test_no_intensity_means_nan_not_zero(workload):
    _, _, pred, summaries = evaluate_scenarios(
        workload, DC, [Scenario(name="base")], t_bins=T_BINS)
    assert pred.gco2 is None
    assert math.isnan(summaries[0].gco2)
    assert math.isnan(summaries[0].carbon_intensity_avg)


# -- power-cap enforcement ----------------------------------------------------

def test_static_cap_is_enforced_not_flagged(workload):
    cap = 6000.0   # 64 hosts idle at 70 W = 4480 W floor; demand exceeds this
    _, _, pred, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="free"), Scenario(name="capped", power_cap_w=cap)],
        t_bins=T_BINS)
    demand = np.asarray(pred.power_demand_w[1])
    delivered = np.asarray(pred.power_w[1])
    exceeded = demand > cap
    assert exceeded.any(), "test cap never binds; tighten it"
    np.testing.assert_allclose(delivered[exceeded], cap, rtol=1e-6)
    np.testing.assert_array_equal(delivered[~exceeded], demand[~exceeded])
    # free lane is untouched: demand == delivered bit-for-bit
    np.testing.assert_array_equal(np.asarray(pred.power_w[0]),
                                  np.asarray(pred.power_demand_w[0]))
    s = summaries[1]
    assert s.cap_exceeded_bins == int(exceeded.sum())
    assert s.peak_power_w <= cap + 1e-3 < s.peak_demand_w
    assert s.energy_kwh < summaries[0].energy_kwh
    # throttling prices the cap in performance currency too
    assert (np.asarray(pred.tflops[1])[exceeded]
            < np.asarray(pred.tflops[0])[exceeded]).all()


def test_carbon_aware_cap_follows_intensity(workload, intensity):
    # cap = base + slope * I_t: dirtier grid -> tighter cap
    base_w, slope = 7000.0, -8.0
    _, _, pred, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="cc", carbon_cap_base_w=base_w,
                  carbon_cap_slope=slope)],
        t_bins=T_BINS, carbon_intensity=intensity)
    cap_t = np.maximum(base_w + slope * intensity, 0.0)
    demand = np.asarray(pred.power_demand_w[0])
    delivered = np.asarray(pred.power_w[0])
    exceeded = demand > cap_t
    assert exceeded.any(), "carbon cap never binds; tighten it"
    np.testing.assert_allclose(delivered[exceeded], cap_t[exceeded],
                               rtol=1e-6)
    np.testing.assert_array_equal(delivered[~exceeded], demand[~exceeded])
    assert summaries[0].cap_exceeded_bins == int(exceeded.sum())
    assert summaries[0].carbon_cap_base_w == pytest.approx(base_w)
    assert summaries[0].gco2 < float((energy_kwh(
        jnp.asarray(demand), 300.0) * intensity).sum())


def test_carbon_cap_without_trace_raises(workload):
    ss = build_scenario_set(
        workload, DC, [Scenario(name="cc", carbon_cap_base_w=5000.0)])
    with pytest.raises(ValueError, match="carbon_cap_base_w"):
        run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=T_BINS)


# -- deferrable-job time-shifting ---------------------------------------------

def _two_job_workload(deferrable):
    return Workload(
        submit_bin=jnp.asarray([0, 2], jnp.int32),
        duration_bins=jnp.asarray([2, 2], jnp.int32),
        cores=jnp.asarray([4, 4], jnp.int32),
        util_levels=jnp.ones((2, 2), jnp.float32),
        valid=jnp.ones((2,), bool),
        deferrable=deferrable,
    )


def test_shift_bins_moves_only_deferrable_jobs():
    w = _two_job_workload(jnp.asarray([True, False]))
    ss = build_scenario_set(w, DatacenterConfig(num_hosts=2, cores_per_host=8),
                            [Scenario(name="s", shift_bins=4)])
    sub = np.sort(np.asarray(ss.workload.submit_bin[0]))
    np.testing.assert_array_equal(sub, [2, 4])     # job0 0->4, job1 stays 2
    # default None deferrable mask = everything moves
    w_all = _two_job_workload(None)
    ss_all = build_scenario_set(
        w_all, DatacenterConfig(num_hosts=2, cores_per_host=8),
        [Scenario(name="s", shift_bins=4)])
    np.testing.assert_array_equal(
        np.sort(np.asarray(ss_all.workload.submit_bin[0])), [4, 6])


def test_shift_keeps_fcfs_order_sorted():
    """The DES's queue order is the array order: after shifting, submission
    times must be non-decreasing or late-shifted jobs would head-block
    earlier work."""
    w = make_surf22_like(SurfTraceSpec(days=0.5, seed=11), DC)
    defer = np.zeros(w.num_jobs, bool)
    defer[::3] = True                               # shift every third job
    w = Workload(w.submit_bin, w.duration_bins, w.cores, w.util_levels,
                 w.valid, jnp.asarray(defer))
    ss = build_scenario_set(w, DC, [Scenario(name="s", shift_bins=24)])
    sub = np.asarray(ss.workload.submit_bin[0])
    assert (np.diff(sub) >= 0).all()
    # mass is conserved: same multiset of durations/cores
    assert np.asarray(ss.workload.valid[0]).sum() == w.num_jobs


def test_shift_toward_clean_bins_cuts_carbon(workload):
    """Intensity dirty early / clean late: delaying deferrable work must cut
    gCO2 while conserving placed work inside a long-enough horizon."""
    t_bins = T_BINS + 48                            # slack so no job falls off
    ci = np.full(t_bins, 600.0, np.float32)
    ci[T_BINS // 2:] = 50.0                        # clean second half
    _, _, _, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="now"), Scenario(name="later", shift_bins=36)],
        t_bins=t_bins, carbon_intensity=ci)
    now, later = summaries
    assert later.unplaced_jobs <= now.unplaced_jobs
    assert later.gco2 < now.gco2
    assert later.cpu_hours == pytest.approx(now.cpu_hours)


# -- single-compile invariant for the carbon grid -----------------------------

def test_carbon_grid_single_compilation(workload, intensity):
    if run_scenarios._cache_size is None:
        pytest.skip("jax private _cache_size API unavailable")
    def grid(k):
        return [Scenario(name=f"{k}-c{c}-s{s}", carbon_cap_base_w=c,
                         carbon_cap_slope=-10.0 * k, shift_bins=s,
                         num_hosts=h)
                for c in (6000.0, 8000.0) for s in (0, 12) for h in (32, 64)]
    ss1 = build_scenario_set(workload, DC, grid(1), max_hosts=64)
    ss2 = build_scenario_set(workload, DC, grid(2), max_hosts=64)
    run_scenarios(ss1, max_hosts=64, t_bins=T_BINS,
                  carbon_intensity=intensity)[0].u_th.block_until_ready()
    after_first = run_scenarios._cache_size()
    run_scenarios(ss2, max_hosts=64, t_bins=T_BINS,
                  carbon_intensity=intensity)[0].u_th.block_until_ready()
    assert run_scenarios._cache_size() == after_first


# -- proposals + orchestrator -------------------------------------------------

def test_propose_carbon_reduction(workload, intensity):
    _, _, _, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="base"),
         Scenario(name="cc", carbon_cap_base_w=6500.0,
                  carbon_cap_slope=-5.0)],
        t_bins=T_BINS, carbon_intensity=intensity)
    base, cc = summaries
    assert cc.gco2 < base.gco2
    props = propose_from_scenario(0, cc, base)
    kinds = {p.kind for p in props}
    assert ProposalKind.CARBON_REDUCTION in kinds
    carbon = next(p for p in props
                  if p.kind == ProposalKind.CARBON_REDUCTION)
    assert carbon.impact["gco2_saving"] > 0
    # no trace -> NaN gco2 -> the carbon rule must stay silent
    no_ci = propose_from_scenario(
        0, summaries[0], summaries[0].__class__(**{
            **summaries[0].__dict__, "gco2": float("nan")}))
    assert ProposalKind.CARBON_REDUCTION not in {p.kind for p in no_ci}


def test_orchestrator_rejects_bad_measured_intensity(workload, intensity):
    """Measured intensity from telemetry extras crosses the same validation
    boundary as the forecast — a negative/NaN sensor stream must raise, not
    flip the sign of the window's gCO2 record."""
    orch = Orchestrator(
        workload, DC, T_BINS,
        OrchestratorConfig(bins_per_window=36, calibrate=False),
        carbon_intensity=intensity)
    sim = orch._ensure_sim()
    u0 = np.asarray(sim.u_th[:36])
    orch.store.ingest(TelemetryWindow(
        window=0, t0_bin=0, u_th=u0, power_w=synthesize_ground_truth(u0),
        extras={CARBON_INTENSITY_KEY: np.full(36, -50.0)}))
    with pytest.raises(ValueError, match=">= 0"):
        orch.run_window(0)


def test_orchestrator_carbon_loop(workload, intensity):
    orch = Orchestrator(
        workload, DC, T_BINS,
        OrchestratorConfig(bins_per_window=36, calibrate=False),
        carbon_intensity=intensity)
    sim = orch._ensure_sim()
    # window 0 telemetry carries *measured* intensity (overrides forecast)
    u0 = np.asarray(sim.u_th[:36])
    p0 = synthesize_ground_truth(u0)
    measured = intensity[:36] * 1.5
    orch.store.ingest(TelemetryWindow(
        window=0, t0_bin=0, u_th=u0, power_w=p0,
        extras={CARBON_INTENSITY_KEY: measured}))
    rec0 = orch.run_window(0)
    rec1 = orch.run_window(1)       # no telemetry: forecast intensity
    assert rec0.gco2 is not None and rec1.gco2 is not None
    expect0 = float((np.asarray(rec0.prediction.energy_kwh, np.float64)
                     * measured.astype(np.float64)).sum())
    assert rec0.gco2 == pytest.approx(expect0, rel=1e-6)
    expect1 = float(np.asarray(rec1.prediction.gco2, np.float64).sum())
    assert rec1.gco2 == pytest.approx(expect1, rel=1e-6)
    # what-if sweeps inherit the forecast: summaries carry finite gCO2
    res = orch.evaluate_whatif([Scenario(name="h32", num_hosts=32)])
    assert all(math.isfinite(s.gco2) for s in res.summaries)

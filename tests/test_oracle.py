"""Masked DES read-out vs the pure-Python oracle: caps, shifts, carbon.

``tests/reference.py`` models the whole per-scenario pipeline — deferrable
time-shifting, FCFS placement, the OpenDC power model, *enforced* static and
carbon-aware power caps with linear throttling, energy and gCO2 — in plain
float64 loops.  These tests drive randomized small cases through the real
batched engine (``evaluate_scenarios``) and demand agreement on every
readout the operator consumes: schedules exactly, float fields to f32
tolerance, throttle flags and wait statistics exactly.

Before this suite only *placement* was oracle-checked (test_policies.py);
the cap/shift/carbon readout path had no independent model.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from reference import apply_shift, reference_readout, reference_scenario

from repro.core.power import PowerParams
from repro.core.scenarios import Scenario, build_scenario_set, evaluate_scenarios
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig, Workload


def _random_case(seed, j=20, hosts=3, cores_per_host=8, t_bins=40):
    """A contended small trace with a random deferrable subset."""
    rng = np.random.default_rng(seed)
    submit = np.sort(rng.integers(0, t_bins // 2, j)).astype(np.int32)
    dur = rng.integers(1, 8, j).astype(np.int32)
    cores = rng.integers(1, cores_per_host + 1, j).astype(np.int32)
    util = rng.uniform(0.1, 1.0, (j, 3)).astype(np.float32)
    defer = rng.random(j) < 0.6
    w = Workload(jnp.asarray(submit), jnp.asarray(dur), jnp.asarray(cores),
                 jnp.asarray(util), jnp.ones((j,), bool),
                 deferrable=jnp.asarray(defer))
    dc = DatacenterConfig(num_hosts=hosts, cores_per_host=cores_per_host)
    intensity = rng.uniform(80.0, 600.0, t_bins).astype(np.float32)
    return w, dc, t_bins, intensity


def _workload_dict(w: Workload) -> dict:
    return dict(
        submit=np.asarray(w.submit_bin).tolist(),
        dur=np.asarray(w.duration_bins).tolist(),
        cores=np.asarray(w.cores).tolist(),
        util=np.asarray(w.util_levels).tolist(),
        valid=np.asarray(w.valid).tolist(),
        deferrable=(None if w.deferrable is None
                    else np.asarray(w.deferrable).tolist()),
    )


#: cap/shift/carbon scenario mix the readout oracle must reproduce.  Caps are
#: deliberately tight enough to throttle some (not all) bins on these traces.
def _scenarios(hosts, cores_per_host):
    watts = hosts * 120.0
    return [
        Scenario(name="base"),
        Scenario(name="shift", shift_bins=7),
        Scenario(name="shift-neg", shift_bins=-4),
        Scenario(name="cap", power_cap_w=watts * 1.5),
        Scenario(name="cc", carbon_cap_base_w=watts * 2.2,
                 carbon_cap_slope=-hosts * 0.4),
        Scenario(name="cap-cc-shift", power_cap_w=watts * 1.6,
                 carbon_cap_base_w=watts * 2.0,
                 carbon_cap_slope=-hosts * 0.3, shift_bins=5),
        Scenario(name="bf-cap", policy="best_fit", backfill_depth=3,
                 power_cap_w=watts * 1.4),
    ]


@pytest.mark.parametrize("seed", [3, 11, 29])
def test_readout_matches_oracle(seed):
    w, dc, t_bins, intensity = _random_case(seed)
    params = PowerParams(p_idle=63.0, p_max=341.0, r=2.3)
    scs = _scenarios(dc.num_hosts, dc.cores_per_host)
    ss, sim, pred, summaries = evaluate_scenarios(
        w, dc, scs, t_bins=t_bins, base_params=params,
        carbon_intensity=intensity)
    wd = _workload_dict(w)
    for i, sc in enumerate(scs):
        ref = reference_scenario(
            wd, dc, sc, t_bins=t_bins, p_idle=63.0, p_max=341.0, r=2.3,
            intensity=[float(v) for v in intensity])
        # schedule and post-shift submission order: exact
        assert np.asarray(sim.job_start[i]).tolist() == ref["job_start"], sc.name
        assert np.asarray(sim.job_host[i]).tolist() == ref["job_host"], sc.name
        assert np.asarray(ss.workload.submit_bin[i]).tolist() == ref["submit"]
        # utilization field and power readouts: f32 engine vs f64 oracle
        np.testing.assert_allclose(
            np.asarray(sim.u_th[i], np.float64), np.asarray(ref["u_th"]),
            rtol=2e-5, atol=1e-6, err_msg=f"{sc.name}: u_th")
        np.testing.assert_allclose(
            np.asarray(pred.power_demand_w[i], np.float64),
            np.asarray(ref["demand"]), rtol=1e-4, err_msg=f"{sc.name}: demand")
        np.testing.assert_allclose(
            np.asarray(pred.power_w[i], np.float64),
            np.asarray(ref["power"]), rtol=1e-4,
            err_msg=f"{sc.name}: delivered power")
        np.testing.assert_allclose(
            np.asarray(pred.gco2[i], np.float64), np.asarray(ref["gco2"]),
            rtol=2e-4, err_msg=f"{sc.name}: gco2")
        np.testing.assert_allclose(
            np.asarray(pred.utilization[i], np.float64),
            np.asarray(ref["util"]), rtol=1e-4, atol=1e-6,
            err_msg=f"{sc.name}: throttled utilization")
        # throttle flags: the engine's delivered < demand exactly where the
        # oracle says the cap binds
        flags = (np.asarray(pred.power_demand_w[i])
                 > np.asarray(pred.power_w[i]))
        assert flags.tolist() == ref["throttled"], f"{sc.name}: throttle flags"
        assert summaries[i].cap_exceeded_bins == sum(ref["throttled"]), sc.name
        # wait statistics flow from the exact schedule
        if ref["waits"]:
            assert summaries[i].mean_wait_bins == pytest.approx(
                sum(ref["waits"]) / len(ref["waits"]))
        else:
            assert math.isnan(summaries[i].mean_wait_bins)
        # energy totals (f64 reduction of the delivered trace)
        assert summaries[i].energy_kwh == pytest.approx(
            sum(ref["energy_kwh"]), rel=1e-4)
        assert summaries[i].gco2 == pytest.approx(sum(ref["gco2"]), rel=2e-4)


@pytest.mark.parametrize("seed", [5, 17])
def test_uncapped_no_shift_oracle_without_carbon(seed):
    """The oracle also covers the pre-carbon path: no intensity trace, no
    caps — demand equals delivered and gCO2 is NaN on both sides."""
    w, dc, t_bins, _ = _random_case(seed)
    params = PowerParams(p_idle=70.0, p_max=350.0, r=2.0)
    scs = [Scenario(name="base"), Scenario(name="h2", num_hosts=2)]
    ss, sim, pred, summaries = evaluate_scenarios(
        w, dc, scs, t_bins=t_bins, base_params=params)
    wd = _workload_dict(w)
    for i, sc in enumerate(scs):
        ref = reference_scenario(wd, dc, sc, t_bins=t_bins, p_idle=70.0,
                                 p_max=350.0, r=2.0, intensity=None)
        assert np.asarray(sim.job_start[i]).tolist() == ref["job_start"]
        np.testing.assert_allclose(
            np.asarray(pred.power_w[i], np.float64),
            np.asarray(ref["power"]), rtol=1e-4)
        assert not any(ref["throttled"])
        assert math.isnan(summaries[i].gco2)


def test_shift_moves_only_deferrable_jobs():
    """Time-shifting at the oracle level: deferrable valid jobs move by
    exactly shift_bins (clipped at 0), others stay, and the axis re-sorts
    stably — matching the engine's stacked workload bit for bit."""
    w, dc, t_bins, intensity = _random_case(7)
    wd = _workload_dict(w)
    shifted = apply_shift(wd["submit"], wd["dur"], wd["util"], wd["cores"],
                          wd["valid"], wd["deferrable"], 9)
    new_submit, _, _, _, _, new_defer = shifted
    assert new_submit == sorted(new_submit)
    # multiset of (submit, deferrable): deferrables moved by +9, rest fixed
    want = sorted((s + 9 if d else s, d)
                  for s, d in zip(wd["submit"], wd["deferrable"]))
    assert sorted(zip(new_submit, new_defer)) == want
    ss = build_scenario_set(w, dc, [Scenario(name="s9", shift_bins=9)])
    assert np.asarray(ss.workload.submit_bin[0]).tolist() == new_submit


def test_oracle_throttle_fraction_is_linear():
    """Hand-built check of the linear-throttle model: one host at full load,
    cap halfway between idle and demand -> delivered power equals the cap
    and utilization halves its above-idle share."""
    p_idle, p_max, r = 100.0, 300.0, 2.0
    u = [[1.0]]                                     # one bin, one host
    demand = p_max                                  # P(1) = p_max
    cap = (p_idle + demand) / 2.0
    ref = reference_readout(u, p_idle=p_idle, p_max=p_max, r=r,
                            power_cap_w=cap)
    assert ref["throttled"] == [True]
    assert ref["power"][0] == pytest.approx(cap)
    assert ref["util"][0] == pytest.approx(0.5)
    # the engine agrees on the same one-bin case
    w = Workload(jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32),
                 jnp.asarray([4], jnp.int32),
                 jnp.ones((1, 2), jnp.float32), jnp.ones((1,), bool))
    dc = DatacenterConfig(num_hosts=1, cores_per_host=4)
    _, _, pred, _ = evaluate_scenarios(
        w, dc, [Scenario(name="cap", power_cap_w=cap)], t_bins=1,
        base_params=PowerParams(p_idle=p_idle, p_max=p_max, r=r))
    assert float(pred.power_w[0, 0]) == pytest.approx(cap)
    assert float(pred.utilization[0, 0]) == pytest.approx(0.5)
    assert float(pred.power_demand_w[0, 0]) == pytest.approx(demand)
    # energy prices the *delivered* watts
    assert float(pred.energy_kwh[0, 0]) == pytest.approx(
        cap * SAMPLE_SECONDS / 3600.0 / 1000.0)

"""Hypothesis property tests on system invariants.

``hypothesis`` is optional (same policy as ``zstandard``, see
``repro/core/codec.py``): environments without it skip this module instead
of failing collection.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.core.calibrate import CalibrationSpec, calibrate_window
from repro.core.power import PowerParams, mape, opendc_power
from repro.core.desim import simulate_utilization
from repro.data.tokens import DataConfig, TokenPipeline
from repro.traces.schema import Workload

SETTINGS = dict(max_examples=25, deadline=None)


@given(
    u=st.lists(st.floats(0, 1), min_size=2, max_size=32),
    r=st.floats(1.0, 6.0),
    p_idle=st.floats(20.0, 120.0),
    span=st.floats(10.0, 400.0),
)
@settings(**SETTINGS)
def test_power_bounded(u, r, p_idle, span):
    params = PowerParams(p_idle, p_idle + span, r)
    us = jnp.asarray(sorted(u), jnp.float32)
    out = np.asarray(opendc_power(us, params))
    tol = 1e-3 * (p_idle + 2 * span)
    assert (out >= p_idle - tol).all()
    # loose cap: shape <= 2u <= 2 (the form overshoots p_max for r > 2)
    assert (out <= p_idle + 2 * span + tol).all()
    if r <= 2.0:
        assert (np.diff(out) >= -tol).all()       # monotone only for r <= 2


@given(
    scale=st.floats(0.5, 2.0),
    vals=st.lists(st.floats(10.0, 1e4), min_size=3, max_size=64),
)
@settings(**SETTINGS)
def test_mape_scale_property(scale, vals):
    a = jnp.asarray(vals, jnp.float32)
    m = float(mape(a, a * scale))
    assert m == np.float32(abs(1 - scale) * 100).item() or \
        abs(m - abs(1 - scale) * 100) < 0.05


@given(
    n_jobs=st.integers(1, 24),
    hosts=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
@settings(**SETTINGS)
def test_des_invariants(n_jobs, hosts, seed):
    """Capacity respected; placed jobs never exceed aggregate utilization 1;
    no job starts before submission."""
    rng = np.random.default_rng(seed)
    t_bins = 48
    sub = rng.integers(0, t_bins // 2, n_jobs).astype(np.int32)
    sub.sort()
    dur = rng.integers(1, 8, n_jobs).astype(np.int32)
    cores = rng.integers(1, 17, n_jobs).astype(np.int32)
    util = rng.uniform(0.1, 1.0, (n_jobs, 4)).astype(np.float32)
    w = Workload(jnp.asarray(sub), jnp.asarray(dur), jnp.asarray(cores),
                 jnp.asarray(util), jnp.ones((n_jobs,), bool))
    out = simulate_utilization(w, num_hosts=hosts, cores_per_host=16,
                               t_bins=t_bins)
    u = np.asarray(out.u_th)
    assert (u <= 1.0 + 1e-5).all()
    starts = np.asarray(out.job_start)
    placed = starts >= 0
    assert (starts[placed] >= sub[placed]).all()


@given(r_true=st.floats(1.2, 5.5), seed=st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_calibration_never_worse_than_base(r_true, seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.uniform(0, 1, (64, 16)).astype(np.float32))
    real = jnp.asarray(np.asarray(
        opendc_power(u, PowerParams(70.0, 350.0, r_true))).sum(1))
    base = PowerParams(70.0, 350.0, 2.0)
    res = calibrate_window(u, real, CalibrationSpec(r_points=96), base)
    base_mape = float(mape(real, jnp.asarray(np.asarray(
        opendc_power(u, base)).sum(1))))
    assert res.mape <= base_mape + 1e-4


@given(step=st.integers(0, 1000), shards=st.sampled_from([1, 2, 4, 8]))
@settings(**SETTINGS)
def test_data_pipeline_shards_partition_global_batch(step, shards):
    cfg = DataConfig(vocab=128, seq_len=16, global_batch=8)
    pipe = TokenPipeline(cfg)
    parts = [pipe.batch(step, s, shards)["tokens"] for s in range(shards)]
    for p in parts:
        assert p.shape == (8 // shards, 16)
    again = [pipe.batch(step, s, shards)["tokens"] for s in range(shards)]
    for a, b in zip(parts, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_checkpoint_roundtrip(tmp_path_factory, seed):
    rng = np.random.default_rng(seed)
    state = {
        "a": rng.normal(size=(3, 5)).astype(np.float32),
        "nested": {"b": rng.integers(0, 9, (4,)).astype(np.int32),
                   "c": float(rng.normal())},
    }
    d = tmp_path_factory.mktemp("ck")
    ckpt.save(str(d), 7, state)
    step, back = ckpt.restore(str(d))
    assert step == 7
    np.testing.assert_array_equal(back["a"], state["a"])
    np.testing.assert_array_equal(back["nested"]["b"], state["nested"]["b"])
    assert back["nested"]["c"] == state["nested"]["c"]

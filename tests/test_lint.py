"""tracecheck (tools/lint) — fixtures per rule, ratchet, suppressions.

The linter is pure stdlib, so these tests run without jax; the fixtures
lint tiny synthetic trees under tmp_path with an injectable registry, and
one tier-1 test asserts the *committed* baseline matches a fresh run of
the real tree (no new findings, no stale entries).
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap
import types

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.lint import engine, rules  # noqa: E402
from tools.lint.engine import load_baseline, run_lint  # noqa: E402


def make_registry(**over):
    base = dict(
        JIT_ENTRYPOINTS={"mod.entry": ()},
        STATIC_PARAM_NAMES=frozenset({"cfg", "model"}),
        DONATING_JITS={},
        BF16_ALLOWED_FILES=frozenset({"src/allowed.py"}),
        OPTIONAL_MODULES=("zstandard", "hypothesis"),
        DETERMINISTIC_DIRS=("src/core/",),
        NONDETERMINISM_ALLOWED=frozenset(),
        JIT_HYGIENE_DIRS=("src/", "benchmarks/"),
        MAX_FAST_EXAMPLES=50,
    )
    base.update(over)
    return types.SimpleNamespace(**base)


def lint(tmp_path, files, registry=None, rule_set=None, baseline=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_lint([tmp_path], root=tmp_path,
                    registry=registry or make_registry(),
                    baseline_entries=baseline or [],
                    rules=rule_set)


# -- TC001: jit construction hygiene ------------------------------------------

BAD_TC001 = {"src/a.py": """\
    import jax

    def f(x):
        g = jax.jit(lambda v: v + 1)
        return g(x)
    """}


def test_tc001_flags_in_function_jit(tmp_path):
    res = lint(tmp_path, BAD_TC001, rule_set=[rules.rule_tc001])
    assert [f.rule for f in res.findings] == ["TC001"]
    assert "src/a.py" in res.findings[0].key


def test_tc001_module_level_and_cached_factories_pass(tmp_path):
    res = lint(tmp_path, {"src/a.py": """\
        import functools
        import jax

        top = jax.jit(lambda v: v + 1)

        @functools.partial(jax.jit, static_argnames=("k",))
        def decorated(v, k):
            return v * k

        @functools.lru_cache(maxsize=None)
        def factory(k):
            return jax.jit(lambda v: v * k)
        """}, rule_set=[rules.rule_tc001])
    assert res.findings == []


def test_tc001_out_of_scope_dirs_exempt(tmp_path):
    files = {"tests/test_a.py": BAD_TC001["src/a.py"]}
    res = lint(tmp_path, files, rule_set=[rules.rule_tc001])
    assert res.findings == []


# -- TC002: concretization in jit-reachable code ------------------------------

def test_tc002_flags_concretized_param_transitively(tmp_path):
    res = lint(tmp_path, {"src/mod.py": """\
        def entry(x, cfg):
            return helper(x) + other(x)

        def helper(y):
            return float(y)

        def other(z):
            return z.item()
        """}, rule_set=[rules.rule_tc002])
    assert sorted(f.message.split("'")[1] for f in res.findings) == ["y", "z"]


def test_tc002_static_shape_and_cfg_pass(tmp_path):
    res = lint(tmp_path, {"src/mod.py": """\
        import jax.numpy as jnp

        def entry(x, cfg, n: int):
            m = int(x.shape[0])          # shape metadata: static
            k = float(cfg.scale)         # cfg: static by convention
            j = int(n)                   # annotated host scalar
            return jnp.asarray(x) * m * k * j
        """}, rule_set=[rules.rule_tc002])
    assert res.findings == []


def test_tc002_unreachable_function_ignored(tmp_path):
    res = lint(tmp_path, {"src/mod.py": """\
        def host_only(x):
            return float(x)
        """}, rule_set=[rules.rule_tc002])
    assert res.findings == []


# -- TC003: python branches on traced values ----------------------------------

def test_tc003_flags_traced_branch(tmp_path):
    res = lint(tmp_path, {"src/mod.py": """\
        def entry(x):
            if x > 0:
                return x
            while x < 5:
                x = x + 1
            return -x
        """}, rule_set=[rules.rule_tc003])
    assert [f.rule for f in res.findings] == ["TC003", "TC003"]


def test_tc003_structural_checks_pass(tmp_path):
    res = lint(tmp_path, {"src/mod.py": """\
        def entry(x, cfg):
            if x is None:
                return None
            if isinstance(x, tuple):
                x = x[0]
            if x.shape[0] > 4:
                return x[:4]
            if cfg.calibrate:
                return x * 2
            return x
        """}, rule_set=[rules.rule_tc003])
    assert res.findings == []


# -- TC004: donated-buffer reuse ----------------------------------------------

DONATING = {"src/mod.py": """\
    import jax

    def step(s, t):
        return s, s.sum()

    step_jit = jax.jit(step, donate_argnums=(0,))
    """}


def test_tc004_flags_read_after_donation(tmp_path):
    files = dict(DONATING)
    files["src/use.py"] = """\
        from mod import step_jit

        def bad(state, t):
            new, out = step_jit(state, t)
            return state.sum()

        def bad_loop(state, ts):
            for t in ts:
                new, out = step_jit(state, t)
            return new
        """
    res = lint(tmp_path, files, rule_set=[rules.rule_tc004])
    assert [f.rule for f in res.findings] == ["TC004", "TC004"]
    assert all("state" in f.message for f in res.findings)


def test_tc004_rebinding_passes(tmp_path):
    files = dict(DONATING)
    files["src/use.py"] = """\
        from mod import step_jit

        def good(state, t):
            state, out = step_jit(state, t)
            return state.sum()

        def good_loop(state, ts):
            for t in ts:
                state, out = step_jit(state, t)
            return state
        """
    res = lint(tmp_path, files, rule_set=[rules.rule_tc004])
    assert res.findings == []


def test_tc004_discovers_donation_without_registry(tmp_path):
    # DONATING_JITS is empty in the fixture registry: the donate_argnums
    # assignment in src/mod.py is discovered syntactically
    files = dict(DONATING)
    files["src/use.py"] = """\
        from mod import step_jit

        def bad(state, t):
            new, out = step_jit(state, t)
            return state
        """
    reg = make_registry(DONATING_JITS={})
    res = lint(tmp_path, files, registry=reg, rule_set=[rules.rule_tc004])
    assert len(res.findings) == 1


# -- TC005: bf16 outside the allow-list ---------------------------------------

def test_tc005_allowlist(tmp_path):
    src = """\
        import jax.numpy as jnp

        def f(x):
            return x.astype(jnp.bfloat16)
        """
    res = lint(tmp_path, {"src/allowed.py": src, "src/stray.py": src},
               rule_set=[rules.rule_tc005])
    assert [f.path for f in res.findings] == ["src/stray.py"]


# -- TC006: optional-dependency imports ---------------------------------------

def test_tc006_bare_vs_guarded(tmp_path):
    res = lint(tmp_path, {
        "src/bare.py": "import zstandard\n",
        "src/guarded.py": """\
            try:
                import zstandard
            except ImportError:
                zstandard = None
            """,
        "tests/test_skipped.py": """\
            import pytest

            pytest.importorskip("hypothesis")
            from hypothesis import given
            """,
    }, rule_set=[rules.rule_tc006])
    assert [f.path for f in res.findings] == ["src/bare.py"]


# -- TC007: nondeterminism in the deterministic core --------------------------

def test_tc007_calls_flagged_references_and_seeded_rngs_pass(tmp_path):
    res = lint(tmp_path, {"src/core/t.py": """\
        import time

        import numpy as np

        def bad():
            return time.time(), np.random.rand()

        def good(clock=time.time):
            rng = np.random.default_rng(42)
            return rng.normal()
        """}, rule_set=[rules.rule_tc007])
    assert sorted(f.line for f in res.findings) == [6, 6]


def test_tc007_allowlist_and_scope(tmp_path):
    src = "import time\n\ndef f():\n    return time.time()\n"
    reg = make_registry(NONDETERMINISM_ALLOWED=frozenset(
        {("src/core/ok.py", "time.time")}))
    res = lint(tmp_path, {"src/core/ok.py": src, "src/shell.py": src},
               registry=reg, rule_set=[rules.rule_tc007])
    assert res.findings == []        # allow-listed + outside core dirs


# -- TC008: slow-worthy tests without the marker ------------------------------

def test_tc008_hypothesis_budget_and_golden_regen(tmp_path):
    res = lint(tmp_path, {"tests/test_heavy.py": """\
        import numpy as np
        import pytest
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=500)
        @given(st.integers())
        def test_big(x):
            assert x == x

        @pytest.mark.slow
        @settings(max_examples=500)
        @given(st.integers())
        def test_big_marked(x):
            assert x == x

        @settings(max_examples=20)
        @given(st.integers())
        def test_small(x):
            assert x == x

        def test_regen():
            np.savez("tests/golden/new.npz", a=1)
        """}, rule_set=[rules.rule_tc008])
    assert [(f.line, f.rule) for f in res.findings] == [(5, "TC008"),
                                                        (22, "TC008")]


# -- suppressions -------------------------------------------------------------

def test_suppression_comment_same_line_and_line_above(tmp_path):
    res = lint(tmp_path, {"src/a.py": """\
        import jax

        def f(x):
            g = jax.jit(lambda v: v)  # tracecheck: disable=TC001 — fixture
            # tracecheck: disable=TC001 — fixture
            h = jax.jit(
                lambda v: v + 1)
            return g(x) + h(x)
        """}, rule_set=[rules.rule_tc001])
    assert res.findings == []


def test_suppression_is_rule_specific(tmp_path):
    res = lint(tmp_path, {"src/a.py": """\
        import jax

        def f(x):
            g = jax.jit(lambda v: v)  # tracecheck: disable=TC005
            return g(x)
        """}, rule_set=[rules.rule_tc001])
    assert len(res.findings) == 1    # TC005 suppression does not hide TC001


# -- baseline ratchet ---------------------------------------------------------

def test_baseline_ratchet(tmp_path):
    # 1. a grandfathered finding passes under its baseline entry
    res = lint(tmp_path, BAD_TC001, rule_set=[rules.rule_tc001])
    key = res.findings[0].key
    entry = [{"key": key, "reason": "fixture debt"}]
    res = lint(tmp_path, BAD_TC001, rule_set=[rules.rule_tc001],
               baseline=entry)
    assert res.ok and [f.key for f in res.baselined] == [key]

    # 2. a NEW finding alongside the old one fails
    files = {"src/a.py": textwrap.dedent(BAD_TC001["src/a.py"])
             + "\n\ndef f2(x):\n    return jax.jit(lambda v: v)(x)\n"}
    res = lint(tmp_path, files, rule_set=[rules.rule_tc001], baseline=entry)
    assert not res.ok and len(res.new) == 1 and len(res.baselined) == 1

    # 3. fixing the debt without deleting the entry fails as stale
    res = lint(tmp_path, {"src/a.py": "X = 1\n"},
               rule_set=[rules.rule_tc001], baseline=entry)
    assert not res.ok and res.stale == [key]


def test_baseline_entries_require_reasons(tmp_path):
    bp = tmp_path / "baseline.json"
    bp.write_text(json.dumps({"version": 1,
                              "entries": [{"key": "TC001::x", "reason": ""}]}))
    with pytest.raises(ValueError, match="no reason"):
        load_baseline(bp)


# -- the real tree ------------------------------------------------------------

def test_committed_baseline_matches_fresh_run():
    """Tier-1 ratchet integrity: a fresh lint of the repo produces no new
    findings and leaves no stale baseline entries."""
    entries = (load_baseline(engine.DEFAULT_BASELINE)
               if engine.DEFAULT_BASELINE.exists() else [])
    res = run_lint(["src", "tests", "benchmarks", "tools"],
                   baseline_entries=entries)
    assert res.new == [], "\n".join(f.render() for f in res.new)
    assert res.stale == [], f"stale baseline entries: {res.stale}"


def test_cli_exit_codes(tmp_path):
    env = {**os.environ, "PYTHONPATH": str(REPO_ROOT)}
    ok = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--explain", "TC003"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env)
    assert ok.returncode == 0 and "lax.cond" in ok.stdout
    # an injected violation must fail the run: lint a fixture tree whose
    # root is tmp_path so the bad file counts as src/
    tree = tmp_path / "src"
    tree.mkdir()
    (tree / "bad.py").write_text(textwrap.dedent(BAD_TC001["src/a.py"]))
    fail = subprocess.run(
        [sys.executable, "-m", "tools.lint", "--no-baseline",
         "--root", str(tmp_path), "src"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=env)
    assert fail.returncode == 1 and "TC001" in fail.stdout

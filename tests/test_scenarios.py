"""Batched what-if scenario engine: equivalence, masking, proposals."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.desim import simulate_utilization
from repro.core.feedback import ProposalKind, propose_from_scenario
from repro.core.orchestrator import Orchestrator, OrchestratorConfig
from repro.core.power import PowerParams
from repro.core.scenarios import (
    Scenario,
    build_scenario_set,
    evaluate_scenarios,
    run_scenarios,
)
from repro.traces.schema import DatacenterConfig, Workload, stack_workloads
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like

T_BINS = int(0.5 * BINS_PER_DAY)
DC = DatacenterConfig(num_hosts=64, cores_per_host=16)


@pytest.fixture(scope="module")
def workload():
    return make_surf22_like(SurfTraceSpec(days=0.5, seed=11), DC)


@pytest.fixture(scope="module")
def reference(workload):
    return simulate_utilization(
        workload, num_hosts=DC.num_hosts, cores_per_host=DC.cores_per_host,
        t_bins=T_BINS)


def test_s1_matches_simulate_utilization_bitwise(workload, reference):
    """The batched engine at S=1 equals the single-topology path exactly."""
    _, sim, _, _ = evaluate_scenarios(
        workload, DC, [Scenario(name="base")], t_bins=T_BINS)
    np.testing.assert_array_equal(np.asarray(sim.u_th[0]),
                                  np.asarray(reference.u_th))
    np.testing.assert_array_equal(np.asarray(sim.queue_len[0]),
                                  np.asarray(reference.queue_len))
    np.testing.assert_array_equal(np.asarray(sim.running[0]),
                                  np.asarray(reference.running))
    np.testing.assert_array_equal(np.asarray(sim.job_start[0]),
                                  np.asarray(reference.job_start))
    np.testing.assert_array_equal(np.asarray(sim.job_host[0]),
                                  np.asarray(reference.job_host))


def test_padded_scenario_matches_unpadded(workload, reference):
    """A 64-host scenario inside a max_hosts=400 batch == an unpadded 64-host
    run on the active prefix, with zero utilization on the padded tail."""
    _, sim, _, _ = evaluate_scenarios(
        workload, DC,
        [Scenario(name="h64", num_hosts=64), Scenario(name="h400", num_hosts=400)],
        t_bins=T_BINS, max_hosts=400)
    u = np.asarray(sim.u_th[0])
    np.testing.assert_array_equal(u[:, :64], np.asarray(reference.u_th))
    assert (u[:, 64:] == 0.0).all()
    np.testing.assert_array_equal(np.asarray(sim.job_start[0]),
                                  np.asarray(reference.job_start))
    np.testing.assert_array_equal(np.asarray(sim.queue_len[0]),
                                  np.asarray(reference.queue_len))
    # padded hosts never receive jobs
    jh = np.asarray(sim.job_host[0])
    assert jh.max() < 64


def test_masked_metrics_ignore_padded_hosts(workload):
    """Mean utilization and power are computed over active hosts only —
    padding must not dilute performance metrics or add phantom idle draw."""
    _, sim, pred, summaries = evaluate_scenarios(
        workload, DC, [Scenario(name="h64", num_hosts=64)],
        t_bins=T_BINS, max_hosts=400)
    u = np.asarray(sim.u_th[0])
    np.testing.assert_allclose(
        np.asarray(pred.utilization[0]), u[:, :64].mean(axis=-1), rtol=1e-5)
    # 64 active hosts' idle floor, not 400
    p_idle = float(np.asarray(PowerParams().p_idle))
    assert np.asarray(pred.power_w[0]).min() >= 64 * p_idle - 1e-3
    assert np.asarray(pred.power_w[0]).max() < 400 * p_idle * 5


def test_summaries_report_unplaced_and_nan_on_empty(workload):
    # a 1-host scenario cannot place everything in half a day
    _, _, _, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="tiny", num_hosts=1), Scenario(name="base")],
        t_bins=T_BINS)
    tiny, base = summaries
    assert tiny.total_jobs == base.total_jobs == workload.num_jobs
    assert tiny.unplaced_jobs > base.unplaced_jobs
    assert tiny.kwh_per_cpu_hour > 0

    # empty workload -> NaN energy intensity, surfaced (not clamped)
    empty = Workload(
        submit_bin=jnp.zeros((2,), jnp.int32),
        duration_bins=jnp.ones((2,), jnp.int32),
        cores=jnp.ones((2,), jnp.int32),
        util_levels=jnp.ones((2, 2), jnp.float32),
        valid=jnp.zeros((2,), bool),
    )
    _, _, _, (s,) = evaluate_scenarios(
        empty, DC, [Scenario(name="empty")], t_bins=8)
    assert s.total_jobs == 0 and s.cpu_hours == 0.0
    assert math.isnan(s.kwh_per_cpu_hour)


def test_workload_perturbations_change_outcomes(workload):
    _, _, _, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="base"),
         Scenario(name="hot", util_scale=2.0),
         Scenario(name="rush", arrival_scale=4.0)],
        t_bins=T_BINS)
    base, hot, rush = summaries
    assert hot.energy_kwh > base.energy_kwh       # hotter jobs draw more
    assert rush.max_queue >= base.max_queue       # compressed arrivals queue


def test_stack_workloads_pads_to_common_max():
    a = Workload(jnp.zeros((2,), jnp.int32), jnp.ones((2,), jnp.int32),
                 jnp.ones((2,), jnp.int32), jnp.ones((2, 2), jnp.float32),
                 jnp.ones((2,), bool))
    b = Workload(jnp.zeros((5,), jnp.int32), jnp.ones((5,), jnp.int32),
                 jnp.ones((5,), jnp.int32), jnp.ones((5, 2), jnp.float32),
                 jnp.ones((5,), bool))
    s = stack_workloads([a, b])
    assert s.submit_bin.shape == (2, 5)
    assert not bool(s.valid[0, 2:].any())         # a's padding is invalid
    assert bool(s.valid[1].all())


def test_single_compilation_across_scenario_mixes(workload):
    """Different candidate mixes with identical (S, max_hosts, J) shapes hit
    the same compiled program — the engine's whole point."""
    if run_scenarios._cache_size is None:
        pytest.skip("jax private _cache_size API unavailable")
    # distinct names on purpose: names are jit-cache-key aux data and must be
    # anonymized by run_scenarios, or every renamed sweep recompiles
    ss1 = build_scenario_set(
        workload, DC,
        [Scenario(name="alpha", num_hosts=16),
         Scenario(name="beta", num_hosts=48)], max_hosts=64)
    ss2 = build_scenario_set(
        workload, DC,
        [Scenario(name="gamma", num_hosts=24),
         Scenario(name="delta", num_hosts=64)], max_hosts=64)
    run_scenarios(ss1, max_hosts=64, t_bins=T_BINS)[0].u_th.block_until_ready()
    after_first = run_scenarios._cache_size()
    run_scenarios(ss2, max_hosts=64, t_bins=T_BINS)[0].u_th.block_until_ready()
    assert run_scenarios._cache_size() == after_first


def test_policy_grid_single_compilation(workload):
    """A (policies x topologies) grid shares one compiled program with any
    other mix of the same (S, max_hosts, J, max_backfill) shape — the
    scheduler axis is traced, never a retrace."""
    if run_scenarios._cache_size is None:
        pytest.skip("jax private _cache_size API unavailable")
    grid1 = [Scenario(name=f"{p}-h{h}", policy=p, num_hosts=h,
                      backfill_depth=2)
             for p in ("first_fit", "worst_fit") for h in (32, 64)]
    grid2 = [Scenario(name=f"{p}-h{h}", policy=p, num_hosts=h,
                      backfill_depth=d)
             for (p, d) in (("best_fit", 1), ("random_fit", 2))
             for h in (16, 48)]
    ss1 = build_scenario_set(workload, DC, grid1, max_hosts=64)
    ss2 = build_scenario_set(workload, DC, grid2, max_hosts=64)
    assert ss1.max_backfill == ss2.max_backfill == 2
    run_scenarios(ss1, max_hosts=64, t_bins=T_BINS)[0].u_th.block_until_ready()
    after_first = run_scenarios._cache_size()
    run_scenarios(ss2, max_hosts=64, t_bins=T_BINS)[0].u_th.block_until_ready()
    assert run_scenarios._cache_size() == after_first


def test_summary_wait_fields(workload):
    _, _, _, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="base"), Scenario(name="tiny", num_hosts=1)],
        t_bins=T_BINS)
    base, tiny = summaries
    assert base.policy == "worst_fit" and base.backfill_depth == 0
    assert np.isfinite(base.mean_wait_bins)
    assert tiny.mean_wait_bins > base.mean_wait_bins   # starved topology waits
    assert tiny.p99_wait_bins >= tiny.mean_wait_bins


def test_propose_from_scenario_rules(workload):
    _, _, _, summaries = evaluate_scenarios(
        workload, DC,
        [Scenario(name="base"),
         Scenario(name="half", num_hosts=32),
         Scenario(name="capped", power_cap_w=100.0)],  # absurdly low cap
        t_bins=T_BINS)
    base, half, capped = summaries
    kinds = {p.kind for p in propose_from_scenario(0, half, base)}
    if half.unplaced_jobs <= base.unplaced_jobs:
        assert ProposalKind.SCALE_DOWN_IDLE in kinds
    cap_props = propose_from_scenario(0, capped, base)
    assert any(p.kind == ProposalKind.POWER_CAP for p in cap_props)


def test_no_intensity_outputs_match_pre_carbon_goldens():
    """With no carbon-intensity trace the engine's outputs are bit-for-bit
    the pre-carbon-subsystem outputs (goldens captured from the pre-PR
    engine).  The capped lane's *sim* outputs and pre-cap demand also match;
    its delivered power differs only where enforcement clips to the cap —
    the one intended behavior change (power_cap_w used to be flag-only)."""
    import pathlib

    g = np.load(pathlib.Path(__file__).parent
                / "golden" / "scenarios_pre_carbon.npz")
    dc = DatacenterConfig(num_hosts=32, cores_per_host=16)
    w = make_surf22_like(SurfTraceSpec(days=0.25, seed=5), dc)
    cap = 5000.0
    scs = [Scenario(name="base"),
           Scenario(name="h16", num_hosts=16),
           Scenario(name="bf", policy="best_fit", backfill_depth=2),
           Scenario(name="hot", util_scale=1.5),
           Scenario(name="cap", power_cap_w=cap)]
    _, sim, pred, summaries = evaluate_scenarios(w, dc, scs, t_bins=72)
    for k in ("u_th", "queue_len", "running", "job_start", "job_host"):
        np.testing.assert_array_equal(np.asarray(getattr(sim, k)), g[k],
                                      err_msg=k)
    for k in ("power_w", "energy_kwh", "tflops", "utilization", "efficiency"):
        np.testing.assert_array_equal(
            np.asarray(getattr(pred, k))[:4], g[k][:4], err_msg=k)
    # capped lane: demand is the old (flag-only) power; delivered power is
    # demand clipped to the cap, bit-identical wherever the cap is slack
    demand = np.asarray(pred.power_demand_w[4])
    np.testing.assert_array_equal(demand, g["power_w"][4])
    exceeded = g["power_w"][4] > cap
    delivered = np.asarray(pred.power_w[4])
    np.testing.assert_array_equal(delivered[~exceeded],
                                  g["power_w"][4][~exceeded])
    assert (delivered <= cap + 1e-6).all() or not exceeded.any()
    np.testing.assert_array_equal(
        [s.cap_exceeded_bins for s in summaries], g["cap_exceeded"])
    np.testing.assert_allclose(
        [s.energy_kwh for s in summaries[:4]], g["energy_total"][:4],
        rtol=1e-6)


def test_orchestrator_evaluate_whatif_routes_gate(workload):
    orch = Orchestrator(workload, DC, T_BINS,
                        OrchestratorConfig(bins_per_window=36, calibrate=False))
    res = orch.evaluate_whatif([Scenario(name="h32", num_hosts=32),
                                Scenario(name="cap", power_cap_w=100.0)])
    assert res.summaries[0].name == "baseline"
    assert len(res.summaries) == 3
    assert any(p.kind == ProposalKind.POWER_CAP for p in res.proposals)
    # proposals were submitted to the HITL gate, pending human decision
    assert len(orch.gate.pending()) >= len(res.proposals)


def test_evaluate_whatif_without_baseline_still_compares_to_baseline(workload):
    """Regression (ISSUE 4 satellite): with ``include_baseline=False`` the
    first *user* scenario used to be silently treated as the baseline —
    compared against itself, excluded from proposal generation.  Every user
    scenario must now be proposed against an explicit baseline summary."""
    cfg = OrchestratorConfig(bins_per_window=36, calibrate=False)
    with_base = Orchestrator(workload, DC, T_BINS, cfg).evaluate_whatif(
        [Scenario(name="cap", power_cap_w=100.0),
         Scenario(name="h32", num_hosts=32)])
    without = Orchestrator(workload, DC, T_BINS, cfg).evaluate_whatif(
        [Scenario(name="cap", power_cap_w=100.0),
         Scenario(name="h32", num_hosts=32)],
        include_baseline=False)
    # summaries: user scenarios only, but outcomes identical to the
    # include_baseline run's non-baseline lanes
    assert [s.name for s in without.summaries] == ["cap", "h32"]
    for a, b in zip(without.summaries, with_base.summaries[1:]):
        for f, va in a.__dict__.items():
            vb = b.__dict__[f]
            eq = (np.array_equal(va, vb, equal_nan=True)
                  if isinstance(va, float) else va == vb)
            assert eq, f"{a.name}.{f}: {va} != {vb}"
    assert np.asarray(without.prediction.power_w).shape[0] == 2
    # the first user scenario ("cap") now generates its POWER_CAP proposal —
    # pre-fix it was the phantom baseline and produced nothing
    assert {p.kind for p in without.proposals} == \
        {p.kind for p in with_base.proposals}
    assert any(p.kind == ProposalKind.POWER_CAP for p in without.proposals)


def test_evaluate_whatif_small_max_hosts_fits_baseline(workload):
    """A downsizing sweep with an explicit max_hosts below the current
    topology must keep working: the internal baseline raises the padded
    host axis instead of raising ValueError."""
    orch = Orchestrator(workload, DC, T_BINS,
                        OrchestratorConfig(bins_per_window=36,
                                           calibrate=False))
    res = orch.evaluate_whatif(
        [Scenario(name="h16", num_hosts=16),
         Scenario(name="h24", num_hosts=24)],
        include_baseline=False, max_hosts=24)
    assert [s.name for s in res.summaries] == ["h16", "h24"]
    # padded axis covers the baseline topology (64), per-lane outputs intact
    assert np.asarray(res.sim.u_th).shape[-1] == DC.num_hosts
    assert res.summaries[0].num_hosts == 16


def test_per_host_params_survive_whatif_path(workload):
    """Regression (ROADMAP item): per-host calibrated params used to be
    collapsed to per-scenario scalar means.  A heterogeneous fleet must
    predict with its own per-host curve on the what-if path."""
    from repro.core.power import datacenter_power

    rng = np.random.default_rng(7)
    p_idle_h = rng.uniform(55.0, 95.0, DC.num_hosts).astype(np.float32)
    p_max_h = rng.uniform(300.0, 420.0, DC.num_hosts).astype(np.float32)
    base = PowerParams(p_idle=jnp.asarray(p_idle_h),
                       p_max=jnp.asarray(p_max_h), r=2.3)
    ss, sim, pred, _ = evaluate_scenarios(
        workload, DC, [Scenario(name="base")], t_bins=T_BINS,
        base_params=base)
    assert ss.params.p_idle.shape == (1, DC.num_hosts)
    np.testing.assert_array_equal(np.asarray(ss.params.p_idle[0]), p_idle_h)
    # eager reference vs the fused jit program: equal to float32-ulp noise
    expect = np.asarray(datacenter_power(sim.u_th[0], base))
    np.testing.assert_allclose(np.asarray(pred.power_w[0]), expect,
                               rtol=1e-5)
    # the old scalar collapse gives a *measurably* different trace here
    collapsed = PowerParams(p_idle=float(p_idle_h.mean()),
                            p_max=float(p_max_h.mean()), r=2.3)
    wrong = np.asarray(datacenter_power(sim.u_th[0], collapsed))
    rel = np.abs(np.asarray(pred.power_w[0]) - wrong) / np.abs(wrong)
    assert rel.max() > 1e-3


def test_per_host_params_scalar_override_replaces_row(workload):
    rng = np.random.default_rng(8)
    base = PowerParams(
        p_idle=jnp.asarray(rng.uniform(60, 80, DC.num_hosts), jnp.float32),
        p_max=jnp.asarray(rng.uniform(330, 370, DC.num_hosts), jnp.float32),
        r=2.0)
    ss = build_scenario_set(
        workload, DC,
        [Scenario(name="keep"), Scenario(name="flat", p_idle=50.0,
                                         p_max=400.0)],
        base_params=base)
    # scenario 0 keeps the heterogeneous rows; scenario 1's override is flat
    assert not np.allclose(np.asarray(ss.params.p_idle[0]), 50.0)
    np.testing.assert_array_equal(np.asarray(ss.params.p_idle[1]),
                                  np.full(DC.num_hosts, 50.0, np.float32))
    np.testing.assert_array_equal(np.asarray(ss.params.p_max[1]),
                                  np.full(DC.num_hosts, 400.0, np.float32))


def test_scenario_knob_validation_at_construction():
    """ISSUE-5 satellite: the remaining unchecked knobs are validated at the
    concrete Scenario boundary, not only inside build_scenario_set."""
    # backfill_depth beyond the uint32 skip-mask width, and negative depths
    # (previously silently clamped to 0), both raise at construction
    with pytest.raises(ValueError, match=r"\[0, 31\]"):
        Scenario(backfill_depth=32)
    with pytest.raises(ValueError, match=r"\[0, 31\]"):
        Scenario(backfill_depth=-1)
    Scenario(backfill_depth=31)                     # boundary value is fine
    # a non-finite carbon_cap_slope would poison the per-bin effective cap
    for bad in (float("nan"), float("inf"), float("-inf")):
        with pytest.raises(ValueError, match="carbon_cap_slope"):
            Scenario(carbon_cap_base_w=1000.0, carbon_cap_slope=bad)
    Scenario(carbon_cap_base_w=1000.0, carbon_cap_slope=-60.0)


def test_build_scenario_set_max_backfill_pins_shape(workload):
    """An explicit max_backfill pins the compile-time backfill window across
    batches with different depth mixes (the optimizer's generation loop);
    depths beyond it are rejected loudly."""
    ss0 = build_scenario_set(workload, DC, [Scenario(name="d0")],
                             max_backfill=4)
    ss2 = build_scenario_set(
        workload, DC, [Scenario(name="d2", backfill_depth=2)],
        max_backfill=4)
    assert ss0.max_backfill == ss2.max_backfill == 4
    with pytest.raises(ValueError, match="max_backfill=1"):
        build_scenario_set(
            workload, DC, [Scenario(name="d2", backfill_depth=2)],
            max_backfill=1)
    with pytest.raises(ValueError, match=r"\[0, 31\]"):
        build_scenario_set(workload, DC, [Scenario(name="d0")],
                           max_backfill=40)
    # same (S, max_hosts, J, max_backfill) shape -> same compiled program
    if run_scenarios._cache_size is not None:
        run_scenarios(ss0, max_hosts=ss0.max_hosts,
                      t_bins=T_BINS)[0].u_th.block_until_ready()
        before = run_scenarios._cache_size()
        run_scenarios(ss2, max_hosts=ss2.max_hosts,
                      t_bins=T_BINS)[0].u_th.block_until_ready()
        assert run_scenarios._cache_size() == before


def test_per_host_params_scaled_up_topology_uses_fleet_mean(workload):
    base = PowerParams(p_idle=jnp.asarray([60.0, 80.0] * 32, jnp.float32),
                       p_max=350.0, r=2.0)
    ss = build_scenario_set(
        workload, DC, [Scenario(name="grow", num_hosts=96)],
        base_params=base, max_hosts=96)
    row = np.asarray(ss.params.p_idle[0])
    np.testing.assert_array_equal(row[:64], np.asarray([60.0, 80.0] * 32))
    # hypothetical added hosts assume fleet-average hardware
    np.testing.assert_allclose(row[64:], 70.0, rtol=1e-6)

"""Differential gate for the fused DES readout kernel (PR 7 tentpole).

Three rings of defense, tightest first:

* **bitwise** — the Pallas kernel (interpret mode, so it runs in tier-1
  CI on CPU) against the XLA reference ``des_readout_ref``: identical
  operand packing + identical tile function ⇒ f32 outputs must be *equal*,
  not close, across every axis combination and power model;
* **oracle** — both backends against the pure-f64 ``tests/reference.py``
  readout at the tolerances ``tests/test_oracle.py`` enforces;
* **engine** — ``run_scenarios(use_pallas=True)`` and
  ``predict_metrics(backend="pallas_interpret")`` against their legacy
  unfused paths: same scan bit-for-bit, readout within oracle tolerance,
  and identical ``None``-leaf structure.

The bf16 precision policy rides the same harness: sustainability leaves
must stay bitwise-f32; only tflops/efficiency may move, and by at most a
few bf16 ulps (the golden pin lives in ``test_precision_golden.py``).
Hypothesis property tests run when the optional dependency is installed
(CI exercises the skip path, per the optional-dependency policy).
"""

import pathlib
import sys

import numpy as np
import pytest

from reference import reference_readout

from repro.core.desim import predict_metrics
from repro.core.power import POWER_MODELS, PowerParams
from repro.core.scenarios import Scenario, evaluate_scenarios
from repro.kernels.des_readout import (
    READOUT_FIELDS,
    des_readout_pallas,
    des_readout_ref,
)
from repro.runtime.fault import DEGRADED, HostFailure
from repro.traces.schema import DatacenterConfig, Workload

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "tools"))
import capture_readout_golden  # noqa: E402  (golden config lives with the tool)

# oracle tolerances — test_oracle.py's contract for the f32 engine
RTOL = 1e-4
RTOL_GCO2 = 2e-4
ATOL = 1e-6

#: small tile size so every case exercises multi-tile grids (t0 offsets,
#: cross-tile failure windows) — the default TB_T would fold 97 bins into
#: one tile and hide indexing bugs
TB = 64

AXES = ("mask", "cap", "carbon", "failures", "pue", "price")


def _case(seed, t=97, h=13, axes=AXES):
    """Randomized readout inputs with the selected axes active."""
    rng = np.random.default_rng(seed)
    kw = dict(
        p_idle=rng.uniform(40.0, 90.0, h).astype(np.float32),
        p_max=rng.uniform(200.0, 420.0, h).astype(np.float32),
        r=np.float32(rng.uniform(1.2, 3.4)),
        peak_tflops=np.float32(rng.uniform(100.0, 500.0)),
        tb_t=TB,
    )
    u = rng.uniform(0.0, 1.15, (t, h)).astype(np.float32)  # >1: SMT bursts
    if "mask" in axes:
        kw["mask"] = rng.uniform(size=h) < 0.8
    if "cap" in axes:
        # cap at a demand quantile so some bins throttle and some don't,
        # never at the f32-vs-f64 knife edge of demand == cap
        rough = float(np.sum(kw["p_idle"]) + 0.4 * np.sum(kw["p_max"]))
        kw["cap_t"] = rng.uniform(0.5 * rough, 1.1 * rough, t).astype(
            np.float32)
    if "carbon" in axes:
        kw["intensity"] = rng.uniform(50.0, 600.0, t).astype(np.float32)
    if "failures" in axes:
        fs = np.where(rng.uniform(size=h) < 0.4,
                      rng.integers(0, t, h),
                      np.iinfo(np.int32).max).astype(np.int32)
        fe = np.minimum(fs.astype(np.int64)
                        + rng.integers(3, max(t // 2, 4), h),
                        np.iinfo(np.int32).max).astype(np.int32)
        kw.update(fail_start=fs, fail_end=fe,
                  fail_kill=rng.uniform(size=h) < 0.7)
    if "pue" in axes:
        kw.update(pue_base=np.float32(rng.uniform(1.05, 1.4)),
                  pue_amb_coeff=np.float32(rng.uniform(0.0, 0.05)),
                  pue_amb_ref=np.float32(rng.uniform(10.0, 22.0)),
                  pue_load_coeff=np.float32(rng.uniform(0.0, 0.25)),
                  ambient=rng.uniform(-5.0, 38.0, t).astype(np.float32))
    if "price" in axes:
        kw["price"] = rng.uniform(-0.05, 0.45, t).astype(np.float32)
    return u, kw


_AXIS_CASES = [
    ((), 0), (("mask",), 1), (("cap",), 2), (("cap", "carbon"), 3),
    (("failures",), 4), (("pue",), 5), (("price",), 6), (AXES, 7),
]


@pytest.mark.parametrize("axes,seed", _AXIS_CASES,
                         ids=["+".join(a) or "plain" for a, _ in _AXIS_CASES])
def test_pallas_bitwise_equals_xla_ref(axes, seed):
    """f32 kernel vs XLA reference: equal bits, every axis combination."""
    u, kw = _case(seed, axes=axes)
    got = des_readout_pallas(u, **kw, interpret=True)
    want = des_readout_ref(u, **kw)
    assert set(got) == set(READOUT_FIELDS)
    for k in READOUT_FIELDS:
        a, b = np.asarray(got[k]), np.asarray(want[k])
        assert a.shape == (u.shape[0],)
        assert np.array_equal(a, b), f"{k}: pallas != ref (axes {axes})"


@pytest.mark.parametrize("model", sorted(POWER_MODELS))
def test_power_models_bitwise(model):
    u, kw = _case(11, axes=("mask", "cap"))
    got = des_readout_pallas(u, **kw, model=model, interpret=True)
    want = des_readout_ref(u, **kw, model=model)
    for k in READOUT_FIELDS:
        assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), (
            f"{model}: {k}")


def test_unknown_model_and_precision_rejected():
    u, kw = _case(0, t=8, h=3, axes=())
    with pytest.raises(ValueError, match="unknown power model"):
        des_readout_ref(u, **kw, model="quartic")
    with pytest.raises(ValueError, match="unknown precision policy"):
        des_readout_ref(u, **kw, precision="f16")


@pytest.mark.parametrize("axes,seed", _AXIS_CASES,
                         ids=["+".join(a) or "plain" for a, _ in _AXIS_CASES])
def test_kernel_matches_f64_oracle(axes, seed):
    """Both backends vs the pure-Python f64 readout at oracle tolerance."""
    u, kw = _case(seed, axes=axes)
    t, h = u.shape
    mask = kw.get("mask", np.ones(h, bool))
    online = None
    if "failures" in axes:
        tt = np.arange(t)[:, None]
        offline = (kw["fail_kill"][None, :]
                   & (tt >= kw["fail_start"][None, :])
                   & (tt < kw["fail_end"][None, :]))
        online = (mask[None, :] & ~offline).tolist()
    elif "mask" in axes:
        online = np.broadcast_to(mask, (t, h)).tolist()
    # the oracle takes scalar p_idle/p_max and a scalar static cap, so the
    # oracle leg re-randomizes those as scalars (the kernel broadcasts them)
    rng = np.random.default_rng(seed + 1000)
    pi, pm = float(rng.uniform(40, 90)), float(rng.uniform(200, 420))
    kw = dict(kw, p_idle=np.float32(pi), p_max=np.float32(pm))
    cap = None
    if "cap" in axes:
        cap = float(h * rng.uniform(0.5, 1.1) * (pi + 0.4 * (pm - pi)))
        kw["cap_t"] = np.full(t, cap, np.float32)
    ref = reference_readout(
        u.tolist(), p_idle=pi, p_max=pm, r=float(kw["r"]),
        power_cap_w=cap,
        intensity=(None if "carbon" not in axes
                   else kw["intensity"].tolist()),
        online=online,
        pue=(None if "pue" not in axes
             else (float(kw["pue_base"]), float(kw["pue_amb_coeff"]),
                   float(kw["pue_amb_ref"]), float(kw["pue_load_coeff"]))),
        ambient=(None if "pue" not in axes else kw["ambient"].tolist()),
        price=(None if "price" not in axes else kw["price"].tolist()))
    for name, out in (("pallas", des_readout_pallas(u, **kw, interpret=True)),
                      ("ref", des_readout_ref(u, **kw))):
        pairs = [("power_demand_w", "demand", RTOL, 0.0),
                 ("power_w", "power", RTOL, 0.0),
                 ("utilization", "util", RTOL, ATOL),
                 ("energy_kwh", "energy_kwh", RTOL, 0.0)]
        if "carbon" in axes:
            pairs.append(("gco2", "gco2", RTOL_GCO2, 0.0))
        if "pue" in axes:
            pairs.append(("pue", "pue", RTOL, 0.0))
        if "price" in axes:
            pairs.append(("energy_cost", "cost", RTOL, 1e-5))
        for got_k, ref_k, rtol, atol in pairs:
            np.testing.assert_allclose(
                np.asarray(out[got_k], np.float64), np.asarray(ref[ref_k]),
                rtol=rtol, atol=atol,
                err_msg=f"{name}:{got_k} vs oracle {ref_k} (axes {axes})")


# -- engine integration -------------------------------------------------------

def _engine_case(seed=3, j=24, hosts=4, t_bins=60):
    rng = np.random.default_rng(seed)
    w = Workload(
        np.sort(rng.integers(0, t_bins // 2, j)).astype(np.int32),
        rng.integers(1, 9, j).astype(np.int32),
        rng.integers(1, 9, j).astype(np.int32),
        rng.uniform(0.1, 1.0, (j, 3)).astype(np.float32),
        np.ones(j, bool),
        deferrable=rng.random(j) < 0.5)
    dc = DatacenterConfig(num_hosts=hosts, cores_per_host=8)
    scs = [
        Scenario(name="base"),
        Scenario(name="small", num_hosts=hosts - 1, policy="best_fit"),
        Scenario(name="cap", power_cap_w=hosts * 150.0,
                 carbon_cap_base_w=hosts * 260.0, carbon_cap_slope=-0.4),
        Scenario(name="outage+pue", pue_base=1.2, pue_load_coeff=0.15,
                 pue_amb_coeff=0.02, failures=(
                     HostFailure(0, t_bins // 4, t_bins // 2),
                     HostFailure(1, 5, 20, kind=DEGRADED))),
        Scenario(name="shift", shift_bins=6),
    ]
    traces = dict(
        carbon_intensity=rng.uniform(80.0, 600.0, t_bins).astype(np.float32),
        ambient_c=rng.uniform(5.0, 35.0, t_bins).astype(np.float32),
        price=rng.uniform(0.02, 0.45, t_bins).astype(np.float32))
    return w, dc, scs, t_bins, traces


def test_run_scenarios_use_pallas_matches_legacy():
    w, dc, scs, t_bins, traces = _engine_case()
    params = PowerParams(p_idle=63.0, p_max=341.0, r=2.3)
    _, sim0, pred0, _ = evaluate_scenarios(
        w, dc, scs, t_bins=t_bins, base_params=params, **traces)
    _, sim1, pred1, _ = evaluate_scenarios(
        w, dc, scs, t_bins=t_bins, base_params=params, **traces,
        use_pallas=True)
    # the DES scan is untouched by the readout swap: schedules are equal
    np.testing.assert_array_equal(np.asarray(sim0.job_start),
                                  np.asarray(sim1.job_start))
    np.testing.assert_array_equal(np.asarray(sim0.u_th),
                                  np.asarray(sim1.u_th))
    for name in ("power_w", "energy_kwh", "tflops", "utilization",
                 "efficiency", "gco2", "power_demand_w", "pue",
                 "energy_cost"):
        a, b = getattr(pred0, name), getattr(pred1, name)
        assert (a is None) == (b is None), f"{name}: structure changed"
        if a is None:
            continue
        rtol = RTOL_GCO2 if name == "gco2" else RTOL
        np.testing.assert_allclose(np.asarray(b, np.float64),
                                   np.asarray(a, np.float64),
                                   rtol=rtol, atol=ATOL, err_msg=name)


def test_run_scenarios_use_pallas_no_axes_structure():
    """Axis-free sweep: optional leaves stay None on the kernel path too."""
    w, dc, scs, t_bins, _ = _engine_case()
    _, _, pred, _ = evaluate_scenarios(
        w, dc, [Scenario(name="base"), Scenario(name="bf",
                                                policy="best_fit")],
        t_bins=t_bins, use_pallas=True)
    assert pred.gco2 is None and pred.energy_cost is None
    assert pred.pue is None
    assert pred.power_demand_w is not None   # always filled by this engine


def test_predict_metrics_backend_matches_legacy():
    rng = np.random.default_rng(5)
    u = rng.uniform(0.0, 1.1, (36, 7)).astype(np.float32)
    dc = DatacenterConfig(num_hosts=7, cores_per_host=8)
    params = PowerParams(p_idle=70.0, p_max=350.0, r=2.0)
    from repro.traces.thermal import PUEParams
    kw = dict(carbon_intensity=rng.uniform(100, 500, 36).astype(np.float32),
              ambient_c=rng.uniform(0, 35, 36).astype(np.float32),
              price=rng.uniform(0.01, 0.4, 36).astype(np.float32),
              pue=PUEParams(base=1.2, amb_coeff=0.03, load_coeff=0.1))
    legacy = predict_metrics(u, params, dc, **kw)
    fused = predict_metrics(u, params, dc, **kw, backend="pallas_interpret")
    for name in ("power_w", "energy_kwh", "tflops", "utilization",
                 "efficiency", "gco2", "pue", "energy_cost"):
        np.testing.assert_allclose(
            np.asarray(getattr(fused, name), np.float64),
            np.asarray(getattr(legacy, name), np.float64),
            rtol=RTOL_GCO2, atol=ATOL, err_msg=name)
    # legacy structure: the demand leaf stays None on the twin-step path
    assert fused.power_demand_w is None and legacy.power_demand_w is None
    bare_l = predict_metrics(u, params, dc)
    bare_f = predict_metrics(u, params, dc, backend="pallas_interpret")
    for name in ("gco2", "pue", "energy_cost", "power_demand_w"):
        assert getattr(bare_f, name) is None
        assert getattr(bare_l, name) is None


# -- precision policy ---------------------------------------------------------

def test_bf16_policy_sustainability_stays_f32():
    """bf16 touches only tflops/efficiency; everything else is bitwise f32."""
    u, kw = _case(7, axes=AXES)
    f32 = des_readout_pallas(u, **kw, interpret=True)
    bf16 = des_readout_pallas(u, **kw, precision="bf16", interpret=True)
    ref16 = des_readout_ref(u, **kw, precision="bf16")
    for k in READOUT_FIELDS:
        # the policy is backend-invariant: pallas bf16 == ref bf16 bitwise
        assert np.array_equal(np.asarray(bf16[k]), np.asarray(ref16[k])), k
    for k in set(READOUT_FIELDS) - {"tflops", "efficiency"}:
        assert np.array_equal(np.asarray(bf16[k]), np.asarray(f32[k])), (
            f"{k}: bf16 policy leaked into a sustainability leaf")
    for k in ("tflops", "efficiency"):
        a, b = np.asarray(bf16[k], np.float64), np.asarray(f32[k], np.float64)
        rel = np.abs(a - b) / np.maximum(np.abs(b), 1e-9)
        # a couple of bf16 rounding steps (eps = 2^-8), never more
        assert float(rel.max()) < 2.0 ** -6, f"{k}: bf16 error {rel.max()}"


def test_bf16_golden_pinned():
    """The precision policy is pinned bit-for-bit by the committed golden.

    Regen (only) on an intentional policy change:
    ``PYTHONPATH=src python tools/capture_readout_golden.py``.
    """
    g = np.load(pathlib.Path(__file__).parent / "golden" / "readout_bf16.npz")
    bf16, f32 = capture_readout_golden.run()
    for k in READOUT_FIELDS:
        np.testing.assert_array_equal(np.asarray(bf16[k]), g[f"bf16_{k}"],
                                      err_msg=f"bf16 {k} drifted from golden")
        np.testing.assert_array_equal(np.asarray(f32[k]), g[f"f32_{k}"],
                                      err_msg=f"f32 {k} drifted from golden")
    # the policy's promise, asserted against the committed artifact itself:
    # sustainability leaves identical, perf leaves inside oracle headroom
    for k in set(READOUT_FIELDS) - {"tflops", "efficiency"}:
        np.testing.assert_array_equal(g[f"bf16_{k}"], g[f"f32_{k}"])
    for k in ("tflops", "efficiency"):
        rel = (np.abs(g[f"bf16_{k}"].astype(np.float64) - g[f"f32_{k}"])
               / np.maximum(np.abs(g[f"f32_{k}"]), 1e-9))
        assert float(rel.max()) < 2.0 ** -6


# hypothesis property tests live in test_des_kernel_property.py (module-level
# importorskip, same optional-dependency policy as tests/test_property.py)

"""Failure, dynamic-PUE and spot-price axes vs the pure-Python oracle.

The three axes added to the scenario engine — per-host failure windows,
dynamic PUE(load, ambient) and electricity spot prices — are traced lanes
of the same single-compile program as caps/shifts/policies/topologies.
These tests check them three ways:

* randomized cross-checks against ``tests/reference.py`` (schedules exact,
  float read-outs to f32 tolerance);
* hand-built semantic cases (outage kills vs drain finishes; outage hosts
  draw nothing, drained hosts keep their idle floor);
* the off-switch: a mixed batch's axis-free lane is bit-for-bit the run
  with no axes at all, and invalid axis inputs fail loudly at build time.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from reference import reference_pue, reference_scenario

from repro.core.feedback import ProposalKind
from repro.core.power import PowerParams
from repro.core.scenarios import (
    Scenario,
    build_scenario_set,
    evaluate_scenarios,
    run_scenarios,
)
from repro.runtime.fault import DEGRADED, HostFailure
from repro.traces.schema import DatacenterConfig, Workload


def _random_case(seed, j=20, hosts=3, cores_per_host=8, t_bins=40):
    rng = np.random.default_rng(seed)
    submit = np.sort(rng.integers(0, t_bins // 2, j)).astype(np.int32)
    dur = rng.integers(1, 8, j).astype(np.int32)
    cores = rng.integers(1, cores_per_host + 1, j).astype(np.int32)
    util = rng.uniform(0.1, 1.0, (j, 3)).astype(np.float32)
    defer = rng.random(j) < 0.6
    w = Workload(jnp.asarray(submit), jnp.asarray(dur), jnp.asarray(cores),
                 jnp.asarray(util), jnp.ones((j,), bool),
                 deferrable=jnp.asarray(defer))
    dc = DatacenterConfig(num_hosts=hosts, cores_per_host=cores_per_host)
    intensity = rng.uniform(80.0, 600.0, t_bins).astype(np.float32)
    ambient = rng.uniform(5.0, 35.0, t_bins).astype(np.float32)
    price = rng.uniform(0.02, 0.45, t_bins).astype(np.float32)
    return w, dc, t_bins, intensity, ambient, price


def _workload_dict(w: Workload) -> dict:
    return dict(
        submit=np.asarray(w.submit_bin).tolist(),
        dur=np.asarray(w.duration_bins).tolist(),
        cores=np.asarray(w.cores).tolist(),
        util=np.asarray(w.util_levels).tolist(),
        valid=np.asarray(w.valid).tolist(),
        deferrable=(None if w.deferrable is None
                    else np.asarray(w.deferrable).tolist()),
    )


#: the new-axes mix: outages, drains, dynamic PUE, and combinations with the
#: pre-existing axes (caps, shifts, policies) in one batch.
def _scenarios(hosts, t_bins):
    watts = hosts * 120.0
    return [
        Scenario(name="base"),
        Scenario(name="outage", failures=(
            HostFailure(0, t_bins // 4, t_bins // 2),)),
        Scenario(name="drain", failures=(
            HostFailure(hosts - 1, 5, t_bins - 3, kind=DEGRADED),)),
        Scenario(name="multi-fail", failures=(
            HostFailure(0, 3, 11),
            HostFailure(1, 8, 20, kind=DEGRADED),)),
        Scenario(name="pue", pue_base=1.15, pue_amb_coeff=0.02,
                 pue_amb_ref=16.0, pue_load_coeff=0.12),
        Scenario(name="pue-cap", pue_base=1.3, power_cap_w=watts * 1.8),
        Scenario(name="fail-pue-shift", shift_bins=5, pue_base=1.1,
                 pue_load_coeff=0.2,
                 failures=(HostFailure(1, t_bins // 3, t_bins // 2),)),
        Scenario(name="bf-fail", policy="best_fit", backfill_depth=3,
                 failures=(HostFailure(0, 10, 25),)),
    ]


@pytest.mark.parametrize("seed", [2, 13, 31])
def test_new_axes_match_oracle(seed):
    w, dc, t_bins, intensity, ambient, price = _random_case(seed)
    params = PowerParams(p_idle=63.0, p_max=341.0, r=2.3)
    scs = _scenarios(dc.num_hosts, t_bins)
    ss, sim, pred, summaries = evaluate_scenarios(
        w, dc, scs, t_bins=t_bins, base_params=params,
        carbon_intensity=intensity, ambient_c=ambient, price=price)
    assert ss.has_failures and ss.pue_on
    wd = _workload_dict(w)
    for i, sc in enumerate(scs):
        ref = reference_scenario(
            wd, dc, sc, t_bins=t_bins, p_idle=63.0, p_max=341.0, r=2.3,
            intensity=[float(v) for v in intensity],
            ambient=[float(v) for v in ambient],
            price=[float(v) for v in price])
        # schedules (kill/drain placement rules) are exact
        assert np.asarray(sim.job_start[i]).tolist() == ref["job_start"], sc.name
        assert np.asarray(sim.job_host[i]).tolist() == ref["job_host"], sc.name
        np.testing.assert_allclose(
            np.asarray(sim.u_th[i], np.float64), np.asarray(ref["u_th"]),
            rtol=2e-5, atol=1e-6, err_msg=f"{sc.name}: u_th")
        np.testing.assert_allclose(
            np.asarray(pred.power_demand_w[i], np.float64),
            np.asarray(ref["demand"]), rtol=1e-4, err_msg=f"{sc.name}: demand")
        np.testing.assert_allclose(
            np.asarray(pred.power_w[i], np.float64),
            np.asarray(ref["power"]), rtol=1e-4,
            err_msg=f"{sc.name}: delivered power")
        np.testing.assert_allclose(
            np.asarray(pred.utilization[i], np.float64),
            np.asarray(ref["util"]), rtol=1e-4, atol=1e-6,
            err_msg=f"{sc.name}: utilization")
        # PUE lane: scenarios without the axis run the identity sentinel 1.0
        got_pue = np.asarray(pred.pue[i], np.float64)
        if sc.pue_base is not None:
            np.testing.assert_allclose(
                got_pue, np.asarray(ref["pue"]), rtol=1e-5,
                err_msg=f"{sc.name}: pue")
        else:
            assert (got_pue == 1.0).all(), f"{sc.name}: identity pue lane"
        np.testing.assert_allclose(
            np.asarray(pred.energy_cost[i], np.float64),
            np.asarray(ref["cost"]), rtol=2e-4, err_msg=f"{sc.name}: cost")
        np.testing.assert_allclose(
            np.asarray(pred.gco2[i], np.float64), np.asarray(ref["gco2"]),
            rtol=2e-4, err_msg=f"{sc.name}: gco2")
        # summary roll-ups
        assert summaries[i].failure_events == len(sc.failures)
        assert summaries[i].energy_cost == pytest.approx(
            sum(ref["cost"]), rel=2e-4)
        assert summaries[i].mean_pue == pytest.approx(
            float(np.mean(got_pue)), rel=1e-6)


def test_outage_kills_and_unpowers_drain_does_not():
    """Hand-built semantics: one long job per host, failure window in the
    middle.  The outage host's job dies at fail_start and the host draws
    *nothing* during the window; the drained host's job finishes and keeps
    paying its power bill throughout."""
    t_bins = 20
    w = Workload(jnp.asarray([0, 0], jnp.int32),
                 jnp.asarray([16, 16], jnp.int32),
                 jnp.asarray([4, 4], jnp.int32),
                 jnp.full((2, 1), 0.8, jnp.float32),
                 jnp.ones((2,), bool))
    dc = DatacenterConfig(num_hosts=2, cores_per_host=4)
    params = PowerParams(p_idle=100.0, p_max=300.0, r=2.0)
    scs = [
        Scenario(name="kill", failures=(HostFailure(0, 5, 12),)),
        Scenario(name="drain", failures=(
            HostFailure(0, 5, 12, kind=DEGRADED),)),
        Scenario(name="none"),
    ]
    _, sim, pred, _ = evaluate_scenarios(
        w, dc, scs, t_bins=t_bins, base_params=params)
    u = np.asarray(sim.u_th)
    # worst_fit ties break to the lowest host index, so job 0 lands on
    # host 0 (the failing host) and job 1 on host 1
    assert np.asarray(sim.job_host[2]).tolist() == [0, 1]
    # kill: host 0's job stops at bin 5, never resumes
    assert u[0, 4, 0] > 0 and (u[0, 5:, 0] == 0).all()
    # drain: job keeps running through the window
    assert (u[1, :16, 0] > 0).all()
    # power: during [5, 12) the outage lane omits host 0 entirely (not even
    # idle watts) while the drain lane keeps both hosts' draw
    p_kill = np.asarray(pred.power_w[0], np.float64)
    p_drain = np.asarray(pred.power_w[1], np.float64)
    p_none = np.asarray(pred.power_w[2], np.float64)
    for t in range(5, 12):
        assert p_drain[t] == pytest.approx(p_none[t], rel=1e-6)
        assert p_kill[t] <= p_drain[t] - params.p_idle + 1e-6
    # after recovery host 0 draws idle again in the kill lane
    assert p_kill[13] > p_kill[6]


def test_killed_jobs_hold_cores_until_recovery():
    """A killed job's cores come back with the host, not at the kill bin:
    a successor can only land on the failed host at fail_end."""
    t_bins = 20
    w = Workload(jnp.asarray([0, 6], jnp.int32),
                 jnp.asarray([10, 4], jnp.int32),
                 jnp.asarray([4, 4], jnp.int32),
                 jnp.full((2, 1), 0.5, jnp.float32),
                 jnp.ones((2,), bool))
    dc = DatacenterConfig(num_hosts=1, cores_per_host=4)
    _, sim, _, _ = evaluate_scenarios(
        w, dc, [Scenario(name="f", failures=(HostFailure(0, 4, 9),))],
        t_bins=t_bins, base_params=PowerParams())
    # job 0 (placed at 0, runs into the window) dies at 4; its cores are
    # held until the host returns at 9, so job 1 (submitted at 6) starts
    # exactly at the recovery bin
    assert np.asarray(sim.job_start[0]).tolist() == [0, 9]


def test_mixed_batch_axis_free_lane_is_bit_for_bit():
    """The static-flag design in action: lanes that do not use an axis run
    the identity sentinels, and their outputs equal an axes-off batch's
    bit for bit (not just approximately)."""
    w, dc, t_bins, intensity, ambient, price = _random_case(8)
    params = PowerParams(p_idle=63.0, p_max=341.0, r=2.3)
    mixed = [Scenario(name="base"),
             Scenario(name="f", failures=(HostFailure(0, 10, 20),)),
             Scenario(name="p", pue_base=1.2, pue_load_coeff=0.1)]
    _, sim_m, pred_m, _ = evaluate_scenarios(
        w, dc, mixed, t_bins=t_bins, base_params=params,
        carbon_intensity=intensity, ambient_c=ambient, price=price)
    _, sim_0, pred_0, _ = evaluate_scenarios(
        w, dc, [Scenario(name="base")], t_bins=t_bins, base_params=params,
        carbon_intensity=intensity)
    assert np.asarray(sim_m.u_th[0]).tobytes() == \
        np.asarray(sim_0.u_th[0]).tobytes()
    assert np.asarray(pred_m.power_w[0]).tobytes() == \
        np.asarray(pred_0.power_w[0]).tobytes()
    # axes off entirely -> the optional outputs stay None
    assert pred_0.pue is None and pred_0.energy_cost is None


def test_degradation_from_stragglers_bridge():
    """Straggler proposals map to DEGRADED windows the DES can consume."""
    from repro.core.feedback import Proposal
    from repro.runtime.straggler import degradation_from_stragglers

    props = [
        Proposal(ProposalKind.RESTART_STRAGGLER, 3, "host 2 slow",
                 impact={"host": 2, "ratio": 1.9}),
        Proposal(ProposalKind.RECALIBRATE, 3, "mape"),
        Proposal(ProposalKind.RESTART_STRAGGLER, 3, "host 2 again",
                 impact={"host": 2, "ratio": 2.1}),
        Proposal(ProposalKind.RESTART_STRAGGLER, 3, "host 0 slow",
                 impact={"host": 0, "ratio": 1.5}),
    ]
    fails = degradation_from_stragglers(props, start_bin=12, duration_bins=6)
    assert [f.host for f in fails] == [2, 0]
    assert all(f.kind == DEGRADED and f.start_bin == 12 and f.end_bin == 18
               for f in fails)
    # and they are valid scenario-axis input
    build_scenario_set(
        Workload(jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32),
                 jnp.asarray([1], jnp.int32), jnp.ones((1, 1), jnp.float32),
                 jnp.ones((1,), bool)),
        DatacenterConfig(num_hosts=3, cores_per_host=4),
        [Scenario(name="s", failures=fails)])


def test_reference_pue_shape():
    """Oracle PUE replica: load term falls with load, ambient term kicks in
    above the reference temperature only."""
    pue = (1.2, 0.05, 18.0, 0.3)
    assert reference_pue(1.0, None, pue) == pytest.approx(1.2)
    assert reference_pue(0.0, None, pue) == pytest.approx(1.5)
    assert reference_pue(1.0, 17.0, pue) == pytest.approx(1.2)
    assert reference_pue(1.0, 28.0, pue) == pytest.approx(1.2 + 0.05 * 10)


def test_orchestrator_window_cost_and_measured_overrides():
    """Windowed twinning with the new forecasts: the energy-cost record
    prices the window, measured telemetry extras (PRICE_KEY/AMBIENT_KEY)
    override the configured forecasts, and a PUE-bearing TwinConfig
    checkpoints and resumes."""
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig
    from repro.core.telemetry import AMBIENT_KEY, PRICE_KEY, clip_to_window
    from repro.traces.thermal import PUEParams

    t_bins, j = 48, 16
    rng = np.random.default_rng(4)
    w = Workload(jnp.asarray(np.sort(rng.integers(0, 24, j)), jnp.int32),
                 jnp.asarray(rng.integers(1, 6, j), jnp.int32),
                 jnp.asarray(rng.integers(1, 4, j), jnp.int32),
                 jnp.asarray(rng.uniform(0.2, 0.9, (j, 2)), jnp.float32),
                 jnp.ones(j, bool))
    dc = DatacenterConfig(num_hosts=3, cores_per_host=4)
    price = np.full(t_bins, 0.10, np.float32)
    ambient = np.full(t_bins, 20.0, np.float32)
    cfg = OrchestratorConfig(
        bins_per_window=24,
        pue=PUEParams(base=1.2, amb_coeff=0.02, load_coeff=0.1))
    orch = Orchestrator(w, dc, t_bins, cfg, ambient_c=ambient, price=price)
    sim = orch._ensure_sim()
    u = np.asarray(sim.u_th)
    p_meas = 80.0 + 150.0 * u.sum(axis=1)
    # window 0 carries measured price 3x the forecast
    orch.store.ingest(clip_to_window(
        0, 24, 0, u[:24], p_meas[:24],
        **{PRICE_KEY: price[:24] * 3.0, AMBIENT_KEY: ambient[:24] + 5.0}))
    r0 = orch.run_window(0)
    r1 = orch.run_window(1)      # no telemetry: forecast-priced
    assert r0.energy_cost is not None and r1.energy_cost is not None
    # measured price is 3x the forecast, same energy to first order -> the
    # window-0 record must be priced well above the forecast-only window
    assert r0.energy_cost > 2.0 * r1.energy_cost
    # facility power: prediction carries a PUE > 1 everywhere
    assert (np.asarray(r0.prediction.pue) > 1.0).all()
    # checkpoint/resume round-trips the PUE-bearing config
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tw.msgpack")
        orch.save_state(path)
        orch.restore_state(path)
    assert orch.state.cfg.pue == cfg.pue


def test_cost_optimal_differs_from_carbon_optimal():
    """On opposing synthetic traces (price cheap where carbon is dirty and
    vice versa) the searched what-if lands on *different* operating points
    under a cost objective vs a carbon objective, and the cost winner is
    routed through the HITL gate as a COST_REDUCTION with a $ breakdown."""
    from repro.core.optimize import ObjectiveSpec, SearchSpace
    from repro.core.orchestrator import Orchestrator, OrchestratorConfig

    t_bins, j = 48, 12
    w = Workload(jnp.zeros(j, jnp.int32), jnp.full(j, 4, jnp.int32),
                 jnp.full(j, 2, jnp.int32),
                 jnp.full((j, 1), 0.8, jnp.float32), jnp.ones(j, bool))
    dc = DatacenterConfig(num_hosts=2, cores_per_host=4)
    price = np.where(np.arange(t_bins) < t_bins // 2, 0.50, 0.05)
    carbon = np.where(np.arange(t_bins) < t_bins // 2, 50.0, 600.0)
    space = SearchSpace(structures=(Scenario(name="s"),),
                        shift_bins=(0, 24))

    def run(objective):
        orch = Orchestrator(
            w, dc, t_bins, OrchestratorConfig(bins_per_window=24),
            carbon_intensity=carbon.astype(np.float32),
            price=price.astype(np.float32))
        return orch.optimize_whatif(space=space, objective=objective, key=1)

    cost = run(ObjectiveSpec(w_gco2_kg=0.0, w_cost=1.0, w_wait=0.0))
    carb = run(ObjectiveSpec(w_gco2_kg=1.0, w_cost=0.0, w_wait=0.0))
    # cost chases the cheap second half; carbon stays in the clean first
    assert cost.result.best.scenario.shift_bins > 0
    assert carb.result.best.scenario.shift_bins == 0
    assert cost.result.best_summary.energy_cost < \
        cost.result.baseline_summary.energy_cost
    # HITL routing: a cost proposal carrying the $ breakdown vs baseline
    kinds = {p.kind for p in cost.proposals}
    assert ProposalKind.COST_REDUCTION in kinds
    for p in cost.proposals:
        bd = p.impact["objective_breakdown"]
        bd0 = p.impact["objective_breakdown_baseline"]
        assert bd["energy_cost"] < bd0["energy_cost"]


# -- validation: every bad axis input fails loudly at build time --------------

def test_scenario_validation_errors():
    with pytest.raises(ValueError, match="pue_base must be finite and >= 1"):
        Scenario(name="x", pue_base=0.9)
    with pytest.raises(ValueError, match="without pue_base"):
        Scenario(name="x", pue_load_coeff=0.1)
    with pytest.raises(ValueError, match="0 <= start < end"):
        HostFailure(0, 7, 7)
    with pytest.raises(ValueError, match="host must be >= 0"):
        HostFailure(-1, 0, 5)
    with pytest.raises(ValueError, match="outage.*degraded"):
        HostFailure(0, 0, 5, kind="meltdown")


def test_build_rejects_bad_failure_hosts():
    w = Workload(jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32),
                 jnp.asarray([1], jnp.int32), jnp.ones((1, 1), jnp.float32),
                 jnp.ones((1,), bool))
    dc = DatacenterConfig(num_hosts=2, cores_per_host=4)
    with pytest.raises(ValueError, match="out of range"):
        build_scenario_set(w, dc, [
            Scenario(name="s", failures=(HostFailure(5, 0, 3),))])
    with pytest.raises(ValueError, match="merge them first"):
        build_scenario_set(w, dc, [
            Scenario(name="s", failures=(HostFailure(0, 0, 3),
                                         HostFailure(0, 4, 6)))])


def test_run_rejects_window_past_horizon_and_missing_traces():
    w = Workload(jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32),
                 jnp.asarray([1], jnp.int32), jnp.ones((1, 1), jnp.float32),
                 jnp.ones((1,), bool))
    dc = DatacenterConfig(num_hosts=2, cores_per_host=4)
    ss = build_scenario_set(w, dc, [
        Scenario(name="s", failures=(HostFailure(0, 50, 60),))])
    with pytest.raises(ValueError, match="can never fire"):
        run_scenarios(ss, max_hosts=2, t_bins=10)
    ss2 = build_scenario_set(w, dc, [
        Scenario(name="s", pue_base=1.2, pue_amb_coeff=0.05)])
    with pytest.raises(ValueError, match="no ambient_c trace"):
        run_scenarios(ss2, max_hosts=2, t_bins=10)
    with pytest.raises(ValueError, match="non-finite"):
        run_scenarios(ss2, max_hosts=2, t_bins=10,
                      ambient_c=np.full(10, 20.0, np.float32),
                      price=np.array([np.nan] * 10, np.float32))


def test_property_validation_fuzz():
    """Property check (optional hypothesis): any pue_base < 1 or non-finite
    is rejected; any valid (base, coeffs) combination is accepted."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(base=st.floats(min_value=-10, max_value=10,
                          allow_nan=True, allow_infinity=True),
           load=st.floats(min_value=0, max_value=2))
    def check(base, load):
        ok = math.isfinite(base) and base >= 1.0
        if ok:
            s = Scenario(name="s", pue_base=base, pue_load_coeff=load)
            assert s.pue_base == base
        else:
            with pytest.raises(ValueError):
                Scenario(name="s", pue_base=base, pue_load_coeff=load)

    check()

    @settings(max_examples=40, deadline=None)
    @given(start=st.integers(min_value=-5, max_value=30),
           end=st.integers(min_value=-5, max_value=30))
    def check_windows(start, end):
        if 0 <= start < end:
            assert HostFailure(0, start, end).end_bin == end
        else:
            with pytest.raises(ValueError):
                HostFailure(0, start, end)

    check_windows()

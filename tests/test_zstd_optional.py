"""Regression tests: `zstandard` is optional, persistence works without it.

The seed suite died at collection on ``import zstandard`` in telemetry and
checkpointing.  These tests pin the fix: the modules import cleanly with the
package absent, blobs round-trip under the stdlib zlib fallback, and the
one-byte codec id makes files self-describing.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.core import codec

# repro is a namespace package (no __init__.py): locate src via __path__
_SRC = os.path.dirname(list(repro.__path__)[0])


def test_imports_survive_missing_zstandard():
    """`import repro.core` / `repro.checkpoint.ckpt` succeed without zstandard.

    Runs in a subprocess with the zstandard import explicitly poisoned so the
    test holds even on machines where the package *is* installed.
    """
    snippet = (
        "import sys\n"
        "sys.modules['zstandard'] = None\n"   # poison: 'import zstandard' fails
        "import repro.core\n"
        "import repro.checkpoint.ckpt\n"
        "from repro.core import codec\n"
        "assert codec.HAVE_ZSTD is False\n"
        "assert codec.default_codec() == codec.CODEC_ZLIB\n"
        "print('IMPORT_OK')\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", snippet],
        capture_output=True, text=True, timeout=120, env=env,
    )
    assert "IMPORT_OK" in out.stdout, out.stdout + out.stderr


def test_codec_zlib_round_trip():
    data = b"windowed telemetry " * 100
    blob = codec.compress(data, codec=codec.CODEC_ZLIB)
    assert blob[:1] == codec.CODEC_ZLIB
    assert codec.decompress(blob) == data


def test_codec_rejects_unknown_id():
    with pytest.raises(ValueError):
        codec.decompress(b"\x7fgarbage")
    with pytest.raises(ValueError):
        codec.decompress(b"")


@pytest.mark.skipif(codec.HAVE_ZSTD, reason="zstandard installed")
def test_zstd_blob_without_zstandard_is_explicit():
    with pytest.raises(RuntimeError, match="zstd"):
        codec.decompress(codec.CODEC_ZSTD + b"\x28\xb5\x2f\xfdxxxx")


def test_telemetry_store_round_trip_zlib(tmp_path, monkeypatch):
    from repro.core.telemetry import TelemetryStore, clip_to_window

    # force the fallback codec regardless of the environment
    monkeypatch.setattr(codec, "HAVE_ZSTD", False)

    rng = np.random.default_rng(0)
    store = TelemetryStore(bins_per_window=6)
    for win in range(3):
        tw = clip_to_window(
            win, 6, win * 6,
            rng.random((6, 4)).astype(np.float32),
            rng.uniform(1e3, 2e3, 6),
            temp=rng.random(6).astype(np.float32),
        )
        store.ingest(tw)
    path = str(tmp_path / "telemetry.bin")
    store.flush(path)
    with open(path, "rb") as f:
        assert f.read(1) == codec.CODEC_ZLIB

    loaded = TelemetryStore.load(path)
    assert sorted(loaded.windows()) == [0, 1, 2]
    for win in range(3):
        a, b = store.get(win), loaded.get(win)
        np.testing.assert_array_equal(a.u_th, b.u_th)
        np.testing.assert_array_equal(a.power_w, b.power_w)
        np.testing.assert_array_equal(a.extras["temp"], b.extras["temp"])


def test_checkpoint_round_trip_zlib(tmp_path, monkeypatch):
    from repro.checkpoint import ckpt

    monkeypatch.setattr(codec, "HAVE_ZSTD", False)

    state = {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
        "step_count": 7,
        "note": "zlib fallback",
    }
    path = ckpt.save(str(tmp_path), 7, state)
    with open(path, "rb") as f:
        assert f.read(1) == codec.CODEC_ZLIB

    step, restored = ckpt.restore(str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(restored["params"]["w"], state["params"]["w"])
    assert restored["step_count"] == 7
    assert restored["note"] == "zlib fallback"

"""Hypothesis property tests for the fused DES readout kernel.

``hypothesis`` is optional (same policy as ``tests/test_property.py``):
environments without it skip this module instead of failing collection.
Randomized shapes and axis subsets probe what the parametrized cases in
``test_des_kernel.py`` can't enumerate — odd tile remainders, single-bin
horizons, every axis power set — and assert both the bitwise
pallas-vs-reference contract and the physical invariants of the readout.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.des_readout import (
    READOUT_FIELDS,
    des_readout_pallas,
    des_readout_ref,
)
from test_des_kernel import AXES, _case


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1),
       t=st.integers(1, 70), h=st.integers(1, 9),
       axes=st.sets(st.sampled_from(AXES)))
def test_bitwise_and_physical_invariants(seed, t, h, axes):
    u, kw = _case(seed, t=t, h=h, axes=tuple(sorted(axes)))
    got = des_readout_pallas(u, **kw, interpret=True)
    want = des_readout_ref(u, **kw)
    for k in READOUT_FIELDS:
        assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), k
    power = np.asarray(got["power_w"], np.float64)
    demand = np.asarray(got["power_demand_w"], np.float64)
    energy = np.asarray(got["energy_kwh"], np.float64)
    util = np.asarray(got["utilization"], np.float64)
    assert np.all(np.isfinite(demand)) and np.all(np.isfinite(util))
    # delivered power never exceeds demand, and the cap is enforced exactly
    assert np.all(power <= demand)
    if "cap" in axes:
        assert np.all(power <= np.asarray(kw["cap_t"], np.float64))
    # energy is delivered power integrated over the 5-minute bin
    np.testing.assert_allclose(energy, power * (300.0 / 3600.0) / 1000.0,
                               rtol=1e-6)
    assert np.all(util >= 0.0) and np.all(util <= 1.0 + 1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1), t=st.integers(1, 50),
       h=st.integers(1, 8))
def test_bf16_policy_never_touches_sustainability(seed, t, h):
    u, kw = _case(seed, t=t, h=h, axes=AXES)
    f32 = des_readout_ref(u, **kw)
    bf16 = des_readout_ref(u, **kw, precision="bf16")
    for k in set(READOUT_FIELDS) - {"tflops", "efficiency"}:
        assert np.array_equal(np.asarray(bf16[k]), np.asarray(f32[k])), k

"""Streaming twin service gates (the PR-9 tentpole).

The load-bearing properties:

* **one program** — serving 64 tenants through arbitrary arrival order and
  partial batches compiles ``fleet_step_masked`` exactly once;
* **bitwise serving** — every emitted window (computed or cache-hit) is
  bit-for-bit the output of a solo ``twin_step`` stream for that tenant;
* **kill-and-restore** — checkpointing mid-stream and restoring into a
  fresh service (with producers replaying from zero) emits exactly what
  the uninterrupted service would have;
* **lossless backpressure** — a full bounded queue rewinds the replayable
  producer instead of dropping windows;
* **eviction round-trip** — an evicted tenant's session re-admits and the
  stream continues as if never interrupted;
* the ``TelemetryStore`` codec round-trip is bitwise (satellite of the
  same PR: flush/load goes through ``repro.core.codec`` records, no dtype
  coercion).
"""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.orchestrator import Clock
from repro.core.state import (
    SimSlice,
    TwinConfig,
    init_twin_state,
    make_telemetry,
    twin_step,
)
from repro.core.telemetry import TelemetryStore, TelemetryWindow
from repro.core.twin import fleet_step_masked
from repro.serve import (
    LaneMap,
    ResultCache,
    ServeConfig,
    SyntheticProducer,
    TwinService,
    WindowManager,
)
from repro.traces.schema import DatacenterConfig

DC = DatacenterConfig(num_hosts=4, cores_per_host=4)
TWIN = TwinConfig(bins_per_window=6, dc=DC)

# shared non-donating solo step: the per-tenant reference the service must
# reproduce bit for bit
_solo_step = jax.jit(twin_step)


def _producer(tenant, seed, num_windows=3, **kw):
    return SyntheticProducer(tenant, hosts=DC.num_hosts,
                             bins_per_window=TWIN.bins_per_window,
                             num_windows=num_windows, seed=seed, **kw)


def _all_events(producer):
    evs = producer.poll(float("inf"))
    assert producer.exhausted
    return evs


def _solo_outputs(events):
    """Reference stream: one tenant's windows through solo twin_step."""
    state = init_twin_state(TWIN)
    outs = {}
    for ev in sorted(events, key=lambda e: e.window):
        state, out = _solo_step(state, make_telemetry(ev.u_th, ev.power_w),
                                SimSlice(u_th=jnp.asarray(ev.sim_u)))
        outs[ev.window] = jax.tree.map(np.asarray, out)
    return outs, state


def _assert_tree_equal(a, b, ctx=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), ctx
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y),
                              equal_nan=True), ctx


def test_64_tenants_interleaved_bitwise_and_single_compile():
    tenants = [f"t{i:02d}" for i in range(64)]
    streams = {t: _all_events(_producer(t, seed=i % 8))
               for i, t in enumerate(tenants)}

    jax.clear_caches()
    svc = TwinService(ServeConfig(twin=TWIN, lanes=64, queue_capacity=1024))
    for t in tenants:
        svc.admit(t)

    # arbitrary arrival: every tenant-window shuffled together, submitted
    # in chunks with serving in between, so batches have varying fill and
    # repeated streams can hit the cache across rounds
    flat = [ev for evs in streams.values() for ev in evs]
    rng = np.random.default_rng(42)
    rng.shuffle(flat)
    for i in range(0, len(flat), 40):
        for ev in flat[i:i + 40]:
            assert svc.submit(ev)
        svc.run_until_idle(pump=False)
    results = svc.drain()

    compiles = svc.compile_count()
    if compiles is not None:
        # the acceptance gate: any tenant mix, any fill — ONE program
        assert compiles == 1, f"fleet program compiled {compiles}x"
    assert svc.stats.windows_served == 64 * 3
    assert svc.stats.windows_cached > 0, "identical streams never hit cache"
    assert svc.stats.batches >= 3

    by_tenant = {}
    for r in results:
        by_tenant.setdefault(r.tenant, []).append(r)
    refs = {s: _solo_outputs(streams[f"t{s:02d}"])[0] for s in range(8)}
    for i, t in enumerate(tenants):
        rs = by_tenant[t]
        assert [r.window for r in rs] == [0, 1, 2], "stream order broken"
        for r in rs:
            _assert_tree_equal(r.output, refs[i % 8][r.window],
                               ctx=f"{t} window {r.window}")


def test_kill_and_restore_equals_uninterrupted(tmp_path):
    tenants = {f"s{i}": i % 3 for i in range(6)}   # seed reuse -> cache hits
    streams = {t: _all_events(_producer(t, seed=s, num_windows=4))
               for t, s in tenants.items()}

    def submit_all(svc, events):
        rng = np.random.default_rng(7)
        events = list(events)
        rng.shuffle(events)
        for ev in events:
            assert svc.submit(ev)
        return svc.run_until_idle(pump=False)

    # uninterrupted reference service
    ref_svc = TwinService(ServeConfig(twin=TWIN, lanes=8, queue_capacity=64))
    for t in tenants:
        ref_svc.admit(t)
    ref = {(r.tenant, r.window): r
           for r in submit_all(ref_svc,
                               [ev for evs in streams.values() for ev in evs])}

    # interrupted: serve windows 0-1, checkpoint, kill
    svc_a = TwinService(ServeConfig(twin=TWIN, lanes=8, queue_capacity=64))
    for t in tenants:
        svc_a.admit(t)
    got_a = submit_all(svc_a, [ev for evs in streams.values() for ev in evs
                               if ev.window < 2])
    svc_a.checkpoint(tmp_path / "sessions")
    del svc_a

    # restore into a fresh service; producers replay from window 0 — the
    # stale-replay filter must drop everything already served
    svc_b = TwinService(ServeConfig(twin=TWIN, lanes=8, queue_capacity=64))
    assert sorted(svc_b.restore(tmp_path / "sessions")) == sorted(tenants)
    for t, s in tenants.items():
        svc_b.attach(_producer(t, seed=s, num_windows=4))
    got_b = svc_b.run_until_idle()

    assert svc_b.stats.stale_dropped == len(tenants) * 2
    combined = {(r.tenant, r.window): r for r in got_a + got_b}
    assert set(combined) == set(ref)
    for key, r in combined.items():
        _assert_tree_equal(r.output, ref[key].output, ctx=str(key))


def test_backpressure_rewinds_producer_losslessly():
    svc = TwinService(ServeConfig(twin=TWIN, lanes=2, queue_capacity=2))
    svc.admit("bp")
    svc.attach(_producer("bp", seed=5, num_windows=6))
    results = svc.run_until_idle()

    assert svc.stats.queue_rejects > 0, "queue never filled — weak test"
    assert [r.window for r in results] == list(range(6))
    ref, _ = _solo_outputs(_all_events(_producer("bp", seed=5,
                                                 num_windows=6)))
    for r in results:
        _assert_tree_equal(r.output, ref[r.window], ctx=f"window {r.window}")


def test_evict_readmit_continues_stream_exactly():
    events = _all_events(_producer("ev", seed=9, num_windows=4))
    ref, _ = _solo_outputs(events)

    svc = TwinService(ServeConfig(twin=TWIN, lanes=2))
    svc.admit("ev")
    for e in events[:2]:
        svc.submit(e)
    first = svc.run_until_idle(pump=False)

    session = svc.evict("ev")
    assert "ev" not in svc.tenants
    svc.admit("other")  # lane reuse while 'ev' is away
    svc.admit("ev", session.state, digest=session.digest,
              next_window=session.next_window)
    for e in events[2:]:
        svc.submit(e)
    rest = svc.run_until_idle(pump=False)

    got = {r.window: r for r in first + rest if r.tenant == "ev"}
    assert sorted(got) == [0, 1, 2, 3]
    for w, r in got.items():
        _assert_tree_equal(r.output, ref[w], ctx=f"window {w}")


def test_live_mode_injected_clock():
    class FakeTime:
        def __init__(self):
            self.t = 0.0
            self.lock = threading.Lock()

        def now(self):
            with self.lock:
                return self.t

        def sleep(self, s):
            with self.lock:
                self.t += s

    ft = FakeTime()
    svc = TwinService(ServeConfig(twin=TWIN, lanes=2, poll_seconds=10.0),
                      clock=Clock(now=ft.now, sleep=ft.sleep))
    svc.admit("live")
    svc.attach(_producer("live", seed=3, num_windows=3, period_s=25.0,
                         jitter_s=5.0))
    svc.start()
    deadline = time.time() + 30.0
    while len(svc.results) < 3 and time.time() < deadline:
        time.sleep(0.01)
    svc.stop()

    results = svc.drain()
    assert [r.window for r in results] == [0, 1, 2]
    ref, _ = _solo_outputs(_all_events(_producer("live", seed=3,
                                                 num_windows=3)))
    for r in results:
        _assert_tree_equal(r.output, ref[r.window], ctx=f"window {r.window}")


def test_lane_map_and_window_manager_bookkeeping():
    lanes = LaneMap(2)
    assert lanes.admit("a") == 0 and lanes.admit("b") == 1
    with pytest.raises(ValueError):
        lanes.admit("c")                     # full
    with pytest.raises(ValueError):
        lanes.admit("a")                     # duplicate
    assert lanes.evict("a") == 0
    assert lanes.admit("c") == 0             # lowest free lane reused

    wm = WindowManager()
    ev = _all_events(_producer("a", seed=0, num_windows=3))
    assert not wm.add(ev[1], next_window=2)          # stale: dropped
    assert wm.add(ev[2], next_window=2)
    assert wm.pop_ready("a", 1) is None              # gap: not ready
    assert wm.pop_ready("a", 2).window == 2
    assert wm.empty


def test_result_cache_lru_and_counters():
    cache = ResultCache(capacity=2)
    cache.put(("k1",), b"1")
    cache.put(("k2",), b"2")
    assert cache.get(("k1",)) == b"1"     # refreshes k1
    cache.put(("k3",), b"3")              # evicts k2 (LRU)
    assert cache.get(("k2",)) is None
    assert cache.get(("k3",)) == b"3"
    assert cache.hits == 2 and cache.misses == 1
    assert cache.hit_rate == pytest.approx(2 / 3)


def test_telemetry_store_codec_roundtrip_is_bitwise(tmp_path):
    store = TelemetryStore(bins_per_window=4)
    rng = np.random.default_rng(0)
    for w in range(3):
        store.ingest(TelemetryWindow(
            window=w, t0_bin=w * 4,
            u_th=rng.random((4, 2)).astype(np.float32),
            power_w=rng.random(4).astype(np.float64) * 400.0,
            extras={"carbon_intensity": rng.random(4).astype(np.float32),
                    "price": rng.random(4).astype(np.float64)}))
    path = tmp_path / "telemetry.bin"
    store.flush(str(path))
    loaded = TelemetryStore.load(str(path))

    assert loaded.bins_per_window == 4
    assert sorted(loaded.windows()) == [0, 1, 2]
    for w in range(3):
        a, b = store.get(w), loaded.get(w)
        assert b.t0_bin == a.t0_bin
        # bitwise AND dtype-exact: the codec records carry dtype + shape,
        # unlike the old flush which forced f32/f64 on every column
        for x, y in [(a.u_th, b.u_th), (a.power_w, b.power_w),
                     *[(a.extras[k], b.extras[k]) for k in a.extras]]:
            assert x.dtype == y.dtype
            assert np.array_equal(x, y)

"""Pure-Python oracle for the vectorized DES and its masked read-out.

An easily-audited, loop-based re-implementation of what the jitted engine
computes — scheduling (FCFS + placement policies + bounded backfill),
deferrable-job time-shifting, the OpenDC power model, **enforced** power
caps (static and carbon-aware) with linear throttling, energy and gCO2.
Everything runs in plain Python floats (float64), so any agreement with the
float32 tensor engine is evidence, not tautology.

Used by ``test_policies.py`` (placement exactness) and ``test_oracle.py``
(cap/shift/readout cross-checks on randomized small cases).  Kept free of
jax imports on purpose: the oracle must not share code with the system
under test.
"""

import math

import numpy as np


# -- placement ----------------------------------------------------------------

def _rand_score(host: int, t: int, salt: int) -> int:
    """Python replica of desim._hash_scores (uint32 mix, masked to 23 bits)."""
    m = 0xFFFFFFFF
    x = ((host * 0x9E3779B1) ^ (t * 0x85EBCA77) ^ (salt * 0xC2B2AE3D)) & m
    x = ((x ^ (x >> 16)) * 0x7FEB352D) & m
    x = ((x ^ (x >> 15)) * 0x846CA68B) & m
    x = x ^ (x >> 16)
    return x & 0x7FFFFF


def _pick_host(free, need, policy, t, salt, online=None):
    """Argmax-of-score host choice; ties break to the lowest host index.

    ``online`` filters placement-eligible hosts (failure windows — both
    outages and drains accept no *new* placements); scores still key on the
    raw free-core counts, matching the engine's masked argmax.
    """
    fits = [h for h in range(len(free)) if free[h] >= need
            and (online is None or online[h])]
    if not fits:
        return None
    if policy == "first_fit":
        return fits[0]
    if policy == "best_fit":
        return min(fits, key=lambda h: (free[h], h))
    if policy == "worst_fit":
        return max(fits, key=lambda h: (free[h], -h))
    if policy == "random_fit":
        return max(fits, key=lambda h: (_rand_score(h, t, salt), -h))
    raise ValueError(policy)


def reference_schedule(submit, dur, cores, valid, *, num_hosts,
                       cores_per_host, t_bins, policy="worst_fit",
                       backfill_depth=0, max_starts_per_bin=64,
                       fail_start=None, fail_end=None, fail_kill=None):
    """Event-semantics FCFS scheduler the vectorized kernel must reproduce.

    Per bin: release finished jobs' cores, then repeatedly (a) place the
    queue head if it is submitted and fits anywhere, else (b) let the first
    of its next `backfill_depth` submitted successors that fits jump ahead,
    else (c) block the bin.  Host choice per `_pick_host`.

    Failure schedules (``fail_start``/``fail_end``/``fail_kill``, per-host
    lists): during ``[fail_start[h], fail_end[h])`` host ``h`` accepts no
    new placements; when ``fail_kill[h]``, a job placed before the window
    that would run into it dies at ``fail_start[h]`` and its cores return
    with the host at ``fail_end[h]``.
    """
    j = len(submit)
    free = [cores_per_host] * num_hosts
    release = [[0] * num_hosts for _ in range(t_bins + 1)]
    job_start = [-1] * j
    job_host = [-1] * j
    next_job = 0

    for t in range(t_bins):
        for h in range(num_hosts):
            free[h] += release[t][h]
        online = (None if fail_start is None else
                  [not (fail_start[h] <= t < fail_end[h])
                   for h in range(num_hosts)])
        n = 0
        while n < max_starts_per_bin:
            while next_job < j and job_start[next_job] >= 0:
                next_job += 1
            if (next_job >= j or submit[next_job] > t
                    or not valid[next_job]):
                break
            jid = next_job
            if _pick_host(free, cores[jid], policy, t, n, online) is None:
                jid = None
                for d in range(1, backfill_depth + 1):
                    c = next_job + d
                    if c >= j:
                        break
                    if (job_start[c] >= 0 or not valid[c]
                            or submit[c] > t):
                        continue
                    if any(free[h] >= cores[c]
                           and (online is None or online[h])
                           for h in range(num_hosts)):
                        jid = c
                        break
                if jid is None:
                    break
            host = _pick_host(free, cores[jid], policy, t, n, online)
            free[host] -= cores[jid]
            job_start[jid] = t
            job_host[jid] = host
            end = min(t + max(dur[jid], 1), t_bins)
            if fail_start is not None and fail_kill[host] \
                    and t < fail_start[host] < t + max(dur[jid], 1):
                # killed at the outage; cores come back with the host
                end = min(fail_end[host], t_bins)
            release[end][host] += cores[jid]
            n += 1
    return job_start, job_host


# -- workload perturbation ----------------------------------------------------

def apply_shift(submit, dur, util, cores, valid, deferrable, shift_bins):
    """Deferrable-job time-shifting, mirroring scenarios._perturb.

    Moves deferrable valid jobs by ``shift_bins`` (clipped at bin 0), then
    stably re-sorts the whole job axis by the new submission times — the
    DES's FCFS queue order *is* the array order.  ``deferrable=None`` means
    all jobs move.  Returns the re-ordered (submit, dur, util, cores, valid,
    deferrable) lists.
    """
    j = len(submit)
    movable = [valid[i] and (deferrable is None or deferrable[i])
               for i in range(j)]
    shifted = [max(submit[i] + shift_bins, 0) if movable[i] else submit[i]
               for i in range(j)]
    order = sorted(range(j), key=lambda i: (shifted[i], i))   # stable
    pick = lambda xs: [xs[i] for i in order]                  # noqa: E731
    return (pick(shifted), pick(dur), pick(util), pick(cores), pick(valid),
            None if deferrable is None else pick(deferrable))


# -- utilization field --------------------------------------------------------

def reference_u_th(job_start, submit, dur, cores, util_levels, job_host, *,
                   num_hosts, cores_per_host, t_bins,
                   fail_start=None, fail_kill=None):
    """``[t_bins][num_hosts]`` per-host utilization from a schedule.

    Replicates the engine's post-scan read-out: a job runs in bins
    ``[start, start + max(dur, 1))``, contributing phase
    ``clip((t - start) * U // max(dur, 1), 0, U - 1)`` of its piecewise
    profile times its core count, normalized by the host's core capacity.
    Killed jobs (pre-outage placements on a ``fail_kill`` host that run
    into its window) stop at ``fail_start`` — phase indexing keeps the
    *original* duration, exactly like the engine's ``end_eff`` clamp.
    """
    j = len(job_start)
    u = [[0.0] * num_hosts for _ in range(t_bins)]
    phases = len(util_levels[0]) if j else 1
    for i in range(j):
        if job_start[i] < 0:
            continue
        d = max(dur[i], 1)
        end = job_start[i] + d
        if (fail_start is not None and fail_kill[job_host[i]]
                and job_start[i] < fail_start[job_host[i]] < end):
            end = fail_start[job_host[i]]
        for t in range(job_start[i], min(end, t_bins)):
            ph = min(max((t - job_start[i]) * phases // d, 0), phases - 1)
            u[t][job_host[i]] += util_levels[i][ph] * cores[i] / cores_per_host
    return u


# -- power / cap / carbon read-out -------------------------------------------

def opendc_power(u, p_idle, p_max, r):
    """OpenDC analytical model, scalar: P = P_idle + span * (2u - u^r)."""
    u = min(max(u, 0.0), 1.0)
    return p_idle + (p_max - p_idle) * (2.0 * u - u ** r)


def effective_cap(power_cap_w, carbon_cap_base_w, carbon_cap_slope,
                  intensity_t):
    """Per-bin enforced cap: min(static, max(base + slope * I_t, 0)).

    ``None`` caps read as +inf (uncapped); the carbon-aware term only
    applies when an intensity value is supplied (matching the engine, which
    rejects carbon caps without a trace).
    """
    cap = power_cap_w if power_cap_w is not None else math.inf
    if intensity_t is not None:
        base = (carbon_cap_base_w if carbon_cap_base_w is not None
                else math.inf)
        cap = min(cap, max(base + carbon_cap_slope * intensity_t, 0.0))
    return cap


def reference_pue(util_raw, ambient_t, pue):
    """Scalar replica of ``repro.traces.thermal.dynamic_pue``.

    ``pue`` is a ``(base, amb_coeff, amb_ref, load_coeff)`` tuple; the
    ambient term only applies when a temperature is supplied.
    """
    base, amb_coeff, amb_ref, load_coeff = pue
    load = min(max(util_raw, 0.0), 1.0)
    p = base + load_coeff * (1.0 - load)
    if ambient_t is not None:
        p += amb_coeff * max(ambient_t - amb_ref, 0.0)
    return p


def reference_readout(u_th, *, p_idle, p_max, r, power_cap_w=None,
                      carbon_cap_base_w=None, carbon_cap_slope=0.0,
                      intensity=None, sample_seconds=300.0,
                      online=None, pue=None, ambient=None, price=None):
    """Masked-readout oracle: demand, enforced cap, throttle, energy, gCO2.

    Mirrors ``scenarios._predict_masked`` in plain float64:

    * ``demand_t``   — sum of the per-host OpenDC power over active hosts;
    * ``cap_t``      — the effective (static ∧ carbon-aware) per-bin cap;
    * ``throttled_t``— demand ran into the cap (the engine's cap-exceeded
      flag);
    * ``power_t``    — delivered = min(demand, cap);
    * ``util_t``     — mean active-host utilization, linearly throttled by
      the above-idle fraction the cap removed when throttled;
    * ``energy_t`` / ``gco2_t`` — delivered energy (kWh) and carbon (g).

    New axes (all default off, reproducing the old read-out exactly):

    * ``online``  — ``[T][H]`` bool; offline (outage) hosts draw no power,
      not even idle, and leave the utilization denominator;
    * ``pue`` / ``ambient`` — ``(base, amb_coeff, amb_ref, load_coeff)``
      tuple + °C list: demand, idle floor and hence cap enforcement move
      to facility watts (PUE from the *unthrottled* utilization);
    * ``price``   — ``[T]`` $/kWh: adds ``cost_t = energy_t * price_t``.
    """
    t_bins = len(u_th)
    num_hosts = len(u_th[0]) if t_bins else 0
    out = {k: [] for k in ("demand", "cap", "power", "throttled", "util",
                           "energy_kwh", "gco2", "pue", "cost")}
    for t in range(t_bins):
        i_t = intensity[t] if intensity is not None else None
        on = online[t] if online is not None else [True] * num_hosts
        n_on = sum(1 for h in range(num_hosts) if on[h])
        demand = sum(opendc_power(u_th[t][h], p_idle, p_max, r)
                     for h in range(num_hosts) if on[h])
        idle_floor = p_idle * n_on
        util_raw = (sum(u_th[t][h] for h in range(num_hosts) if on[h])
                    / max(n_on, 1))
        pue_t = math.nan
        if pue is not None:
            pue_t = reference_pue(
                util_raw, ambient[t] if ambient is not None else None, pue)
            demand *= pue_t
            idle_floor *= pue_t
        cap = effective_cap(power_cap_w, carbon_cap_base_w,
                            carbon_cap_slope, i_t)
        throttled = demand > cap
        power = min(demand, cap)
        throttle = min(max((cap - idle_floor)
                           / max(demand - idle_floor, 1e-9), 0.0), 1.0)
        util = util_raw * throttle if throttled else util_raw
        energy = power * sample_seconds / 3600.0 / 1000.0
        out["demand"].append(demand)
        out["cap"].append(cap)
        out["power"].append(power)
        out["throttled"].append(throttled)
        out["util"].append(util)
        out["energy_kwh"].append(energy)
        out["gco2"].append(energy * i_t if i_t is not None else math.nan)
        out["pue"].append(pue_t)
        out["cost"].append(energy * price[t] if price is not None
                           else math.nan)
    return out


def reference_mape(real, sim, eps=1e-9):
    """Scalar replica of ``repro.core.power.mape``: denominator
    ``|real| + eps``, zero-real bins excluded, all-zero → NaN, in %."""
    total, n = 0.0, 0
    for rv, sv in zip(real, sim):
        if abs(rv) > eps:
            total += abs((rv - sv) / (abs(rv) + eps))
            n += 1
    return total / n * 100.0 if n else math.nan


def reference_calibrate_per_host(u_th, real_power, candidates, fleet_params,
                                 fleet_mape):
    """Loop-based oracle for ``calibrate._per_host_refit`` (float64).

    ``u_th`` is ``[T][H]``, ``real_power`` ``[T]``, ``candidates`` a list of
    ``(p_idle, p_max, r)`` scalar tuples (the same grid the engine scores),
    ``fleet_params`` a ``(p_idle, p_max, r)`` tuple of scalars or ``[H]``
    lists.  Measured total power is attributed to hosts by their predicted
    share under the fleet fit; each host argmins the grid against its share
    column (first finite minimum wins, like the engine's argmin), hosts with
    no finite score keep the fleet row, and the returned MAPE is the
    *total-power* MAPE of the combined per-host prediction (fleet MAPE when
    that is undefined).  Returns ``((p_idle_row, p_max_row, r_row), mape)``.
    """
    t_bins, h = len(u_th), len(u_th[0])

    def fleet_row(v):
        return list(v) if isinstance(v, (list, tuple)) else [v] * h

    fpi, fpm, fr = (fleet_row(fleet_params[0]), fleet_row(fleet_params[1]),
                    fleet_row(fleet_params[2]))
    pred = [[opendc_power(u_th[t][j], fpi[j], fpm[j], fr[j])
             for j in range(h)] for t in range(t_bins)]
    rows = ([], [], [])
    for j in range(h):
        target = [real_power[t] * pred[t][j] / max(sum(pred[t]), 1e-9)
                  for t in range(t_bins)]
        best, best_m = None, math.inf
        for c in candidates:
            m = reference_mape(
                target, [opendc_power(u_th[t][j], *c) for t in range(t_bins)])
            if not math.isnan(m) and m < best_m:
                best, best_m = c, m
        chosen = best if best is not None else (fpi[j], fpm[j], fr[j])
        for row, v in zip(rows, chosen):
            row.append(v)
    combined = [sum(opendc_power(u_th[t][j], rows[0][j], rows[1][j],
                                 rows[2][j]) for j in range(h))
                for t in range(t_bins)]
    m = reference_mape(real_power, combined)
    return rows, (fleet_mape if math.isnan(m) else m)


def reference_scenario(workload, dc, scenario, *, t_bins, p_idle, p_max, r,
                       intensity=None, ambient=None, price=None,
                       max_starts_per_bin=64):
    """Full single-scenario oracle: perturb -> schedule -> readout.

    ``workload`` is a dict of plain lists (``submit``, ``dur``, ``cores``,
    ``util`` — ``[J][U]`` —, ``valid``, optional ``deferrable``);
    ``scenario`` a :class:`repro.core.scenarios.Scenario`; power params are
    the *resolved* scalars (scenario override already applied by the
    caller, or the base).  Returns the readout dict plus the schedule and
    post-perturbation submit times (``job_start``, ``job_host``,
    ``submit``, ``waits`` over started valid jobs).

    The scenario's failure windows, PUE fields and the ``ambient``/``price``
    traces are threaded through schedule, utilization and read-out exactly
    like the engine's traced lanes.
    """
    submit = list(workload["submit"])
    dur = list(workload["dur"])
    util = [list(row) for row in workload["util"]]
    cores = list(workload["cores"])
    valid = list(workload["valid"])
    defer = (None if workload.get("deferrable") is None
             else list(workload["deferrable"]))

    if scenario.arrival_scale != 1.0:
        # float32 on purpose: mirrors scenarios._perturb's rounding exactly
        submit = [int(np.floor(np.float32(s) / np.float32(
            scenario.arrival_scale))) for s in submit]
    if scenario.duration_scale != 1.0:
        dur = [max(int(np.ceil(np.float32(d) * np.float32(
            scenario.duration_scale))), 1) for d in dur]
    if scenario.util_scale != 1.0:
        util = [[min(max(u * scenario.util_scale, 0.0), 1.0) for u in row]
                for row in util]
    if scenario.shift_bins != 0:
        submit, dur, util, cores, valid, defer = apply_shift(
            submit, dur, util, cores, valid, defer, int(scenario.shift_bins))

    num_hosts = (scenario.num_hosts if scenario.num_hosts is not None
                 else dc.num_hosts)
    cores_per_host = (scenario.cores_per_host
                      if scenario.cores_per_host is not None
                      else dc.cores_per_host)
    policy = scenario.policy if scenario.policy is not None else "worst_fit"

    fs = fe = fk = None
    if scenario.failures:
        fs = [t_bins + 10 ** 6] * num_hosts  # sentinel: never fails
        fe = [0] * num_hosts
        fk = [False] * num_hosts
        for f in scenario.failures:
            fs[f.host] = int(f.start_bin)
            fe[f.host] = int(f.end_bin)
            fk[f.host] = f.kind == "outage"

    job_start, job_host = reference_schedule(
        submit, dur, cores, valid, num_hosts=num_hosts,
        cores_per_host=cores_per_host, t_bins=t_bins, policy=policy,
        backfill_depth=int(scenario.backfill_depth),
        max_starts_per_bin=max_starts_per_bin,
        fail_start=fs, fail_end=fe, fail_kill=fk)
    u_th = reference_u_th(
        job_start, submit, dur, cores, util, job_host,
        num_hosts=num_hosts, cores_per_host=cores_per_host, t_bins=t_bins,
        fail_start=fs, fail_kill=fk)
    online = None
    if fs is not None:
        # power-side availability: only *outage* hosts go dark (drained
        # hosts keep drawing power), matching scenarios._scenario_lanes
        online = [[not (fk[h] and fs[h] <= t < fe[h])
                   for h in range(num_hosts)] for t in range(t_bins)]
    pue = None
    if scenario.pue_base is not None:
        pue = (float(scenario.pue_base), float(scenario.pue_amb_coeff),
               float(scenario.pue_amb_ref), float(scenario.pue_load_coeff))
    out = reference_readout(
        u_th, p_idle=p_idle, p_max=p_max, r=r,
        power_cap_w=scenario.power_cap_w,
        carbon_cap_base_w=scenario.carbon_cap_base_w,
        carbon_cap_slope=scenario.carbon_cap_slope, intensity=intensity,
        online=online, pue=pue, ambient=ambient, price=price)
    out.update(
        job_start=job_start, job_host=job_host, submit=submit, u_th=u_th,
        waits=[job_start[i] - submit[i] for i in range(len(submit))
               if valid[i] and job_start[i] >= 0])
    return out

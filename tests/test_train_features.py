"""Training-step features: gradient accumulation equivalence; optimizer."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch.steps import make_train_step, param_specs_for
from repro.models.common import init_params
from repro.optim.adamw import (
    AdamWConfig,
    apply_updates,
    init_opt_state,
    schedule,
)


def _tiny():
    return ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                       vocab=64, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=64, remat="none").validate()


@pytest.mark.slow
def test_grad_accum_matches_full_batch():
    cfg = _tiny()
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10,
                          weight_decay=0.0)
    params = init_params(param_specs_for(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    opt = init_opt_state(params, opt_cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, 64),
    }
    p1, _, m1 = jax.jit(make_train_step(cfg, opt_cfg))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt_cfg, grad_accum=2))(
        params, opt, batch)
    # same global batch -> same loss and same updated params (within fp tol)
    assert float(m1["loss"]) == np.float32(m2["loss"]).item() or \
        abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_adamw_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(schedule(jnp.asarray(s), cfg)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6           # end of warmup
    assert lrs[-1] <= 0.11                    # decayed to min_lr_frac
    assert all(a >= b - 1e-6 for a, b in zip(lrs[1:], lrs[2:]))


def test_adamw_clips_gradients():
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    st = init_opt_state(p, cfg)
    _, _, m = apply_updates(p, g, st, cfg)
    assert float(m["grad_norm"]) == 200.0     # reported pre-clip

"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.calib_mape import calib_mape_grid_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.power_sim import power_sim_pallas

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("t,h,c", [
    (64, 16, 8), (100, 64, 33), (288, 277, 64), (512, 128, 200),
])
def test_calib_mape_sweep(t, h, c):
    u = jnp.asarray(RNG.uniform(0, 1, (t, h)).astype(np.float32))
    real = jnp.asarray(RNG.uniform(1e3, 5e3, (t,)).astype(np.float32))
    pi = jnp.asarray(RNG.uniform(50, 90, (c,)).astype(np.float32))
    pm = jnp.asarray(RNG.uniform(250, 450, (c,)).astype(np.float32))
    r = jnp.asarray(RNG.uniform(1, 6, (c,)).astype(np.float32))
    want = ref.calib_mape_grid_ref(u, real, pi, pm, r)
    got = calib_mape_grid_pallas(u, real, pi, pm, r, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("t,h", [(96, 17), (300, 277), (1024, 64)])
def test_power_sim_sweep(t, h):
    u = jnp.asarray(RNG.uniform(0, 1, (t, h)).astype(np.float32))
    kw = dict(p_idle=70.0, p_max=350.0, r=2.3, peak_tflops=120.0,
              dt_seconds=300.0)
    want = ref.power_sim_ref(u, 70.0, 350.0, 2.3, peak_tflops=120.0,
                             dt_seconds=300.0)
    got = power_sim_pallas(u, interpret=True, **kw)
    for w, g in zip(want, got):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-2)


@pytest.mark.parametrize("b,hq,hkv,sq,skv,d,causal,dtype", [
    (1, 4, 4, 128, 128, 64, True, jnp.float32),      # MHA causal
    (2, 8, 2, 100, 100, 32, True, jnp.float32),      # GQA ragged seq
    (2, 4, 1, 64, 64, 64, False, jnp.float32),       # MQA bidirectional
    (1, 6, 2, 1, 96, 64, True, jnp.float32),         # decode shape
    # tracecheck: disable=TC005 — attention dtype sweep, not twin math
    (2, 4, 2, 128, 128, 64, True, jnp.bfloat16),     # bf16
    (1, 4, 4, 257, 257, 16, True, jnp.float32),      # non-tile-aligned
])
def test_flash_attention_sweep(b, hq, hkv, sq, skv, d, causal, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, hq, sq, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, hkv, skv, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, hkv, skv, d)), dtype)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, interpret=True,
                                 q_blk=64, k_blk=64)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5  # tracecheck: disable=TC005 — dtype sweep tolerance
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol * 10)


def test_ops_backend_dispatch():
    u = jnp.asarray(RNG.uniform(0, 1, (64, 32)).astype(np.float32))
    real = jnp.asarray(RNG.uniform(1e3, 2e3, (64,)).astype(np.float32))
    c = jnp.asarray([2.0, 3.0], jnp.float32)
    pi = jnp.full((2,), 70.0)
    pm = jnp.full((2,), 350.0)
    a = ops.calib_mape_grid(u, real, pi, pm, c, backend="xla")
    b = ops.calib_mape_grid(u, real, pi, pm, c, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
    assert ops.resolve_backend("auto") in ("xla", "pallas")


@pytest.mark.parametrize("bc,q,h,p,g,n", [
    (2, 16, 2, 8, 1, 16), (3, 32, 4, 16, 2, 24), (1, 64, 8, 32, 4, 64),
])
def test_ssd_chunk_sweep(bc, q, h, p, g, n):
    x = jnp.asarray(RNG.normal(0, 1, (bc, q, h, p)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (bc, q, h)).astype(np.float32))
    al = jnp.asarray(RNG.normal(0, 0.3, (h,)).astype(np.float32))
    b = jnp.asarray(RNG.normal(0, 1, (bc, q, g, n)).astype(np.float32))
    c = jnp.asarray(RNG.normal(0, 1, (bc, q, g, n)).astype(np.float32))
    d = jnp.asarray(RNG.normal(0, 1, (h,)).astype(np.float32))
    y1, s1 = ref.ssd_chunk_ref(x, dt, al, b, c, d)
    y2, s2 = ops.ssd_chunk(x, dt, al, b, c, d, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_kernel_plus_interchunk_matches_full_ssd():
    """Kernel intra-chunk + JAX inter-chunk recurrence == models.mamba2
    full chunked SSD (the kernel is a drop-in for the quadratic part)."""
    from repro.models.mamba2 import ssd_chunked

    bsz, s, h, p, g, n, q = 2, 64, 4, 8, 2, 16, 16
    nc = s // q
    xh = jnp.asarray(RNG.normal(0, 1, (bsz, s, h, p)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (bsz, s, h)).astype(np.float32))
    al = jnp.asarray(RNG.normal(0, 0.3, (h,)).astype(np.float32))
    bb = jnp.asarray(RNG.normal(0, 1, (bsz, s, g, n)).astype(np.float32))
    cc = jnp.asarray(RNG.normal(0, 1, (bsz, s, g, n)).astype(np.float32))
    dd = jnp.asarray(RNG.normal(0, 1, (h,)).astype(np.float32))
    want = ssd_chunked(xh, dt, al, bb, cc, dd, q)

    # kernel path: flatten (batch, chunk), run intra-chunk, then recur
    def chunked(t, trailing):
        return t.reshape((bsz, nc, q) + trailing)

    xk = chunked(xh, (h, p)).reshape(bsz * nc, q, h, p)
    dtk = chunked(dt, (h,)).reshape(bsz * nc, q, h)
    bk = chunked(bb, (g, n)).reshape(bsz * nc, q, g, n)
    ck = chunked(cc, (g, n)).reshape(bsz * nc, q, g, n)
    y_intra, states = ops.ssd_chunk(xk, dtk, al, bk, ck, dd,
                                    backend="pallas_interpret")
    y_intra = y_intra.reshape(bsz, nc, q, h, p)
    states = states.reshape(bsz, nc, h, p, n)

    # inter-chunk recurrence + readout (same math as models/mamba2.py)
    a = -jnp.exp(al)
    da = dt * a[None, None]
    csum = jnp.cumsum(da.reshape(bsz, nc, q, h), axis=2)
    total = csum[:, :, -1]
    rep = h // g
    cgrp = jnp.repeat(cc.reshape(bsz, nc, q, g, n), rep, axis=3)

    def scan_fn(state, inp):
        tot_c, st_c = inp
        out = state
        state = state * jnp.exp(tot_c)[:, :, None, None] + st_c
        return state, out

    import jax as _jax
    _, prev = _jax.lax.scan(
        scan_fn, jnp.zeros((bsz, h, p, n), jnp.float32),
        (total.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev = prev.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", cgrp, jnp.exp(csum), prev)
    got = (y_intra + y_inter).reshape(bsz, s, h, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

"""Model-layer correctness: chunked attention vs naive softmax; decode paths
consistent with full-sequence forward (GQA cache, MLA absorbed, Mamba2 SSD).

Whole module is tier-2 (``slow``): the decode-vs-forward equivalences scan
whole sequences through jitted step functions (~70 s on CPU) — run via
``pytest -m slow``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.configs.base import ModelConfig
from repro.kernels.ref import flash_attention_ref
from repro.models import mamba2 as m2
from repro.models import mla
from repro.models.attention import chunked_attention, decode_attention
from repro.models.common import init_params

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("b,hq,hkv,s,d,chunk", [
    (2, 4, 2, 96, 32, 32), (1, 8, 8, 64, 16, 64), (2, 6, 1, 128, 64, 32),
])
def test_chunked_attention_matches_naive(b, hq, hkv, s, d, chunk):
    q = jnp.asarray(RNG.normal(0, 1, (b, s, hq, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)).astype(np.float32))
    got = chunked_attention(q, k, v, causal=True, kv_chunk=chunk)
    want = flash_attention_ref(                      # [B,H,S,D] layout
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_last_position():
    b, hq, hkv, s, d = 2, 4, 2, 48, 32
    q = jnp.asarray(RNG.normal(0, 1, (b, s, hq, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(0, 1, (b, s, hkv, d)).astype(np.float32))
    full = chunked_attention(q, k, v, causal=True, kv_chunk=16)
    dec = decode_attention(q[:, -1:], k, v)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def _mla_cfg():
    return ModelConfig(
        name="t", family="dense", num_layers=1, d_model=64, vocab=64,
        n_heads=4, n_kv_heads=4, head_dim=24, attn_kind="mla",
        q_lora=32, kv_lora=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    ).validate()


def test_mla_absorbed_decode_matches_prefill():
    """Absorbed latent decode must equal the expanded prefill path at the
    last position, given identical cache contents."""
    cfg = _mla_cfg()
    specs = mla.mla_specs(cfg, 1)
    p = init_params(specs, jax.random.PRNGKey(0), jnp.float32)
    p = jax.tree.map(lambda t: t[0], p)             # drop layer dim
    b, s = 2, 24
    x = jnp.asarray(RNG.normal(0, 0.3, (b, s, cfg.d_model)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    want = mla.mla_prefill(p, cfg, x, pos, kv_chunk=8)[:, -1]

    # build the latent cache from the full prefix, decode the last token
    c_kv, k_rope = mla._latent_kv(p, cfg, x[:, :-1], pos[:, :-1])
    cache = {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, 1), (0, 0))),
        "k_rope": jnp.pad(k_rope, ((0, 0), (0, 1), (0, 0))),
    }
    got, _ = mla.mla_decode(p, cfg, x[:, -1:], cache, pos[:, -1:],
                            cache_len=jnp.full((b,), s - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def _ssm_cfg():
    return ModelConfig(
        name="t", family="ssm", attn_kind="none", num_layers=1, d_model=32,
        vocab=64, d_state=16, expand=2, ssm_headdim=16, ssd_chunk=8,
    ).validate()


def test_mamba2_decode_matches_forward():
    """Stepping the recurrence token-by-token must reproduce the chunked
    full-sequence forward."""
    cfg = _ssm_cfg()
    specs = m2.mamba2_specs(cfg, 1)
    p = init_params(specs, jax.random.PRNGKey(1), jnp.float32)
    p = jax.tree.map(lambda t: t[0], p)
    b, s = 2, 16
    x = jnp.asarray(RNG.normal(0, 0.5, (b, s, cfg.d_model)).astype(np.float32))
    full = m2.mamba2_forward(p, cfg, x)

    state = m2.mamba2_init_state(cfg, b, jnp.float32)
    outs = []
    for t in range(s):
        y, state = m2.mamba2_decode(p, cfg, x[:, t:t + 1], state)
        outs.append(y[:, 0])
    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    b, s, h, pd, n, g = 1, 32, 2, 8, 8, 1
    xh = jnp.asarray(RNG.normal(0, 1, (b, s, h, pd)).astype(np.float32))
    dt = jnp.asarray(RNG.uniform(0.1, 0.9, (b, s, h)).astype(np.float32))
    al = jnp.asarray(RNG.normal(0, 0.3, (h,)).astype(np.float32))
    bb = jnp.asarray(RNG.normal(0, 1, (b, s, g, n)).astype(np.float32))
    cc = jnp.asarray(RNG.normal(0, 1, (b, s, g, n)).astype(np.float32))
    dd = jnp.asarray(RNG.normal(0, 1, (h,)).astype(np.float32))
    y8 = m2.ssd_chunked(xh, dt, al, bb, cc, dd, 8)
    y16 = m2.ssd_chunked(xh, dt, al, bb, cc, dd, 16)
    y32 = m2.ssd_chunked(xh, dt, al, bb, cc, dd, 32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32),
                               rtol=1e-4, atol=1e-4)


def test_decode_step_matches_forward_dense():
    """Greedy decode against a cache built token-by-token must reproduce the
    full-sequence forward logits at every position (embed -> blocks ->
    unembed, the whole serve path)."""
    from repro.configs.base import ModelConfig
    from repro.models import lm

    cfg = ModelConfig(name="t", family="dense", num_layers=3, d_model=48,
                      vocab=96, n_heads=4, n_kv_heads=2, head_dim=12,
                      d_ff=96, remat="none").validate()
    p = init_params(lm.model_specs(cfg), jax.random.PRNGKey(5), jnp.float32)
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(6), (b, s), 0, 96)
    full_logits = lm.forward(cfg, p, {"tokens": toks})      # [B,S,V]

    state = jax.tree.map(
        lambda t: jnp.zeros_like(t),
        init_params(lm.decode_state_specs(cfg, b, s), jax.random.PRNGKey(7),
                    jnp.float32))
    outs = []
    for i in range(s):
        batch = {"token": toks[:, i:i + 1],
                 "cache_len": jnp.full((b,), i, jnp.int32)}
        logits, state = lm.decode_step(cfg, p, state, batch)
        outs.append(logits)
    stepped = jnp.stack(outs, axis=1)                        # [B,S,V]
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_decode_step_matches_forward_mla():
    from repro.configs.base import ModelConfig
    from repro.models import lm

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=48,
                      vocab=64, n_heads=4, n_kv_heads=4, head_dim=24,
                      attn_kind="mla", q_lora=24, kv_lora=24, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16, d_ff=96,
                      remat="none").validate()
    p = init_params(lm.model_specs(cfg), jax.random.PRNGKey(8), jnp.float32)
    b, s = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(9), (b, s), 0, 64)
    full_logits = lm.forward(cfg, p, {"tokens": toks})

    state = jax.tree.map(
        lambda t: jnp.zeros_like(t),
        init_params(lm.decode_state_specs(cfg, b, s), jax.random.PRNGKey(1),
                    jnp.float32))
    outs = []
    for i in range(s):
        batch = {"token": toks[:, i:i + 1],
                 "cache_len": jnp.full((b,), i, jnp.int32)}
        logits, state = lm.decode_step(cfg, p, state, batch)
        outs.append(logits)
    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(full_logits),
                               rtol=3e-3, atol=3e-3)


def test_encdec_decode_matches_teacher_forced():
    """Enc-dec serve path: stepping the decoder against self+cross caches
    must reproduce the teacher-forced decoder hidden states' logits."""
    from repro.configs.base import ModelConfig
    from repro.models import encdec as ed
    from repro.models.common import dense

    cfg = ModelConfig(name="t", family="encdec", num_layers=0, d_model=48,
                      vocab=80, n_heads=4, n_kv_heads=2, head_dim=12,
                      d_ff=96, enc_layers=2, dec_layers=2, num_frames=8,
                      remat="none").validate()
    p = init_params(ed.encdec_specs(cfg), jax.random.PRNGKey(3), jnp.float32)
    b, s, f = 2, 10, 8
    frames = jnp.asarray(RNG.normal(0, 0.3, (b, f, 48)).astype(np.float32))
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, s), 0, 80)

    enc_out = ed.encode(cfg, p, frames)
    x = ed.decode_train(cfg, p, toks, enc_out)
    want = dense(x, p["unembed"])                      # [B,S,V]

    # build decode state: zero self cache + precomputed cross K/V
    state = jax.tree.map(
        lambda t: jnp.zeros_like(t),
        init_params(ed.encdec_state_specs(cfg, b, s), jax.random.PRNGKey(5),
                    jnp.float32))
    cross_k = jnp.stack([dense(enc_out, p["decoder"]["x_wk"][i])
                         for i in range(cfg.dec_layers)])
    cross_v = jnp.stack([dense(enc_out, p["decoder"]["x_wv"][i])
                         for i in range(cfg.dec_layers)])
    state["cross"] = {"k": cross_k, "v": cross_v}

    outs = []
    for i in range(s):
        batch = {"token": toks[:, i:i + 1],
                 "cache_len": jnp.full((b,), i, jnp.int32)}
        logits, state = ed.encdec_decode_step(cfg, p, state, batch)
        outs.append(logits)
    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stepped), np.asarray(want),
                               rtol=2e-3, atol=2e-3)

"""Live roofline of the masked-DES hot path (scan vs readout).

:mod:`benchmarks.roofline` tabulates *dry-run artifacts* for the kernel
experiments; this module instead interrogates the **running** XLA compiler
about the program every scenario lane actually pays for — the masked
placement scan (``lax.scan`` over bins driving the policy kernel and the
failure mask) and the post-scan readout that expands placements into the
dense ``[T, H]`` utilization grid.

Per phase it reports:

  * ``flops`` / ``bytes`` from ``jit(f).lower(x).compile().cost_analysis()``
    (XLA's own cost model — unavailable on some backends/versions, in which
    case the fields are ``None`` and only wall times are reported);
  * measured wall seconds, split with the same dead-code-elimination trick
    as :func:`benchmarks.nfr2_speed.des_hot_path` (a wrapper returning only
    ``job_start`` compiles the readout away);
  * derived achieved GFLOP/s, GB/s and arithmetic intensity (FLOP/byte) —
    the coordinates of each phase on a machine roofline.

Usage::

    PYTHONPATH=src python analysis/roofline.py
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.desim import simulate_utilization_masked
from repro.traces.schema import DatacenterConfig, host_mask
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def _time(fn, n: int = 5) -> float:
    fn()                                  # warmup / compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


def xla_cost(fn, *args) -> dict | None:
    """``{"flops": ..., "bytes": ...}`` from XLA's compiled cost model.

    Guarded: ``cost_analysis`` is backend/version dependent (it may raise,
    return ``None``, or return a one-element list) — any failure degrades to
    ``None`` rather than breaking the benchmark run.
    """
    try:
        analysis = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(analysis, (list, tuple)):
            analysis = analysis[0] if analysis else None
        if not analysis:
            return None
        return {
            "flops": float(analysis.get("flops", 0.0)),
            "bytes": float(analysis.get("bytes accessed", 0.0)),
        }
    except Exception:
        return None


def _phase(name: str, cost: dict | None, wall_s: float) -> dict:
    out = {"name": name, "wall_s": wall_s,
           "flops": None, "bytes": None,
           "gflop_per_s": None, "gb_per_s": None, "flop_per_byte": None}
    if cost is not None:
        out["flops"], out["bytes"] = cost["flops"], cost["bytes"]
        if wall_s > 0:
            out["gflop_per_s"] = cost["flops"] / wall_s / 1e9
            out["gb_per_s"] = cost["bytes"] / wall_s / 1e9
        if cost["bytes"] > 0:
            out["flop_per_byte"] = cost["flops"] / cost["bytes"]
    return out


def analyze_des_hot_path(days: float = 2.0,
                         dc: DatacenterConfig | None = None) -> dict:
    """Roofline coordinates for the scan and readout phases of the DES."""
    dc = dc or DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    mask = host_mask(dc.num_hosts, dc.num_hosts)
    cores = jnp.asarray(dc.cores_per_host, jnp.int32)
    kw = dict(max_hosts=dc.num_hosts, t_bins=t_bins)

    def scan_only(wl):
        return simulate_utilization_masked(wl, mask, cores, **kw).job_start

    def full(wl):
        return simulate_utilization_masked(wl, mask, cores, **kw).u_th

    scan_cost = xla_cost(scan_only, w)
    full_cost = xla_cost(full, w)
    readout_cost = None
    if scan_cost is not None and full_cost is not None:
        readout_cost = {
            "flops": max(full_cost["flops"] - scan_cost["flops"], 0.0),
            "bytes": max(full_cost["bytes"] - scan_cost["bytes"], 0.0),
        }

    scan_s = _time(lambda: jax.jit(scan_only)(w).block_until_ready())
    total_s = _time(lambda: jax.jit(full)(w).block_until_ready())
    readout_s = max(total_s - scan_s, 0.0)

    return {
        "days": days,
        "t_bins": t_bins,
        "num_hosts": dc.num_hosts,
        "jobs": int(w.duration_bins.shape[0]),
        "cost_analysis_available": full_cost is not None,
        "phases": [
            _phase("placement_scan", scan_cost, scan_s),
            _phase("post_scan_readout", readout_cost, readout_s),
            _phase("total", full_cost, total_s),
        ],
    }


def table(result: dict) -> str:
    hdr = (f"{'phase':20s} {'wall_s':>9s} {'GFLOP':>9s} {'GB':>9s} "
           f"{'GFLOP/s':>9s} {'GB/s':>8s} {'FLOP/B':>7s}")
    rows = [hdr, "-" * len(hdr)]

    def fmt(v, scale=1.0, spec=".3f"):
        return "--" if v is None else format(v / scale, spec)

    for p in result["phases"]:
        rows.append(
            f"{p['name']:20s} {p['wall_s']:9.4f} "
            f"{fmt(p['flops'], 1e9):>9s} {fmt(p['bytes'], 1e9):>9s} "
            f"{fmt(p['gflop_per_s']):>9s} {fmt(p['gb_per_s']):>8s} "
            f"{fmt(p['flop_per_byte'], 1.0, '.2f'):>7s}")
    return "\n".join(rows)


if __name__ == "__main__":
    import json

    res = analyze_des_hot_path()
    print(f"masked DES hot path: {res['t_bins']} bins x "
          f"{res['num_hosts']} hosts, {res['jobs']} jobs "
          f"(cost_analysis {'ok' if res['cost_analysis_available'] else 'n/a'})")
    print(table(res))
    print(json.dumps(res, indent=2))

"""E1 (paper §3.3, Fig. 4/5): reproduce the FootPrinter experiment with the
digital twin, then extend it with performance/efficiency metrics.

FootPrinter [30]: a linear host power model, hand-tuned ONCE on the first
day of telemetry (least squares on aggregate power vs. aggregate busy
cores), then run once over the full horizon — no recalibration.
OpenDT: the generic OpenDC analytical model, continuously predicting at the
5-minute industry granularity (uncalibrated in E1; E2 adds calibration).
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import mape, run_surf_experiment
from repro.core.twin import TraceGroundTruth
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like

DAYS = 7.0


def footprinter_day1_fit(u_th: np.ndarray, real: np.ndarray) -> np.ndarray:
    """Hand-tuned linear model: lstsq fit P ~ a + b * sum(u) on day 1."""
    su = u_th.sum(axis=1)
    d1 = slice(0, BINS_PER_DAY)
    A = np.stack([np.ones_like(su[d1]), su[d1]], axis=1)
    coef, *_ = np.linalg.lstsq(A, real[d1], rcond=None)
    return coef[0] + coef[1] * su


def run(days: float = DAYS, seed: int = 22) -> dict:
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days, seed=seed), dc)
    t_bins = int(days * BINS_PER_DAY)

    t0 = time.time()
    truth = TraceGroundTruth(w, dc, t_bins)
    real = truth.power
    u = truth.u_th.astype(np.float64)

    # FootPrinter baseline (run once)
    fp = footprinter_day1_fit(u, real)
    fp_mape = float(mape(jnp.asarray(real, dtype=jnp.float32),
                         jnp.asarray(fp.astype(np.float32))))

    # OpenDT continuous, uncalibrated (E1 does not calibrate)
    res = run_surf_experiment(w, dc, t_bins, calibrate=False)
    wall = time.time() - t0

    # Extension (Fig. 5B/C): performance + efficiency from the same run
    tflops = np.concatenate(
        [np.asarray(r.prediction.tflops) for r in res.records])
    energy = np.concatenate(
        [np.asarray(r.prediction.energy_kwh) for r in res.records])
    util = np.concatenate(
        [np.asarray(r.prediction.utilization) for r in res.records])
    # discretize per hour like the paper (12 x 5-min bins)
    hours = len(tflops) // 12
    tf_h = tflops[: hours * 12].reshape(hours, 12).mean(1)
    en_h = energy[: hours * 12].reshape(hours, 12).sum(1)
    eff_h = tf_h / np.maximum(en_h, 1e-9)

    return {
        "footprinter_mape": fp_mape,
        "opendt_mape": res.overall_mape,
        "improvement_pp": fp_mape - res.overall_mape,
        "paper_footprinter_mape": 7.86,
        "paper_opendt_mape": 5.13,
        "mean_utilization": float(util.mean()),
        "peak_tflops_hour": float(tf_h.max()),
        "mean_tflops": float(tf_h.mean()),
        "best_efficiency_tflops_per_kwh": float(eff_h.max()),
        "efficiency_at_peak_perf": float(eff_h[int(np.argmax(tf_h))]),
        "underutilization_insight": bool(util.mean() < 0.30),
        "wall_seconds": wall,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

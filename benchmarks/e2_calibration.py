"""E2 (paper §3.4, Fig. 6): live self-recalibration vs. static simulation.

Reports: overall MAPE with/without calibration, NFR1 compliance (<10 % MAPE
for >=90 % of time), under-estimation fractions, and per-window MAPE traces.
Also runs the beyond-paper joint (r, p_idle, p_max) calibration mode.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import OrchestratorConfig, run_surf_experiment
from repro.core.calibrate import CalibrationSpec
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like

DAYS = 7.0


def run(days: float = DAYS, seed: int = 22) -> dict:
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days, seed=seed), dc)
    t_bins = int(days * BINS_PER_DAY)

    t0 = time.time()
    unc = run_surf_experiment(w, dc, t_bins, calibrate=False)
    cal = run_surf_experiment(w, dc, t_bins, calibrate=True)
    joint = run_surf_experiment(
        w, dc, t_bins, calibrate=True,
        cfg=OrchestratorConfig(
            calibration=CalibrationSpec(mode="joint", refine_iters=1)))
    wall = time.time() - t0

    def slo(res):
        r = res.slo_reports[0]
        return {"compliance": r.compliance, "met": r.met}

    cal_wins = int(np.sum(cal.per_window_mape < unc.per_window_mape))
    return {
        "uncalibrated_mape": unc.overall_mape,
        "calibrated_mape": cal.overall_mape,
        "joint_calibrated_mape": joint.overall_mape,   # beyond-paper
        "improvement_pp": unc.overall_mape - cal.overall_mape,
        "paper_uncalibrated_mape": 5.13,
        "paper_calibrated_mape": 4.39,
        "paper_improvement_pp": 0.74,
        "nfr1_uncalibrated": slo(unc),
        "nfr1_calibrated": slo(cal),
        "paper_nfr1": {"uncalibrated": 0.86, "calibrated": 0.92},
        "under_estimation_uncal": unc.under_estimation_fraction,
        "under_estimation_cal": cal.under_estimation_fraction,
        "paper_under_estimation": {"uncal": 0.85, "cal": 0.66},
        "calibration_wins_windows": cal_wins,
        "total_windows": len(cal.records),
        "calibration_not_always_better": cal_wins < len(cal.records),
        # prediction + calibration fuse into one twin_step program since the
        # pure-core redesign; there is no separable calibration timing.
        "mean_window_step_seconds": float(np.mean(
            [r.sim_seconds for r in cal.records])),
        "per_window_mape_cal": np.round(cal.per_window_mape, 3).tolist(),
        "per_window_mape_unc": np.round(unc.per_window_mape, 3).tolist(),
        "wall_seconds": wall,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

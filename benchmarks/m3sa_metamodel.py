"""Multi-model / Meta-Model analysis (paper §2.2; M3SA [28]).

OpenDT "enables ... multi-model simulation that combines the results of
multiple heterogeneous models ... to improve accuracy and quantify
fine-grained differences".  We run the OpenDC model zoo over the same
utilization field and compare each model and three combiners against the
hidden-model telemetry.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import mape
from repro.core.metamodel import combine, run_multi_model
from repro.core.power import PowerParams
from repro.core.twin import TraceGroundTruth
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def run(days: float = 7.0, seed: int = 22) -> dict:
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days, seed=seed), dc)
    t_bins = int(days * BINS_PER_DAY)
    truth = TraceGroundTruth(w, dc, t_bins)
    u = jnp.asarray(truth.u_th)
    real = truth.power

    per = run_multi_model(u, PowerParams())
    real32 = jnp.asarray(real, dtype=jnp.float32)

    def m(x):
        return float(mape(real32, jnp.asarray(np.asarray(x, np.float32))))

    out = {f"model_{k}_mape": m(v) for k, v in per.items()}
    # calibration window for the weighted combiner: day 1 telemetry
    d1 = slice(0, BINS_PER_DAY)
    w_out = combine({k: v[d1] for k, v in per.items()}, "inv_mape",
                    reference=real[d1])
    weights = w_out.weights
    stack = np.stack([per[k] for k in sorted(per)])
    wvec = np.array([weights[k] for k in sorted(per)])
    out["meta_mean_mape"] = m(combine(per, "mean").combined)
    out["meta_median_mape"] = m(combine(per, "median").combined)
    out["meta_weighted_mape"] = m((wvec[:, None] * stack).sum(0))
    out["weights"] = {k: round(v, 3) for k, v in weights.items()}
    best_single = min(v for k, v in out.items()
                      if k.startswith("model_") and k.endswith("_mape"))
    out["meta_beats_worst_single"] = out["meta_weighted_mape"] < max(
        v for k, v in out.items()
        if k.startswith("model_") and k.endswith("_mape"))
    out["best_single_mape"] = best_single
    return out


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

"""NFR2 (paper §2.1/§3.1): twin 7 days of operation in under 1 hour.

The paper's prototype: 46 minutes on an M1 Max (10 cores).  Here the
vectorized DES is one jitted program; we report wall time on 1 CPU core for
the full closed loop (DES + windowed prediction + calibration + SLO), plus
DES-only throughput and calibration-kernel microbenchmarks.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import run_surf_experiment
from repro.core.calibrate import CalibrationSpec, calibrate_window
from repro.core.desim import simulate_utilization
from repro.core.power import PowerParams
from repro.kernels import ops
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def _time(fn, n=5):
    fn()                                  # warmup / compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


def run(days: float = 7.0) -> dict:
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)

    # full closed loop (the NFR2 measurement)
    t0 = time.time()
    res = run_surf_experiment(w, dc, t_bins, calibrate=True)
    loop_wall = time.time() - t0

    # DES-only steady-state throughput
    des_s = _time(lambda: simulate_utilization(
        w, num_hosts=dc.num_hosts, cores_per_host=dc.cores_per_host,
        t_bins=t_bins).u_th.block_until_ready())

    # calibration grid microbench (the Pallas kernel's oracle path on CPU)
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.uniform(0, 1, (288 * 4, 277)).astype(np.float32))
    real = jnp.asarray(rng.uniform(2e4, 5e4, (288 * 4,)).astype(np.float32))
    base = PowerParams()
    spec = CalibrationSpec(r_points=64)
    cal_s = _time(lambda: calibrate_window(u, real, spec, base), n=10)
    cand_per_s = 64 / cal_s

    return {
        "days_twinned": days,
        "closed_loop_wall_s": loop_wall,
        "paper_wall_s": 46 * 60.0,
        "speedup_vs_paper": 46 * 60.0 / loop_wall,
        "nfr2_met": loop_wall < 3600.0,
        "des_only_wall_s": des_s,
        "sim_days_per_wall_second": days / des_s,
        "calibration_window_s": cal_s,
        "calibration_candidates_per_s": cand_per_s,
        "overall_mape_check": res.overall_mape,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

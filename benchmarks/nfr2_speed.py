"""NFR2 (paper §2.1/§3.1): twin 7 days of operation in under 1 hour.

The paper's prototype: 46 minutes on an M1 Max (10 cores).  Here the
vectorized DES is one jitted program; we report wall time on 1 CPU core for
the full closed loop (DES + windowed prediction + calibration + SLO), plus
DES-only throughput and calibration-kernel microbenchmarks.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import run_surf_experiment
from repro.core.calibrate import CalibrationSpec, calibrate_window
from repro.core.desim import simulate_utilization, simulate_utilization_masked
from repro.core.power import PowerParams
from repro.kernels import ops
from repro.traces.schema import DatacenterConfig, host_mask
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like


def _time(fn, n=5):
    fn()                                  # warmup / compile
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


def des_hot_path(days: float = 2.0, dc: DatacenterConfig | None = None) -> dict:
    """Split the masked DES wall time into its two real phases.

    The hot path every scenario lane pays is (a) the **placement scan** —
    the sequential ``lax.scan`` over bins running the policy kernel and the
    failure mask — and (b) the **post-scan readout** that expands placements
    into the dense ``[T, H]`` utilization grid.  The split is measured with
    XLA's own dead-code elimination: a jitted wrapper returning only
    ``job_start`` (pure scan state) compiles the readout away, so

        scan_s    = time(scan-only program)
        readout_s = time(full program) - scan_s

    This is the denominator the single-compile refactors optimize for, and
    the baseline :mod:`analysis.roofline` prices against the hardware.
    """
    dc = dc or DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    mask = host_mask(dc.num_hosts, dc.num_hosts)
    cores = jnp.asarray(dc.cores_per_host, jnp.int32)
    kw = dict(max_hosts=dc.num_hosts, t_bins=t_bins)

    # scan only: the readout never feeds job_start, so XLA DCEs it entirely
    # tracecheck: disable=TC001 — throwaway jits; compile time is measured
    scan_only = jax.jit(lambda wl: simulate_utilization_masked(
        wl, mask, cores, **kw).job_start)
    # tracecheck: disable=TC001 — throwaway jits; compile time is measured
    full = jax.jit(lambda wl: simulate_utilization_masked(
        wl, mask, cores, **kw).u_th)

    scan_s = _time(lambda: scan_only(w).block_until_ready())
    total_s = _time(lambda: full(w).block_until_ready())
    return {
        "days": days,
        "t_bins": t_bins,
        "num_hosts": dc.num_hosts,
        "scan_s": scan_s,
        "readout_s": max(total_s - scan_s, 0.0),
        "total_s": total_s,
        "scan_fraction": min(scan_s / total_s, 1.0) if total_s > 0 else None,
    }


def run(days: float = 7.0) -> dict:
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)

    # full closed loop (the NFR2 measurement)
    t0 = time.time()
    res = run_surf_experiment(w, dc, t_bins, calibrate=True)
    loop_wall = time.time() - t0

    # DES-only steady-state throughput
    des_s = _time(lambda: simulate_utilization(
        w, num_hosts=dc.num_hosts, cores_per_host=dc.cores_per_host,
        t_bins=t_bins).u_th.block_until_ready())

    # calibration grid microbench (the Pallas kernel's oracle path on CPU)
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.uniform(0, 1, (288 * 4, 277)).astype(np.float32))
    real = jnp.asarray(rng.uniform(2e4, 5e4, (288 * 4,)).astype(np.float32))
    base = PowerParams()
    spec = CalibrationSpec(r_points=64)
    cal_s = _time(lambda: calibrate_window(u, real, spec, base), n=10)
    cand_per_s = 64 / cal_s

    hot = des_hot_path()                  # scan vs readout split, 2-day trace

    return {
        "des_hot_path": hot,
        "days_twinned": days,
        "closed_loop_wall_s": loop_wall,
        "paper_wall_s": 46 * 60.0,
        "speedup_vs_paper": 46 * 60.0 / loop_wall,
        "nfr2_met": loop_wall < 3600.0,
        "des_only_wall_s": des_s,
        "sim_days_per_wall_second": days / des_s,
        "calibration_window_s": cal_s,
        "calibration_candidates_per_s": cand_per_s,
        "overall_mape_check": res.overall_mape,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=2))

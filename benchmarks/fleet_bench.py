"""Fleet-axis engine bench (the ROADMAP item-5 trajectory entry).

Runs the same multi-tenant fleet (D resident twins x W windows) through
both ``run_fleet`` execution paths:

  * **vmap** — the single-device batched program (the pre-item-5 engine);
  * **sharded** — ``shard_map`` over the device mesh's ``fleet`` axis,
    padded replica lanes and all, which must reproduce the vmap stream
    bit for bit (pinned by ``tests/test_shard_fleet.py`` and re-asserted
    here on whatever mesh this machine exposes).

The gated invariants are the per-path compile counts (ONE program each,
warm re-run included — the ``_commit_to_mesh`` steady-state guarantee)
and the bitwise cross-check; wall clocks are machine-dependent reference
points recorded with the backend/device count.  On a single device the
sharded path runs through a trivial mesh, so the two walls should match;
the ``tier1-multidevice`` environment is where lanes/device drops.

    PYTHONPATH=src python benchmarks/fleet_bench.py
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.state import SimSlice, TelemetrySlice, TwinConfig, init_twin_state
from repro.core.twin import FLEET_AXIS, fleet_mesh, run_fleet, stack_twin_states
from repro.traces.schema import DatacenterConfig

HOSTS = 16
BINS = 36
LANES = 8          # D: resident tenant twins
WINDOWS = 6

CFG = TwinConfig(bins_per_window=BINS,
                 dc=DatacenterConfig(num_hosts=HOSTS, cores_per_host=16))


def _inputs():
    rng = np.random.default_rng(0)
    u = rng.uniform(0, 1, (WINDOWS, LANES, BINS, HOSTS)).astype(np.float32)
    p = (HOSTS * 70.0 + HOSTS * 280.0
         * rng.uniform(0.2, 0.9, (WINDOWS, LANES, BINS))).astype(np.float32)
    telem = TelemetrySlice(u_th=jnp.asarray(u), power_w=jnp.asarray(p),
                           valid=jnp.ones((WINDOWS, LANES), bool))
    return telem, SimSlice(u_th=jnp.asarray(u))


def _fresh():
    return stack_twin_states([init_twin_state(CFG) for _ in range(LANES)])


def _block(tree) -> None:
    for leaf in jax.tree.leaves(tree):
        leaf.block_until_ready()


def _timed(fn) -> tuple[float, tuple]:
    t0 = time.time()
    out = fn()
    _block(out)
    return time.time() - t0, out


def run() -> dict:
    jax.clear_caches()
    telem, sims = _inputs()
    size = run_fleet._cache_size

    # vmap path: cold (includes the compile), then warm from the evolved
    # state — the donated carry's steady state.
    vmap_cold_s, (st, vmap_outs) = _timed(lambda: run_fleet(
        _fresh(), telem, sims))
    vmap_warm_s, _ = _timed(lambda: run_fleet(st, telem, sims))
    vmap_compiles = size() if callable(size) else None

    # sharded path: same fleet through the device mesh, then a warm re-run
    # feeding the committed outputs back (the serve dispatch loop's shape).
    mesh = fleet_mesh()
    n_dev = mesh.shape[FLEET_AXIS]
    sh_cold_s, (sh_st, sh_outs) = _timed(lambda: run_fleet(
        _fresh(), telem, sims, shard=True, mesh=mesh))
    sh_warm_s, _ = _timed(lambda: run_fleet(
        sh_st, telem, sims, shard=True, mesh=mesh))
    sharded_compiles = (size() - vmap_compiles) if callable(size) else None

    if vmap_compiles is not None:
        assert vmap_compiles == 1, f"vmap path compiled {vmap_compiles}x"
    if sharded_compiles is not None:
        assert sharded_compiles == 1, \
            f"sharded path compiled {sharded_compiles}x (warm re-run retraced)"

    bitwise = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(vmap_outs), jax.tree.leaves(sh_outs)))
    assert bitwise, "sharded fleet diverged from the vmap path"

    per_dev = -(-LANES // n_dev)
    if n_dev > 1:
        per_dev = max(per_dev, 2)   # replica-lane floor (see _fleet_pad)
    return {
        "lanes": LANES,
        "windows": WINDOWS,
        "hosts": HOSTS,
        "bins_per_window": BINS,
        "mesh_devices": n_dev,
        "lanes_per_device": per_dev,
        "vmap_compiles": vmap_compiles,
        "sharded_compiles": sharded_compiles,
        "sharded_bitwise_equal": bitwise,
        "vmap_cold_s": vmap_cold_s,
        "vmap_warm_s": vmap_warm_s,
        "sharded_cold_s": sh_cold_s,
        "sharded_warm_s": sh_warm_s,
        "vmap_window_step_s": vmap_warm_s / WINDOWS,
        "sharded_window_step_s": sh_warm_s / WINDOWS,
    }


def main() -> None:
    r = run()
    print(f"fleet engine: {r['lanes']} twins x {r['windows']} windows "
          f"({r['hosts']} hosts, {r['bins_per_window']} bins) on "
          f"{r['mesh_devices']} device(s), {r['lanes_per_device']} "
          "lanes/device")
    if r["vmap_compiles"] is not None:
        print(f"  compiles: vmap {r['vmap_compiles']}, sharded "
              f"{r['sharded_compiles']} (PASS: one program each, asserted)")
    print(f"  bitwise vmap == sharded: {r['sharded_bitwise_equal']}")
    print(f"  vmap    cold {r['vmap_cold_s']:7.2f} s, warm "
          f"{r['vmap_warm_s']:7.2f} s "
          f"({r['vmap_window_step_s'] * 1e3:.1f} ms/window)")
    print(f"  sharded cold {r['sharded_cold_s']:7.2f} s, warm "
          f"{r['sharded_warm_s']:7.2f} s "
          f"({r['sharded_window_step_s'] * 1e3:.1f} ms/window)")


if __name__ == "__main__":
    main()

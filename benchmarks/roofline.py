"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs useful fraction, and peak bytes/device.
"""

from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_cells(results_dir: str = RESULTS) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def table(cells: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':7s} {'status':8s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>8s} "
           f"{'dominant':>10s} {'useful':>7s} {'roofl%':>7s} {'GB/dev':>7s}")
    rows = [hdr, "-" * len(hdr)]
    for c in cells:
        if c["status"] != "ok":
            rows.append(f"{c['arch']:24s} {c['shape']:12s} {c['mesh']:7s} "
                        f"{c['status']:8s} -- {c.get('reason', c.get('error', ''))[:60]}")
            continue
        r = c["roofline"]
        rows.append(
            f"{c['arch']:24s} {c['shape']:12s} {c['mesh']:7s} {'ok':8s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:8.4f} {r['dominant']:>10s} "
            f"{r['useful_flops_fraction']:7.3f} "
            f"{100*r['roofline_fraction']:7.3f} "
            f"{c['memory']['peak_bytes_per_device']/1e9:7.2f}")
    return "\n".join(rows)


def summarize(cells: list[dict]) -> dict:
    ok = [c for c in cells if c["status"] == "ok"]
    skipped = [c for c in cells if c["status"] == "skipped"]
    err = [c for c in cells if c["status"] == "error"]
    dominants: dict[str, int] = {}
    for c in ok:
        d = c["roofline"]["dominant"]
        dominants[d] = dominants.get(d, 0) + 1
    worst = sorted(ok, key=lambda c: c["roofline"]["roofline_fraction"])[:3]
    most_coll = sorted(ok, key=lambda c: -c["roofline"]["collective_s"])[:3]
    return {
        "cells_ok": len(ok),
        "cells_skipped": len(skipped),
        "cells_error": len(err),
        "dominant_counts": dominants,
        "worst_roofline": [
            (c["arch"], c["shape"], c["mesh"],
             c["roofline"]["roofline_fraction"]) for c in worst],
        "most_collective_bound": [
            (c["arch"], c["shape"], c["mesh"], c["roofline"]["collective_s"])
            for c in most_coll],
    }


if __name__ == "__main__":
    cells = load_cells()
    print(table(cells))
    print()
    print(json.dumps(summarize(cells), indent=2))

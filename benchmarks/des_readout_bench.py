"""Fused DES readout benchmark: legacy vs fused-XLA vs Pallas (PR 7).

Three measurements, tightest scope first:

* **readout microbench** — the per-bin readout alone (utilization ->
  power shape -> PUE -> cap/throttle -> energy/gCO2/cost) on a dense
  ``[T, H]`` grid with every axis on, as three warm jitted programs: the
  legacy unfused composition (``scenarios._predict_masked``), the fused
  single-pass XLA reference (``des_readout_ref``), and the Pallas kernel.
  On CPU runtimes the Pallas program runs in *interpret mode* — a
  correctness emulation, not a performance path — so its wall time is
  recorded honestly next to the ``backend`` field rather than sold as a
  speedup; on TPU the compiled kernel is the number that matters.

* **engine sweep** — ``run_scenarios`` end-to-end on a mixed
  (failures x PUE x price x cap) grid, legacy vs ``use_pallas=True``:
  warm wall and the single-compile guarantee for both paths.

* **optimizer** — warm candidates/s of the donated single-program search
  (``whatif_batch.run_optimizer``), the steady-state number the what-if
  loop is judged by.

    PYTHONPATH=src python benchmarks/run.py des
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

import nfr2_speed
import whatif_batch
from nfr2_speed import _time

from repro.core.power import PowerParams
from repro.core.scenarios import Scenario, _predict_masked, build_scenario_set, run_scenarios
from repro.kernels.des_readout import des_readout_pallas, des_readout_ref
from repro.runtime.fault import DEGRADED, OUTAGE, HostFailure
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.price import make_diurnal_price
from repro.traces.schema import DatacenterConfig
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like
from repro.traces.thermal import make_diurnal_ambient


def readout_microbench(t_bins: int = 2 * 288, hosts: int = 277) -> dict:
    """Warm per-call wall of the three readout programs on one [T, H] grid."""
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.uniform(0, 1, (t_bins, hosts)).astype(np.float32))
    params = PowerParams()
    mask = jnp.ones((hosts,), bool)
    cap_t = jnp.asarray(
        rng.uniform(15_000.0, 30_000.0, t_bins).astype(np.float32))
    intensity = jnp.asarray(make_diurnal_carbon(t_bins))
    ambient = jnp.asarray(make_diurnal_ambient(t_bins, seed=2))
    price = jnp.asarray(make_diurnal_price(t_bins, seed=3))
    from repro.traces.thermal import PUEParams
    pue = PUEParams(base=1.12, amb_coeff=0.004, amb_ref=18.0,
                    load_coeff=0.08)
    peak = jnp.float32(100.0)

    # tracecheck: disable=TC001 — throwaway jits; compile time is measured
    legacy = jax.jit(lambda x: _predict_masked(
        x, params, mask, peak, "opendc", cap_t, intensity,
        pue=pue, ambient=ambient, price=price).power_w)
    kw = dict(p_idle=params.p_idle, p_max=params.p_max, r=params.r,
              cap_t=cap_t, intensity=intensity, ambient=ambient, price=price,
              peak_tflops=100.0, pue_base=1.12, pue_amb_coeff=0.004,
              pue_amb_ref=18.0, pue_load_coeff=0.08)
    # tracecheck: disable=TC001 — throwaway jits; compile time is measured
    fused = jax.jit(lambda x: des_readout_ref(x, **kw)["power_w"])
    interpret = jax.default_backend() != "tpu"
    # tracecheck: disable=TC001 — throwaway jits; compile time is measured
    pallas = jax.jit(
        lambda x: des_readout_pallas(x, **kw, interpret=interpret)["power_w"])

    legacy_s = _time(lambda: legacy(u).block_until_ready())
    fused_s = _time(lambda: fused(u).block_until_ready())
    pallas_s = _time(lambda: pallas(u).block_until_ready(),
                     n=2 if interpret else 5)
    return {
        "t_bins": t_bins,
        "hosts": hosts,
        "legacy_unfused_s": legacy_s,
        "fused_xla_s": fused_s,
        "pallas_s": pallas_s,
        "pallas_mode": "interpret" if interpret else "compiled",
        "fused_vs_legacy_speedup": legacy_s / fused_s,
        "pallas_vs_xla_speedup": fused_s / pallas_s,
    }


def engine_sweep(days: float = 0.5) -> dict:
    """run_scenarios on a mixed-axes grid: legacy vs fused readout path."""
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    scs = []
    for fi in (0, 1):
        fails = () if fi == 0 else (
            HostFailure(host=4, start_bin=10, end_bin=60, kind=OUTAGE),
            HostFailure(host=40, start_bin=30, end_bin=90, kind=DEGRADED))
        for pb, plc in ((1.0, 0.0), (1.12, 0.08)):
            for cap in (45_000.0, 70_000.0):
                scs.append(Scenario(name=f"f{fi}-p{pb:.2f}-c{cap:.0f}",
                                    failures=fails, pue_base=pb,
                                    pue_load_coeff=plc,
                                    pue_amb_coeff=0.004 if plc else 0.0,
                                    power_cap_w=cap))
    kw = dict(t_bins=t_bins,
              carbon_intensity=make_diurnal_carbon(t_bins),
              ambient_c=make_diurnal_ambient(t_bins, seed=2),
              price=make_diurnal_price(t_bins, seed=3))
    ss = build_scenario_set(w, dc, scs)

    out = {"grid": len(scs), "t_bins": t_bins}
    for label, use_pallas in (("legacy", False), ("pallas", True)):
        jax.clear_caches()
        cache = run_scenarios._cache_size

        def sweep():
            _, pred = run_scenarios(ss, max_hosts=ss.max_hosts, **kw,
                                    use_pallas=use_pallas)
            pred.energy_cost.block_until_ready()

        warm_s = _time(sweep, n=3)
        out[f"{label}_warm_s"] = warm_s
        out[f"{label}_compiles"] = cache() if cache is not None else None
    out["pallas_vs_legacy_warm"] = out["legacy_warm_s"] / out["pallas_warm_s"]
    return out


def run(days: float = 0.5) -> dict:
    return {
        "des_hot_path": nfr2_speed.des_hot_path(),
        "readout_microbench": readout_microbench(),
        "engine_sweep": engine_sweep(days),
        "optimizer": whatif_batch.run_optimizer(days=days),
    }

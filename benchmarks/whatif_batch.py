"""What-if sweep: batched scenario engine vs the sequential per-topology loop.

The old operator loop re-traced and re-compiled ``simulate_utilization`` once
per candidate topology (S compiles for S candidates).  The batched engine
(``repro.core.scenarios``) pads the host axis to a static ``max_hosts``,
vmaps the masked DES over the stacked scenario pytree, and compiles **once**
for the whole sweep.  This benchmark times both paths at S=16 candidate host
counts on the same trace and reports the wall-clock ratio (target: >= 5x).

A second case sweeps the *scheduler* axis: a (4 placement policies x 4
topologies) grid runs as one jitted program — the policy is a traced
scenario knob, so compile count stays 1 for the whole grid — and the
worst-fit/no-backfill lane is checked bit-for-bit against a direct
``simulate_utilization_masked`` call (the pre-policy-kernel scheduler).

A third case sweeps the *carbon* axes: a (carbon-aware power caps x
deferrable-job time shifts x topologies) grid against a diurnal
grid-carbon-intensity trace — single-compile is **asserted** (cap
parameters are traced ``[S]`` scalars, shifts are same-shape workload
data), including across re-parameterized grids of the same shape.

A fourth case *shards the scenario axis*: ``run_scenarios(shard=True)``
``shard_map``s S over the device mesh, records the warm speedup vs the
single-device vmap and asserts bit-for-bit equality (multi-device runtimes
only — on CPU export ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
before launch).

A fifth case runs the *scenario optimizer* (``repro.core.optimize``): a
multi-generation search over (structures x carbon caps x time shifts) whose
fixed-shape candidate batches must all ride ONE compiled evaluator —
**asserted**: exactly 1 compile for the whole search, and 0 further
compiles for a second search after warmup.  Reports candidates/sec and the
objective reached vs an exhaustive grid of equal candidate budget.

A sixth case sweeps the *newest axes* together: a (host-failure schedules x
dynamic-PUE models x power caps) grid against carbon, ambient and spot-price
traces — single-compile **asserted** (failure windows are traced ``[S, H]``
schedules, PUE parameters traced ``[S]`` scalars), including across
re-parameterized grids, plus bit-for-bit shard_map equality when the
runtime has >= 2 devices.

    PYTHONPATH=src python benchmarks/whatif_batch.py
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.desim import PLACEMENT_POLICIES, simulate, simulate_utilization_masked
from repro.core.optimize import (
    ObjectiveSpec,
    OptimizerConfig,
    SearchSpace,
    optimize,
    score_batch,
)
from repro.core.scenarios import Scenario, build_scenario_set, run_scenarios
from repro.runtime.fault import DEGRADED, OUTAGE, HostFailure
from repro.traces.carbon import make_diurnal_carbon
from repro.traces.price import make_diurnal_price
from repro.traces.schema import DatacenterConfig, host_mask
from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like
from repro.traces.thermal import make_diurnal_ambient


def run(days: float = 2.0, num_scenarios: int = 16) -> dict:
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)

    # S distinct host counts: every one is a fresh static shape for the
    # sequential path, i.e. a fresh trace + compile.
    host_counts = [64 + 24 * i for i in range(num_scenarios)]
    scenarios = [Scenario(name=f"h{h}", num_hosts=h) for h in host_counts]

    # -- sequential loop (the old examples/whatif_scaling.py shape):
    # one simulate() per candidate = fresh trace + compile + run + metrics.
    jax.clear_caches()
    t0 = time.time()
    seq_outs = []
    for h in host_counts:
        sim, pred = simulate(
            w, DatacenterConfig(num_hosts=h, cores_per_host=dc.cores_per_host),
            t_bins)
        pred.power_w.block_until_ready()
        seq_outs.append(sim.u_th.block_until_ready())
    sequential_s = time.time() - t0

    # -- batched engine: one jitted program for all S ------------------------
    # build_scenario_set (stacking S workload copies) is part of every real
    # sweep's cost, so it sits inside the timed region on both passes.
    jax.clear_caches()
    t0 = time.time()
    ss = build_scenario_set(w, dc, scenarios)
    sim_b, _ = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=t_bins)
    sim_b.u_th.block_until_ready()
    batched_cold_s = time.time() - t0            # includes the one compile

    t0 = time.time()
    ss2 = build_scenario_set(w, dc, scenarios)
    sim_b2, _ = run_scenarios(ss2, max_hosts=ss2.max_hosts, t_bins=t_bins)
    sim_b2.u_th.block_until_ready()
    batched_warm_s = time.time() - t0            # steady-state sweep cost

    return {
        "num_scenarios": num_scenarios,
        "days": days,
        "t_bins": t_bins,
        "max_hosts": ss.max_hosts,
        "sequential_s": sequential_s,
        "batched_cold_s": batched_cold_s,
        "batched_warm_s": batched_warm_s,
        "speedup_cold": sequential_s / batched_cold_s,
        "speedup_warm": sequential_s / batched_warm_s,
    }


def run_policy_grid(days: float = 1.0) -> dict:
    """(4 policies x 4 topologies) scheduler sweep as ONE jitted program.

    Verifies the two properties the policy-axis refactor promises:
      * single compile for the whole grid (checked via the jit cache size
        when jax exposes it);
      * the worst-fit/no-backfill lane is bit-for-bit the plain masked DES.
    """
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    host_counts = [64, 128, 200, 277]
    policies = sorted(PLACEMENT_POLICIES)
    grid = [Scenario(name=f"{p}-h{h}", policy=p, num_hosts=h,
                     backfill_depth=0 if p == "worst_fit" else 8)
            for p in policies for h in host_counts]

    jax.clear_caches()
    cache = run_scenarios._cache_size
    t0 = time.time()
    ss = build_scenario_set(w, dc, grid)
    sim, _ = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=t_bins)
    sim.u_th.block_until_ready()
    grid_s = time.time() - t0
    compiles = cache() if cache is not None else None

    # exactness: the worst-fit/no-backfill lanes must reproduce the direct
    # masked-DES output (the pre-policy-kernel scheduler) exactly.
    exact = True
    for i, sc in enumerate(grid):
        if sc.policy != "worst_fit":
            continue
        ref = simulate_utilization_masked(
            jax.tree.map(lambda x: x[i], ss.workload),
            host_mask(sc.num_hosts, ss.max_hosts),
            jnp.asarray(dc.cores_per_host, jnp.int32),
            max_hosts=ss.max_hosts, t_bins=t_bins)
        exact &= bool(
            (np.asarray(sim.u_th[i]) == np.asarray(ref.u_th)).all()
            and (np.asarray(sim.job_start[i])
                 == np.asarray(ref.job_start)).all()
            and (np.asarray(sim.job_host[i])
                 == np.asarray(ref.job_host)).all())

    return {
        "grid": len(grid),
        "policies": len(policies),
        "topologies": len(host_counts),
        "t_bins": t_bins,
        "grid_s": grid_s,
        "compiles": compiles,
        "worst_fit_exact": exact,
    }


def run_carbon_grid(days: float = 1.0) -> dict:
    """(carbon-cap x time-shift x topology) grid as ONE jitted program.

    The carbon axes are traced ``[S]`` scalars (cap base/slope) or
    same-shape workload data (time shifts), so the sweep must share one
    compilation — asserted via the jit cache when jax exposes it, exactly
    like the policy grid.  A second differently-valued grid of the same
    shape must not add a compile either.
    """
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    intensity = make_diurnal_carbon(t_bins)

    def grid(cap_scale: float) -> list[Scenario]:
        return [
            Scenario(name=f"c{cap}-s{sh}-h{h}",
                     carbon_cap_base_w=cap * cap_scale,
                     carbon_cap_slope=-60.0,
                     shift_bins=sh, num_hosts=h)
            for cap in (40_000.0, 60_000.0)
            for sh in (0, 36)
            for h in (128, 277)]

    jax.clear_caches()
    cache = run_scenarios._cache_size
    t0 = time.time()
    ss = build_scenario_set(w, dc, grid(1.0), max_hosts=277)
    sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=t_bins,
                              carbon_intensity=intensity)
    pred.gco2.block_until_ready()
    grid_s = time.time() - t0
    compiles = cache() if cache is not None else None

    ss2 = build_scenario_set(w, dc, grid(1.25), max_hosts=277)
    _, pred2 = run_scenarios(ss2, max_hosts=ss2.max_hosts, t_bins=t_bins,
                             carbon_intensity=intensity)
    pred2.gco2.block_until_ready()
    compiles_after = cache() if cache is not None else None
    if compiles is not None:
        # the acceptance gate: a (caps x shifts x topologies) sweep is ONE
        # compiled program, and re-parameterizing it does not retrace.
        assert compiles == 1, f"carbon grid compiled {compiles}x, want 1"
        assert compiles_after == compiles, "re-parameterized grid retraced"

    gco2 = np.asarray(pred.gco2).sum(axis=1)
    return {
        "grid": len(ss.names),
        "t_bins": t_bins,
        "grid_s": grid_s,
        "compiles": compiles,
        "gco2_min_kg": float(gco2.min() / 1e3),
        "gco2_max_kg": float(gco2.max() / 1e3),
    }


def run_new_axes_grid(days: float = 1.0) -> dict:
    """(failure x dynamic-PUE x spot-price x power-cap) grid, ONE program.

    The PR-6 axes ride the same traced lanes as caps/shifts/policies: failure
    windows are ``[S, max_hosts]`` int32 schedules, the PUE model is four
    ``[S]`` scalars, and the ambient/price traces are shared ``[T]`` operands
    next to grid carbon.  Single-compile is **asserted**, including for a
    re-parameterized grid of the same shape (different windows, coefficients
    and caps — no retrace).  With >= 2 devices the same mixed batch is also
    pushed through ``run_scenarios(shard=True)`` and checked bit for bit
    against the vmap path.
    """
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    intensity = make_diurnal_carbon(t_bins)
    ambient = make_diurnal_ambient(t_bins, seed=2)
    price = make_diurnal_price(t_bins, seed=3)

    def grid(shift: int) -> list[Scenario]:
        # 2 failure sets x 2 PUE models x 2 caps = S=8; `shift` re-seeds the
        # windows/coefficients for the no-retrace check (same shapes).
        scs = []
        for fi in (0, 1):
            fails = () if fi == 0 else (
                HostFailure(host=4 + shift, start_bin=20 + shift,
                            end_bin=80 + shift, kind=OUTAGE),
                HostFailure(host=40, start_bin=60, end_bin=160 + shift,
                            kind=DEGRADED))
            for pb, plc in ((1.0, 0.0), (1.12 + 0.01 * shift, 0.08)):
                for cap in (45_000.0, 70_000.0 + 100.0 * shift):
                    scs.append(Scenario(
                        name=f"f{fi}-p{pb:.2f}-c{cap:.0f}",
                        failures=fails, pue_base=pb, pue_load_coeff=plc,
                        pue_amb_coeff=0.004 if plc else 0.0,
                        power_cap_w=cap))
        return scs

    jax.clear_caches()
    cache = run_scenarios._cache_size
    kw = dict(t_bins=t_bins, carbon_intensity=intensity,
              ambient_c=ambient, price=price)
    t0 = time.time()
    ss = build_scenario_set(w, dc, grid(0))
    sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, **kw)
    pred.energy_cost.block_until_ready()
    grid_s = time.time() - t0
    compiles = cache() if cache is not None else None

    ss2 = build_scenario_set(w, dc, grid(3))
    _, pred2 = run_scenarios(ss2, max_hosts=ss2.max_hosts, **kw)
    pred2.energy_cost.block_until_ready()
    compiles_after = cache() if cache is not None else None
    if compiles is not None:
        # the acceptance gate: failures/PUE/price are traced axes — the whole
        # mixed grid is ONE compiled program and re-parameterizing it (new
        # outage windows, coefficients, caps) does not retrace.
        assert compiles == 1, f"new-axes grid compiled {compiles}x, want 1"
        assert compiles_after == compiles, "re-parameterized grid retraced"

    # the shard_map cross-check needs >= 2 devices; a single-device runtime
    # records an explicit skip reason instead of a silent null so the
    # committed snapshot says WHY the check did not run (and check_bench.py
    # can tell "skipped" from "forgot")
    n_dev = len(jax.devices())
    if n_dev >= 2:
        sh_sim, sh_pred = run_scenarios(ss, max_hosts=ss.max_hosts, **kw,
                                        shard=True)
        sharded_exact = all(
            bool((np.asarray(a) == np.asarray(b)).all())
            for a, b in zip(jax.tree.leaves((sim, pred)),
                            jax.tree.leaves((sh_sim, sh_pred))))
        assert sharded_exact, "sharded new-axes grid diverged from vmap"
    else:
        sharded_exact = (
            f"skipped: 1 device (need >= 2; export "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=4)")

    cost = np.asarray(pred.energy_cost, np.float64).sum(axis=1)
    pue = np.asarray(pred.pue)
    return {
        "grid": len(ss.names),
        "t_bins": t_bins,
        "grid_s": grid_s,
        "compiles": compiles,
        "cost_min_usd": float(cost.min()),
        "cost_max_usd": float(cost.max()),
        "mean_pue_max": float(pue.mean(axis=1).max()),
        "sharded_bitwise_equal": sharded_exact,
    }


def run_optimizer(days: float = 0.5) -> dict:
    """Scenario optimizer vs an exhaustive grid at equal candidate budget.

    The acceptance gates, **asserted** (when jax exposes its jit cache):

      * the whole multi-generation search — init batches plus every
        refinement generation — compiles the evaluator exactly once;
      * a second search after warmup adds zero compiles ("<= 1 compile
        after warmup").

    Reported: fresh-candidates/sec for the warm search (reserved
    baseline/incumbent lanes excluded from the count — they are evaluator
    work, not search budget), the same for an exhaustive grid holding
    **exactly the same number of candidates** (evaluated the way an
    operator would: one big batch, its own compile), and the best
    objective each reaches.
    """
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    intensity = make_diurnal_carbon(t_bins)
    space = SearchSpace(
        structures=(Scenario(name="wf"),
                    Scenario(name="bf", policy="best_fit", backfill_depth=8),
                    Scenario(name="h200", num_hosts=200)),
        carbon_cap_base_w=(30_000.0, 80_000.0),
        carbon_cap_slope=(-80.0, 0.0),
        shift_bins=(0, 72))
    objective = ObjectiveSpec(w_gco2_kg=1.0, w_wait=0.5, w_unplaced=50.0,
                              w_throttled=0.1)
    # 8 fresh lanes/batch x (1 init + 2 refinement) = 24 fresh candidates —
    # exactly the size of the levels-2 exhaustive grid below (equal budget)
    cfg = OptimizerConfig(batch_size=10, generations=2, init="random")
    kw = dict(t_bins=t_bins, carbon_intensity=intensity, key=0, config=cfg)

    jax.clear_caches()
    cache = run_scenarios._cache_size
    t0 = time.time()
    res = optimize(w, dc, space, objective, **kw)
    cold_s = time.time() - t0
    compiles = cache() if cache is not None else None
    t0 = time.time()
    res = optimize(w, dc, space, objective, **kw)
    warm_s = time.time() - t0
    compiles_after = cache() if cache is not None else None
    if compiles is not None:
        # the acceptance gate: all generations ride ONE compiled program,
        # and a repeated search after warmup never recompiles.
        assert compiles == 1, f"optimizer compiled {compiles}x, want 1"
        assert compiles_after == compiles, "warm optimizer search retraced"

    # exhaustive grid at (as near as the axes allow) equal budget, evaluated
    # the way an operator would: one batch, scored once.
    levels = 2
    grid = space.grid(levels)           # 3 structures x 2^3 levels = 24
    t0 = time.time()
    ss = build_scenario_set(w, dc, grid, max_hosts=space.max_hosts(dc),
                            max_backfill=space.max_backfill())
    sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=t_bins,
                              carbon_intensity=intensity)
    grid_obj = score_batch(objective, ss, sim, pred,
                           t_bins=t_bins)["objective"]
    grid_s = time.time() - t0
    assert res.candidates == len(grid), "budgets drifted; fix cfg or levels"

    return {
        "t_bins": t_bins,
        "candidates": res.candidates,
        "evaluations": res.evaluations,
        "batches": res.batches,
        "compiles": compiles,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "cand_per_s_warm": res.candidates / warm_s,
        "best_objective": res.best.objective,
        "baseline_objective": res.baseline.objective,
        "grid_candidates": len(grid),
        "grid_s": grid_s,
        "grid_cand_per_s": len(grid) / grid_s,
        "grid_best_objective": float(grid_obj.min()),
    }


def run_scale(days: float = 0.25, num_scenarios: int = 1000,
              slice_s: int = 16) -> dict:
    """S>=1000 mixed scenario batch: ONE program, lanes == a sliced run.

    The scale case behind the streaming-service PR: a thousand-and-more
    lane batch over mixed traced axes (host counts, power caps, time
    shifts, dynamic-PUE models) on a smaller datacenter (64 hosts), so the
    batch stays memory-light while S dwarfs anything the other grids run.
    Two properties are **asserted**:

      * the whole S-lane batch compiles exactly once (the S axis is vmapped
        data, never a shape);
      * the first ``slice_s`` lanes are bit-for-bit an independent
        ``slice_s``-scenario run of the same prefix on the same
        ``max_hosts`` padding — lanes are airtight at any S.
    """
    dc = DatacenterConfig(num_hosts=64)
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    intensity = make_diurnal_carbon(t_bins)
    ambient = make_diurnal_ambient(t_bins, seed=2)

    # mixed traced axes, deterministic in i — no two lanes identical, no
    # shape depends on i
    scs = [
        Scenario(
            name=f"s{i}",
            num_hosts=32 + (i % 33),
            power_cap_w=8_000.0 + 25.0 * (i % 800),
            shift_bins=(i % 4) * (t_bins // 8),
            pue_base=1.0 + 0.002 * (i % 150),
            pue_load_coeff=0.08 if i % 2 else 0.0,
            pue_amb_coeff=0.004 if i % 2 else 0.0)
        for i in range(num_scenarios)]

    jax.clear_caches()
    cache = run_scenarios._cache_size
    kw = dict(t_bins=t_bins, carbon_intensity=intensity, ambient_c=ambient)
    t0 = time.time()
    ss = build_scenario_set(w, dc, scs, max_hosts=dc.num_hosts)
    sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, **kw)
    pred.power_w.block_until_ready()
    batch_s = time.time() - t0
    compiles = cache() if cache is not None else None
    if compiles is not None:
        # the acceptance gate: S is data — a thousand lanes, one program.
        assert compiles == 1, f"S={num_scenarios} batch compiled {compiles}x"

    # airtight lanes: an independent small run of the same scenario prefix
    # (same max_hosts padding => same per-lane program) must equal the big
    # batch's first lanes bit for bit.
    ss_small = build_scenario_set(w, dc, scs[:slice_s], max_hosts=dc.num_hosts)
    sim_sm, pred_sm = run_scenarios(ss_small, max_hosts=ss_small.max_hosts,
                                    **kw)
    sliced_equal = all(
        bool((np.asarray(a)[:slice_s] == np.asarray(b)).all())
        for a, b in zip(jax.tree.leaves((sim, pred)),
                        jax.tree.leaves((sim_sm, pred_sm))))
    assert sliced_equal, (
        f"lanes 0..{slice_s - 1} of the S={num_scenarios} batch diverged "
        "from the standalone run")

    return {
        "num_scenarios": num_scenarios,
        "t_bins": t_bins,
        "max_hosts": ss.max_hosts,
        "batch_s": batch_s,
        "scenarios_per_s": num_scenarios / batch_s,
        "compiles": compiles,
        "sliced_bitwise_equal": sliced_equal,
    }


def run_sharded(days: float = 1.0, num_scenarios: int = 16) -> dict | None:
    """Scenario-axis sharding: shard_map over S vs the single-device vmap.

    Needs a multi-device runtime; on CPU boxes export

        XLA_FLAGS=--xla_force_host_platform_device_count=4

    *before* process start (the tier1-multidevice CI job does exactly this).
    Reports warm wall-clock for both paths and asserts the shard_map output
    is bit-for-bit the vmap output — the same gate as
    ``tests/test_shard_scenarios.py``.
    """
    n_dev = len(jax.devices())
    if n_dev < 2:
        return None
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=days), dc)
    t_bins = int(days * BINS_PER_DAY)
    host_counts = [64 + 12 * i for i in range(num_scenarios)]
    ss = build_scenario_set(
        w, dc, [Scenario(name=f"h{h}", num_hosts=h) for h in host_counts])

    def timed(**kw):
        sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=t_bins,
                                  **kw)
        sim.u_th.block_until_ready()          # warm-up/compile
        t0 = time.time()
        sim, pred = run_scenarios(ss, max_hosts=ss.max_hosts, t_bins=t_bins,
                                  **kw)
        sim.u_th.block_until_ready()
        return time.time() - t0, sim, pred

    vmap_s, sim_v, pred_v = timed()
    shard_s, sim_s, pred_s = timed(shard=True)
    exact = all(
        bool((np.asarray(a) == np.asarray(b)).all())
        for a, b in zip(jax.tree.leaves((sim_v, pred_v)),
                        jax.tree.leaves((sim_s, pred_s))))
    # the acceptance gate, enforced (not just printed): shard_map over S
    # must reproduce the single-device vmap path bit for bit.
    assert exact, "sharded scenario outputs diverged from the vmap path"
    return {
        "devices": n_dev,
        "num_scenarios": num_scenarios,
        "t_bins": t_bins,
        "vmap_warm_s": vmap_s,
        "shard_warm_s": shard_s,
        "speedup": vmap_s / shard_s,
        "bitwise_equal": exact,
    }


def main() -> None:
    r = run()
    print(f"what-if sweep, S={r['num_scenarios']} topologies, "
          f"{r['days']:.0f} days ({r['t_bins']} bins), "
          f"max_hosts={r['max_hosts']}")
    print(f"  sequential loop (S compiles): {r['sequential_s']:8.2f} s")
    print(f"  batched engine, cold (1 compile): {r['batched_cold_s']:6.2f} s "
          f"-> {r['speedup_cold']:.1f}x")
    print(f"  batched engine, warm:         {r['batched_warm_s']:8.2f} s "
          f"-> {r['speedup_warm']:.1f}x")
    target = 5.0
    ok = r["speedup_cold"] >= target
    print(f"  target >= {target:.0f}x cold: {'PASS' if ok else 'FAIL'}")

    g = run_policy_grid()
    print(f"\npolicy grid: {g['policies']} policies x {g['topologies']} "
          f"topologies = S={g['grid']}, {g['t_bins']} bins: {g['grid_s']:.2f} s")
    if g["compiles"] is not None:
        print(f"  compiled programs: {g['compiles']} "
              f"({'PASS' if g['compiles'] == 1 else 'FAIL'}: single compile)")
    print(f"  worst-fit lanes == plain masked DES: "
          f"{'PASS' if g['worst_fit_exact'] else 'FAIL'}")

    c = run_carbon_grid()
    print(f"\ncarbon grid: (2 caps x 2 shifts x 2 topologies) = "
          f"S={c['grid']}, {c['t_bins']} bins: {c['grid_s']:.2f} s")
    if c["compiles"] is not None:
        print(f"  compiled programs: {c['compiles']} (PASS: single compile, "
              "asserted incl. re-parameterization)")
    print(f"  per-scenario gCO2 spread: {c['gco2_min_kg']:.1f} - "
          f"{c['gco2_max_kg']:.1f} kgCO2")

    a = run_new_axes_grid()
    print(f"\nnew-axes grid: (2 failure sets x 2 PUE models x 2 caps) = "
          f"S={a['grid']} + price/carbon/ambient traces, {a['t_bins']} bins: "
          f"{a['grid_s']:.2f} s")
    if a["compiles"] is not None:
        print(f"  compiled programs: {a['compiles']} (PASS: single compile, "
              "asserted incl. re-parameterization)")
    print(f"  per-scenario energy cost spread: ${a['cost_min_usd']:.2f} - "
          f"${a['cost_max_usd']:.2f}; worst mean PUE {a['mean_pue_max']:.3f}")
    sbe = a["sharded_bitwise_equal"]
    if isinstance(sbe, str):
        print(f"  sharded bit-for-bit vs vmap: {sbe}")
    else:
        print(f"  sharded bit-for-bit vs vmap: {'PASS' if sbe else 'FAIL'}")

    o = run_optimizer()
    print(f"\nscenario optimizer: {o['candidates']} fresh candidates "
          f"({o['evaluations']} lanes incl. baseline/incumbent) over "
          f"{o['batches']} fixed-shape batches, {o['t_bins']} bins")
    if o["compiles"] is not None:
        print(f"  compiled programs: {o['compiles']} (PASS: single compile "
              "across all generations, asserted incl. a warm re-search)")
    print(f"  search, cold: {o['cold_s']:6.2f} s   warm: {o['warm_s']:6.2f} s"
          f" -> {o['cand_per_s_warm']:.1f} candidates/s")
    print(f"  exhaustive grid at equal budget ({o['grid_candidates']} "
          f"candidates, own compile): {o['grid_s']:6.2f} s -> "
          f"{o['grid_cand_per_s']:.1f} candidates/s")
    print(f"  objective: searched {o['best_objective']:.2f} vs grid best "
          f"{o['grid_best_objective']:.2f} vs baseline "
          f"{o['baseline_objective']:.2f}")

    sc = run_scale()
    print(f"\nscale batch: S={sc['num_scenarios']} mixed scenarios, "
          f"{sc['t_bins']} bins, max_hosts={sc['max_hosts']}: "
          f"{sc['batch_s']:.2f} s -> {sc['scenarios_per_s']:.0f} scenarios/s")
    if sc["compiles"] is not None:
        print(f"  compiled programs: {sc['compiles']} (PASS: single compile "
              "at S=1000, asserted)")
    print(f"  lanes 0..15 == standalone S=16 run: "
          f"{'PASS' if sc['sliced_bitwise_equal'] else 'FAIL'}")

    s = run_sharded()
    if s is None:
        print("\nsharded scenario axis: skipped (single device; export "
              "XLA_FLAGS=--xla_force_host_platform_device_count=4 to "
              "exercise shard_map on CPU)")
    else:
        print(f"\nsharded scenario axis: S={s['num_scenarios']} over "
              f"{s['devices']} devices, {s['t_bins']} bins")
        print(f"  vmap (1 device), warm:  {s['vmap_warm_s']:8.2f} s")
        print(f"  shard_map, warm:        {s['shard_warm_s']:8.2f} s "
              f"-> {s['speedup']:.2f}x")
        print(f"  bit-for-bit vs vmap: "
              f"{'PASS' if s['bitwise_equal'] else 'FAIL'}")


if __name__ == "__main__":
    main()

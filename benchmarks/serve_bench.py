"""Streaming-service throughput bench (the PR-9 trajectory entry).

Three phases through one :class:`repro.serve.TwinService` shape:

  * **cold** — N tenants stream W windows each through a fresh service;
    the wall clock includes the single ``fleet_step_masked`` compile;
  * **warm** — a second service with fresh tenants, same shapes: the
    steady-state serving rate (tenant-windows/s) with zero recompiles;
  * **replay** — a third service serves one tenant group, then an
    identical-seed group: the second group rides the result cache, so the
    phase measures the cache path's rate and hit ratio.

The compile count across ALL phases is the gated invariant (ONE program,
asserted here and schema-checked by ``tools/check_bench.py``); wall-clock
numbers are machine-dependent reference points.

    PYTHONPATH=src python benchmarks/serve_bench.py
"""

from __future__ import annotations

import time

import jax

from repro.core.state import TwinConfig
from repro.core.twin import fleet_step_masked
from repro.serve import ServeConfig, SyntheticProducer, TwinService
from repro.traces.schema import DatacenterConfig

HOSTS = 16
BINS = 36
LANES = 32
TENANTS = 32
WINDOWS = 8


def _config() -> ServeConfig:
    return ServeConfig(
        twin=TwinConfig(bins_per_window=BINS,
                        dc=DatacenterConfig(num_hosts=HOSTS,
                                            cores_per_host=16)),
        lanes=LANES, queue_capacity=4 * TENANTS * WINDOWS)


def _stream(svc: TwinService, prefix: str, n: int, seed0: int) -> float:
    """Admit n tenants + producers, serve to idle; returns wall seconds."""
    for i in range(n):
        t = f"{prefix}{i}"
        svc.admit(t)
        svc.attach(SyntheticProducer(
            t, hosts=HOSTS, bins_per_window=BINS, num_windows=WINDOWS,
            seed=seed0 + i, util_mean=0.3 + 0.02 * (i % 10)))
    t0 = time.time()
    results = svc.run_until_idle()
    wall = time.time() - t0
    assert len(results) == n * WINDOWS, "service dropped windows"
    return wall


def run() -> dict:
    jax.clear_caches()

    svc_cold = TwinService(_config())
    cold_s = _stream(svc_cold, "cold-", TENANTS, seed0=0)

    svc_warm = TwinService(_config())
    warm_s = _stream(svc_warm, "warm-", TENANTS, seed0=1000)

    svc_replay = TwinService(_config())
    _stream(svc_replay, "orig-", TENANTS // 2, seed0=2000)
    replay_s = _stream(svc_replay, "dup-", TENANTS // 2, seed0=2000)

    size = fleet_step_masked._cache_size
    compiles = size() if callable(size) else None
    if compiles is not None:
        # the acceptance gate: three services, three arrival patterns,
        # cache hits and all — ONE compiled fleet program.
        assert compiles == 1, f"serving compiled {compiles}x, want 1"

    return {
        "tenants": TENANTS,
        "windows_per_tenant": WINDOWS,
        "lanes": LANES,
        "hosts": HOSTS,
        "bins_per_window": BINS,
        "compiles": compiles,
        "cold_s": cold_s,
        "warm_s": warm_s,
        "replay_s": replay_s,
        "tenants_per_s_warm": TENANTS / warm_s,
        "windows_per_s_warm": TENANTS * WINDOWS / warm_s,
        "batch_fill_ratio": svc_warm.stats.fill_ratio,
        "cache_hit_rate": svc_replay.cache.hit_rate,
        "replay_windows_cached": svc_replay.stats.windows_cached,
    }


def main() -> None:
    r = run()
    print(f"streaming twin service: {r['tenants']} tenants x "
          f"{r['windows_per_tenant']} windows on {r['lanes']} lanes "
          f"({r['hosts']} hosts, {r['bins_per_window']} bins)")
    if r["compiles"] is not None:
        print(f"  compiled fleet programs: {r['compiles']} (PASS: one "
              "program across cold/warm/replay, asserted)")
    print(f"  cold (incl. compile): {r['cold_s']:7.2f} s")
    print(f"  warm:                 {r['warm_s']:7.2f} s -> "
          f"{r['windows_per_s_warm']:.1f} windows/s "
          f"({r['tenants_per_s_warm']:.1f} tenants/s)")
    print(f"  batch fill ratio (warm): {r['batch_fill_ratio']:.0%}")
    print(f"  replay of an identical tenant group: {r['replay_s']:7.2f} s, "
          f"{r['replay_windows_cached']} windows from cache "
          f"(hit rate {r['cache_hit_rate']:.0%})")


if __name__ == "__main__":
    main()

"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract), then a
human-readable summary per experiment.

  E1  (Fig. 4/5)  reproduce FootPrinter + extend with perf/efficiency
  E2  (Fig. 6)    self-calibration accuracy vs static simulation
  NFR2 (§3.1)     7 days twinned under 1 hour
  roofline        dry-run-derived roofline table (results/dryrun)
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import e1_footprinter  # noqa: E402
import m3sa_metamodel  # noqa: E402
import e2_calibration  # noqa: E402
import nfr2_speed  # noqa: E402
import roofline  # noqa: E402


def main() -> None:
    rows: list[tuple[str, float, str]] = []

    e1 = e1_footprinter.run()
    rows.append((
        "e1_footprinter_reproduce",
        e1["wall_seconds"] * 1e6,
        f"fp_mape={e1['footprinter_mape']:.2f}%"
        f";opendt_mape={e1['opendt_mape']:.2f}%"
        f";paper=7.86%/5.13%"
        f";mean_util={e1['mean_utilization']:.3f}"
        f";best_eff={e1['best_efficiency_tflops_per_kwh']:.1f}TFLOPs/kWh",
    ))

    e2 = e2_calibration.run()
    rows.append((
        "e2_self_calibration",
        e2["wall_seconds"] * 1e6,
        f"uncal={e2['uncalibrated_mape']:.2f}%"
        f";cal={e2['calibrated_mape']:.2f}%"
        f";joint={e2['joint_calibrated_mape']:.2f}%"
        f";paper=5.13%/4.39%"
        f";nfr1_cal={e2['nfr1_calibrated']['compliance']:.2f}"
        f";nfr1_unc={e2['nfr1_uncalibrated']['compliance']:.2f}",
    ))

    n2 = nfr2_speed.run()
    rows.append((
        "nfr2_twin_speed",
        n2["closed_loop_wall_s"] * 1e6,
        f"7days_in={n2['closed_loop_wall_s']:.1f}s"
        f";paper=2760s;speedup={n2['speedup_vs_paper']:.0f}x"
        f";des_days_per_s={n2['sim_days_per_wall_second']:.1f}",
    ))
    rows.append((
        "calibration_grid",
        n2["calibration_window_s"] * 1e6,
        f"candidates_per_s={n2['calibration_candidates_per_s']:.0f}",
    ))

    m3 = m3sa_metamodel.run()
    rows.append((
        "m3sa_multi_model",
        0.0,
        f"opendc={m3['model_opendc_mape']:.2f}%"
        f";linear={m3['model_linear_mape']:.2f}%"
        f";weighted_meta={m3['meta_weighted_mape']:.2f}%"
        f";weights={m3['weights']}",
    ))

    cells = roofline.load_cells()
    summ = roofline.summarize(cells)
    rows.append((
        "dryrun_roofline",
        0.0,
        f"ok={summ['cells_ok']};skipped={summ['cells_skipped']}"
        f";errors={summ['cells_error']}"
        f";dominant={summ['dominant_counts']}",
    ))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    print("\n=== E1 (paper Fig. 4/5) ===")
    print(json.dumps(e1, indent=2))
    print("\n=== E2 (paper Fig. 6) ===")
    print(json.dumps({k: v for k, v in e2.items()
                      if not k.startswith("per_window")}, indent=2))
    print("\n=== Multi-model / Meta-Model (paper §2.2, M3SA) ===")
    print(json.dumps(m3, indent=2))
    print("\n=== NFR2 ===")
    print(json.dumps(n2, indent=2))
    print("\n=== Roofline (results/dryrun) ===")
    print(roofline.table(cells))
    print(json.dumps(summ, indent=2))


if __name__ == "__main__":
    main()

"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract), then a
human-readable summary per experiment.

  E1  (Fig. 4/5)  reproduce FootPrinter + extend with perf/efficiency
  E2  (Fig. 6)    self-calibration accuracy vs static simulation
  NFR2 (§3.1)     7 days twinned under 1 hour
  roofline        dry-run-derived roofline table (results/dryrun)
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import des_readout_bench  # noqa: E402
import e1_footprinter  # noqa: E402
import m3sa_metamodel  # noqa: E402
import e2_calibration  # noqa: E402
import fleet_bench  # noqa: E402
import nfr2_speed  # noqa: E402
import roofline  # noqa: E402
import serve_bench  # noqa: E402
import whatif_batch  # noqa: E402

#: committed what-if/scenario-engine performance snapshot (regenerate with
#: ``PYTHONPATH=src python benchmarks/run.py whatif``)
BENCH_WHATIF = os.path.join(os.path.dirname(__file__), "BENCH_whatif.json")

#: committed DES readout-kernel performance snapshot (regenerate with
#: ``PYTHONPATH=src python benchmarks/run.py des``)
BENCH_DES = os.path.join(os.path.dirname(__file__), "BENCH_des.json")

#: committed streaming-service performance snapshot (regenerate with
#: ``PYTHONPATH=src python benchmarks/run.py serve``)
BENCH_SERVE = os.path.join(os.path.dirname(__file__), "BENCH_serve.json")

#: committed fleet-axis engine snapshot (regenerate with
#: ``PYTHONPATH=src python benchmarks/run.py fleet``)
BENCH_FLEET = os.path.join(os.path.dirname(__file__), "BENCH_fleet.json")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint_findings() -> int:
    """Standing tracecheck debt, recorded in snapshot provenance.

    Counts every post-suppression finding a fresh ``python -m tools.lint``
    run reports (baselined or new), so the perf trajectory also shows the
    contract-debt trend (tools/check_bench.py --compare prints the drift).
    """
    if _REPO_ROOT not in sys.path:
        sys.path.insert(0, _REPO_ROOT)
    from tools.lint.engine import DEFAULT_BASELINE, load_baseline, run_lint
    entries = (load_baseline(DEFAULT_BASELINE)
               if DEFAULT_BASELINE.exists() else [])
    res = run_lint(["src", "tests", "benchmarks", "tools"],
                   baseline_entries=entries)
    return len(res.findings)


def whatif_snapshot(days: float = 0.5) -> dict:
    """Write the scenario-engine performance snapshot to BENCH_whatif.json.

    Captures the steady-state numbers the what-if refactors are judged by:
    optimizer warm candidates/s (single compiled evaluator, asserted inside
    :func:`whatif_batch.run_optimizer`), the mixed new-axes grid's compile
    count (failure x PUE x price x cap — one program, asserted), mean
    closed-loop window-step seconds, and the DES hot-path scan/readout wall
    split that :mod:`analysis.roofline` prices against the hardware.

    Wall-clock numbers are machine-dependent — the committed snapshot is a
    reference point (backend/device count recorded alongside), not a gate;
    the compile counts are the invariants.
    """
    import jax

    from repro.core import run_surf_experiment
    from repro.traces.schema import DatacenterConfig
    from repro.traces.surf import BINS_PER_DAY, SurfTraceSpec, make_surf22_like

    opt = whatif_batch.run_optimizer(days=days)
    axes = whatif_batch.run_new_axes_grid(days=days)
    hot = nfr2_speed.des_hot_path()

    # mean window-step seconds: a 1-day calibrated closed loop, per-window
    # fused twin_step timings from the orchestrator's own records.
    dc = DatacenterConfig()
    w = make_surf22_like(SurfTraceSpec(days=1.0), dc)
    res = run_surf_experiment(w, dc, int(1.0 * BINS_PER_DAY), calibrate=True)
    steps = [r.sim_seconds for r in res.records]

    snap = {
        "regenerate_with": "PYTHONPATH=src python benchmarks/run.py whatif",
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "lint_findings": lint_findings(),
        "optimizer": {
            "days": days,
            "candidates": opt["candidates"],
            "compiles": opt["compiles"],
            "warm_s": opt["warm_s"],
            "warm_candidates_per_s": opt["cand_per_s_warm"],
        },
        "new_axes_grid": axes,
        "window_step": {
            "windows": len(steps),
            "mean_seconds": float(np_mean(steps)),
            "max_seconds": float(max(steps)) if steps else None,
        },
        "des_hot_path": hot,
    }
    with open(BENCH_WHATIF, "w") as f:
        json.dump(snap, f, indent=2)
        f.write("\n")
    return snap


def des_snapshot(days: float = 0.5) -> dict:
    """Write the DES readout-kernel performance snapshot to BENCH_des.json.

    The PR-7 trajectory entry (ROADMAP open item 2): the DES hot path's
    scan/readout wall split, the readout microbench (legacy unfused vs
    fused-XLA vs Pallas, the latter interpret-mode on CPU and recorded as
    such), the end-to-end engine sweep on both readout paths, and the
    donated optimizer's warm candidates/s.  The compile counts are the
    gated invariants (``tools/check_bench.py --compare``); wall-clock
    numbers are machine-dependent reference points with the backend and
    device count recorded alongside.
    """
    import jax

    d = des_readout_bench.run(days=days)
    snap = {
        "regenerate_with": "PYTHONPATH=src python benchmarks/run.py des",
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "lint_findings": lint_findings(),
        **d,
    }
    with open(BENCH_DES, "w") as f:
        json.dump(snap, f, indent=2)
        f.write("\n")
    return snap


def serve_snapshot() -> dict:
    """Write the streaming-service performance snapshot to BENCH_serve.json.

    The PR-9 trajectory entry (ROADMAP open item 1): warm serving rate
    (tenants/s and tenant-windows/s through ``TwinService``), batch fill
    ratio, the replay phase's cache hit rate, and the gated invariant —
    cold/warm/replay services all riding ONE compiled
    ``fleet_step_masked`` program.  Wall-clock numbers are
    machine-dependent reference points; the compile count is the gate.
    """
    import jax

    snap = {
        "regenerate_with": "PYTHONPATH=src python benchmarks/run.py serve",
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "lint_findings": lint_findings(),
        "serve": serve_bench.run(),
    }
    with open(BENCH_SERVE, "w") as f:
        json.dump(snap, f, indent=2)
        f.write("\n")
    return snap


def fleet_snapshot() -> dict:
    """Write the fleet-axis engine snapshot to BENCH_fleet.json.

    The ROADMAP item-5 trajectory entry: warm window-step seconds on the
    vmap and sharded ``run_fleet`` paths, the per-path compile counts
    (ONE program each, warm re-run included — asserted in
    :mod:`fleet_bench` and schema-checked by ``tools/check_bench.py``),
    the sharded-vs-vmap bitwise cross-check, and lanes/device on this
    machine's mesh.  Wall clocks are machine-dependent reference points.
    """
    import jax

    snap = {
        "regenerate_with": "PYTHONPATH=src python benchmarks/run.py fleet",
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "lint_findings": lint_findings(),
        "fleet": fleet_bench.run(),
    }
    with open(BENCH_FLEET, "w") as f:
        json.dump(snap, f, indent=2)
        f.write("\n")
    return snap


def np_mean(xs: list) -> float:
    return sum(xs) / len(xs) if xs else float("nan")


def main() -> None:
    rows: list[tuple[str, float, str]] = []

    e1 = e1_footprinter.run()
    rows.append((
        "e1_footprinter_reproduce",
        e1["wall_seconds"] * 1e6,
        f"fp_mape={e1['footprinter_mape']:.2f}%"
        f";opendt_mape={e1['opendt_mape']:.2f}%"
        f";paper=7.86%/5.13%"
        f";mean_util={e1['mean_utilization']:.3f}"
        f";best_eff={e1['best_efficiency_tflops_per_kwh']:.1f}TFLOPs/kWh",
    ))

    e2 = e2_calibration.run()
    rows.append((
        "e2_self_calibration",
        e2["wall_seconds"] * 1e6,
        f"uncal={e2['uncalibrated_mape']:.2f}%"
        f";cal={e2['calibrated_mape']:.2f}%"
        f";joint={e2['joint_calibrated_mape']:.2f}%"
        f";paper=5.13%/4.39%"
        f";nfr1_cal={e2['nfr1_calibrated']['compliance']:.2f}"
        f";nfr1_unc={e2['nfr1_uncalibrated']['compliance']:.2f}",
    ))

    n2 = nfr2_speed.run()
    rows.append((
        "nfr2_twin_speed",
        n2["closed_loop_wall_s"] * 1e6,
        f"7days_in={n2['closed_loop_wall_s']:.1f}s"
        f";paper=2760s;speedup={n2['speedup_vs_paper']:.0f}x"
        f";des_days_per_s={n2['sim_days_per_wall_second']:.1f}",
    ))
    rows.append((
        "calibration_grid",
        n2["calibration_window_s"] * 1e6,
        f"candidates_per_s={n2['calibration_candidates_per_s']:.0f}",
    ))

    m3 = m3sa_metamodel.run()
    rows.append((
        "m3sa_multi_model",
        0.0,
        f"opendc={m3['model_opendc_mape']:.2f}%"
        f";linear={m3['model_linear_mape']:.2f}%"
        f";weighted_meta={m3['meta_weighted_mape']:.2f}%"
        f";weights={m3['weights']}",
    ))

    wi = whatif_snapshot()
    rows.append((
        "whatif_snapshot",
        wi["window_step"]["mean_seconds"] * 1e6,
        f"cand_per_s={wi['optimizer']['warm_candidates_per_s']:.1f}"
        f";opt_compiles={wi['optimizer']['compiles']}"
        f";axes_compiles={wi['new_axes_grid']['compiles']}"
        f";scan_frac={wi['des_hot_path']['scan_fraction']:.2f}",
    ))

    de = des_snapshot()
    rows.append((
        "des_snapshot",
        de["readout_microbench"]["fused_xla_s"] * 1e6,
        f"fused_vs_legacy="
        f"{de['readout_microbench']['fused_vs_legacy_speedup']:.2f}x"
        f";pallas_mode={de['readout_microbench']['pallas_mode']}"
        f";sweep_compiles={de['engine_sweep']['pallas_compiles']}"
        f";cand_per_s={de['optimizer']['cand_per_s_warm']:.1f}",
    ))

    sv = serve_snapshot()
    rows.append((
        "serve_snapshot",
        sv["serve"]["warm_s"] * 1e6,
        f"windows_per_s={sv['serve']['windows_per_s_warm']:.1f}"
        f";fill={sv['serve']['batch_fill_ratio']:.2f}"
        f";cache_hit_rate={sv['serve']['cache_hit_rate']:.2f}"
        f";compiles={sv['serve']['compiles']}",
    ))

    fl = fleet_snapshot()
    rows.append((
        "fleet_snapshot",
        fl["fleet"]["sharded_window_step_s"] * 1e6,
        f"vmap_ms_per_window={fl['fleet']['vmap_window_step_s'] * 1e3:.1f}"
        f";sharded_ms_per_window="
        f"{fl['fleet']['sharded_window_step_s'] * 1e3:.1f}"
        f";lanes_per_device={fl['fleet']['lanes_per_device']}"
        f";compiles={fl['fleet']['vmap_compiles']}"
        f"+{fl['fleet']['sharded_compiles']}"
        f";bitwise={fl['fleet']['sharded_bitwise_equal']}",
    ))

    cells = roofline.load_cells()
    summ = roofline.summarize(cells)
    rows.append((
        "dryrun_roofline",
        0.0,
        f"ok={summ['cells_ok']};skipped={summ['cells_skipped']}"
        f";errors={summ['cells_error']}"
        f";dominant={summ['dominant_counts']}",
    ))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    print("\n=== E1 (paper Fig. 4/5) ===")
    print(json.dumps(e1, indent=2))
    print("\n=== E2 (paper Fig. 6) ===")
    print(json.dumps({k: v for k, v in e2.items()
                      if not k.startswith("per_window")}, indent=2))
    print("\n=== Multi-model / Meta-Model (paper §2.2, M3SA) ===")
    print(json.dumps(m3, indent=2))
    print("\n=== NFR2 ===")
    print(json.dumps(n2, indent=2))
    print("\n=== Roofline (results/dryrun) ===")
    print(roofline.table(cells))
    print(json.dumps(summ, indent=2))
    print(f"\n=== What-if snapshot (written to {BENCH_WHATIF}) ===")
    print(json.dumps(wi, indent=2))
    print(f"\n=== DES readout snapshot (written to {BENCH_DES}) ===")
    print(json.dumps(de, indent=2))
    print(f"\n=== Streaming-service snapshot (written to {BENCH_SERVE}) ===")
    print(json.dumps(sv, indent=2))
    print(f"\n=== Fleet-axis snapshot (written to {BENCH_FLEET}) ===")
    print(json.dumps(fl, indent=2))


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "whatif":
        print(json.dumps(whatif_snapshot(), indent=2))
    elif len(sys.argv) > 1 and sys.argv[1] == "des":
        print(json.dumps(des_snapshot(), indent=2))
    elif len(sys.argv) > 1 and sys.argv[1] == "serve":
        print(json.dumps(serve_snapshot(), indent=2))
    elif len(sys.argv) > 1 and sys.argv[1] == "fleet":
        print(json.dumps(fleet_snapshot(), indent=2))
    else:
        main()

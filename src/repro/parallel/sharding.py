"""Logical-axis sharding rules -> PartitionSpecs (MaxText-style).

Every parameter and activation carries a tuple of *logical* axis names; a
rule table per execution mode maps logical axes onto mesh axes:

  train:  DP over 'pod', FSDP (ZeRO-3) over 'data', TP over 'model'
  serve:  replicas over ('pod','data'), TP over 'model'  (weight-stationary)

A logical axis mapping to a mesh axis is dropped (replicated) when the axis
size does not divide the mesh axis — e.g. kv_heads=8 on a 16-way model axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

LogicalAxes = tuple[str | None, ...]


def make_mesh_compat(shape, axes, *, devices=None) -> Mesh:
    """``jax.make_mesh`` across jax versions.

    Newer jax grew an ``axis_types`` kwarg (and ``jax.sharding.AxisType``);
    older releases (<= 0.4.x) have neither.  Explicit-Auto is the default
    everywhere, so omitting it on old jax is behavior-identical.
    """
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(shape)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def abstract_mesh_compat(shape, axes):
    """``jax.sharding.AbstractMesh`` across jax versions.

    New API: ``AbstractMesh(shape, axis_names)``; 0.4.x API:
    ``AbstractMesh(tuple of (name, size) pairs)``.
    """
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))

#: mode -> logical axis -> mesh axis (or tuple of mesh axes)
RULES: dict[str, dict[str, Any]] = {
    "train": {
        "batch": ("pod", "data"),
        "seq": None,
        "embed": "data",        # ZeRO-3: shard the replicated dim over data
        "embed_nofsdp": None,
        "heads": "model",
        "kv_heads": "model",
        "qk": None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "moe_ff": None,
        "lora": None,
        "dstate": None,
        "conv": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "attn_q_seq": "model",   # context-parallel fallback for attention
        "frames": None,
        "patches": None,
        "cache_seq": None,
        "cache_heads": "model",
    },
    "serve": {
        "batch": ("pod", "data"),
        "seq": None,
        "embed": None,          # weight-stationary TP: no FSDP gather latency
        "embed_nofsdp": None,
        "heads": "model",
        "kv_heads": "model",
        "qk": None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "moe_ff": None,
        "lora": None,
        "dstate": None,
        "conv": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "attn_q_seq": "model",   # context-parallel fallback for attention
        "frames": None,
        "patches": None,
        "cache_seq": "model",
        "cache_heads": "model",
    },
}


def mesh_axis_size(mesh: Mesh, axis: Any) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def logical_to_spec(
    axes: LogicalAxes,
    shape: tuple[int, ...],
    mesh: Mesh,
    mode: str = "train",
) -> P:
    """Map logical axes to a PartitionSpec, dropping non-divisible shardings."""
    rules = RULES[mode]
    used: set[str] = set()
    parts: list[Any] = []
    for dim, name in zip(shape, axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is None:
            parts.append(None)
            continue
        flat = mesh_axis if isinstance(mesh_axis, tuple) else (mesh_axis,)
        # keep only non-trivial axes present in this mesh, not yet consumed
        flat = tuple(a for a in flat
                     if a in mesh.shape and mesh.shape[a] > 1
                     and a not in used)
        if not flat:
            parts.append(None)
            continue
        if dim % mesh_axis_size(mesh, flat) != 0:
            parts.append(None)          # non-divisible -> replicate
            continue
        used.update(flat)
        parts.append(flat if len(flat) > 1 else flat[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def tree_shardings(
    axes_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    mode: str = "train",
) -> Any:
    """NamedShardings for a pytree of (axes, shapes)."""

    def one(axes: LogicalAxes, shaped) -> NamedSharding:
        spec = logical_to_spec(axes, tuple(shaped.shape), mesh, mode)
        return NamedSharding(mesh, spec)

    return jax.tree.map(
        one, axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def constraint(x: jax.Array, axes: LogicalAxes, mesh: Mesh | None,
               mode: str = "train") -> jax.Array:
    """with_sharding_constraint via logical axes (no-op without a mesh)."""
    if mesh is None or mesh.empty:
        return x
    spec = logical_to_spec(axes, tuple(x.shape), mesh, mode)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    """Threaded through model code so layers can place activations."""

    mesh: Mesh | None = None
    mode: str = "train"

    def on(self, x: jax.Array, *axes: str | None) -> jax.Array:
        return constraint(x, tuple(axes), self.mesh, self.mode)


# -- ambient context -----------------------------------------------------------
# Step factories bind the ShardingCtx here at trace time so deep layers
# (attention inner scans, SSD chunk scans) can pin activation shardings
# without threading ctx through every call signature.

import contextlib as _contextlib
import contextvars as _contextvars

_AMBIENT: _contextvars.ContextVar[ShardingCtx] = _contextvars.ContextVar(
    "repro_sharding_ctx", default=ShardingCtx())


def current_ctx() -> ShardingCtx:
    return _AMBIENT.get()


@_contextlib.contextmanager
def use_ctx(ctx: ShardingCtx):
    tok = _AMBIENT.set(ctx)
    try:
        yield ctx
    finally:
        _AMBIENT.reset(tok)


def activation(x: jax.Array, *axes: str | None) -> jax.Array:
    """Constrain an activation under the ambient ShardingCtx (no-op on 1 dev)."""
    return _AMBIENT.get().on(x, *axes)

"""Checkpointing: msgpack + compressed columnar blobs, atomic publish, restore.

Saves the *whole job state*: model params, optimizer moments, data cursor,
rng, and the digital twin's state (calibrated power parameters + window
index) — after a restart the twin resumes calibrated, it does not relearn
from scratch.  Writes are atomic (tmp + rename) and keep a bounded history
so a crash mid-write can never destroy the latest good checkpoint.

Optional-dependency policy: compression goes through :mod:`repro.core.codec`
(zstd when ``zstandard`` is installed, stdlib zlib otherwise) — importing
this module must never fail on a missing compressor.  Every checkpoint file
starts with a one-byte codec id (``0x01`` zstd, ``0x02`` zlib) so a restore
in one environment opens checkpoints written in the other.
"""

from __future__ import annotations

import dataclasses
import os
import re
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core import codec

_CKPT_RE = re.compile(r"ckpt_(\d+)\.mpz$")


def _pack_tree(tree: Any) -> Any:
    """Pytree -> msgpack-able structure (arrays become dicts)."""
    def enc(x):
        if isinstance(x, (jax.Array, np.ndarray)):
            arr = np.asarray(x)
            return {"__nd__": True, "d": arr.tobytes(),
                    "t": str(arr.dtype), "s": list(arr.shape)}
        if isinstance(x, (int, float, str, bool, type(None))):
            return x
        raise TypeError(f"unsupported leaf {type(x)}")

    return jax.tree.map(enc, tree)


def _unpack_tree(obj: Any) -> Any:
    def dec(x):
        if isinstance(x, dict) and x.get("__nd__"):
            return np.frombuffer(x["d"], x["t"]).reshape(x["s"])
        return x

    return jax.tree.map(
        dec, obj, is_leaf=lambda x: isinstance(x, dict) and x.get("__nd__"))


def save(path_dir: str, step: int, state: Any, keep: int = 3) -> str:
    os.makedirs(path_dir, exist_ok=True)
    blob = codec.compress(
        msgpack.packb(_pack_tree(state), use_bin_type=True), level=3)
    final = os.path.join(path_dir, f"ckpt_{step:08d}.mpz")
    fd, tmp = tempfile.mkstemp(dir=path_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        f.write(blob)
    os.replace(tmp, final)                      # atomic publish
    _gc(path_dir, keep)
    return final


def latest_step(path_dir: str) -> int | None:
    if not os.path.isdir(path_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path_dir)
             if (m := _CKPT_RE.search(f))]
    return max(steps) if steps else None


def restore(path_dir: str, step: int | None = None) -> tuple[int, Any]:
    step = latest_step(path_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {path_dir}")
    path = os.path.join(path_dir, f"ckpt_{step:08d}.mpz")
    with open(path, "rb") as f:
        obj = msgpack.unpackb(
            codec.decompress(f.read()), raw=False, strict_map_key=False)
    return step, _unpack_tree(obj)


def restore_as_jax(path_dir: str, like: Any, step: int | None = None
                   ) -> tuple[int, Any]:
    """Restore and cast/shard to match a template pytree (shapes + dtypes +
    shardings) — the elastic-restart path re-shards here when the mesh
    changed between runs."""
    step, host = restore(path_dir, step)
    flat_h, _ = jax.tree.flatten(host)
    flat_l, tdef = jax.tree.flatten(like)
    assert len(flat_h) == len(flat_l), "checkpoint/template mismatch"
    out = []
    for h, l in zip(flat_h, flat_l):
        arr = jnp.asarray(np.asarray(h).astype(l.dtype))
        if hasattr(l, "sharding") and l.sharding is not None:
            arr = jax.device_put(arr, l.sharding)
        out.append(arr)
    return step, tdef.unflatten(out)


def _gc(path_dir: str, keep: int) -> None:
    steps = sorted(
        int(m.group(1)) for f in os.listdir(path_dir)
        if (m := _CKPT_RE.search(f)))
    for s in steps[:-keep]:
        os.unlink(os.path.join(path_dir, f"ckpt_{s:08d}.mpz"))

"""Deterministic synthetic LM token pipeline.

Seeded, restartable (cursor = step index), and shard-aware: every data shard
computes only its slice of the global batch from (seed, step, shard) — no
host-side shuffling state to checkpoint beyond the step counter, which is
exactly what restores after preemption (see repro.checkpoint).

The generator produces skewed-Zipf token streams with local n-gram structure
so training losses move (pure uniform tokens give a flat loss surface).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 17
    zipf_a: float = 1.2


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return (p / p.sum()).astype(np.float32)


class TokenPipeline:
    """Stateless-per-step batch synthesis: batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = jnp.asarray(_zipf_probs(cfg.vocab, cfg.zipf_a))
        self._logits = jnp.log(self._probs)

    def batch(self, step: int, shard: int = 0, num_shards: int = 1
              ) -> dict[str, Array]:
        """Global batch slice for ``shard``: tokens + next-token labels."""
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        local = cfg.global_batch // num_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), shard)
        k1, k2 = jax.random.split(key)
        toks = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits,
                                 (local, cfg.seq_len + 1, cfg.vocab)))
        # local bigram structure: with p=0.25 repeat the previous token + 1
        rep = jax.random.bernoulli(k2, 0.25, (local, cfg.seq_len + 1))
        shifted = jnp.roll(toks, 1, axis=1) + 1
        toks = jnp.where(rep, shifted % cfg.vocab, toks).astype(jnp.int32)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }

    def global_batch(self, step: int) -> dict[str, Array]:
        return self.batch(step, shard=0, num_shards=1)

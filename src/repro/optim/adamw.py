"""AdamW (decoupled weight decay) + cosine schedule + global-norm clipping.

Self-contained (no optax in this container).  Optimizer state mirrors the
parameter pytree, so the FSDP shardings derived from ParamSpecs apply to the
moments unchanged (ZeRO-style: moments shard exactly like their parameter).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: Array
    mu: Any          # first moments  (pytree like params)
    nu: Any          # second moments


def init_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def abstract_opt_state(params: Any, cfg: AdamWConfig) -> OptState:
    """ShapeDtypeStruct mirror — for the dry-run."""
    dt = jnp.dtype(cfg.moment_dtype)
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(sds, params),
        nu=jax.tree.map(sds, params),
    )


def schedule(step: Array, cfg: AdamWConfig) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params: Any, grads: Any, state: OptState,
                  cfg: AdamWConfig) -> tuple[Any, OptState, dict[str, Array]]:
    """One AdamW step.  Returns (params', state', metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(step, cfg)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m.astype(jnp.dtype(cfg.moment_dtype)), \
            v.astype(jnp.dtype(cfg.moment_dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr,
    }

"""Parameter-spec system and common layers.

A model is declared as a pytree of ParamSpec (shape + logical axes + init).
From the single spec tree we derive, without duplication:
  * materialized parameters           (init_params)
  * ShapeDtypeStructs for the dry-run (abstract_params — never allocates)
  * NamedShardings                     (specs_to_shardings)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.parallel.sharding import logical_to_spec

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float = 1.0          # multiplier on the fan-in init
    dtype: str | None = None    # None = model dtype (caches may pin f32)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: Any, key: Array, dtype: jnp.dtype) -> Any:
    """Materialize a spec tree into parameters (host-splittable rng)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(spec: ParamSpec, k: Array) -> Array:
        dt = jnp.dtype(spec.dtype) if spec.dtype else dtype
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        if spec.init == "embed":
            return (jax.random.normal(k, spec.shape, jnp.float32)
                    * spec.scale).astype(dt)
        # fan-in scaled normal over the last contraction dim
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dt)

    return treedef.unflatten([one(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs: Any, dtype: jnp.dtype) -> Any:
    """ShapeDtypeStructs — for .lower() in the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype) if s.dtype else dtype),
        specs, is_leaf=_is_spec,
    )


def axes_tree(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def specs_to_shardings(specs: Any, mesh: Mesh, mode: str) -> Any:
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, logical_to_spec(s.axes, s.shape, mesh, mode)),
        specs, is_leaf=_is_spec,
    )


def spec_param_count(specs: Any) -> int:
    return sum(int(math.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))


# -- layers -------------------------------------------------------------------


def rms_norm(x: Array, gamma: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * gamma + beta


def swiglu(gate: Array, up: Array) -> Array:
    return jax.nn.silu(gate) * up


def dense(x: Array, w: Array) -> Array:
    """x [..., d_in] @ w [d_in, ...out] with f32 accumulation."""
    out_dims = w.ndim - 1
    return jax.lax.dot_general(
        x, w,
        ((tuple(range(x.ndim - 1, x.ndim)), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype) if out_dims == 1 else _dense_multi(x, w)


def _dense_multi(x: Array, w: Array) -> Array:
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def cross_entropy(logits: Array, labels: Array, ignore: int = -100
                  ) -> tuple[Array, Array]:
    """Mean CE over non-ignored labels.  Returns (loss, token_count)."""
    mask = labels != ignore
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), safe[..., None], axis=-1)[..., 0]
    nll = (logz - picked) * mask
    n = jnp.maximum(mask.sum(), 1)
    return nll.sum() / n, n

"""Encoder-decoder backbone (Seamless-M4T medium, [arXiv:2308.11596]).

The modality frontend (speech encoder frontend / text tokenizer) is a STUB:
``input_specs()`` supplies precomputed frame embeddings [B, F, d] — per the
assignment, only the transformer backbone is modeled.  The encoder is
bidirectional; the decoder is causal with cross-attention.  RoPE replaces
Seamless' relative position bias (TPU-friendlier; recorded in DESIGN.md).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import chunked_attention, decode_attention
from repro.models.blocks import attn_specs, dense_ffn, ffn_specs, gqa_decode
from repro.models.common import ParamSpec, dense, rms_norm
from repro.models.lm import KV_CHUNK, _remat
from repro.models.rope import apply_rope
from repro.parallel.sharding import ShardingCtx

Array = jax.Array


def encdec_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    enc: dict[str, ParamSpec] = {
        "ln1": ParamSpec((cfg.enc_layers, d), (None, None), init="ones"),
        "ln2": ParamSpec((cfg.enc_layers, d), (None, None), init="ones"),
    }
    enc.update(attn_specs(cfg, cfg.enc_layers))
    enc.update(ffn_specs(cfg, cfg.enc_layers))

    dec: dict[str, ParamSpec] = {
        "ln1": ParamSpec((cfg.dec_layers, d), (None, None), init="ones"),
        "ln_x": ParamSpec((cfg.dec_layers, d), (None, None), init="ones"),
        "ln2": ParamSpec((cfg.dec_layers, d), (None, None), init="ones"),
    }
    dec.update(attn_specs(cfg, cfg.dec_layers))
    dec.update(attn_specs(cfg, cfg.dec_layers, prefix="x_"))
    dec.update(ffn_specs(cfg, cfg.dec_layers))

    return {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), init="embed",
                           scale=0.02),
        "enc_norm": ParamSpec((d,), (None,), init="ones"),
        "final_norm": ParamSpec((d,), (None,), init="ones"),
        "unembed": ParamSpec((d, cfg.vocab), ("embed", "vocab")),
        "encoder": enc,
        "decoder": dec,
    }


def _self_attn(cfg: ModelConfig, p, x, positions, causal, prefix=""):
    q = dense(x, p[f"{prefix}wq"])
    k = dense(x, p[f"{prefix}wk"])
    v = dense(x, p[f"{prefix}wv"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=causal, kv_chunk=KV_CHUNK)
    return jnp.einsum("bshd,hdq->bsq", out, p[f"{prefix}wo"]).astype(x.dtype)


def _cross_attn(cfg: ModelConfig, p, x, enc_out):
    q = dense(x, p["x_wq"])
    k = dense(enc_out, p["x_wk"])
    v = dense(enc_out, p["x_wv"])
    out = chunked_attention(q, k, v, causal=False, kv_chunk=KV_CHUNK)
    return jnp.einsum("bshd,hdq->bsq", out, p["x_wo"]).astype(x.dtype)


def encode(cfg: ModelConfig, params, frames: Array,
           ctx: ShardingCtx = ShardingCtx()) -> Array:
    """frames [B, F, d] (stub frontend embeddings) -> [B, F, d]."""
    b, f, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(f, dtype=jnp.int32), (b, f))

    def body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + _self_attn(cfg, lp, h, positions, causal=False)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + dense_ffn(lp, cfg, h2), None

    x, _ = jax.lax.scan(_remat(body, cfg), frames, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(cfg: ModelConfig, params, tokens: Array, enc_out: Array,
                 ctx: ShardingCtx = ShardingCtx()) -> Array:
    """Teacher-forced decoder.  tokens [B, S] -> hidden [B, S, d]."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        x = carry
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + _self_attn(cfg, lp, h, positions, causal=True)
        hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        x = x + _cross_attn(cfg, lp, hx, enc_out)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + dense_ffn(lp, cfg, h2), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["decoder"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def encdec_loss(cfg: ModelConfig, params, batch,
                ctx: ShardingCtx = ShardingCtx()
                ) -> tuple[Array, dict[str, Array]]:
    from repro.models.lm import chunked_ce

    enc_out = encode(cfg, params, batch["frames"], ctx)
    x = decode_train(cfg, params, batch["tokens"], enc_out, ctx)
    loss, tok = chunked_ce(cfg, x, params["unembed"], batch["labels"])
    return loss, {"ce": loss, "moe_aux": jnp.zeros((), jnp.float32),
                  "tokens": tok}


def encdec_state_specs(cfg: ModelConfig, batch: int, seq: int
                       ) -> dict[str, Any]:
    """Self-attn cache + precomputed cross K/V (encoder ran at prefill)."""
    kv, hd, L = cfg.n_kv_heads, cfg.head_dim, cfg.dec_layers
    f = cfg.num_frames
    c = ("batch", "cache_seq", "cache_heads", None)
    return {
        "self": {
            "k": ParamSpec((L, batch, seq, kv, hd), (None,) + c, init="zeros"),
            "v": ParamSpec((L, batch, seq, kv, hd), (None,) + c, init="zeros"),
        },
        "cross": {
            "k": ParamSpec((L, batch, f, kv, hd),
                           (None, "batch", None, "cache_heads", None),
                           init="zeros"),
            "v": ParamSpec((L, batch, f, kv, hd),
                           (None, "batch", None, "cache_heads", None),
                           init="zeros"),
        },
    }


def encdec_decode_step(cfg: ModelConfig, params, state, batch,
                       ctx: ShardingCtx = ShardingCtx()
                       ) -> tuple[Array, dict[str, Any]]:
    """One decoder token against self cache + fixed cross K/V."""
    x = jnp.take(params["embed"], batch["token"], axis=0)   # [B,1,d]
    cache_len = batch.get("cache_len")
    positions = (batch.get("positions") if batch.get("positions") is not None
                 else cache_len[:, None])

    def body(carry, inp):
        lp, self_c, cross_k, cross_v = inp
        x = carry
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        attn, self_c = gqa_decode(lp, cfg, h, self_c, positions, cache_len)
        x = x + attn
        hx = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        q = dense(hx, lp["x_wq"])
        out = decode_attention(q, cross_k, cross_v)
        x = x + jnp.einsum("bshd,hdq->bsq", out, lp["x_wo"]).astype(x.dtype)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + dense_ffn(lp, cfg, h2), self_c

    x, new_self = jax.lax.scan(
        body, x,
        (params["decoder"], state["self"], state["cross"]["k"],
         state["cross"]["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense(x[:, 0], params["unembed"])
    return logits, {"self": new_self, "cross": state["cross"]}

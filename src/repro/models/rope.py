"""Rotary position embeddings: standard, partial (StableLM) and M-RoPE
(Qwen2-VL: separate temporal/height/width sections of the head dim)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_freqs(dim: int, theta: float) -> Array:
    """[dim/2] inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: Array, positions: Array, theta: float,
               fraction: float = 1.0) -> Array:
    """Rotate the first ``fraction`` of the head dim.

    x: [B, S, H, D]; positions: [B, S] int32.
    """
    b, s, h, d = x.shape
    rot = int(d * fraction) // 2 * 2
    if rot == 0:
        return x
    inv = rope_freqs(rot, theta)                       # [rot/2]
    ang = positions.astype(jnp.float32)[..., None] * inv   # [B, S, rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def apply_mrope(x: Array, positions: Array, theta: float,
                sections: tuple[int, int, int]) -> Array:
    """Multimodal RoPE (Qwen2-VL).

    positions: [3, B, S] — temporal/height/width position ids.  The rotary
    half-dim is partitioned into ``sections`` (t, h, w); each section's
    angles use the corresponding position stream.
    """
    b, s, h, d = x.shape
    half = d // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_freqs(d, theta)                         # [half]
    # select per-frequency position stream by section
    sec_ids = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=half)  # [half]
    pos = positions.astype(jnp.float32)                # [3, B, S]
    pos_sel = jnp.take(pos, sec_ids, axis=0)           # [half, B, S]
    ang = jnp.einsum("fbs,f->bsf", pos_sel, inv)       # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)

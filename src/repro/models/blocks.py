"""Transformer blocks: GQA attention (+cache decode), dense/parallel FFN.

Layout conventions: activations [B, S, d]; caches [B, T, KV, hd];
stacked layer params carry a leading L dim and are scanned.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import chunked_attention, decode_attention
from repro.models.common import ParamSpec, dense, rms_norm, swiglu
from repro.models.rope import apply_mrope, apply_rope
from repro.parallel.sharding import activation

Array = jax.Array


def attn_specs(cfg: ModelConfig, L: int, prefix: str = "") -> dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        f"{prefix}wq": ParamSpec((L, d, h, hd), (None, "embed", "heads", "qk")),
        f"{prefix}wk": ParamSpec((L, d, kv, hd), (None, "embed", "kv_heads", "qk")),
        f"{prefix}wv": ParamSpec((L, d, kv, hd), (None, "embed", "kv_heads", "qk")),
        f"{prefix}wo": ParamSpec((L, h, hd, d), (None, "heads", "qk", "embed")),
    }
    if cfg.qk_norm:
        s[f"{prefix}q_norm"] = ParamSpec((L, hd), (None, None), init="ones")
        s[f"{prefix}k_norm"] = ParamSpec((L, hd), (None, None), init="ones")
    return s


def ffn_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((L, d, f), (None, "embed", "ff")),
        "w_up": ParamSpec((L, d, f), (None, "embed", "ff")),
        "w_down": ParamSpec((L, f, d), (None, "ff", "embed")),
    }


def block_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    d = cfg.d_model
    s = {"ln1": ParamSpec((L, d), (None, None), init="ones")}
    s.update(attn_specs(cfg, L))
    if not cfg.parallel_block:
        s["ln2"] = ParamSpec((L, d), (None, None), init="ones")
    s.update(ffn_specs(cfg, L))
    return s


def _rope_q_k(cfg: ModelConfig, q: Array, k: Array, positions: Array
              ) -> tuple[Array, Array]:
    if cfg.mrope:
        return (apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections),
                apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections))
    return (apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction),
            apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction))


def gqa_attention(p: dict[str, Array], cfg: ModelConfig, x: Array,
                  positions: Array, *, causal: bool = True,
                  kv_chunk: int = 1024, prefix: str = "") -> Array:
    q = activation(dense(x, p[f"{prefix}wq"]),
                   "batch", "seq", "heads", None)   # [B,S,H,hd]
    k = activation(dense(x, p[f"{prefix}wk"]),
                   "batch", "seq", "kv_heads", None)
    v = activation(dense(x, p[f"{prefix}wv"]),
                   "batch", "seq", "kv_heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, p[f"{prefix}q_norm"], cfg.norm_eps)
        k = rms_norm(k, p[f"{prefix}k_norm"], cfg.norm_eps)
    q, k = _rope_q_k(cfg, q, k, positions)
    out = chunked_attention(q, k, v, causal=causal, kv_chunk=kv_chunk)
    return jnp.einsum("bshd,hdq->bsq", out, p[f"{prefix}wo"]).astype(x.dtype)


def gqa_decode(p: dict[str, Array], cfg: ModelConfig, x: Array,
               cache: dict[str, Array], positions: Array,
               cache_len: Array | None, prefix: str = ""
               ) -> tuple[Array, dict[str, Array]]:
    """Single-token attention with cache insert.  x [B,1,d]."""
    b = x.shape[0]
    q = dense(x, p[f"{prefix}wq"])
    k = dense(x, p[f"{prefix}wk"])
    v = dense(x, p[f"{prefix}wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p[f"{prefix}q_norm"], cfg.norm_eps)
        k = rms_norm(k, p[f"{prefix}k_norm"], cfg.norm_eps)
    q, k = _rope_q_k(cfg, q, k, positions)
    t = cache["k"].shape[1]
    idx = (cache_len if cache_len is not None
           else jnp.full((b,), t - 1, jnp.int32))
    bidx = jnp.arange(b)
    kc = cache["k"].at[bidx, idx].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[bidx, idx].set(v[:, 0].astype(cache["v"].dtype))
    out = decode_attention(q, kc, vc,
                           cache_len=idx + 1 if cache_len is not None else None)
    y = jnp.einsum("bshd,hdq->bsq", out, p[f"{prefix}wo"]).astype(x.dtype)
    return y, {"k": kc, "v": vc}


def dense_ffn(p: dict[str, Array], cfg: ModelConfig, x: Array) -> Array:
    if cfg.ffn_act == "swiglu":
        h = swiglu(dense(x, p["w_gate"]), dense(x, p["w_up"]))
    else:
        h = jax.nn.gelu(dense(x, p["w_up"]))
    return dense(h, p["w_down"])


def init_attn_cache(cfg: ModelConfig, batch: int, seq: int, dtype: Any,
                    layers: int | None = None) -> dict[str, Array]:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, seq, kv, hd)
    if layers is not None:
        shape = (layers,) + shape
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}

"""Attention: chunked-flash (pure JAX, the dry-run/XLA path), Pallas-backed
option, and cache decode.  GQA throughout.

The chunked path is the same blocking as kernels/flash_attention.py expressed
with lax.scan over KV chunks + online softmax, so it lowers on any backend
and never materializes the [S, S] score matrix (prefill_32k would otherwise
need TBs).  Layout: q [B, S, Hq, D];  k/v [B, Skv, Hkv, D].
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.parallel.sharding import activation, current_ctx

Array = jax.Array

NEG_INF = -1e30


def _gqa_logits(q: Array, k: Array) -> Array:
    """q [B,S,Hkv,G,D] x k [B,T,Hkv,D] -> [B,Hkv,G,S,T] f32."""
    return jnp.einsum(
        "bshgd,bthd->bhgst", q, k, preferred_element_type=jnp.float32
    )


def chunked_attention(
    q: Array, k: Array, v: Array,
    *,
    causal: bool = True,
    kv_chunk: int = 1024,
    scale: float | None = None,
    kv_len: Array | None = None,
    backend: str = "xla",
) -> Array:
    """Online-softmax attention over KV chunks.

    kv_len: optional [B] active cache lengths (decode with a partially
    filled cache); positions >= kv_len are masked out.
    """
    b, s, hq, d = q.shape
    _, t, hkv, _ = k.shape
    dv = v.shape[-1]                      # may differ from d (MLA)
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    if backend in ("pallas", "pallas_interpret") and kv_len is None:
        # kernel layout is [B, H, S, D]
        out = kops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, scale=scale,
            backend=backend,
        )
        return out.transpose(0, 2, 1, 3)

    # Distribution of the attention interior: shard KV heads over 'model'
    # when divisible; otherwise fall back to sharding the QUERY sequence over
    # 'model' (context parallelism) — K/V stay replicated (they already are
    # when heads don't divide), and every model shard owns an S/tp query
    # slice, so the O(S^2) score traffic and FLOPs distribute instead of
    # replicating.  See EXPERIMENTS.md §Perf.
    # REPRO_BASELINE_ATTN=1 restores the paper-baseline behavior (no CP
    # fallback, plain autodiff through the scan) for §Perf A/B measurement.
    baseline = os.environ.get("REPRO_BASELINE_ATTN") == "1"
    mesh = current_ctx().mesh
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    if hkv % tp == 0:
        q_axes = ("batch", None, "kv_heads", None, None)
        acc_axes = ("batch", "kv_heads", None, None, None)
    elif not baseline:
        q_axes = ("batch", "attn_q_seq", None, None, None)
        acc_axes = ("batch", None, None, "attn_q_seq", None)
    else:
        q_axes = ("batch", None, "kv_heads", None, None)
        acc_axes = ("batch", "kv_heads", None, "seq", None)
    qg = activation((q * scale).reshape(b, s, hkv, g, d), *q_axes)
    n_chunks = max(t // kv_chunk, 1)
    kv_chunk = t // n_chunks
    assert t % kv_chunk == 0, (t, kv_chunk)

    kc = k.reshape(b, n_chunks, kv_chunk, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hkv, dv).transpose(1, 0, 2, 3, 4)

    if kv_len is None and not baseline:
        # training/prefill: flash custom-VJP — the backward recomputes the
        # per-chunk score tile instead of letting autodiff stack every
        # [.., S, C] intermediate as scan residuals (EXPERIMENTS.md §Perf).
        out = _flash(qg, kc, vc, causal, kv_chunk, t, s, acc_axes)
    else:
        out, _ = _flash_fwd_scan(qg, kc, vc, causal, kv_chunk, t, s,
                                 acc_axes, kv_len)
    return (out.transpose(0, 3, 1, 2, 4).reshape(b, s, hkv * g, dv)
            .astype(q.dtype))


def _flash_fwd_scan(qg, kc, vc, causal, kv_chunk, t, s, acc_axes,
                    kv_len=None):
    """Online-softmax forward.  Returns (out [b,hkv,g,s,dv] f32,
    lse [b,hkv,g,s,1])."""
    _, b, _, hkv, _ = kc.shape             # kc: [n_chunks, B, C, Hkv, D]
    dv = vc.shape[-1]                      # V head dim (may differ: MLA)
    g = qg.shape[3]
    q_pos = jnp.arange(s)[:, None] + (t - s)      # global query positions
    acc0 = activation(jnp.zeros((b, hkv, g, s, dv), jnp.float32), *acc_axes)
    m0 = activation(jnp.full((b, hkv, g, s, 1), NEG_INF, jnp.float32),
                    *acc_axes)
    l0 = activation(jnp.zeros((b, hkv, g, s, 1), jnp.float32), *acc_axes)

    def step(carry, inp):
        acc, m, l, ci = carry
        kb, vb = inp                               # [B, C, Hkv, D]
        logits = _gqa_logits(qg, kb)               # [B,Hkv,G,S,C]
        k_pos = ci * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = jnp.ones((s, kv_chunk), bool)
        if causal:
            mask &= q_pos >= k_pos
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        if kv_len is not None:
            live = (ci * kv_chunk
                    + jnp.arange(kv_chunk))[None, :] < kv_len[:, None]
            logits = jnp.where(live[:, None, None, None, :], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1, keepdims=True)
        pv = jnp.einsum("bhgsc,bchd->bhgsd", p, vb.astype(jnp.float32))
        acc = activation(acc * alpha + pv, *acc_axes)
        return (acc, m_new, l, ci + 1), None

    (acc, m, l, _), _ = jax.lax.scan(
        step, (acc0, m0, l0, jnp.asarray(0)), (kc, vc)
    )
    l = jnp.maximum(l, 1e-30)
    return acc / l, m + jnp.log(l)


def _chunk_mask(ci, kv_chunk, t, s, causal):
    q_pos = jnp.arange(s)[:, None] + (t - s)
    k_pos = ci * kv_chunk + jnp.arange(kv_chunk)[None, :]
    mask = jnp.ones((s, kv_chunk), bool)
    if causal:
        mask &= q_pos >= k_pos
    return mask


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(qg, kc, vc, causal, kv_chunk, t, s, acc_axes):
    out, _ = _flash_fwd_scan(qg, kc, vc, causal, kv_chunk, t, s, acc_axes)
    return out


def _flash_vjp_fwd(qg, kc, vc, causal, kv_chunk, t, s, acc_axes):
    out, lse = _flash_fwd_scan(qg, kc, vc, causal, kv_chunk, t, s, acc_axes)
    return out, (qg, kc, vc, out, lse)


def _flash_vjp_bwd(causal, kv_chunk, t, s, acc_axes, res, dout):
    qg, kc, vc, out, lse = res
    dout = activation(dout.astype(jnp.float32), *acc_axes)
    # D_i = sum_d dO * O  (flash-attention-2 backward)
    delta = jnp.sum(dout * out, axis=-1, keepdims=True)   # [b,hkv,g,s,1]

    def step(dq, inp):
        kb, vb, ci = inp                                   # [B,C,Hkv,D]
        logits = _gqa_logits(qg, kb)
        mask = _chunk_mask(ci, kv_chunk, t, s, causal)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        p = jnp.exp(logits - lse)                          # normalized probs
        dp = jnp.einsum("bhgsd,bchd->bhgsc", dout,
                        vb.astype(jnp.float32))
        ds = p * (dp - delta)                              # [b,hkv,g,s,c]
        dq = dq + jnp.einsum("bhgsc,bchd->bshgd", ds,
                             kb.astype(jnp.float32))
        dkb = jnp.einsum("bhgsc,bshgd->bchd", ds,
                         qg.astype(jnp.float32))
        dvb = jnp.einsum("bhgsc,bhgsd->bchd", p, dout)
        return dq, (dkb, dvb)

    n_chunks = kc.shape[0]
    dq0 = jnp.zeros(qg.shape, jnp.float32)
    dq, (dk, dv) = jax.lax.scan(
        step, dq0, (kc, vc, jnp.arange(n_chunks)))
    return dq.astype(qg.dtype), dk.astype(kc.dtype), dv.astype(vc.dtype)


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def decode_attention(
    q: Array,         # [B, 1, Hq, D]
    k_cache: Array,   # [B, T, Hkv, D]
    v_cache: Array,
    *,
    cache_len: Array | None = None,    # [B] live lengths
    scale: float | None = None,
) -> Array:
    """Single-token attention against a (possibly seq-sharded) cache.

    One einsum over the cache: under pjit, sharding the cache's T axis over
    'model' turns this into sequence-parallel decode — XLA inserts the
    partial-softmax reduction collectives automatically.
    """
    b, _, hq, d = q.shape
    _, t, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    qg = (q * scale).reshape(b, hkv, g, d)
    logits = activation(
        jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32),
        "batch", "cache_heads", None, "cache_seq")
    if cache_len is not None:
        live = jnp.arange(t)[None] < cache_len[:, None]       # [B, T]
        logits = jnp.where(live[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)

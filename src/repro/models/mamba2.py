"""Mamba-2 / SSD (state-space duality) block [arXiv:2405.21060].

Train/prefill: the chunked SSD algorithm — intra-chunk "attention-like"
quadratic term + inter-chunk linear state recurrence (lax.scan over chunks).
This is the exact blocking a TPU Pallas SSD kernel uses; expressed in jnp so
the multi-pod dry-run lowers everywhere.

Decode: O(1) recurrent state update — the reason ``long_500k`` runs for the
SSM/hybrid archs: the "cache" is a fixed-size [B, H, P, N] state plus a
[B, k-1, channels] conv window, independent of context length.

TP sharding: d_inner (= heads x headdim) is sharded over 'model'; the B/C
group projections (G*N small) stay replicated; out_proj is row-parallel.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, dense, rms_norm
from repro.parallel.sharding import activation

Array = jax.Array


def mamba2_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    d = cfg.d_model
    din = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.d_state
    h = cfg.ssm_heads
    k = cfg.d_conv
    return {
        "norm_in": ParamSpec((L, d), (None, None), init="ones"),
        "wz": ParamSpec((L, d, din), (None, "embed", "ssm_inner")),
        "wx": ParamSpec((L, d, din), (None, "embed", "ssm_inner")),
        "wB": ParamSpec((L, d, gn), (None, "embed", None)),
        "wC": ParamSpec((L, d, gn), (None, "embed", None)),
        "wdt": ParamSpec((L, d, h), (None, "embed", None)),
        "conv_x_w": ParamSpec((L, k, din), (None, "conv", "ssm_inner"),
                              scale=0.5),
        "conv_x_b": ParamSpec((L, din), (None, "ssm_inner"), init="zeros"),
        "conv_B_w": ParamSpec((L, k, gn), (None, "conv", None), scale=0.5),
        "conv_B_b": ParamSpec((L, gn), (None, None), init="zeros"),
        "conv_C_w": ParamSpec((L, k, gn), (None, "conv", None), scale=0.5),
        "conv_C_b": ParamSpec((L, gn), (None, None), init="zeros"),
        "A_log": ParamSpec((L, h), (None, None), init="zeros"),
        "D": ParamSpec((L, h), (None, None), init="ones"),
        "dt_bias": ParamSpec((L, h), (None, None), init="zeros"),
        "norm_g": ParamSpec((L, din), (None, "ssm_inner"), init="ones"),
        "wo": ParamSpec((L, din, d), (None, "ssm_inner", "embed")),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over seq.  x [B,S,C], w [K,C], b [C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return out + b[None, None, :]


def _conv_step(state: Array, new: Array, w: Array, b: Array
               ) -> tuple[Array, Array]:
    """Single-token conv.  state [B,K-1,C], new [B,C] -> (out [B,C], state')."""
    k = w.shape[0]
    window = jnp.concatenate([state, new[:, None, :]], axis=1)  # [B,K,C]
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return out, window[:, 1:, :]


def _project(p: dict[str, Array], cfg: ModelConfig, x: Array
             ) -> tuple[Array, Array, Array, Array, Array]:
    """x [B,S,d] -> (z, xs, B_, C_, dt) pre-conv, pre-activation."""
    z = dense(x, p["wz"])
    xs = dense(x, p["wx"])
    b_ = dense(x, p["wB"])
    c_ = dense(x, p["wC"])
    dt = dense(x, p["wdt"]).astype(jnp.float32)
    return z, xs, b_, c_, dt


def ssd_chunked(
    xh: Array,     # [B, S, H, P] conv'd+SiLU'd inputs, head-split
    dt: Array,     # [B, S, H] post-softplus
    a_log: Array,  # [H]
    b_: Array,     # [B, S, G, N]
    c_: Array,     # [B, S, G, N]
    d_skip: Array, # [H]
    chunk: int,
) -> Array:
    """Chunked state-space-duality scan.  Returns y [B, S, H, P]."""
    bsz, s, h, pdim = xh.shape
    g = b_.shape[2]
    rep = h // g
    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    assert s % chunk == 0

    a = -jnp.exp(a_log.astype(jnp.float32))              # [H] negative
    da = dt * a[None, None, :]                           # [B,S,H]

    def rs(t, last):  # reshape to chunks
        return t.reshape((bsz, n_chunks, chunk) + last)

    xc = activation(rs(xh.astype(jnp.float32), (h, pdim)),
                    "batch", None, "seq", "ssm_heads", None)
    dtc = rs(dt, (h,))
    dac = rs(da, (h,))
    bc = jnp.repeat(rs(b_.astype(jnp.float32), (g, cdim := b_.shape[-1])),
                    rep, axis=3)                          # [B,c,Q,H,N]
    cc = jnp.repeat(rs(c_.astype(jnp.float32), (g, cdim)), rep, axis=3)

    csum = jnp.cumsum(dac, axis=2)                        # [B,c,Q,H]
    total = csum[:, :, -1, :]                             # [B,c,H]

    # intra-chunk quadratic term
    seg = csum[:, :, :, None, :] - csum[:, :, None, :, :]  # [B,c,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcqhn,bckhn->bcqkh", cc, bc)          # [B,c,Qi,Qj,H]
    att = cb * decay * dtc[:, :, None, :, :]               # weight by dt_j
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", att, xc)

    # chunk boundary states
    decay_to_end = jnp.exp(total[:, :, None, :] - csum)    # [B,c,Q,H]
    xb = jnp.einsum("bckhn,bckh,bckhp->bchpn", bc,
                    dtc * decay_to_end, xc)                # [B,c,H,P,N]

    def scan_fn(state, inp):
        tot_c, xb_c = inp                                   # [B,H], [B,H,P,N]
        out = state
        state = activation(
            state * jnp.exp(tot_c)[:, :, None, None] + xb_c,
            "batch", "ssm_heads", None, None)
        return state, out

    _, prev_states = jax.lax.scan(
        scan_fn,
        activation(jnp.zeros((bsz, h, pdim, b_.shape[-1]), jnp.float32),
                   "batch", "ssm_heads", None, None),
        (total.transpose(1, 0, 2), xb.transpose(1, 0, 2, 3, 4)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # [B,c,H,P,N]

    y_inter = jnp.einsum(
        "bcqhn,bcqh,bchpn->bcqhp", cc, jnp.exp(csum), prev_states)

    y = (y_intra + y_inter).reshape(bsz, s, h, pdim)
    return y + xh.astype(jnp.float32) * d_skip[None, None, :, None]


def mamba2_forward(p: dict[str, Array], cfg: ModelConfig, x: Array) -> Array:
    """Full-sequence Mamba2 block.  x [B,S,d] -> [B,S,d]."""
    bsz, s, d = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.d_state
    z, xs, b_, c_, dt = _project(p, cfg, x)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x_w"], p["conv_x_b"]))
    b_ = jax.nn.silu(_causal_conv(b_, p["conv_B_w"], p["conv_B_b"]))
    c_ = jax.nn.silu(_causal_conv(c_, p["conv_C_w"], p["conv_C_b"]))
    dt = jax.nn.softplus(dt + p["dt_bias"][None, None].astype(jnp.float32))

    xh = activation(xs.reshape(bsz, s, h, pdim),
                    "batch", "seq", "ssm_heads", None)
    bg = b_.reshape(bsz, s, cfg.ssm_ngroups, n)
    cg = c_.reshape(bsz, s, cfg.ssm_ngroups, n)
    y = ssd_chunked(xh, dt, p["A_log"], bg, cg, p["D"], cfg.ssd_chunk)
    y = y.reshape(bsz, s, h * pdim).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    return dense(y, p["wo"])


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype: Any
                      ) -> dict[str, Array]:
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.d_state
    gn = cfg.ssm_ngroups * cfg.d_state
    k = cfg.d_conv
    return {
        "ssm": jnp.zeros((batch, h, pdim, n), jnp.float32),
        "conv_x": jnp.zeros((batch, k - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, k - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, k - 1, gn), dtype),
    }


def mamba2_decode(p: dict[str, Array], cfg: ModelConfig, x: Array,
                  state: dict[str, Array]
                  ) -> tuple[Array, dict[str, Array]]:
    """Single-token recurrent step.  x [B,1,d]."""
    bsz = x.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.d_state
    z, xs, b_, c_, dt = _project(p, cfg, x)
    xs1, conv_x = _conv_step(state["conv_x"], xs[:, 0], p["conv_x_w"],
                             p["conv_x_b"])
    b1, conv_b = _conv_step(state["conv_B"], b_[:, 0], p["conv_B_w"],
                            p["conv_B_b"])
    c1, conv_c = _conv_step(state["conv_C"], c_[:, 0], p["conv_C_w"],
                            p["conv_C_b"])
    xs1 = jax.nn.silu(xs1).astype(jnp.float32)
    b1 = jax.nn.silu(b1).astype(jnp.float32)
    c1 = jax.nn.silu(c1).astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0] + p["dt_bias"][None].astype(jnp.float32))

    a = -jnp.exp(p["A_log"].astype(jnp.float32))          # [H]
    xh = xs1.reshape(bsz, h, pdim)
    rep = h // cfg.ssm_ngroups
    bh = jnp.repeat(b1.reshape(bsz, cfg.ssm_ngroups, n), rep, axis=1)
    ch = jnp.repeat(c1.reshape(bsz, cfg.ssm_ngroups, n), rep, axis=1)

    decay = jnp.exp(dt1 * a[None])                        # [B,H]
    ssm = (state["ssm"] * decay[:, :, None, None]
           + jnp.einsum("bh,bhp,bhn->bhpn", dt1, xh, bh))
    y = jnp.einsum("bhpn,bhn->bhp", ssm, ch)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, 1, h * pdim).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_g"], cfg.norm_eps)
    return dense(y, p["wo"]), {
        "ssm": ssm, "conv_x": conv_x, "conv_B": conv_b, "conv_C": conv_c,
    }

"""Multi-head Latent Attention (DeepSeek-V2 [arXiv:2405.04434]; MiniCPM3).

Prefill: expand the latent KV into per-head K/V and run chunked-flash MHA.
Decode: *absorbed* attention — the production trick: fold W_uk into the query
and W_uv into the output so attention runs directly in the kv_lora latent
space.  The KV cache stores only [c_kv (kv_lora) ; k_rope (qk_rope_dim)] per
token — the MLA memory win (e.g. 576 vs 2x16x192 floats/token for DS-V2-Lite).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import chunked_attention
from repro.models.common import ParamSpec, dense, rms_norm
from repro.models.rope import apply_rope
from repro.parallel.sharding import activation

Array = jax.Array


def mla_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    d = cfg.d_model
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    s: dict[str, ParamSpec] = {}
    if cfg.q_lora:
        s["wq_a"] = ParamSpec((L, d, cfg.q_lora), (None, "embed", "lora"))
        s["q_norm"] = ParamSpec((L, cfg.q_lora), (None, None), init="ones")
        s["wq_b"] = ParamSpec((L, cfg.q_lora, h, dn + dr),
                              (None, "lora", "heads", "qk"))
    else:
        s["wq"] = ParamSpec((L, d, h, dn + dr), (None, "embed", "heads", "qk"))
    s["wkv_a"] = ParamSpec((L, d, cfg.kv_lora + dr), (None, "embed", "lora"))
    s["kv_norm"] = ParamSpec((L, cfg.kv_lora), (None, None), init="ones")
    s["wkv_b"] = ParamSpec((L, cfg.kv_lora, h, dn + dv),
                           (None, "lora", "heads", "qk"))
    s["wo"] = ParamSpec((L, h, dv, d), (None, "heads", "qk", "embed"))
    return s


def _queries(p: dict[str, Array], cfg: ModelConfig, x: Array,
             positions: Array) -> tuple[Array, Array]:
    """-> (q_nope [B,S,H,dn], q_rope [B,S,H,dr])."""
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora:
        ql = rms_norm(dense(x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
        q = dense(ql, p["wq_b"])
    else:
        q = dense(x, p["wq"])
    q = activation(q, "batch", "seq", "heads", None)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _latent_kv(p: dict[str, Array], cfg: ModelConfig, x: Array,
               positions: Array) -> tuple[Array, Array]:
    """-> (c_kv [B,S,lora] normalized, k_rope [B,S,dr] rotated)."""
    lora = cfg.kv_lora
    ckv = dense(x, p["wkv_a"])
    c_kv, k_rope = ckv[..., :lora], ckv[..., lora:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_prefill(p: dict[str, Array], cfg: ModelConfig, x: Array,
                positions: Array, kv_chunk: int = 1024) -> Array:
    """Full-sequence MLA via latent expansion + chunked flash."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qn, qr = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latent_kv(p, cfg, x, positions)

    kv = activation(dense(c_kv, p["wkv_b"]),
                    "batch", "seq", "heads", None)  # [B,S,H,dn+dv]
    kn, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)
    scale = (dn + dr) ** -0.5
    # chunked_attention supports distinct QK and V head dims natively — no
    # V padding (EXPERIMENTS.md §Perf It.5: padding cost 1.5x on PV traffic)
    out = chunked_attention(q, k, v, causal=True, kv_chunk=kv_chunk,
                            scale=scale)
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"]).astype(x.dtype)


def mla_decode(p: dict[str, Array], cfg: ModelConfig, x: Array,
               cache: dict[str, Array], positions: Array,
               cache_len: Array | None = None) -> tuple[Array, dict[str, Array]]:
    """Absorbed single-token decode against the latent cache.

    cache: {"c_kv": [B,T,lora], "k_rope": [B,T,dr]};  x: [B,1,d].
    """
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qn, qr = _queries(p, cfg, x, positions)         # [B,1,H,dn],[B,1,H,dr]
    c_new, r_new = _latent_kv(p, cfg, x, positions)

    # insert at cache_len (dry-run: static full cache, write at T-1)
    t = cache["c_kv"].shape[1]
    idx = (cache_len if cache_len is not None
           else jnp.full((x.shape[0],), t - 1, jnp.int32))
    bidx = jnp.arange(x.shape[0])
    c_kv = cache["c_kv"].at[bidx, idx].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[bidx, idx].set(r_new[:, 0].astype(cache["k_rope"].dtype))

    w_uk = p["wkv_b"][..., :dn]                     # [lora, H, dn]
    w_uv = p["wkv_b"][..., dn:]                     # [lora, H, dv]
    q_lat = jnp.einsum("bshn,lhn->bshl", qn, w_uk)  # [B,1,H,lora]

    scale = (dn + dr) ** -0.5
    logits = (
        jnp.einsum("bshl,btl->bhst", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bshr,btr->bhst", qr, k_rope,
                     preferred_element_type=jnp.float32)
    ) * scale                                        # [B,H,1,T]
    if cache_len is not None:
        live = jnp.arange(t)[None] <= idx[:, None]
        logits = jnp.where(live[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    ctx_lat = jnp.einsum("bhst,btl->bshl", probs,
                         c_kv.astype(jnp.float32))   # [B,1,H,lora]
    out = jnp.einsum("bshl,lhv->bshv", ctx_lat.astype(x.dtype), w_uv)
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"]).astype(x.dtype)
    return y, {"c_kv": c_kv, "k_rope": k_rope}

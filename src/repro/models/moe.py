"""Mixture-of-Experts FFN with expert parallelism (Qwen1.5-MoE, DeepSeek-V2).

Capacity-based scatter dispatch (NOT the GShard one-hot dispatch einsum: that
is O(S^2 * k * cf * d) per token group and dominates compiled FLOPs — see
EXPERIMENTS.md §Perf for the measurement).

EP layout: experts are sharded over the 'model' mesh axis; activations are
batch-sharded over ('pod','data') and *replicated* across 'model' (the same
layout every TP layer already uses, so dispatch needs NO extra all-gather).
Each model shard routes its token block against the experts it owns, padded
to per-expert capacity, runs the expert FFN as one batched matmul, and a
single psum over 'model' assembles token outputs — the identical collective
pattern to a row-parallel dense FFN.

Implemented as a shard-local function wrapped in jax.shard_map (the mesh-less
call runs the same function with one shard — single source of truth for
tests).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec, dense, swiglu
from repro.parallel.sharding import RULES, ShardingCtx

Array = jax.Array

#: experts are padded so every supported model-axis size divides the count
EXPERT_PAD_TO = 16


def padded_experts(cfg: ModelConfig) -> int:
    return math.ceil(cfg.n_experts / EXPERT_PAD_TO) * EXPERT_PAD_TO


def moe_specs(cfg: ModelConfig, L: int) -> dict[str, ParamSpec]:
    d = cfg.d_model
    e = padded_experts(cfg)
    f = cfg.moe_d_ff
    s = {
        "router": ParamSpec((L, d, cfg.n_experts), (None, "embed", None),
                            scale=0.1),
        "w_gate": ParamSpec((L, e, d, f), (None, "experts", "embed", "moe_ff")),
        "w_up": ParamSpec((L, e, d, f), (None, "experts", "embed", "moe_ff")),
        "w_down": ParamSpec((L, e, f, d), (None, "experts", "moe_ff", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.shared_d_ff
        s["ws_gate"] = ParamSpec((L, d, fs), (None, "embed", "ff"))
        s["ws_up"] = ParamSpec((L, d, fs), (None, "embed", "ff"))
        s["ws_down"] = ParamSpec((L, fs, d), (None, "ff", "embed"))
    return s


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    e = padded_experts(cfg)
    c = math.ceil(tokens * cfg.top_k * cfg.capacity_factor / e)
    return max(8, math.ceil(c / 8) * 8)


def _moe_local(
    x: Array,            # [Tl, d]  this shard's tokens
    router: Array,       # [d, E_real]  replicated
    w_gate: Array,       # [El, d, f]   this shard's experts
    w_up: Array,
    w_down: Array,       # [El, f, d]
    *,
    cfg: ModelConfig,
    e0: Array | int,     # first owned expert id
    n_shards: int,
) -> tuple[Array, Array]:
    """Shard-local capacity routing + expert FFN.  Returns (y, aux_loss)."""
    tl, d = x.shape
    el = w_gate.shape[0]
    e_pad = el * n_shards
    cap = _capacity(tl, cfg)

    logits = dense(x, router).astype(jnp.float32)           # [Tl, E_real]
    if e_pad > cfg.n_experts:                                # mask pad experts
        logits = jnp.pad(logits, ((0, 0), (0, e_pad - cfg.n_experts)),
                         constant_values=-1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)             # [Tl, k]
    if cfg.router_scale:
        gates = gates / jnp.maximum(
            gates.sum(axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance auxiliary loss (over real experts).
    density = jnp.mean(
        (ids[..., None] == jnp.arange(e_pad)[None, None]).any(axis=1)
        .astype(jnp.float32), axis=0)                        # [E]
    mean_prob = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * mean_prob) * cfg.n_experts

    # dispatch: tokens -> [El, cap, d] buffers for owned experts
    buf = jnp.zeros((el * cap, d), x.dtype)
    keeps, slots = [], []
    counts = jnp.zeros((el,), jnp.int32)
    for slot in range(cfg.top_k):
        eid = ids[:, slot]
        lid = eid - e0                                        # local expert id
        own = (lid >= 0) & (lid < el)
        lid = jnp.clip(lid, 0, el - 1)
        oh = jax.nn.one_hot(lid, el, dtype=jnp.int32) * own[:, None]
        pos = counts[None, :] + jnp.cumsum(oh, axis=0) - oh   # pre-increment
        pos = jnp.sum(pos * oh, axis=1)                       # [Tl]
        counts = counts + oh.sum(axis=0)
        keep = own & (pos < cap)
        slot_idx = jnp.where(keep, lid * cap + pos, el * cap)  # OOB drop
        buf = buf.at[slot_idx].add(
            jnp.where(keep[:, None], x, 0), mode="drop",
            indices_are_sorted=False, unique_indices=False)
        keeps.append(keep)
        slots.append(slot_idx)

    eb = buf.reshape(el, cap, d)
    h = jnp.einsum("ecd,edf->ecf", eb, w_gate)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", eb, w_up)
    out = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), w_down)
    out = out.reshape(el * cap, d)

    y = jnp.zeros_like(x)
    for slot in range(cfg.top_k):
        keep, slot_idx = keeps[slot], slots[slot]
        g = (gates[:, slot] * keep).astype(x.dtype)
        y = y + g[:, None] * out.at[jnp.clip(slot_idx, 0, el * cap - 1)].get(
            mode="clip")
    return y, aux


def moe_ffn(ctx: ShardingCtx, cfg: ModelConfig, p: dict[str, Array],
            x: Array) -> tuple[Array, Array]:
    """x: [B, S, d] -> (y [B, S, d], aux scalar)."""
    b, s, d = x.shape
    e_pad = padded_experts(cfg)

    mesh = ctx.mesh
    use_shmap = (
        mesh is not None and not mesh.empty and "model" in mesh.shape
        and mesh.shape["model"] > 1 and e_pad % mesh.shape["model"] == 0
    )
    if use_shmap:
        n_shards = mesh.shape["model"]
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

        def shard_fn(xs, router, wg, wu, wd):
            bl, sl, _ = xs.shape              # local block
            xf = xs.reshape(bl * sl, d)       # flatten inside the shard
            el = wg.shape[0]
            e0 = jax.lax.axis_index("model") * el
            y, aux = _moe_local(xf, router, wg, wu, wd, cfg=cfg, e0=e0,
                                n_shards=n_shards)
            y = jax.lax.psum(y, "model")
            aux = jax.lax.psum(aux, "model") / n_shards
            if batch_axes:
                aux = jax.lax.pmean(aux, batch_axes)
            return y.reshape(bl, sl, d), aux

        y, aux = jax.shard_map(
            shard_fn,
            mesh=mesh,
            in_specs=(
                P(batch_axes if batch_axes else None, None, None),
                P(None, None),
                P("model", None, None),
                P("model", None, None),
                P("model", None, None),
            ),
            out_specs=(P(batch_axes if batch_axes else None, None, None), P()),
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    else:
        yflat, aux = _moe_local(
            x.reshape(b * s, d), p["router"], p["w_gate"], p["w_up"],
            p["w_down"], cfg=cfg, e0=0, n_shards=1)
        y = yflat.reshape(b, s, d)

    if cfg.n_shared_experts:
        h = swiglu(dense(x, p["ws_gate"]), dense(x, p["ws_up"]))
        y = y + dense(h, p["ws_down"])
    return y, aux

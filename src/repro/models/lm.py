"""Unified decoder-only LM over ModelConfig: dense / MoE / MLA / SSM /
hybrid (Zamba2) / VLM (Qwen2-VL backbone).

Single source of truth per architecture:
  model_specs(cfg)        -> ParamSpec pytree (init, shardings, dry-run)
  forward(cfg, p, batch)  -> [B, S, vocab] logits (or chunked loss directly)
  loss_fn(...)            -> scalar CE (+ MoE aux), seq-chunked so the full
                             [B, S, V] logits tensor never materializes
  decode_state_specs(cfg) -> cache/state ParamSpec pytree
  decode_step(...)        -> one-token serve step over the cache
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba2 as m2
from repro.models import mla
from repro.models import moe as moe_mod
from repro.models.blocks import (
    attn_specs,
    block_specs,
    dense_ffn,
    ffn_specs,
    gqa_attention,
    gqa_decode,
)
from repro.models.common import (
    ParamSpec,
    cross_entropy,
    dense,
    rms_norm,
    spec_param_count,
)
from repro.parallel.sharding import ShardingCtx, activation

Array = jax.Array

LOSS_CHUNK = 1024         # seq tokens per unembed/CE chunk
KV_CHUNK = 1024           # flash attention KV block


# -- specs -----------------------------------------------------------------


def _layer_specs(cfg: ModelConfig, L: int, moe_layer: bool) -> dict[str, ParamSpec]:
    d = cfg.d_model
    s: dict[str, ParamSpec] = {
        "ln1": ParamSpec((L, d), (None, None), init="ones")}
    if cfg.attn_kind == "mla":
        s.update(mla.mla_specs(cfg, L))
    else:
        s.update(attn_specs(cfg, L))
    if not cfg.parallel_block:
        s["ln2"] = ParamSpec((L, d), (None, None), init="ones")
    if moe_layer:
        s.update(moe_mod.moe_specs(cfg, L))
    else:
        s.update(ffn_specs(cfg, L))
    return s


def model_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed"), init="embed",
                           scale=0.02),
        "final_norm": ParamSpec((d,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"),
                                     scale=1.0)

    if cfg.family in ("dense", "vlm"):
        specs["layers"] = _layer_specs(cfg, cfg.num_layers, moe_layer=False)
    elif cfg.family == "moe":
        nd = cfg.first_dense_layers
        if nd:
            specs["dense_layers"] = _layer_specs(cfg, nd, moe_layer=False)
        specs["layers"] = _layer_specs(cfg, cfg.num_layers - nd,
                                       moe_layer=True)
    elif cfg.family == "ssm":
        specs["layers"] = m2.mamba2_specs(cfg, cfg.num_layers)
    elif cfg.family == "hybrid":
        n_groups, per_group, tail = _hybrid_shape(cfg)
        group_specs = m2.mamba2_specs(cfg, per_group)
        specs["groups"] = jax.tree.map(
            lambda s: ParamSpec((n_groups,) + s.shape, (None,) + s.axes,
                                init=s.init, scale=s.scale, dtype=s.dtype),
            group_specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        if tail:
            specs["tail"] = m2.mamba2_specs(cfg, tail)
        # one shared attention block + per-invocation q-LoRA adapters
        shared = _layer_specs(cfg, 1, moe_layer=False)
        specs["shared_attn"] = shared
        r = cfg.shared_attn_lora
        if r:
            specs["shared_lora_a"] = ParamSpec(
                (n_groups, d, r), (None, "embed", "lora"))
            specs["shared_lora_b"] = ParamSpec(
                (n_groups, r, d), (None, "lora", None), init="zeros")
    else:
        raise ValueError(f"model_specs: family {cfg.family} (encdec lives in"
                         " models/encdec.py)")
    return specs


def _hybrid_shape(cfg: ModelConfig) -> tuple[int, int, int]:
    per = cfg.shared_attn_every
    n_groups = cfg.num_layers // per
    tail = cfg.num_layers - n_groups * per
    return n_groups, per, tail


# -- forward ------------------------------------------------------------------


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def _block_forward(cfg: ModelConfig, p: dict[str, Array], x: Array,
                   positions: Array, ctx: ShardingCtx, moe_layer: bool
                   ) -> tuple[Array, Array]:
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        attn = mla.mla_prefill(p, cfg, h, positions, kv_chunk=KV_CHUNK)
    else:
        attn = gqa_attention(p, cfg, h, positions, kv_chunk=KV_CHUNK)
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        f = dense_ffn(p, cfg, h)
        return x + attn + f, aux
    x = x + attn
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe_layer:
        f, aux = moe_mod.moe_ffn(ctx, cfg, p, h2)
    else:
        f = dense_ffn(p, cfg, h2)
    return x + f, aux


def _scan_blocks(cfg: ModelConfig, stacked: dict[str, Array], x: Array,
                 positions: Array, ctx: ShardingCtx, moe_layer: bool
                 ) -> tuple[Array, Array]:
    def body(carry, lp):
        y, aux = _block_forward(cfg, lp, carry, positions, ctx, moe_layer)
        return activation(y, "batch", "seq", None), aux

    body = _remat(body, cfg)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(body, x, stacked)
        return x, jnp.sum(auxs)
    n = jax.tree.leaves(stacked)[0].shape[0]
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(n):
        lp = jax.tree.map(lambda t: t[i], stacked)
        x, aux = body(x, lp)
        aux_total += aux
    return x, aux_total


def _scan_mamba(cfg: ModelConfig, stacked: dict[str, Array], x: Array
                ) -> Array:
    def body(carry, lp):  # pre-norm residual mamba block
        h = rms_norm(carry, lp["norm_in"], cfg.norm_eps)
        return carry + m2.mamba2_forward(lp, cfg, h), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def _hybrid_forward(cfg: ModelConfig, params: dict[str, Any], x: Array,
                    positions: Array, ctx: ShardingCtx) -> Array:
    n_groups, per, tail = _hybrid_shape(cfg)

    def superblock(carry, inp):
        gp, lora_a, lora_b = inp
        x = carry
        # shared attention block (weights broadcast, q-LoRA per invocation)
        sp = jax.tree.map(lambda t: t[0], params["shared_attn"])
        h = rms_norm(x, sp["ln1"], cfg.norm_eps)
        attn = gqa_attention(sp, cfg, h, positions, kv_chunk=KV_CHUNK)
        if cfg.shared_attn_lora:
            dq = dense(dense(h, lora_a), lora_b)
            attn = attn + dq
        x = x + attn
        h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
        x = x + dense_ffn(sp, cfg, h2)
        # inner mamba stack
        def inner(c, lp):
            hh = rms_norm(c, lp["norm_in"], cfg.norm_eps)
            return c + m2.mamba2_forward(lp, cfg, hh), None
        x, _ = jax.lax.scan(_remat(inner, cfg), x, gp)
        return x, None

    lora_a = params.get("shared_lora_a")
    lora_b = params.get("shared_lora_b")
    if lora_a is None:
        lora_a = jnp.zeros((n_groups, cfg.d_model, 1), x.dtype)
        lora_b = jnp.zeros((n_groups, 1, cfg.d_model), x.dtype)
    x, _ = jax.lax.scan(superblock, x, (params["groups"], lora_a, lora_b))
    if tail:
        def inner(c, lp):
            hh = rms_norm(c, lp["norm_in"], cfg.norm_eps)
            return c + m2.mamba2_forward(lp, cfg, hh), None
        x, _ = jax.lax.scan(_remat(inner, cfg), x, params["tail"])
    return x


def embed_tokens(cfg: ModelConfig, params: dict[str, Any], batch
                 ) -> Array:
    x = activation(jnp.take(params["embed"], batch["tokens"], axis=0),
                   "batch", "seq", None)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        b = x.shape[0]
        bidx = jnp.arange(b)[:, None]
        x = x.at[bidx, batch["vision_pos"]].set(
            batch["vision_embeds"].astype(x.dtype))
    return x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype) \
        if cfg.tie_embeddings else x


def backbone(cfg: ModelConfig, params: dict[str, Any], batch,
             ctx: ShardingCtx) -> tuple[Array, Array]:
    """Token embed -> final norm.  Returns (hidden [B,S,d], moe aux)."""
    x = embed_tokens(cfg, params, batch)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "vlm"):
        x, aux = _scan_blocks(cfg, params["layers"], x, positions, ctx, False)
    elif cfg.family == "moe":
        if cfg.first_dense_layers:
            x, _ = _scan_blocks(cfg, params["dense_layers"], x, positions,
                                ctx, False)
        x, aux = _scan_blocks(cfg, params["layers"], x, positions, ctx, True)
    elif cfg.family == "ssm":
        x = _scan_mamba(cfg, params["layers"], x)
    elif cfg.family == "hybrid":
        x = _hybrid_forward(cfg, params, x, positions, ctx)
    else:
        raise ValueError(cfg.family)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def _unembed_matrix(cfg: ModelConfig, params: dict[str, Any]) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def forward(cfg: ModelConfig, params: dict[str, Any], batch,
            ctx: ShardingCtx = ShardingCtx()) -> Array:
    """Full logits (use loss_fn for training: it never materializes these)."""
    x, _ = backbone(cfg, params, batch, ctx)
    return dense(x, _unembed_matrix(cfg, params))


def chunked_ce(cfg: ModelConfig, x: Array, w: Array, labels: Array
               ) -> tuple[Array, Array]:
    """Seq-chunked CE: logits chunks of [B, LOSS_CHUNK, V], never [B, S, V]."""
    b, s, d = x.shape
    chunk = min(LOSS_CHUNK, s)
    n = max(s // chunk, 1)
    chunk = s // n

    def ce_chunk(carry, inp):
        xc, yc = inp                          # [B, C, d], [B, C]
        logits = dense(xc, w)
        nll_sum, cnt = _ce_sums(logits, yc)
        loss_sum, tok = carry
        return (loss_sum + nll_sum, tok + cnt), None

    xc = activation(x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3),
                    None, "batch", None, None)
    yc = activation(labels.reshape(b, n, chunk).transpose(1, 0, 2),
                    None, "batch", None)
    (loss_sum, tok), _ = jax.lax.scan(
        _remat(ce_chunk, cfg), (jnp.zeros((), jnp.float32),
                                jnp.zeros((), jnp.int32)), (xc, yc))
    return loss_sum / jnp.maximum(tok, 1), tok


def loss_fn(cfg: ModelConfig, params: dict[str, Any], batch,
            ctx: ShardingCtx = ShardingCtx(),
            aux_weight: float = 0.01) -> tuple[Array, dict[str, Array]]:
    x, aux = backbone(cfg, params, batch, ctx)
    loss, tok = chunked_ce(cfg, x, _unembed_matrix(cfg, params),
                           batch["labels"])
    total = loss + aux_weight * aux
    return total, {"ce": loss, "moe_aux": aux, "tokens": tok}


def _ce_sums(logits: Array, labels: Array, ignore: int = -100
             ) -> tuple[Array, Array]:
    mask = labels != ignore
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), safe[..., None], axis=-1)[..., 0]
    return ((logz - picked) * mask).sum(), mask.sum().astype(jnp.int32)


# -- decode ---------------------------------------------------------------------


def decode_state_specs(cfg: ModelConfig, batch: int, seq: int
                       ) -> dict[str, Any]:
    """Cache/state ParamSpec tree for serve_step (shardable, abstractable)."""
    def kv_cache(layers: int | None) -> dict[str, ParamSpec]:
        if cfg.attn_kind == "mla":
            shp = lambda d: ((layers,) if layers else ()) + (batch, seq, d)
            axes = lambda: ((None,) if layers else ()) + (
                "batch", "cache_seq", None)
            return {
                "c_kv": ParamSpec(shp(cfg.kv_lora), axes()),
                "k_rope": ParamSpec(shp(cfg.qk_rope_dim), axes()),
            }
        shp = ((layers,) if layers else ()) + (
            batch, seq, cfg.n_kv_heads, cfg.head_dim)
        axes = ((None,) if layers else ()) + (
            "batch", "cache_seq", "cache_heads", None)
        return {"k": ParamSpec(shp, axes, init="zeros"),
                "v": ParamSpec(shp, axes, init="zeros")}

    def ssm_state(lead: tuple[int, ...]) -> dict[str, ParamSpec]:
        h, pdim, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.d_state
        gn = cfg.ssm_ngroups * cfg.d_state
        k = cfg.d_conv
        la = (None,) * len(lead)
        return {
            "ssm": ParamSpec(lead + (batch, h, pdim, n),
                             la + ("batch", "cache_heads", None, None),
                             init="zeros", dtype="float32"),
            "conv_x": ParamSpec(lead + (batch, k - 1, cfg.d_inner),
                                la + ("batch", None, "ssm_inner"),
                                init="zeros"),
            "conv_B": ParamSpec(lead + (batch, k - 1, gn),
                                la + ("batch", None, None), init="zeros"),
            "conv_C": ParamSpec(lead + (batch, k - 1, gn),
                                la + ("batch", None, None), init="zeros"),
        }

    if cfg.family in ("dense", "vlm"):
        return {"layers": kv_cache(cfg.num_layers)}
    if cfg.family == "moe":
        nd = cfg.first_dense_layers
        out: dict[str, Any] = {"layers": kv_cache(cfg.num_layers - nd)}
        if nd:
            out["dense_layers"] = kv_cache(nd)
        return out
    if cfg.family == "ssm":
        return {"layers": ssm_state((cfg.num_layers,))}
    if cfg.family == "hybrid":
        n_groups, per, tail = _hybrid_shape(cfg)
        out = {
            "groups": ssm_state((n_groups, per)),
            "shared": kv_cache(n_groups),
        }
        if tail:
            out["tail"] = ssm_state((tail,))
        return out
    raise ValueError(cfg.family)


def _block_decode(cfg: ModelConfig, p, x, cache, positions, cache_len,
                  ctx: ShardingCtx, moe_layer: bool):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        attn, cache = mla.mla_decode(p, cfg, h, cache, positions, cache_len)
    else:
        attn, cache = gqa_decode(p, cfg, h, cache, positions, cache_len)
    aux = jnp.zeros((), jnp.float32)
    if cfg.parallel_block:
        f = dense_ffn(p, cfg, h)
        return x + attn + f, cache
    x = x + attn
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe_layer:
        f, aux = moe_mod.moe_ffn(ctx, cfg, p, h2)
    else:
        f = dense_ffn(p, cfg, h2)
    return x + f, cache


def decode_step(cfg: ModelConfig, params: dict[str, Any],
                state: dict[str, Any], batch,
                ctx: ShardingCtx = ShardingCtx()
                ) -> tuple[Array, dict[str, Any]]:
    """One-token decode.  batch: {"token": [B,1], "cache_len": [B],
    "positions": [B,1] or [3,B,1]}.  Returns (logits [B, vocab], new state).
    """
    x = jnp.take(params["embed"], batch["token"], axis=0)   # [B,1,d]
    positions = batch.get("positions")
    if positions is None:
        positions = batch["cache_len"][:, None]
    cache_len = batch.get("cache_len")
    new_state: dict[str, Any] = {}

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.first_dense_layers:
            def body_d(carry, inp):
                lp, lc = inp
                y, c = _block_decode(cfg, lp, carry, lc, positions,
                                     cache_len, ctx, False)
                return y, c
            x, new_dc = jax.lax.scan(
                body_d, x, (params["dense_layers"], state["dense_layers"]))
            new_state["dense_layers"] = new_dc

        moe_layer = cfg.family == "moe"

        def body(carry, inp):
            lp, lc = inp
            y, c = _block_decode(cfg, lp, carry, lc, positions, cache_len,
                                 ctx, moe_layer)
            return y, c

        x, new_c = jax.lax.scan(body, x, (params["layers"], state["layers"]))
        new_state["layers"] = new_c

    elif cfg.family == "ssm":
        def body(carry, inp):
            lp, lc = inp
            hh = rms_norm(carry, lp["norm_in"], cfg.norm_eps)
            y, c = m2.mamba2_decode(lp, cfg, hh, lc)
            return carry + y, c

        x, new_c = jax.lax.scan(body, x, (params["layers"], state["layers"]))
        new_state["layers"] = new_c

    elif cfg.family == "hybrid":
        n_groups, per, tail = _hybrid_shape(cfg)
        lora_a = params.get("shared_lora_a")
        lora_b = params.get("shared_lora_b")
        if lora_a is None:
            lora_a = jnp.zeros((n_groups, cfg.d_model, 1), x.dtype)
            lora_b = jnp.zeros((n_groups, 1, cfg.d_model), x.dtype)
        sp = jax.tree.map(lambda t: t[0], params["shared_attn"])

        def superblock(carry, inp):
            gp, la, lb, shared_c, group_c = inp
            x = carry
            h = rms_norm(x, sp["ln1"], cfg.norm_eps)
            attn, shared_c = gqa_decode(sp, cfg, h, shared_c, positions,
                                        cache_len)
            attn = attn + dense(dense(h, la), lb)
            x = x + attn
            h2 = rms_norm(x, sp["ln2"], cfg.norm_eps)
            x = x + dense_ffn(sp, cfg, h2)

            def inner(c, inp2):
                lp, lc = inp2
                hh = rms_norm(c, lp["norm_in"], cfg.norm_eps)
                y, lc = m2.mamba2_decode(lp, cfg, hh, lc)
                return c + y, lc

            x, group_c = jax.lax.scan(inner, x, (gp, group_c))
            return x, (shared_c, group_c)

        x, (new_shared, new_groups) = jax.lax.scan(
            superblock, x,
            (params["groups"], lora_a, lora_b, state["shared"],
             state["groups"]))
        new_state["shared"] = new_shared
        new_state["groups"] = new_groups
        if tail:
            def inner(c, inp2):
                lp, lc = inp2
                hh = rms_norm(c, lp["norm_in"], cfg.norm_eps)
                y, lc = m2.mamba2_decode(lp, cfg, hh, lc)
                return c + y, lc
            x, new_tail = jax.lax.scan(inner, x,
                                       (params["tail"], state["tail"]))
            new_state["tail"] = new_tail
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = dense(x[:, 0], _unembed_matrix(cfg, params))
    return logits, new_state


# -- param counting ---------------------------------------------------------------


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    if cfg.family == "encdec":
        from repro.models.encdec import encdec_specs

        return spec_param_count(encdec_specs(cfg))
    specs = model_specs(cfg)
    total = spec_param_count(specs)
    if cfg.moe:
        e_pad = moe_mod.padded_experts(cfg)
        n_moe_layers = cfg.num_layers - cfg.first_dense_layers
        per_expert = 3 * cfg.d_model * cfg.moe_d_ff
        total -= n_moe_layers * per_expert * (e_pad - cfg.n_experts)  # padding
        if active_only:
            total -= n_moe_layers * per_expert * (cfg.n_experts - cfg.top_k)
    return total

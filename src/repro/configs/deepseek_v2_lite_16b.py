"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

27L d_model=2048 16H, MLA (kv_lora=512, nope 128 / rope 64 / v 128),
MoE 64 routed top-6 + 2 shared, per-expert d_ff=1408, layer 0 dense
(d_ff=10944), vocab=102400.  The assignment line reads "MoE 64e top-6" with
a "160 routed" aside; we follow the binding 64-routed reading (HF config).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        vocab=102400,
        n_heads=16,
        n_kv_heads=16,
        head_dim=192,            # qk_nope + qk_rope
        attn_kind="mla",
        q_lora=0,                # lite: direct q projection
        kv_lora=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        d_ff=10944,              # the single leading dense layer
        moe=True,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        shared_d_ff=2816,
        first_dense_layers=1,
        router_scale=True,
        rope_theta=10_000.0,
    ).validate()

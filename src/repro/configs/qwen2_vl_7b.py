"""Qwen2-VL-7B backbone [arXiv:2409.12191] — M-RoPE decoder.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  Vision frontend
is a STUB: input_specs() supplies precomputed patch embeddings; M-RoPE
(t/h/w sections 16/24/24 of the rotary half-dim) positions are inputs.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        vocab=152064,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        mrope=True,
        mrope_sections=(16, 24, 24),
        num_patches=1024,
        rope_theta=1_000_000.0,
    ).validate()

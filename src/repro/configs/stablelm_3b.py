"""StableLM-3B-class dense model [hf:stabilityai/stablelm-2; unverified].

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304, partial rotary 25%.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        num_layers=32,
        d_model=2560,
        vocab=50304,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        rope_fraction=0.25,
    ).validate()

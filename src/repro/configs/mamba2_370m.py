"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD.

48L d_model=1024, ssm_state=128, headdim=64 -> d_inner=2048 (32 heads),
vocab=50280, tied embeddings.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        attn_kind="none",
        num_layers=48,
        d_model=1024,
        vocab=50280,
        d_state=128,
        expand=2,
        ssm_headdim=64,
        ssm_ngroups=1,
        tie_embeddings=True,
    ).validate()

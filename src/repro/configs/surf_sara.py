"""SURF-SARA datacenter topology (paper §3.2) for the digital twin."""

from repro.traces.schema import DatacenterConfig


def config() -> DatacenterConfig:
    return DatacenterConfig(
        num_hosts=277,
        cores_per_host=16,
        ghz=2.1,
        mem_gb=128.0,
    )

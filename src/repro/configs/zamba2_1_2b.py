"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + shared attention.

38 Mamba2 layers (d_model=2048, ssm_state=64, headdim=64 -> d_inner=4096,
64 ssm heads); one SHARED transformer block (32H, d_ff=8192) invoked every
6 layers with per-invocation q-LoRA adapters; vocab=32000.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        vocab=32000,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        d_state=64,
        expand=2,
        ssm_headdim=64,
        ssm_ngroups=1,
        shared_attn_every=6,
        shared_attn_lora=128,
    ).validate()

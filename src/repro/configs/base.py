"""Model configuration schema covering all assigned architecture families."""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    vocab: int

    # -- attention ------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    attn_kind: Literal["gqa", "mla", "none"] = "gqa"
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0          # partial rotary (stablelm: 0.25)
    mrope: bool = False                  # Qwen2-VL multimodal RoPE
    mrope_sections: tuple[int, int, int] = (16, 24, 24)  # t/h/w of head_dim/2
    parallel_block: bool = False         # Cohere parallel attn+FFN
    attn_bias: bool = False
    qk_norm: bool = False

    # -- FFN --------------------------------------------------------------
    d_ff: int = 0
    ffn_act: Literal["swiglu", "gelu"] = "swiglu"

    # -- MLA (DeepSeek-V2 / MiniCPM3) --------------------------------------
    q_lora: int = 0                      # 0 = direct q projection
    kv_lora: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # -- MoE ----------------------------------------------------------------
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_d_ff: int = 0                    # per-expert hidden
    shared_d_ff: int = 0                 # shared-experts hidden (total)
    first_dense_layers: int = 0          # leading dense-FFN layers (DS-V2)
    router_scale: bool = False           # normalize top-k gates (DS-V2)

    # -- SSM (Mamba2/SSD) ----------------------------------------------------
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssd_chunk: int = 128

    # -- hybrid (Zamba2) -------------------------------------------------------
    shared_attn_every: int = 0           # one shared attn block per N ssm layers
    shared_attn_lora: int = 0            # per-invocation LoRA rank on shared block

    # -- enc-dec (Seamless backbone) -------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    num_frames: int = 512                # stub frontend: frames per sample

    # -- vlm (Qwen2-VL backbone) -------------------------------------------------
    num_patches: int = 0                 # stub frontend: patch embeds per sample

    # -- common -----------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # -- training -----------------------------------------------------------------
    remat: str = "dots"                  # none | dots | full
    scan_layers: bool = True

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def validate(self) -> "ModelConfig":
        if self.attn_kind == "gqa" and self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.moe:
            assert self.top_k > 0 and self.n_experts > 0 and self.moe_d_ff > 0
        if self.family in ("ssm", "hybrid"):
            assert self.d_state > 0 and self.d_inner % self.ssm_headdim == 0
        return self


def param_count(cfg: ModelConfig) -> int:
    """Approximate parameter count (used for 6*N*D MODEL_FLOPS)."""
    from repro.models.lm import count_params_analytic

    return count_params_analytic(cfg)


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) parameters — MoE uses top-k + shared experts only."""
    from repro.models.lm import count_params_analytic

    return count_params_analytic(cfg, active_only=True)

"""Seamless-M4T medium backbone [arXiv:2308.11596] — enc-dec.

12L encoder + 12L decoder, d_model=1024 16H (kv=16) d_ff=4096
vocab=256206.  The modality frontend is a STUB: input_specs() supplies
precomputed frame embeddings (assignment rule).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="encdec",
        num_layers=24,
        d_model=1024,
        vocab=256206,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        enc_layers=12,
        dec_layers=12,
        num_frames=512,
    ).validate()

"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) per-expert d_ff=1408 vocab=151936,
60 routed experts top-4 + 4 shared experts (shared hidden 4x1408=5632).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        vocab=151936,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=5632,
        moe=True,
        n_experts=60,
        n_shared_experts=4,
        top_k=4,
        moe_d_ff=1408,
        shared_d_ff=5632,
        rope_theta=1_000_000.0,
    ).validate()

"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — deep-thin MLA dense model.

62L d_model=2560 40H MLA (q_lora=768, kv_lora=256, nope 64 / rope 32 /
v 64) d_ff=6400 vocab=73448.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        vocab=73448,
        n_heads=40,
        n_kv_heads=40,
        head_dim=96,             # qk_nope + qk_rope
        attn_kind="mla",
        q_lora=768,
        kv_lora=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        d_ff=6400,
    ).validate()

"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M] — llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, tied embeddings.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        vocab=49152,
        n_heads=15,
        n_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        tie_embeddings=True,
    ).validate()

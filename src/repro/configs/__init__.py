"""Architecture registry: --arch <id> -> ModelConfig."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCHS: dict[str, str] = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "zamba2-1.2b": "zamba2_1_2b",
    "stablelm-3b": "stablelm_3b",
    "minicpm3-4b": "minicpm3_4b",
    "command-r-plus-104b": "command_r_plus_104b",
    "smollm-360m": "smollm_360m",
    "mamba2-370m": "mamba2_370m",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen2-vl-7b": "qwen2_vl_7b",
}

#: archs whose sequence handling is sub-quadratic (run long_500k)
SUBQUADRATIC = {"mamba2-370m", "zamba2-1.2b"}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.config()


def all_archs() -> list[str]:
    return list(ARCHS)

"""Command-R+-class 104B dense [hf:CohereForAI; unverified].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000, parallel
attention+FFN block, no biases.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12288,
        vocab=256000,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=33792,
        parallel_block=True,
        rope_theta=75_000_000.0,
    ).validate()

"""Trip-count-aware cost extraction from compiled HLO text.

``compiled.cost_analysis()`` on XLA:CPU counts ``while`` bodies ONCE
(verified empirically — see DESIGN.md §7), which under-counts scan-over-
layers models by ~L x.  Compiled HLO annotates loops with
``backend_config={"known_trip_count":{"n":...}}``; this module parses the
program, builds the computation call graph, and accumulates:

  * dot FLOPs              (2 x prod(out) x prod(contracting))
  * HBM bytes              (post-fusion: operands + results of top-level ops)
  * collective wire bytes  (per-device, with (g-1)/g factors per collective)

multiplied through while trip counts and call/fusion edges.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->")
_TRIP_RE = re.compile(r'known_trip_count[\"={\s:]+n[\":\s]+\"?(\d+)')
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    # plain elementwise ops at computation level: XLA:TPU fuses these into
    # neighbors, so charging their operands+results would double-count HBM
    # traffic that never happens on the target (XLA:CPU fuses less).
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "negate", "abs", "compare", "select", "and", "or", "not", "xor",
    "power", "rsqrt", "sqrt", "cbrt", "convert", "broadcast", "reshape",
    "clamp", "floor", "ceil", "sign", "cosine", "sine", "is-finite",
    "reduce-precision", "atan2", "expm1", "log1p", "logistic",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "round-nearest-afz", "round-nearest-even", "popcnt", "clz",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _parse_shape(type_str: str) -> tuple[int, list[tuple[str, list[int]]]]:
    """-> (total bytes, [(dtype, dims), ...]) handling tuple types."""
    out = []
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in dims_s.split(",") if x] or [1]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        out.append((dt, dims))
    return total, out


@dataclasses.dataclass
class OpInfo:
    name: str
    opcode: str
    out_bytes: int
    out_dims: list[int]
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[OpInfo]
    shapes: dict[str, tuple[int, list[int]]]   # sym -> (bytes, dims of first)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0          # per-device wire bytes
    coll_counts: dict[str, int] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    {kk: int(v * k) for kk, v in self.coll_counts.items()})


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if not line.strip() or line.strip().startswith("//"):
            continue
        if not line.startswith(" ") and (line.startswith("ENTRY")
                                         or line.lstrip().startswith("%")) \
                and "->" in line and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[m.group(1)] = cur
                if line.startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        out_bytes, shapes = _parse_shape(type_str)
        dims = shapes[0][1] if shapes else []
        operands = _OPERAND_RE.findall(rest.split(" metadata=")[0])
        cur.ops.append(OpInfo(name, opcode, out_bytes, dims, operands, rest))
        cur.shapes[name] = (out_bytes, dims)
    return comps


#: ops that pin HBM traffic even inside a fusion (TPU-fusion approximation)
_HEAVY_OPS = {
    "dot", "reduce", "scatter", "gather", "dynamic-slice",
    "dynamic-update-slice", "sort", "concatenate", "pad", "slice",
    "transpose", "reduce-window", "convolution", "reverse", "rng",
    "copy",
}


def _is_heavy(comp: "Computation | None") -> bool:
    if comp is None:
        return True                 # unknown body: be conservative
    return any(op.opcode in _HEAVY_OPS for op in comp.ops)


def _group_size(attrs: str, total_devices: int) -> int:
    m = _GROUPS_RE.search(attrs)
    if m:
        return int(m.group(2))              # [n_groups, group_size]
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return max(len(m.group(1).strip("{}").split(",")), 1)
    return max(total_devices, 1)


def _collective_wire_bytes(opcode: str, op: OpInfo,
                           comp: Computation, g: int) -> float:
    in_bytes = sum(comp.shapes.get(o, (0, []))[0] for o in op.operands
                   if o in comp.shapes)
    out_bytes = op.out_bytes
    frac = (g - 1) / g if g > 1 else 0.0
    base = opcode.replace("-start", "")
    if base == "all-gather":
        return out_bytes * frac
    if base == "all-reduce":
        return 2.0 * out_bytes * frac
    if base == "reduce-scatter":
        return in_bytes * frac
    if base == "all-to-all":
        return max(in_bytes, out_bytes) * frac
    if base == "collective-permute":
        return float(out_bytes)
    return 0.0


def _dot_flops(op: OpInfo, comp: Computation) -> float:
    out_elems = 1
    for d in op.out_dims:
        out_elems *= d
    m = _CDIMS_RE.search(op.attrs)
    k = 1
    if m and op.operands:
        lhs = op.operands[0]
        _, lhs_dims = comp.shapes.get(lhs, (0, []))
        for idx_s in m.group(1).split(","):
            if idx_s and lhs_dims and int(idx_s) < len(lhs_dims):
                k *= lhs_dims[int(idx_s)]
    return 2.0 * out_elems * k


def compute_cost(comps: dict[str, Computation], total_devices: int,
                 _memo: dict[str, Cost] | None = None,
                 name: str = "__entry__") -> Cost:
    memo = _memo if _memo is not None else {}
    if name in memo:
        return memo[name]
    comp = comps.get(name)
    total = Cost()
    if comp is None:
        return total
    memo[name] = total                      # break accidental cycles
    for op in comp.ops:
        oc = op.opcode
        called = _CALLED_RE.findall(op.attrs)
        if oc == "while":
            m = _TRIP_RE.search(op.attrs)
            trips = int(m.group(1)) if m else 1
            inner = Cost()
            for c in called:
                inner += compute_cost(comps, total_devices, memo, c)
            total += inner.scaled(trips)
            continue
        if oc in ("fusion", "call", "conditional", "async-start"):
            for c in called:
                inner = compute_cost(comps, total_devices, memo, c)
                if oc == "fusion":
                    # a fusion's HBM traffic is its boundary, not its body
                    inner = Cost(flops=inner.flops, bytes=0.0,
                                 coll_bytes=inner.coll_bytes,
                                 coll_counts=dict(inner.coll_counts))
                total += inner
            if oc == "fusion" and any(_is_heavy(comps.get(c)) for c in called):
                # XLA:CPU fuses far less than XLA:TPU; pure-elementwise
                # fusions (convert/multiply chains) merge into neighboring
                # matmuls on the TPU target, so only fusions containing a
                # heavy op charge their boundary traffic.
                in_b = sum(comp.shapes.get(o, (0, []))[0]
                           for o in op.operands if o in comp.shapes)
                total += Cost(bytes=float(in_b + op.out_bytes))
            continue
        if oc == "dot":
            f = _dot_flops(op, comp)
            in_b = sum(comp.shapes.get(o, (0, []))[0]
                       for o in op.operands if o in comp.shapes)
            total += Cost(flops=f, bytes=float(in_b + op.out_bytes))
            continue
        if oc in _COLLECTIVES:
            g = _group_size(op.attrs, total_devices)
            wire = _collective_wire_bytes(oc, op, comp, g)
            total += Cost(coll_bytes=wire,
                          coll_counts={oc.replace("-start", ""): 1})
            continue
        if oc in _SKIP_BYTES_OPS or oc.endswith("-done"):
            continue
        # generic op: HBM traffic only
        in_b = sum(comp.shapes.get(o, (0, []))[0]
                   for o in op.operands if o in comp.shapes)
        total += Cost(bytes=float(in_b + op.out_bytes))
    memo[name] = total
    return total


def analyze_compiled_text(text: str, total_devices: int) -> dict[str, Any]:
    comps = parse_hlo(text)
    cost = compute_cost(comps, total_devices)
    return {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes,
        "collective_wire_bytes_per_device": cost.coll_bytes,
        "collective_counts": cost.coll_counts,
        "num_computations": len(comps) - 1,
    }

"""Three-term roofline from the compiled dry-run artifact.

Hardware model (TPU v5e-class target, per chip):
  peak bf16 compute   197 TFLOP/s
  HBM bandwidth       819 GB/s
  ICI                 ~50 GB/s per link.  Collectives ride the links of
                      their mesh axis; we charge the conservative
                      single-link rate (ring algorithms overlap both
                      directions, so real deployments can do up to ~2x
                      better — the relative comparisons are unaffected).

Terms (seconds, per device — the roofline lower-bounds step latency):
  compute    = HLO_FLOPs / peak
  memory     = HLO_bytes / HBM_bw
  collective = wire_bytes / ICI_bw
"""

from __future__ import annotations

import dataclasses
from typing import Any

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (one link charged)


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    wire_bytes: float
    model_flops: float          # 6 * N(_active) * D, global
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (global) — remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: how close the step's *useful*
        math runs to the hardware roofline if the bound is achieved."""
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful_s / self.bound_s if self.bound_s else 0.0

    def to_dict(self) -> dict[str, Any]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "model_flops_global": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def make_roofline(parsed: dict[str, Any], model_flops: float,
                  chips: int) -> Roofline:
    f = parsed["flops_per_device"]
    b = parsed["bytes_per_device"]
    w = parsed["collective_wire_bytes_per_device"]
    return Roofline(
        compute_s=f / PEAK_FLOPS,
        memory_s=b / HBM_BW,
        collective_s=w / ICI_BW,
        flops=f, bytes=b, wire_bytes=w,
        model_flops=model_flops, chips=chips,
    )


def model_flops_for(cfg, shape_kind: str, batch: int, seq: int,
                    train: bool) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode processes batch tokens."""
    from repro.configs.base import active_param_count

    n = active_param_count(cfg)
    if shape_kind == "train":
        d = batch * seq
        return 6.0 * n * d
    if shape_kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: one token per sequence

"""Straggler detection driven by the digital twin's step-time prediction.

The twin predicts what a training step *should* cost (roofline-derived
expectation, continuously re-centered on observed telemetry with the same
EWMA-style self-calibration idea as the power model).  Hosts whose reported
step times sit far above the calibrated expectation get flagged; the runtime
proposes RESTART_STRAGGLER through the HITL gate (paper stage 3 semantics —
the twin recommends, the operator/policy approves).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.feedback import Proposal, ProposalKind


@dataclasses.dataclass
class StragglerConfig:
    ewma: float = 0.1               # calibration rate for expected step time
    threshold: float = 1.35         # flag hosts slower than 1.35x expectation
    min_samples: int = 8            # warmup before flagging
    hysteresis: int = 3             # consecutive slow windows before proposal


class StragglerDetector:
    def __init__(self, num_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.expected: float | None = None       # calibrated step seconds
        self.samples = 0
        self.slow_streak = np.zeros(num_hosts, np.int32)

    def observe(self, step_seconds_per_host: np.ndarray, window: int
                ) -> list[Proposal]:
        """Per-host step durations for one window -> straggler proposals."""
        t = np.asarray(step_seconds_per_host, np.float64)
        med = float(np.median(t))
        if self.expected is None:
            self.expected = med
        else:
            self.expected = ((1 - self.cfg.ewma) * self.expected
                             + self.cfg.ewma * med)
        self.samples += 1
        if self.samples < self.cfg.min_samples:
            return []
        slow = t > self.cfg.threshold * self.expected
        self.slow_streak = np.where(slow, self.slow_streak + 1, 0)
        out = []
        for h in np.nonzero(self.slow_streak >= self.cfg.hysteresis)[0]:
            out.append(Proposal(
                ProposalKind.RESTART_STRAGGLER, window,
                f"host {h}: {t[h]:.2f}s/step vs calibrated "
                f"{self.expected:.2f}s ({t[h]/self.expected:.2f}x) for "
                f"{int(self.slow_streak[h])} windows",
                impact={"host": int(h), "ratio": float(t[h] / self.expected)},
            ))
            self.slow_streak[h] = 0               # proposal in flight
        return out


def degradation_from_stragglers(proposals, *, start_bin: int,
                                duration_bins: int):
    """Straggler proposals -> DEGRADED failure windows for the what-if DES.

    Bridges runtime detection into the scenario engine's failure axis: each
    RESTART_STRAGGLER proposal becomes a drain window (no new placements,
    running jobs finish, power still drawn) starting at ``start_bin`` —
    i.e. "what if we drained the flagged hosts for the next N bins".
    Duplicate hosts collapse to one window (the DES carries one per host).
    """
    from repro.runtime.fault import DEGRADED, HostFailure

    hosts = []
    for p in proposals:
        if p.kind is not ProposalKind.RESTART_STRAGGLER:
            continue
        h = int(p.impact["host"])
        if h not in hosts:
            hosts.append(h)
    return tuple(
        HostFailure(h, start_bin, start_bin + duration_bins, kind=DEGRADED)
        for h in hosts)

"""Fault-tolerant training driver: checkpoint / crash / restore / re-mesh.

``run_with_restarts`` wraps a step function in the restart loop a cluster
scheduler would drive: periodic checkpoints, (optionally injected) failures,
restore-from-latest on restart, elastic re-mesh when the surviving device
count changed.  The same loop hosts the digital twin: telemetry flows into
the twin each window and approved proposals flow back (straggler restarts,
power caps).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from repro.checkpoint import ckpt
from repro.runtime.elastic import MeshPlan, plan_mesh


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50            # steps
    keep: int = 3
    max_restarts: int = 10


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: kill at steps."""

    fail_at_steps: tuple[int, ...] = ()
    device_loss: int = 0            # devices lost at each failure
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(step, self.device_loss)


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int, device_loss: int):
        super().__init__(f"simulated node failure at step {step}")
        self.step = step
        self.device_loss = device_loss


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    checkpoints: int
    losses: list[float]
    restored_from: list[int]


def run_with_restarts(
    *,
    total_steps: int,
    make_state: Callable[[], Any],
    step_fn: Callable[[Any, int], tuple[Any, float]],
    fault_cfg: FaultConfig = FaultConfig(),
    injector: FailureInjector | None = None,
    on_window: Callable[[int, Any], None] | None = None,
) -> RunReport:
    """Drive step_fn to total_steps across simulated crashes.

    make_state: fresh job state (params, opt, data cursor, twin state).
    step_fn(state, step) -> (state', loss).
    """
    report = RunReport(0, 0, 0, [], [])
    restarts = 0
    while True:
        start = ckpt.latest_step(fault_cfg.ckpt_dir)
        if start is None:
            state = make_state()
            step0 = 0
        else:
            step0, host_state = ckpt.restore(fault_cfg.ckpt_dir)
            state = _rehydrate(make_state(), host_state)
            report.restored_from.append(step0)
        try:
            for step in range(step0, total_steps):
                if injector is not None:
                    injector.check(step)
                state, loss = step_fn(state, step)
                report.losses.append(loss)
                report.steps_done = step + 1
                if (step + 1) % fault_cfg.ckpt_every == 0:
                    ckpt.save(fault_cfg.ckpt_dir, step + 1, state,
                              keep=fault_cfg.keep)
                    report.checkpoints += 1
                if on_window is not None:
                    on_window(step, state)
            return report
        except SimulatedFailure:
            restarts += 1
            report.restarts = restarts
            if restarts > fault_cfg.max_restarts:
                raise
            # loop: restore from latest checkpoint and continue
            continue


def _rehydrate(template: Any, host_state: Any) -> Any:
    import jax
    import jax.numpy as jnp

    flat_t, tdef = jax.tree.flatten(template)
    flat_h = jax.tree.leaves(host_state)
    assert len(flat_t) == len(flat_h), "state structure changed across restart"
    out = []
    for t, h in zip(flat_t, flat_h):
        if hasattr(t, "dtype"):
            out.append(jnp.asarray(np.asarray(h)).astype(t.dtype))
        else:
            out.append(h)
    return tdef.unflatten(out)

"""Fault-tolerant training driver + host-failure schedules for the DES.

Two layers share this module because they model the same physical event
(a host dying) at different granularities:

* :class:`HostFailure` / :func:`failure_arrays` — *scenario-axis* failure
  schedules.  A tuple of per-host outage/degradation windows becomes
  three dense ``[max_hosts]`` arrays (start, end, kill-flag) the batched
  DES folds into a time-varying host mask, so "rack 3 dies at noon" is
  one traced lane of a what-if batch.
* ``run_with_restarts`` — the *training-loop* restart driver a cluster
  scheduler would run: periodic checkpoints, (optionally injected)
  failures, restore-from-latest, elastic re-mesh when the surviving
  device count changed.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

from repro.checkpoint import ckpt
from repro.runtime.elastic import MeshPlan, plan_mesh

#: schedule sentinel for "this host never fails": the window start sits
#: past any representable bin, so every `start <= t < end` test is false
#: and the compiled program is bit-for-bit the no-failure program.
NEVER_BIN = np.iinfo(np.int32).max

#: failure kinds: an OUTAGE kills running jobs and draws no power for the
#: window; a DEGRADED host drains — no new placements, but running jobs
#: finish normally and the host keeps drawing power.
OUTAGE = "outage"
DEGRADED = "degraded"


@dataclasses.dataclass(frozen=True)
class HostFailure:
    """One per-host failure window ``[start_bin, end_bin)`` on the DES clock.

    ``kind="outage"`` models a hard failure: jobs running on the host at
    ``start_bin`` are killed (their cores come back when the host does,
    at ``end_bin``), the host accepts no placements and draws no power
    during the window.  ``kind="degraded"`` models a drain/slow host:
    no *new* placements land during the window, but running jobs keep
    running and the host keeps drawing power.
    """

    host: int
    start_bin: int
    end_bin: int
    kind: str = OUTAGE

    def __post_init__(self):
        if self.host < 0:
            raise ValueError(f"failure host must be >= 0, got {self.host}")
        if not 0 <= self.start_bin < self.end_bin:
            raise ValueError(
                f"failure window must satisfy 0 <= start < end, got "
                f"[{self.start_bin}, {self.end_bin})")
        if self.kind not in (OUTAGE, DEGRADED):
            raise ValueError(
                f"failure kind must be {OUTAGE!r} or {DEGRADED!r}, "
                f"got {self.kind!r}")


def failure_arrays(failures, max_hosts: int
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Dense ``[max_hosts]`` (start, end, kill) arrays from a failure tuple.

    Hosts without a window get the ``NEVER_BIN`` sentinel start (and end
    0), so the traced comparisons are false at every bin — disabled lanes
    in a mixed batch run the exact no-failure program.  One window per
    host: the DES carries a single (start, end) pair per host, so
    overlapping schedules must be merged by the caller.
    """
    fs = np.full(max_hosts, NEVER_BIN, np.int32)
    fe = np.zeros(max_hosts, np.int32)
    kill = np.zeros(max_hosts, bool)
    for f in failures:
        if f.host >= max_hosts:
            raise ValueError(
                f"failure host {f.host} out of range for {max_hosts} hosts")
        if fs[f.host] != NEVER_BIN:
            raise ValueError(
                f"host {f.host} has multiple failure windows; the DES "
                "carries one window per host — merge them first")
        fs[f.host] = f.start_bin
        fe[f.host] = f.end_bin
        kill[f.host] = f.kind == OUTAGE
    return fs, fe, kill


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50            # steps
    keep: int = 3
    max_restarts: int = 10


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: kill at steps."""

    fail_at_steps: tuple[int, ...] = ()
    device_loss: int = 0            # devices lost at each failure
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(step, self.device_loss)


class SimulatedFailure(RuntimeError):
    def __init__(self, step: int, device_loss: int):
        super().__init__(f"simulated node failure at step {step}")
        self.step = step
        self.device_loss = device_loss


@dataclasses.dataclass
class RunReport:
    steps_done: int
    restarts: int
    checkpoints: int
    losses: list[float]
    restored_from: list[int]


def run_with_restarts(
    *,
    total_steps: int,
    make_state: Callable[[], Any],
    step_fn: Callable[[Any, int], tuple[Any, float]],
    fault_cfg: FaultConfig = FaultConfig(),
    injector: FailureInjector | None = None,
    on_window: Callable[[int, Any], None] | None = None,
) -> RunReport:
    """Drive step_fn to total_steps across simulated crashes.

    make_state: fresh job state (params, opt, data cursor, twin state).
    step_fn(state, step) -> (state', loss).
    """
    report = RunReport(0, 0, 0, [], [])
    restarts = 0
    while True:
        start = ckpt.latest_step(fault_cfg.ckpt_dir)
        if start is None:
            state = make_state()
            step0 = 0
        else:
            step0, host_state = ckpt.restore(fault_cfg.ckpt_dir)
            state = _rehydrate(make_state(), host_state)
            report.restored_from.append(step0)
        try:
            for step in range(step0, total_steps):
                if injector is not None:
                    injector.check(step)
                state, loss = step_fn(state, step)
                report.losses.append(loss)
                report.steps_done = step + 1
                if (step + 1) % fault_cfg.ckpt_every == 0:
                    ckpt.save(fault_cfg.ckpt_dir, step + 1, state,
                              keep=fault_cfg.keep)
                    report.checkpoints += 1
                if on_window is not None:
                    on_window(step, state)
            return report
        except SimulatedFailure:
            restarts += 1
            report.restarts = restarts
            if restarts > fault_cfg.max_restarts:
                raise
            # loop: restore from latest checkpoint and continue
            continue


def _rehydrate(template: Any, host_state: Any) -> Any:
    import jax
    import jax.numpy as jnp

    flat_t, tdef = jax.tree.flatten(template)
    flat_h = jax.tree.leaves(host_state)
    assert len(flat_t) == len(flat_h), "state structure changed across restart"
    out = []
    for t, h in zip(flat_t, flat_h):
        if hasattr(t, "dtype"):
            out.append(jnp.asarray(np.asarray(h)).astype(t.dtype))
        else:
            out.append(h)
    return tdef.unflatten(out)

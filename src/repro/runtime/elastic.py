"""Elastic re-meshing after node loss / capacity change.

On a real fleet this re-runs device discovery; here the policy layer is what
matters: given the surviving device count, pick the largest valid
(pod, data, model) mesh that preserves the model-parallel degree (TP size is
an algorithmic invariant — changing it re-shards every weight), shrink the
data axis, and rescale per-shard batch so the GLOBAL batch stays constant
(bitwise-stable loss scaling across restarts).
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from repro.parallel.sharding import make_mesh_compat


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    per_shard_batch: int
    grad_accum: int

    @property
    def data_shards(self) -> int:
        s = dict(zip(self.axes, self.shape))
        return s.get("data", 1) * s.get("pod", 1)


def plan_mesh(
    available_devices: int,
    *,
    model_parallel: int,
    global_batch: int,
    prefer_pods: int = 1,
) -> MeshPlan:
    """Largest data-parallel degree that fits the surviving devices."""
    if available_devices < model_parallel:
        raise RuntimeError(
            f"cannot re-mesh: {available_devices} devices < TP degree "
            f"{model_parallel}")
    data = available_devices // model_parallel
    # data shards must divide the global batch; shrink until they do,
    # adding gradient accumulation to keep the global batch constant.
    while data > 1 and global_batch % data != 0:
        data -= 1
    pods = prefer_pods if data % prefer_pods == 0 else 1
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    if pods > 1:
        shape, axes = (pods, data // pods, model_parallel), ("pod", "data", "model")
    else:
        shape, axes = (data, model_parallel), ("data", "model")
    per_shard = global_batch // data
    return MeshPlan(shape=shape, axes=axes, per_shard_batch=per_shard,
                    grad_accum=1)


def build_mesh(plan: MeshPlan, devices) -> Mesh:
    """Materialize a plan over an explicit device list.

    ``devices`` is required (pass ``jax.devices()`` at the call site): mesh
    re-planning after a failure must be a pure function of the surviving
    device set the caller observed, not of ambient discovery at build time
    (tracecheck TC007 — the runtime layer is deterministic-core).
    """
    n = int(np.prod(plan.shape))
    return make_mesh_compat(plan.shape, plan.axes, devices=devices[:n])

"""Workload-trace schema.

Mirrors the OpenDC workload input format (fragments of jobs with CPU demand)
at the granularity the paper reads out (5-minute sampling).  A trace is a
struct-of-arrays over jobs — dense tensors, directly consumable by the
vectorized simulator.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: industry-standard sampling granularity used throughout the paper (§3.3).
SAMPLE_SECONDS = 300.0  # 5 minutes


@dataclasses.dataclass(frozen=True)
class Workload:
    """A job trace, struct-of-arrays, SURF-22 shaped.

    Attributes:
      submit_bin: ``[J] int32`` — submission time, in 5-min bins from t0.
      duration_bins: ``[J] int32`` — runtime in bins (ceil).
      cores: ``[J] int32`` — cores requested (single-host jobs, <= cores/host).
      util_levels: ``[J, U] float32`` — piecewise utilization profile of the
        job over its lifetime, expressed as U equal-length phases of per-core
        utilization in [0, 1] (OpenDC "fragments").
      valid: ``[J] bool`` — padding mask (traces are padded to fixed J).
      deferrable: ``[J] bool`` or ``None`` — which jobs tolerate submission
        time-shifting (batch/background work vs. interactive).  ``None``
        means *all* jobs are deferrable — the permissive default keeps
        carbon-aware time-shift scenarios (``Scenario.shift_bins``)
        available on traces that carry no deferability metadata.
    """

    submit_bin: Array
    duration_bins: Array
    cores: Array
    util_levels: Array
    valid: Array
    deferrable: Array | None = None

    @property
    def num_jobs(self) -> int:
        return int(self.submit_bin.shape[0])

    @property
    def num_phases(self) -> int:
        return int(self.util_levels.shape[1])

    def cpu_hours(self) -> Array:
        """Total CPU-hours per job (core-hours, the SURF-22 reporting unit)."""
        hours = self.duration_bins.astype(jnp.float32) * (SAMPLE_SECONDS / 3600.0)
        return jnp.where(self.valid, hours * self.cores.astype(jnp.float32), 0.0)


jax.tree_util.register_pytree_node(
    Workload,
    lambda w: ((w.submit_bin, w.duration_bins, w.cores, w.util_levels,
                w.valid, w.deferrable), None),
    lambda _, c: Workload(*c),
)


@dataclasses.dataclass(frozen=True)
class DatacenterConfig:
    """Static topology of the twinned datacenter (paper §3.2: SURF-SARA)."""

    num_hosts: int = 277
    cores_per_host: int = 16
    ghz: float = 2.1
    mem_gb: float = 128.0
    #: double-precision FLOPs per core per cycle (FMA width) — used for the
    #: TFLOPs performance metric in E1's extension (Fig. 5B).
    flops_per_cycle: float = 16.0

    @property
    def peak_tflops(self) -> float:
        """Peak datacenter TFLOP/s at 100 % utilization."""
        return (
            self.num_hosts * self.cores_per_host * self.ghz * 1e9 * self.flops_per_cycle
        ) / 1e12


def stack_workloads(ws: "list[Workload] | tuple[Workload, ...]") -> Workload:
    """Stack S workloads into one batched Workload with leaves ``[S, J, ...]``.

    Workloads with differing job counts are first padded (see
    :func:`pad_workload`) to the common maximum so every scenario is
    shape-identical — the precondition for vmapping the DES over the
    scenario axis (``repro.core.scenarios``).
    """
    if not ws:
        raise ValueError("need at least one workload to stack")
    to_jobs = max(w.num_jobs for w in ws)
    padded = [pad_workload(w, to_jobs) for w in ws]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *padded)


def host_mask(num_hosts: "int | np.ndarray | Array", max_hosts: int) -> Array:
    """Active-host mask(s) ``[..., max_hosts]`` for a padded host axis.

    ``num_hosts`` may be a scalar (one mask) or an ``[S]`` vector (a mask per
    scenario).
    """
    n = jnp.asarray(num_hosts, jnp.int32)
    return jnp.arange(max_hosts, dtype=jnp.int32) < n[..., None]


def pad_workload(w: Workload, to_jobs: int) -> Workload:
    """Pad a workload to a fixed job count (static shapes for jit)."""
    j = w.num_jobs
    if j >= to_jobs:
        return w
    pad = to_jobs - j

    def _pad(x, fill=0):
        widths = [(0, pad)] + [(0, 0)] * (x.ndim - 1)
        return jnp.pad(x, widths, constant_values=fill)

    return Workload(
        submit_bin=_pad(w.submit_bin, np.iinfo(np.int32).max // 4),
        duration_bins=_pad(w.duration_bins, 1),
        cores=_pad(w.cores, 1),
        util_levels=_pad(w.util_levels, 0.0),
        valid=_pad(w.valid, False),
        deferrable=(None if w.deferrable is None
                    else _pad(w.deferrable, False)),
    )

"""Synthetic SURF-22 workload + ground-truth telemetry synthesis.

The SURF-22 trace (Versluis et al., FGCS'23 [34]) is public but not vendored
in this offline container.  ``make_surf22_like`` generates a statistically
matched surrogate: 277 hosts x 16 cores @ 2.1 GHz, lognormal job durations
with mean ~39.52 CPU-hours [28], diurnal Poisson arrivals, and piecewise
utilization profiles (OpenDC-style fragments).

Ground-truth power telemetry (``synthesize_ground_truth``) comes from a
*richer hidden model* the simulator does not know about (paper §2.4: "hardware
behavior varies with temperature, aging, and firmware updates"):

  * per-host spread of P_idle / P_max (manufacturing variation),
  * a slowly drifting calibration exponent r*(t) (thermal/aging drift),
  * heteroscedastic measurement noise.

This is what makes self-calibration *matter*: a static model drifts away from
reality exactly as §2.4 describes, and the calibrator tracks it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp

from repro.core.power import PowerParams, opendc_power
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig, Workload

#: bins per day at the 5-minute sampling granularity
BINS_PER_DAY = int(24 * 3600 / SAMPLE_SECONDS)  # 288


@dataclasses.dataclass(frozen=True)
class SurfTraceSpec:
    """Knobs of the synthetic SURF-22 surrogate."""

    days: float = 7.0
    mean_cpu_hours: float = 39.52      # SURF-22 mean job CPU-hours [28]
    duration_sigma: float = 1.1        # lognormal sigma of durations
    target_utilization: float = 0.28   # paper §3.3: "under 30 % ... used"
    seed: int = 22


def _num_bins(spec: SurfTraceSpec) -> int:
    return int(round(spec.days * BINS_PER_DAY))


def make_surf22_like(
    spec: SurfTraceSpec = SurfTraceSpec(),
    dc: DatacenterConfig = DatacenterConfig(),
    num_phases: int = 8,
) -> Workload:
    """Generate the synthetic SURF-22-like workload (numpy; host-side I/O)."""
    rng = np.random.default_rng(spec.seed)
    t_bins = _num_bins(spec)

    # Aggregate CPU demand so the mean *datacenter* utilization lands near the
    # paper's observed <30 %: total core-bins available x target share.
    total_core_bins = dc.num_hosts * dc.cores_per_host * t_bins * spec.target_utilization

    # Draw jobs until the demand mass is met.  Durations ~ lognormal with the
    # SURF-22 CPU-hour mean; core counts ~ SURF-like (1..16, skewed small).
    mean_bins = spec.mean_cpu_hours * 3600.0 / SAMPLE_SECONDS  # CPU-hours -> core-bins
    jobs: list[tuple[int, int, int]] = []
    mass = 0.0
    # lognormal parameterized to hit the requested mean of (duration*cores)
    mu = np.log(mean_bins) - spec.duration_sigma**2 / 2.0
    while mass < total_core_bins:
        core_bins = float(rng.lognormal(mu, spec.duration_sigma))
        cores = int(min(dc.cores_per_host, max(1, rng.geometric(0.35))))
        dur = int(np.clip(round(core_bins / cores), 1, t_bins))
        # diurnal arrival: more submissions during working hours
        day = rng.integers(0, max(1, int(spec.days)))
        hour_weights = 0.5 + 0.5 * np.sin(np.linspace(0, 2 * np.pi, 24, endpoint=False) - np.pi / 2) ** 2
        hour = rng.choice(24, p=hour_weights / hour_weights.sum())
        minute_bin = rng.integers(0, BINS_PER_DAY // 24)
        submit = int(day * BINS_PER_DAY + hour * (BINS_PER_DAY // 24) + minute_bin)
        submit = min(submit, t_bins - 1)
        jobs.append((submit, dur, cores))
        mass += dur * cores

    j = len(jobs)
    submit = np.array([x[0] for x in jobs], np.int32)
    dur = np.array([x[1] for x in jobs], np.int32)
    cores = np.array([x[2] for x in jobs], np.int32)

    # Piecewise utilization profiles: jobs run hot with phase structure
    # (ramp-up, steady, I/O dips) — OpenDC fragment style.
    base = rng.beta(2.2, 1.3, size=(j, 1)).astype(np.float32)    # wide spread, ~0.63 mean
    wobble = rng.normal(0, 0.08, size=(j, num_phases)).astype(np.float32)
    ramp = np.linspace(0.6, 1.0, num_phases, dtype=np.float32)[None, :]
    util = np.clip(base * ramp + wobble, 0.05, 1.0)

    # sort by submission: the simulator places in submit order (FCFS)
    order = np.argsort(submit, kind="stable")
    return Workload(
        submit_bin=jnp.asarray(submit[order]),
        duration_bins=jnp.asarray(dur[order]),
        cores=jnp.asarray(cores[order]),
        util_levels=jnp.asarray(util[order]),
        valid=jnp.ones((j,), bool),
    )


@dataclasses.dataclass(frozen=True)
class GroundTruthSpec:
    """Hidden-model parameters for telemetry synthesis (unknown to the sim).

    The error budget mirrors §2.4 of the paper ("hardware behavior varies
    with temperature, aging, and firmware updates, while workload
    characteristics evolve"):

      * *level terms* — true idle/max draw differ from the configured
        defaults (spec sheets lie); produces the under-estimation bias the
        paper observes in Fig. 6;
      * *drift terms* — r*(t) ramps (aging/firmware) with a diurnal thermal
        wobble; a low-frequency facility wander (cooling share) — the part
        live re-calibration can track;
      * *noise terms* — heteroscedastic meter/sub-sampling noise: 5-min
        mean-power samples hide within-bin dynamics, so noise scales with the
        *active* (above-idle) power, plus a small absolute meter floor —
        irreducible for any 5-min simulator, calibrated or not.
    """

    p_idle_mean: float = 71.5         # true idle (sim assumes 70.0)
    p_idle_spread: float = 6.0        # per-host sigma, W
    p_max_mean: float = 362.0         # true max (sim assumes 350.0)
    p_max_spread: float = 18.0        # per-host sigma, W
    r_start: float = 1.45             # true exponent at t0
    r_end: float = 3.40               # true exponent at t_end (aging drift)
    r_diurnal: float = 0.10           # thermal diurnal wobble on r*(t)
    wander_daily_sigma: float = 0.02  # facility share random walk per day
    noise_active_frac: float = 0.10   # sub-bin dynamics ~ active power
    noise_total_frac: float = 0.006   # absolute meter noise floor
    step_day: float | None = 4.5      # firmware-update step change (day index)
    step_frac: float = 0.05           # fractional power jump at step_day
    seed: int = 7


def synthesize_ground_truth(
    u_th: np.ndarray | jnp.ndarray,
    gt: GroundTruthSpec = GroundTruthSpec(),
) -> np.ndarray:
    """Produce 'measured reality' power telemetry [T] from utilization [T,H].

    The hidden model is the OpenDC form but with per-host parameters, a
    time-varying exponent r*(t), facility wander and heteroscedastic meter
    noise.  The simulator only ever sees the *telemetry*, never these
    parameters.
    """
    u = np.asarray(u_th, np.float64)
    t_bins, num_hosts = u.shape
    rng = np.random.default_rng(gt.seed)

    p_idle_h = rng.normal(gt.p_idle_mean, gt.p_idle_spread, num_hosts)
    p_max_h = rng.normal(gt.p_max_mean, gt.p_max_spread, num_hosts)
    tt = np.linspace(0.0, 1.0, t_bins)
    days = max(t_bins / BINS_PER_DAY, 1.0)
    r_t = (
        gt.r_start
        + (gt.r_end - gt.r_start) * tt
        + gt.r_diurnal * np.sin(2 * np.pi * tt * days)
    )

    params = PowerParams(
        p_idle=jnp.asarray(p_idle_h[None, :]),
        p_max=jnp.asarray(p_max_h[None, :]),
        r=jnp.asarray(r_t[:, None]),
    )
    p_th = np.asarray(opendc_power(jnp.asarray(u), params), dtype=np.float64)
    total = p_th.sum(axis=1)
    idle_floor = float(p_idle_h.sum())
    active = np.maximum(total - idle_floor, 0.0)

    # low-frequency facility wander (cooling share follows ambient): a
    # mean-one geometric random walk with per-day sigma.
    step_sigma = gt.wander_daily_sigma / np.sqrt(BINS_PER_DAY)
    wander = np.exp(np.cumsum(rng.normal(0.0, step_sigma, t_bins)))

    # discrete firmware-update event: a step change in draw (paper §2.4)
    step = np.ones(t_bins)
    if gt.step_day is not None:
        step_bin = int(gt.step_day * BINS_PER_DAY)
        if 0 <= step_bin < t_bins:
            step[step_bin:] += gt.step_frac

    noise = (
        rng.normal(0.0, 1.0, t_bins) * (gt.noise_active_frac * active)
        + rng.normal(0.0, 1.0, t_bins) * (gt.noise_total_frac * total)
    )
    return (total * wander * step + noise).astype(np.float64)

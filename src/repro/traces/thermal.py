"""Ambient-temperature traces and a dynamic PUE/cooling model.

The paper's energy numbers assume a fixed facility overhead; real
datacenters don't — cooling power tracks the weather and the IT load
(HPC-digital-twin studies of scheduling vs power *and cooling* make the
same point).  This module supplies the two pieces the scenario engine
needs to make PUE a *traced* axis:

  * ambient-temperature traces (``[T]`` °C at the 5-minute sampling
    granularity): a loader (:func:`load_ambient`) with the same CSV/
    resampling machinery as :mod:`repro.traces.carbon`, a synthetic
    diurnal generator (:func:`make_diurnal_ambient`) and shared
    validation (:func:`validate_ambient`);
  * :class:`PUEParams` + :func:`dynamic_pue` — PUE as a function of the
    ambient trace and the instantaneous IT load:

        pue_t = base + amb_coeff * max(ambient_t - amb_ref, 0)
                     + load_coeff * (1 - load_frac_t)

    Hotter-than-reference air costs cooling power (chillers work
    harder); *low* IT load costs relative overhead (fans/CRACs don't
    scale down linearly — the classic partially-loaded-facility PUE
    penalty).  ``base >= 1`` by definition of PUE; with zero
    coefficients the model degrades to a constant overhead, and
    ``PUEParams()`` is the exact identity (facility power == IT power).

Downstream, the scenario engine multiplies the per-bin PUE into the
delivered-power readout (facility watts), so energy, gCO2 and energy
cost all price the cooling overhead.
"""

from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.traces.schema import SAMPLE_SECONDS

Array = jax.Array

#: same day length as repro.traces.surf.BINS_PER_DAY, derived here from
#: the schema directly — importing surf (or carbon) at module scope would
#: pull in repro.core and close an import cycle back to the trace layer.
BINS_PER_DAY = int(24 * 3600 / SAMPLE_SECONDS)  # 288

#: plausible outdoor-air band, °C: values outside trigger a sanity
#: *warning* (Kelvin or Fahrenheit fed as Celsius), not a rejection.
TYPICAL_RANGE = (-40.0, 60.0)


def validate_ambient(ambient: np.ndarray,
                     t_bins: int | None = None) -> np.ndarray:
    """Validate an ambient trace: 1-D, finite, length T; contiguous f32.

    >>> validate_ambient([20.0, 22.0]).dtype
    dtype('float32')
    >>> validate_ambient([[20.0]])
    Traceback (most recent call last):
        ...
    ValueError: ambient trace must be [T], got shape (1, 1)
    """
    arr = np.asarray(ambient, np.float32)
    if arr.ndim != 1:
        raise ValueError(f"ambient trace must be [T], got shape {arr.shape}")
    if arr.size == 0:
        raise ValueError("ambient trace is empty")
    if not np.isfinite(arr).all():
        raise ValueError("ambient trace contains non-finite values")
    if t_bins is not None and arr.shape[0] != t_bins:
        raise ValueError(
            f"ambient trace has {arr.shape[0]} bins, horizon needs {t_bins}"
            " (use load_ambient(..., t_bins=...) to resample)")
    if float(arr.min()) < TYPICAL_RANGE[0] or float(arr.max()) > TYPICAL_RANGE[1]:
        warnings.warn(
            f"ambient trace spans [{arr.min():.0f}, {arr.max():.0f}] °C, "
            f"outside the plausible outdoor band {TYPICAL_RANGE} — "
            "check the input units (Kelvin/Fahrenheit?)",
            stacklevel=2)
    return np.ascontiguousarray(arr)


def load_ambient(path: str, t_bins: int | None = None) -> np.ndarray:
    """Load a ``[T]`` °C ambient trace from a CSV-ish file.

    Same accepted layouts as :func:`repro.traces.carbon.load_carbon_intensity`
    (one value per line, or ``timestamp,value`` — last column wins; ``#``
    comments and one non-numeric header row are skipped).  With ``t_bins``
    the trace is tiled/truncated to the horizon (weather is
    diurnal-periodic at day length, like grid carbon).
    """
    vals: list[float] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            cell = line.split(",")[-1].strip()
            try:
                vals.append(float(cell))
            except ValueError:
                if vals:
                    raise ValueError(
                        f"{path}: non-numeric row {line!r} after data rows")
                continue  # header row
    arr = validate_ambient(np.asarray(vals, np.float32))
    if t_bins is not None:
        # local import: carbon pulls in repro.core at module scope
        from repro.traces.carbon import _resample
        arr = _resample(arr, t_bins)
    return arr


def make_diurnal_ambient(
    t_bins: int,
    *,
    base: float = 16.0,
    amplitude: float = 8.0,
    wander_daily_sigma: float = 0.5,
    seed: int | None = 0,
) -> np.ndarray:
    """Synthetic diurnal ambient-temperature trace ``[t_bins]`` (°C).

    A sinusoid peaking mid-afternoon (~15:00, thermal lag behind solar
    noon) and bottoming out pre-dawn, plus an optional per-day additive
    wander (°C, weather fronts).  ``seed=None`` disables the wander.

    >>> a = make_diurnal_ambient(288, seed=None)
    >>> a.shape
    (288,)
    >>> bool(a.max() <= 16.0 + 8.0 + 1e-5)
    True
    """
    if t_bins <= 0:
        raise ValueError(f"t_bins must be positive, got {t_bins}")
    tod = (np.arange(t_bins) % BINS_PER_DAY) / BINS_PER_DAY  # [0, 1) day phase
    out = base + amplitude * np.sin(2.0 * np.pi * (tod * 24.0 - 9.0) / 24.0)
    if seed is not None and wander_daily_sigma > 0:
        rng = np.random.default_rng(seed)
        n_days = -(-t_bins // BINS_PER_DAY)
        daily = rng.normal(0.0, wander_daily_sigma, n_days)
        out = out + np.repeat(daily, BINS_PER_DAY)[:t_bins]
    return validate_ambient(out.astype(np.float32), t_bins)


def _concrete(x) -> np.ndarray | None:
    """Concrete value or None for tracers (see power._concrete)."""
    if isinstance(x, jax.core.Tracer):
        return None
    try:
        return np.asarray(x)
    except Exception:
        return None


@dataclasses.dataclass(frozen=True)
class PUEParams:
    """Parameters of the dynamic PUE model (pytree; scalars or ``[S]``).

    ``base`` is the best-case facility overhead (>= 1 by the definition
    of PUE: facility power / IT power), ``amb_coeff`` the cooling
    penalty per °C above ``amb_ref``, ``load_coeff`` the partial-load
    penalty at zero IT utilization (both >= 0).  The default is the
    exact identity — multiplying by ``dynamic_pue`` with ``PUEParams()``
    leaves every watt bit-for-bit unchanged.

    >>> PUEParams().base
    1.0
    >>> PUEParams(base=0.8)
    Traceback (most recent call last):
        ...
    ValueError: PUE base must be >= 1 (facility/IT power ratio), got 0.8
    """

    base: Array | float = 1.0        # dimensionless, >= 1
    amb_coeff: Array | float = 0.0   # PUE per °C above amb_ref
    amb_ref: Array | float = 18.0    # °C free-cooling reference
    load_coeff: Array | float = 0.0  # PUE penalty at zero IT load

    def __post_init__(self):
        b = _concrete(self.base)
        if b is not None and b.size and (~np.isfinite(b) | (b < 1.0)).any():
            raise ValueError(
                f"PUE base must be >= 1 (facility/IT power ratio), "
                f"got {float(np.min(b))}")
        for name in ("amb_coeff", "load_coeff"):
            v = _concrete(getattr(self, name))
            if v is not None and v.size and (~np.isfinite(v) | (v < 0)).any():
                raise ValueError(
                    f"PUE {name} must be finite and >= 0, "
                    f"got {float(np.min(v))}")
        ar = _concrete(self.amb_ref)
        if ar is not None and ar.size and (~np.isfinite(ar)).any():
            raise ValueError("PUE amb_ref must be finite °C")


jax.tree_util.register_pytree_node(
    PUEParams,
    lambda p: ((p.base, p.amb_coeff, p.amb_ref, p.load_coeff), None),
    lambda _, c: PUEParams(*c),
)


def dynamic_pue(load_frac: Array, ambient_c: Array | None,
                params: PUEParams) -> Array:
    """Per-bin PUE from IT load and (optionally) the ambient trace.

    ``load_frac`` is the ``[T]`` mean IT utilization (clipped to [0, 1]);
    ``ambient_c`` the ``[T]`` °C trace or ``None`` (ambient term off).
    Returns ``[T]`` PUE >= base.  With ``PUEParams()`` the result is
    exactly 1.0 everywhere — an IEEE-exact identity multiplier.
    """
    load = jnp.clip(jnp.asarray(load_frac), 0.0, 1.0)
    pue = jnp.asarray(params.base, load.dtype) + jnp.asarray(
        params.load_coeff, load.dtype) * (1.0 - load)
    if ambient_c is not None:
        amb = jnp.asarray(ambient_c, load.dtype)
        pue = pue + jnp.asarray(params.amb_coeff, load.dtype) * jnp.maximum(
            amb - jnp.asarray(params.amb_ref, load.dtype), 0.0)
    return pue

"""Grid carbon-intensity traces (gCO2 per kWh) for carbon-aware what-ifs.

The sustainability loop the paper motivates (and DCVerse / FootPrinter close)
needs one more input next to the workload trace: the carbon intensity of the
grid feeding the datacenter, ``[T]`` gCO2/kWh at the same 5-minute sampling
granularity as everything else.  This module provides

  * a schema-level loader (:func:`load_carbon_intensity`) for the common
    one-value-per-line / ``bin,intensity`` CSV exports of grid APIs
    (ElectricityMaps-style), resampled to the simulation horizon;
  * a synthetic diurnal generator (:func:`make_diurnal_carbon`) for offline
    experiments: a solar-shaped midday dip, an evening peak, and optional
    day-to-day wander — deterministic under a seed;
  * validation (:func:`validate_carbon_intensity`) shared by both.

Downstream, the intensity trace multiplies per-bin energy into gCO2
(:func:`repro.core.power.carbon_gco2`) and parameterizes the carbon-aware
power cap in the scenario engine (``cap_t = base + slope * intensity_t``).
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.traces.surf import BINS_PER_DAY

#: typical grid bounds, gCO2/kWh: hydro-heavy grids sit near 20, coal-heavy
#: peaks near 900.  Values above the band trigger a sanity *warning* (unit
#: mix-ups, e.g. kgCO2/MWh fed as gCO2/Wh), not a hard rejection.
TYPICAL_RANGE = (0.0, 2000.0)


def validate_carbon_intensity(intensity: np.ndarray,
                              t_bins: int | None = None) -> np.ndarray:
    """Validate an intensity trace: 1-D, finite, non-negative, length T.

    Returns the trace as a contiguous float32 array.  Raises ``ValueError``
    loudly on bad data — a silently wrong carbon signal corrupts every
    downstream sustainability number, the exact failure mode this PR's
    power-model validation closes for watts.
    """
    arr = np.asarray(intensity, np.float32)
    if arr.ndim != 1:
        raise ValueError(f"carbon intensity must be [T], got shape {arr.shape}")
    if arr.size == 0:
        raise ValueError("carbon intensity trace is empty")
    if not np.isfinite(arr).all():
        raise ValueError("carbon intensity contains non-finite values")
    if (arr < 0).any():
        raise ValueError(
            f"carbon intensity must be >= 0 gCO2/kWh (min {arr.min():.1f})")
    if t_bins is not None and arr.shape[0] != t_bins:
        raise ValueError(
            f"carbon intensity has {arr.shape[0]} bins, horizon needs {t_bins}"
            " (use load_carbon_intensity(..., t_bins=...) to resample)")
    if float(arr.max()) > TYPICAL_RANGE[1]:
        warnings.warn(
            f"carbon intensity peaks at {arr.max():.0f} gCO2/kWh, above the "
            f"typical grid band {TYPICAL_RANGE} — check the input units",
            stacklevel=2)
    return np.ascontiguousarray(arr)


def _resample(arr: np.ndarray, t_bins: int) -> np.ndarray:
    """Fit a trace to the horizon: tile a shorter (periodic) trace, truncate
    a longer one.  Grid intensity is diurnal, so tiling is the natural
    extension for day-length inputs."""
    if arr.shape[0] == t_bins:
        return arr
    if arr.shape[0] > t_bins:
        return arr[:t_bins]
    reps = -(-t_bins // arr.shape[0])
    return np.tile(arr, reps)[:t_bins]


def load_carbon_intensity(path: str, t_bins: int | None = None) -> np.ndarray:
    """Load a ``[T]`` gCO2/kWh trace from a CSV-ish file.

    Accepted layouts (comment lines starting with ``#`` and a non-numeric
    header row are skipped):

      * one intensity value per line;
      * ``bin,intensity`` (or ``timestamp,intensity``) — the *last* column
        is taken, rows are used in file order.

    When ``t_bins`` is given the trace is resampled to the horizon: tiled if
    shorter (intensity is diurnal-periodic), truncated if longer.
    """
    vals: list[float] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            cell = line.split(",")[-1].strip()
            try:
                vals.append(float(cell))
            except ValueError:
                if vals:
                    raise ValueError(
                        f"{path}: non-numeric row {line!r} after data rows")
                continue  # header row
    arr = validate_carbon_intensity(np.asarray(vals, np.float32))
    if t_bins is not None:
        arr = _resample(arr, t_bins)
    return arr


def make_diurnal_carbon(
    t_bins: int,
    *,
    base: float = 320.0,
    solar_dip: float = 180.0,
    evening_peak: float = 120.0,
    wander_daily_sigma: float = 0.04,
    seed: int | None = 0,
) -> np.ndarray:
    """Synthetic diurnal grid-carbon-intensity trace ``[t_bins]`` (gCO2/kWh).

    Shape: ``base`` minus a solar-shaped midday dip (clean generation
    displacing fossil) plus an evening ramp peak (demand outruns renewables),
    with an optional per-day multiplicative wander (weather).  ``seed=None``
    disables the wander entirely (pure deterministic sinusoids).
    """
    if t_bins <= 0:
        raise ValueError(f"t_bins must be positive, got {t_bins}")
    tod = (np.arange(t_bins) % BINS_PER_DAY) / BINS_PER_DAY  # [0, 1) day phase
    # solar: positive hump centered at 13:00 local, zero at night
    solar = np.clip(np.sin(np.pi * (tod * 24.0 - 7.0) / 12.0), 0.0, None) ** 2
    # evening ramp: hump centered at 19:30
    evening = np.exp(-0.5 * ((tod * 24.0 - 19.5) / 1.8) ** 2)
    out = base - solar_dip * solar + evening_peak * evening
    if seed is not None and wander_daily_sigma > 0:
        rng = np.random.default_rng(seed)
        n_days = -(-t_bins // BINS_PER_DAY)
        daily = np.exp(rng.normal(0.0, wander_daily_sigma, n_days))
        out = out * np.repeat(daily, BINS_PER_DAY)[:t_bins]
    return validate_carbon_intensity(
        np.maximum(out, 0.0).astype(np.float32), t_bins)

"""Electricity spot-price traces ($/kWh at the 5-minute granularity).

Same shape machinery as :mod:`repro.traces.carbon`: a ``[T]`` float32
trace validated once on the host, then consumed as a traced operand by
the scenario engine — ``cost_t = energy_kwh_t * price_t`` threads into
:class:`~repro.core.desim.Prediction` and the optimizer's objective, so
`optimize_whatif` can trade energy cost against carbon and SLOs.

Spot markets clear *negative* on windy/sunny low-demand days (being paid
to consume), so unlike carbon intensity the trace is not constrained to
be non-negative — only finite.  :func:`make_diurnal_price` is shaped
deliberately *opposite* to the carbon generator's midday solar dip
(cheap night, expensive evening ramp): on the same horizon the
cost-optimal shift differs from the carbon-optimal one, which is exactly
the trade-off the optimizer test pins.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.traces.schema import SAMPLE_SECONDS

#: day length in 5-min bins (see repro.traces.thermal for why this is
#: derived from the schema instead of imported from surf/carbon).
BINS_PER_DAY = int(24 * 3600 / SAMPLE_SECONDS)  # 288

#: plausible retail/spot band, $/kWh: values above trigger a sanity
#: *warning* ($/MWh fed as $/kWh), not a rejection.
TYPICAL_MAX = 5.0


def validate_price(price: np.ndarray, t_bins: int | None = None) -> np.ndarray:
    """Validate a price trace: 1-D, finite, length T; contiguous f32.

    Negative prices are allowed (spot markets clear below zero), NaN/inf
    are not — a non-finite price would silently poison every cost total
    downstream.

    >>> validate_price([0.12, -0.03]).dtype
    dtype('float32')
    >>> validate_price([float("nan")])
    Traceback (most recent call last):
        ...
    ValueError: price trace contains non-finite values
    """
    arr = np.asarray(price, np.float32)
    if arr.ndim != 1:
        raise ValueError(f"price trace must be [T], got shape {arr.shape}")
    if arr.size == 0:
        raise ValueError("price trace is empty")
    if not np.isfinite(arr).all():
        raise ValueError("price trace contains non-finite values")
    if t_bins is not None and arr.shape[0] != t_bins:
        raise ValueError(
            f"price trace has {arr.shape[0]} bins, horizon needs {t_bins}"
            " (use load_price_trace(..., t_bins=...) to resample)")
    if float(arr.max()) > TYPICAL_MAX:
        warnings.warn(
            f"price trace peaks at {arr.max():.2f} $/kWh, above the "
            f"plausible band (<= {TYPICAL_MAX}) — check the input units "
            "($/MWh?)", stacklevel=2)
    return np.ascontiguousarray(arr)


def load_price_trace(path: str, t_bins: int | None = None) -> np.ndarray:
    """Load a ``[T]`` $/kWh spot-price trace from a CSV-ish file.

    Same accepted layouts as :func:`repro.traces.carbon.load_carbon_intensity`
    (one value per line, or ``timestamp,value`` — last column wins; ``#``
    comments and one non-numeric header row are skipped).  With ``t_bins``
    the trace is tiled/truncated to the horizon.
    """
    vals: list[float] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            cell = line.split(",")[-1].strip()
            try:
                vals.append(float(cell))
            except ValueError:
                if vals:
                    raise ValueError(
                        f"{path}: non-numeric row {line!r} after data rows")
                continue  # header row
    arr = validate_price(np.asarray(vals, np.float32))
    if t_bins is not None:
        # local import: carbon pulls in repro.core at module scope
        from repro.traces.carbon import _resample
        arr = _resample(arr, t_bins)
    return arr


def make_diurnal_price(
    t_bins: int,
    *,
    base: float = 0.10,
    night_discount: float = 0.06,
    evening_peak: float = 0.15,
    wander_daily_sigma: float = 0.05,
    seed: int | None = 0,
) -> np.ndarray:
    """Synthetic diurnal spot-price trace ``[t_bins]`` ($/kWh).

    Cheap overnight (a gaussian valley centred ~03:00), an expensive
    evening demand ramp (~19:00) — deliberately the *opposite* shape to
    :func:`repro.traces.carbon.make_diurnal_carbon`'s midday solar dip,
    so cost-optimal and carbon-optimal schedules disagree on the same
    horizon.  A per-day lognormal wander (``seed=None`` disables it)
    models day-to-day market spread.

    >>> p = make_diurnal_price(288, seed=None)
    >>> p.shape
    (288,)
    >>> int(p.argmin()) < 288 // 2 < int(p.argmax())  # cheap night, dear eve
    True
    """
    if t_bins <= 0:
        raise ValueError(f"t_bins must be positive, got {t_bins}")
    tod = (np.arange(t_bins) % BINS_PER_DAY) / BINS_PER_DAY  # [0, 1) day phase
    hours = tod * 24.0
    night = np.exp(-0.5 * ((hours - 3.0) / 2.5) ** 2)
    evening = np.exp(-0.5 * ((hours - 19.0) / 2.0) ** 2)
    out = base - night_discount * night + evening_peak * evening
    if seed is not None and wander_daily_sigma > 0:
        rng = np.random.default_rng(seed)
        n_days = -(-t_bins // BINS_PER_DAY)
        daily = rng.lognormal(0.0, wander_daily_sigma, n_days)
        out = out * np.repeat(daily, BINS_PER_DAY)[:t_bins]
    return validate_price(out.astype(np.float32), t_bins)

"""Pure-jnp oracles for every Pallas kernel.

These are the mathematical specifications: each kernel in this package must
match its oracle to float tolerance across the shape/dtype sweeps in
``tests/test_kernels.py``.  The oracles are also the XLA execution path used
on CPU and in the multi-pod dry-run (kernels/ops.py ``backend="xla"``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def calib_mape_grid_ref(
    u_th: Array,        # [T, H] utilization
    real_power: Array,  # [T] measured total power
    p_idle: Array,      # [C]
    p_max: Array,       # [C]
    r: Array,           # [C]
) -> Array:             # [C] MAPE in %
    """Grid-search MAPE oracle.

    For candidate c:  sim_t = H*p_idle_c + (p_max_c - p_idle_c) * (S2_t - Sr_t(c))
    with S2_t = sum_h 2*u_th and Sr_t(c) = sum_h u_th^{r_c}; MAPE over t.

    Same MAPE semantics as :func:`repro.core.power.mape`: denominator
    ``|real| + eps``, zero-real bins (all hosts offline) excluded from
    the mean — one dead bin must not blow every candidate's score to 1e10 %
    and wash out the grid search — and an *all*-zero window returns NaN for
    every candidate (undefined, surfaced; ``calibrate_window`` keeps the
    incumbent parameters on such windows instead of shipping an arbitrary
    grid point as a "perfect" fit).  The mask is candidate-independent, so
    exclusion is a per-bin weight, not a shape change.

    The [C, T] intermediate is materialized here — the Pallas kernel's whole
    point is to tile this away (see calib_mape.py).
    """
    u = jnp.clip(u_th.astype(jnp.float32), 0.0, 1.0)
    t, h = u.shape
    s2 = jnp.sum(2.0 * u, axis=1)                       # [T]
    # [C, T]: sum_h u^r per candidate
    log_u = jnp.log(jnp.maximum(u, 1e-30))              # [T, H]
    sr = jnp.sum(
        jnp.exp(r.astype(jnp.float32)[:, None, None] * log_u[None]), axis=2
    )                                                   # [C, T]
    span = (p_max - p_idle).astype(jnp.float32)[:, None]
    sim = h * p_idle.astype(jnp.float32)[:, None] + span * (s2[None, :] - sr)
    rp = real_power.astype(jnp.float32)[None, :]
    nonzero = jnp.abs(rp) > 1e-9                        # [1, T]
    n_nz = jnp.sum(nonzero)
    ape = jnp.abs((rp - sim) / (jnp.abs(rp) + 1e-9)) * nonzero
    out = jnp.sum(ape, axis=1) * (100.0 / jnp.maximum(n_nz, 1))
    return jnp.where(n_nz > 0, out, jnp.nan)


def power_sim_ref(
    u_th: Array,              # [T, H]
    p_idle: float | Array,
    p_max: float | Array,
    r: float | Array,
    *,
    peak_tflops: float,
    dt_seconds: float,
) -> tuple[Array, Array, Array]:
    """Windowed power/energy/TFLOPs map oracle.  Returns ([T], [T], [T])."""
    u = jnp.clip(u_th.astype(jnp.float32), 0.0, 1.0)
    h = u.shape[1]
    shape = 2.0 * u - jnp.exp(
        jnp.asarray(r, jnp.float32) * jnp.log(jnp.maximum(u, 1e-30))
    )
    p_idle = jnp.asarray(p_idle, jnp.float32)
    p_max = jnp.asarray(p_max, jnp.float32)
    power = h * p_idle + (p_max - p_idle) * jnp.sum(shape, axis=1)
    energy = power * (dt_seconds / 3600.0) / 1000.0
    tflops = jnp.mean(u, axis=1) * peak_tflops
    return power, energy, tflops


def flash_attention_ref(
    q: Array,   # [B, Hq, S, D]
    k: Array,   # [B, Hkv, Skv, D]
    v: Array,   # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    scale: float | None = None,
) -> Array:     # [B, Hq, S, D]
    """Vanilla attention oracle with GQA head grouping."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf)
    if causal:
        skv = k.shape[2]
        # query i attends to keys j <= i + (skv - s)  (supports prefix caches)
        mask = (jnp.arange(s)[:, None] + (skv - s)) >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vf)
    return out.astype(q.dtype)


def ssd_chunk_ref(
    x,       # [BC, Q, H, P]
    dt,      # [BC, Q, H]
    a_log,   # [H]
    b,       # [BC, Q, G, N]
    c,       # [BC, Q, G, N]
    d_skip,  # [H]
):
    """SSD intra-chunk oracle: (y_intra [BC,Q,H,P], states [BC,H,P,N])."""
    bc, q, h, p = x.shape
    g = b.shape[2]
    rep = h // g
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = -jnp.exp(a_log.astype(jnp.float32))
    bb = jnp.repeat(b.astype(jnp.float32), rep, axis=2)   # [BC,Q,H,N]
    cc = jnp.repeat(c.astype(jnp.float32), rep, axis=2)
    da = dtf * a[None, None, :]
    csum = jnp.cumsum(da, axis=1)                         # [BC,Q,H]
    seg = csum[:, :, None, :] - csum[:, None, :, :]       # [BC,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bqhn,bkhn->bqkh", cc, bb)
    att = cb * decay * dtf[:, None, :, :]
    y = jnp.einsum("bqkh,bkhp->bqhp", att, xf)
    y = y + xf * d_skip.astype(jnp.float32)[None, None, :, None]
    decay_end = jnp.exp(csum[:, -1:, :] - csum) * dtf     # [BC,Q,H]
    st = jnp.einsum("bqhp,bqh,bqhn->bhpn", xf, decay_end, bb)
    return y, st

"""Flash-attention forward Pallas kernel (TPU target), GQA-aware.

The LM substrate's chunked-XLA attention (models/attention.py) is the exact
same blocking expressed with lax.scan so the multi-pod dry-run can lower on
any backend; this kernel is the TPU-native realization for the perf path.

Blocking: grid (B, Hq, Q_tiles, KV_tiles).  TPU grids execute sequentially
over the last axis, so the online-softmax running state (m, l, acc) lives in
VMEM scratch that persists across the KV axis.  K/V BlockSpec index maps
divide the query head by the GQA group size, so grouped heads read the same
KV block without materializing the head expansion in HBM.

Causal skipping: KV tiles strictly above the diagonal are skipped via
pl.when (zero work, not just masking).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_QB = 256
DEFAULT_KB = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, kv_tiles: int, q_blk: int, k_blk: int,
            s_q: int, s_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # query offset includes the kv/q length delta so decode/prefix caches
    # (s_kv >= s_q) line up on the last diagonal.
    diag_off = s_kv - s_q

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [Qb, D]
        k = k_ref[0, 0].astype(jnp.float32)                # [Kb, D]
        v = v_ref[0, 0].astype(jnp.float32)                # [Kb, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                   # [Qb, Kb]
        q_ids = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_ids = ki * k_blk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_ids < s_kv                                 # ragged kv pad
        if causal:
            mask &= (q_ids + diag_off) >= k_ids
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                 # [Qb, 1]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                              # [Qb, Kb]
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # last query row of this q tile vs first kv row of this kv tile
        needed = (qi * q_blk + q_blk - 1 + diag_off) >= ki * k_blk
        pl.when(needed)(compute)
    else:
        compute()

    @pl.when(ki == kv_tiles - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "q_blk", "k_blk", "interpret"),
)
def flash_attention_pallas(
    q: Array,   # [B, Hq, Sq, D]
    k: Array,   # [B, Hkv, Skv, D]
    v: Array,   # [B, Hkv, Skv, D]
    *,
    causal: bool = True,
    scale: float | None = None,
    q_blk: int = DEFAULT_QB,
    k_blk: int = DEFAULT_KB,
    interpret: bool = False,
) -> Array:
    b, hq, s_q, d = q.shape
    _, hkv, s_kv, _ = k.shape
    assert hq % hkv == 0, "GQA requires Hq % Hkv == 0"
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale

    q_blk = min(q_blk, max(s_q, 8))
    k_blk = min(k_blk, max(s_kv, 8))
    sqp = pl.cdiv(s_q, q_blk) * q_blk
    skp = pl.cdiv(s_kv, k_blk) * k_blk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp - s_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skp - s_kv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp - s_kv), (0, 0)))

    q_tiles = sqp // q_blk
    kv_tiles = skp // k_blk
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, kv_tiles=kv_tiles,
        q_blk=q_blk, k_blk=k_blk, s_q=s_q, s_kv=s_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, q_tiles, kv_tiles),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, k_blk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, k_blk, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, d), jnp.float32),   # acc
            pltpu.VMEM((q_blk, 1), jnp.float32),   # m
            pltpu.VMEM((q_blk, 1), jnp.float32),   # l
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s_q, :]

"""Mamba2 / SSD intra-chunk Pallas kernel (TPU target).

One grid step computes, for a (batch x chunk, head) pair, the quadratic
intra-chunk term and the chunk boundary state of the state-space-duality
decomposition [arXiv:2405.21060]:

    att[i,j] = (C_i . B_j) * exp(csum_i - csum_j) * dt_j      (j <= i)
    y_intra  = att @ x + D * x
    state    = sum_j exp(csum_Q - csum_j) * dt_j * (B_j (x) x_j)

The [Q, Q] decay/score tile, the [Q, N] B/C blocks and the [Q, P] head
activations all live in VMEM (Q=128, P<=64, N<=128 -> < 0.5 MB per step);
nothing chunk-quadratic touches HBM.  The O(chunks) inter-chunk recurrence
stays in JAX (models/mamba2.ssd_chunked) — it is linear and sequential.

Grid:   (B*C, H)
Blocks: x   (1, Q, 1, P)   dt/da (1, Q, 1)    B/C (1, Q, 1, N) via group map
Out:    y   (1, Q, 1, P)   state (1, 1, P, N)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, st_ref, *,
            q: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # [Q]
    a = a_ref[0, 0].astype(jnp.float32)                # scalar A_log
    bb = b_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]
    cc = c_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]
    d_skip = d_ref[0, 0].astype(jnp.float32)

    da = dt * (-jnp.exp(a))                             # [Q]
    csum = jnp.cumsum(da)                               # [Q]

    seg = csum[:, None] - csum[None, :]                 # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    att = cb * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]
    y_ref[0, :, 0, :] = (y + x * d_skip).astype(y_ref.dtype)

    decay_end = jnp.exp(csum[q - 1] - csum) * dt        # [Q]
    # state[p, n] = sum_j x[j, p] * decay_end[j] * B[j, n]
    st = jax.lax.dot_general(x * decay_end[:, None], bb,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [P, N]
    st_ref[0, 0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(
    x: Array,       # [BC, Q, H, P]   chunked head activations
    dt: Array,      # [BC, Q, H]      post-softplus
    a_log: Array,   # [H]
    b: Array,       # [BC, Q, G, N]
    c: Array,       # [BC, Q, G, N]
    d_skip: Array,  # [H]
    *,
    interpret: bool = False,
) -> tuple[Array, Array]:
    """-> (y_intra [BC, Q, H, P] f32, states [BC, H, P, N] f32)."""
    bc, q, h, p = x.shape
    g = b.shape[2]
    hpg = h // g
    kernel = functools.partial(_kernel, q=q)
    y, st = pl.pallas_call(
        kernel,
        grid=(bc, h),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, hh: (i, 0, hh, 0)),
            pl.BlockSpec((1, q, 1), lambda i, hh: (i, 0, hh)),
            pl.BlockSpec((1, 1), lambda i, hh: (0, hh)),
            pl.BlockSpec((1, q, 1, b.shape[-1]),
                         lambda i, hh, k=hpg: (i, 0, hh // k, 0)),
            pl.BlockSpec((1, q, 1, b.shape[-1]),
                         lambda i, hh, k=hpg: (i, 0, hh // k, 0)),
            pl.BlockSpec((1, 1), lambda i, hh: (0, hh)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda i, hh: (i, 0, hh, 0)),
            pl.BlockSpec((1, 1, p, b.shape[-1]), lambda i, hh: (i, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bc, h, p, b.shape[-1]), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, a_log[None, :], b, c, d_skip[None, :])
    return y, st

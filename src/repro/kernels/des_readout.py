"""Fused DES readout (Pallas): the whole per-bin metric pipeline, one pass.

The post-scan readout of the scenario engine (`scenarios._predict_masked`)
re-reads the utilization field ``[T, H]`` once per metric: per-host power
shape, online masking, the idle floor, mean utilization, dynamic PUE,
cap/throttle enforcement, then energy/gCO2/cost integration.  At
``BENCH_whatif.json`` scale that readout is ~half of every DES call.  This
kernel fuses the pipeline into one VMEM pass per ``[Tb, Hp]`` tile: the
utilization block is read once and all nine ``Prediction`` leaves come out
as ``[Tb, 1]`` columns.

Grid:   (T_tiles,)
Blocks: u (Tb, Hp);  per-host rows (1, Hp);  per-bin columns (Tb, 1);
        packed scalar row (1, 128);  9 outputs (Tb, 1).

Every axis of the scenario engine is an *operand*, never a recompile:

  * inactive hosts — ``mask`` row zeros (idle watts and the utilization
    denominator both respect it);
  * failures — ``fail_start``/``fail_end``/``fail_kill`` rows; the per-bin
    online mask is rebuilt in-kernel from ``broadcasted_iota`` time ids,
    so no ``[T, H]`` availability tensor is ever materialized;
  * dynamic PUE — identity parameters (base 1, coeffs 0) are an IEEE-exact
    no-op (``x * 1.0`` and ``+ 0.0``), so the PUE multiply is always
    compiled in and axis-free lanes stay bitwise on the one program;
  * caps — ``+inf`` is the uncapped sentinel (``min(x, inf) == x``);
  * absent carbon/price traces — zero columns (outputs ignored upstream).

``des_readout_ref`` is the XLA fallback: it packs operands with the *same*
padding and runs the *same* tile function via ``lax.map`` over the same
tile decomposition, so the interpret-mode kernel and the reference agree
**bit for bit** in f32 (pinned by ``tests/test_des_kernel.py``).  The
legacy unfused readout and the f64 oracle are tolerance gates, not bitwise
ones: summing a zero-padded 128-lane row is not IEEE-identical to summing
the unpadded row.

Precision policy (``precision="bf16"``): sustainability leaves (power,
energy, gCO2, cost, PUE, demand) and utilization stay f32 — the oracle
tolerance in ``tests/test_oracle.py`` is rtol 1e-4..2e-4, 20-40x tighter
than one bf16 ulp (2^-8) — while the derived performance leaves (tflops,
efficiency) are computed in bf16 and stored as f32.  The policy is pinned
against ``tests/golden/readout_bf16.npz``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array

TB_T = 512

#: output order of the fused readout — Prediction's array leaves
READOUT_FIELDS = ("power_w", "energy_kwh", "tflops", "utilization",
                  "efficiency", "gco2", "power_demand_w", "pue",
                  "energy_cost")

#: floor under the log in the exp/log power form (0**r -> ~0, never -inf)
_LOG_FLOOR = 1e-30


def _shape_term(u: Array, r: Array, model: str) -> Array:
    """Power-curve shape term of :data:`repro.core.power.POWER_MODELS`.

    ``u`` must be pre-clipped to [0, 1].  The opendc exponent uses the
    ``exp(r * log(u))`` form (Pallas/TPU has no f32 ``pow`` primitive;
    same trick as ``power_sim._kernel``) — within 1 ulp of ``u**r`` and
    exactly reproduced by the XLA reference.
    """
    if model == "opendc":
        return 2.0 * u - jnp.exp(r * jnp.log(jnp.maximum(u, _LOG_FLOOR)))
    if model == "linear":
        return u
    if model == "sqrt":
        return jnp.sqrt(u)
    if model == "cubic":
        return u * u * u
    raise ValueError(f"unknown power model {model!r}")


def _tile_readout(u, pi, pm, rr, mask, fs, fe, kill, cap, ci, amb, prc,
                  scal, t0, *, model: str, precision: str,
                  dt_seconds: float, tb_t: int):
    """The fused readout over one ``[tb_t, Hp]`` tile (pure jnp).

    Shared verbatim by the Pallas kernel body and the XLA reference so the
    two paths execute the identical op sequence on identical tile shapes.
    ``t0`` is the absolute bin index of the tile's first row; ``scal`` is
    the packed ``(1, 128)`` scalar row
    ``[peak_tflops, pue_base, pue_load_coeff, pue_amb_coeff, pue_amb_ref]``.
    """
    t_ids = t0 + jax.lax.broadcasted_iota(jnp.int32, (tb_t, 1), 0)
    # per-bin availability: outage hosts draw nothing inside their window
    off = (kill > 0.0) & (t_ids >= fs) & (t_ids < fe)            # [Tb, Hp]
    on = jnp.where(off, 0.0, 1.0) * mask
    uc = jnp.clip(u, 0.0, 1.0)
    host_p = pi + (pm - pi) * _shape_term(uc, rr, model)
    it_demand = jnp.sum(host_p * on, axis=1, keepdims=True)      # [Tb, 1]
    idle_floor = jnp.sum(pi * on, axis=1, keepdims=True)
    util_raw = jnp.sum(u * on, axis=1, keepdims=True) / jnp.maximum(
        jnp.sum(on, axis=1, keepdims=True), 1.0)
    peak, p_base, p_load, p_amb, p_ref = (
        scal[:, i:i + 1] for i in range(5))                      # [1, 1] each
    # dynamic PUE (traces/thermal.dynamic_pue); identity params -> exact 1.0
    load = jnp.clip(util_raw, 0.0, 1.0)
    pue = p_base + p_load * (1.0 - load)
    pue = pue + p_amb * jnp.maximum(amb - p_ref, 0.0)
    demand = it_demand * pue
    floor = idle_floor * pue
    # cap enforcement + linear throttle (scenarios._predict_masked)
    exceeded = demand > cap
    power = jnp.minimum(demand, cap)
    throttle = jnp.clip(
        (cap - floor) / jnp.maximum(demand - floor, 1e-9), 0.0, 1.0)
    e = power * (dt_seconds / 3600.0) / 1000.0
    util = jnp.where(exceeded, util_raw * throttle, util_raw)
    if precision == "bf16":
        # performance derivatives only; sustainability stays f32 (see
        # module docstring) — stored back as f32 for structural stability
        tf16 = util.astype(jnp.bfloat16) * peak.astype(jnp.bfloat16)
        eff = (tf16 / jnp.maximum(e, 1e-9).astype(jnp.bfloat16)
               ).astype(jnp.float32)
        tflops = tf16.astype(jnp.float32)
    elif precision == "f32":
        tflops = util * peak
        eff = tflops / jnp.maximum(e, 1e-9)
    else:
        raise ValueError(f"unknown precision policy {precision!r}")
    gco2 = e * ci
    cost = e * prc
    return power, e, tflops, util, eff, gco2, demand, pue, cost


def _kernel(u_ref, pi_ref, pm_ref, rr_ref, mk_ref, fs_ref, fe_ref, kl_ref,
            cap_ref, ci_ref, amb_ref, prc_ref, scal_ref, *out_refs,
            model: str, precision: str, dt_seconds: float, tb_t: int):
    outs = _tile_readout(
        u_ref[...], pi_ref[...], pm_ref[...], rr_ref[...], mk_ref[...],
        fs_ref[...], fe_ref[...], kl_ref[...], cap_ref[...], ci_ref[...],
        amb_ref[...], prc_ref[...], scal_ref[...],
        pl.program_id(0) * tb_t,
        model=model, precision=precision, dt_seconds=dt_seconds, tb_t=tb_t)
    for ref, val in zip(out_refs, outs):
        ref[...] = val


def _pack_operands(u_th, *, p_idle, p_max, r, mask, cap_t, intensity,
                   ambient, price, peak_tflops, pue_base, pue_amb_coeff,
                   pue_amb_ref, pue_load_coeff, fail_start, fail_end,
                   fail_kill, tb_t):
    """Pad every axis into kernel operands (shared by pallas and ref).

    Padded host lanes carry ``p_idle = p_max = 0``, ``r = 1`` and a zero
    mask; padded time rows carry a ``+inf`` cap (all finite outputs, then
    sliced off).  Both paths call this, so their operand bits are equal by
    construction.
    """
    t, h = u_th.shape
    hp = pl.cdiv(h, 128) * 128
    tp = pl.cdiv(t, tb_t) * tb_t
    f32 = jnp.float32
    u = jnp.pad(u_th.astype(f32), ((0, tp - t), (0, hp - h)))

    def row(x, fill=0.0, dtype=f32):
        x = jnp.broadcast_to(jnp.asarray(x, dtype), (h,))
        return jnp.pad(x, (0, hp - h), constant_values=fill)[None, :]

    pi = row(p_idle)
    pm = row(p_max)
    rr = row(r, fill=1.0)
    mk = row(jnp.ones((h,), f32) if mask is None
             else jnp.asarray(mask).astype(f32))
    if fail_start is None:
        fs = jnp.full((1, hp), np.iinfo(np.int32).max, jnp.int32)
        fe = jnp.zeros((1, hp), jnp.int32)
        kl = jnp.zeros((1, hp), f32)
    else:
        fs = row(fail_start, fill=np.iinfo(np.int32).max, dtype=jnp.int32)
        fe = row(fail_end, dtype=jnp.int32)
        kl = row(jnp.asarray(fail_kill).astype(f32))

    def col(x, fill=0.0):
        x = jnp.broadcast_to(jnp.asarray(x, f32), (t,))
        return jnp.pad(x, (0, tp - t), constant_values=fill)[:, None]

    cap = col(jnp.inf if cap_t is None else cap_t, fill=np.inf)
    ci = col(0.0 if intensity is None else intensity)
    amb = col(0.0 if ambient is None else ambient)
    prc = col(0.0 if price is None else price)
    scal = jnp.zeros((1, 128), f32)
    for i, v in enumerate((peak_tflops, pue_base, pue_load_coeff,
                           pue_amb_coeff, pue_amb_ref)):
        scal = scal.at[0, i].set(jnp.asarray(v, f32))
    return (u, pi, pm, rr, mk, fs, fe, kl, cap, ci, amb, prc, scal), (t, tp, hp)


def des_readout_pallas(
    u_th: Array,
    *,
    p_idle,
    p_max,
    r,
    mask: Array | None = None,
    cap_t: Array | None = None,
    intensity: Array | None = None,
    ambient: Array | None = None,
    price: Array | None = None,
    peak_tflops=1.0,
    pue_base=1.0,
    pue_amb_coeff=0.0,
    pue_amb_ref=18.0,
    pue_load_coeff=0.0,
    fail_start: Array | None = None,
    fail_end: Array | None = None,
    fail_kill: Array | None = None,
    model: str = "opendc",
    precision: str = "f32",
    dt_seconds: float = 300.0,
    interpret: bool = False,
    tb_t: int = TB_T,
) -> dict[str, Array]:
    """Fused scenario readout, Pallas path.

    Returns ``{field: [T] f32}`` for every name in :data:`READOUT_FIELDS`
    (always all nine — callers map absent axes back to ``None`` leaves).
    vmap-safe: every per-lane quantity is an operand, so the scenario
    engine vmaps this over S without retracing.
    """
    operands, (t, tp, hp) = _pack_operands(
        u_th, p_idle=p_idle, p_max=p_max, r=r, mask=mask, cap_t=cap_t,
        intensity=intensity, ambient=ambient, price=price,
        peak_tflops=peak_tflops, pue_base=pue_base,
        pue_amb_coeff=pue_amb_coeff, pue_amb_ref=pue_amb_ref,
        pue_load_coeff=pue_load_coeff, fail_start=fail_start,
        fail_end=fail_end, fail_kill=fail_kill, tb_t=tb_t)
    kernel = functools.partial(
        _kernel, model=model, precision=precision,
        dt_seconds=dt_seconds, tb_t=tb_t)
    row_spec = pl.BlockSpec((1, hp), lambda ti: (0, 0))
    col_spec = pl.BlockSpec((tb_t, 1), lambda ti: (ti, 0))
    shape_t = jax.ShapeDtypeStruct((tp, 1), jnp.float32)
    outs = pl.pallas_call(
        kernel,
        grid=(tp // tb_t,),
        in_specs=[
            pl.BlockSpec((tb_t, hp), lambda ti: (ti, 0)),       # u
            row_spec, row_spec, row_spec, row_spec,             # pi pm rr mk
            row_spec, row_spec, row_spec,                       # fs fe kl
            col_spec, col_spec, col_spec, col_spec,             # cap ci amb prc
            pl.BlockSpec((1, 128), lambda ti: (0, 0)),          # scal
        ],
        out_specs=[col_spec] * len(READOUT_FIELDS),
        out_shape=[shape_t] * len(READOUT_FIELDS),
        interpret=interpret,
    )(*operands)
    return {k: v[:t, 0] for k, v in zip(READOUT_FIELDS, outs)}


def des_readout_ref(
    u_th: Array,
    *,
    p_idle,
    p_max,
    r,
    mask: Array | None = None,
    cap_t: Array | None = None,
    intensity: Array | None = None,
    ambient: Array | None = None,
    price: Array | None = None,
    peak_tflops=1.0,
    pue_base=1.0,
    pue_amb_coeff=0.0,
    pue_amb_ref=18.0,
    pue_load_coeff=0.0,
    fail_start: Array | None = None,
    fail_end: Array | None = None,
    fail_kill: Array | None = None,
    model: str = "opendc",
    precision: str = "f32",
    dt_seconds: float = 300.0,
    tb_t: int = TB_T,
) -> dict[str, Array]:
    """XLA reference/fallback of :func:`des_readout_pallas`.

    Identical operand packing and the identical tile function, mapped over
    the identical tile decomposition (``lax.map`` = the grid loop) — so in
    f32 the two paths are bitwise equal, not just close.
    """
    operands, (t, tp, hp) = _pack_operands(
        u_th, p_idle=p_idle, p_max=p_max, r=r, mask=mask, cap_t=cap_t,
        intensity=intensity, ambient=ambient, price=price,
        peak_tflops=peak_tflops, pue_base=pue_base,
        pue_amb_coeff=pue_amb_coeff, pue_amb_ref=pue_amb_ref,
        pue_load_coeff=pue_load_coeff, fail_start=fail_start,
        fail_end=fail_end, fail_kill=fail_kill, tb_t=tb_t)
    u, pi, pm, rr, mk, fs, fe, kl, cap, ci, amb, prc, scal = operands
    n_tiles = tp // tb_t

    def tile(ti):
        s = ti * tb_t
        outs = _tile_readout(
            jax.lax.dynamic_slice(u, (s, 0), (tb_t, hp)),
            pi, pm, rr, mk, fs, fe, kl,
            jax.lax.dynamic_slice(cap, (s, 0), (tb_t, 1)),
            jax.lax.dynamic_slice(ci, (s, 0), (tb_t, 1)),
            jax.lax.dynamic_slice(amb, (s, 0), (tb_t, 1)),
            jax.lax.dynamic_slice(prc, (s, 0), (tb_t, 1)),
            scal, s, model=model, precision=precision,
            dt_seconds=dt_seconds, tb_t=tb_t)
        return tuple(o[:, 0] for o in outs)

    outs = jax.lax.map(tile, jnp.arange(n_tiles, dtype=jnp.int32))
    return {k: v.reshape(tp)[:t]
            for k, v in zip(READOUT_FIELDS, outs)}

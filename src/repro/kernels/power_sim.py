"""Fused windowed power/energy/TFLOPs map (Pallas).

One VMEM pass over the utilization field [T, H] produces all three read-out
metrics of the prediction layer (paper Fig. 5A/B/C) without re-reading the
field per metric: power [T], per-bin energy [T], achieved TFLOP/s [T].

Grid:   (T_tiles,)
Blocks: u (Tb, Hp) VMEM;  outputs 3x (Tb, 1) VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

TB_T = 512


def _kernel(u_ref, pow_ref, en_ref, tf_ref, *,
            p_idle: float, p_max: float, r: float, n_h: int,
            peak_tflops: float, dt_seconds: float):
    u = u_ref[...].astype(jnp.float32)
    u = jnp.clip(u, 0.0, 1.0)
    shape = 2.0 * u - jnp.exp(r * jnp.log(jnp.maximum(u, 1e-30)))
    ssum = jnp.sum(shape, axis=1, keepdims=True)            # [Tb, 1]
    power = n_h * p_idle + (p_max - p_idle) * ssum
    pow_ref[...] = power
    en_ref[...] = power * (dt_seconds / 3600.0 / 1000.0)
    tf_ref[...] = jnp.sum(u, axis=1, keepdims=True) / n_h * peak_tflops


@functools.partial(
    jax.jit,
    static_argnames=("p_idle", "p_max", "r", "peak_tflops", "dt_seconds",
                     "interpret", "tb_t"),
)
def power_sim_pallas(
    u_th: Array,
    *,
    p_idle: float,
    p_max: float,
    r: float,
    peak_tflops: float,
    dt_seconds: float,
    interpret: bool = False,
    tb_t: int = TB_T,
) -> tuple[Array, Array, Array]:
    t, h = u_th.shape
    hp = pl.cdiv(h, 128) * 128
    tp = pl.cdiv(t, tb_t) * tb_t
    u = jnp.pad(u_th.astype(jnp.float32), ((0, tp - t), (0, hp - h)))
    kernel = functools.partial(
        _kernel, p_idle=p_idle, p_max=p_max, r=r, n_h=h,
        peak_tflops=peak_tflops, dt_seconds=dt_seconds,
    )
    shape_t = jax.ShapeDtypeStruct((tp, 1), jnp.float32)
    power, energy, tflops = pl.pallas_call(
        kernel,
        grid=(tp // tb_t,),
        in_specs=[pl.BlockSpec((tb_t, hp), lambda ti: (ti, 0))],
        out_specs=[pl.BlockSpec((tb_t, 1), lambda ti: (ti, 0))] * 3,
        out_shape=[shape_t, shape_t, shape_t],
        interpret=interpret,
    )(u)
    return power[:t, 0], energy[:t, 0], tflops[:t, 0]

"""Jit'd public wrappers for the kernels, with a backend switch.

backend = "pallas"           — compiled Pallas (TPU deployment target)
backend = "pallas_interpret" — Pallas interpret mode (CPU validation; the
                               kernel body runs in Python, semantics identical)
backend = "xla"              — the pure-jnp oracle (ref.py); used on CPU for
                               speed and in the multi-pod dry-run lowering.

The default is resolved from the platform at call time so library code never
hard-codes a backend.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.calib_mape import calib_mape_grid_pallas
from repro.kernels.des_readout import des_readout_pallas, des_readout_ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.power_sim import power_sim_pallas
from repro.kernels.ssd_chunk import ssd_chunk_pallas

Array = jax.Array
Backend = Literal["auto", "pallas", "pallas_interpret", "xla"]


def resolve_backend(backend: Backend) -> str:
    if backend != "auto":
        return backend
    platform = jax.devices()[0].platform  # tracecheck: disable=TC007 — backend="auto" dispatch
    return "pallas" if platform == "tpu" else "xla"


def calib_mape_grid(
    u_th: Array, real_power: Array,
    p_idle: Array, p_max: Array, r: Array,
    *, backend: Backend = "auto",
) -> Array:
    """[C] candidate MAPEs over a cached utilization window."""
    b = resolve_backend(backend)
    if b == "xla":
        return ref.calib_mape_grid_ref(u_th, real_power, p_idle, p_max, r)
    return calib_mape_grid_pallas(
        u_th, real_power, p_idle, p_max, r,
        interpret=(b == "pallas_interpret"),
    )


def power_sim(
    u_th: Array, *, p_idle: float, p_max: float, r: float,
    peak_tflops: float, dt_seconds: float, backend: Backend = "auto",
) -> tuple[Array, Array, Array]:
    """Fused (power, energy, tflops) window map."""
    b = resolve_backend(backend)
    if b == "xla":
        return ref.power_sim_ref(
            u_th, p_idle, p_max, r,
            peak_tflops=peak_tflops, dt_seconds=dt_seconds,
        )
    return power_sim_pallas(
        u_th, p_idle=p_idle, p_max=p_max, r=r,
        peak_tflops=peak_tflops, dt_seconds=dt_seconds,
        interpret=(b == "pallas_interpret"),
    )


def des_readout(u_th: Array, *, backend: Backend = "auto",
                **kw) -> dict[str, Array]:
    """Fused DES readout: the full per-bin metric set in one pass.

    Keyword operands are those of
    :func:`repro.kernels.des_readout.des_readout_pallas`; the ``xla``
    backend runs the reference over the identical tile decomposition, so
    in f32 the two backends agree bit for bit (not merely within
    tolerance).
    """
    b = resolve_backend(backend)
    if b == "xla":
        return des_readout_ref(u_th, **kw)
    return des_readout_pallas(u_th, interpret=(b == "pallas_interpret"),
                              **kw)


def flash_attention(
    q: Array, k: Array, v: Array, *,
    causal: bool = True, scale: float | None = None,
    backend: Backend = "auto",
) -> Array:
    """GQA flash attention forward."""
    b = resolve_backend(backend)
    if b == "xla":
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, scale=scale,
        interpret=(b == "pallas_interpret"),
    )


def ssd_chunk(
    x: Array, dt: Array, a_log: Array, b: Array, c: Array, d_skip: Array,
    *, backend: Backend = "auto",
) -> tuple[Array, Array]:
    """Mamba2/SSD intra-chunk term + boundary states."""
    bk = resolve_backend(backend)
    if bk == "xla":
        return ref.ssd_chunk_ref(x, dt, a_log, b, c, d_skip)
    return ssd_chunk_pallas(x, dt, a_log, b, c, d_skip,
                            interpret=(bk == "pallas_interpret"))

"""Fused grid-search MAPE Pallas kernel (the Self-Calibrator's hot spot).

The calibrator evaluates C candidate power-model parameterizations against a
cached utilization window [T, H] (see core/calibrate.py).  The naive
formulation materializes a [C, T] (or worse, [C, T, H]) tensor in HBM; with
the beyond-paper joint grid C reaches 10^4-10^5 and the window grows with the
history length, so the intermediate dominates HBM traffic.

TPU adaptation: tile candidates x time.  Each grid step loads one [Tb, Hp]
utilization block into VMEM once and evaluates a whole [Cb] candidate tile
against it, accumulating per-candidate |rel-err| partial sums in the output
block across the T grid dimension (TPU grids execute sequentially, so the
last grid axis is a legal reduction axis).  Arithmetic intensity rises by Cb
per utilization byte vs. the naive map; nothing [C, T]-shaped ever exists.

Grid:     (C_tiles, T_tiles)               (T last => sequential reduction)
Blocks:   u:    (Tb, Hp)   VMEM            Hp = H padded to 128 lanes
          real: (Tb, 1)    VMEM
          p_*:  (1, Cb)    VMEM
          out:  (1, Cb)    VMEM accumulator
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

# default tile sizes — MXU/VPU aligned (lane dim multiples of 128)
TB_T = 256     # time-bins per block
TB_C = 128     # candidates per block


def _kernel(u_ref, real_ref, pidle_ref, pmax_ref, r_ref, out_ref, *,
            n_t: int, n_h: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u = u_ref[...].astype(jnp.float32)            # [Tb, Hp]
    u = jnp.clip(u, 0.0, 1.0)
    log_u = jnp.log(jnp.maximum(u, 1e-30))        # [Tb, Hp]
    s2 = jnp.sum(2.0 * u, axis=1, keepdims=True)  # [Tb, 1]

    real = real_ref[...].astype(jnp.float32)      # [Tb, 1]
    p_idle = pidle_ref[...].astype(jnp.float32)   # [1, Cb]
    p_max = pmax_ref[...].astype(jnp.float32)     # [1, Cb]
    r = r_ref[...].astype(jnp.float32)            # [1, Cb]

    # valid-time mask for the ragged last block
    t0 = ti * u.shape[0]
    t_ids = t0 + jax.lax.broadcasted_iota(jnp.int32, (u.shape[0], 1), 0)
    t_mask = (t_ids < n_t).astype(jnp.float32)    # [Tb, 1]

    # sum_h u^r per candidate: einsum over the host dim keeps the MXU busy:
    # exp(r * log u) is [Tb, Hp, Cb]-shaped logically; we stream it per
    # candidate tile as exp(log_u[...,None] * r) then reduce hosts.
    # [Tb, Hp, 1] * [1, 1, Cb] -> [Tb, Hp, Cb] in VREGs, reduce axis 1.
    sr = jnp.sum(jnp.exp(log_u[:, :, None] * r[None]), axis=1)  # [Tb, Cb]

    # MAPE semantics shared with power.mape / the XLA oracle: |real| in the
    # denominator, zero-real bins masked out (the bin-count normalization
    # 100/n_nonzero is applied by the wrapper — n_nonzero is data-dependent
    # and candidate-independent, so the kernel only accumulates raw sums).
    nz_mask = (jnp.abs(real) > 1e-9).astype(jnp.float32)         # [Tb, 1]
    sim = n_h * p_idle + (p_max - p_idle) * (s2 - sr)            # [Tb, Cb]
    rel = (jnp.abs((real - sim) / (jnp.abs(real) + 1e-9))
           * t_mask * nz_mask)                                   # [Tb, Cb]
    out_ref[...] += jnp.sum(rel, axis=0, keepdims=True)          # [1, Cb]


@functools.partial(jax.jit, static_argnames=("interpret", "tb_t", "tb_c"))
def calib_mape_grid_pallas(
    u_th: Array,        # [T, H] float
    real_power: Array,  # [T]
    p_idle: Array,      # [C]
    p_max: Array,       # [C]
    r: Array,           # [C]
    *,
    interpret: bool = False,
    tb_t: int = TB_T,
    tb_c: int = TB_C,
) -> Array:             # [C] MAPE %
    t, h = u_th.shape
    c = r.shape[0]
    hp = pl.cdiv(h, 128) * 128
    tp = pl.cdiv(t, tb_t) * tb_t
    cp = pl.cdiv(c, tb_c) * tb_c

    u = jnp.pad(u_th.astype(jnp.float32), ((0, tp - t), (0, hp - h)))
    real = jnp.pad(real_power.astype(jnp.float32), (0, tp - t),
                   constant_values=1.0)[:, None]           # avoid /0 in pad
    pad_c = (0, cp - c)
    pi = jnp.pad(p_idle.astype(jnp.float32), pad_c)[None, :]
    pm = jnp.pad(p_max.astype(jnp.float32), pad_c, constant_values=1.0)[None, :]
    rr = jnp.pad(r.astype(jnp.float32), pad_c, constant_values=1.0)[None, :]

    t_tiles = tp // tb_t
    c_tiles = cp // tb_c
    kernel = functools.partial(_kernel, n_t=t, n_h=h)
    out = pl.pallas_call(
        kernel,
        grid=(c_tiles, t_tiles),
        in_specs=[
            pl.BlockSpec((tb_t, hp), lambda ci, ti: (ti, 0)),    # u
            pl.BlockSpec((tb_t, 1), lambda ci, ti: (ti, 0)),     # real
            pl.BlockSpec((1, tb_c), lambda ci, ti: (0, ci)),     # p_idle
            pl.BlockSpec((1, tb_c), lambda ci, ti: (0, ci)),     # p_max
            pl.BlockSpec((1, tb_c), lambda ci, ti: (0, ci)),     # r
        ],
        out_specs=pl.BlockSpec((1, tb_c), lambda ci, ti: (0, ci)),
        out_shape=jax.ShapeDtypeStruct((1, cp), jnp.float32),
        interpret=interpret,
    )(u, real, pi, pm, rr)
    # normalization matches power.mape: mean over the *nonzero-real* bins
    # (zero-real bins carry no meaningful percentage error and were masked
    # inside the kernel); an all-zero window is undefined -> NaN for every
    # candidate, so the calibrator keeps its incumbent instead of "fitting".
    n_nz = jnp.sum(jnp.abs(real_power.astype(jnp.float32)) > 1e-9)
    scaled = out[0, :c] * (100.0 / jnp.maximum(n_nz, 1))
    return jnp.where(n_nz > 0, scaled, jnp.nan)

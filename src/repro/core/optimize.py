"""Carbon-aware scenario *optimizer*: search the cap/shift/topology space.

The batched what-if engine (:mod:`repro.core.scenarios`) *evaluates* a
hand-written grid of candidates; the paper's stage-3 vision is the twin
*finding* the operating point to propose.  This module closes that gap: a
search driver that optimizes over the scenario knob space — continuous power
caps (``power_cap_w``, ``carbon_cap_base_w``, ``carbon_cap_slope``), the
integer deferrable-job ``shift_bins`` axis, and discrete topology/scheduler
candidates — against a scalarized :class:`ObjectiveSpec` (weighted gCO2 +
energy + SLO-violation penalties, with hard-constraint masking).

Design rules the driver obeys:

* **Every generation is one already-compiled program.**  Candidates are
  evaluated in fixed-shape batches of ``OptimizerConfig.batch_size`` lanes
  through :func:`repro.core.scenarios.run_scenarios`, with ``max_hosts`` /
  ``max_backfill`` pinned across generations, so the jitted evaluator
  compiles exactly once for the whole search (asserted in
  ``benchmarks/whatif_batch.py``) and composes with ``shard=True`` on a
  device mesh.
* **Deterministic under an explicit PRNG key.**  All sampling flows from the
  ``key`` argument through ``jax.random.fold_in`` — no ambient state, so a
  fixed key makes the whole trajectory (candidates, objectives, incumbent
  choices) bit-reproducible (pinned by ``tests/golden/optimize_trajectory.npz``).
* **Successive halving + coordinate refinement.**  Generation 0 seeds the
  search (a coarse grid over the discretized space, or uniform samples);
  each later generation keeps a halving number of survivors and resamples
  around them with per-axis widths that shrink by ``refine_scale`` — local
  refinement around incumbents on the continuous axes, occasional discrete
  mutation on the structure axis.
* **The baseline and incumbent ride every batch** (lanes 0 and 1), so the
  winner always compares against the *current* configuration, elitism is
  structural, and the final batch yields operator-grade
  :class:`~repro.core.scenarios.ScenarioSummary` records for both without an
  extra compile.

``Orchestrator.optimize_whatif`` wires this into the twin loop: the search
space is built against the *current calibrated* ``TwinState`` params and the
winning operating point is routed through
:func:`repro.core.feedback.propose_from_optimum` and the HITL gate.

>>> spec = ObjectiveSpec(w_gco2_kg=1.0, w_energy_kwh=0.1,
...                      max_unplaced_jobs=0)
>>> spec.w_gco2_kg
1.0
>>> space = SearchSpace(power_cap_w=(40e3, 80e3), shift_bins=(0, 12))
>>> len(space.grid(levels=3))          # 1 structure x 3 caps x 3 shifts
9
>>> [s.shift_bins for s in space.grid(levels=3)][:3]
[0, 6, 12]
>>> SearchSpace(power_cap_w=(80e3, 40e3))
Traceback (most recent call last):
    ...
ValueError: power_cap_w range (80000.0, 40000.0) must have lo <= hi
"""

from __future__ import annotations

import dataclasses
import itertools
import math

import jax
import numpy as np

from repro.core.power import PowerParams
from repro.core.scenarios import (
    Scenario,
    ScenarioSummary,
    build_scenario_set,
    run_scenarios,
    summarize_scenarios,
)
from repro.traces.schema import DatacenterConfig, Workload

Array = jax.Array

#: continuous axes of a :class:`SearchSpace` (name on Scenario == name here)
_CONT_AXES = ("power_cap_w", "carbon_cap_base_w", "carbon_cap_slope")


# -- objective ----------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """Scalarized operator objective the search minimizes.

    ``total = w_gco2_kg * gCO2[kg] + w_energy_kwh * energy[kWh]
    + w_cost * energy_cost[$]
    + w_wait * max(0, mean_wait - wait_target_bins)
    + w_makespan * max(0, makespan - makespan_target_bins)
    + w_unplaced * unplaced_jobs + w_throttled * cap_exceeded_bins``

    The penalty terms price SLO violations (queue wait, horizon makespan,
    unfinished work) and cap-throttled bins (the enforced cap trades
    delivered performance for watts — a tight cap must not look free); the
    ``max_*`` fields are *hard* constraints — a candidate violating any of
    them is masked infeasible (objective ``+inf``) and can never become the
    incumbent, no matter its score.  Weights must be finite and >= 0 (this
    is a cost, not a reward), and at least one must be positive.  A non-zero
    ``w_gco2_kg`` requires a carbon-intensity trace at :func:`optimize`
    time; a non-zero ``w_cost`` (or a ``max_energy_cost`` bound) requires a
    spot-price trace the same way — ``w_cost`` weights *dollars*, so with
    both carbon and cost active the search trades them at the chosen
    exchange rate.
    """

    w_gco2_kg: float = 1.0          # per kg CO2
    w_energy_kwh: float = 0.0       # per kWh delivered
    w_wait: float = 1.0             # per mean queue-wait bin above target
    w_makespan: float = 0.0         # per makespan bin above target
    w_unplaced: float = 100.0       # per valid job never started
    w_throttled: float = 0.0        # per bin where the cap throttled demand
    w_cost: float = 0.0             # per $ of spot-priced energy
    wait_target_bins: float = 0.0
    makespan_target_bins: float = 0.0
    max_unplaced_jobs: int | None = None
    max_mean_wait_bins: float | None = None
    max_p99_wait_bins: float | None = None
    max_peak_power_w: float | None = None
    max_energy_cost: float | None = None

    _WEIGHTS = ("w_gco2_kg", "w_energy_kwh", "w_wait", "w_makespan",
                "w_unplaced", "w_throttled", "w_cost")

    def __post_init__(self):
        for k in (*self._WEIGHTS, "wait_target_bins", "makespan_target_bins"):
            v = getattr(self, k)
            if not (math.isfinite(v) and v >= 0):
                raise ValueError(
                    f"objective {k} must be finite and >= 0, got {v}")
        if not any(getattr(self, k) > 0 for k in self._WEIGHTS):
            raise ValueError("objective needs at least one positive weight")
        for k in ("max_unplaced_jobs", "max_mean_wait_bins",
                  "max_p99_wait_bins", "max_peak_power_w"):
            v = getattr(self, k)
            if v is not None and (math.isnan(v) or v < 0):
                raise ValueError(f"objective {k} must be >= 0, got {v}")
        # cost may legitimately be negative (spot markets pay consumers),
        # so its bound is only required to be non-NaN
        if self.max_energy_cost is not None and math.isnan(self.max_energy_cost):
            raise ValueError("objective max_energy_cost must not be NaN")


#: per-candidate fields :func:`score_batch` reports (all ``[S]`` float64)
BREAKDOWN_FIELDS = (
    "gco2_kg", "energy_kwh", "mean_wait_bins", "p99_wait_bins",
    "makespan_bins", "unplaced_jobs", "peak_power_w", "cap_exceeded_bins",
    "penalty_wait", "penalty_makespan", "penalty_unplaced",
    "penalty_throttled", "energy_cost", "total",
)


def score_batch(spec: ObjectiveSpec, ss, sim, pred, *,
                t_bins: int) -> dict[str, np.ndarray]:
    """Score a batched sweep's outputs against an objective, host-side.

    Returns a dict of ``[S]`` float64 arrays: the :data:`BREAKDOWN_FIELDS`
    components, plus ``feasible`` (bool — every hard constraint holds and
    the total is finite) and ``objective`` (``total`` with infeasible lanes
    masked to ``+inf`` — the array the search driver ranks on).
    """
    start = np.asarray(sim.job_start)                     # [S, J]
    submit = np.asarray(ss.workload.submit_bin)           # [S, J] post-shift
    dur = np.maximum(np.asarray(ss.workload.duration_bins), 1)
    valid = np.asarray(ss.workload.valid)                 # [S, J]
    s_n = start.shape[0]

    placed = (start >= 0) & valid
    unplaced = ((start < 0) & valid).sum(axis=1).astype(np.float64)
    waits = np.where(placed, start - submit, 0).astype(np.float64)
    n_placed = placed.sum(axis=1)
    mean_wait = np.where(
        n_placed > 0, waits.sum(axis=1) / np.maximum(n_placed, 1), 0.0)
    p99_wait = np.zeros(s_n, np.float64)
    for s in range(s_n):                   # tiny per-lane percentile loop
        w = (start[s] - submit[s])[placed[s]]
        p99_wait[s] = float(np.percentile(w, 99)) if w.size else 0.0
    end = np.where(placed, np.minimum(start + dur, t_bins), 0)
    makespan = end.max(axis=1).astype(np.float64)

    power = np.asarray(pred.power_w, np.float64)            # [S, T] delivered
    demand = (np.asarray(pred.power_demand_w, np.float64)
              if pred.power_demand_w is not None else power)
    energy = np.asarray(pred.energy_kwh, np.float64).sum(axis=1)
    peak_power = power.max(axis=1)
    # bins where the enforced cap clipped demand (delivered < wanted)
    cap_exceeded = (demand > power).sum(axis=1).astype(np.float64)
    if pred.gco2 is not None:
        gco2_kg = np.asarray(pred.gco2, np.float64).sum(axis=1) / 1e3
    elif spec.w_gco2_kg > 0:
        raise ValueError(
            "objective weights gCO2 but the sweep ran without a "
            "carbon_intensity trace — pass carbon_intensity=[t_bins] "
            "gCO2/kWh or set w_gco2_kg=0")
    else:
        gco2_kg = np.full(s_n, np.nan)
    if pred.energy_cost is not None:
        cost = np.asarray(pred.energy_cost, np.float64).sum(axis=1)
    elif spec.w_cost > 0 or spec.max_energy_cost is not None:
        raise ValueError(
            "objective prices energy cost (w_cost/max_energy_cost) but the "
            "sweep ran without a price trace — pass price=[t_bins] $/kWh "
            "or drop the cost terms")
    else:
        cost = np.full(s_n, np.nan)

    pen_wait = spec.w_wait * np.maximum(mean_wait - spec.wait_target_bins, 0.0)
    pen_mk = spec.w_makespan * np.maximum(
        makespan - spec.makespan_target_bins, 0.0)
    pen_unp = spec.w_unplaced * unplaced
    pen_thr = spec.w_throttled * cap_exceeded
    total = (pen_wait + pen_mk + pen_unp + pen_thr
             + spec.w_energy_kwh * energy)
    if spec.w_gco2_kg > 0:
        total = total + spec.w_gco2_kg * gco2_kg
    if spec.w_cost > 0:
        total = total + spec.w_cost * cost

    feasible = np.isfinite(total)
    if spec.max_unplaced_jobs is not None:
        feasible &= unplaced <= spec.max_unplaced_jobs
    if spec.max_mean_wait_bins is not None:
        feasible &= mean_wait <= spec.max_mean_wait_bins
    if spec.max_p99_wait_bins is not None:
        feasible &= p99_wait <= spec.max_p99_wait_bins
    if spec.max_peak_power_w is not None:
        feasible &= peak_power <= spec.max_peak_power_w
    if spec.max_energy_cost is not None:
        feasible &= cost <= spec.max_energy_cost

    return {
        "gco2_kg": gco2_kg, "energy_kwh": energy,
        "mean_wait_bins": mean_wait, "p99_wait_bins": p99_wait,
        "makespan_bins": makespan, "unplaced_jobs": unplaced,
        "peak_power_w": peak_power, "cap_exceeded_bins": cap_exceeded,
        "penalty_wait": pen_wait, "penalty_makespan": pen_mk,
        "penalty_unplaced": pen_unp, "penalty_throttled": pen_thr,
        "energy_cost": cost,
        "total": total, "feasible": feasible,
        "objective": np.where(feasible, total, np.inf),
    }


# -- search space -------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SearchSpace:
    """The knob space :func:`optimize` searches.

    ``structures`` are discrete candidates — :class:`Scenario` templates
    carrying the topology/scheduler axes (``num_hosts``, ``cores_per_host``,
    ``policy``, ``backfill_depth``); the sampled continuous knobs are grafted
    onto the chosen template.  Each ``(lo, hi)`` range activates one
    continuous axis (``None`` leaves the template's own value untouched);
    ``shift_bins`` is the integer deferrable-job time-shift axis.  Cap
    ranges must be positive (a cap of 0 W is not a configuration, it is an
    outage) and slope/shift ranges merely ordered and finite.
    """

    structures: tuple[Scenario, ...] = (Scenario(),)
    power_cap_w: tuple[float, float] | None = None
    carbon_cap_base_w: tuple[float, float] | None = None
    carbon_cap_slope: tuple[float, float] | None = None
    shift_bins: tuple[int, int] | None = None

    def __post_init__(self):
        if not self.structures:
            raise ValueError("search space needs at least one structure")
        for name in (*_CONT_AXES, "shift_bins"):
            rng = getattr(self, name)
            if rng is None:
                continue
            lo, hi = float(rng[0]), float(rng[1])
            if not (math.isfinite(lo) and math.isfinite(hi)):
                raise ValueError(f"{name} range {rng} must be finite")
            if lo > hi:
                raise ValueError(f"{name} range {rng} must have lo <= hi")
            if name in ("power_cap_w", "carbon_cap_base_w") and lo <= 0:
                raise ValueError(f"{name} range {rng} must be > 0 W")

    def active_axes(self) -> tuple[str, ...]:
        """Names of the activated continuous axes (+ ``shift_bins``)."""
        return tuple(n for n in (*_CONT_AXES, "shift_bins")
                     if getattr(self, n) is not None)

    def grid(self, levels: int = 3) -> list[Scenario]:
        """The exhaustive discretized grid: structures x ``levels`` per axis.

        Continuous axes discretize to ``levels`` evenly spaced points
        (``shift_bins`` to unique rounded integers); the product over all
        active axes and structures is the grid :func:`optimize` seeds its
        first generation with under ``init="grid"`` — and the reference an
        optimizer run is asserted against (the incumbent can only be at
        least as good, having evaluated a superset).
        """
        if levels < 1:
            raise ValueError(f"levels must be >= 1, got {levels}")
        axes: list[list] = []
        names: list[str] = []
        for name in _CONT_AXES:
            rng = getattr(self, name)
            if rng is not None:
                axes.append([float(v) for v in
                             np.unique(np.linspace(rng[0], rng[1], levels))])
                names.append(name)
        if self.shift_bins is not None:
            lo, hi = self.shift_bins
            axes.append([int(v) for v in np.unique(
                np.round(np.linspace(lo, hi, levels)).astype(np.int64))])
            names.append("shift_bins")
        out = []
        for si, tmpl in enumerate(self.structures):
            for combo in itertools.product(*axes):
                over = dict(zip(names, combo))
                name = "-".join(
                    [tmpl.name or f"t{si}"]
                    + [f"{n.split('_')[0]}{v:g}" for n, v in over.items()])
                out.append(dataclasses.replace(tmpl, name=name, **over))
        return out

    def max_hosts(self, dc: DatacenterConfig) -> int:
        """Padded host axis covering every structure plus the baseline."""
        return max([dc.num_hosts] + [
            s.num_hosts if s.num_hosts is not None else dc.num_hosts
            for s in self.structures])

    def max_backfill(self) -> int:
        """Static backfill window covering every structure (baseline = 0)."""
        return max([0] + [int(s.backfill_depth) for s in self.structures])


# -- driver -------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    """Search-driver knobs.

    ``batch_size`` lanes per evaluation batch (fixed — the single-compile
    guarantee); lanes 0/1 are reserved for the baseline and the incumbent,
    so each batch evaluates ``batch_size - 2`` fresh candidates.
    ``generations`` refinement rounds follow the init generation; round g
    keeps ``max(1, batch_size >> g)`` survivors (successive halving, unless
    ``survivors`` pins a count) and samples around them with per-axis widths
    shrunk by ``refine_scale ** g``.
    """

    batch_size: int = 16
    generations: int = 3
    init: str = "grid"              # "grid" | "random"
    init_levels: int = 3            # grid discretization per continuous axis
    survivors: int | None = None    # None = halving schedule
    refine_scale: float = 0.5
    mutate_structure_prob: float = 0.25

    def __post_init__(self):
        if self.batch_size < 4:
            raise ValueError(
                f"batch_size must be >= 4 (2 reserved lanes + candidates), "
                f"got {self.batch_size}")
        if self.generations < 0:
            raise ValueError(f"generations must be >= 0, got {self.generations}")
        if self.init not in ("grid", "random"):
            raise ValueError(f"init must be 'grid' or 'random', got {self.init!r}")
        if not 0.0 < self.refine_scale <= 1.0:
            raise ValueError(
                f"refine_scale must be in (0, 1], got {self.refine_scale}")


@dataclasses.dataclass(frozen=True)
class _Knobs:
    """One candidate's point in the search space (host-side, hashable)."""

    struct: int                          # index into structures; -1 = baseline
    power_cap_w: float | None = None
    carbon_cap_base_w: float | None = None
    carbon_cap_slope: float | None = None
    shift_bins: int | None = None


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One evaluated operating point (host-side record)."""

    scenario: Scenario
    objective: float                     # +inf when infeasible
    feasible: bool
    breakdown: dict                      # BREAKDOWN_FIELDS -> float
    generation: int                      # 0 = init generation
    lane: int                            # lane within its evaluation batch


@dataclasses.dataclass(frozen=True)
class OptimizeResult:
    """What the search found, plus everything needed to audit it.

    ``best`` is the incumbent — the feasible candidate with the lowest
    objective over *every* evaluation the driver made (``history`` holds
    them all, in evaluation order).  ``best_summary``/``baseline_summary``
    are operator-grade records from the final evaluation batch, ready for
    :func:`repro.core.feedback.propose_from_optimum`.
    ``incumbent_objective`` traces the incumbent after each batch — the
    convergence curve the trajectory golden pins.

    ``candidates`` counts *fresh* knob points the search tried;
    ``evaluations`` counts every lane scored, including the reserved
    baseline/incumbent lanes and incumbent padding replicas — use
    ``candidates`` for search-budget comparisons (candidates/sec, grid at
    equal budget), ``evaluations`` for raw evaluator work.
    """

    best: Candidate
    baseline: Candidate
    best_summary: ScenarioSummary
    baseline_summary: ScenarioSummary
    history: tuple[Candidate, ...]
    incumbent_objective: np.ndarray      # [n_batches] float64
    candidates: int
    evaluations: int
    batches: int


def _scenario_from_knobs(space: SearchSpace, kn: _Knobs, name: str) -> Scenario:
    if kn.struct < 0:
        # the reserved baseline lane: the PUE model describes the *facility*
        # (same building for every candidate), not an intervention knob — a
        # bare-IT baseline would beat every facility-priced candidate on
        # energy by construction.  Inherit structures[0]'s PUE model.
        t0 = space.structures[0]
        tmpl = Scenario(pue_base=t0.pue_base, pue_amb_coeff=t0.pue_amb_coeff,
                        pue_amb_ref=t0.pue_amb_ref,
                        pue_load_coeff=t0.pue_load_coeff)
    else:
        tmpl = space.structures[kn.struct]
    over: dict = {}
    # a None knob value on an active axis means "inherit the template" —
    # the baseline lane carries no sampled values by construction
    for axis in _CONT_AXES:
        if getattr(space, axis) is not None and getattr(kn, axis) is not None:
            over[axis] = getattr(kn, axis)
    if space.shift_bins is not None and kn.shift_bins is not None:
        over["shift_bins"] = int(kn.shift_bins)
    return dataclasses.replace(tmpl, name=name, **over)


def _knobs_from_scenario(space: SearchSpace, struct: int,
                         sc: Scenario) -> _Knobs:
    return _Knobs(
        struct=struct,
        power_cap_w=(sc.power_cap_w if space.power_cap_w is not None
                     else None),
        carbon_cap_base_w=(sc.carbon_cap_base_w
                           if space.carbon_cap_base_w is not None else None),
        carbon_cap_slope=(sc.carbon_cap_slope
                          if space.carbon_cap_slope is not None else None),
        shift_bins=(int(sc.shift_bins) if space.shift_bins is not None
                    else None),
    )


def _grid_knobs(space: SearchSpace, levels: int) -> list[_Knobs]:
    """The discretized grid as knob points (struct index preserved)."""
    scs = space.grid(levels)
    per_struct = len(scs) // len(space.structures)
    return [_knobs_from_scenario(space, i // per_struct, sc)
            for i, sc in enumerate(scs)]


def _sample_knobs(space: SearchSpace, key: Array, n: int) -> list[_Knobs]:
    """n uniform samples over the space (init="random")."""
    ks = jax.random.split(key, 5)
    struct = np.asarray(jax.random.randint(
        ks[0], (n,), 0, len(space.structures)))
    draws: dict[str, np.ndarray] = {}
    for i, axis in enumerate(_CONT_AXES):
        rng = getattr(space, axis)
        if rng is not None:
            draws[axis] = np.asarray(jax.random.uniform(
                ks[1 + i], (n,), minval=rng[0], maxval=rng[1]), np.float64)
    if space.shift_bins is not None:
        lo, hi = space.shift_bins
        draws["shift_bins"] = np.asarray(jax.random.randint(
            ks[4], (n,), lo, hi + 1))
    return [_Knobs(struct=int(struct[i]),
                   **{a: (float(v[i]) if a != "shift_bins" else int(v[i]))
                      for a, v in draws.items()})
            for i in range(n)]


def _refine_knobs(space: SearchSpace, key: Array, parents: list[_Knobs],
                  n: int, width_scale: float,
                  mutate_prob: float) -> list[_Knobs]:
    """n children around the survivors: gaussian coordinate refinement on
    the continuous axes (clipped to range), occasional structure mutation."""
    ks = jax.random.split(key, 6)
    mutate = np.asarray(jax.random.bernoulli(ks[0], mutate_prob, (n,)))
    rand_struct = np.asarray(jax.random.randint(
        ks[1], (n,), 0, len(space.structures)))
    normals = {axis: np.asarray(jax.random.normal(ks[2 + i], (n,)),
                                np.float64)
               for i, axis in enumerate(_CONT_AXES)}
    shift_n = np.asarray(jax.random.normal(ks[5], (n,)), np.float64)

    out = []
    for i in range(n):
        p = parents[i % len(parents)]
        fields: dict = {"struct": (int(rand_struct[i]) if mutate[i]
                                   else p.struct)}
        for axis in _CONT_AXES:
            rng = getattr(space, axis)
            if rng is None:
                continue
            lo, hi = float(rng[0]), float(rng[1])
            base = getattr(p, axis)
            base = 0.5 * (lo + hi) if base is None else float(base)
            width = 0.5 * (hi - lo) * width_scale
            fields[axis] = float(np.clip(base + normals[axis][i] * width,
                                         lo, hi))
        if space.shift_bins is not None:
            lo, hi = space.shift_bins
            base = (0.5 * (lo + hi) if p.shift_bins is None
                    else float(p.shift_bins))
            width = max(0.5 * (hi - lo) * width_scale, 1.0)
            fields["shift_bins"] = int(np.clip(
                np.round(base + shift_n[i] * width), lo, hi))
        out.append(_Knobs(**fields))
    return out


def optimize(
    workload: Workload,
    dc: DatacenterConfig,
    space: SearchSpace,
    objective: ObjectiveSpec = ObjectiveSpec(),
    *,
    t_bins: int,
    base_params: PowerParams = PowerParams(),
    carbon_intensity: "np.ndarray | Array | None" = None,
    ambient_c: "np.ndarray | Array | None" = None,
    price: "np.ndarray | Array | None" = None,
    key: "int | Array" = 0,
    config: OptimizerConfig = OptimizerConfig(),
    model: str = "opendc",
    max_starts_per_bin: int = 64,
    shard: bool = False,
    mesh=None,
    use_pallas: bool = False,
) -> OptimizeResult:
    """Search the scenario space for the best feasible operating point.

    Runs generations of fixed-shape candidate batches through
    :func:`repro.core.scenarios.run_scenarios` (one compiled program for the
    whole search; ``shard=True`` spans a device mesh bit-for-bit — same
    guarantee as the evaluator itself), scores every lane against
    ``objective`` (:func:`score_batch`), and refines around survivors.
    Deterministic given ``key`` (an int seed or a ``jax.random`` key).
    ``use_pallas`` selects the fused readout kernel inside the evaluator
    (see :func:`run_scenarios`).

    On the single-device path every generation *donates* its
    ``ScenarioSet`` buffers to the evaluator (``run_scenarios(donate=True)``
    — the set is rebuilt per batch, so the device copies are dead weight
    after the call); the host-side leaves that scoring and the final
    summaries read are snapshotted first.

    Raises ``ValueError`` when the space needs a carbon trace that was not
    supplied, or when *no* evaluated candidate (baseline included) satisfies
    the hard constraints.
    """
    if isinstance(key, (int, np.integer)):
        key = jax.random.PRNGKey(int(key))
    if carbon_intensity is None and (space.carbon_cap_base_w is not None
                                     or space.carbon_cap_slope is not None):
        raise ValueError(
            "search space activates carbon-aware cap axes but no "
            "carbon_intensity trace was supplied")
    if carbon_intensity is None and objective.w_gco2_kg > 0:
        raise ValueError(
            "objective weights gCO2 (w_gco2_kg > 0) but no carbon_intensity "
            "trace was supplied — pass one or set w_gco2_kg=0")
    if price is None and (objective.w_cost > 0
                          or objective.max_energy_cost is not None):
        raise ValueError(
            "objective prices energy cost (w_cost/max_energy_cost) but no "
            "price trace was supplied — pass price=[t_bins] $/kWh or drop "
            "the cost terms")
    if ambient_c is None and any(s.pue_amb_coeff != 0.0
                                 for s in space.structures):
        raise ValueError(
            "search-space structure(s) set pue_amb_coeff but no ambient_c "
            "trace was supplied — pass ambient_c=[t_bins] °C")

    mh = space.max_hosts(dc)
    mb = space.max_backfill()
    # axis-presence flags are jit cache-key aux on the ScenarioSet: pin them
    # from the *space* (not per batch) so a generation whose mutations happen
    # to drop every failure/PUE lane cannot flip the flag and recompile
    has_failures = any(s.failures for s in space.structures)
    pue_on = any(s.pue_base is not None for s in space.structures)
    s_lanes = config.batch_size
    per_batch = s_lanes - 2              # lanes 0/1 = baseline/incumbent
    baseline_kn = _Knobs(struct=-1)
    if space.shift_bins is not None:
        baseline_kn = dataclasses.replace(baseline_kn, shift_bins=0)

    history: list[Candidate] = []
    history_kn: list[_Knobs] = []        # knob point per history entry
    incumbent_trace: list[float] = []
    incumbent: Candidate | None = None
    incumbent_kn = baseline_kn
    baseline_cand: Candidate | None = None
    final_lanes: list[_Knobs] = []
    final_artifacts = None               # (ss, sim, pred) of the last batch
    n_fresh = 0                          # fresh candidate lanes (no padding)

    def eval_batch(knobs: list[_Knobs], gen: int) -> None:
        nonlocal incumbent, incumbent_kn, baseline_cand, final_artifacts, \
            final_lanes, n_fresh
        # fixed S: pad short batches with incumbent replicas (cheap re-evals
        # of a known point — never a recompile)
        knobs = list(knobs)[:per_batch]
        n_fresh += len(knobs)
        knobs += [incumbent_kn] * (per_batch - len(knobs))
        lanes = [baseline_kn, incumbent_kn, *knobs]
        batch = len(incumbent_trace)     # names stay unique across batches
        scenarios = [
            _scenario_from_knobs(space, kn, name=(
                "baseline" if i == 0 else
                "incumbent" if i == 1 else f"g{gen}b{batch}-l{i}"))
            for i, kn in enumerate(lanes)]
        ss = build_scenario_set(workload, dc, scenarios, base_params,
                                max_hosts=mh, max_backfill=mb,
                                has_failures=has_failures, pue_on=pue_on)
        # the donating call below invalidates ss's device buffers, so the
        # leaves scoring + the final summaries read live on as a host copy
        ss_host = jax.tree.map(np.asarray, ss)
        sim, pred = run_scenarios(
            ss, max_hosts=mh, t_bins=t_bins,
            max_starts_per_bin=max_starts_per_bin, model=model,
            carbon_intensity=carbon_intensity, ambient_c=ambient_c,
            price=price, shard=shard, mesh=mesh, use_pallas=use_pallas,
            donate=not shard)
        scores = score_batch(objective, ss_host, sim, pred, t_bins=t_bins)
        for i, kn in enumerate(lanes):
            cand = Candidate(
                scenario=scenarios[i],
                objective=float(scores["objective"][i]),
                feasible=bool(scores["feasible"][i]),
                # no-price sweeps mark cost absent with None, not NaN —
                # candidates are compared with == and NaN != NaN would make
                # otherwise-identical breakdowns unequal (gco2_kg keeps its
                # historical NaN-when-absent convention).
                breakdown={
                    f: (None if f == "energy_cost"
                        and not np.isfinite(scores[f][i])
                        else float(scores[f][i]))
                    for f in BREAKDOWN_FIELDS},
                generation=gen, lane=i)
            history.append(cand)
            history_kn.append(kn)
            if i == 0 and baseline_cand is None:
                baseline_cand = cand
            if cand.feasible and (incumbent is None
                                  or cand.objective < incumbent.objective):
                incumbent, incumbent_kn = cand, kn
        incumbent_trace.append(
            incumbent.objective if incumbent is not None else math.inf)
        final_artifacts, final_lanes = (ss_host, sim, pred), lanes

    # generation 0: seed the search
    if config.init == "grid":
        seeds = _grid_knobs(space, config.init_levels)
    else:
        seeds = _sample_knobs(space, jax.random.fold_in(key, 0), per_batch)
    n_batches0 = max(1, -(-len(seeds) // per_batch))
    for b in range(n_batches0):
        eval_batch(seeds[b * per_batch:(b + 1) * per_batch], gen=0)

    # refinement generations: successive halving + coordinate refinement
    for g in range(1, config.generations + 1):
        k_g = (config.survivors if config.survivors is not None
               else max(1, s_lanes >> g))
        # survivors = the best distinct knob points evaluated so far (their
        # exact _Knobs ride along with the history, so a survivor always
        # refines around its true structure template)
        ranked = sorted((i for i, c in enumerate(history) if c.feasible),
                        key=lambda i: history[i].objective)
        seen, parents = set(), []
        for i in ranked:
            kn = history_kn[i]
            if kn not in seen:
                seen.add(kn)
                parents.append(kn)
            if len(parents) >= k_g:
                break
        if not parents:
            parents = [baseline_kn]
        children = _refine_knobs(
            space, jax.random.fold_in(key, g), parents, per_batch,
            width_scale=config.refine_scale ** g,
            mutate_prob=config.mutate_structure_prob)
        eval_batch(children, gen=g)

    if incumbent is None:
        raise ValueError(
            "no feasible candidate found (baseline included) — relax the "
            "hard constraints or widen the search space")

    # operator-grade summaries from the final batch: lane 0 is the baseline
    # and lane 1 the final incumbent (identical program + inputs in every
    # batch, so these equal the lanes the candidates were first scored from)
    ss_f, sim_f, pred_f = final_artifacts
    summaries = summarize_scenarios(ss_f, sim_f, pred_f,
                                    carbon_intensity=carbon_intensity)
    # the final incumbent always rides the final batch: lane 1 carries the
    # incumbent as of the batch's start, and if that batch improved it, the
    # improving candidate is one of its own lanes
    best_lane = final_lanes.index(incumbent_kn)
    return OptimizeResult(
        best=incumbent,
        baseline=baseline_cand,
        best_summary=dataclasses.replace(summaries[best_lane],
                                         name=incumbent.scenario.name),
        baseline_summary=summaries[0],
        history=tuple(history),
        incumbent_objective=np.asarray(incumbent_trace, np.float64),
        candidates=n_fresh,
        evaluations=len(history),
        batches=len(incumbent_trace),
    )

"""SLO definitions and monitors (SLO-aware simulation + NFR checks).

NFR1 (paper §2.1): prediction error (MAPE) must stay below 10 % for at least
90 % of the operational time.  The monitor tracks the per-window MAPE stream
and the under/over-estimation bias the paper analyses in Fig. 6.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLO:
    """A service-level objective over a telemetry-derived series."""

    name: str
    metric: str                  # e.g. "mape", "power_w", "queue_len"
    threshold: float
    comparison: str = "lt"       # metric must be: lt | le | gt | ge threshold
    min_compliance: float = 0.90 # fraction of time the comparison must hold

    def holds(self, value: float) -> bool:
        return {
            "lt": value < self.threshold,
            "le": value <= self.threshold,
            "gt": value > self.threshold,
            "ge": value >= self.threshold,
        }[self.comparison]


#: NFR1 exactly as stated in the paper.
NFR1 = SLO(name="NFR1-accuracy", metric="mape", threshold=10.0,
           comparison="lt", min_compliance=0.90)


@dataclasses.dataclass
class SLOReport:
    slo: SLO
    samples: int
    compliant: int

    @property
    def compliance(self) -> float:
        return self.compliant / self.samples if self.samples else 1.0

    @property
    def met(self) -> bool:
        return self.compliance >= self.slo.min_compliance


class SLOMonitor:
    """Streams per-sample metric values against a set of SLOs."""

    def __init__(self, slos: list[SLO]):
        self.slos = slos
        self._counts = {s.name: [0, 0] for s in slos}  # [samples, compliant]

    def observe(self, metric: str, values: np.ndarray | list[float]) -> None:
        arr = np.atleast_1d(np.asarray(values, np.float64))
        for s in self.slos:
            if s.metric != metric:
                continue
            c = self._counts[s.name]
            c[0] += arr.size
            c[1] += int(sum(s.holds(float(v)) for v in arr))

    def report(self) -> list[SLOReport]:
        return [
            SLOReport(s, *self._counts[s.name]) for s in self.slos
        ]


@dataclasses.dataclass
class BiasTracker:
    """Under/over-estimation bias of the predictive model (paper Fig. 6).

    Under-estimation (sim < real) risks under-provisioning; over-estimation
    wastes energy (paper §3.4, SPEC RG Cloud framing [13]).

    Exact ties (``sim == real``) carry no directional information and are
    counted separately — folding them into *over* (the pre-fix behavior)
    skewed the Fig. 6 bias split whenever predictions hit measurements
    exactly (synthetic traces, quantized meters, zero-power windows).
    ``under_fraction``/``over_fraction`` are therefore fractions of the
    *directional* samples only; ``ties`` is reported alongside.
    """

    under: int = 0
    over: int = 0
    ties: int = 0

    def observe(self, real: np.ndarray, sim: np.ndarray) -> None:
        real = np.asarray(real)
        sim = np.asarray(sim)
        self.under += int(np.sum(sim < real))
        self.over += int(np.sum(sim > real))
        self.ties += int(np.sum(sim == real))

    @property
    def samples(self) -> int:
        return self.under + self.over + self.ties

    @property
    def directional(self) -> int:
        """Samples that actually lean one way (excludes exact ties)."""
        return self.under + self.over

    @property
    def under_fraction(self) -> float:
        return self.under / self.directional if self.directional else 0.0

    @property
    def over_fraction(self) -> float:
        return self.over / self.directional if self.directional else 0.0

"""SLO definitions and monitors (SLO-aware simulation + NFR checks).

NFR1 (paper §2.1): prediction error (MAPE) must stay below 10 % for at least
90 % of the operational time.  The monitor tracks the per-window MAPE stream
and the under/over-estimation bias the paper analyses in Fig. 6.

Two styles live here:

  * the *imperative* monitors (:class:`SLOMonitor`, :class:`BiasTracker`) —
    host-side streaming objects for interactive use;
  * the *functional* accumulators (:func:`observe_slos`,
    :func:`observe_bias`) — pure jnp update rules over integer count arrays,
    used by the pure twin core (``repro.core.state.twin_step``) so the whole
    windowed cycle stays jit/vmap-able.  The imperative classes hydrate from
    those counts (:meth:`SLOMonitor.from_counts`) for reporting.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SLO:
    """A service-level objective over a telemetry-derived series."""

    name: str
    metric: str                  # e.g. "mape", "power_w", "queue_len"
    threshold: float
    comparison: str = "lt"       # metric must be: lt | le | gt | ge threshold
    min_compliance: float = 0.90 # fraction of time the comparison must hold

    def holds(self, value: float) -> bool:
        return {
            "lt": value < self.threshold,
            "le": value <= self.threshold,
            "gt": value > self.threshold,
            "ge": value >= self.threshold,
        }[self.comparison]


#: NFR1 exactly as stated in the paper.
NFR1 = SLO(name="NFR1-accuracy", metric="mape", threshold=10.0,
           comparison="lt", min_compliance=0.90)


@dataclasses.dataclass
class SLOReport:
    slo: SLO
    samples: int
    compliant: int

    @property
    def compliance(self) -> float:
        return self.compliant / self.samples if self.samples else 1.0

    @property
    def met(self) -> bool:
        return self.compliance >= self.slo.min_compliance


def slo_holds(slo: SLO, value):
    """Traced compliance check: does ``value`` satisfy the SLO's comparison?

    Pure jnp (the comparison operator is static, the value may be a tracer);
    NaN values never comply, matching the host-side :meth:`SLO.holds` where
    every comparison against NaN is False.
    """
    return {
        "lt": lambda v: v < slo.threshold,
        "le": lambda v: v <= slo.threshold,
        "gt": lambda v: v > slo.threshold,
        "ge": lambda v: v >= slo.threshold,
    }[slo.comparison](value)


def observe_slos(slos: tuple[SLO, ...], samples, compliant, value, valid,
                 metric: str = "mape"):
    """One functional SLO-accumulator update over a shared metric stream.

    ``samples``/``compliant`` are ``[len(slos)]`` int32 arrays; ``value`` is
    an observation of ``metric`` (scalar, may be NaN) and ``valid`` a bool
    scalar masking the whole update (no telemetry -> no observation).  Like
    the imperative :meth:`SLOMonitor.observe`, only SLOs defined over
    ``metric`` are updated — the rest keep their counts (and read as
    unobserved in reports).  Returns the updated ``(samples, compliant)``
    pair; pure, so `jit`/`vmap` compose.
    """
    if not slos:
        return samples, compliant
    inc = jnp.asarray(valid, jnp.int32)
    on = jnp.asarray([s.metric == metric for s in slos], jnp.int32)
    holds = jnp.stack([jnp.asarray(slo_holds(s, value), jnp.int32)
                       for s in slos])
    return samples + inc * on, compliant + holds * inc * on


def observe_bias(under, over, ties, real, sim, valid):
    """Functional :class:`BiasTracker` update (pure jnp).

    Counts the directional split of ``sim`` vs ``real`` over a window and
    adds it to the running int32 scalars when ``valid``; exact ties stay a
    separate bucket (same semantics as the imperative tracker).
    """
    inc = jnp.asarray(valid, jnp.int32)
    return (under + inc * jnp.sum(sim < real).astype(jnp.int32),
            over + inc * jnp.sum(sim > real).astype(jnp.int32),
            ties + inc * jnp.sum(sim == real).astype(jnp.int32))


class SLOMonitor:
    """Streams per-sample metric values against a set of SLOs."""

    def __init__(self, slos: list[SLO]):
        self.slos = slos
        self._counts = {s.name: [0, 0] for s in slos}  # [samples, compliant]

    @classmethod
    def from_counts(cls, slos: "list[SLO] | tuple[SLO, ...]",
                    samples, compliant) -> "SLOMonitor":
        """Hydrate a monitor from the pure core's accumulator arrays."""
        mon = cls(list(slos))
        for i, s in enumerate(mon.slos):
            mon._counts[s.name] = [int(np.asarray(samples)[i]),
                                   int(np.asarray(compliant)[i])]
        return mon

    def observe(self, metric: str, values: np.ndarray | list[float]) -> None:
        arr = np.atleast_1d(np.asarray(values, np.float64))
        for s in self.slos:
            if s.metric != metric:
                continue
            c = self._counts[s.name]
            c[0] += arr.size
            c[1] += int(sum(s.holds(float(v)) for v in arr))

    def report(self) -> list[SLOReport]:
        return [
            SLOReport(s, *self._counts[s.name]) for s in self.slos
        ]


@dataclasses.dataclass
class BiasTracker:
    """Under/over-estimation bias of the predictive model (paper Fig. 6).

    Under-estimation (sim < real) risks under-provisioning; over-estimation
    wastes energy (paper §3.4, SPEC RG Cloud framing [13]).

    Exact ties (``sim == real``) carry no directional information and are
    counted separately — folding them into *over* (the pre-fix behavior)
    skewed the Fig. 6 bias split whenever predictions hit measurements
    exactly (synthetic traces, quantized meters, zero-power windows).
    ``under_fraction``/``over_fraction`` are therefore fractions of the
    *directional* samples only; ``ties`` is reported alongside.
    """

    under: int = 0
    over: int = 0
    ties: int = 0

    def observe(self, real: np.ndarray, sim: np.ndarray) -> None:
        real = np.asarray(real)
        sim = np.asarray(sim)
        self.under += int(np.sum(sim < real))
        self.over += int(np.sum(sim > real))
        self.ties += int(np.sum(sim == real))

    @property
    def samples(self) -> int:
        return self.under + self.over + self.ties

    @property
    def directional(self) -> int:
        """Samples that actually lean one way (excludes exact ties)."""
        return self.under + self.over

    @property
    def under_fraction(self) -> float:
        return self.under / self.directional if self.directional else 0.0

    @property
    def over_fraction(self) -> float:
        return self.over / self.directional if self.directional else 0.0

"""Power models for datacenter hosts.

The paper (§3.2) adopts the OpenDC analytical CPU power formula

    P(u) = P_idle + (P_max - P_idle) * (2u - u^r)

where ``u`` is CPU utilization in [0, 1], ``P_idle``/``P_max`` are the host's
idle and maximum power draw, and ``r`` is the *calibration parameter* tuned by
the Self-Calibrator (§2.4).  The FootPrinter baseline [30] uses the linear
special case obtained at r = 1 (P = P_idle + (P_max - P_idle) * u).

All models are pure functions over dense utilization tensors so they can be
vmapped over calibration candidates and pallas-tiled over (time, host) blocks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _concrete(x) -> np.ndarray | None:
    """Return ``x`` as a numpy array when it is concrete, else ``None``.

    Validation must never touch traced values: ``PowerParams`` is a pytree
    whose unflatten runs inside jit/vmap with tracers as leaves, and a
    concrete-only check there would abort tracing.
    """
    if isinstance(x, jax.core.Tracer):
        return None
    if isinstance(x, (bool, int, float, np.ndarray, np.generic, jax.Array)):
        try:
            return np.asarray(x)
        except Exception:  # e.g. a donated/deleted buffer
            return None
    return None


def validate_power_params(p_idle, p_max, r) -> None:
    """Reject parameterizations outside the model's valid domain — loudly.

    The OpenDC form ``P = P_idle + (P_max - P_idle)(2u - u^r)`` silently
    produces garbage outside it:

      * ``r <= 0`` — at ``u = 0`` the shape term ``2u - u^r`` is ``-1``
        (``0^0 = 1``) so the defaults yield **-210 W**, and ``r < 0``
        divides by zero (``0^r = inf`` -> ``-inf`` watts);
      * ``p_max < p_idle`` — a negative span inverts the curve (full load
        "draws less" than idle).

    Only *concrete* values are checked; traced values (inside jit/vmap)
    pass through — every host-side construction boundary (``PowerParams``
    itself, ``Scenario``, ``build_scenario_set``) is concrete, so bad
    values cannot reach a traced program unvalidated.
    """
    rv = _concrete(r)
    if rv is not None and rv.size and (~np.isfinite(rv) | (rv <= 0)).any():
        raise ValueError(
            f"power-model exponent r must be finite and > 0, got "
            f"{float(np.min(rv))}: r <= 0 makes P(u=0) negative "
            "(0^0 = 1 -> shape term -1), r < 0 yields -inf watts, and "
            "NaN/inf poisons every downstream kWh/gCO2")
    pi, pm = _concrete(p_idle), _concrete(p_max)
    if pi is not None and pi.size and (~np.isfinite(pi) | (pi < 0)).any():
        raise ValueError(
            f"p_idle must be finite and >= 0 W, got {float(np.min(pi))}")
    if pm is not None and pm.size and (~np.isfinite(pm)).any():
        raise ValueError("p_max must be finite W, got non-finite value(s)")
    if pi is not None and pm is not None and pi.size and pm.size:
        try:
            bad = np.broadcast_arrays(pm, pi)
        except ValueError:
            return  # non-broadcastable shapes fail later with a shape error
        if (bad[0] < bad[1]).any():
            raise ValueError(
                f"p_max must be >= p_idle (got p_max min "
                f"{float(bad[0].min())} < p_idle {float(bad[1].max())}): a "
                "negative span inverts the power curve")


@dataclasses.dataclass(frozen=True)
class PowerParams:
    """Parameters of the OpenDC analytical power model.

    Each field is a scalar (shared across hosts) or a ``[H]`` vector
    (per-host).  The calibrator treats ``r`` (and, beyond the paper,
    ``p_idle``/``p_max``) as free parameters.

    Construction validates concrete values (``r > 0``, ``p_max >= p_idle``,
    see :func:`validate_power_params`); traced leaves inside jit/vmap are
    exempt, so the pytree round-trip stays trace-safe.
    """

    p_idle: Array | float = 70.0   # W, idle draw per host
    p_max: Array | float = 350.0   # W, full-load draw per host
    r: Array | float = 2.0         # calibration exponent (paper §3.2)

    def __post_init__(self):
        validate_power_params(self.p_idle, self.p_max, self.r)

    def tree_flatten(self):  # pragma: no cover - convenience
        return (self.p_idle, self.p_max, self.r), None


jax.tree_util.register_pytree_node(
    PowerParams,
    lambda p: ((p.p_idle, p.p_max, p.r), None),
    lambda _, c: PowerParams(*c),
)


def opendc_power(u: Array, params: PowerParams) -> Array:
    """OpenDC analytical model: P(u) = P_idle + (P_max - P_idle)(2u - u^r).

    ``u`` may have any shape; params broadcast against the trailing host dim.
    Utilization is clipped to [0, 1] — the physical twin can report transient
    >100 % samples (SMT burst); the model domain is the unit interval.
    """
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    r = jnp.asarray(params.r, u.dtype)
    # u**r with u==0 and fractional r is fine (0**r = 0 for r>0); r <= 0 is
    # rejected at the PowerParams/Scenario boundary (validate_power_params).
    shape = 2.0 * u - jnp.power(u, r)
    return p_idle + (p_max - p_idle) * shape


def linear_power(u: Array, params: PowerParams) -> Array:
    """FootPrinter-style linear model [30]: the r = 1 special case."""
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    return p_idle + (p_max - p_idle) * u


def sqrt_power(u: Array, params: PowerParams) -> Array:
    """Square-root model (OpenDC model zoo; used by the meta-model ensemble)."""
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    return p_idle + (p_max - p_idle) * jnp.sqrt(u)


def cubic_power(u: Array, params: PowerParams) -> Array:
    """Cubic model (OpenDC model zoo; used by the meta-model ensemble)."""
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    return p_idle + (p_max - p_idle) * u**3


PowerModelFn = Callable[[Array, PowerParams], Array]

POWER_MODELS: dict[str, PowerModelFn] = {
    "opendc": opendc_power,
    "linear": linear_power,
    "sqrt": sqrt_power,
    "cubic": cubic_power,
}


def datacenter_power(u_th: Array, params: PowerParams,
                     model: str = "opendc",
                     online_mask: Array | None = None) -> Array:
    """Aggregate datacenter power trace.

    Args:
      u_th: ``[T, H]`` per-host utilization.
      params: power model parameters (scalar or per-host).
      model: key into :data:`POWER_MODELS`.
      online_mask: optional ``[T, H]`` or ``[H]`` 0/1 mask of powered hosts
        (offline hosts draw nothing — availability events).

    Returns:
      ``[T]`` total power draw in watts.
    """
    p = POWER_MODELS[model](u_th, params)
    if online_mask is not None:
        p = p * online_mask
    return jnp.sum(p, axis=-1)


def energy_kwh(power_w: Array, dt_seconds: float) -> Array:
    """Integrate a power trace [T] (W) into per-sample energy (kWh)."""
    return power_w * (dt_seconds / 3600.0) / 1000.0


def carbon_gco2(energy_kwh_t: Array, intensity: Array) -> Array:
    """Per-bin operational carbon [T] gCO2 from energy and grid intensity.

    ``energy_kwh_t`` is the per-bin energy trace (kWh, see
    :func:`energy_kwh`); ``intensity`` is the grid carbon-intensity trace
    (gCO2/kWh, see :mod:`repro.traces.carbon`) broadcast against it.  The
    sustainability headline of a run is ``jnp.sum(carbon_gco2(...))``.
    """
    return energy_kwh_t * jnp.asarray(intensity, energy_kwh_t.dtype)


def mape(real: Array, sim: Array, eps: float = 1e-9) -> Array:
    """Mean Absolute Percentage Error, % (paper §3.2).

    The denominator is ``|real| + eps`` (never ``real + eps``: a negative
    residual trace must not flip the error's sign or cancel against eps),
    and **zero-real bins are excluded from the mean** — a bin where the
    measured value is exactly 0 (every host offline) has no meaningful
    percentage error, and dividing by eps there exploded the window MAPE to
    ~5e10 % per zero bin.  If *all* bins are zero-real the MAPE is undefined
    and NaN is returned (surfaced, not hidden — NaN fails any SLO check).
    """
    real = jnp.asarray(real)
    sim = jnp.asarray(sim)
    nonzero = jnp.abs(real) > eps
    n = jnp.sum(nonzero)
    ape = jnp.abs((real - sim) / (jnp.abs(real) + eps))
    total = jnp.sum(jnp.where(nonzero, ape, 0.0))
    return jnp.where(n > 0, total / jnp.maximum(n, 1), jnp.nan) * 100.0

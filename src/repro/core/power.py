"""Power models for datacenter hosts.

The paper (§3.2) adopts the OpenDC analytical CPU power formula

    P(u) = P_idle + (P_max - P_idle) * (2u - u^r)

where ``u`` is CPU utilization in [0, 1], ``P_idle``/``P_max`` are the host's
idle and maximum power draw, and ``r`` is the *calibration parameter* tuned by
the Self-Calibrator (§2.4).  The FootPrinter baseline [30] uses the linear
special case obtained at r = 1 (P = P_idle + (P_max - P_idle) * u).

All models are pure functions over dense utilization tensors so they can be
vmapped over calibration candidates and pallas-tiled over (time, host) blocks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PowerParams:
    """Parameters of the OpenDC analytical power model.

    Each field is a scalar (shared across hosts) or a ``[H]`` vector
    (per-host).  The calibrator treats ``r`` (and, beyond the paper,
    ``p_idle``/``p_max``) as free parameters.
    """

    p_idle: Array | float = 70.0   # W, idle draw per host
    p_max: Array | float = 350.0   # W, full-load draw per host
    r: Array | float = 2.0         # calibration exponent (paper §3.2)

    def tree_flatten(self):  # pragma: no cover - convenience
        return (self.p_idle, self.p_max, self.r), None


jax.tree_util.register_pytree_node(
    PowerParams,
    lambda p: ((p.p_idle, p.p_max, p.r), None),
    lambda _, c: PowerParams(*c),
)


def opendc_power(u: Array, params: PowerParams) -> Array:
    """OpenDC analytical model: P(u) = P_idle + (P_max - P_idle)(2u - u^r).

    ``u`` may have any shape; params broadcast against the trailing host dim.
    Utilization is clipped to [0, 1] — the physical twin can report transient
    >100 % samples (SMT burst); the model domain is the unit interval.
    """
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    r = jnp.asarray(params.r, u.dtype)
    # u**r with u==0 and fractional r is fine (0**r = 0 for r>0); guard r<=0.
    shape = 2.0 * u - jnp.power(u, r)
    return p_idle + (p_max - p_idle) * shape


def linear_power(u: Array, params: PowerParams) -> Array:
    """FootPrinter-style linear model [30]: the r = 1 special case."""
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    return p_idle + (p_max - p_idle) * u


def sqrt_power(u: Array, params: PowerParams) -> Array:
    """Square-root model (OpenDC model zoo; used by the meta-model ensemble)."""
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    return p_idle + (p_max - p_idle) * jnp.sqrt(u)


def cubic_power(u: Array, params: PowerParams) -> Array:
    """Cubic model (OpenDC model zoo; used by the meta-model ensemble)."""
    u = jnp.clip(u, 0.0, 1.0)
    p_idle = jnp.asarray(params.p_idle, u.dtype)
    p_max = jnp.asarray(params.p_max, u.dtype)
    return p_idle + (p_max - p_idle) * u**3


PowerModelFn = Callable[[Array, PowerParams], Array]

POWER_MODELS: dict[str, PowerModelFn] = {
    "opendc": opendc_power,
    "linear": linear_power,
    "sqrt": sqrt_power,
    "cubic": cubic_power,
}


def datacenter_power(u_th: Array, params: PowerParams,
                     model: str = "opendc",
                     online_mask: Array | None = None) -> Array:
    """Aggregate datacenter power trace.

    Args:
      u_th: ``[T, H]`` per-host utilization.
      params: power model parameters (scalar or per-host).
      model: key into :data:`POWER_MODELS`.
      online_mask: optional ``[T, H]`` or ``[H]`` 0/1 mask of powered hosts
        (offline hosts draw nothing — availability events).

    Returns:
      ``[T]`` total power draw in watts.
    """
    p = POWER_MODELS[model](u_th, params)
    if online_mask is not None:
        p = p * online_mask
    return jnp.sum(p, axis=-1)


def energy_kwh(power_w: Array, dt_seconds: float) -> Array:
    """Integrate a power trace [T] (W) into per-sample energy (kWh)."""
    return power_w * (dt_seconds / 3600.0) / 1000.0


def mape(real: Array, sim: Array, eps: float = 1e-9) -> Array:
    """Mean Absolute Percentage Error, % (paper §3.2)."""
    real = jnp.asarray(real)
    sim = jnp.asarray(sim)
    return jnp.mean(jnp.abs((real - sim) / (real + eps))) * 100.0

"""Self-Calibrator (paper §2.4, component G).

The calibrator measures the difference between simulation-predicted power and
actual telemetry over recent history, grid-searches the power-model parameter
space, and ships the argmin-MAPE configuration to the Simulation Engine for
the *next* window (pipelined: C0 calibrates S1, Fig. 3).

Structural optimization over the paper's implementation (recorded in
DESIGN.md §3): utilization is independent of the power-model parameters, so
instead of re-running short simulations per candidate we re-evaluate the
power map over a **cached utilization window** for all candidates at once —
a ``[C, T, H]`` embarrassingly parallel grid evaluated either by the fused
Pallas kernel (TPU target) or its jnp oracle (CPU / dry-run).

Faithful mode (the paper): 1-D grid over the exponent ``r``.
Beyond-paper mode: 3-D grid over ``(r, p_idle, p_max)`` plus iterative
coordinate refinement ("zoom"), see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.power import PowerParams, mape, opendc_power
from repro.kernels import ops as kops

Array = jax.Array

Backend = Literal["xla", "pallas", "pallas_interpret"]


@dataclasses.dataclass(frozen=True)
class CalibrationSpec:
    """Grid-search configuration.

    The paper's Self-Calibrator sweeps the calibration exponent ``r`` only.
    ``mode='joint'`` additionally sweeps idle/max power — the beyond-paper
    extension evaluated in EXPERIMENTS.md.
    """

    mode: Literal["r_only", "joint"] = "r_only"
    r_lo: float = 1.0
    r_hi: float = 6.0
    r_points: int = 64
    # joint mode: multiplicative sweeps around the configured idle/max power
    scale_lo: float = 0.85
    scale_hi: float = 1.15
    scale_points: int = 12
    refine_iters: int = 0          # 0 = pure grid (faithful); >0 = zoom refine
    refine_shrink: float = 0.25
    # per-host mode (beyond-paper): after the fleet-level fit, re-fit every
    # host against its predicted-share slice of the measured total power and
    # carry ``[H]`` parameter rows instead of one fleet scalar.  Hosts with
    # no finite history keep the fleet-level result.
    per_host: bool = False


def candidate_grid(spec: CalibrationSpec, base: PowerParams) -> PowerParams:
    """Build the candidate parameter grid as a batched PowerParams [C].

    Joint mode clamps each candidate's ``p_max`` to its ``p_idle``: when the
    base span is narrow (``p_max/p_idle < scale_hi/scale_lo``) the scale
    meshgrid would otherwise produce inverted-curve candidates that the
    ``PowerParams`` boundary rightly rejects.  Clamped candidates are
    degenerate (zero span) and simply score badly — the grid shape stays
    static.
    """
    r = np.linspace(spec.r_lo, spec.r_hi, spec.r_points, dtype=np.float32)
    if spec.mode == "r_only":
        c = r.shape[0]
        return PowerParams(
            p_idle=jnp.full((c,), float(np.asarray(base.p_idle).mean()), jnp.float32),
            p_max=jnp.full((c,), float(np.asarray(base.p_max).mean()), jnp.float32),
            r=jnp.asarray(r),
        )
    s = np.linspace(spec.scale_lo, spec.scale_hi, spec.scale_points, dtype=np.float32)
    rr, si, sm = np.meshgrid(r, s, s, indexing="ij")
    p_idle = si.ravel() * float(np.asarray(base.p_idle).mean())
    p_max = sm.ravel() * float(np.asarray(base.p_max).mean())
    return PowerParams(
        p_idle=jnp.asarray(p_idle),
        p_max=jnp.asarray(np.maximum(p_max, p_idle)),
        r=jnp.asarray(rr.ravel()),
    )


def evaluate_candidates(
    u_th: Array,
    real_power: Array,
    cand: PowerParams,
    backend: Backend = "xla",
) -> Array:
    """MAPE [%] of every candidate over the window.  ``[C]``.

    Dispatches to the fused Pallas grid kernel (TPU) or the jnp oracle.
    """
    return kops.calib_mape_grid(
        u_th, real_power, cand.p_idle, cand.p_max, cand.r, backend=backend
    )


def _grid_traced(spec: CalibrationSpec, base: PowerParams,
                 r_lo, r_hi, s_lo, s_hi) -> PowerParams:
    """Candidate grid with *traced* bounds (the refine path of the pure core).

    Mirrors :func:`candidate_grid` but builds the grid with jnp so the zoom
    bounds may depend on traced values (the incumbent best parameters inside
    ``jit``).  ``jnp.linspace`` and ``np.linspace`` can differ in the last
    ulp, so refined sweeps are numerically — not bitwise — equivalent to the
    host-side path; the default spec (``refine_iters=0``) never takes this
    path.
    """
    r = jnp.linspace(r_lo, r_hi, spec.r_points).astype(jnp.float32)
    pi_base = jnp.mean(jnp.asarray(base.p_idle, jnp.float32))
    pm_base = jnp.mean(jnp.asarray(base.p_max, jnp.float32))
    if spec.mode == "r_only":
        c = spec.r_points
        return PowerParams(p_idle=jnp.full((c,), pi_base),
                           p_max=jnp.full((c,), pm_base), r=r)
    s = jnp.linspace(s_lo, s_hi, spec.scale_points).astype(jnp.float32)
    rr, si, sm = jnp.meshgrid(r, s, s, indexing="ij")
    p_idle = si.ravel() * pi_base
    p_max = sm.ravel() * pm_base
    return PowerParams(p_idle=p_idle, p_max=jnp.maximum(p_max, p_idle),
                       r=rr.ravel())


def calibrate_traced(
    u_th: Array,
    real_power: Array,
    cand: PowerParams,
    spec: CalibrationSpec,
    base: PowerParams,
    backend: Backend = "xla",
) -> tuple[PowerParams, Array]:
    """Pure, jittable calibration cycle (the core of :func:`calibrate_window`).

    ``cand`` is the precomputed base grid (``candidate_grid(spec, base)`` —
    host-side, so the grid values are bitwise those of the imperative path).
    Returns ``(params, best_mape)`` as traced scalars: the argmin-MAPE
    candidate, refined ``spec.refine_iters`` times, or ``base`` with a NaN
    MAPE when no candidate has a defined MAPE (all-zero-power history —
    same keep-the-incumbent rule as :func:`calibrate_window`).
    """
    mapes = evaluate_candidates(u_th, real_power, cand, backend=backend)
    b = jnp.argmin(jnp.where(jnp.isnan(mapes), jnp.inf, mapes))
    best = PowerParams(p_idle=cand.p_idle[b], p_max=cand.p_max[b], r=cand.r[b])
    best_mape = mapes[b]
    any_finite = jnp.any(jnp.isfinite(mapes))

    r_lo, r_hi = spec.r_lo, spec.r_hi
    s_lo, s_hi = spec.scale_lo, spec.scale_hi
    for _ in range(spec.refine_iters):
        span_r = (r_hi - r_lo) * spec.refine_shrink
        span_s = (s_hi - s_lo) * spec.refine_shrink
        r_lo = jnp.maximum(1.0, best.r - span_r / 2)
        r_hi = best.r + span_r / 2
        s_lo, s_hi = 1.0 - span_s / 2, 1.0 + span_s / 2
        cand2 = _grid_traced(spec, best, r_lo, r_hi, s_lo, s_hi)
        m2 = evaluate_candidates(u_th, real_power, cand2, backend=backend)
        b2 = jnp.argmin(jnp.where(jnp.isnan(m2), jnp.inf, m2))
        # NaN-safe in both directions: a NaN refined candidate never wins,
        # and a NaN incumbent (all-NaN base grid) loses to any finite one —
        # the host-side semantics of calibrate_window's refine loop.
        better = jnp.logical_and(
            jnp.isfinite(m2[b2]),
            jnp.logical_or(jnp.isnan(best_mape), m2[b2] < best_mape))
        best = PowerParams(
            p_idle=jnp.where(better, cand2.p_idle[b2], best.p_idle),
            p_max=jnp.where(better, cand2.p_max[b2], best.p_max),
            r=jnp.where(better, cand2.r[b2], best.r))
        best_mape = jnp.where(better, m2[b2], best_mape)
        # refined rounds count toward "did any candidate score at all"
        any_finite = jnp.logical_or(any_finite, jnp.any(jnp.isfinite(m2)))

    params = jax.tree.map(
        lambda chosen, fallback: jnp.where(
            any_finite, chosen, jnp.mean(jnp.asarray(fallback, jnp.float32))),
        best, base)
    if spec.per_host:
        return _per_host_refit(u_th, real_power, cand, params, best_mape,
                               backend=backend)
    return params, best_mape


def _per_host_refit(
    u_th: Array,
    real_power: Array,
    cand: PowerParams,
    fleet_params: PowerParams,
    fleet_mape: Array,
    backend: Backend = "xla",
) -> tuple[PowerParams, Array]:
    """Per-host re-fit stage of ``CalibrationSpec(per_host=True)``.

    Telemetry carries only the fleet *total* power, so the measured signal
    is first attributed to hosts by each host's predicted share under the
    fleet-level fit (``fleet_params``), then every host grid-searches its
    own ``argmin``-MAPE row over the shared candidate grid — a vmap over
    the host axis of the same kernel the fleet path uses, so the per-host
    semantics are exactly the ``H=1`` fleet semantics.  Hosts whose share
    target has no finite MAPE (no finite history) keep the fleet-level
    result, and the returned MAPE is the *total-power* MAPE of the combined
    per-host prediction — comparable with the fleet-level number.
    """
    pred = opendc_power(u_th, fleet_params)                    # [T, H]
    total = jnp.sum(pred, axis=-1, keepdims=True)
    share = pred / jnp.maximum(total, 1e-9)
    target = real_power[..., None] * share                     # [T, H]

    def one_host(u_col: Array, target_col: Array):
        m = evaluate_candidates(u_col[:, None], target_col, cand,
                                backend=backend)
        b = jnp.argmin(jnp.where(jnp.isnan(m), jnp.inf, m))
        p = PowerParams(p_idle=cand.p_idle[b], p_max=cand.p_max[b], r=cand.r[b])
        return p, jnp.any(jnp.isfinite(m))

    host_params, host_finite = jax.vmap(one_host, in_axes=(1, 1))(u_th, target)
    rows = jax.tree.map(
        lambda hp, fp: jnp.where(host_finite,
                                 jnp.asarray(hp, jnp.float32),
                                 jnp.asarray(fp, jnp.float32)),
        host_params, fleet_params)
    combined = jnp.sum(opendc_power(u_th, rows), axis=-1)      # [T]
    per_host_mape = mape(real_power, combined)
    # an all-zero window keeps the fleet path's NaN verdict either way
    best_mape = jnp.where(jnp.isnan(per_host_mape), fleet_mape, per_host_mape)
    return rows, best_mape


@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    params: PowerParams          # scalar best parameters
    mape: float                  # best candidate's window MAPE [%]
    evaluated: int               # number of candidates evaluated
    mapes: np.ndarray            # [C] all candidate MAPEs (diagnostics)


def calibrate_window(
    u_th: Array,
    real_power: Array,
    spec: CalibrationSpec,
    base: PowerParams,
    backend: Backend = "xla",
) -> CalibrationResult:
    """One calibration cycle (one C-event in Fig. 3).

    An all-zero-power window (every host offline) has no defined MAPE: the
    kernel returns NaN for every candidate and this function keeps the
    incumbent ``base`` parameters rather than crowning an arbitrary grid
    point a "perfect" fit.
    """
    cand = candidate_grid(spec, base)
    mapes = evaluate_candidates(u_th, real_power, cand, backend=backend)
    mapes_np = np.asarray(mapes)
    total = int(mapes_np.shape[0])
    if not np.isfinite(mapes_np).any():
        return CalibrationResult(base, float("nan"), total, mapes_np)
    best = int(np.argmin(mapes_np))
    best_params = PowerParams(
        p_idle=float(np.asarray(cand.p_idle)[best]),
        p_max=float(np.asarray(cand.p_max)[best]),
        r=float(np.asarray(cand.r)[best]),
    )
    best_mape = float(mapes_np[best])

    # Beyond-paper: iterative zoom refinement around the incumbent.
    cur = spec
    for _ in range(spec.refine_iters):
        span_r = (cur.r_hi - cur.r_lo) * spec.refine_shrink
        span_s = (cur.scale_hi - cur.scale_lo) * spec.refine_shrink
        cur = dataclasses.replace(
            cur,
            r_lo=max(1.0, best_params.r - span_r / 2),
            r_hi=best_params.r + span_r / 2,
            scale_lo=1.0 - span_s / 2,
            scale_hi=1.0 + span_s / 2,
        )
        cand = candidate_grid(cur, best_params)
        m = np.asarray(evaluate_candidates(u_th, real_power, cand, backend=backend))
        total += int(m.shape[0])
        b = int(np.argmin(m))
        if float(m[b]) < best_mape:
            best_mape = float(m[b])
            best_params = PowerParams(
                p_idle=float(np.asarray(cand.p_idle)[b]),
                p_max=float(np.asarray(cand.p_max)[b]),
                r=float(np.asarray(cand.r)[b]),
            )
    return CalibrationResult(best_params, best_mape, total, mapes_np)


class SelfCalibrator:
    """Pipelined calibrator: results from window k feed simulation of k+1.

    Mimics the paper's two-thread timeline (Fig. 3) deterministically: the
    orchestrator calls :meth:`observe` when window-k telemetry lands and
    :meth:`params_for_next` when the engine starts window k+1.
    """

    def __init__(self, spec: CalibrationSpec, base: PowerParams,
                 backend: Backend = "xla", history_windows: int = 4):
        self.spec = spec
        self.base = base
        self.backend = backend
        self.history_windows = history_windows
        self._pending = base       # result of the latest completed cycle
        self._u: list[np.ndarray] = []
        self._p: list[np.ndarray] = []
        self.history: list[CalibrationResult] = []

    def observe(self, u_th: Array, real_power: Array) -> CalibrationResult:
        """Ingest window telemetry, run one calibration cycle."""
        self._u.append(np.asarray(u_th))
        self._p.append(np.asarray(real_power))
        self._u = self._u[-self.history_windows:]
        self._p = self._p[-self.history_windows:]
        u = jnp.asarray(np.concatenate(self._u, axis=0))
        p = jnp.asarray(np.concatenate(self._p, axis=0))
        res = calibrate_window(u, p, self.spec, self.base, backend=self.backend)
        self.history.append(res)
        self._pending = res.params
        return res

    def params_for_next(self) -> PowerParams:
        """Parameters the Simulation Engine should use for the next window."""
        return self._pending

"""DigitalTwin facade — the whole OpenDT loop in one object.

Wires the physical-twin telemetry source, the Orchestrator (windows,
pipelined simulate/calibrate), the SLO monitor and the HITL gate into the
closed cycle of Figure 1:  telemetry -> twin -> (simulate + calibrate) ->
SLO-aware feedback -> human-in-the-loop.

Two physical-twin flavors ship with the repo:
  * ``TraceGroundTruth`` — replays a workload trace with synthesized hidden-
    model telemetry (experiments E1/E2);
  * the live-training producer in examples/live_twin_training.py, which
    pushes measured telemetry from an actual JAX training run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import jax
import numpy as np

from repro.core.desim import simulate_utilization
from repro.core.feedback import HITLGate, Proposal
from repro.core.orchestrator import Orchestrator, OrchestratorConfig, WindowRecord
from repro.core.power import PowerParams
from repro.core.slo import SLOReport
from repro.core.state import TwinState, WindowOutput, twin_step
from repro.core.telemetry import TelemetryWindow, clip_to_window

# NOTE: repro.traces.* is imported lazily inside functions — traces depends on
# repro.core.power, and importing it at module scope would close a cycle
# through the repro.core package __init__.


class TraceGroundTruth:
    """Physical-twin stand-in: hidden-model telemetry over a trace replay."""

    def __init__(self, workload, dc, t_bins: int, gt=None):
        from repro.traces.surf import GroundTruthSpec, synthesize_ground_truth
        gt = gt or GroundTruthSpec()
        sim = simulate_utilization(
            workload, num_hosts=dc.num_hosts,
            cores_per_host=dc.cores_per_host, t_bins=t_bins,
        )
        self.u_th = np.asarray(sim.u_th)
        self.power = synthesize_ground_truth(self.u_th, gt)

    def window(self, idx: int, bins_per_window: int) -> TelemetryWindow:
        return clip_to_window(
            idx, bins_per_window, 0, self.u_th, self.power
        )


@dataclasses.dataclass
class TwinRunResult:
    records: list[WindowRecord]
    overall_mape: float
    per_window_mape: np.ndarray
    slo_reports: list[SLOReport]
    under_estimation_fraction: float
    approved_proposals: list[Proposal]


class DigitalTwin:
    """OpenDT's outer loop."""

    def __init__(
        self,
        workload,
        dc,
        t_bins: int,
        cfg: OrchestratorConfig = OrchestratorConfig(),
        base_params: PowerParams = PowerParams(),
        hitl_policy: Callable[[Proposal], bool | None] | None = None,
    ):
        self.gate = HITLGate(policy=hitl_policy)
        self.orchestrator = Orchestrator(
            workload, dc, t_bins, cfg, base_params, gate=self.gate,
        )

    def run(
        self,
        telemetry_source: Callable[[int, int], TelemetryWindow],
        num_windows: int | None = None,
    ) -> TwinRunResult:
        """Run the closed loop: per window, ingest telemetry then twin it."""
        orch = self.orchestrator
        n = num_windows if num_windows is not None else orch.num_windows
        approved: list[Proposal] = []
        for w in range(n):
            tw = telemetry_source(w, orch.cfg.bins_per_window)
            orch.store.ingest(tw)
            orch.run_window(w)
            approved.extend(self.gate.drain())
        return TwinRunResult(
            records=orch.records,
            overall_mape=orch.overall_mape(),
            per_window_mape=orch.per_window_mape(),
            slo_reports=orch.monitor.report(),
            under_estimation_fraction=orch.bias.under_fraction,
            approved_proposals=approved,
        )


# -- fleet twinning: vmap(twin_step) over independent datacenters -------------

def stack_twin_states(states: "list[TwinState] | tuple[TwinState, ...]") -> TwinState:
    """Stack D independent twins into one batched ``TwinState`` ``[D, ...]``.

    Every state must share the same :class:`~repro.core.state.TwinConfig`
    (the config is pytree aux data, so mismatched configs fail loudly at
    stack time) and the same array shapes — i.e. the fleet twins datacenters
    of one padded size per compiled program, like the scenario engine's
    ``max_hosts`` axis.
    """
    if not states:
        raise ValueError("need at least one TwinState to stack")
    cfg = states[0].cfg
    for s in states[1:]:
        if s.cfg != cfg:
            raise ValueError(
                "fleet states must share one TwinConfig (got differing "
                f"configs:\n  {cfg}\n  {s.cfg})")
    return jax.tree.map(lambda *xs: jax.numpy.stack(xs, axis=0), *states)


def index_twin_state(fleet: TwinState, i: int) -> TwinState:
    """Extract one twin's state from a batched fleet state."""
    return jax.tree.map(lambda x: x[i], fleet)


def update_twin_state_lane(fleet: TwinState, i: int,
                           state: TwinState) -> TwinState:
    """Write one twin's state into lane ``i`` of a batched fleet state.

    The admission half of lane multiplexing (:mod:`repro.serve.batching`):
    a tenant joins a resident fleet by landing its ``TwinState`` on a free
    lane; :func:`index_twin_state` is the eviction half.  Host-side eager
    ops — admission/eviction are rare control-plane events, not per-step
    work — and config-checked like :func:`stack_twin_states`.
    """
    if state.cfg != fleet.cfg:
        raise ValueError(
            "lane state must share the fleet's TwinConfig (got differing "
            f"configs:\n  {fleet.cfg}\n  {state.cfg})")
    return jax.tree.map(lambda f, s: f.at[i].set(s), fleet, state)


#: one fused program that twins D datacenters for one window: every leaf of
#: the three inputs leads with the fleet axis [D, ...].
fleet_step = jax.jit(jax.vmap(twin_step))


def _fleet_step_masked(fleet: TwinState, telemetry, sim_slices, lane_active):
    """One fleet window with per-lane masking (partially-filled steps).

    ``lane_active`` is a ``[D]`` bool vector: active lanes advance exactly
    as :func:`fleet_step` would (each lane bitwise-identical to a solo
    ``twin_step`` — the pinned fleet invariant), inactive lanes carry their
    state through **unchanged** — window index, history, accumulators, all
    of it.  That is what lets a dynamic batcher pack any subset of resident
    tenants into a fixed-shape ``[D]`` call: empty lanes ride along on
    padding telemetry without their twins ever noticing, the same
    pad-and-mask trick the scenario engine plays on the S axis.

    Outputs are returned for every lane (inactive lanes produce padding
    predictions the caller must ignore — the batcher only reads active
    lanes).
    """
    stepped, outs = jax.vmap(twin_step)(fleet, telemetry, sim_slices)

    def keep(new, old):
        mask = lane_active.reshape(lane_active.shape + (1,) * (new.ndim - 1))
        return jax.numpy.where(mask, new, old)

    return jax.tree.map(keep, stepped, fleet), outs


# the fleet carry is donated like fleet_step's would be: callers rebind
# `fleet, outs = fleet_step_masked(fleet, ...)`, so the incoming lane
# buffers are reused in place batch after batch
_fleet_step_masked_jit = jax.jit(_fleet_step_masked, donate_argnums=(0,))


def fleet_step_masked(fleet: TwinState, telemetry, sim_slices, lane_active
                      ) -> tuple[TwinState, WindowOutput]:
    """Advance a partially-filled fleet one window in ONE compiled program.

    The serving primitive behind :class:`repro.serve.service.TwinService`:
    every dynamic batch — whatever mix of tenants is ready — is one call to
    this one jitted program, so an arbitrary tenant arrival pattern never
    recompiles.  ``fleet`` leaves lead with ``[D, ...]``; ``telemetry`` /
    ``sim_slices`` are one window's
    :class:`~repro.core.state.TelemetrySlice` /
    :class:`~repro.core.state.SimSlice` with ``[D, ...]`` leaves;
    ``lane_active`` is the ``[D]`` bool fill mask.

    The ``fleet`` argument's buffers are **donated** — rebind the returned
    state.
    """
    return _fleet_step_masked_jit(fleet, telemetry, sim_slices, lane_active)


# surfaced for the single-compile serving tests, like run_fleet below
fleet_step_masked._cache_size = getattr(
    _fleet_step_masked_jit, "_cache_size", None)


def _run_fleet(fleet: TwinState, telemetry, sim_slices):
    def body(state, inputs):
        telem, sl = inputs
        return jax.vmap(twin_step)(state, telem, sl)

    return jax.lax.scan(body, fleet, (telemetry, sim_slices))


# the fleet carry is donated like twin_step_jit's: run_fleet returns the
# successor state, so the incoming fleet's buffers are reused in place
_run_fleet_jit = jax.jit(_run_fleet, donate_argnums=(0,))


def run_fleet(fleet: TwinState, telemetry, sim_slices
              ) -> tuple[TwinState, WindowOutput]:
    """Twin a whole fleet over a whole horizon in ONE compiled program.

    ``fleet`` is a batched :class:`~repro.core.state.TwinState` (see
    :func:`stack_twin_states`); ``telemetry`` / ``sim_slices`` are
    :class:`~repro.core.state.TelemetrySlice` /
    :class:`~repro.core.state.SimSlice` pytrees whose array leaves lead with
    ``[W, D, ...]`` (windows, datacenters).  Runs ``lax.scan`` over the
    window axis of ``vmap(twin_step)`` over the fleet axis, so D datacenters
    x W windows — prediction, scoring, SLO/bias accumulation and grid-search
    calibration — compile once and execute as a single fused program.

    Returns the final fleet state and the per-window outputs stacked
    ``[W, D, ...]``.  Each lane is the exact computation :func:`twin_step`
    performs solo (pinned by ``tests/test_twin_core.py``).

    The ``fleet`` argument's buffers are **donated** (rebind the return
    value; re-running from the same starting state requires a fresh
    :func:`stack_twin_states`).
    """
    return _run_fleet_jit(fleet, telemetry, sim_slices)


# surfaced for the single-compilation regression test; `_cache_size` is
# private jax API, so its absence must degrade to None, not an import error
run_fleet._cache_size = getattr(_run_fleet_jit, "_cache_size", None)


def run_surf_experiment(
    workload,
    dc,
    t_bins: int,
    *,
    calibrate: bool,
    cfg: OrchestratorConfig | None = None,
    base_params: PowerParams = PowerParams(),
    gt=None,
    hitl_policy: Callable[[Proposal], bool | None] | None = None,
) -> TwinRunResult:
    """One E1/E2-style run: trace replay + hidden-model telemetry."""
    cfg = cfg or OrchestratorConfig()
    cfg = dataclasses.replace(cfg, calibrate=calibrate)
    truth = TraceGroundTruth(workload, dc, t_bins, gt)
    twin = DigitalTwin(workload, dc, t_bins, cfg, base_params,
                       hitl_policy=hitl_policy)
    return twin.run(truth.window)

"""DigitalTwin facade — the whole OpenDT loop in one object.

Wires the physical-twin telemetry source, the Orchestrator (windows,
pipelined simulate/calibrate), the SLO monitor and the HITL gate into the
closed cycle of Figure 1:  telemetry -> twin -> (simulate + calibrate) ->
SLO-aware feedback -> human-in-the-loop.

Two physical-twin flavors ship with the repo:
  * ``TraceGroundTruth`` — replays a workload trace with synthesized hidden-
    model telemetry (experiments E1/E2);
  * the live-training producer in examples/live_twin_training.py, which
    pushes measured telemetry from an actual JAX training run.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.desim import simulate_utilization
from repro.core.feedback import HITLGate, Proposal
from repro.core.orchestrator import Orchestrator, OrchestratorConfig, WindowRecord
from repro.core.power import PowerParams
from repro.core.slo import SLOReport
from repro.core.state import TwinState, WindowOutput, twin_step
from repro.core.telemetry import TelemetryWindow, clip_to_window

# NOTE: repro.traces.* is imported lazily inside functions — traces depends on
# repro.core.power, and importing it at module scope would close a cycle
# through the repro.core package __init__.


class TraceGroundTruth:
    """Physical-twin stand-in: hidden-model telemetry over a trace replay."""

    def __init__(self, workload, dc, t_bins: int, gt=None):
        from repro.traces.surf import GroundTruthSpec, synthesize_ground_truth
        gt = gt or GroundTruthSpec()
        sim = simulate_utilization(
            workload, num_hosts=dc.num_hosts,
            cores_per_host=dc.cores_per_host, t_bins=t_bins,
        )
        self.u_th = np.asarray(sim.u_th)
        self.power = synthesize_ground_truth(self.u_th, gt)

    def window(self, idx: int, bins_per_window: int) -> TelemetryWindow:
        return clip_to_window(
            idx, bins_per_window, 0, self.u_th, self.power
        )


@dataclasses.dataclass
class TwinRunResult:
    records: list[WindowRecord]
    overall_mape: float
    per_window_mape: np.ndarray
    slo_reports: list[SLOReport]
    under_estimation_fraction: float
    approved_proposals: list[Proposal]


class DigitalTwin:
    """OpenDT's outer loop."""

    def __init__(
        self,
        workload,
        dc,
        t_bins: int,
        cfg: OrchestratorConfig = OrchestratorConfig(),
        base_params: PowerParams = PowerParams(),
        hitl_policy: Callable[[Proposal], bool | None] | None = None,
    ):
        self.gate = HITLGate(policy=hitl_policy)
        self.orchestrator = Orchestrator(
            workload, dc, t_bins, cfg, base_params, gate=self.gate,
        )

    def run(
        self,
        telemetry_source: Callable[[int, int], TelemetryWindow],
        num_windows: int | None = None,
    ) -> TwinRunResult:
        """Run the closed loop: per window, ingest telemetry then twin it."""
        orch = self.orchestrator
        n = num_windows if num_windows is not None else orch.num_windows
        approved: list[Proposal] = []
        for w in range(n):
            tw = telemetry_source(w, orch.cfg.bins_per_window)
            orch.store.ingest(tw)
            orch.run_window(w)
            approved.extend(self.gate.drain())
        return TwinRunResult(
            records=orch.records,
            overall_mape=orch.overall_mape(),
            per_window_mape=orch.per_window_mape(),
            slo_reports=orch.monitor.report(),
            under_estimation_fraction=orch.bias.under_fraction,
            approved_proposals=approved,
        )


# -- fleet twinning: vmap(twin_step) over independent datacenters -------------

def _flatten_with_names(state: TwinState):
    """``[(field-qualified leaf name, leaf), ...]`` + treedef, for errors.

    ``TwinState`` (and ``PowerParams``) register plain pytree nodes without
    key paths, so names are built from the dataclass fields — the level an
    error message needs (``params.p_idle``, ``hist_u``, ``sim_u``).
    """
    out = []
    for f in dataclasses.fields(state):
        if f.name == "cfg":
            continue
        sub = getattr(state, f.name)
        if isinstance(sub, PowerParams):
            out.extend((f"{f.name}.{g.name}", getattr(sub, g.name))
                       for g in dataclasses.fields(sub))
        else:
            out.extend((f.name, x) for x in jax.tree_util.tree_leaves(sub))
    return out, jax.tree_util.tree_structure(state)


def stack_twin_states(states: "list[TwinState] | tuple[TwinState, ...]") -> TwinState:
    """Stack D independent twins into one batched ``TwinState`` ``[D, ...]``.

    Every state must share the same :class:`~repro.core.state.TwinConfig`
    *and* the same leaf shapes (both checked up front, so mismatched fleets
    fail loudly at stack time, naming the offending leaf and lane) — i.e.
    the fleet twins datacenters of one padded size per compiled program,
    like the scenario engine's ``max_hosts`` axis.
    """
    if not states:
        raise ValueError("need at least one TwinState to stack")
    cfg = states[0].cfg
    ref, ref_def = _flatten_with_names(states[0])
    for lane, s in enumerate(states[1:], start=1):
        if s.cfg != cfg:
            raise ValueError(
                "fleet states must share one TwinConfig (got differing "
                f"configs:\n  {cfg}\n  {s.cfg})")
        cur, cur_def = _flatten_with_names(s)
        if cur_def != ref_def:
            raise ValueError(
                f"fleet states must share one pytree structure; lane {lane} "
                "differs from lane 0 (a field present on one side only, "
                "e.g. sim_u)")
        for (name, a), (_, b) in zip(ref, cur):
            if jnp.shape(a) != jnp.shape(b):
                raise ValueError(
                    f"fleet states must share leaf shapes; leaf {name} has "
                    f"shape {jnp.shape(b)} in lane {lane} vs "
                    f"{jnp.shape(a)} in lane 0")
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *states)


def index_twin_state(fleet: TwinState, i: int) -> TwinState:
    """Extract one twin's state from a batched fleet state."""
    return jax.tree.map(lambda x: x[i], fleet)


def update_twin_state_lane(fleet: TwinState, i: int,
                           state: TwinState) -> TwinState:
    """Write one twin's state into lane ``i`` of a batched fleet state.

    The admission half of lane multiplexing (:mod:`repro.serve.batching`):
    a tenant joins a resident fleet by landing its ``TwinState`` on a free
    lane; :func:`index_twin_state` is the eviction half.  Host-side eager
    ops — admission/eviction are rare control-plane events, not per-step
    work — and config- and shape-checked like :func:`stack_twin_states`
    (a mismatched state names the offending leaf and lane instead of
    surfacing as a cryptic scatter error).
    """
    if state.cfg != fleet.cfg:
        raise ValueError(
            "lane state must share the fleet's TwinConfig (got differing "
            f"configs:\n  {fleet.cfg}\n  {state.cfg})")
    f_leaves, f_def = _flatten_with_names(fleet)
    s_leaves, s_def = _flatten_with_names(state)
    if s_def != f_def:
        raise ValueError(
            f"lane {i} state must share the fleet's pytree structure "
            "(a field present on one side only, e.g. sim_u)")
    for (name, f), (_, s) in zip(f_leaves, s_leaves):
        if jnp.shape(f)[1:] != jnp.shape(s):
            raise ValueError(
                f"lane {i} state leaf {name} has shape {jnp.shape(s)}; the "
                f"fleet carries {jnp.shape(f)} (want {jnp.shape(f)[1:]} "
                "per lane)")
    return jax.tree.map(lambda f, s: f.at[i].set(s), fleet, state)


#: one fused program that twins D datacenters for one window: every leaf of
#: the three inputs leads with the fleet axis [D, ...].
fleet_step = jax.jit(jax.vmap(twin_step))


def _fleet_step_masked(fleet: TwinState, telemetry, sim_slices, lane_active):
    """One fleet window with per-lane masking (partially-filled steps).

    ``lane_active`` is a ``[D]`` bool vector: active lanes advance exactly
    as :func:`fleet_step` would (each lane bitwise-identical to a solo
    ``twin_step`` — the pinned fleet invariant), inactive lanes carry their
    state through **unchanged** — window index, history, accumulators, all
    of it.  That is what lets a dynamic batcher pack any subset of resident
    tenants into a fixed-shape ``[D]`` call: empty lanes ride along on
    padding telemetry without their twins ever noticing, the same
    pad-and-mask trick the scenario engine plays on the S axis.

    Outputs are returned for every lane (inactive lanes produce padding
    predictions the caller must ignore — the batcher only reads active
    lanes).
    """
    stepped, outs = jax.vmap(twin_step)(fleet, telemetry, sim_slices)

    def keep(new, old):
        mask = lane_active.reshape(lane_active.shape + (1,) * (new.ndim - 1))
        return jax.numpy.where(mask, new, old)

    return jax.tree.map(keep, stepped, fleet), outs


# the fleet carry is donated like fleet_step's would be: callers rebind
# `fleet, outs = fleet_step_masked(fleet, ...)`, so the incoming lane
# buffers are reused in place batch after batch
_fleet_step_masked_jit = jax.jit(_fleet_step_masked, donate_argnums=(0,))


def fleet_step_masked(fleet: TwinState, telemetry, sim_slices, lane_active,
                      *, shard: bool = False, mesh=None
                      ) -> tuple[TwinState, WindowOutput]:
    """Advance a partially-filled fleet one window in ONE compiled program.

    The serving primitive behind :class:`repro.serve.service.TwinService`:
    every dynamic batch — whatever mix of tenants is ready — is one call to
    this one jitted program, so an arbitrary tenant arrival pattern never
    recompiles.  ``fleet`` leaves lead with ``[D, ...]``; ``telemetry`` /
    ``sim_slices`` are one window's
    :class:`~repro.core.state.TelemetrySlice` /
    :class:`~repro.core.state.SimSlice` with ``[D, ...]`` leaves;
    ``lane_active`` is the ``[D]`` bool fill mask.

    With ``shard=True`` the D axis is ``shard_map``-ped over ``mesh``
    (default: :func:`fleet_mesh` over all local devices): lanes pad to a
    multiple of the device count with *inactive* lane-0 replicas and the
    outputs slice back, bit-for-bit vs the vmap path (pinned by
    ``tests/test_shard_fleet.py``) — the serving fleet spreads resident
    tenants across devices without the batcher noticing.

    On the default path the ``fleet`` argument's buffers are **donated** —
    rebind the returned state (the sharded program, like the S axis's, does
    not donate: padding copies the carry anyway).
    """
    if not shard:
        return _fleet_step_masked_jit(fleet, telemetry, sim_slices,
                                      lane_active)
    mesh = fleet_mesh() if mesh is None else mesh
    d = jax.tree.leaves(fleet)[0].shape[0]
    pad = _fleet_pad(d, mesh)
    new_fleet, outs = _fleet_step_masked_sharded_jit(
        _commit_to_mesh(_pad_fleet_axis(fleet, pad, axis=0), mesh, axis=0),
        _commit_to_mesh(_pad_fleet_axis(telemetry, pad, axis=0), mesh, axis=0),
        _commit_to_mesh(_pad_fleet_axis(sim_slices, pad, axis=0), mesh, axis=0),
        _commit_to_mesh(
            jnp.concatenate([jnp.asarray(lane_active, bool),
                             jnp.zeros((pad,), bool)]) if pad
            else jnp.asarray(lane_active, bool), mesh, axis=0),
        mesh=mesh)
    if pad:
        new_fleet = jax.tree.map(lambda x: x[:d], new_fleet)
        outs = jax.tree.map(lambda x: x[:d], outs)
    return new_fleet, outs


def _run_fleet(fleet: TwinState, telemetry, sim_slices):
    def body(state, inputs):
        telem, sl = inputs
        return jax.vmap(twin_step)(state, telem, sl)

    return jax.lax.scan(body, fleet, (telemetry, sim_slices))


# the fleet carry is donated like twin_step_jit's: run_fleet returns the
# successor state, so the incoming fleet's buffers are reused in place
_run_fleet_jit = jax.jit(_run_fleet, donate_argnums=(0,))


def run_fleet(fleet: TwinState, telemetry, sim_slices,
              *, shard: bool = False, mesh=None
              ) -> tuple[TwinState, WindowOutput]:
    """Twin a whole fleet over a whole horizon in ONE compiled program.

    ``fleet`` is a batched :class:`~repro.core.state.TwinState` (see
    :func:`stack_twin_states`); ``telemetry`` / ``sim_slices`` are
    :class:`~repro.core.state.TelemetrySlice` /
    :class:`~repro.core.state.SimSlice` pytrees whose array leaves lead with
    ``[W, D, ...]`` (windows, datacenters).  Runs ``lax.scan`` over the
    window axis of ``vmap(twin_step)`` over the fleet axis, so D datacenters
    x W windows — prediction, scoring, SLO/bias accumulation and grid-search
    calibration — compile once and execute as a single fused program.

    With ``shard=True`` the D axis is additionally ``shard_map``-ped over
    the devices of ``mesh`` (default: a 1-D :func:`fleet_mesh` over all
    local devices), the same recipe as ``run_scenarios(shard=True)`` on the
    S axis: D pads to a multiple of the device count with lane-0 replicas,
    each device scans its local lanes, and the outputs slice back to the
    true D — **bit-for-bit identical** to the single-device vmap path
    (pinned by ``tests/test_shard_fleet.py``).

    Returns the final fleet state and the per-window outputs stacked
    ``[W, D, ...]``.  Each lane is the exact computation :func:`twin_step`
    performs solo (pinned by ``tests/test_twin_core.py``).

    On the default path the ``fleet`` argument's buffers are **donated**
    (rebind the return value; re-running from the same starting state
    requires a fresh :func:`stack_twin_states`).
    """
    if not shard:
        return _run_fleet_jit(fleet, telemetry, sim_slices)
    mesh = fleet_mesh() if mesh is None else mesh
    d = jax.tree.leaves(fleet)[0].shape[0]
    pad = _fleet_pad(d, mesh)
    new_fleet, outs = _run_fleet_sharded_jit(
        _commit_to_mesh(_pad_fleet_axis(fleet, pad, axis=0), mesh, axis=0),
        _commit_to_mesh(_pad_fleet_axis(telemetry, pad, axis=1), mesh, axis=1),
        _commit_to_mesh(_pad_fleet_axis(sim_slices, pad, axis=1), mesh, axis=1),
        mesh=mesh)
    if pad:
        new_fleet = jax.tree.map(lambda x: x[:d], new_fleet)
        outs = jax.tree.map(lambda x: x[:, :d], outs)
    return new_fleet, outs


# -- fleet-axis sharding: shard_map over D, bit-for-bit vs the vmap path ------

#: mesh axis name the fleet (lane) axis is sharded over
FLEET_AXIS = "fleet"


def fleet_mesh(num_devices: int | None = None):
    """A 1-D device mesh over ``FLEET_AXIS`` (default: all local devices).

    On CPU-only deployments, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* process
    start to split the host into N devices (the ``tier1-multidevice`` CI job
    runs the fleet equivalence suite exactly that way).
    """
    from repro.parallel.sharding import make_mesh_compat

    devs = jax.devices()  # tracecheck: disable=TC007 — mesh discovery is this helper's purpose
    n = len(devs) if num_devices is None else int(num_devices)
    return make_mesh_compat((n,), (FLEET_AXIS,), devices=np.array(devs[:n]))


def _fleet_pad(d: int, mesh) -> int:
    """Lanes to add so every device holds an equal, safe shard of D."""
    n_dev = mesh.shape[FLEET_AXIS]
    per_dev = -(-d // n_dev)
    if n_dev > 1:
        # keep >= 2 lanes per device: a batch-1 vmapped while_loop inside
        # shard_map trips an XLA sharding-propagation bug on jax 0.4.x —
        # same workaround as the scenario engine's S axis.
        per_dev = max(per_dev, 2)
    return per_dev * n_dev - d


def _pad_fleet_axis(tree, pad: int, axis: int):
    """Pad the fleet axis by replicating lane 0 (sliced off by the caller)."""
    if pad == 0:
        return tree

    def pad_leaf(x):
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(0, 1)
        return jnp.concatenate(
            [x, jnp.repeat(x[tuple(sl)], pad, axis=axis)], axis=axis)

    return jax.tree.map(pad_leaf, tree)


def _commit_to_mesh(tree, mesh, axis: int):
    """Commit every leaf to the mesh, fleet axis sharded over ``FLEET_AXIS``.

    The sharded jits cache on input *sharding*: without this, the first call
    (uncommitted host arrays) and every steady-state call (the previous
    call's ``NamedSharding`` outputs fed back as the carry — the serve
    dispatch loop) would trace two separate programs.  ``device_put`` is a
    no-op for already-matching leaves, so the steady state pays nothing.
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    sharding = NamedSharding(mesh, P(*((None,) * axis), FLEET_AXIS))
    return jax.tree.map(lambda x: jax.device_put(x, sharding), tree)


@functools.partial(jax.jit, static_argnames=("mesh",))
def _run_fleet_sharded_jit(fleet, telemetry, sim_slices, *, mesh):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    return shard_map(
        _run_fleet, mesh=mesh,
        # fleet-state leaves lead with D; telemetry/sim leaves are [W, D, ..]
        in_specs=(P(FLEET_AXIS), P(None, FLEET_AXIS), P(None, FLEET_AXIS)),
        out_specs=(P(FLEET_AXIS), P(None, FLEET_AXIS)),
        check_rep=False,
    )(fleet, telemetry, sim_slices)


@functools.partial(jax.jit, static_argnames=("mesh",))
def _fleet_step_masked_sharded_jit(fleet, telemetry, sim_slices, lane_active,
                                   *, mesh):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    return shard_map(
        _fleet_step_masked, mesh=mesh,
        # one window: every input/output leaf leads with the D axis
        in_specs=(P(FLEET_AXIS),) * 4,
        out_specs=(P(FLEET_AXIS), P(FLEET_AXIS)),
        check_rep=False,
    )(fleet, telemetry, sim_slices, lane_active)


# surfaced for the single-compilation regression tests; `_cache_size` is
# private jax API, so its absence must degrade to None, not an import
# error.  The sharded program is a distinct executable with its own cache,
# so each counter sums both paths — a vmap-only workload and a sharded one
# each still count 1.
_run_fleet_caches = tuple(
    getattr(f, "_cache_size", None)
    for f in (_run_fleet_jit, _run_fleet_sharded_jit))
run_fleet._cache_size = (
    (lambda: sum(c() for c in _run_fleet_caches))
    if all(_run_fleet_caches) else None)

_fleet_step_caches = tuple(
    getattr(f, "_cache_size", None)
    for f in (_fleet_step_masked_jit, _fleet_step_masked_sharded_jit))
fleet_step_masked._cache_size = (
    (lambda: sum(c() for c in _fleet_step_caches))
    if all(_fleet_step_caches) else None)


def run_surf_experiment(
    workload,
    dc,
    t_bins: int,
    *,
    calibrate: bool,
    cfg: OrchestratorConfig | None = None,
    base_params: PowerParams = PowerParams(),
    gt=None,
    hitl_policy: Callable[[Proposal], bool | None] | None = None,
) -> TwinRunResult:
    """One E1/E2-style run: trace replay + hidden-model telemetry."""
    cfg = cfg or OrchestratorConfig()
    cfg = dataclasses.replace(cfg, calibrate=calibrate)
    truth = TraceGroundTruth(workload, dc, t_bins, gt)
    twin = DigitalTwin(workload, dc, t_bins, cfg, base_params,
                       hitl_policy=hitl_policy)
    return twin.run(truth.window)

"""DigitalTwin facade — the whole OpenDT loop in one object.

Wires the physical-twin telemetry source, the Orchestrator (windows,
pipelined simulate/calibrate), the SLO monitor and the HITL gate into the
closed cycle of Figure 1:  telemetry -> twin -> (simulate + calibrate) ->
SLO-aware feedback -> human-in-the-loop.

Two physical-twin flavors ship with the repo:
  * ``TraceGroundTruth`` — replays a workload trace with synthesized hidden-
    model telemetry (experiments E1/E2);
  * the live-training producer in examples/live_twin_training.py, which
    pushes measured telemetry from an actual JAX training run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

from repro.core.desim import simulate_utilization
from repro.core.feedback import HITLGate, Proposal
from repro.core.orchestrator import Orchestrator, OrchestratorConfig, WindowRecord
from repro.core.power import PowerParams
from repro.core.slo import SLOReport
from repro.core.telemetry import TelemetryWindow, clip_to_window

# NOTE: repro.traces.* is imported lazily inside functions — traces depends on
# repro.core.power, and importing it at module scope would close a cycle
# through the repro.core package __init__.


class TraceGroundTruth:
    """Physical-twin stand-in: hidden-model telemetry over a trace replay."""

    def __init__(self, workload, dc, t_bins: int, gt=None):
        from repro.traces.surf import GroundTruthSpec, synthesize_ground_truth
        gt = gt or GroundTruthSpec()
        sim = simulate_utilization(
            workload, num_hosts=dc.num_hosts,
            cores_per_host=dc.cores_per_host, t_bins=t_bins,
        )
        self.u_th = np.asarray(sim.u_th)
        self.power = synthesize_ground_truth(self.u_th, gt)

    def window(self, idx: int, bins_per_window: int) -> TelemetryWindow:
        return clip_to_window(
            idx, bins_per_window, 0, self.u_th, self.power
        )


@dataclasses.dataclass
class TwinRunResult:
    records: list[WindowRecord]
    overall_mape: float
    per_window_mape: np.ndarray
    slo_reports: list[SLOReport]
    under_estimation_fraction: float
    approved_proposals: list[Proposal]


class DigitalTwin:
    """OpenDT's outer loop."""

    def __init__(
        self,
        workload,
        dc,
        t_bins: int,
        cfg: OrchestratorConfig = OrchestratorConfig(),
        base_params: PowerParams = PowerParams(),
        hitl_policy: Callable[[Proposal], bool | None] | None = None,
    ):
        self.gate = HITLGate(policy=hitl_policy)
        self.orchestrator = Orchestrator(
            workload, dc, t_bins, cfg, base_params, gate=self.gate,
        )

    def run(
        self,
        telemetry_source: Callable[[int, int], TelemetryWindow],
        num_windows: int | None = None,
    ) -> TwinRunResult:
        """Run the closed loop: per window, ingest telemetry then twin it."""
        orch = self.orchestrator
        n = num_windows if num_windows is not None else orch.num_windows
        approved: list[Proposal] = []
        for w in range(n):
            tw = telemetry_source(w, orch.cfg.bins_per_window)
            orch.store.ingest(tw)
            orch.run_window(w)
            approved.extend(self.gate.drain())
        return TwinRunResult(
            records=orch.records,
            overall_mape=orch.overall_mape(),
            per_window_mape=orch.per_window_mape(),
            slo_reports=orch.monitor.report(),
            under_estimation_fraction=orch.bias.under_fraction,
            approved_proposals=approved,
        )


def run_surf_experiment(
    workload,
    dc,
    t_bins: int,
    *,
    calibrate: bool,
    cfg: OrchestratorConfig | None = None,
    base_params: PowerParams = PowerParams(),
    gt=None,
    hitl_policy: Callable[[Proposal], bool | None] | None = None,
) -> TwinRunResult:
    """One E1/E2-style run: trace replay + hidden-model telemetry."""
    cfg = cfg or OrchestratorConfig()
    cfg = dataclasses.replace(cfg, calibrate=calibrate)
    truth = TraceGroundTruth(workload, dc, t_bins, gt)
    twin = DigitalTwin(workload, dc, t_bins, cfg, base_params,
                       hitl_policy=hitl_policy)
    return twin.run(truth.window)

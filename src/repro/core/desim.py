"""Vectorized discrete-event datacenter simulation.

OpenDC — the simulator at the paper's core (FR2) — is an event-queue DES.
Event queues are pointer-chasing and data-dependent: hostile to TPUs and to
XLA.  Since the paper only ever *reads out* the simulation at the
industry-standard 5-minute granularity (§3.3), we adapt the simulator to the
hardware instead of porting the algorithm: a **dense, fixed-timestep,
time-marching simulation** whose state is tensors over ``[hosts]`` and
``[jobs]``, advanced by ``jax.lax.scan`` over 5-minute bins.

Event-driven semantics preserved at bin granularity:
  * job completion releases cores at the bin where ``start + duration`` falls;
  * FCFS placement with a bounded ``fori_loop`` of first-fit attempts per bin
    (strict head-of-line blocking, like OpenDC's default scheduler);
  * per-job piecewise utilization profiles (OpenDC "fragments").

Everything is one jitted program — NFR2's "7 days in under an hour" becomes
"7 days in well under a second" on a single CPU core (see benchmarks).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.power import PowerParams, datacenter_power, energy_kwh
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig, Workload

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SimOutput:
    """Dense simulation read-out at 5-minute granularity.

    Attributes:
      u_th: ``[T, H]`` per-host utilization in [0, 1].
      queue_len: ``[T]`` jobs submitted but not yet started.
      running: ``[T]`` jobs running.
      job_start: ``[J]`` assigned start bin (-1 if never started).
      job_host: ``[J]`` assigned host (-1 if never started).
    """

    u_th: Array
    queue_len: Array
    running: Array
    job_start: Array
    job_host: Array


jax.tree_util.register_pytree_node(
    SimOutput,
    lambda s: ((s.u_th, s.queue_len, s.running, s.job_start, s.job_host), None),
    lambda _, c: SimOutput(*c),
)


@functools.partial(jax.jit, static_argnames=("num_hosts", "cores_per_host",
                                             "t_bins", "max_starts_per_bin"))
def simulate_utilization(
    w: Workload,
    *,
    num_hosts: int,
    cores_per_host: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
) -> SimOutput:
    """Run the vectorized DES and return the utilization field.

    Placement (the event-driven part) is a bounded first-fit loop inside the
    scan body; utilization accumulation is a segment-sum scatter over host
    assignments.  Utilization is *independent of power-model parameters* —
    the structural fact the Self-Calibrator exploits (see calibrate.py).
    """
    j = w.num_jobs
    u_phases = w.num_phases

    init = dict(
        free=jnp.full((num_hosts,), cores_per_host, jnp.int32),
        job_host=jnp.full((j,), -1, jnp.int32),
        job_start=jnp.full((j,), -1, jnp.int32),
        next_job=jnp.asarray(0, jnp.int32),
    )

    submit = w.submit_bin
    dur = jnp.maximum(w.duration_bins, 1)
    cores = w.cores
    valid = w.valid

    def place_one(_, carry):
        free, job_host, job_start, next_job, blocked, t = carry
        jid = jnp.minimum(next_job, j - 1)
        eligible = (
            (next_job < j)
            & (submit[jid] <= t)
            & valid[jid]
            & jnp.logical_not(blocked)
        )
        need = cores[jid]
        fits = free >= need
        any_fit = jnp.any(fits)
        # worst-fit among fitting hosts (most free cores) — spreads load like
        # OpenDC's default mem/core-aware filter+weigher pipeline.
        host = jnp.argmax(jnp.where(fits, free, -1))
        do_place = eligible & any_fit
        free = jnp.where(
            do_place, free.at[host].add(-need), free
        )
        job_host = jnp.where(do_place, job_host.at[jid].set(host), job_host)
        job_start = jnp.where(do_place, job_start.at[jid].set(t), job_start)
        next_job = next_job + do_place.astype(jnp.int32)
        # strict FCFS: if the head job could not be placed, stop this bin.
        blocked = blocked | (eligible & jnp.logical_not(any_fit))
        return free, job_host, job_start, next_job, blocked, t

    def step(state, t):
        free, job_host, job_start, next_job = (
            state["free"], state["job_host"], state["job_start"], state["next_job"],
        )
        # 1) completions: release cores for jobs ending at bin t.
        started = job_start >= 0
        ends = started & (job_start + dur == t)
        seg = jnp.where(ends, job_host, num_hosts)  # sentinel bucket
        released = jax.ops.segment_sum(
            jnp.where(ends, cores, 0), seg, num_segments=num_hosts + 1
        )[:num_hosts]
        free = free + released.astype(jnp.int32)

        # 2) FCFS placement, bounded attempts.
        free, job_host, job_start, next_job, _, _ = jax.lax.fori_loop(
            0, max_starts_per_bin, place_one,
            (free, job_host, job_start, next_job, jnp.asarray(False), t),
        )

        # 3) utilization accumulation over running jobs.
        started = job_start >= 0
        running = started & (t >= job_start) & (t < job_start + dur)
        phase = jnp.clip(
            ((t - job_start) * u_phases) // jnp.maximum(dur, 1), 0, u_phases - 1
        )
        u_job = jnp.take_along_axis(
            w.util_levels, phase[:, None], axis=1
        )[:, 0]
        busy = jnp.where(running, u_job * cores.astype(u_job.dtype), 0.0)
        seg = jnp.where(running, job_host, num_hosts)
        host_busy = jax.ops.segment_sum(busy, seg, num_segments=num_hosts + 1)[:num_hosts]
        u_h = host_busy / float(cores_per_host)

        queued = jnp.sum((submit <= t) & valid & jnp.logical_not(started))
        out_t = (u_h, queued.astype(jnp.int32), jnp.sum(running).astype(jnp.int32))
        new_state = dict(free=free, job_host=job_host, job_start=job_start,
                         next_job=next_job)
        return new_state, out_t

    state, (u_th, queue_len, running) = jax.lax.scan(
        step, init, jnp.arange(t_bins, dtype=jnp.int32)
    )
    return SimOutput(
        u_th=u_th,
        queue_len=queue_len,
        running=running,
        job_start=state["job_start"],
        job_host=state["job_host"],
    )


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Multi-metric prediction for a window (NFR3: >=2 perf + >=2 sust.)."""

    power_w: Array        # [T] total power draw (sustainability #1)
    energy_kwh: Array     # [T] per-bin energy (sustainability #2)
    tflops: Array         # [T] achieved TFLOP/s (performance #1)
    utilization: Array    # [T] mean datacenter utilization (performance #2)
    efficiency: Array     # [T] TFLOPs per kWh (paper Fig. 5C)


jax.tree_util.register_pytree_node(
    Prediction,
    lambda p: ((p.power_w, p.energy_kwh, p.tflops, p.utilization, p.efficiency), None),
    lambda _, c: Prediction(*c),
)


def predict_metrics(
    u_th: Array,
    params: PowerParams,
    dc: DatacenterConfig,
    model: str = "opendc",
) -> Prediction:
    """Map a utilization field to the paper's metric set (Fig. 5A/B/C)."""
    power = datacenter_power(u_th, params, model=model)
    e = energy_kwh(power, SAMPLE_SECONDS)
    util = jnp.mean(u_th, axis=-1)
    tflops = util * dc.peak_tflops
    eff = tflops / jnp.maximum(e, 1e-9)
    return Prediction(power_w=power, energy_kwh=e, tflops=tflops,
                      utilization=util, efficiency=eff)


def simulate(
    w: Workload,
    dc: DatacenterConfig,
    t_bins: int,
    params: PowerParams = PowerParams(),
    model: str = "opendc",
) -> tuple[SimOutput, Prediction]:
    """One-call trace-in, metrics-out simulation (FR2)."""
    sim = simulate_utilization(
        w,
        num_hosts=dc.num_hosts,
        cores_per_host=dc.cores_per_host,
        t_bins=t_bins,
    )
    return sim, predict_metrics(sim.u_th, params, dc, model=model)

"""Vectorized discrete-event datacenter simulation.

OpenDC — the simulator at the paper's core (FR2) — is an event-queue DES.
Event queues are pointer-chasing and data-dependent: hostile to TPUs and to
XLA.  Since the paper only ever *reads out* the simulation at the
industry-standard 5-minute granularity (§3.3), we adapt the simulator to the
hardware instead of porting the algorithm: a **dense, fixed-timestep,
time-marching simulation** whose state is tensors over ``[hosts]`` and
``[jobs]``, advanced by ``jax.lax.scan`` over 5-minute bins.

Event-driven semantics preserved at bin granularity:
  * job completion releases cores at the bin where ``start + duration`` falls;
  * FCFS placement with a bounded ``fori_loop`` of first-fit attempts per bin
    (strict head-of-line blocking, like OpenDC's default scheduler);
  * per-job piecewise utilization profiles (OpenDC "fragments").

Everything is one jitted program — NFR2's "7 days in under an hour" becomes
"7 days in well under a second" on a single CPU core (see benchmarks).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.power import PowerParams, datacenter_power, energy_kwh
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig, Workload

Array = jax.Array

#: time-axis block size of the post-scan read-out — bounds the dense
#: [jobs, bins] intermediates at O(jobs * block) per scenario (one day of
#: 5-minute bins per block).
_READOUT_BLOCK = 288

#: below this many [jobs, bins] elements per scenario the read-out runs in a
#: single pass (no lax.map): the intermediates are small and the blocked
#: scan only adds compile time.
_READOUT_CHUNK_THRESHOLD = 4_000_000


@dataclasses.dataclass(frozen=True)
class SimOutput:
    """Dense simulation read-out at 5-minute granularity.

    Attributes:
      u_th: ``[T, H]`` per-host utilization in [0, 1].
      queue_len: ``[T]`` jobs submitted but not yet started.
      running: ``[T]`` jobs running.
      job_start: ``[J]`` assigned start bin (-1 if never started).
      job_host: ``[J]`` assigned host (-1 if never started).
    """

    u_th: Array
    queue_len: Array
    running: Array
    job_start: Array
    job_host: Array


jax.tree_util.register_pytree_node(
    SimOutput,
    lambda s: ((s.u_th, s.queue_len, s.running, s.job_start, s.job_host), None),
    lambda _, c: SimOutput(*c),
)


def simulate_utilization_masked(
    w: Workload,
    host_mask: Array,
    cores_per_host: Array,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
    force_chunked_readout: bool = False,
) -> SimOutput:
    """Masked-host-axis DES core (trace-level; callers jit/vmap it).

    The host axis is padded to a static ``max_hosts``; ``host_mask [max_hosts]``
    marks the active hosts and ``cores_per_host`` is a *traced* int32 scalar.
    Inactive hosts start with 0 free cores and are excluded from placement, so
    they never run jobs and report 0 utilization.  Because every argument that
    varies between what-if candidates (mask, cores, workload) is a tensor,
    the whole simulation is ``jax.vmap``-able over a scenario axis — the
    batched engine in :mod:`repro.core.scenarios` is exactly that vmap.

    Placement (the event-driven part) is a bounded first-fit loop inside the
    scan body; utilization accumulation is a segment-sum scatter over host
    assignments.  Utilization is *independent of power-model parameters* —
    the structural fact the Self-Calibrator exploits (see calibrate.py).
    """
    j = w.num_jobs
    host_mask = jnp.asarray(host_mask, jnp.bool_)
    cores_per_host = jnp.asarray(cores_per_host, jnp.int32)

    submit = w.submit_bin
    dur = jnp.maximum(w.duration_bins, 1)
    cores = w.cores
    valid = w.valid

    # The scan carries *placement state only*: which job starts where/when,
    # free cores, and a [t_bins+1, max_hosts] core-release table written at
    # placement time (row t_bins absorbs clipped past-horizon releases).
    # Everything read out per bin (utilization field, queue depth, running
    # count) is reconstructed vectorized AFTER the scan from job_start —
    # per-bin O(jobs) passes inside the scan would dominate the runtime and,
    # under the scenario vmap, multiply by S with no amortization.
    init = dict(
        free=jnp.where(host_mask, cores_per_host, 0).astype(jnp.int32),
        job_host=jnp.full((j,), -1, jnp.int32),
        job_start=jnp.full((j,), -1, jnp.int32),
        next_job=jnp.asarray(0, jnp.int32),
        release=jnp.zeros((t_bins + 1, max_hosts), jnp.int32),
    )

    def head_ready(next_job, blocked, t):
        """Is the FCFS head job submittable at bin t (and are we unblocked)?"""
        jid = jnp.minimum(next_job, j - 1)
        return ((next_job < j) & (submit[jid] <= t) & valid[jid]
                & jnp.logical_not(blocked))

    # Placement runs in a while_loop with a deliberately *small* carry:
    # under vmap, the batched while_loop body re-runs for every lane until
    # all lanes are done and select-freezes every carry leaf per iteration,
    # so carrying the [jobs]-sized state here would cost O(S * jobs) per
    # attempt.  Instead each attempt records (job, host) into a
    # [max_starts_per_bin] buffer; the buffers are scattered into the scan
    # carry once per bin.
    def place_one(carry):
        free, next_job, blocked, t, attempts, buf_jid, buf_host = carry
        jid = jnp.minimum(next_job, j - 1)
        # re-checked inside the body: finished vmap lanes degrade to no-ops.
        eligible = head_ready(next_job, blocked, t)
        need = cores[jid]
        fits = (free >= need) & host_mask
        any_fit = jnp.any(fits)
        # worst-fit among fitting hosts (most free cores) — spreads load like
        # OpenDC's default mem/core-aware filter+weigher pipeline.
        host = jnp.argmax(jnp.where(fits, free, -1))
        do_place = eligible & any_fit
        free = free.at[host].add(jnp.where(do_place, -need, 0))
        buf_jid = buf_jid.at[attempts].set(jnp.where(do_place, jid, j))
        buf_host = buf_host.at[attempts].set(host)
        next_job = next_job + do_place.astype(jnp.int32)
        # strict FCFS: if the head job could not be placed, stop this bin.
        blocked = blocked | (eligible & jnp.logical_not(any_fit))
        return free, next_job, blocked, t, attempts + 1, buf_jid, buf_host

    def keep_placing(carry):
        free, next_job, blocked, t, attempts, buf_jid, buf_host = carry
        return head_ready(next_job, blocked, t) & (attempts < max_starts_per_bin)

    def step(state, t):
        # 1) completions: cores banked in the release table at placement time.
        free = state["free"] + state["release"][t]

        # 2) FCFS placement, bounded attempts with early exit: most bins
        # place far fewer than max_starts_per_bin jobs, and the while_loop
        # stops as soon as the head job is unsubmittable or blocked instead
        # of burning the remaining attempts on no-op iterations.
        buf_jid = jnp.full((max_starts_per_bin,), j, jnp.int32)
        buf_host = jnp.zeros((max_starts_per_bin,), jnp.int32)
        free, next_job, _, _, _, buf_jid, buf_host = jax.lax.while_loop(
            keep_placing, place_one,
            (free, state["next_job"], jnp.asarray(False), t,
             jnp.asarray(0, jnp.int32), buf_jid, buf_host),
        )

        # 3) apply this bin's placements (unused buffer slots hold the
        # out-of-bounds sentinel job id j and are dropped by the scatter).
        jj = jnp.minimum(buf_jid, j - 1)
        placed = buf_jid < j
        job_host = state["job_host"].at[buf_jid].set(buf_host, mode="drop")
        job_start = state["job_start"].at[buf_jid].set(t, mode="drop")
        end_bin = jnp.minimum(t + dur[jj], t_bins)
        release = state["release"].at[end_bin, buf_host].add(
            jnp.where(placed, cores[jj], 0))

        new_state = dict(free=free, job_host=job_host, job_start=job_start,
                         next_job=next_job, release=release)
        return new_state, None

    state, _ = jax.lax.scan(
        step, init, jnp.arange(t_bins, dtype=jnp.int32)
    )
    job_start, job_host = state["job_start"], state["job_host"]

    # -- vectorized post-scan read-out ---------------------------------------
    # Reconstructs exactly what the old per-bin accumulation produced:
    # integer counts are exact, and the float utilization scatter-adds in the
    # same job order as the per-bin segment-sum did.  Bins are processed in
    # blocks of _READOUT_BLOCK so the dense [jobs, bins] intermediates stay
    # bounded at O(jobs * block) per scenario (under the scenario vmap the
    # full-horizon version would materialize [S, jobs, bins] arrays).
    u_phases = w.num_phases
    started = job_start >= 0                           # [J]
    st = job_start[:, None]                            # [J, 1]
    du = dur[:, None]
    seg = jnp.where(started, job_host, max_hosts)      # sentinel bucket

    def readout_block(tt):
        # tt [B] with -1 padding past the horizon (matches nothing below)
        running = started[:, None] & (tt >= st) & (tt < st + du)   # [J, B]
        phase = jnp.clip((tt - st) * u_phases // jnp.maximum(du, 1),
                         0, u_phases - 1)
        u_job = jnp.take_along_axis(w.util_levels, phase, axis=1)  # [J, B]
        busy = jnp.where(
            running, u_job * cores[:, None].astype(u_job.dtype), 0.0)
        host_busy = jax.ops.segment_sum(
            busy, seg, num_segments=max_hosts + 1)[:max_hosts]     # [H, B]
        u_b = host_busy.T / jnp.maximum(cores_per_host, 1).astype(
            host_busy.dtype)
        started_by_t = started[:, None] & (tt >= st)               # [J, B]
        queued = jnp.sum(
            (submit[:, None] <= tt) & valid[:, None]
            & jnp.logical_not(started_by_t), axis=0).astype(jnp.int32)
        running_ct = jnp.sum(running, axis=0).astype(jnp.int32)
        return u_b, queued, running_ct

    # force_chunked_readout: a vmapping caller multiplies every intermediate
    # by its batch size, which this function cannot see — the batch engine
    # applies its own S-aware bound (see scenarios.run_scenarios).
    if not force_chunked_readout and j * t_bins <= _READOUT_CHUNK_THRESHOLD:
        u_th, queued, running_ct = readout_block(
            jnp.arange(t_bins, dtype=jnp.int32))
    else:
        block = min(t_bins, _READOUT_BLOCK)
        n_blocks = -(-t_bins // block)
        tt_pad = jnp.full((n_blocks * block,), -1, jnp.int32)
        tt_pad = tt_pad.at[:t_bins].set(jnp.arange(t_bins, dtype=jnp.int32))
        u_b, q_b, r_b = jax.lax.map(
            readout_block, tt_pad.reshape(n_blocks, block))
        u_th = u_b.reshape(n_blocks * block, max_hosts)[:t_bins]
        queued = q_b.reshape(-1)[:t_bins]
        running_ct = r_b.reshape(-1)[:t_bins]

    return SimOutput(
        u_th=u_th,
        queue_len=queued,
        running=running_ct,
        job_start=job_start,
        job_host=job_host,
    )


@functools.partial(jax.jit, static_argnames=("num_hosts", "cores_per_host",
                                             "t_bins", "max_starts_per_bin"))
def simulate_utilization(
    w: Workload,
    *,
    num_hosts: int,
    cores_per_host: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
) -> SimOutput:
    """Run the vectorized DES and return the utilization field.

    Single-topology entry point: the masked core with every host active.
    See :func:`simulate_utilization_masked` for the vmap-able core and
    :mod:`repro.core.scenarios` for the batched what-if engine built on it.
    """
    return simulate_utilization_masked(
        w,
        jnp.ones((num_hosts,), jnp.bool_),
        cores_per_host,
        max_hosts=num_hosts,
        t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin,
    )


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Multi-metric prediction for a window (NFR3: >=2 perf + >=2 sust.)."""

    power_w: Array        # [T] total power draw (sustainability #1)
    energy_kwh: Array     # [T] per-bin energy (sustainability #2)
    tflops: Array         # [T] achieved TFLOP/s (performance #1)
    utilization: Array    # [T] mean datacenter utilization (performance #2)
    efficiency: Array     # [T] TFLOPs per kWh (paper Fig. 5C)


jax.tree_util.register_pytree_node(
    Prediction,
    lambda p: ((p.power_w, p.energy_kwh, p.tflops, p.utilization, p.efficiency), None),
    lambda _, c: Prediction(*c),
)


def predict_metrics(
    u_th: Array,
    params: PowerParams,
    dc: DatacenterConfig,
    model: str = "opendc",
) -> Prediction:
    """Map a utilization field to the paper's metric set (Fig. 5A/B/C)."""
    power = datacenter_power(u_th, params, model=model)
    e = energy_kwh(power, SAMPLE_SECONDS)
    util = jnp.mean(u_th, axis=-1)
    tflops = util * dc.peak_tflops
    eff = tflops / jnp.maximum(e, 1e-9)
    return Prediction(power_w=power, energy_kwh=e, tflops=tflops,
                      utilization=util, efficiency=eff)


def simulate(
    w: Workload,
    dc: DatacenterConfig,
    t_bins: int,
    params: PowerParams = PowerParams(),
    model: str = "opendc",
) -> tuple[SimOutput, Prediction]:
    """One-call trace-in, metrics-out simulation (FR2)."""
    sim = simulate_utilization(
        w,
        num_hosts=dc.num_hosts,
        cores_per_host=dc.cores_per_host,
        t_bins=t_bins,
    )
    return sim, predict_metrics(sim.u_th, params, dc, model=model)

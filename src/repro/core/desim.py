"""Vectorized discrete-event datacenter simulation.

OpenDC — the simulator at the paper's core (FR2) — is an event-queue DES.
Event queues are pointer-chasing and data-dependent: hostile to TPUs and to
XLA.  Since the paper only ever *reads out* the simulation at the
industry-standard 5-minute granularity (§3.3), we adapt the simulator to the
hardware instead of porting the algorithm: a **dense, fixed-timestep,
time-marching simulation** whose state is tensors over ``[hosts]`` and
``[jobs]``, advanced by ``jax.lax.scan`` over 5-minute bins.

Event-driven semantics preserved at bin granularity:
  * job completion releases cores at the bin where ``start + duration`` falls;
  * FCFS placement with a bounded while-loop of placement attempts per bin
    (head-of-line blocking, like OpenDC's default scheduler), optionally
    relaxed by a bounded backfill window (see below);
  * per-job piecewise utilization profiles (OpenDC "fragments").

The *placement policy* — which host a job lands on, and whether queued
successors may jump a blocked head — is a **traced scenario knob**, not a
code path: host selection goes through a branchless ``policy_id``-indexed
score kernel (first-fit / best-fit / worst-fit / random-fit) and a traced
``backfill_depth`` bounds how many blocked-queue successors may start ahead
of the head.  Because both knobs are int32 scalars, the whole simulation
stays ``jax.vmap``-able over a scenario axis and one jitted program sweeps
schedulers *and* topologies together (see :mod:`repro.core.scenarios`).

Everything is one jitted program — NFR2's "7 days in under an hour" becomes
"7 days in well under a second" on a single CPU core (see benchmarks).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.power import (
    PowerParams,
    carbon_gco2,
    datacenter_power,
    energy_kwh,
)
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig, Workload

Array = jax.Array

#: time-axis block size of the post-scan read-out — bounds the dense
#: [jobs, bins] intermediates at O(jobs * block) per scenario (one day of
#: 5-minute bins per block).
_READOUT_BLOCK = 288

#: below this many [jobs, bins] elements per scenario the read-out runs in a
#: single pass (no lax.map): the intermediates are small and the blocked
#: scan only adds compile time.
_READOUT_CHUNK_THRESHOLD = 4_000_000

# -- placement policies -------------------------------------------------------
# Policy ids are *traced* int32 scalars: a scenario batch carries one per lane
# and the score kernel indexes a stacked [4, hosts] score table, so sweeping
# schedulers never retraces or recompiles.

FIRST_FIT = 0   #: lowest-indexed host that fits (packs the host prefix)
BEST_FIT = 1    #: fitting host with the fewest free cores (tightest pack)
WORST_FIT = 2   #: fitting host with the most free cores (spreads load;
                #: OpenDC's default mem/core-aware weigher — the seed behavior)
RANDOM_FIT = 3  #: deterministic pseudo-random fitting host (hash of
                #: (bin, placement#, host) — reproducible, seed-free)

#: name -> traced policy id, the scenario-facing vocabulary
PLACEMENT_POLICIES = {
    "first_fit": FIRST_FIT,
    "best_fit": BEST_FIT,
    "worst_fit": WORST_FIT,
    "random_fit": RANDOM_FIT,
}

#: id -> name (summaries / examples print this)
POLICY_NAMES = {v: k for k, v in PLACEMENT_POLICIES.items()}

#: bias making best-fit scores positive: scores must stay above the -1
#: "does not fit" sentinel, and free-core counts are far below 2**24.
_BEST_FIT_BIAS = 1 << 24


def resolve_policy(policy: "str | int | None") -> int:
    """Map a policy name (or id) to its int id; ``None`` -> worst-fit.

    >>> resolve_policy("first_fit")
    0
    >>> resolve_policy(None) == PLACEMENT_POLICIES["worst_fit"]
    True
    """
    if policy is None:
        return WORST_FIT
    if isinstance(policy, str):
        try:
            return PLACEMENT_POLICIES[policy]
        except KeyError:
            raise ValueError(
                f"unknown placement policy {policy!r}; "
                f"one of {sorted(PLACEMENT_POLICIES)}") from None
    p = int(policy)
    if p not in POLICY_NAMES:
        raise ValueError(f"policy id {p} not in {sorted(POLICY_NAMES)}")
    return p


def _hash_scores(host_idx: Array, t: Array, salt: Array) -> Array:
    """Deterministic per-host pseudo-random scores for RANDOM_FIT.

    A seed-free integer mix of (bin, placement-count-within-bin, host index):
    reproducible across runs and replicable in plain numpy (the test
    reference), with no PRNG key threaded through the scan carry.
    """
    x = (host_idx.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
         ^ t.astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
         ^ salt.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D))
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return (x & jnp.uint32(0x7FFFFF)).astype(jnp.int32)


def _policy_host(free: Array, fits: Array, policy_id: Array,
                 t: Array, salt: Array, max_hosts: int) -> Array:
    """Branchless host selection: argmax of a policy-indexed score.

    Builds the [4, max_hosts] score table (all int32, all >= 0 so the -1
    "does not fit" sentinel always loses), gathers the row for the *traced*
    ``policy_id``, and takes the argmax over fitting hosts.  Ties break to
    the lowest host index (argmax returns the first maximum), which makes
    WORST_FIT bit-identical to the pre-policy-kernel scheduler
    ``argmax(where(fits, free, -1))``.
    """
    idx = jnp.arange(max_hosts, dtype=jnp.int32)
    scores = jnp.stack([
        max_hosts - idx,                                    # FIRST_FIT
        _BEST_FIT_BIAS - jnp.minimum(free, _BEST_FIT_BIAS - 1),  # BEST_FIT
        free,                                               # WORST_FIT
        _hash_scores(idx, t, salt),                         # RANDOM_FIT
    ])
    score = scores[jnp.clip(policy_id, 0, len(PLACEMENT_POLICIES) - 1)]
    return jnp.argmax(jnp.where(fits, score, -1))


@dataclasses.dataclass(frozen=True)
class SimOutput:
    """Dense simulation read-out at 5-minute granularity.

    Attributes:
      u_th: ``[T, H]`` per-host utilization in [0, 1].
      queue_len: ``[T]`` jobs submitted but not yet started.
      running: ``[T]`` jobs running.
      job_start: ``[J]`` assigned start bin (-1 if never started).
      job_host: ``[J]`` assigned host (-1 if never started).
    """

    u_th: Array
    queue_len: Array
    running: Array
    job_start: Array
    job_host: Array


jax.tree_util.register_pytree_node(
    SimOutput,
    lambda s: ((s.u_th, s.queue_len, s.running, s.job_start, s.job_host), None),
    lambda _, c: SimOutput(*c),
)


def simulate_utilization_masked(
    w: Workload,
    host_mask: Array,
    cores_per_host: Array,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
    policy_id: "Array | int | None" = None,
    backfill_depth: "Array | int | None" = None,
    max_backfill: int = 0,
    force_chunked_readout: bool = False,
    fail_start: "Array | None" = None,
    fail_end: "Array | None" = None,
    fail_kill: "Array | None" = None,
) -> SimOutput:
    """Masked-host-axis DES core (trace-level; callers jit/vmap it).

    The host axis is padded to a static ``max_hosts``; ``host_mask [max_hosts]``
    marks the active hosts and ``cores_per_host`` is a *traced* int32 scalar.
    Inactive hosts start with 0 free cores and are excluded from placement, so
    they never run jobs and report 0 utilization.  Because every argument that
    varies between what-if candidates (mask, cores, workload, **policy**) is a
    tensor, the whole simulation is ``jax.vmap``-able over a scenario axis —
    the batched engine in :mod:`repro.core.scenarios` is exactly that vmap.

    Scheduling knobs (both *traced* int32 scalars, hence scenario axes):

    ``policy_id``
        Which host a placeable job lands on — one of
        :data:`PLACEMENT_POLICIES` (``None`` -> :data:`WORST_FIT`, the
        seed scheduler).  Selection is a branchless score-table gather
        (:func:`_policy_host`), so all four policies share one program.
    ``backfill_depth``
        When the FCFS head job is submitted but no host fits it, up to
        ``backfill_depth`` of its queued successors (submitted, valid, not
        already started) may start ahead of it, scanned in queue order.
        0 (the default) is strict head-of-line blocking.  Backfill never
        runs while the head is merely unsubmitted — jobs cannot start
        before jobs that have not arrived yet.

    ``max_backfill`` is the *static* window the traced depth is clipped to;
    leaving it 0 compiles the backfill machinery out entirely, making the
    default path structurally identical to the pre-policy-kernel scheduler.

    Failure schedules (``fail_start`` / ``fail_end`` / ``fail_kill``, all
    ``[max_hosts]``, together or not at all) add a *time-varying* layer to
    the host mask: during ``[fail_start[h], fail_end[h])`` host ``h``
    accepts no new placements, and if ``fail_kill[h]`` its running jobs
    are killed at the window start (cores return when the host does, at
    ``fail_end``; killed jobs are not re-queued) — a hard outage.  With
    ``fail_kill[h]`` false the host merely drains (running jobs finish
    normally).  Hosts that never fail carry the sentinel start
    ``np.iinfo(int32).max`` (see :func:`repro.runtime.fault.failure_arrays`),
    making every window comparison false — a disabled lane in a mixed
    batch computes bit-for-bit the no-failure schedule.  Presence of the
    arrays is *structural* (a Python-level ``is not None``), so the
    default program is unchanged when the axis is off.

    Placement (the event-driven part) is a bounded policy-kernel loop inside
    the scan body; utilization accumulation is a segment-sum scatter over
    host assignments.  Utilization is *independent of power-model
    parameters* — the structural fact the Self-Calibrator exploits (see
    calibrate.py).
    """
    if not 0 <= max_backfill <= 31:
        # the skip bitmask is uint32 and bit max_backfill must be addressable
        raise ValueError(f"max_backfill must be in [0, 31], got {max_backfill}")
    j = w.num_jobs
    host_mask = jnp.asarray(host_mask, jnp.bool_)
    cores_per_host = jnp.asarray(cores_per_host, jnp.int32)
    policy_id = jnp.asarray(
        WORST_FIT if policy_id is None else policy_id, jnp.int32)
    backfill_depth = jnp.asarray(
        0 if backfill_depth is None else backfill_depth, jnp.int32)
    depth = jnp.minimum(backfill_depth, max_backfill)
    if (fail_start is None) != (fail_end is None) or \
            (fail_start is None) != (fail_kill is None):
        raise ValueError(
            "fail_start/fail_end/fail_kill must be supplied together")
    if fail_start is not None:
        fail_start = jnp.asarray(fail_start, jnp.int32)
        fail_end = jnp.asarray(fail_end, jnp.int32)
        fail_kill = jnp.asarray(fail_kill, jnp.bool_)

    submit = w.submit_bin
    dur = jnp.maximum(w.duration_bins, 1)
    cores = w.cores
    valid = w.valid

    # The scan carries *placement state only*: which job starts where/when,
    # free cores, a [t_bins+1, max_hosts] core-release table written at
    # placement time (row t_bins absorbs clipped past-horizon releases), and
    # a skip bitmask of backfilled jobs ahead of the FCFS pointer.
    # Everything read out per bin (utilization field, queue depth, running
    # count) is reconstructed vectorized AFTER the scan from job_start —
    # per-bin O(jobs) passes inside the scan would dominate the runtime and,
    # under the scenario vmap, multiply by S with no amortization.
    init = dict(
        free=jnp.where(host_mask, cores_per_host, 0).astype(jnp.int32),
        job_host=jnp.full((j,), -1, jnp.int32),
        job_start=jnp.full((j,), -1, jnp.int32),
        next_job=jnp.asarray(0, jnp.int32),
        # bit d set <=> job next_job+d already started via backfill.  Bit 0 is
        # never set at rest: every pointer advance immediately consumes the
        # trailing run of set bits, so the head is always an unstarted job.
        skip=jnp.asarray(0, jnp.uint32),
        release=jnp.zeros((t_bins + 1, max_hosts), jnp.int32),
    )

    def head_ready(next_job, blocked, t):
        """Is the FCFS head job submittable at bin t (and are we unblocked)?"""
        jid = jnp.minimum(next_job, j - 1)
        return ((next_job < j) & (submit[jid] <= t) & valid[jid]
                & jnp.logical_not(blocked))

    def consume_skips(next_job, skip):
        """Advance the FCFS pointer past already-backfilled (started) jobs."""
        # trailing-ones count: first zero bit index.  Backfill sets bits
        # 1..max_backfill only, so a zero always exists in this window.
        bits = ((skip >> jnp.arange(max_backfill + 2, dtype=jnp.uint32))
                & jnp.uint32(1))
        k = jnp.argmin(bits).astype(jnp.uint32)
        return next_job + k.astype(jnp.int32), skip >> k

    # Placement runs in a while_loop with a deliberately *small* carry:
    # under vmap, the batched while_loop body re-runs for every lane until
    # all lanes are done and select-freezes every carry leaf per iteration,
    # so carrying the [jobs]-sized state here would cost O(S * jobs) per
    # attempt.  Instead each attempt records (job, host) into a
    # [max_starts_per_bin] buffer; the buffers are scattered into the scan
    # carry once per bin.  Every iteration either places exactly one job or
    # sets `blocked` (ending the bin), so the loop is bounded by
    # max_starts_per_bin placements.
    def place_one(carry):
        free, next_job, skip, blocked, t, n, buf_jid, buf_host = carry
        # failed hosts (outage or drain) accept no new placements during
        # their window; sentinel starts make this the plain mask.
        if fail_start is not None:
            online = host_mask & jnp.logical_not(
                (fail_start <= t) & (t < fail_end))
        else:
            online = host_mask
        jid_h = jnp.minimum(next_job, j - 1)
        # re-checked inside the body: finished vmap lanes degrade to no-ops.
        eligible = head_ready(next_job, blocked, t)
        head_fits = jnp.any((free >= cores[jid_h]) & online)
        place_head = eligible & head_fits

        if max_backfill > 0:
            # head is submitted but capacity-blocked: scan the next
            # `depth` queue positions in order for the first startable job.
            d_off = jnp.arange(1, max_backfill + 1, dtype=jnp.int32)  # [K]
            cand = next_job + d_off
            jid_c = jnp.minimum(cand, j - 1)
            already = ((skip >> d_off.astype(jnp.uint32)) & 1).astype(bool)
            elig_c = ((cand < j) & (submit[jid_c] <= t) & valid[jid_c]
                      & jnp.logical_not(already) & (d_off <= depth))
            fits_c = ((free[None, :] >= cores[jid_c][:, None])
                      & online[None, :])                             # [K, H]
            startable = elig_c & jnp.any(fits_c, axis=1)             # [K]
            any_bf = jnp.any(startable)
            d_sel = jnp.argmax(startable)        # first startable offset - 1
            place_bf = eligible & jnp.logical_not(head_fits) & any_bf
            jid = jnp.where(place_head, jid_h, jid_c[d_sel])
        else:
            place_bf = jnp.asarray(False)
            jid = jid_h

        need = cores[jid]
        fits = (free >= need) & online
        host = _policy_host(free, fits, policy_id, t,
                            jnp.asarray(n, jnp.int32), max_hosts)
        do_place = place_head | place_bf
        free = free.at[host].add(jnp.where(do_place, -need, 0))
        buf_jid = buf_jid.at[n].set(jnp.where(do_place, jid, j))
        buf_host = buf_host.at[n].set(host)

        if max_backfill > 0:
            # head placed: advance past it and any backfilled successors.
            nj_adv, skip_adv = consume_skips(next_job + 1, skip >> 1)
            skip_bf = skip | jnp.where(
                place_bf,
                jnp.uint32(1) << (d_sel + 1).astype(jnp.uint32),
                jnp.uint32(0))
            next_job = jnp.where(place_head, nj_adv, next_job)
            skip = jnp.where(place_head, skip_adv, skip_bf)
            blocked = blocked | (eligible & jnp.logical_not(head_fits)
                                 & jnp.logical_not(any_bf))
        else:
            next_job = next_job + place_head.astype(jnp.int32)
            # strict FCFS: if the head job could not be placed, stop this bin.
            blocked = blocked | (eligible & jnp.logical_not(head_fits))

        return (free, next_job, skip, blocked, t,
                n + do_place.astype(jnp.int32), buf_jid, buf_host)

    def keep_placing(carry):
        free, next_job, skip, blocked, t, n, buf_jid, buf_host = carry
        return head_ready(next_job, blocked, t) & (n < max_starts_per_bin)

    def step(state, t):
        # 1) completions: cores banked in the release table at placement time.
        free = state["free"] + state["release"][t]

        # 2) placement, bounded attempts with early exit: most bins place far
        # fewer than max_starts_per_bin jobs, and the while_loop stops as
        # soon as the head job is unsubmittable or the bin is blocked instead
        # of burning the remaining attempts on no-op iterations.
        buf_jid = jnp.full((max_starts_per_bin,), j, jnp.int32)
        buf_host = jnp.zeros((max_starts_per_bin,), jnp.int32)
        free, next_job, skip, _, _, _, buf_jid, buf_host = jax.lax.while_loop(
            keep_placing, place_one,
            (free, state["next_job"], state["skip"], jnp.asarray(False), t,
             jnp.asarray(0, jnp.int32), buf_jid, buf_host),
        )

        # 3) apply this bin's placements (unused buffer slots hold the
        # out-of-bounds sentinel job id j and are dropped by the scatter).
        jj = jnp.minimum(buf_jid, j - 1)
        placed = buf_jid < j
        job_host = state["job_host"].at[buf_jid].set(buf_host, mode="drop")
        job_start = state["job_start"].at[buf_jid].set(t, mode="drop")
        end_nom = t + dur[jj]
        if fail_start is not None:
            # kill rule, applied at placement time: a job landing on a
            # kill-host *before* its outage and running into it dies at
            # fail_start, and its cores come back with the host at
            # fail_end.  The `t < fail_start` guard keeps post-recovery
            # placements alive (for them t >= fail_end > fail_start).
            killed = (fail_kill[buf_host] & (t < fail_start[buf_host])
                      & (end_nom > fail_start[buf_host]))
            end_bin = jnp.minimum(
                jnp.where(killed, fail_end[buf_host], end_nom), t_bins)
        else:
            end_bin = jnp.minimum(end_nom, t_bins)
        release = state["release"].at[end_bin, buf_host].add(
            jnp.where(placed, cores[jj], 0))

        new_state = dict(free=free, job_host=job_host, job_start=job_start,
                         next_job=next_job, skip=skip, release=release)
        return new_state, None

    state, _ = jax.lax.scan(
        step, init, jnp.arange(t_bins, dtype=jnp.int32)
    )
    job_start, job_host = state["job_start"], state["job_host"]

    # -- vectorized post-scan read-out ---------------------------------------
    # Reconstructs exactly what the old per-bin accumulation produced:
    # integer counts are exact, and the float utilization scatter-adds in the
    # same job order as the per-bin segment-sum did.  Bins are processed in
    # blocks of _READOUT_BLOCK so the dense [jobs, bins] intermediates stay
    # bounded at O(jobs * block) per scenario (under the scenario vmap the
    # full-horizon version would materialize [S, jobs, bins] arrays).
    u_phases = w.num_phases
    started = job_start >= 0                           # [J]
    st = job_start[:, None]                            # [J, 1]
    du = dur[:, None]
    seg = jnp.where(started, job_host, max_hosts)      # sentinel bucket
    if fail_start is not None:
        # per-job effective end: killed jobs (placed pre-outage on a
        # kill-host, overlapping its window) stop at fail_start.  Mirrors
        # the release-table kill rule above.
        h_j = jnp.where(started, job_host, 0)
        fs_j = fail_start[h_j][:, None]                # [J, 1]
        kill_j = (fail_kill[h_j] & started)[:, None]
        killed_j = kill_j & (st < fs_j) & (st + du > fs_j)
        end_eff = jnp.where(killed_j, fs_j, st + du)
    else:
        end_eff = st + du

    def readout_block(tt):
        # tt [B] with -1 padding past the horizon (matches nothing below)
        running = started[:, None] & (tt >= st) & (tt < end_eff)   # [J, B]
        phase = jnp.clip((tt - st) * u_phases // jnp.maximum(du, 1),
                         0, u_phases - 1)
        u_job = jnp.take_along_axis(w.util_levels, phase, axis=1)  # [J, B]
        busy = jnp.where(
            running, u_job * cores[:, None].astype(u_job.dtype), 0.0)
        host_busy = jax.ops.segment_sum(
            busy, seg, num_segments=max_hosts + 1)[:max_hosts]     # [H, B]
        u_b = host_busy.T / jnp.maximum(cores_per_host, 1).astype(
            host_busy.dtype)
        started_by_t = started[:, None] & (tt >= st)               # [J, B]
        queued = jnp.sum(
            (submit[:, None] <= tt) & valid[:, None]
            & jnp.logical_not(started_by_t), axis=0).astype(jnp.int32)
        running_ct = jnp.sum(running, axis=0).astype(jnp.int32)
        return u_b, queued, running_ct

    # force_chunked_readout: a vmapping caller multiplies every intermediate
    # by its batch size, which this function cannot see — the batch engine
    # applies its own S-aware bound (see scenarios.run_scenarios).
    if not force_chunked_readout and j * t_bins <= _READOUT_CHUNK_THRESHOLD:
        u_th, queued, running_ct = readout_block(
            jnp.arange(t_bins, dtype=jnp.int32))
    else:
        block = min(t_bins, _READOUT_BLOCK)
        n_blocks = -(-t_bins // block)
        tt_pad = jnp.full((n_blocks * block,), -1, jnp.int32)
        tt_pad = tt_pad.at[:t_bins].set(jnp.arange(t_bins, dtype=jnp.int32))
        u_b, q_b, r_b = jax.lax.map(
            readout_block, tt_pad.reshape(n_blocks, block))
        u_th = u_b.reshape(n_blocks * block, max_hosts)[:t_bins]
        queued = q_b.reshape(-1)[:t_bins]
        running_ct = r_b.reshape(-1)[:t_bins]

    return SimOutput(
        u_th=u_th,
        queue_len=queued,
        running=running_ct,
        job_start=job_start,
        job_host=job_host,
    )


@functools.partial(jax.jit, static_argnames=("num_hosts", "cores_per_host",
                                             "t_bins", "max_starts_per_bin",
                                             "policy", "backfill_depth"))
def simulate_utilization(
    w: Workload,
    *,
    num_hosts: int,
    cores_per_host: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
    policy: "str | int | None" = None,
    backfill_depth: int = 0,
) -> SimOutput:
    """Run the vectorized DES and return the utilization field.

    Single-topology entry point: the masked core with every host active.
    ``policy``/``backfill_depth`` select the scheduler (static here — one
    compile per policy; defaults reproduce the seed worst-fit FCFS exactly).
    See :func:`simulate_utilization_masked` for the vmap-able core and
    :mod:`repro.core.scenarios` for the batched what-if engine that sweeps
    policies and topologies in one program.
    """
    return simulate_utilization_masked(
        w,
        jnp.ones((num_hosts,), jnp.bool_),
        cores_per_host,
        max_hosts=num_hosts,
        t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin,
        policy_id=resolve_policy(policy),
        backfill_depth=backfill_depth,
        max_backfill=int(backfill_depth),
    )


@dataclasses.dataclass(frozen=True)
class Prediction:
    """Multi-metric prediction for a window (NFR3: >=2 perf + >=2 sust.).

    The two optional leaves are ``None`` on the default path (no carbon
    trace, no enforced cap) so legacy predictions are structurally
    unchanged; the scenario engine fills them when the corresponding
    scenario axes are in play.
    """

    power_w: Array        # [T] delivered power draw (sustainability #1)
    energy_kwh: Array     # [T] per-bin energy (sustainability #2)
    tflops: Array         # [T] achieved TFLOP/s (performance #1)
    utilization: Array    # [T] mean datacenter utilization (performance #2)
    efficiency: Array     # [T] TFLOPs per kWh (paper Fig. 5C)
    gco2: Array | None = None           # [T] per-bin carbon (sust. #3)
    power_demand_w: Array | None = None  # [T] pre-cap demand (cap analysis)
    pue: Array | None = None            # [T] dynamic PUE (facility/IT ratio)
    energy_cost: Array | None = None    # [T] per-bin cost ($, spot price)


jax.tree_util.register_pytree_node(
    Prediction,
    lambda p: ((p.power_w, p.energy_kwh, p.tflops, p.utilization,
                p.efficiency, p.gco2, p.power_demand_w, p.pue,
                p.energy_cost), None),
    lambda _, c: Prediction(*c),
)


def predict_metrics(
    u_th: Array,
    params: PowerParams,
    dc: DatacenterConfig,
    model: str = "opendc",
    carbon_intensity: Array | None = None,
    ambient_c: Array | None = None,
    price: Array | None = None,
    pue: "object | None" = None,
    backend: str = "xla",
) -> Prediction:
    """Map a utilization field to the paper's metric set (Fig. 5A/B/C).

    ``carbon_intensity`` (``[T]`` gCO2/kWh, broadcastable against the power
    trace) additionally fills the per-bin ``gco2`` leaf; without it the
    prediction is bit-for-bit the pre-carbon output with ``gco2=None``.

    ``pue`` (a :class:`repro.traces.thermal.PUEParams`) turns on the
    dynamic cooling model: the power trace becomes *facility* watts
    (IT power x PUE, with PUE a traced function of mean utilization and
    the optional ``ambient_c`` °C trace) and the per-bin PUE fills the
    ``pue`` leaf.  ``price`` (``[T]`` $/kWh) fills ``energy_cost`` from
    the (facility) energy.  All three default off, leaving the legacy
    structure untouched.

    ``backend`` selects the readout implementation: ``"xla"`` (and
    ``"auto"`` off TPU) is the unfused pipeline below, bit-for-bit the
    historical output; ``"pallas"``/``"pallas_interpret"`` route through
    the fused one-pass kernel (:mod:`repro.kernels.des_readout`), within
    oracle tolerance of the unfused path but not bitwise (padded-lane
    summation).  ``TwinConfig.kernel_backend`` threads this through
    ``twin_step``, mirroring the calibration kernel switch.
    """
    from repro.kernels.ops import resolve_backend
    from repro.traces.thermal import dynamic_pue

    if resolve_backend(backend) != "xla":
        from repro.kernels.ops import des_readout

        kw = {}
        if pue is not None:
            kw = dict(pue_base=pue.base, pue_amb_coeff=pue.amb_coeff,
                      pue_amb_ref=pue.amb_ref, pue_load_coeff=pue.load_coeff)
        rd = des_readout(
            u_th, backend=backend, p_idle=params.p_idle,
            p_max=params.p_max, r=params.r, intensity=carbon_intensity,
            ambient=ambient_c, price=price, peak_tflops=dc.peak_tflops,
            model=model, dt_seconds=SAMPLE_SECONDS, **kw)
        return Prediction(
            power_w=rd["power_w"], energy_kwh=rd["energy_kwh"],
            tflops=rd["tflops"], utilization=rd["utilization"],
            efficiency=rd["efficiency"],
            gco2=None if carbon_intensity is None else rd["gco2"],
            pue=None if pue is None else rd["pue"],
            energy_cost=None if price is None else rd["energy_cost"])

    power = datacenter_power(u_th, params, model=model)
    util = jnp.mean(u_th, axis=-1)
    pue_t = None
    if pue is not None:
        pue_t = dynamic_pue(
            util,
            None if ambient_c is None else jnp.asarray(ambient_c),
            pue)
        power = power * pue_t
    e = energy_kwh(power, SAMPLE_SECONDS)
    tflops = util * dc.peak_tflops
    eff = tflops / jnp.maximum(e, 1e-9)
    gco2 = None
    if carbon_intensity is not None:
        gco2 = carbon_gco2(e, jnp.asarray(carbon_intensity))
    cost = None
    if price is not None:
        cost = e * jnp.asarray(price, e.dtype)
    return Prediction(power_w=power, energy_kwh=e, tflops=tflops,
                      utilization=util, efficiency=eff, gco2=gco2,
                      pue=pue_t, energy_cost=cost)


def simulate(
    w: Workload,
    dc: DatacenterConfig,
    t_bins: int,
    params: PowerParams = PowerParams(),
    model: str = "opendc",
) -> tuple[SimOutput, Prediction]:
    """One-call trace-in, metrics-out simulation (FR2)."""
    sim = simulate_utilization(
        w,
        num_hosts=dc.num_hosts,
        cores_per_host=dc.cores_per_host,
        t_bins=t_bins,
    )
    return sim, predict_metrics(sim.u_th, params, dc, model=model)

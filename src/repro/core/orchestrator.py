"""The Orchestrator (paper §2.3, component C) — now a thin imperative shell.

All per-window math lives in the pure functional core
(:mod:`repro.core.state`): a pytree :class:`~repro.core.state.TwinState`
advanced by the jitted :func:`~repro.core.state.twin_step`.  This shell owns
only what a pure function cannot: telemetry I/O (the
:class:`~repro.core.telemetry.TelemetryStore`), wall-clock pacing
(acceleration factor), run metadata (:class:`WindowRecord` — "which outputs
belong together", §2.3), float64 sustainability bookkeeping, and the
SLO-aware proposals routed through the human-in-the-loop gate.

The split is behavior-preserving: the shell reproduces the pre-redesign
per-window MAPE, parameter stream and gCO2 records bit-for-bit (pinned by
``tests/golden/orchestrator_pre_core.npz``), while the core it delegates to
additionally composes with ``vmap`` (fleets of twins,
``repro.core.twin.run_fleet``) and ``scan``.

Acceleration factor (paper §2.3): ratio between simulated and wall time.
  * factor=1   — live twinning: the loop sleeps out each window's wall time.
  * factor>1   — fixed acceleration.
  * factor=None — maximum acceleration (as fast as compute allows).
"""

from __future__ import annotations

import dataclasses
import time
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import CalibrationSpec
from repro.core.desim import PLACEMENT_POLICIES, Prediction, SimOutput, simulate_utilization
from repro.core.feedback import (
    HITLGate,
    Proposal,
    ProposalKind,
    propose_from_optimum,
    propose_from_scenario,
    propose_from_state,
)
from repro.core.optimize import (
    ObjectiveSpec,
    OptimizeResult,
    OptimizerConfig,
    SearchSpace,
    optimize,
)
from repro.core.power import PowerParams, mape
from repro.core.scenarios import Scenario, ScenarioSummary, evaluate_scenarios
from repro.core.state import (
    SimSlice,
    TwinConfig,
    TwinState,
    empty_telemetry,
    init_twin_state,
    load_state,
    make_telemetry,
    save_state,
    twin_step_jit,
)
from repro.traces.carbon import validate_carbon_intensity
from repro.traces.price import validate_price
from repro.traces.thermal import PUEParams, validate_ambient
from repro.core.slo import NFR1, BiasTracker, SLOMonitor
from repro.core.telemetry import (
    AMBIENT_KEY,
    CARBON_INTENSITY_KEY,
    PRICE_KEY,
    TelemetryStore,
    TelemetryWindow,
)
from repro.traces.schema import SAMPLE_SECONDS, DatacenterConfig, Workload


@dataclasses.dataclass(frozen=True)
class OrchestratorConfig:
    bins_per_window: int = 36            # 3 h windows at 5-min sampling
    calibration: CalibrationSpec = CalibrationSpec()
    calibrate: bool = True               # E2 ablation switch
    history_windows: int = 4             # telemetry history per calibration
    acceleration: float | None = None    # None = max acceleration (paper mode 3)
    power_cap_w: float | None = None
    power_model: str = "opendc"
    kernel_backend: str = "xla"          # "pallas" on TPU deployments
    #: facility PUE model: window predictions and what-if sweeps report
    #: facility power (IT x PUE(load, ambient)) instead of bare IT draw.
    #: Scenarios that set their own ``pue_base`` override this default.
    pue: PUEParams | None = None
    #: resident-DES mode (paper stage 3): the full-horizon utilization field
    #: lives *inside* ``TwinState.sim_u`` and ``twin_step`` slices its own
    #: window, so an applied topology/scheduler proposal
    #: (:meth:`Orchestrator.apply_proposal`) re-seeds the twin's own
    #: simulation instead of an external cache.  Off by default: the
    #: external-cache path stays bitwise-pinned by the goldens.
    sim_in_state: bool = False


@dataclasses.dataclass(frozen=True)
class Clock:
    """Injectable wall clock for the I/O shell (tracecheck TC007).

    ``now``/``sleep`` default to the real clock; pacing tests inject fakes
    so acceleration behavior is asserted deterministically instead of
    slept out.  The pure core never sees this object — wall time only
    touches records and pacing, never the traced math.
    """

    now: Callable[[], float] = time.time
    sleep: Callable[[float], None] = time.sleep


@dataclasses.dataclass
class WindowRecord:
    """Run metadata the orchestrator records per window (paper §2.3:
    'which outputs belong together').

    ``sim_seconds`` times the whole fused ``twin_step`` (prediction *and*
    calibration — they compile into one program since the pure-core
    redesign); ``calib_seconds`` is kept for schema compatibility but is
    always 0.0, as the fused program has no separable calibration phase.
    """

    window: int
    started_at: float
    sim_seconds: float
    calib_seconds: float
    params: PowerParams
    prediction: Prediction
    mape: float | None = None        # filled when telemetry lands
    gco2: float | None = None        # window carbon (needs intensity trace)
    energy_cost: float | None = None  # window cost in $ (needs price trace)
    proposals: int = 0


@dataclasses.dataclass(frozen=True)
class WhatIfResult:
    """Outcome of one batched what-if sweep.

    ``summaries[0]`` is the baseline (current topology) when the sweep was
    run with ``include_baseline=True``; with ``include_baseline=False`` the
    summaries are the user's scenarios only (the baseline is still evaluated
    internally so every candidate — including the first — is compared
    against the *current* configuration, never against another candidate).
    ``proposals`` are already submitted to the orchestrator's HITL gate.
    """

    summaries: list[ScenarioSummary]
    proposals: list[Proposal]
    sim: SimOutput              # batched, leaves [S, ...]
    prediction: Prediction      # batched, leaves [S, ...]


@dataclasses.dataclass(frozen=True)
class OptimizeWhatIfResult:
    """Outcome of one searched what-if: the optimum plus its HITL routing.

    ``result`` is the raw :class:`~repro.core.optimize.OptimizeResult`
    (incumbent, baseline, full evaluation history, convergence trace);
    ``proposals`` are already submitted to the orchestrator's HITL gate and
    carry the searched optimum's objective breakdown vs the baseline in
    their ``impact``.
    """

    result: OptimizeResult
    proposals: list[Proposal]


class Orchestrator:
    """Drives the closed loop over a trace-driven physical twin.

    The physical twin is abstracted as the TelemetryStore producer —
    experiments push synthesized ground truth; the live-training example
    pushes real measurements from the training run.

    The windowed math is one ``twin_step`` per window on ``self.state``;
    this object is the I/O shell around it.
    """

    def __init__(
        self,
        workload: Workload,
        dc: DatacenterConfig,
        t_bins: int,
        cfg: OrchestratorConfig = OrchestratorConfig(),
        base_params: PowerParams = PowerParams(),
        gate: HITLGate | None = None,
        carbon_intensity: "np.ndarray | None" = None,
        ambient_c: "np.ndarray | None" = None,
        price: "np.ndarray | None" = None,
        clock: Clock | None = None,
    ):
        self.workload = workload
        self.dc = dc
        self.t_bins = int(t_bins)
        self.cfg = cfg
        self.base_params = base_params
        # full-horizon forecasts ([t_bins] each): grid carbon intensity
        # (gCO2/kWh), outside-air temperature (deg C, feeds the dynamic-PUE
        # model) and electricity spot price ($/kWh).  Window predictions
        # gain gCO2 / facility PUE / energy cost and what-if sweeps become
        # carbon-, cooling- and cost-aware.  Per-window *measured* values in
        # telemetry extras (telemetry.CARBON_INTENSITY_KEY / AMBIENT_KEY /
        # PRICE_KEY) override these forecasts when scoring a window.
        if carbon_intensity is not None:
            carbon_intensity = validate_carbon_intensity(
                np.asarray(carbon_intensity), self.t_bins)
        self.carbon_intensity = carbon_intensity
        if ambient_c is not None:
            ambient_c = validate_ambient(np.asarray(ambient_c), self.t_bins)
        self.ambient_c = ambient_c
        if price is not None:
            price = validate_price(np.asarray(price), self.t_bins)
        self.price = price
        if (cfg.pue is not None and cfg.pue.amb_coeff > 0.0
                and ambient_c is None):
            raise ValueError(
                "OrchestratorConfig.pue has amb_coeff > 0 but no ambient_c "
                "trace was supplied — pass ambient_c=[t_bins] deg C or use "
                "a load-only PUE model (amb_coeff=0)")
        self.clock = clock or Clock()
        self.store = TelemetryStore(cfg.bins_per_window)
        self.gate = gate or HITLGate()
        self.records: list[WindowRecord] = []
        # scheduler knobs the resident DES runs under; structural proposals
        # (apply_proposal) are the only writers after construction.
        self.policy: str | None = None
        self.backfill_depth: int = 0
        self._sim: SimOutput | None = None
        self.twin_cfg = TwinConfig(
            bins_per_window=cfg.bins_per_window,
            dc=dc,
            calibration=cfg.calibration,
            calibrate=cfg.calibrate,
            history_windows=cfg.history_windows,
            power_model=cfg.power_model,
            kernel_backend=cfg.kernel_backend,
            slos=(NFR1,),
            pue=cfg.pue,
            sim_bins=self.t_bins if cfg.sim_in_state else 0,
        )
        sim_u = self._ensure_sim().u_th if cfg.sim_in_state else None
        self.state: TwinState = init_twin_state(self.twin_cfg, base_params,
                                                sim_u=sim_u)

    # -- pure-core views ------------------------------------------------------
    @property
    def monitor(self) -> SLOMonitor:
        """SLO compliance view, hydrated from the core's accumulators."""
        return SLOMonitor.from_counts(
            self.twin_cfg.slos, self.state.slo_samples,
            self.state.slo_compliant)

    @property
    def bias(self) -> BiasTracker:
        """Fig.-6 bias split, hydrated from the core's accumulators."""
        return BiasTracker(under=int(self.state.bias_under),
                           over=int(self.state.bias_over),
                           ties=int(self.state.bias_ties))

    def save_state(self, path: str) -> None:
        """Checkpoint the twin core (see :func:`repro.core.state.save_state`)."""
        save_state(self.state, path)

    def restore_state(self, path: str) -> None:
        """Resume from a checkpoint; the config must match this orchestrator."""
        state = load_state(path)
        if state.cfg != self.twin_cfg:
            raise ValueError(
                "checkpointed TwinConfig differs from this orchestrator's "
                f"configuration:\n  saved: {state.cfg}\n  here:  {self.twin_cfg}")
        self.state = state

    # -- simulation engine (component H) ------------------------------------
    def _ensure_sim(self) -> SimOutput:
        """Trace-driven utilization simulation for the full horizon.

        Deterministic and power-parameter independent, so it is computed once
        and windows read slices — the DES itself re-runs only when the
        workload or topology changes (what-if analysis does exactly that).
        """
        if self._sim is None:
            self._sim = simulate_utilization(
                self.workload,
                num_hosts=self.dc.num_hosts,
                cores_per_host=self.dc.cores_per_host,
                t_bins=self.t_bins,
                policy=self.policy,
                backfill_depth=self.backfill_depth,
            )
        return self._sim

    def invalidate(self) -> None:
        """Drop the cached DES state (topology/workload changed)."""
        self._sim = None

    @property
    def num_windows(self) -> int:
        return self.t_bins // self.cfg.bins_per_window

    def window_slice(self, window: int) -> slice:
        w = self.cfg.bins_per_window
        return slice(window * w, (window + 1) * w)

    # -- one window of operation --------------------------------------------
    def run_window(self, window: int) -> WindowRecord:
        """Execute one window: gather its inputs, advance the pure core one
        ``twin_step`` (predict S_k with params from C_{k-1}; score + calibrate
        C_k when telemetry has landed), then do the shell work — records,
        float64 carbon bookkeeping, proposals, pacing."""
        t_start = self.clock.now()
        sim = self._ensure_sim()
        sl = self.window_slice(window)

        # Telemetry for this window (produced asynchronously by the physical
        # twin; in-loop experiments ingest it before calling run_window).
        tw = self.store.get(window)
        # Telemetry measured on a *different* topology (ingested before an
        # apply_proposal resize) cannot score this twin — same not-landed
        # treatment as missing telemetry, never a shape error inside jit.
        if tw is not None and np.asarray(tw.u_th).shape[1] != self.dc.num_hosts:
            tw = None
        # window carbon: prefer *measured* intensity from telemetry extras
        # over the configured forecast (same precedence as power itself).
        ci_meas = (tw.extras.get(CARBON_INTENSITY_KEY)
                   if tw is not None else None)
        if ci_meas is not None and np.asarray(ci_meas).shape[0] != (sl.stop - sl.start):
            ci_meas = None  # partially-clipped extras: fall back to forecast
        if ci_meas is not None:
            # same boundary rule as the forecast: a NaN/negative measured
            # intensity (sensor glitch) must fail loudly, not flip the sign
            # of the sustainability record.
            ci_meas = validate_carbon_intensity(np.asarray(ci_meas))

        # measured spot price / ambient from telemetry extras, same
        # shape-check fallback and loud validation as carbon above.
        w_bins = sl.stop - sl.start
        pr_meas = tw.extras.get(PRICE_KEY) if tw is not None else None
        if pr_meas is not None and np.asarray(pr_meas).shape[0] != w_bins:
            pr_meas = None
        if pr_meas is not None:
            pr_meas = validate_price(np.asarray(pr_meas))
        amb_meas = tw.extras.get(AMBIENT_KEY) if tw is not None else None
        if amb_meas is not None and np.asarray(amb_meas).shape[0] != w_bins:
            amb_meas = None
        if amb_meas is not None:
            amb_meas = validate_ambient(np.asarray(amb_meas))

        ci_w = (jnp.asarray(self.carbon_intensity[sl], jnp.float32)
                if self.carbon_intensity is not None else None)
        # ambient feeds the *prediction* itself (PUE multiplies power), so a
        # measured trace replaces the forecast slice before the step runs —
        # a value-level swap, same shapes, no retrace.
        amb_host = (amb_meas if amb_meas is not None
                    else (self.ambient_c[sl]
                          if self.ambient_c is not None else None))
        amb_w = (jnp.asarray(amb_host, jnp.float32)
                 if amb_host is not None else None)
        pr_w = (jnp.asarray(self.price[sl], jnp.float32)
                if self.price is not None else None)
        telem = (make_telemetry(tw.u_th, tw.power_w) if tw is not None
                 else empty_telemetry(self.cfg.bins_per_window,
                                      self.dc.num_hosts))

        # All the math: one pure, jitted step on the twin core.  In
        # resident-DES mode the step slices its own window from
        # ``state.sim_u`` (u_th=None), so what it predicts from is whatever
        # apply_proposal last seeded — not this shell's cache.
        t0 = self.clock.now()
        self.state, out = twin_step_jit(
            self.state, telem, SimSlice(u_th=(None if self.cfg.sim_in_state
                                              else sim.u_th[sl]),
                                        carbon_intensity=ci_w,
                                        ambient_c=amb_w,
                                        price=pr_w))
        pred = out.prediction
        pred.power_w.block_until_ready()
        sim_seconds = self.clock.now() - t0

        rec = WindowRecord(
            window=window, started_at=t_start, sim_seconds=sim_seconds,
            calib_seconds=0.0, params=out.params_used, prediction=pred,
        )

        # float64 sustainability record (host-side reporting precision).
        if ci_meas is not None:
            rec.gco2 = float(np.sum(
                np.asarray(pred.energy_kwh, np.float64)
                * np.asarray(ci_meas, np.float64)))
        elif pred.gco2 is not None:
            rec.gco2 = float(np.sum(np.asarray(pred.gco2, np.float64)))

        # float64 energy-cost record: measured spot price wins over the
        # forecast the traced lane priced with.
        if pr_meas is not None:
            rec.energy_cost = float(np.sum(
                np.asarray(pred.energy_kwh, np.float64)
                * np.asarray(pr_meas, np.float64)))
        elif pred.energy_cost is not None:
            rec.energy_cost = float(np.sum(
                np.asarray(pred.energy_cost, np.float64)))

        if tw is not None:
            rec.mape = float(out.mape)

            # SLO-aware proposals through the HITL gate.
            props = propose_from_state(
                window,
                mape=rec.mape,
                mean_util=float(np.mean(tw.u_th)),
                queue_len=float(np.mean(np.asarray(sim.queue_len[sl]))),
                power_w=float(np.mean(np.asarray(pred.power_w))),
                power_cap_w=self.cfg.power_cap_w,
            )
            for p_ in props:
                self.gate.submit(p_)
            rec.proposals = len(props)

        self.records.append(rec)

        # acceleration factor: live mode sleeps out the window's wall time.
        if self.cfg.acceleration:
            wall = self.cfg.bins_per_window * SAMPLE_SECONDS / self.cfg.acceleration
            spent = self.clock.now() - t_start
            if wall > spent:
                self.clock.sleep(min(wall - spent, 1.0))  # capped for tests
        return rec

    # -- batched what-if analysis (paper Fig. 1, operator loop) --------------
    def evaluate_whatif(
        self,
        scenarios: "list[Scenario] | tuple[Scenario, ...]",
        *,
        include_baseline: bool = True,
        max_hosts: int | None = None,
    ) -> "WhatIfResult":
        """Evaluate S candidate configurations in one jitted program.

        Uses the *calibrated* power parameters (the twin's current best model
        of reality) so what-if outcomes reflect the live datacenter, not the
        spec sheet.  A baseline scenario (the current topology and scheduler
        — worst-fit FCFS, no backfill) is always evaluated alongside the
        candidates and **every** user scenario is compared against it; each
        candidate that improves a sustainability metric without breaking
        SLOs, cuts queue wait via a cheaper *scheduler* (placement policy /
        backfill depth, a software-only change), or violates its power cap
        becomes a proposal routed through the HITL gate.

        ``include_baseline`` only controls whether the baseline appears in
        the returned ``summaries``/``sim``/``prediction`` (as entry 0) — it
        never changes which scenarios generate proposals.  (Before this fix,
        ``include_baseline=False`` silently treated the *first user scenario*
        as the baseline and excluded it from proposal generation.)  Because
        the baseline always rides along, an explicit ``max_hosts`` is raised
        to at least the current topology's host count (the padded host axis
        must fit the baseline; per-lane outputs are unaffected).
        """
        params = self.state.params
        scs = [self._with_pue(s)
               for s in [Scenario(name="baseline")] + list(scenarios)]
        if max_hosts is not None:
            max_hosts = max(int(max_hosts), self.dc.num_hosts)
        _, sim, pred, summaries = evaluate_scenarios(
            self.workload, self.dc, scs,
            t_bins=self.t_bins, base_params=params, max_hosts=max_hosts,
            model=self.cfg.power_model,
            carbon_intensity=self.carbon_intensity,
            ambient_c=self.ambient_c,
            price=self.price,
        )
        window = len(self.records)
        baseline = summaries[0]
        proposals: list[Proposal] = []
        for s in summaries[1:]:
            for p in propose_from_scenario(window, s, baseline):
                proposals.append(self.gate.submit(p))
        if not include_baseline:
            sim = jax.tree.map(lambda x: x[1:], sim)
            pred = jax.tree.map(lambda x: x[1:], pred)
            summaries = summaries[1:]
        return WhatIfResult(summaries=summaries, proposals=proposals,
                            sim=sim, prediction=pred)

    # -- applying approved proposals (paper stage 3, closing the loop) -------
    def apply_proposal(self, p: Proposal) -> None:
        """Apply an approved structural proposal to this twin.

        Closes the paper's operator loop: a what-if/optimize sweep produced
        the proposal, the HITL gate approved it, and this call makes the
        twin *be* the proposed datacenter.  ``SCHEDULER_CHANGE`` swaps the
        DES scheduler (placement policy + backfill depth, a software-only
        change); ``SCALE_UP`` / ``SCALE_DOWN_IDLE`` resize the topology.
        The full-horizon DES then re-runs under the new configuration and
        the twin core is rebuilt around it (:meth:`_rebuild_state`) — in
        resident-DES mode (``cfg.sim_in_state``) that re-seeds the state's
        own ``sim_u``, so the very next ``twin_step`` predicts the new
        datacenter without this shell re-slicing anything.

        Raises for unapproved proposals (route them through the gate first)
        and for kinds with no structural interpretation here (power caps and
        time-shifting live on the scenario axis, not the twin's topology).
        """
        if p.approved is not True:
            raise ValueError(
                f"proposal {p.kind.value}@w{p.window} is not approved — "
                "route it through the HITL gate before applying")
        if p.kind is ProposalKind.SCHEDULER_CHANGE:
            self.policy = p.impact.get("policy", self.policy)
            self.backfill_depth = int(
                p.impact.get("backfill_depth", self.backfill_depth))
        elif p.kind in (ProposalKind.SCALE_UP, ProposalKind.SCALE_DOWN_IDLE):
            if "num_hosts" not in p.impact:
                raise ValueError(
                    f"{p.kind.value} proposal carries no num_hosts impact")
            n = int(p.impact["num_hosts"])
            if n <= 0:
                raise ValueError(f"proposed num_hosts must be >= 1; got {n}")
            self.dc = dataclasses.replace(self.dc, num_hosts=n)
        else:
            raise ValueError(
                f"{p.kind.value} is not a structural proposal this twin can "
                "apply (power caps / load shifting are scenario axes; "
                "recalibration is automatic)")
        p.applied = True
        self.invalidate()
        self._rebuild_state()

    def _rebuild_state(self) -> None:
        """Rebuild the twin core around the current ``self.dc`` / scheduler.

        Run accumulators (window counter, SLO counts, bias split) always
        migrate — they describe the run, not the topology.  Calibrated
        parameters migrate too and become the new base (per-host rows keep
        their first ``min(old, new)`` hosts and mean-pad growth, the same
        convention as the what-if path).  Calibration history migrates only
        while the host axis is unchanged: telemetry measured on a different
        topology would mis-calibrate the new one, so a resize starts the
        history fresh.  In resident-DES mode the rebuilt state is seeded
        with the re-run DES horizon.
        """
        old = self.state
        old_h = old.cfg.dc.num_hosts
        h = self.dc.num_hosts
        self.twin_cfg = dataclasses.replace(self.twin_cfg, dc=self.dc)
        sim_u = self._ensure_sim().u_th if self.cfg.sim_in_state else None

        def row(x):
            v = np.asarray(x, np.float32)
            if v.ndim == 0:
                return v
            out = np.full((h,), float(v.mean()), np.float32)
            out[:min(v.size, h)] = v[:h]
            return out

        params = PowerParams(p_idle=row(old.params.p_idle),
                             p_max=row(old.params.p_max),
                             r=row(old.params.r))
        state = init_twin_state(self.twin_cfg, params, sim_u=sim_u)
        keep = dict(window=old.window,
                    slo_samples=old.slo_samples,
                    slo_compliant=old.slo_compliant,
                    bias_under=old.bias_under,
                    bias_over=old.bias_over,
                    bias_ties=old.bias_ties)
        if h == old_h:
            keep.update(hist_u=old.hist_u, hist_p=old.hist_p,
                        hist_n=old.hist_n)
        self.state = dataclasses.replace(state, **keep)

    def _with_pue(self, s: Scenario) -> Scenario:
        """Apply the orchestrator's facility PUE model to a scenario.

        Scenarios that set their own ``pue_base`` keep it; with
        ``cfg.pue=None`` this is the identity.  Applying the default to
        *every* lane (baseline included) keeps what-if comparisons
        facility-vs-facility, never facility-vs-bare-IT.
        """
        p = self.cfg.pue
        if p is None or s.pue_base is not None:
            return s
        return dataclasses.replace(
            s, pue_base=p.base, pue_amb_coeff=p.amb_coeff,
            pue_amb_ref=p.amb_ref, pue_load_coeff=p.load_coeff)

    # -- searched what-if: optimize over the scenario space ------------------
    def default_search_space(self) -> SearchSpace:
        """A conservative software-only search space for the current twin.

        Structures: the current topology under every placement policy (the
        non-default policies get a backfill window — a pure scheduler
        change); continuous axes: deferrable-job time-shifting up to 3 hours.
        Cap axes stay off by default — capping trades performance for watts
        and deserves an explicitly chosen range (pass a custom
        :class:`~repro.core.optimize.SearchSpace` to search them).
        """
        structures = tuple(
            Scenario(name=p, policy=p,
                     backfill_depth=0 if p == "worst_fit" else 4)
            for p in sorted(PLACEMENT_POLICIES))
        return SearchSpace(structures=structures, shift_bins=(0, 36))

    def optimize_whatif(
        self,
        space: SearchSpace | None = None,
        objective: ObjectiveSpec | None = None,
        *,
        key: "int | jax.Array" = 0,
        config: OptimizerConfig = OptimizerConfig(),
        shard: bool = False,
        mesh=None,
    ) -> OptimizeWhatIfResult:
        """Search the scenario space and route the optimum through the gate.

        Where :meth:`evaluate_whatif` scores a hand-written candidate list,
        this *finds* the operating point: the search space defaults to
        :meth:`default_search_space` and is evaluated against the twin's
        **current calibrated** power parameters (``self.state.params``) and
        carbon forecast, so the optimum reflects the live datacenter, not
        the spec sheet.  The winner is compared against the always-evaluated
        baseline and submitted to the HITL gate via
        :func:`repro.core.feedback.propose_from_optimum` — proposals carry
        the searched optimum plus its objective breakdown vs baseline.
        Deterministic given ``key``; ``shard=True`` spans the device mesh.
        """
        if space is None:
            space = self.default_search_space()
        if self.cfg.pue is not None:
            space = dataclasses.replace(
                space,
                structures=tuple(self._with_pue(s) for s in space.structures))
        if objective is None:
            # no carbon forecast -> optimize energy instead of gCO2 (the
            # gCO2 weight would otherwise demand a trace we don't have)
            objective = (ObjectiveSpec() if self.carbon_intensity is not None
                         else ObjectiveSpec(w_gco2_kg=0.0, w_energy_kwh=1.0))
        res = optimize(
            self.workload, self.dc, space, objective,
            t_bins=self.t_bins, base_params=self.state.params,
            carbon_intensity=self.carbon_intensity,
            ambient_c=self.ambient_c, price=self.price,
            key=key, config=config,
            model=self.cfg.power_model, shard=shard, mesh=mesh,
        )
        window = len(self.records)
        proposals = [
            self.gate.submit(p) for p in propose_from_optimum(
                window, res.best_summary, res.baseline_summary,
                objective=res.best.objective,
                baseline_objective=res.baseline.objective,
                breakdown=res.best.breakdown,
                baseline_breakdown=res.baseline.breakdown,
            )]
        return OptimizeWhatIfResult(result=res, proposals=proposals)

    def run(self, num_windows: int | None = None) -> list[WindowRecord]:
        n = num_windows if num_windows is not None else self.num_windows
        for w in range(n):
            self.run_window(w)
        return self.records

    # -- results -------------------------------------------------------------
    def overall_mape(self) -> float:
        """MAPE over all scored bins (concatenated windows)."""
        real, simp = [], []
        for rec in self.records:
            tw = self.store.get(rec.window)
            if tw is None:
                continue
            real.append(tw.power_w)
            simp.append(np.asarray(rec.prediction.power_w, np.float64))
        if not real:
            return float("nan")
        return float(mape(jnp.asarray(np.concatenate(real)),
                          jnp.asarray(np.concatenate(simp))))

    def per_window_mape(self) -> np.ndarray:
        return np.array([r.mape if r.mape is not None else np.nan
                         for r in self.records])

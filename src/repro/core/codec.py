"""Compression codec for persisted blobs (telemetry flushes, checkpoints).

Optional-dependency policy: ``zstandard`` is the *preferred* codec but must
never be required — offline deployments (and CI) run without it.  Every blob
written through this module is tagged with a **one-byte codec id** so any
reader can open any file regardless of which codecs its environment has:

  * ``0x01`` — zstd-compressed payload (requires ``zstandard`` to read);
  * ``0x02`` — zlib-compressed payload (stdlib, always readable).

Writers pick zstd when the package is importable and fall back to zlib
otherwise.  Legacy blobs from before the codec byte existed are raw zstd
frames (magic ``28 B5 2F FD``); :func:`decompress` detects and handles them
for backward compatibility.
"""

from __future__ import annotations

import zlib

try:  # optional dependency — never a hard import
    import zstandard  # type: ignore

    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - environment dependent
    zstandard = None  # type: ignore
    HAVE_ZSTD = False

#: one-byte codec ids prepended to every blob
CODEC_ZSTD = b"\x01"
CODEC_ZLIB = b"\x02"

#: magic prefix of a raw (un-tagged, pre-codec-byte) zstd frame
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def default_codec() -> bytes:
    """The codec id a writer should use in this environment."""
    return CODEC_ZSTD if HAVE_ZSTD else CODEC_ZLIB


def compress(data: bytes, level: int = 3, codec: bytes | None = None) -> bytes:
    """Compress ``data`` and prepend the codec id byte.

    ``codec`` forces a specific codec (tests exercise the zlib path even when
    zstandard is installed); by default the best available codec is used.
    """
    codec = default_codec() if codec is None else codec
    if codec == CODEC_ZSTD:
        if not HAVE_ZSTD:
            raise RuntimeError("zstd codec requested but zstandard is not installed")
        return CODEC_ZSTD + zstandard.ZstdCompressor(level=level).compress(data)
    if codec == CODEC_ZLIB:
        return CODEC_ZLIB + zlib.compress(data, level=min(level * 2, 9))
    raise ValueError(f"unknown codec id {codec!r}")


def decompress(blob: bytes) -> bytes:
    """Decompress a tagged blob (or a legacy raw zstd frame)."""
    if not blob:
        raise ValueError("empty blob")
    tag, payload = blob[:1], blob[1:]
    if tag == CODEC_ZSTD or blob[:4] == _ZSTD_MAGIC:
        if not HAVE_ZSTD:
            raise RuntimeError(
                "blob was written with the zstd codec but zstandard is not "
                "installed; install it or re-write the file with zlib"
            )
        data = blob if blob[:4] == _ZSTD_MAGIC else payload
        return zstandard.ZstdDecompressor().decompress(data)
    if tag == CODEC_ZLIB:
        return zlib.decompress(payload)
    raise ValueError(f"unknown codec id {tag!r}")

"""Compression codec for persisted blobs (telemetry flushes, checkpoints).

Optional-dependency policy: ``zstandard`` is the *preferred* codec but must
never be required — offline deployments (and CI) run without it.  Every blob
written through this module is tagged with a **one-byte codec id** so any
reader can open any file regardless of which codecs its environment has:

  * ``0x01`` — zstd-compressed payload (requires ``zstandard`` to read);
  * ``0x02`` — zlib-compressed payload (stdlib, always readable).

Writers pick zstd when the package is importable and fall back to zlib
otherwise.  Legacy blobs from before the codec byte existed are raw zstd
frames (magic ``28 B5 2F FD``); :func:`decompress` detects and handles them
for backward compatibility.
"""

from __future__ import annotations

import zlib

import msgpack
import numpy as np

try:  # optional dependency — never a hard import
    import zstandard  # type: ignore

    HAVE_ZSTD = True
except ImportError:  # pragma: no cover - environment dependent
    zstandard = None  # type: ignore
    HAVE_ZSTD = False

#: one-byte codec ids prepended to every blob
CODEC_ZSTD = b"\x01"
CODEC_ZLIB = b"\x02"

#: magic prefix of a raw (un-tagged, pre-codec-byte) zstd frame
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def default_codec() -> bytes:
    """The codec id a writer should use in this environment."""
    return CODEC_ZSTD if HAVE_ZSTD else CODEC_ZLIB


def compress(data: bytes, level: int = 3, codec: bytes | None = None) -> bytes:
    """Compress ``data`` and prepend the codec id byte.

    ``codec`` forces a specific codec (tests exercise the zlib path even when
    zstandard is installed); by default the best available codec is used.
    """
    codec = default_codec() if codec is None else codec
    if codec == CODEC_ZSTD:
        if not HAVE_ZSTD:
            raise RuntimeError("zstd codec requested but zstandard is not installed")
        return CODEC_ZSTD + zstandard.ZstdCompressor(level=level).compress(data)
    if codec == CODEC_ZLIB:
        return CODEC_ZLIB + zlib.compress(data, level=min(level * 2, 9))
    raise ValueError(f"unknown codec id {codec!r}")


def pack_array(x) -> dict:
    """Lossless wire form of one array: raw bytes + dtype + shape.

    The repo-wide array serialization used by every persisted blob that
    carries tensors (checkpoints, telemetry flushes, cached window results).
    Round-trips **bitwise** — dtype and shape are recorded, never coerced —
    so ``unpack_array(pack_array(x)) == x`` exactly for any numpy array.
    """
    a = np.asarray(x)
    return {"b": a.tobytes(), "d": a.dtype.str, "s": list(a.shape)}


def unpack_array(rec: dict) -> np.ndarray:
    """Inverse of :func:`pack_array` (returns a numpy array)."""
    return np.frombuffer(rec["b"], np.dtype(rec["d"])).reshape(rec["s"])


def dumps(payload, level: int = 3) -> bytes:
    """msgpack-encode ``payload`` and compress it with the codec-id tag.

    The one call every persisted blob in this repo goes through: msgpack for
    structure, :func:`compress` for the optional-zstd policy.  ``payload``
    may contain :func:`pack_array` records for tensors.
    """
    return compress(msgpack.packb(payload, use_bin_type=True), level=level)


def loads(blob: bytes):
    """Inverse of :func:`dumps` (tolerates int map keys, e.g. window ids)."""
    return msgpack.unpackb(decompress(blob), raw=False, strict_map_key=False)


def decompress(blob: bytes) -> bytes:
    """Decompress a tagged blob (or a legacy raw zstd frame)."""
    if not blob:
        raise ValueError("empty blob")
    tag, payload = blob[:1], blob[1:]
    if tag == CODEC_ZSTD or blob[:4] == _ZSTD_MAGIC:
        if not HAVE_ZSTD:
            raise RuntimeError(
                "blob was written with the zstd codec but zstandard is not "
                "installed; install it or re-write the file with zlib"
            )
        data = blob if blob[:4] == _ZSTD_MAGIC else payload
        return zstandard.ZstdDecompressor().decompress(data)
    if tag == CODEC_ZLIB:
        return zlib.decompress(payload)
    raise ValueError(f"unknown codec id {tag!r}")

"""Telemetry ingestion and the data platform (paper components B & D).

The paper's prototype moves telemetry over Kafka and parks it in a
Parquet-on-shared-FS data platform.  In this single-program JAX runtime the
*semantics* that matter are kept (FR1):

  * telemetry arrives **asynchronously** and is **windowed** — records are
    clipped to the window of operation before the simulator sees them;
  * the store is **columnar** and persistent (compressed msgpack columns —
    same role Parquet played in the prototype);
  * consumers (simulator, calibrator, UI) read *consistent snapshots* keyed
    by window index, never a half-written window.

Optional-dependency policy: compression goes through :mod:`repro.core.codec`,
which prefers ``zstandard`` but falls back to stdlib ``zlib`` when it is not
installed — importing this module must never fail on a missing compressor.
Every flushed file starts with a one-byte codec id (``0x01`` zstd, ``0x02``
zlib) so either reader opens either file.
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import threading
from typing import Iterable

import numpy as np

from repro.core import codec
from repro.traces.schema import SAMPLE_SECONDS

#: well-known extras column: measured grid carbon intensity ``[Tw]``
#: (gCO2/kWh) for the window.  When present the orchestrator scores window
#: carbon against this *measured* signal instead of its configured forecast
#: (same precedence reality takes over the model everywhere else).
CARBON_INTENSITY_KEY = "carbon_intensity"

#: well-known extras column: measured electricity spot price ``[Tw]``
#: ($/kWh).  Overrides the orchestrator's configured price forecast when
#: scoring a window's energy cost.
PRICE_KEY = "price"

#: well-known extras column: measured outside-air temperature ``[Tw]``
#: (deg C).  Overrides the configured ambient forecast feeding the
#: dynamic-PUE model when scoring a window.
AMBIENT_KEY = "ambient_c"


@dataclasses.dataclass(frozen=True)
class TelemetryWindow:
    """One window of operation's worth of physical-twin telemetry.

    ``extras`` carries additional aligned ``[Tw]``-leading columns; known
    keys: :data:`CARBON_INTENSITY_KEY` (measured grid carbon intensity,
    gCO2/kWh).  Extras are clipped, persisted and loaded with the window.
    """

    window: int               # window index (lock-step schedule)
    t0_bin: int               # first 5-min bin covered
    u_th: np.ndarray          # [Tw, H] per-host utilization
    power_w: np.ndarray       # [Tw] measured total power draw
    extras: dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def bins(self) -> int:
        return int(self.power_w.shape[0])


def clip_to_window(window: int, bins_per_window: int, t0_bin: int,
                   u_th: np.ndarray, power_w: np.ndarray,
                   **extras: np.ndarray) -> TelemetryWindow:
    """Pre-processing step: clip raw records to the window of operation.

    Telemetry "does not arrive all at once" (paper §2.3) — producers may
    deliver partial or overflowing slices; everything outside
    ``[window*W, (window+1)*W)`` is dropped, gaps are forward-filled.
    """
    w0 = window * bins_per_window
    w1 = w0 + bins_per_window
    lo = max(w0 - t0_bin, 0)
    hi = max(min(w1 - t0_bin, power_w.shape[0]), lo)
    u = u_th[lo:hi]
    p = power_w[lo:hi]
    if p.shape[0] < bins_per_window:  # forward-fill missing tail
        pad = bins_per_window - p.shape[0]
        if p.shape[0] == 0:
            u = np.zeros((bins_per_window,) + u_th.shape[1:], u_th.dtype)
            p = np.zeros((bins_per_window,), power_w.dtype)
        else:
            u = np.concatenate([u, np.repeat(u[-1:], pad, axis=0)])
            p = np.concatenate([p, np.repeat(p[-1:], pad)])
    ex = {k: v[lo:hi] for k, v in extras.items()}
    return TelemetryWindow(window=window, t0_bin=w0, u_th=u, power_w=p, extras=ex)


class TelemetryStore:
    """Columnar, windowed, thread-safe telemetry store.

    Append-only per window; readers get immutable snapshots.  ``flush`` and
    ``load`` persist columns as codec-tagged compressed msgpack (zstd when
    available, zlib otherwise) — inspectable runtime state, like the
    prototype's shared-directory workspace (§3.1).
    """

    def __init__(self, bins_per_window: int,
                 sample_seconds: float = SAMPLE_SECONDS):
        self.bins_per_window = int(bins_per_window)
        self.sample_seconds = float(sample_seconds)
        self._windows: dict[int, TelemetryWindow] = {}
        self._lock = threading.Lock()

    # -- producer side ------------------------------------------------------
    def ingest(self, tw: TelemetryWindow) -> None:
        if tw.bins != self.bins_per_window:
            raise ValueError(
                f"window {tw.window}: got {tw.bins} bins, "
                f"expected {self.bins_per_window} (clip first)"
            )
        with self._lock:
            if tw.window in self._windows:
                raise ValueError(f"window {tw.window} already ingested")
            self._windows[tw.window] = tw

    # -- consumer side ------------------------------------------------------
    def get(self, window: int) -> TelemetryWindow | None:
        with self._lock:
            return self._windows.get(window)

    def latest(self) -> int:
        with self._lock:
            return max(self._windows, default=-1)

    def history(self, upto: int, n: int) -> list[TelemetryWindow]:
        """The last ``n`` complete windows ending at ``upto`` (inclusive)."""
        with self._lock:
            return [self._windows[w] for w in range(max(0, upto - n + 1), upto + 1)
                    if w in self._windows]

    def windows(self) -> Iterable[int]:
        with self._lock:
            return sorted(self._windows)

    # -- persistence --------------------------------------------------------
    def flush(self, path: str) -> None:
        """Persist every window through :mod:`repro.core.codec`.

        Columns are :func:`repro.core.codec.pack_array` records (raw bytes +
        dtype + shape), so the round-trip is **bitwise** — no dtype coercion
        — and the blob obeys the repo-wide optional-zstd policy (one codec-id
        byte, zlib fallback) exactly like checkpoints do.
        """
        cols: dict = {"version": 2,
                      "bins_per_window": self.bins_per_window,
                      "sample_seconds": self.sample_seconds, "windows": {}}
        with self._lock:
            for w, tw in sorted(self._windows.items()):
                cols["windows"][w] = {
                    "t0_bin": tw.t0_bin,
                    "u_th": codec.pack_array(tw.u_th),
                    "power_w": codec.pack_array(tw.power_w),
                    "extras": {k: codec.pack_array(v)
                               for k, v in tw.extras.items()},
                }
        blob = codec.dumps(cols, level=6)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)  # atomic publish

    @classmethod
    def load(cls, path: str) -> "TelemetryStore":
        with open(path, "rb") as f:
            cols = codec.loads(f.read())
        store = cls(cols["bins_per_window"], cols["sample_seconds"])
        legacy = cols.get("version", 1) < 2
        for w, rec in cols["windows"].items():
            if legacy:  # pre-codec columns: ad-hoc bytes with forced dtypes
                u = np.frombuffer(rec["u_th"],
                                  np.float32).reshape(rec["u_shape"])
                p = np.frombuffer(rec["power_w"], np.float64)
                extras = {
                    k: np.frombuffer(v["b"], np.float32).reshape(v["s"])
                    for k, v in rec["extras"].items()
                }
            else:
                u = codec.unpack_array(rec["u_th"])
                p = codec.unpack_array(rec["power_w"])
                extras = {k: codec.unpack_array(v)
                          for k, v in rec["extras"].items()}
            store.ingest(TelemetryWindow(int(w), rec["t0_bin"], u, p, extras))
        return store

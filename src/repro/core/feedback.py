"""SLO-aware feedback with a human-in-the-loop gate (paper stage 3 / comp. I).

The twin emits *proposals* — it never touches the physical twin directly.
Major changes require explicit human approval (the paper keeps automated
steering out of scope; we keep the same boundary but make the interface
first-class so the runtime layer can consume approved proposals).
"""

from __future__ import annotations

import dataclasses
import enum
import math
import time
from typing import TYPE_CHECKING, Callable

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.scenarios import ScenarioSummary


class ProposalKind(enum.Enum):
    RECALIBRATE = "recalibrate"            # minor: applied automatically
    POWER_CAP = "power_cap"                # major: needs approval
    SCALE_DOWN_IDLE = "scale_down_idle"    # major
    SCALE_UP = "scale_up"                  # major
    RESTART_STRAGGLER = "restart_straggler"  # major
    REBALANCE = "rebalance"                # major
    SCHEDULER_CHANGE = "scheduler_change"  # major: swap placement policy
    CARBON_REDUCTION = "carbon_reduction"  # major: cap/shift for lower gCO2
    COST_REDUCTION = "cost_reduction"      # major: cap/shift for lower $ cost
    RESILIENCE = "resilience"              # major: config rides out failures


#: proposal kinds the orchestrator may apply without a human (minor changes)
MINOR = {ProposalKind.RECALIBRATE}


@dataclasses.dataclass
class Proposal:
    kind: ProposalKind
    window: int
    detail: str
    impact: dict = dataclasses.field(default_factory=dict)
    created_at: float = dataclasses.field(default_factory=time.time)
    approved: bool | None = None    # None = pending
    applied: bool = False


class HITLGate:
    """Approval queue between the twin and the physical ICT.

    ``policy`` decides pending proposals when :meth:`drain` runs — the default
    interactive policy leaves everything pending (a human must call
    :meth:`approve`/:meth:`reject`); tests and the closed-loop examples plug
    in auto-policies.
    """

    def __init__(self, policy: Callable[[Proposal], bool | None] | None = None):
        self.policy = policy
        self.queue: list[Proposal] = []
        self.log: list[Proposal] = []

    def submit(self, p: Proposal) -> Proposal:
        if p.kind in MINOR:
            p.approved = True
        self.queue.append(p)
        return p

    def approve(self, idx: int) -> None:
        self.queue[idx].approved = True

    def reject(self, idx: int) -> None:
        self.queue[idx].approved = False

    def pending(self) -> list[Proposal]:
        return [p for p in self.queue if p.approved is None]

    def drain(self) -> list[Proposal]:
        """Resolve with the policy; return newly approved, unapplied ones."""
        out = []
        for p in self.queue:
            if p.approved is None and self.policy is not None:
                p.approved = self.policy(p)
            if p.approved and not p.applied:
                p.applied = True
                out.append(p)
        self.log.extend(out)
        self.queue = [p for p in self.queue if p.approved is None]
        return out


def propose_from_state(window: int, *, mape: float | None,
                       mean_util: float, queue_len: float,
                       power_w: float, power_cap_w: float | None) -> list[Proposal]:
    """Rule set mapping twin state to operator proposals (paper §3.3 insight:
    'under 30 % of the available processing power is used' -> plan better)."""
    out: list[Proposal] = []
    if mape is not None and mape > 10.0:
        out.append(Proposal(
            ProposalKind.RECALIBRATE, window,
            f"window MAPE {mape:.2f}% breaches NFR1 threshold; recalibrate",
            impact={"mape": mape}))
    if mean_util < 0.30 and queue_len < 1:
        out.append(Proposal(
            ProposalKind.SCALE_DOWN_IDLE, window,
            f"mean utilization {mean_util:.1%} with empty queue; "
            "idle hosts could be powered down",
            impact={"mean_util": mean_util}))
    if queue_len > 50:
        out.append(Proposal(
            ProposalKind.SCALE_UP, window,
            f"queue length {queue_len:.0f}; capacity expansion advised",
            impact={"queue_len": queue_len}))
    if power_cap_w is not None and power_w > power_cap_w:
        out.append(Proposal(
            ProposalKind.POWER_CAP, window,
            f"predicted draw {power_w/1e3:.1f} kW exceeds cap "
            f"{power_cap_w/1e3:.1f} kW",
            impact={"power_w": power_w}))
    return out


def propose_from_scenario(
    window: int,
    summary: "ScenarioSummary",
    baseline: "ScenarioSummary",
    *,
    queue_tolerance: float = 1.5,
    min_energy_saving_frac: float = 0.02,
    min_wait_improvement_frac: float = 0.10,
    max_energy_regression_frac: float = 0.02,
    min_carbon_saving_frac: float = 0.02,
    min_cost_saving_frac: float = 0.02,
) -> list[Proposal]:
    """Map a batched what-if candidate's summary to operator proposals.

    The scenario engine (``repro.core.scenarios``) evaluates S candidates
    against the calibrated twin; each candidate that *dominates* the baseline
    on a sustainability metric without breaking SLOs becomes a proposal for
    the HITL gate — the twin recommends, the human decides (paper stage 3).

    Scheduler changes: a candidate on the *same topology* whose placement
    policy or backfill depth differs from the baseline's becomes a
    SCHEDULER_CHANGE proposal when it places at least as many jobs, cuts
    mean queue wait by ``min_wait_improvement_frac`` (or places strictly
    more jobs), and costs at most ``max_energy_regression_frac`` extra
    energy — software-only wins surface before any hardware moves.

    Carbon: when the sweep ran against a grid carbon-intensity trace (both
    ``gco2`` fields finite), a candidate that cuts total gCO2 by at least
    ``min_carbon_saving_frac`` without breaking SLOs becomes a
    CARBON_REDUCTION proposal naming the knob that did it (time shift,
    carbon-aware cap, or topology) — the carbon-driven action the HITL gate
    exists to approve.

    Cost: when the sweep ran against an electricity spot-price trace (both
    ``energy_cost`` fields set), a candidate that cuts the bill by at least
    ``min_cost_saving_frac`` without breaking SLOs becomes a COST_REDUCTION
    proposal — cost and carbon rules fire independently, so a candidate
    that wins on both surfaces twice, each with its own evidence.

    Resilience: a candidate evaluated *under failure windows*
    (``failure_events > 0``) that still meets the baseline's SLOs becomes a
    RESILIENCE proposal — evidence the current configuration rides out the
    modeled outages/drains without operator action.
    """
    out: list[Proposal] = []
    slo_ok = (
        summary.unplaced_jobs <= baseline.unplaced_jobs
        and summary.p99_queue <= max(baseline.p99_queue * queue_tolerance,
                                     baseline.p99_queue + 5.0)
    )
    saving = baseline.energy_kwh - summary.energy_kwh
    if (slo_ok and summary.num_hosts < baseline.num_hosts
            and saving > min_energy_saving_frac * max(baseline.energy_kwh, 1e-9)):
        out.append(Proposal(
            ProposalKind.SCALE_DOWN_IDLE, window,
            f"what-if '{summary.name}': {summary.num_hosts} hosts "
            f"(vs {baseline.num_hosts}) saves {saving:.1f} kWh "
            f"({saving / max(baseline.energy_kwh, 1e-9):.1%}) with "
            f"p99 queue {summary.p99_queue:.0f} and "
            f"{summary.unplaced_jobs} unplaced jobs",
            impact={"scenario": summary.name, "num_hosts": summary.num_hosts,
                    "energy_saving_kwh": saving,
                    "p99_queue": summary.p99_queue}))
    if (summary.num_hosts > baseline.num_hosts
            and baseline.unplaced_jobs > 0
            and summary.unplaced_jobs < baseline.unplaced_jobs):
        out.append(Proposal(
            ProposalKind.SCALE_UP, window,
            f"what-if '{summary.name}': {summary.num_hosts} hosts places "
            f"{baseline.unplaced_jobs - summary.unplaced_jobs} more jobs "
            f"(baseline leaves {baseline.unplaced_jobs} unplaced)",
            impact={"scenario": summary.name, "num_hosts": summary.num_hosts,
                    "unplaced_jobs": summary.unplaced_jobs}))
    same_topology = (summary.num_hosts == baseline.num_hosts
                     and summary.cores_per_host == baseline.cores_per_host)
    scheduler_differs = (summary.policy != baseline.policy
                         or summary.backfill_depth != baseline.backfill_depth)
    if same_topology and scheduler_differs:
        places_more = summary.unplaced_jobs < baseline.unplaced_jobs
        # NaN-safe: a NaN baseline wait (nothing started) never qualifies.
        wait_cut = baseline.mean_wait_bins - summary.mean_wait_bins
        wait_improves = (
            wait_cut > min_wait_improvement_frac
            * max(baseline.mean_wait_bins, 1.0))
        energy_ok = (summary.energy_kwh <= baseline.energy_kwh
                     * (1.0 + max_energy_regression_frac))
        if (summary.unplaced_jobs <= baseline.unplaced_jobs and energy_ok
                and (places_more or wait_improves)):
            out.append(Proposal(
                ProposalKind.SCHEDULER_CHANGE, window,
                f"what-if '{summary.name}': switch scheduler to "
                f"{summary.policy}/backfill={summary.backfill_depth} "
                f"(from {baseline.policy}/backfill={baseline.backfill_depth}): "
                f"mean wait {summary.mean_wait_bins:.1f} bins "
                f"(vs {baseline.mean_wait_bins:.1f}), "
                f"{summary.unplaced_jobs} unplaced "
                f"(vs {baseline.unplaced_jobs}), "
                f"energy {summary.energy_kwh:.1f} kWh "
                f"(vs {baseline.energy_kwh:.1f})",
                impact={"scenario": summary.name, "policy": summary.policy,
                        "backfill_depth": summary.backfill_depth,
                        "mean_wait_bins": summary.mean_wait_bins,
                        "unplaced_jobs": summary.unplaced_jobs,
                        "energy_kwh": summary.energy_kwh}))
    # carbon-driven actions: only comparable when both ran with a trace
    g_base, g_cand = baseline.gco2, summary.gco2
    if (math.isfinite(g_base) and math.isfinite(g_cand) and slo_ok
            and g_base - g_cand > min_carbon_saving_frac * max(g_base, 1e-9)):
        knobs = []
        if summary.shift_bins != baseline.shift_bins:
            knobs.append(f"shift deferrable jobs by {summary.shift_bins} bins")
        if summary.carbon_cap_base_w is not None:
            knobs.append(
                f"carbon-aware cap {summary.carbon_cap_base_w/1e3:.1f} kW "
                f"{summary.carbon_cap_slope:+.1f} W/(gCO2/kWh)")
        if summary.num_hosts != baseline.num_hosts:
            knobs.append(f"{summary.num_hosts} hosts")
        out.append(Proposal(
            ProposalKind.CARBON_REDUCTION, window,
            f"what-if '{summary.name}': {', '.join(knobs) or 'candidate'} "
            f"cuts carbon to {g_cand/1e3:.1f} kgCO2 "
            f"(vs {g_base/1e3:.1f}, -{(g_base - g_cand)/max(g_base,1e-9):.1%}) "
            f"at {summary.energy_kwh:.1f} kWh (vs {baseline.energy_kwh:.1f})",
            impact={"scenario": summary.name,
                    "gco2": g_cand,
                    "gco2_saving": g_base - g_cand,
                    "shift_bins": summary.shift_bins,
                    "carbon_cap_base_w": summary.carbon_cap_base_w,
                    "energy_kwh": summary.energy_kwh}))
    # cost-driven actions: only comparable when both lanes were priced
    c_base, c_cand = baseline.energy_cost, summary.energy_cost
    if (c_base is not None and c_cand is not None
            and math.isfinite(c_base) and math.isfinite(c_cand) and slo_ok
            and c_base - c_cand > min_cost_saving_frac * max(abs(c_base), 1e-9)):
        knobs = []
        if summary.shift_bins != baseline.shift_bins:
            knobs.append(f"shift deferrable jobs by {summary.shift_bins} bins")
        if summary.power_cap_w is not None:
            knobs.append(f"cap {summary.power_cap_w/1e3:.1f} kW")
        if summary.carbon_cap_base_w is not None:
            knobs.append(
                f"carbon-aware cap {summary.carbon_cap_base_w/1e3:.1f} kW "
                f"{summary.carbon_cap_slope:+.1f} W/(gCO2/kWh)")
        if summary.num_hosts != baseline.num_hosts:
            knobs.append(f"{summary.num_hosts} hosts")
        out.append(Proposal(
            ProposalKind.COST_REDUCTION, window,
            f"what-if '{summary.name}': {', '.join(knobs) or 'candidate'} "
            f"cuts energy cost to ${c_cand:.2f} (vs ${c_base:.2f}, "
            f"-{(c_base - c_cand)/max(abs(c_base), 1e-9):.1%}) at "
            f"{summary.energy_kwh:.1f} kWh (vs {baseline.energy_kwh:.1f})",
            impact={"scenario": summary.name,
                    "energy_cost": c_cand,
                    "cost_saving": c_base - c_cand,
                    "shift_bins": summary.shift_bins,
                    "energy_kwh": summary.energy_kwh}))
    # resilience: the candidate was stress-tested under failure windows and
    # still meets the baseline's SLOs — worth surfacing to the operator.
    if summary.failure_events > 0 and slo_ok:
        out.append(Proposal(
            ProposalKind.RESILIENCE, window,
            f"what-if '{summary.name}' rides out {summary.failure_events} "
            f"host failure window(s): {summary.unplaced_jobs} unplaced "
            f"(baseline {baseline.unplaced_jobs}), p99 queue "
            f"{summary.p99_queue:.0f} (baseline {baseline.p99_queue:.0f})",
            impact={"scenario": summary.name,
                    "failure_events": summary.failure_events,
                    "unplaced_jobs": summary.unplaced_jobs,
                    "p99_queue": summary.p99_queue}))
    cap = summary.power_cap_w
    carbon_capped = summary.carbon_cap_base_w is not None
    if ((carbon_capped or (cap is not None and math.isfinite(cap)))
            and summary.cap_exceeded_bins > 0):
        cap_desc = (f"{cap/1e3:.1f} kW" if cap is not None
                    else f"carbon-aware <= {summary.carbon_cap_base_w/1e3:.1f} kW")
        out.append(Proposal(
            ProposalKind.POWER_CAP, window,
            f"what-if '{summary.name}': demand runs into cap {cap_desc} "
            f"in {summary.cap_exceeded_bins} bins "
            f"(peak demand {summary.peak_demand_w/1e3:.1f} kW, "
            f"delivered peak {summary.peak_power_w/1e3:.1f} kW)",
            impact={"scenario": summary.name,
                    "cap_exceeded_bins": summary.cap_exceeded_bins,
                    "peak_power_w": summary.peak_power_w,
                    "peak_demand_w": summary.peak_demand_w}))
    return out


def propose_from_optimum(
    window: int,
    summary: "ScenarioSummary",
    baseline: "ScenarioSummary",
    *,
    objective: float,
    baseline_objective: float,
    breakdown: dict,
    baseline_breakdown: dict,
    **thresholds,
) -> list[Proposal]:
    """Route a *searched* operating point through the proposal rules.

    The scenario optimizer (:mod:`repro.core.optimize`) hands the winning
    candidate here with its scalarized objective breakdown; every proposal
    the ordinary what-if rules emit for it
    (:func:`propose_from_scenario`, ``thresholds`` forwarded) gains the
    search provenance an approver needs: the winner's objective vs the
    baseline's and the per-term breakdown (gCO2, energy, SLO penalties).

    When the searched optimum improves the objective but trips none of the
    threshold-based rules (savings below the per-metric thresholds, or
    spread across several metrics), a CARBON_REDUCTION proposal is emitted
    anyway — the whole point of searching is that the optimizer may land on
    an operating point no single-metric rule would have flagged.  A winner
    identical to the baseline configuration proposes nothing.
    """
    out = propose_from_scenario(window, summary, baseline, **thresholds)
    improved = (math.isfinite(objective)
                and objective < baseline_objective)
    same_config = (
        summary.num_hosts == baseline.num_hosts
        and summary.cores_per_host == baseline.cores_per_host
        and summary.policy == baseline.policy
        and summary.backfill_depth == baseline.backfill_depth
        and summary.shift_bins == baseline.shift_bins
        and summary.power_cap_w == baseline.power_cap_w
        and summary.carbon_cap_base_w == baseline.carbon_cap_base_w
        and summary.carbon_cap_slope == baseline.carbon_cap_slope
        and summary.failure_events == baseline.failure_events)
    if not out and improved and not same_config:
        knobs = []
        if summary.policy != baseline.policy or \
                summary.backfill_depth != baseline.backfill_depth:
            knobs.append(f"scheduler {summary.policy}"
                         f"/backfill={summary.backfill_depth}")
        if summary.num_hosts != baseline.num_hosts:
            knobs.append(f"{summary.num_hosts} hosts")
        if summary.cores_per_host != baseline.cores_per_host:
            knobs.append(f"{summary.cores_per_host} cores/host")
        if summary.shift_bins != baseline.shift_bins:
            knobs.append(f"shift deferrable jobs by {summary.shift_bins} bins")
        if summary.power_cap_w is not None:
            knobs.append(f"cap {summary.power_cap_w/1e3:.1f} kW")
        if summary.carbon_cap_base_w is not None:
            knobs.append(
                f"carbon-aware cap {summary.carbon_cap_base_w/1e3:.1f} kW "
                f"{summary.carbon_cap_slope:+.1f} W/(gCO2/kWh)")
        # pick the kind from the breakdown: a winner whose gain is dollars
        # (cost down, carbon flat or worse) is a COST_REDUCTION; everything
        # else keeps the historical CARBON_REDUCTION label.
        def _gain(key):
            try:
                return (float(baseline_breakdown.get(key))
                        - float(breakdown.get(key)))
            except (TypeError, ValueError):
                return math.nan
        cost_gain = _gain("energy_cost")
        carbon_gain = _gain("gco2_kg")
        kind = (ProposalKind.COST_REDUCTION
                if math.isfinite(cost_gain) and cost_gain > 0
                and (not math.isfinite(carbon_gain) or carbon_gain <= 0)
                else ProposalKind.CARBON_REDUCTION)
        out.append(Proposal(
            kind, window,
            f"searched optimum '{summary.name}': "
            f"{', '.join(knobs) or 'candidate'} "
            f"improves the operating objective to {objective:.3f} "
            f"(vs baseline {baseline_objective:.3f})",
            impact={"scenario": summary.name}))
    for p in out:
        p.impact["objective"] = objective
        p.impact["objective_baseline"] = baseline_objective
        p.impact["objective_breakdown"] = dict(breakdown)
        p.impact["objective_breakdown_baseline"] = dict(baseline_breakdown)
        p.impact["searched_optimum"] = summary.name
    return out

"""SLO-aware feedback with a human-in-the-loop gate (paper stage 3 / comp. I).

The twin emits *proposals* — it never touches the physical twin directly.
Major changes require explicit human approval (the paper keeps automated
steering out of scope; we keep the same boundary but make the interface
first-class so the runtime layer can consume approved proposals).
"""

from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable


class ProposalKind(enum.Enum):
    RECALIBRATE = "recalibrate"            # minor: applied automatically
    POWER_CAP = "power_cap"                # major: needs approval
    SCALE_DOWN_IDLE = "scale_down_idle"    # major
    SCALE_UP = "scale_up"                  # major
    RESTART_STRAGGLER = "restart_straggler"  # major
    REBALANCE = "rebalance"                # major


#: proposal kinds the orchestrator may apply without a human (minor changes)
MINOR = {ProposalKind.RECALIBRATE}


@dataclasses.dataclass
class Proposal:
    kind: ProposalKind
    window: int
    detail: str
    impact: dict = dataclasses.field(default_factory=dict)
    created_at: float = dataclasses.field(default_factory=time.time)
    approved: bool | None = None    # None = pending
    applied: bool = False


class HITLGate:
    """Approval queue between the twin and the physical ICT.

    ``policy`` decides pending proposals when :meth:`drain` runs — the default
    interactive policy leaves everything pending (a human must call
    :meth:`approve`/:meth:`reject`); tests and the closed-loop examples plug
    in auto-policies.
    """

    def __init__(self, policy: Callable[[Proposal], bool | None] | None = None):
        self.policy = policy
        self.queue: list[Proposal] = []
        self.log: list[Proposal] = []

    def submit(self, p: Proposal) -> Proposal:
        if p.kind in MINOR:
            p.approved = True
        self.queue.append(p)
        return p

    def approve(self, idx: int) -> None:
        self.queue[idx].approved = True

    def reject(self, idx: int) -> None:
        self.queue[idx].approved = False

    def pending(self) -> list[Proposal]:
        return [p for p in self.queue if p.approved is None]

    def drain(self) -> list[Proposal]:
        """Resolve with the policy; return newly approved, unapplied ones."""
        out = []
        for p in self.queue:
            if p.approved is None and self.policy is not None:
                p.approved = self.policy(p)
            if p.approved and not p.applied:
                p.applied = True
                out.append(p)
        self.log.extend(out)
        self.queue = [p for p in self.queue if p.approved is None]
        return out


def propose_from_state(window: int, *, mape: float | None,
                       mean_util: float, queue_len: float,
                       power_w: float, power_cap_w: float | None) -> list[Proposal]:
    """Rule set mapping twin state to operator proposals (paper §3.3 insight:
    'under 30 % of the available processing power is used' -> plan better)."""
    out: list[Proposal] = []
    if mape is not None and mape > 10.0:
        out.append(Proposal(
            ProposalKind.RECALIBRATE, window,
            f"window MAPE {mape:.2f}% breaches NFR1 threshold; recalibrate",
            impact={"mape": mape}))
    if mean_util < 0.30 and queue_len < 1:
        out.append(Proposal(
            ProposalKind.SCALE_DOWN_IDLE, window,
            f"mean utilization {mean_util:.1%} with empty queue; "
            "idle hosts could be powered down",
            impact={"mean_util": mean_util}))
    if queue_len > 50:
        out.append(Proposal(
            ProposalKind.SCALE_UP, window,
            f"queue length {queue_len:.0f}; capacity expansion advised",
            impact={"queue_len": queue_len}))
    if power_cap_w is not None and power_w > power_cap_w:
        out.append(Proposal(
            ProposalKind.POWER_CAP, window,
            f"predicted draw {power_w/1e3:.1f} kW exceeds cap "
            f"{power_cap_w/1e3:.1f} kW",
            impact={"power_w": power_w}))
    return out

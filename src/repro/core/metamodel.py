"""Multi-model / Meta-Model simulation (paper §2.2, M3SA [28]).

OpenDT "enables high-complexity techniques that combine individual
simulations, e.g., multi-model simulation that combines the results of
multiple heterogeneous models, simulated independently, to improve accuracy
and quantify fine-grained differences".  This module runs the OpenDC model
zoo (opendc / linear / sqrt / cubic) over the same utilization field and
combines their power predictions.

Combiners: mean, median, and inverse-MAPE weighting (models that tracked
recent telemetry better get more weight — the meta-model alleviates
individual model biases [28]).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.power import POWER_MODELS, PowerParams, datacenter_power, mape

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class MultiModelOutput:
    per_model: dict[str, np.ndarray]   # model name -> [T] power
    combined: np.ndarray               # [T] meta-model power
    weights: dict[str, float]


def run_multi_model(
    u_th: Array,
    params: PowerParams,
    models: tuple[str, ...] = ("opendc", "linear", "sqrt", "cubic"),
) -> dict[str, np.ndarray]:
    return {
        m: np.asarray(datacenter_power(u_th, params, model=m)) for m in models
    }


def combine(
    per_model: dict[str, np.ndarray],
    how: str = "mean",
    reference: np.ndarray | None = None,
) -> MultiModelOutput:
    names = sorted(per_model)
    stack = np.stack([per_model[n] for n in names])    # [M, T]
    if how == "mean":
        weights = {n: 1.0 / len(names) for n in names}
        comb = stack.mean(axis=0)
    elif how == "median":
        weights = {n: float("nan") for n in names}
        comb = np.median(stack, axis=0)
    elif how == "inv_mape":
        if reference is None:
            raise ValueError("inv_mape weighting needs reference telemetry")
        errs = np.array([
            float(mape(jnp.asarray(reference), jnp.asarray(per_model[n])))
            for n in names
        ])
        w = 1.0 / np.maximum(errs, 1e-6)
        w = w / w.sum()
        weights = dict(zip(names, w.tolist()))
        comb = (w[:, None] * stack).sum(axis=0)
    else:
        raise ValueError(f"unknown combiner {how!r}")
    return MultiModelOutput(per_model=per_model, combined=comb, weights=weights)

"""Batched what-if scenario engine (paper Fig. 1, operator loop).

What-if analysis re-simulates the same trace against S candidate
configurations — topologies (host count, cores per host), **placement
policies** (first/best/worst/random-fit, backfill depth), power-model
parameters, power caps, workload perturbations — and compares SLO and
sustainability outcomes before any hardware moves.  The naive loop pays S
trace + compile + run cycles; since the masked DES core
(:func:`repro.core.desim.simulate_utilization_masked`) is shape-identical
across candidates once the host axis is padded to a static ``max_hosts``,
and the scheduler is a *traced* ``policy_id``/``backfill_depth`` pair, the
whole sweep is **one jitted program**: ``jax.vmap`` over a stacked scenario
pytree, one compilation for any S — including (policies x topologies) grids.

Pipeline::

    [Scenario, ...]  --build_scenario_set-->  ScenarioSet (leaves [S, ...])
    ScenarioSet      --run_scenarios------->  SimOutput + Prediction ([S, ...])
    ScenarioSet      --evaluate_scenarios-->  [ScenarioSummary] (host-side)

``Orchestrator.evaluate_whatif`` wires the summaries into SLO-aware
proposals through the HITL gate (``feedback.propose_from_scenario``),
including scheduler-change recommendations.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.desim import (
    POLICY_NAMES,
    Prediction,
    SimOutput,
    resolve_policy,
    simulate_utilization_masked,
)
from repro.core.power import PowerParams, datacenter_power, energy_kwh
from repro.traces.schema import (
    SAMPLE_SECONDS,
    DatacenterConfig,
    Workload,
    host_mask,
)

Array = jax.Array

#: above this many total [S, jobs, bins] elements the batched read-out is
#: chunked over time (see desim._READOUT_BLOCK) — ~128 MB per dense float32
#: intermediate at the threshold, a few of which are live simultaneously.
_BATCH_READOUT_THRESHOLD = 32_000_000


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One what-if candidate.  ``None`` fields inherit the base config.

    Axes:
      * **Topology** — ``num_hosts`` / ``cores_per_host`` (defaults: the base
        :class:`~repro.traces.schema.DatacenterConfig`).
      * **Scheduler** — ``policy`` is a placement-policy name from
        :data:`repro.core.desim.PLACEMENT_POLICIES` (``"first_fit"``,
        ``"best_fit"``, ``"worst_fit"``, ``"random_fit"``; ``None`` means
        worst-fit, the seed scheduler) and ``backfill_depth`` lets up to that
        many queued successors start ahead of a capacity-blocked FCFS head
        (0 = strict head-of-line blocking).  Both become *traced* scalars,
        so a scheduler sweep shares one compilation with a topology sweep.
      * **Power model** — ``p_idle`` / ``p_max`` / ``r`` override the
        calibrated parameters; ``power_cap_w`` flags bins above the cap.
      * **Workload** — multiplicative knobs on the shared base trace:
        ``arrival_scale`` compresses submission times (×k arrival rate),
        ``duration_scale`` stretches runtimes, ``util_scale`` scales the
        per-phase utilization profiles (clipped to [0, 1]).

    >>> Scenario(name="bf", policy="best_fit", backfill_depth=4).policy
    'best_fit'
    >>> Scenario().backfill_depth        # default: strict FCFS worst-fit
    0
    """

    name: str = ""
    num_hosts: int | None = None
    cores_per_host: int | None = None
    policy: str | int | None = None
    backfill_depth: int = 0
    p_idle: float | None = None
    p_max: float | None = None
    r: float | None = None
    power_cap_w: float | None = None
    arrival_scale: float = 1.0
    duration_scale: float = 1.0
    util_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class ScenarioSet:
    """Device-ready stacked scenario batch (every array leaf leads with S).

    Built by :func:`build_scenario_set`; consumed by :func:`run_scenarios`.
    Shapes (``S`` scenarios, ``J`` padded jobs, ``H = max_hosts`` padded
    hosts):

    ======================  ==========================  =====================
    field                   shape / dtype               meaning
    ======================  ==========================  =====================
    ``workload``            leaves ``[S, J, ...]``      per-scenario perturbed
                                                        copies of one base
                                                        trace (padding jobs
                                                        have ``valid=False``)
    ``host_mask_s``         ``[S, H]`` bool             active-host mask;
                                                        padded hosts never run
                                                        jobs or draw power
    ``num_hosts``           ``[S]`` int32               active host count
    ``cores_per_host``      ``[S]`` int32               cores per active host
    ``policy_id``           ``[S]`` int32               placement policy (see
                                                        ``PLACEMENT_POLICIES``)
    ``backfill_depth``      ``[S]`` int32               successors that may
                                                        jump a blocked head
    ``params``              leaves ``[S]`` float32      power-model params
    ``power_cap_w``         ``[S]`` float32             +inf = uncapped
    ``peak_tflops``         ``[S]`` float32             topology peak
    ======================  ==========================  =====================

    ``names`` (tuple of str) and ``max_backfill`` (static int: the compile-
    time backfill window all traced depths are clipped to) are pytree *aux
    data* — part of the jit cache key, not device arrays.  ``max_hosts`` is
    implied by ``host_mask_s.shape[-1]``.
    """

    workload: Workload        # leaves [S, J, ...]
    host_mask_s: Array        # [S, max_hosts] bool
    num_hosts: Array          # [S] int32
    cores_per_host: Array     # [S] int32
    policy_id: Array          # [S] int32
    backfill_depth: Array     # [S] int32
    params: PowerParams       # leaves [S] float32
    power_cap_w: Array        # [S] float32 (+inf = uncapped)
    peak_tflops: Array        # [S] float32
    names: tuple[str, ...]
    max_backfill: int = 0

    @property
    def num_scenarios(self) -> int:
        return len(self.names)

    @property
    def max_hosts(self) -> int:
        return int(self.host_mask_s.shape[-1])


jax.tree_util.register_pytree_node(
    ScenarioSet,
    lambda s: ((s.workload, s.host_mask_s, s.num_hosts, s.cores_per_host,
                s.policy_id, s.backfill_depth, s.params, s.power_cap_w,
                s.peak_tflops), (s.names, s.max_backfill)),
    lambda aux, c: ScenarioSet(*c, names=aux[0], max_backfill=aux[1]),
)


def _perturb(submit: np.ndarray, dur: np.ndarray, util: np.ndarray,
             sc: Scenario) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Apply a scenario's workload knobs (host-side numpy: build-time path)."""
    if sc.arrival_scale != 1.0:
        # ×k arrival rate = submissions land k× denser on the bin axis
        submit = np.floor(
            submit.astype(np.float32) / sc.arrival_scale).astype(np.int32)
    if sc.duration_scale != 1.0:
        dur = np.maximum(
            np.ceil(dur.astype(np.float32) * sc.duration_scale), 1.0
        ).astype(np.int32)
    if sc.util_scale != 1.0:
        util = np.clip(util * sc.util_scale, 0.0, 1.0).astype(np.float32)
    return submit, dur, util


def _scalar(x) -> float:
    """Collapse a scalar-or-per-host power parameter to one scalar."""
    return float(np.mean(np.asarray(x)))


def build_scenario_set(
    workload: Workload,
    dc: DatacenterConfig,
    scenarios: "list[Scenario] | tuple[Scenario, ...]",
    base_params: PowerParams = PowerParams(),
    max_hosts: int | None = None,
) -> ScenarioSet:
    """Stack S candidate configurations against one base trace/topology.

    Host-side (numpy) assembly: each :class:`Scenario`'s knobs are resolved
    against the base ``dc``/``base_params``, workload perturbations are
    applied to copies of the base trace, and everything is stacked into a
    device-ready :class:`ScenarioSet` whose array leaves lead with the
    scenario axis ``[S, ...]``.

    Padding semantics: the host axis is padded to ``max_hosts`` (default:
    the largest candidate host count — pass it explicitly to pin one
    compilation cache key across sweeps of different candidate mixes) and
    per-scenario activity is recorded in ``host_mask_s``; padded hosts never
    receive jobs, contribute no utilization and draw no power.  Per-host
    power parameters are collapsed to scalars on this path (see ROADMAP).
    The static backfill window ``max_backfill`` is the max candidate depth,
    so depth-0 sweeps compile the backfill machinery out entirely.

    Raises ``ValueError`` on an empty scenario list or a candidate wanting
    more hosts than ``max_hosts``.
    """
    if not scenarios:
        raise ValueError("need at least one scenario")
    hosts = [sc.num_hosts if sc.num_hosts is not None else dc.num_hosts
             for sc in scenarios]
    mh = max(hosts) if max_hosts is None else int(max_hosts)
    if max(hosts) > mh:
        raise ValueError(f"scenario wants {max(hosts)} hosts > max_hosts={mh}")

    cores = [sc.cores_per_host if sc.cores_per_host is not None
             else dc.cores_per_host for sc in scenarios]
    names = tuple(sc.name or f"s{i}" for i, sc in enumerate(scenarios))

    # Every scenario perturbs the same base trace, so the stacked workload is
    # assembled host-side in numpy (one device transfer per field) — this
    # runs on every sweep and must not cost a per-scenario dispatch cascade.
    s_count, n_jobs = len(scenarios), workload.num_jobs
    base_sub = np.asarray(workload.submit_bin)
    base_dur = np.asarray(workload.duration_bins)
    base_util = np.asarray(workload.util_levels)
    perturbed = [_perturb(base_sub, base_dur, base_util, sc)
                 for sc in scenarios]
    wl = Workload(
        submit_bin=jnp.asarray(np.stack([p[0] for p in perturbed])),
        duration_bins=jnp.asarray(np.stack([p[1] for p in perturbed])),
        cores=jnp.asarray(np.broadcast_to(
            np.asarray(workload.cores), (s_count, n_jobs))),
        util_levels=jnp.asarray(np.stack([p[2] for p in perturbed])),
        valid=jnp.asarray(np.broadcast_to(
            np.asarray(workload.valid), (s_count, n_jobs))),
    )

    def pick(field: str):
        base = _scalar(getattr(base_params, field))
        return jnp.asarray(
            [getattr(sc, field) if getattr(sc, field) is not None else base
             for sc in scenarios], jnp.float32)

    hosts_a = jnp.asarray(hosts, jnp.int32)
    cores_a = jnp.asarray(cores, jnp.int32)
    depths = [max(int(sc.backfill_depth), 0) for sc in scenarios]
    if max(depths) > 31:
        # the DES skip bitmask is uint32 — reject rather than silently
        # mis-schedule (simulate_utilization_masked enforces the same bound)
        raise ValueError(
            f"backfill_depth {max(depths)} > 31 (uint32 skip-mask width)")
    peak = jnp.asarray(
        [dataclasses.replace(dc, num_hosts=h, cores_per_host=c).peak_tflops
         for h, c in zip(hosts, cores)], jnp.float32)
    cap = jnp.asarray(
        [sc.power_cap_w if sc.power_cap_w is not None else math.inf
         for sc in scenarios], jnp.float32)
    return ScenarioSet(
        workload=wl,
        host_mask_s=host_mask(hosts_a, mh),
        num_hosts=hosts_a,
        cores_per_host=cores_a,
        policy_id=jnp.asarray([resolve_policy(sc.policy) for sc in scenarios],
                              jnp.int32),
        backfill_depth=jnp.asarray(depths, jnp.int32),
        params=PowerParams(p_idle=pick("p_idle"), p_max=pick("p_max"),
                           r=pick("r")),
        power_cap_w=cap,
        peak_tflops=peak,
        names=names,
        max_backfill=max(depths),
    )


def _predict_masked(u_th: Array, params: PowerParams, mask: Array,
                    peak_tflops: Array, model: str) -> Prediction:
    """Mask-aware :func:`repro.core.desim.predict_metrics` for one scenario.

    Padded (inactive) hosts must not dilute mean utilization or draw idle
    power, so both aggregations respect the active-host mask.
    """
    maskf = mask.astype(u_th.dtype)
    power = datacenter_power(u_th, params, model=model, online_mask=maskf)
    e = energy_kwh(power, SAMPLE_SECONDS)
    util = jnp.sum(u_th * maskf, axis=-1) / jnp.maximum(jnp.sum(maskf), 1.0)
    tflops = util * peak_tflops
    eff = tflops / jnp.maximum(e, 1e-9)
    return Prediction(power_w=power, energy_kwh=e, tflops=tflops,
                      utilization=util, efficiency=eff)


@functools.partial(jax.jit, static_argnames=("max_hosts", "t_bins",
                                             "max_starts_per_bin", "model"))
def _run_scenarios_jit(
    ss: ScenarioSet,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int,
    model: str,
) -> tuple[SimOutput, Prediction]:
    # the DES core's own readout bound is per-scenario; under the scenario
    # vmap every intermediate gains the S axis, so the bound must include S
    # (workload leaves are [S, J]: take J from the trailing axis).
    n_jobs = int(ss.workload.submit_bin.shape[-1])
    chunk = ss.num_scenarios * n_jobs * t_bins > _BATCH_READOUT_THRESHOLD

    def one(w, mask, cores, policy_id, backfill_depth, params, peak):
        sim = simulate_utilization_masked(
            w, mask, cores,
            max_hosts=max_hosts, t_bins=t_bins,
            max_starts_per_bin=max_starts_per_bin,
            policy_id=policy_id, backfill_depth=backfill_depth,
            max_backfill=ss.max_backfill,   # static aux, uniform over S
            force_chunked_readout=chunk,
        )
        pred = _predict_masked(sim.u_th, params, mask, peak, model)
        return sim, pred

    return jax.vmap(one)(ss.workload, ss.host_mask_s, ss.cores_per_host,
                         ss.policy_id, ss.backfill_depth,
                         ss.params, ss.peak_tflops)


def run_scenarios(
    ss: ScenarioSet,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
    model: str = "opendc",
) -> tuple[SimOutput, Prediction]:
    """Simulate + predict all S scenarios in one jitted program.

    Returns a batched :class:`SimOutput` and :class:`Prediction` whose array
    leaves lead with the scenario axis: ``sim.u_th`` is
    ``[S, t_bins, max_hosts]`` (padded hosts read 0), ``sim.job_start`` /
    ``sim.job_host`` are ``[S, J]`` (-1 = never started), and every
    :class:`~repro.core.desim.Prediction` leaf is ``[S, t_bins]``.

    One compilation covers any scenario batch with the same
    ``(S, max_hosts, t_bins, J, max_backfill)`` shape — the sequential
    what-if loop's per-candidate retrace/recompile is gone, and because the
    placement policy is a traced ``[S]`` axis, scheduler sweeps ride the
    same program as topology sweeps.  Scenario *names* are pytree aux data
    (part of the jit cache key), so they are anonymized before entering jit
    — differently-named sweeps of the same shape share one compilation.
    """
    anon = dataclasses.replace(ss, names=("",) * ss.num_scenarios)
    return _run_scenarios_jit(
        anon, max_hosts=max_hosts, t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin, model=model,
    )


# surfaced for the single-compilation regression test; `_cache_size` is
# private jax API, so its absence must degrade to None, not an import error
run_scenarios._cache_size = getattr(_run_scenarios_jit, "_cache_size", None)


@dataclasses.dataclass(frozen=True)
class ScenarioSummary:
    """Host-side per-scenario read-out an operator (or the HITL gate) compares.

    Scheduler provenance and outcome travel together: ``policy`` /
    ``backfill_depth`` identify the placement policy the scenario ran,
    ``mean_wait_bins`` / ``p99_wait_bins`` are queue-wait statistics
    (``job_start - submit`` in 5-minute bins, over jobs that started; NaN if
    nothing started) and ``unplaced_jobs`` counts valid jobs that never
    started inside the horizon — the fields
    :func:`repro.core.feedback.propose_from_scenario` needs to recommend a
    scheduler change on wait/placement grounds against an energy budget.

    ``kwh_per_cpu_hour`` is NaN when the scenario's workload has zero CPU-hours
    — an empty trace is surfaced, never hidden behind a clamped denominator.
    """

    name: str
    num_hosts: int
    cores_per_host: int
    policy: str
    backfill_depth: int
    mean_util: float
    p99_queue: float
    max_queue: int
    mean_wait_bins: float
    p99_wait_bins: float
    unplaced_jobs: int
    total_jobs: int
    energy_kwh: float
    mean_power_w: float
    peak_power_w: float
    cpu_hours: float
    kwh_per_cpu_hour: float
    power_cap_w: float | None
    cap_exceeded_bins: int


def summarize_scenarios(
    ss: ScenarioSet, sim: SimOutput, pred: Prediction
) -> list[ScenarioSummary]:
    """Collapse batched outputs into one comparable record per scenario."""
    util = np.asarray(pred.utilization)        # [S, T] (mask-aware)
    queue = np.asarray(sim.queue_len)          # [S, T]
    start = np.asarray(sim.job_start)          # [S, J]
    submit = np.asarray(ss.workload.submit_bin)  # [S, J] (post-perturbation)
    valid = np.asarray(ss.workload.valid)      # [S, J]
    power = np.asarray(pred.power_w)           # [S, T]
    energy = np.asarray(pred.energy_kwh)       # [S, T]
    cap = np.asarray(ss.power_cap_w)           # [S]
    policy = np.asarray(ss.policy_id)          # [S]
    depth = np.asarray(ss.backfill_depth)      # [S]
    cpu_h = np.asarray(
        jax.vmap(lambda w: jnp.sum(w.cpu_hours()))(ss.workload))

    out = []
    for s, name in enumerate(ss.names):
        ch = float(cpu_h[s])
        ekwh = float(energy[s].sum())
        placed = (start[s] >= 0) & valid[s]
        waits = (start[s] - submit[s])[placed]
        out.append(ScenarioSummary(
            name=name,
            num_hosts=int(ss.num_hosts[s]),
            cores_per_host=int(ss.cores_per_host[s]),
            policy=POLICY_NAMES[int(policy[s])],
            backfill_depth=int(depth[s]),
            mean_wait_bins=(float(waits.mean()) if waits.size
                            else float("nan")),
            p99_wait_bins=(float(np.percentile(waits, 99)) if waits.size
                           else float("nan")),
            mean_util=float(util[s].mean()),
            p99_queue=float(np.percentile(queue[s], 99)),
            max_queue=int(queue[s].max()),
            unplaced_jobs=int(((start[s] < 0) & valid[s]).sum()),
            total_jobs=int(valid[s].sum()),
            energy_kwh=ekwh,
            mean_power_w=float(power[s].mean()),
            peak_power_w=float(power[s].max()),
            cpu_hours=ch,
            kwh_per_cpu_hour=(ekwh / ch) if ch > 0 else float("nan"),
            power_cap_w=None if np.isinf(cap[s]) else float(cap[s]),
            cap_exceeded_bins=int((power[s] > cap[s]).sum()),
        ))
    return out


def evaluate_scenarios(
    workload: Workload,
    dc: DatacenterConfig,
    scenarios: "list[Scenario] | tuple[Scenario, ...]",
    *,
    t_bins: int,
    base_params: PowerParams = PowerParams(),
    max_hosts: int | None = None,
    model: str = "opendc",
    max_starts_per_bin: int = 64,
) -> tuple[ScenarioSet, SimOutput, Prediction, list[ScenarioSummary]]:
    """End-to-end what-if sweep: build, batch-simulate, summarize.

    Convenience wrapper over :func:`build_scenario_set` ->
    :func:`run_scenarios` -> :func:`summarize_scenarios`; returns all four
    artifacts (the device-side batch plus host-side summaries) so callers
    can both rank candidates and drill into per-bin fields.  ``scenarios``
    may sweep any :class:`Scenario` axis — topology, placement policy,
    backfill depth, power model, caps, workload scaling — and the whole
    sweep still compiles once per ``(S, max_hosts, t_bins, J, max_backfill)``
    shape.
    """
    ss = build_scenario_set(workload, dc, scenarios, base_params,
                            max_hosts=max_hosts)
    sim, pred = run_scenarios(
        ss, max_hosts=ss.max_hosts, t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin, model=model,
    )
    return ss, sim, pred, summarize_scenarios(ss, sim, pred)

"""Batched what-if scenario engine (paper Fig. 1, operator loop).

What-if analysis re-simulates the same trace against S candidate
configurations — topologies (host count, cores per host), power-model
parameters, power caps, workload perturbations — and compares SLO and
sustainability outcomes before any hardware moves.  The naive loop pays S
trace + compile + run cycles; since the masked DES core
(:func:`repro.core.desim.simulate_utilization_masked`) is shape-identical
across candidates once the host axis is padded to a static ``max_hosts``,
the whole sweep is **one jitted program**: ``jax.vmap`` over a stacked
scenario pytree, one compilation for any S.

Pipeline::

    [Scenario, ...]  --build_scenario_set-->  ScenarioSet (leaves [S, ...])
    ScenarioSet      --run_scenarios------->  SimOutput + Prediction ([S, ...])
    ScenarioSet      --evaluate_scenarios-->  [ScenarioSummary] (host-side)

``Orchestrator.evaluate_whatif`` wires the summaries into SLO-aware
proposals through the HITL gate (``feedback.propose_from_scenario``).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.desim import (
    Prediction,
    SimOutput,
    simulate_utilization_masked,
)
from repro.core.power import PowerParams, datacenter_power, energy_kwh
from repro.traces.schema import (
    SAMPLE_SECONDS,
    DatacenterConfig,
    Workload,
    host_mask,
)

Array = jax.Array

#: above this many total [S, jobs, bins] elements the batched read-out is
#: chunked over time (see desim._READOUT_BLOCK) — ~128 MB per dense float32
#: intermediate at the threshold, a few of which are live simultaneously.
_BATCH_READOUT_THRESHOLD = 32_000_000


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One what-if candidate.  ``None`` fields inherit the base config.

    Workload perturbations are multiplicative knobs on the shared base trace:
    ``arrival_scale`` compresses submission times (×k arrival rate),
    ``duration_scale`` stretches runtimes, ``util_scale`` scales the
    per-phase utilization profiles (clipped to [0, 1]).
    """

    name: str = ""
    num_hosts: int | None = None
    cores_per_host: int | None = None
    p_idle: float | None = None
    p_max: float | None = None
    r: float | None = None
    power_cap_w: float | None = None
    arrival_scale: float = 1.0
    duration_scale: float = 1.0
    util_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class ScenarioSet:
    """Device-ready stacked scenario batch (every array leaf leads with S).

    ``max_hosts`` is the static padded host axis; per-scenario activity is
    ``host_mask_s``.  ``names`` is aux data (static across jit).
    """

    workload: Workload        # leaves [S, J, ...]
    host_mask_s: Array        # [S, max_hosts] bool
    num_hosts: Array          # [S] int32
    cores_per_host: Array     # [S] int32
    params: PowerParams       # leaves [S] float32
    power_cap_w: Array        # [S] float32 (+inf = uncapped)
    peak_tflops: Array        # [S] float32
    names: tuple[str, ...]

    @property
    def num_scenarios(self) -> int:
        return len(self.names)

    @property
    def max_hosts(self) -> int:
        return int(self.host_mask_s.shape[-1])


jax.tree_util.register_pytree_node(
    ScenarioSet,
    lambda s: ((s.workload, s.host_mask_s, s.num_hosts, s.cores_per_host,
                s.params, s.power_cap_w, s.peak_tflops), s.names),
    lambda names, c: ScenarioSet(*c, names=names),
)


def _perturb(submit: np.ndarray, dur: np.ndarray, util: np.ndarray,
             sc: Scenario) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Apply a scenario's workload knobs (host-side numpy: build-time path)."""
    if sc.arrival_scale != 1.0:
        # ×k arrival rate = submissions land k× denser on the bin axis
        submit = np.floor(
            submit.astype(np.float32) / sc.arrival_scale).astype(np.int32)
    if sc.duration_scale != 1.0:
        dur = np.maximum(
            np.ceil(dur.astype(np.float32) * sc.duration_scale), 1.0
        ).astype(np.int32)
    if sc.util_scale != 1.0:
        util = np.clip(util * sc.util_scale, 0.0, 1.0).astype(np.float32)
    return submit, dur, util


def _scalar(x) -> float:
    """Collapse a scalar-or-per-host power parameter to one scalar."""
    return float(np.mean(np.asarray(x)))


def build_scenario_set(
    workload: Workload,
    dc: DatacenterConfig,
    scenarios: "list[Scenario] | tuple[Scenario, ...]",
    base_params: PowerParams = PowerParams(),
    max_hosts: int | None = None,
) -> ScenarioSet:
    """Stack S candidate configurations against one base trace/topology.

    ``max_hosts`` defaults to the largest candidate host count; pass it
    explicitly to pin a compilation cache key across sweeps of different
    candidate mixes.
    """
    if not scenarios:
        raise ValueError("need at least one scenario")
    hosts = [sc.num_hosts if sc.num_hosts is not None else dc.num_hosts
             for sc in scenarios]
    mh = max(hosts) if max_hosts is None else int(max_hosts)
    if max(hosts) > mh:
        raise ValueError(f"scenario wants {max(hosts)} hosts > max_hosts={mh}")

    cores = [sc.cores_per_host if sc.cores_per_host is not None
             else dc.cores_per_host for sc in scenarios]
    names = tuple(sc.name or f"s{i}" for i, sc in enumerate(scenarios))

    # Every scenario perturbs the same base trace, so the stacked workload is
    # assembled host-side in numpy (one device transfer per field) — this
    # runs on every sweep and must not cost a per-scenario dispatch cascade.
    s_count, n_jobs = len(scenarios), workload.num_jobs
    base_sub = np.asarray(workload.submit_bin)
    base_dur = np.asarray(workload.duration_bins)
    base_util = np.asarray(workload.util_levels)
    perturbed = [_perturb(base_sub, base_dur, base_util, sc)
                 for sc in scenarios]
    wl = Workload(
        submit_bin=jnp.asarray(np.stack([p[0] for p in perturbed])),
        duration_bins=jnp.asarray(np.stack([p[1] for p in perturbed])),
        cores=jnp.asarray(np.broadcast_to(
            np.asarray(workload.cores), (s_count, n_jobs))),
        util_levels=jnp.asarray(np.stack([p[2] for p in perturbed])),
        valid=jnp.asarray(np.broadcast_to(
            np.asarray(workload.valid), (s_count, n_jobs))),
    )

    def pick(field: str):
        base = _scalar(getattr(base_params, field))
        return jnp.asarray(
            [getattr(sc, field) if getattr(sc, field) is not None else base
             for sc in scenarios], jnp.float32)

    hosts_a = jnp.asarray(hosts, jnp.int32)
    cores_a = jnp.asarray(cores, jnp.int32)
    peak = jnp.asarray(
        [dataclasses.replace(dc, num_hosts=h, cores_per_host=c).peak_tflops
         for h, c in zip(hosts, cores)], jnp.float32)
    cap = jnp.asarray(
        [sc.power_cap_w if sc.power_cap_w is not None else math.inf
         for sc in scenarios], jnp.float32)
    return ScenarioSet(
        workload=wl,
        host_mask_s=host_mask(hosts_a, mh),
        num_hosts=hosts_a,
        cores_per_host=cores_a,
        params=PowerParams(p_idle=pick("p_idle"), p_max=pick("p_max"),
                           r=pick("r")),
        power_cap_w=cap,
        peak_tflops=peak,
        names=names,
    )


def _predict_masked(u_th: Array, params: PowerParams, mask: Array,
                    peak_tflops: Array, model: str) -> Prediction:
    """Mask-aware :func:`repro.core.desim.predict_metrics` for one scenario.

    Padded (inactive) hosts must not dilute mean utilization or draw idle
    power, so both aggregations respect the active-host mask.
    """
    maskf = mask.astype(u_th.dtype)
    power = datacenter_power(u_th, params, model=model, online_mask=maskf)
    e = energy_kwh(power, SAMPLE_SECONDS)
    util = jnp.sum(u_th * maskf, axis=-1) / jnp.maximum(jnp.sum(maskf), 1.0)
    tflops = util * peak_tflops
    eff = tflops / jnp.maximum(e, 1e-9)
    return Prediction(power_w=power, energy_kwh=e, tflops=tflops,
                      utilization=util, efficiency=eff)


@functools.partial(jax.jit, static_argnames=("max_hosts", "t_bins",
                                             "max_starts_per_bin", "model"))
def _run_scenarios_jit(
    ss: ScenarioSet,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int,
    model: str,
) -> tuple[SimOutput, Prediction]:
    # the DES core's own readout bound is per-scenario; under the scenario
    # vmap every intermediate gains the S axis, so the bound must include S
    # (workload leaves are [S, J]: take J from the trailing axis).
    n_jobs = int(ss.workload.submit_bin.shape[-1])
    chunk = ss.num_scenarios * n_jobs * t_bins > _BATCH_READOUT_THRESHOLD

    def one(w, mask, cores, params, peak):
        sim = simulate_utilization_masked(
            w, mask, cores,
            max_hosts=max_hosts, t_bins=t_bins,
            max_starts_per_bin=max_starts_per_bin,
            force_chunked_readout=chunk,
        )
        pred = _predict_masked(sim.u_th, params, mask, peak, model)
        return sim, pred

    return jax.vmap(one)(ss.workload, ss.host_mask_s, ss.cores_per_host,
                         ss.params, ss.peak_tflops)


def run_scenarios(
    ss: ScenarioSet,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
    model: str = "opendc",
) -> tuple[SimOutput, Prediction]:
    """Simulate + predict all S scenarios in one jitted program.

    Returns a batched :class:`SimOutput` and :class:`Prediction` whose leaves
    lead with the scenario axis.  One compilation covers any scenario batch
    with the same ``(S, max_hosts, t_bins, J)`` shape — the sequential
    what-if loop's per-candidate retrace/recompile is gone.  Scenario
    *names* are pytree aux data (part of the jit cache key), so they are
    anonymized before entering jit — differently-named sweeps of the same
    shape share one compilation.
    """
    anon = dataclasses.replace(ss, names=("",) * ss.num_scenarios)
    return _run_scenarios_jit(
        anon, max_hosts=max_hosts, t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin, model=model,
    )


# surfaced for the single-compilation regression test; `_cache_size` is
# private jax API, so its absence must degrade to None, not an import error
run_scenarios._cache_size = getattr(_run_scenarios_jit, "_cache_size", None)


@dataclasses.dataclass(frozen=True)
class ScenarioSummary:
    """Host-side per-scenario read-out an operator (or the HITL gate) compares.

    ``kwh_per_cpu_hour`` is NaN when the scenario's workload has zero CPU-hours
    — an empty trace is surfaced, never hidden behind a clamped denominator.
    """

    name: str
    num_hosts: int
    cores_per_host: int
    mean_util: float
    p99_queue: float
    max_queue: int
    unplaced_jobs: int
    total_jobs: int
    energy_kwh: float
    mean_power_w: float
    peak_power_w: float
    cpu_hours: float
    kwh_per_cpu_hour: float
    power_cap_w: float | None
    cap_exceeded_bins: int


def summarize_scenarios(
    ss: ScenarioSet, sim: SimOutput, pred: Prediction
) -> list[ScenarioSummary]:
    """Collapse batched outputs into one comparable record per scenario."""
    util = np.asarray(pred.utilization)        # [S, T] (mask-aware)
    queue = np.asarray(sim.queue_len)          # [S, T]
    start = np.asarray(sim.job_start)          # [S, J]
    valid = np.asarray(ss.workload.valid)      # [S, J]
    power = np.asarray(pred.power_w)           # [S, T]
    energy = np.asarray(pred.energy_kwh)       # [S, T]
    cap = np.asarray(ss.power_cap_w)           # [S]
    cpu_h = np.asarray(
        jax.vmap(lambda w: jnp.sum(w.cpu_hours()))(ss.workload))

    out = []
    for s, name in enumerate(ss.names):
        ch = float(cpu_h[s])
        ekwh = float(energy[s].sum())
        out.append(ScenarioSummary(
            name=name,
            num_hosts=int(ss.num_hosts[s]),
            cores_per_host=int(ss.cores_per_host[s]),
            mean_util=float(util[s].mean()),
            p99_queue=float(np.percentile(queue[s], 99)),
            max_queue=int(queue[s].max()),
            unplaced_jobs=int(((start[s] < 0) & valid[s]).sum()),
            total_jobs=int(valid[s].sum()),
            energy_kwh=ekwh,
            mean_power_w=float(power[s].mean()),
            peak_power_w=float(power[s].max()),
            cpu_hours=ch,
            kwh_per_cpu_hour=(ekwh / ch) if ch > 0 else float("nan"),
            power_cap_w=None if np.isinf(cap[s]) else float(cap[s]),
            cap_exceeded_bins=int((power[s] > cap[s]).sum()),
        ))
    return out


def evaluate_scenarios(
    workload: Workload,
    dc: DatacenterConfig,
    scenarios: "list[Scenario] | tuple[Scenario, ...]",
    *,
    t_bins: int,
    base_params: PowerParams = PowerParams(),
    max_hosts: int | None = None,
    model: str = "opendc",
    max_starts_per_bin: int = 64,
) -> tuple[ScenarioSet, SimOutput, Prediction, list[ScenarioSummary]]:
    """End-to-end what-if sweep: build, batch-simulate, summarize."""
    ss = build_scenario_set(workload, dc, scenarios, base_params,
                            max_hosts=max_hosts)
    sim, pred = run_scenarios(
        ss, max_hosts=ss.max_hosts, t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin, model=model,
    )
    return ss, sim, pred, summarize_scenarios(ss, sim, pred)

"""Batched what-if scenario engine (paper Fig. 1, operator loop).

What-if analysis re-simulates the same trace against S candidate
configurations — topologies (host count, cores per host), **placement
policies** (first/best/worst/random-fit, backfill depth), power-model
parameters, **enforced power caps** (static and carbon-aware,
``cap_t = base + slope * intensity_t``), workload perturbations including
**deferrable-job time-shifting** — and compares SLO and sustainability
outcomes (energy, power, **gCO2** against a grid carbon-intensity trace)
before any hardware moves.  The naive loop pays S
trace + compile + run cycles; since the masked DES core
(:func:`repro.core.desim.simulate_utilization_masked`) is shape-identical
across candidates once the host axis is padded to a static ``max_hosts``,
and the scheduler is a *traced* ``policy_id``/``backfill_depth`` pair, the
whole sweep is **one jitted program**: ``jax.vmap`` over a stacked scenario
pytree, one compilation for any S — including (policies x topologies) grids.

Pipeline::

    [Scenario, ...]  --build_scenario_set-->  ScenarioSet (leaves [S, ...])
    ScenarioSet      --run_scenarios------->  SimOutput + Prediction ([S, ...])
    ScenarioSet      --evaluate_scenarios-->  [ScenarioSummary] (host-side)

``Orchestrator.evaluate_whatif`` wires the summaries into SLO-aware
proposals through the HITL gate (``feedback.propose_from_scenario``),
including scheduler-change recommendations.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.desim import (
    POLICY_NAMES,
    Prediction,
    SimOutput,
    resolve_policy,
    simulate_utilization_masked,
)
from repro.core.power import (
    PowerParams,
    carbon_gco2,
    datacenter_power,
    energy_kwh,
)
from repro.traces.carbon import validate_carbon_intensity
from repro.traces.schema import (
    SAMPLE_SECONDS,
    DatacenterConfig,
    Workload,
    host_mask,
)

Array = jax.Array

#: above this many total [S, jobs, bins] elements the batched read-out is
#: chunked over time (see desim._READOUT_BLOCK) — ~128 MB per dense float32
#: intermediate at the threshold, a few of which are live simultaneously.
_BATCH_READOUT_THRESHOLD = 32_000_000


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One what-if candidate.  ``None`` fields inherit the base config.

    Axes:
      * **Topology** — ``num_hosts`` / ``cores_per_host`` (defaults: the base
        :class:`~repro.traces.schema.DatacenterConfig`).
      * **Scheduler** — ``policy`` is a placement-policy name from
        :data:`repro.core.desim.PLACEMENT_POLICIES` (``"first_fit"``,
        ``"best_fit"``, ``"worst_fit"``, ``"random_fit"``; ``None`` means
        worst-fit, the seed scheduler) and ``backfill_depth`` lets up to that
        many queued successors start ahead of a capacity-blocked FCFS head
        (0 = strict head-of-line blocking).  Both become *traced* scalars,
        so a scheduler sweep shares one compilation with a topology sweep.
      * **Power model** — ``p_idle`` / ``p_max`` / ``r`` override the
        calibrated parameters.  Invalid overrides (``r <= 0``,
        ``p_max < p_idle``) raise at construction — they would otherwise
        produce negative watts (see ``power.validate_power_params``).
      * **Power cap** — ``power_cap_w`` is a static facility cap, now
        *enforced* in the read-out (delivered power is clipped to the cap
        and performance metrics are throttled accordingly, not merely
        flagged); ``carbon_cap_base_w``/``carbon_cap_slope`` add a
        carbon-aware cap ``base + slope * intensity_t`` (slope in W per
        gCO2/kWh, usually negative: dirtier grid -> tighter cap).  The
        effective per-bin cap is the minimum of the two.  Carbon-aware caps
        require a ``carbon_intensity`` trace at run time.
      * **Workload** — multiplicative knobs on the shared base trace:
        ``arrival_scale`` compresses submission times (×k arrival rate),
        ``duration_scale`` stretches runtimes, ``util_scale`` scales the
        per-phase utilization profiles (clipped to [0, 1]), and
        ``shift_bins`` time-shifts *deferrable* jobs (see
        ``Workload.deferrable``; default: all jobs) by that many 5-minute
        bins — positive delays work into later (e.g. cleaner-grid) bins.
      * **Failures** — ``failures`` is a tuple of
        :class:`repro.runtime.fault.HostFailure` windows: during
        ``[start_bin, end_bin)`` the host accepts no placements; an
        ``"outage"`` additionally kills its running jobs (cores return at
        ``end_bin``) and draws no power, a ``"degraded"`` host drains.
        One window per host; windows must start inside the horizon
        (checked at :func:`run_scenarios`, where ``t_bins`` is known).
      * **Dynamic PUE** — ``pue_base`` (>= 1) switches the cooling model
        on: facility power becomes IT power times
        ``pue_base + pue_amb_coeff * max(ambient_t - pue_amb_ref, 0)
        + pue_load_coeff * (1 - util_t)`` (see
        :func:`repro.traces.thermal.dynamic_pue`).  Caps, energy, gCO2
        and cost then price the cooling overhead.  Coefficients without
        ``pue_base`` are rejected — a silent half-enabled axis.

    All knobs stack into ``[S]`` (or ``[S, H]``) tensors or per-scenario
    workload copies of identical shape, so a (failures × PUE × caps ×
    shifts × topologies) grid still compiles **once** (see
    :func:`run_scenarios`).

    >>> Scenario(name="bf", policy="best_fit", backfill_depth=4).policy
    'best_fit'
    >>> Scenario().backfill_depth        # default: strict FCFS worst-fit
    0
    >>> Scenario(r=0.0)
    Traceback (most recent call last):
        ...
    ValueError: scenario '': power-model exponent r must be > 0, got 0.0
    >>> Scenario(backfill_depth=40)
    Traceback (most recent call last):
        ...
    ValueError: scenario '': backfill_depth must be in [0, 31] (uint32 skip-mask width), got 40
    >>> Scenario(pue_base=0.9)
    Traceback (most recent call last):
        ...
    ValueError: scenario '': pue_base must be finite and >= 1 (facility/IT power ratio), got 0.9
    >>> Scenario(pue_load_coeff=0.2)
    Traceback (most recent call last):
        ...
    ValueError: scenario '': PUE coefficients set without pue_base — set pue_base (>= 1) to enable the dynamic-PUE axis
    """

    name: str = ""
    num_hosts: int | None = None
    cores_per_host: int | None = None
    policy: str | int | None = None
    backfill_depth: int = 0
    p_idle: float | None = None
    p_max: float | None = None
    r: float | None = None
    power_cap_w: float | None = None
    carbon_cap_base_w: float | None = None
    carbon_cap_slope: float = 0.0
    arrival_scale: float = 1.0
    duration_scale: float = 1.0
    util_scale: float = 1.0
    shift_bins: int = 0
    failures: tuple = ()
    pue_base: float | None = None
    pue_amb_coeff: float = 0.0
    pue_amb_ref: float = 18.0
    pue_load_coeff: float = 0.0

    def __post_init__(self):
        # the Scenario boundary is host-side and concrete: bad power-model
        # parameters must never survive long enough to emit negative watts.
        if self.r is not None and not (math.isfinite(self.r) and self.r > 0):
            raise ValueError(
                f"scenario {self.name!r}: power-model exponent r must be "
                f"> 0, got {self.r}")
        if self.p_idle is not None and not (math.isfinite(self.p_idle)
                                            and self.p_idle >= 0):
            raise ValueError(
                f"scenario {self.name!r}: p_idle must be finite and >= 0 W, "
                f"got {self.p_idle}")
        if self.p_max is not None and not math.isfinite(self.p_max):
            raise ValueError(
                f"scenario {self.name!r}: p_max must be finite W, "
                f"got {self.p_max}")
        if (self.p_idle is not None and self.p_max is not None
                and self.p_max < self.p_idle):
            raise ValueError(
                f"scenario {self.name!r}: p_max ({self.p_max}) < p_idle "
                f"({self.p_idle}) inverts the power curve")
        if self.power_cap_w is not None and not self.power_cap_w > 0:
            raise ValueError(
                f"scenario {self.name!r}: power_cap_w must be > 0 W, "
                f"got {self.power_cap_w}")
        if self.carbon_cap_base_w is not None and not self.carbon_cap_base_w > 0:
            raise ValueError(
                f"scenario {self.name!r}: carbon_cap_base_w must be > 0 W, "
                f"got {self.carbon_cap_base_w}")
        if not math.isfinite(self.carbon_cap_slope):
            # a NaN/inf slope silently poisons the per-bin effective cap
            # (min with NaN is NaN in numpy, propagates to every readout)
            raise ValueError(
                f"scenario {self.name!r}: carbon_cap_slope must be finite "
                f"W per gCO2/kWh, got {self.carbon_cap_slope}")
        if not 0 <= int(self.backfill_depth) <= 31:
            # the DES skip bitmask is uint32; checked here at the concrete
            # Scenario boundary, not only in build_scenario_set, so a bad
            # depth can never reach a traced program (and a negative depth
            # is rejected instead of being silently clamped to 0)
            raise ValueError(
                f"scenario {self.name!r}: backfill_depth must be in [0, 31] "
                f"(uint32 skip-mask width), got {self.backfill_depth}")
        for knob in ("arrival_scale", "duration_scale"):
            if not getattr(self, knob) > 0:
                raise ValueError(
                    f"scenario {self.name!r}: {knob} must be > 0, "
                    f"got {getattr(self, knob)}")
        if not self.util_scale >= 0:
            raise ValueError(
                f"scenario {self.name!r}: util_scale must be >= 0, "
                f"got {self.util_scale}")
        if not isinstance(self.failures, tuple):
            object.__setattr__(self, "failures", tuple(self.failures))
        for f in self.failures:
            # duck-typed so constructing a Scenario never has to import the
            # runtime layer; HostFailure validates its own invariants
            for attr in ("host", "start_bin", "end_bin", "kind"):
                if not hasattr(f, attr):
                    raise ValueError(
                        f"scenario {self.name!r}: failures must be "
                        f"HostFailure windows, got {f!r}")
        if self.pue_base is not None and not (
                math.isfinite(self.pue_base) and self.pue_base >= 1.0):
            raise ValueError(
                f"scenario {self.name!r}: pue_base must be finite and >= 1 "
                f"(facility/IT power ratio), got {self.pue_base}")
        for knob in ("pue_amb_coeff", "pue_load_coeff"):
            v = getattr(self, knob)
            if not (math.isfinite(v) and v >= 0):
                raise ValueError(
                    f"scenario {self.name!r}: {knob} must be finite and "
                    f">= 0, got {v}")
        if not math.isfinite(self.pue_amb_ref):
            raise ValueError(
                f"scenario {self.name!r}: pue_amb_ref must be finite °C, "
                f"got {self.pue_amb_ref}")
        if self.pue_base is None and (self.pue_amb_coeff != 0.0
                                      or self.pue_load_coeff != 0.0):
            raise ValueError(
                f"scenario {self.name!r}: PUE coefficients set without "
                "pue_base — set pue_base (>= 1) to enable the dynamic-PUE "
                "axis")


@dataclasses.dataclass(frozen=True)
class ScenarioSet:
    """Device-ready stacked scenario batch (every array leaf leads with S).

    Built by :func:`build_scenario_set`; consumed by :func:`run_scenarios`.
    Shapes (``S`` scenarios, ``J`` padded jobs, ``H = max_hosts`` padded
    hosts):

    ======================  ==========================  =====================
    field                   shape / dtype               meaning
    ======================  ==========================  =====================
    ``workload``            leaves ``[S, J, ...]``      per-scenario perturbed
                                                        copies of one base
                                                        trace (padding jobs
                                                        have ``valid=False``)
    ``host_mask_s``         ``[S, H]`` bool             active-host mask;
                                                        padded hosts never run
                                                        jobs or draw power
    ``num_hosts``           ``[S]`` int32               active host count
    ``cores_per_host``      ``[S]`` int32               cores per active host
    ``policy_id``           ``[S]`` int32               placement policy (see
                                                        ``PLACEMENT_POLICIES``)
    ``backfill_depth``      ``[S]`` int32               successors that may
                                                        jump a blocked head
    ``params``              leaves ``[S, H]`` float32   per-host power-model
                                                        params (rows constant
                                                        for scalar bases)
    ``power_cap_w``         ``[S]`` float32             static cap, enforced
                                                        (+inf = uncapped)
    ``carbon_cap_base_w``   ``[S]`` float32             carbon-aware cap base
                                                        (+inf = no carbon cap)
    ``carbon_cap_slope``    ``[S]`` float32             W per gCO2/kWh; the
                                                        per-bin cap is
                                                        ``base + slope * I_t``
    ``shift_bins``          ``[S]`` int32               applied time shift
                                                        (provenance)
    ``peak_tflops``         ``[S]`` float32             topology peak
    ``fail_start``          ``[S, H]`` int32            failure-window start
                                                        bin (int32 max = the
                                                        host never fails)
    ``fail_end``            ``[S, H]`` int32            failure-window end bin
    ``fail_kill``           ``[S, H]`` bool             outage (kill jobs, no
                                                        power) vs drain
    ``pue_base``            ``[S]`` float32             dynamic-PUE base
                                                        (1.0 = identity)
    ``pue_amb_coeff``       ``[S]`` float32             PUE per °C above ref
    ``pue_amb_ref``         ``[S]`` float32             free-cooling ref °C
    ``pue_load_coeff``      ``[S]`` float32             partial-load penalty
    ======================  ==========================  =====================

    ``names`` (tuple of str), ``max_backfill`` (static int: the compile-
    time backfill window all traced depths are clipped to) and the axis
    flags ``has_failures`` / ``pue_on`` (static bools: whether the failure /
    dynamic-PUE machinery is compiled in at all) are pytree *aux data* —
    part of the jit cache key, not device arrays.  With a flag off the
    compiled program is *structurally* the pre-axis program; with it on,
    disabled lanes carry exact-identity sentinels (never-fail windows,
    PUE 1.0) and stay bit-for-bit equal to axis-off runs.  ``max_hosts``
    is implied by ``host_mask_s.shape[-1]``.
    """

    workload: Workload        # leaves [S, J, ...]
    host_mask_s: Array        # [S, max_hosts] bool
    num_hosts: Array          # [S] int32
    cores_per_host: Array     # [S] int32
    policy_id: Array          # [S] int32
    backfill_depth: Array     # [S] int32
    params: PowerParams       # leaves [S] float32
    power_cap_w: Array        # [S] float32 (+inf = uncapped)
    carbon_cap_base_w: Array  # [S] float32 (+inf = no carbon-aware cap)
    carbon_cap_slope: Array   # [S] float32 (W per gCO2/kWh)
    shift_bins: Array         # [S] int32 (provenance; already applied)
    peak_tflops: Array        # [S] float32
    fail_start: Array         # [S, max_hosts] int32 (int32 max = never)
    fail_end: Array           # [S, max_hosts] int32
    fail_kill: Array          # [S, max_hosts] bool
    pue_base: Array           # [S] float32 (1.0 = identity)
    pue_amb_coeff: Array      # [S] float32
    pue_amb_ref: Array        # [S] float32
    pue_load_coeff: Array     # [S] float32
    names: tuple[str, ...]
    max_backfill: int = 0
    has_failures: bool = False
    pue_on: bool = False

    @property
    def num_scenarios(self) -> int:
        return len(self.names)

    @property
    def max_hosts(self) -> int:
        return int(self.host_mask_s.shape[-1])


jax.tree_util.register_pytree_node(
    ScenarioSet,
    lambda s: ((s.workload, s.host_mask_s, s.num_hosts, s.cores_per_host,
                s.policy_id, s.backfill_depth, s.params, s.power_cap_w,
                s.carbon_cap_base_w, s.carbon_cap_slope, s.shift_bins,
                s.peak_tflops, s.fail_start, s.fail_end, s.fail_kill,
                s.pue_base, s.pue_amb_coeff, s.pue_amb_ref,
                s.pue_load_coeff),
               (s.names, s.max_backfill, s.has_failures, s.pue_on)),
    lambda aux, c: ScenarioSet(*c, names=aux[0], max_backfill=aux[1],
                               has_failures=aux[2], pue_on=aux[3]),
)


def _perturb(base: dict[str, np.ndarray | None],
             sc: Scenario) -> dict[str, np.ndarray | None]:
    """Apply a scenario's workload knobs (host-side numpy: build-time path).

    ``base`` holds the job-axis arrays (``submit``, ``dur``, ``util``,
    ``cores``, ``valid``, ``deferrable`` — the last possibly ``None``).
    Time-shifting moves deferrable valid jobs by ``sc.shift_bins`` bins
    (clipped at 0) and then re-sorts the job axis by the new submission
    times: the DES's FCFS queue order *is* the array order, so an unsorted
    axis would let late-shifted jobs head-block earlier work.  The stable
    sort keeps padding jobs (huge submit sentinel) at the tail and is the
    identity when nothing shifts.
    """
    out = dict(base)
    submit, dur, util = base["submit"], base["dur"], base["util"]
    if sc.arrival_scale != 1.0:
        # ×k arrival rate = submissions land k× denser on the bin axis
        submit = np.floor(
            submit.astype(np.float32) / sc.arrival_scale).astype(np.int32)
    if sc.duration_scale != 1.0:
        dur = np.maximum(
            np.ceil(dur.astype(np.float32) * sc.duration_scale), 1.0
        ).astype(np.int32)
    if sc.util_scale != 1.0:
        util = np.clip(util * sc.util_scale, 0.0, 1.0).astype(np.float32)
    out.update(submit=submit, dur=dur, util=util)
    if sc.shift_bins != 0:
        defer = base["deferrable"]
        movable = (base["valid"] if defer is None
                   else (defer & base["valid"]))
        submit = np.where(
            movable, np.maximum(submit + int(sc.shift_bins), 0), submit
        ).astype(np.int32)
        order = np.argsort(submit, kind="stable")
        out.update(
            submit=submit[order], dur=out["dur"][order],
            util=out["util"][order], cores=base["cores"][order],
            valid=base["valid"][order],
            deferrable=None if defer is None else defer[order],
        )
    return out


def _per_host_params(base_params: PowerParams, scenarios, hosts,
                     mh: int) -> PowerParams:
    """Stack power params as ``[S, max_hosts]`` rows (per-host aware).

    The base parameters may be scalars (one row value) or per-host vectors
    from calibration against a heterogeneous fleet; scenario overrides are
    scalars and replace the whole row.  Hosts beyond the base vector's
    length (scaled-up topologies, padding) assume fleet-average hardware —
    they are masked out of power/utilization unless the scenario activates
    them.  Pre-redesign this collapsed everything to per-scenario scalar
    means, silently flattening heterogeneous fleets on the what-if path
    (ROADMAP item).
    """
    def rows(field: str) -> Array:
        base_v = np.asarray(getattr(base_params, field),
                            np.float32).reshape(-1)
        base_row = np.full((mh,), float(base_v.mean()), np.float32)
        base_row[:min(base_v.size, mh)] = base_v[:mh]
        out = np.empty((len(scenarios), mh), np.float32)
        for i, sc in enumerate(scenarios):
            ov = getattr(sc, field)
            out[i] = base_row if ov is None else np.float32(ov)
        return jnp.asarray(out)

    # PowerParams validates the [S, H] stacks elementwise: a scenario that
    # overrides only p_max below the base p_idle (or vice versa) fails here.
    return PowerParams(p_idle=rows("p_idle"), p_max=rows("p_max"),
                       r=rows("r"))


def build_scenario_set(
    workload: Workload,
    dc: DatacenterConfig,
    scenarios: "list[Scenario] | tuple[Scenario, ...]",
    base_params: PowerParams = PowerParams(),
    max_hosts: int | None = None,
    max_backfill: int | None = None,
    has_failures: bool | None = None,
    pue_on: bool | None = None,
) -> ScenarioSet:
    """Stack S candidate configurations against one base trace/topology.

    Host-side (numpy) assembly: each :class:`Scenario`'s knobs are resolved
    against the base ``dc``/``base_params``, workload perturbations are
    applied to copies of the base trace, and everything is stacked into a
    device-ready :class:`ScenarioSet` whose array leaves lead with the
    scenario axis ``[S, ...]``.

    Padding semantics: the host axis is padded to ``max_hosts`` (default:
    the largest candidate host count — pass it explicitly to pin one
    compilation cache key across sweeps of different candidate mixes) and
    per-scenario activity is recorded in ``host_mask_s``; padded hosts never
    receive jobs, contribute no utilization and draw no power.  Power-model
    parameters are carried as ``[S, max_hosts]`` per-host rows, so
    heterogeneous fleets (per-host calibrated bases) survive the what-if
    path; scalar scenario overrides replace a whole row.
    The static backfill window ``max_backfill`` defaults to the max candidate
    depth, so depth-0 sweeps compile the backfill machinery out entirely;
    pass it explicitly (like ``max_hosts``) to pin one compilation cache key
    across batches whose depth mixes differ — the optimizer's generation
    loop (:mod:`repro.core.optimize`) relies on exactly this.

    The static axis flags ``has_failures`` / ``pue_on`` follow the same
    pinning convention: they default to "derived from this batch" (any
    scenario with failure windows / a ``pue_base``), and like
    ``max_hosts``/``max_backfill`` they are jit cache-key aux — pass them
    explicitly when successive batches may mix axis presence (again, the
    optimizer's generation loop).  Forcing a flag on for an axis no
    scenario uses is sound (sentinel lanes compute identical results);
    forcing one *off* while a scenario uses the axis is rejected.

    Raises ``ValueError`` on an empty scenario list, a candidate wanting
    more hosts than ``max_hosts``, a depth beyond ``max_backfill``, or a
    failure window on a host the scenario's topology does not have.
    """
    if not scenarios:
        raise ValueError("need at least one scenario")
    hosts = [sc.num_hosts if sc.num_hosts is not None else dc.num_hosts
             for sc in scenarios]
    mh = max(hosts) if max_hosts is None else int(max_hosts)
    if max(hosts) > mh:
        raise ValueError(f"scenario wants {max(hosts)} hosts > max_hosts={mh}")

    cores = [sc.cores_per_host if sc.cores_per_host is not None
             else dc.cores_per_host for sc in scenarios]
    names = tuple(sc.name or f"s{i}" for i, sc in enumerate(scenarios))

    # Every scenario perturbs the same base trace, so the stacked workload is
    # assembled host-side in numpy (one device transfer per field) — this
    # runs on every sweep and must not cost a per-scenario dispatch cascade.
    base = dict(
        submit=np.asarray(workload.submit_bin),
        dur=np.asarray(workload.duration_bins),
        util=np.asarray(workload.util_levels),
        cores=np.asarray(workload.cores),
        valid=np.asarray(workload.valid),
        deferrable=(None if workload.deferrable is None
                    else np.asarray(workload.deferrable)),
    )
    perturbed = [_perturb(base, sc) for sc in scenarios]
    wl = Workload(
        submit_bin=jnp.asarray(np.stack([p["submit"] for p in perturbed])),
        duration_bins=jnp.asarray(np.stack([p["dur"] for p in perturbed])),
        cores=jnp.asarray(np.stack([p["cores"] for p in perturbed])),
        util_levels=jnp.asarray(np.stack([p["util"] for p in perturbed])),
        valid=jnp.asarray(np.stack([p["valid"] for p in perturbed])),
        deferrable=(None if base["deferrable"] is None else jnp.asarray(
            np.stack([p["deferrable"] for p in perturbed]))),
    )

    hosts_a = jnp.asarray(hosts, jnp.int32)
    cores_a = jnp.asarray(cores, jnp.int32)
    # per-scenario depths are already range-checked at Scenario construction
    depths = [int(sc.backfill_depth) for sc in scenarios]
    mb = max(depths) if max_backfill is None else int(max_backfill)
    if not 0 <= mb <= 31:
        raise ValueError(
            f"max_backfill must be in [0, 31] (uint32 skip-mask width), "
            f"got {mb}")
    if max(depths) > mb:
        raise ValueError(
            f"scenario wants backfill_depth {max(depths)} > "
            f"max_backfill={mb}")
    peak = jnp.asarray(
        [dataclasses.replace(dc, num_hosts=h, cores_per_host=c).peak_tflops
         for h, c in zip(hosts, cores)], jnp.float32)
    cap = jnp.asarray(
        [sc.power_cap_w if sc.power_cap_w is not None else math.inf
         for sc in scenarios], jnp.float32)
    carbon_base = jnp.asarray(
        [sc.carbon_cap_base_w if sc.carbon_cap_base_w is not None
         else math.inf for sc in scenarios], jnp.float32)
    carbon_slope = jnp.asarray(
        [sc.carbon_cap_slope for sc in scenarios], jnp.float32)

    # failure axis: dense [S, mh] window arrays with never-fail sentinels.
    # fault.py is imported locally — it reaches repro.core via the
    # checkpoint layer, and a module-level import here would close an
    # import cycle through repro.core.__init__ (same pattern as
    # scenario_mesh's local sharding import).
    from repro.runtime.fault import failure_arrays

    any_fail = any(sc.failures for sc in scenarios)
    if has_failures is None:
        has_failures = any_fail
    elif any_fail and not has_failures:
        raise ValueError(
            "has_failures=False but scenario(s) carry failure windows")
    fs_rows, fe_rows, fk_rows = [], [], []
    for sc, h in zip(scenarios, hosts):
        for f in sc.failures:
            if f.host >= h:
                raise ValueError(
                    f"scenario {sc.name!r}: failure host {f.host} out of "
                    f"range for its {h}-host topology")
        fs, fe, fk = failure_arrays(sc.failures, mh)
        fs_rows.append(fs)
        fe_rows.append(fe)
        fk_rows.append(fk)

    # dynamic-PUE axis: per-scenario model params with identity sentinels
    # (base 1.0, coeffs 0) on lanes that leave it off.
    any_pue = any(sc.pue_base is not None for sc in scenarios)
    if pue_on is None:
        pue_on = any_pue
    elif any_pue and not pue_on:
        raise ValueError("pue_on=False but scenario(s) set pue_base")
    pue_base = jnp.asarray(
        [1.0 if sc.pue_base is None else sc.pue_base for sc in scenarios],
        jnp.float32)
    pue_amb_coeff = jnp.asarray(
        [sc.pue_amb_coeff for sc in scenarios], jnp.float32)
    pue_amb_ref = jnp.asarray(
        [sc.pue_amb_ref for sc in scenarios], jnp.float32)
    pue_load_coeff = jnp.asarray(
        [sc.pue_load_coeff for sc in scenarios], jnp.float32)

    return ScenarioSet(
        workload=wl,
        host_mask_s=host_mask(hosts_a, mh),
        num_hosts=hosts_a,
        cores_per_host=cores_a,
        policy_id=jnp.asarray([resolve_policy(sc.policy) for sc in scenarios],
                              jnp.int32),
        backfill_depth=jnp.asarray(depths, jnp.int32),
        params=_per_host_params(base_params, scenarios, hosts, mh),
        power_cap_w=cap,
        carbon_cap_base_w=carbon_base,
        carbon_cap_slope=carbon_slope,
        shift_bins=jnp.asarray([int(sc.shift_bins) for sc in scenarios],
                               jnp.int32),
        peak_tflops=peak,
        fail_start=jnp.asarray(np.stack(fs_rows)),
        fail_end=jnp.asarray(np.stack(fe_rows)),
        fail_kill=jnp.asarray(np.stack(fk_rows)),
        pue_base=pue_base,
        pue_amb_coeff=pue_amb_coeff,
        pue_amb_ref=pue_amb_ref,
        pue_load_coeff=pue_load_coeff,
        names=names,
        max_backfill=mb,
        has_failures=bool(has_failures),
        pue_on=bool(pue_on),
    )


def _predict_masked(u_th: Array, params: PowerParams, mask: Array,
                    peak_tflops: Array, model: str,
                    cap_t: Array, intensity: Array | None,
                    *,
                    online_th: Array | None = None,
                    pue=None,
                    ambient: Array | None = None,
                    price: Array | None = None) -> Prediction:
    """Mask-aware :func:`repro.core.desim.predict_metrics` for one scenario.

    Padded (inactive) hosts must not dilute mean utilization or draw idle
    power, so both aggregations respect the active-host mask.

    Power-cap **enforcement** (vs. the old flag-only behavior): ``cap_t``
    (scalar or ``[T]``; +inf = uncapped) clips the *delivered* power, and
    performance metrics lose the same fraction of the active (above-idle)
    draw — a linear-throttle (DVFS-proxy) approximation.  Pre-cap demand is
    preserved in ``Prediction.power_demand_w`` so cap-violation analysis
    still sees what the workload *wanted*.  An uncapped scenario
    (``cap_t = +inf``) stays bit-for-bit the pre-enforcement output:
    ``min(x, inf) == x`` and the throttle select falls through to the raw
    utilization.

    New-axis hooks (all default off, leaving the body above unchanged):

    ``online_th`` (``[T, H]`` bool)
        Time-varying host availability from the failure axis — hosts in an
        *outage* window draw no power (not even idle) and drop out of the
        utilization denominator.  Degraded (drain) hosts stay online here.
    ``pue`` / ``ambient``
        Dynamic cooling: per-bin PUE from the **unthrottled** mean
        utilization and the °C trace (:func:`repro.traces.thermal.dynamic_pue`).
        Demand, cap enforcement, the idle floor, energy, gCO2 and cost all
        move to *facility* watts — the cap constrains what the meter sees.
    ``price`` (``[T]`` $/kWh)
        Fills ``energy_cost`` from delivered (facility) energy.
    """
    maskf = mask.astype(u_th.dtype)
    if online_th is None:
        it_demand = datacenter_power(u_th, params, model=model,
                                     online_mask=maskf)
        idle_floor = jnp.sum(jnp.asarray(params.p_idle, u_th.dtype) * maskf)
        util_raw = jnp.sum(u_th * maskf, axis=-1) / jnp.maximum(
            jnp.sum(maskf), 1.0)
    else:
        onf = online_th.astype(u_th.dtype) * maskf               # [T, H]
        it_demand = datacenter_power(u_th, params, model=model,
                                     online_mask=onf)
        # per-bin idle floor and utilization denominator: offline hosts
        # contribute neither idle watts nor zero-util dilution
        idle_floor = jnp.sum(
            jnp.asarray(params.p_idle, u_th.dtype) * onf, axis=-1)
        util_raw = jnp.sum(u_th * onf, axis=-1) / jnp.maximum(
            jnp.sum(onf, axis=-1), 1.0)
    pue_t = None
    demand = it_demand
    if pue is not None:
        from repro.traces.thermal import dynamic_pue
        pue_t = dynamic_pue(util_raw, ambient, pue)
        demand = it_demand * pue_t
        idle_floor = idle_floor * pue_t
    exceeded = demand > cap_t
    power = jnp.minimum(demand, cap_t)
    throttle = jnp.clip(
        (cap_t - idle_floor) / jnp.maximum(demand - idle_floor, 1e-9),
        0.0, 1.0)
    e = energy_kwh(power, SAMPLE_SECONDS)
    util = jnp.where(exceeded, util_raw * throttle, util_raw)
    tflops = util * peak_tflops
    eff = tflops / jnp.maximum(e, 1e-9)
    gco2 = None if intensity is None else carbon_gco2(e, intensity)
    cost = None if price is None else e * jnp.asarray(price, e.dtype)
    return Prediction(power_w=power, energy_kwh=e, tflops=tflops,
                      utilization=util, efficiency=eff, gco2=gco2,
                      power_demand_w=demand, pue=pue_t, energy_cost=cost)


def _scenario_lanes(
    ss: ScenarioSet,
    carbon_intensity: Array | None,
    ambient_c: Array | None,
    price: Array | None,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int,
    model: str,
    chunk: bool,
    use_pallas: bool = False,
    precision: str = "f32",
) -> tuple[SimOutput, Prediction]:
    """vmap of the per-lane DES + prediction — the shared trace-level body.

    Both execution paths run exactly this: the single-device path vmaps it
    over the full S axis, the sharded path runs it per device over the local
    S shard (``chunk`` is resolved from the *global* batch in both cases, so
    every lane compiles the same readout program and the two paths agree bit
    for bit).  The ``[t_bins]`` traces (carbon, ambient, price) are shared
    closure constants under the vmap; everything per-scenario rides the S
    axis, and the static ``has_failures``/``pue_on`` aux flags decide
    whether the failure/PUE machinery is compiled in at all.

    ``use_pallas`` swaps the unfused readout (:func:`_predict_masked`) for
    the fused kernel (:mod:`repro.kernels.des_readout` — interpret mode off
    TPU), which rebuilds the per-bin online mask in-kernel instead of
    materializing the ``[T, H]`` availability tensor; ``precision`` is its
    bf16-where-tolerable policy knob.  The kernel path is within the
    ``tests/reference.py`` oracle tolerance of the unfused one but not
    bitwise (padded-lane summation), so it is opt-in per call.
    """
    if use_pallas:
        from repro.kernels.ops import des_readout
        # tracecheck: disable=TC007 — platform dispatch at trace time
        pallas_backend = ("pallas" if jax.devices()[0].platform == "tpu"
                          else "pallas_interpret")

    def one(w, mask, cores, policy_id, backfill_depth, params,
            cap_w, carbon_base, carbon_slope, peak,
            fail_start, fail_end, fail_kill,
            pue_base, pue_amb_coeff, pue_amb_ref, pue_load_coeff):
        use_fail = ss.has_failures
        sim = simulate_utilization_masked(
            w, mask, cores,
            max_hosts=max_hosts, t_bins=t_bins,
            max_starts_per_bin=max_starts_per_bin,
            policy_id=policy_id, backfill_depth=backfill_depth,
            max_backfill=ss.max_backfill,   # static aux, uniform over S
            force_chunked_readout=chunk,
            fail_start=fail_start if use_fail else None,
            fail_end=fail_end if use_fail else None,
            fail_kill=fail_kill if use_fail else None,
        )
        # effective per-bin cap: min(static facility cap, carbon-aware cap).
        # The intensity trace is shared across scenarios (closure constant
        # under the vmap); only the scalar cap parameters ride the S axis,
        # so (caps x shifts x topologies) grids stay one program.
        cap_t = cap_w
        if carbon_intensity is not None:
            cap_t = jnp.minimum(
                cap_t,
                jnp.maximum(carbon_base + carbon_slope * carbon_intensity,
                            0.0))
        if use_pallas:
            # fused readout: failure windows become kernel operands (the
            # online mask is rebuilt per tile from iota time ids) and the
            # identity-PUE sentinels make the PUE multiply an exact no-op
            # on lanes that leave the axis off.
            rd = des_readout(
                sim.u_th, backend=pallas_backend,
                p_idle=params.p_idle, p_max=params.p_max, r=params.r,
                mask=mask, cap_t=cap_t, intensity=carbon_intensity,
                ambient=ambient_c, price=price, peak_tflops=peak,
                pue_base=pue_base, pue_amb_coeff=pue_amb_coeff,
                pue_amb_ref=pue_amb_ref, pue_load_coeff=pue_load_coeff,
                fail_start=fail_start if use_fail else None,
                fail_end=fail_end if use_fail else None,
                fail_kill=fail_kill if use_fail else None,
                model=model, precision=precision,
                dt_seconds=SAMPLE_SECONDS)
            pred = Prediction(
                power_w=rd["power_w"], energy_kwh=rd["energy_kwh"],
                tflops=rd["tflops"], utilization=rd["utilization"],
                efficiency=rd["efficiency"],
                gco2=None if carbon_intensity is None else rd["gco2"],
                power_demand_w=rd["power_demand_w"],
                pue=rd["pue"] if ss.pue_on else None,
                energy_cost=None if price is None else rd["energy_cost"])
            return sim, pred
        online_th = None
        if use_fail:
            # power-side availability: only *outage* hosts stop drawing
            # power during their window (degraded hosts drain but burn)
            tt = jnp.arange(t_bins, dtype=jnp.int32)[:, None]     # [T, 1]
            offline = (fail_kill[None, :] & (tt >= fail_start[None, :])
                       & (tt < fail_end[None, :]))                # [T, H]
            online_th = mask[None, :] & jnp.logical_not(offline)
        pue = None
        if ss.pue_on:
            from repro.traces.thermal import PUEParams
            pue = PUEParams(base=pue_base, amb_coeff=pue_amb_coeff,
                            amb_ref=pue_amb_ref, load_coeff=pue_load_coeff)
        pred = _predict_masked(sim.u_th, params, mask, peak, model,
                               cap_t, carbon_intensity,
                               online_th=online_th, pue=pue,
                               ambient=ambient_c, price=price)
        return sim, pred

    return jax.vmap(one)(ss.workload, ss.host_mask_s, ss.cores_per_host,
                         ss.policy_id, ss.backfill_depth, ss.params,
                         ss.power_cap_w, ss.carbon_cap_base_w,
                         ss.carbon_cap_slope, ss.peak_tflops,
                         ss.fail_start, ss.fail_end, ss.fail_kill,
                         ss.pue_base, ss.pue_amb_coeff, ss.pue_amb_ref,
                         ss.pue_load_coeff)


def _run_scenarios_body(
    ss: ScenarioSet,
    carbon_intensity: Array | None,
    ambient_c: Array | None,
    price: Array | None,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int,
    model: str,
    use_pallas: bool,
    precision: str,
) -> tuple[SimOutput, Prediction]:
    # the DES core's own readout bound is per-scenario; under the scenario
    # vmap every intermediate gains the S axis, so the bound must include S
    # (workload leaves are [S, J]: take J from the trailing axis).
    n_jobs = int(ss.workload.submit_bin.shape[-1])
    chunk = ss.num_scenarios * n_jobs * t_bins > _BATCH_READOUT_THRESHOLD
    return _scenario_lanes(
        ss, carbon_intensity, ambient_c, price,
        max_hosts=max_hosts, t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin, model=model, chunk=chunk,
        use_pallas=use_pallas, precision=precision)


_RUN_STATICS = ("max_hosts", "t_bins", "max_starts_per_bin", "model",
                "use_pallas", "precision")
_run_scenarios_jit = jax.jit(_run_scenarios_body,
                             static_argnames=_RUN_STATICS)
#: same program, but the ScenarioSet argument's buffers are donated — the
#: optimizer's generation carry uses this so warm searches stop
#: double-buffering the [S, J] workload leaves.  A separate compiled
#: program, hence a separate cache: run_scenarios._cache_size sums both.
_run_scenarios_jit_donated = jax.jit(_run_scenarios_body,
                                     static_argnames=_RUN_STATICS,
                                     donate_argnums=(0,))


#: mesh axis name the scenario batch is sharded over
SCENARIO_AXIS = "scenarios"


def scenario_mesh(num_devices: int | None = None):
    """A 1-D device mesh over ``SCENARIO_AXIS`` (default: all local devices).

    On CPU-only deployments, export
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* process
    start to split the host into N devices (the ``tier1-multidevice`` CI job
    runs the equivalence suite exactly that way).
    """
    from repro.parallel.sharding import make_mesh_compat

    devs = jax.devices()  # tracecheck: disable=TC007 — mesh discovery is this helper's purpose
    n = len(devs) if num_devices is None else int(num_devices)
    return make_mesh_compat((n,), (SCENARIO_AXIS,),
                            devices=np.array(devs[:n]))


@functools.partial(jax.jit, static_argnames=("mesh", "max_hosts", "t_bins",
                                             "max_starts_per_bin", "model",
                                             "chunk", "use_pallas",
                                             "precision"))
def _run_scenarios_sharded_jit(
    ss: ScenarioSet,
    carbon_intensity: Array | None,
    ambient_c: Array | None,
    price: Array | None,
    *,
    mesh,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int,
    model: str,
    chunk: bool,
    use_pallas: bool = False,
    precision: str = "f32",
) -> tuple[SimOutput, Prediction]:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(ss_local: ScenarioSet, ci_local: Array | None,
             amb_local: Array | None, price_local: Array | None):
        return _scenario_lanes(
            ss_local, ci_local, amb_local, price_local,
            max_hosts=max_hosts, t_bins=t_bins,
            max_starts_per_bin=max_starts_per_bin, model=model, chunk=chunk,
            use_pallas=use_pallas, precision=precision)

    return shard_map(
        body, mesh=mesh,
        # S-axis sharded; the [T] traces replicated on every device
        in_specs=(P(SCENARIO_AXIS), P(), P(), P()),
        out_specs=P(SCENARIO_AXIS),
        check_rep=False,
    )(ss, carbon_intensity, ambient_c, price)


def _pad_scenario_axis(ss: ScenarioSet, pad: int) -> ScenarioSet:
    """Pad the S axis by replicating lane 0 (masked off by the caller).

    Mirrors the host-axis padding story: the padded lanes are real
    (scenario-0 copies) so every device runs a full shard, and the caller
    slices the outputs back to the true S.
    """
    if pad == 0:
        return ss
    padded = jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.repeat(x[:1], pad, axis=0)], axis=0), ss)
    return dataclasses.replace(padded, names=ss.names + ("",) * pad)


def run_scenarios(
    ss: ScenarioSet,
    *,
    max_hosts: int,
    t_bins: int,
    max_starts_per_bin: int = 64,
    model: str = "opendc",
    carbon_intensity: "Array | np.ndarray | None" = None,
    ambient_c: "Array | np.ndarray | None" = None,
    price: "Array | np.ndarray | None" = None,
    shard: bool = False,
    mesh=None,
    use_pallas: bool = False,
    readout_precision: str = "f32",
    donate: bool = False,
) -> tuple[SimOutput, Prediction]:
    """Simulate + predict all S scenarios in one jitted program.

    Returns a batched :class:`SimOutput` and :class:`Prediction` whose array
    leaves lead with the scenario axis: ``sim.u_th`` is
    ``[S, t_bins, max_hosts]`` (padded hosts read 0), ``sim.job_start`` /
    ``sim.job_host`` are ``[S, J]`` (-1 = never started), and every
    :class:`~repro.core.desim.Prediction` leaf is ``[S, t_bins]``.

    ``carbon_intensity`` (``[t_bins]`` gCO2/kWh, shared by all scenarios —
    see :mod:`repro.traces.carbon`) activates the carbon subsystem: the
    prediction gains per-bin ``gco2`` and carbon-aware power caps
    (``Scenario.carbon_cap_base_w``) become computable.  Omitting it keeps
    every output leaf bit-for-bit identical to the pre-carbon engine
    (``gco2=None``); scenarios that *request* a carbon-aware cap without a
    trace are rejected loudly rather than silently uncapped.

    ``ambient_c`` (``[t_bins]`` °C, see :mod:`repro.traces.thermal`) feeds
    the dynamic-PUE axis of lanes that set ``Scenario.pue_base``; lanes
    whose ``pue_amb_coeff`` is nonzero *require* it (rejected loudly,
    mirroring the carbon-cap rule).  ``price`` (``[t_bins]`` $/kWh, see
    :mod:`repro.traces.price`) fills ``Prediction.energy_cost`` for every
    lane from delivered (facility) energy.  Failure windows
    (``Scenario.failures``) need no trace but must *start* inside the
    horizon — a window opening at or past ``t_bins`` can never fire and is
    rejected as a mis-specified what-if.

    One compilation covers any scenario batch with the same
    ``(S, max_hosts, t_bins, J, max_backfill)`` shape (per intensity
    presence) — the sequential what-if loop's per-candidate
    retrace/recompile is gone, and because the placement policy, caps and
    time shifts are traced ``[S]`` axes (or same-shape workload data),
    scheduler/carbon sweeps ride the same program as topology sweeps.
    Scenario *names* are pytree aux data (part of the jit cache key), so
    they are anonymized before entering jit — differently-named sweeps of
    the same shape share one compilation.

    **Scenario-axis sharding**: with ``shard=True`` the S axis is
    ``shard_map``-ped over the devices of ``mesh`` (default: a 1-D
    :func:`scenario_mesh` over all local devices) — each device runs the
    *same* per-lane program over its local shard, so 100s-of-candidate
    sweeps scale across cores/chips while staying **bit-for-bit identical**
    to the single-device vmap path (pinned by
    ``tests/test_shard_scenarios.py``; speedup recorded by
    ``benchmarks/whatif_batch.py``).  S is padded to a multiple of the
    device count with masked scenario-0 replicas and the outputs are sliced
    back to the true S, mirroring the host-axis padding story.

    **Fused readout** (``use_pallas=True``): the post-scan readout runs as
    the one-pass :mod:`repro.kernels.des_readout` kernel (Pallas on TPU,
    interpret mode elsewhere) instead of the unfused XLA pipeline.
    Outputs stay inside the ``tests/reference.py`` oracle tolerance but
    are *not* bitwise vs the default readout (padded-lane summation), so
    the flag defaults off and golden comparisons keep the legacy path.
    ``readout_precision="bf16"`` additionally computes the derived
    performance leaves (tflops, efficiency) in bf16 — sustainability
    leaves stay f32; pinned by ``tests/golden/readout_bf16.npz``.

    **Donation** (``donate=True``, single-device path only): the
    ``ScenarioSet``'s array buffers are donated to the compiled program,
    halving peak residency of the dominant ``[S, J]`` workload leaves on
    warm calls.  The caller's ``ss`` (its leaves, including any aliases)
    is **invalidated** — snapshot anything still needed first.  The
    optimizer's generation loop runs this way (it re-builds ``ss`` every
    generation); it is a separate compiled program from the non-donating
    one, and ``run_scenarios._cache_size`` counts both.
    """
    if carbon_intensity is None:
        if np.isfinite(np.asarray(ss.carbon_cap_base_w)).any():
            raise ValueError(
                "scenario(s) set carbon_cap_base_w but no carbon_intensity "
                "trace was supplied — a carbon-aware cap cannot be computed "
                "without one (pass carbon_intensity=[t_bins] gCO2/kWh)")
        ci = None
    else:
        ci = jnp.asarray(
            validate_carbon_intensity(np.asarray(carbon_intensity), t_bins),
            jnp.float32)
    if ss.has_failures:
        fs = np.asarray(ss.fail_start)
        bad = (fs < np.iinfo(np.int32).max) & (fs >= t_bins)
        if bad.any():
            s_bad, h_bad = map(int, np.argwhere(bad)[0])
            raise ValueError(
                f"scenario {s_bad} host {h_bad}: failure window starts at "
                f"bin {int(fs[s_bad, h_bad])}, at/past the {t_bins}-bin "
                "horizon — it can never fire")
    if ambient_c is None:
        if ss.pue_on and np.asarray(ss.pue_amb_coeff).any():
            raise ValueError(
                "scenario(s) set pue_amb_coeff but no ambient_c trace was "
                "supplied — the ambient-driven PUE term cannot be computed "
                "without one (pass ambient_c=[t_bins] °C)")
        amb = None
    else:
        from repro.traces.thermal import validate_ambient
        amb = jnp.asarray(
            validate_ambient(np.asarray(ambient_c), t_bins), jnp.float32)
    if price is None:
        pr = None
    else:
        from repro.traces.price import validate_price
        pr = jnp.asarray(
            validate_price(np.asarray(price), t_bins), jnp.float32)
    s = ss.num_scenarios
    anon = dataclasses.replace(ss, names=("",) * s)
    if not shard:
        run = _run_scenarios_jit_donated if donate else _run_scenarios_jit
        with warnings.catch_warnings():
            # expected on the donated program: the small [S] knob leaves
            # have no same-shaped output to reuse, and jax reports them.
            # The [S, J] workload leaves — the residency that matters —
            # do get reused; tests/test_compile_invariants.py asserts it.
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return run(
                anon, ci, amb, pr, max_hosts=max_hosts, t_bins=t_bins,
                max_starts_per_bin=max_starts_per_bin, model=model,
                use_pallas=use_pallas, precision=readout_precision,
            )
    mesh = scenario_mesh() if mesh is None else mesh
    n_dev = mesh.shape[SCENARIO_AXIS]
    per_dev = -(-s // n_dev)
    if n_dev > 1:
        # keep >= 2 lanes per device: a batch-1 vmapped while_loop inside
        # shard_map trips an XLA sharding-propagation bug on jax 0.4.x
        # ("tile_assignment should have N devices" on the backfill skip-mask
        # iota) — one extra masked replica lane per device sidesteps it.
        per_dev = max(per_dev, 2)
    padded = _pad_scenario_axis(anon, per_dev * n_dev - s)
    # readout chunking is resolved from the *global* (unpadded) batch so the
    # per-lane program matches the vmap path's exactly (bit-for-bit gate).
    n_jobs = int(ss.workload.submit_bin.shape[-1])
    chunk = s * n_jobs * t_bins > _BATCH_READOUT_THRESHOLD
    out = _run_scenarios_sharded_jit(
        padded, ci, amb, pr, mesh=mesh, max_hosts=max_hosts, t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin, model=model, chunk=chunk,
        use_pallas=use_pallas, precision=readout_precision,
    )
    return jax.tree.map(lambda x: x[:s], out)


# surfaced for the single-compilation regression tests; `_cache_size` is
# private jax API, so its absence must degrade to None, not an import
# error.  The donated program is a distinct executable with its own cache,
# so the counter sums both: a donated-only workload (the optimizer) and a
# non-donating one (the grid benchmarks) each still count 1.
_jit_caches = tuple(
    getattr(f, "_cache_size", None)
    for f in (_run_scenarios_jit, _run_scenarios_jit_donated))
run_scenarios._cache_size = (
    (lambda: sum(c() for c in _jit_caches)) if all(_jit_caches) else None)


@dataclasses.dataclass(frozen=True)
class ScenarioSummary:
    """Host-side per-scenario read-out an operator (or the HITL gate) compares.

    Scheduler provenance and outcome travel together: ``policy`` /
    ``backfill_depth`` identify the placement policy the scenario ran,
    ``mean_wait_bins`` / ``p99_wait_bins`` are queue-wait statistics
    (``job_start - submit`` in 5-minute bins, over jobs that started; NaN if
    nothing started) and ``unplaced_jobs`` counts valid jobs that never
    started inside the horizon — the fields
    :func:`repro.core.feedback.propose_from_scenario` needs to recommend a
    scheduler change on wait/placement grounds against an energy budget.

    ``kwh_per_cpu_hour`` is NaN when the scenario's workload has zero CPU-hours
    — an empty trace is surfaced, never hidden behind a clamped denominator.

    Sustainability fields: ``gco2`` is the scenario's total operational
    carbon (grams CO2; NaN when no carbon-intensity trace was supplied) and
    ``carbon_intensity_avg`` the energy-weighted mean grid intensity it ran
    against (gCO2/kWh; NaN without a trace or with zero energy).  Cap
    fields reflect *enforcement*: ``energy_kwh``/``mean_power_w``/
    ``peak_power_w`` are delivered (post-cap), ``peak_demand_w`` is what the
    workload wanted, and ``cap_exceeded_bins`` counts bins where demand ran
    into the effective (static ∧ carbon-aware) cap.  ``shift_bins`` records
    the applied deferrable-job time shift.

    New-axis fields (``None``/0 when the axis is off — ``None`` rather
    than NaN so dataclass equality keeps working in the shard-equivalence
    tests): ``mean_pue`` is the energy-unweighted mean dynamic PUE,
    ``energy_cost`` the total electricity cost ($, against the spot-price
    trace; power fields are *facility*-level when PUE is on) and
    ``failure_events`` the number of failure windows the scenario injects.
    """

    name: str
    num_hosts: int
    cores_per_host: int
    policy: str
    backfill_depth: int
    mean_util: float
    p99_queue: float
    max_queue: int
    mean_wait_bins: float
    p99_wait_bins: float
    unplaced_jobs: int
    total_jobs: int
    energy_kwh: float
    mean_power_w: float
    peak_power_w: float
    peak_demand_w: float
    cpu_hours: float
    kwh_per_cpu_hour: float
    gco2: float
    carbon_intensity_avg: float
    shift_bins: int
    power_cap_w: float | None
    carbon_cap_base_w: float | None
    carbon_cap_slope: float
    cap_exceeded_bins: int
    mean_pue: float | None = None
    energy_cost: float | None = None
    failure_events: int = 0


def summarize_scenarios(
    ss: ScenarioSet, sim: SimOutput, pred: Prediction,
    carbon_intensity: "np.ndarray | Array | None" = None,
) -> list[ScenarioSummary]:
    """Collapse batched outputs into one comparable record per scenario.

    Pass the same ``carbon_intensity`` the sweep ran with so cap-violation
    counting sees the effective (carbon-aware) per-bin cap; carbon totals
    come from ``pred.gco2`` directly.
    """
    util = np.asarray(pred.utilization)        # [S, T] (mask-aware)
    queue = np.asarray(sim.queue_len)          # [S, T]
    start = np.asarray(sim.job_start)          # [S, J]
    submit = np.asarray(ss.workload.submit_bin)  # [S, J] (post-perturbation)
    valid = np.asarray(ss.workload.valid)      # [S, J]
    power = np.asarray(pred.power_w)           # [S, T] delivered (post-cap)
    demand = (np.asarray(pred.power_demand_w)  # [S, T] pre-cap demand
              if pred.power_demand_w is not None else power)
    energy = np.asarray(pred.energy_kwh)       # [S, T]
    gco2 = (np.asarray(pred.gco2)              # [S, T] or None
            if pred.gco2 is not None else None)
    cap = np.asarray(ss.power_cap_w)           # [S]
    cbase = np.asarray(ss.carbon_cap_base_w)   # [S]
    cslope = np.asarray(ss.carbon_cap_slope)   # [S]
    shifts = np.asarray(ss.shift_bins)         # [S]
    policy = np.asarray(ss.policy_id)          # [S]
    depth = np.asarray(ss.backfill_depth)      # [S]
    pue = (np.asarray(pred.pue)                # [S, T] or None
           if pred.pue is not None else None)
    cost = (np.asarray(pred.energy_cost, np.float64)  # [S, T] or None
            if pred.energy_cost is not None else None)
    fail_ct = (np.asarray(ss.fail_start)       # [S] windows per scenario
               < np.iinfo(np.int32).max).sum(axis=-1)
    ci = (None if carbon_intensity is None
          else np.asarray(carbon_intensity, np.float64))
    cpu_h = np.asarray(
        jax.vmap(lambda w: jnp.sum(w.cpu_hours()))(ss.workload))

    out = []
    for s, name in enumerate(ss.names):
        ch = float(cpu_h[s])
        ekwh = float(energy[s].sum())
        placed = (start[s] >= 0) & valid[s]
        waits = (start[s] - submit[s])[placed]
        cap_t = np.full_like(power[s], cap[s])     # effective per-bin cap
        if ci is not None:
            cap_t = np.minimum(
                cap_t, np.maximum(cbase[s] + cslope[s] * ci, 0.0))
        g = float(gco2[s].sum()) if gco2 is not None else float("nan")
        out.append(ScenarioSummary(
            name=name,
            num_hosts=int(ss.num_hosts[s]),
            cores_per_host=int(ss.cores_per_host[s]),
            policy=POLICY_NAMES[int(policy[s])],
            backfill_depth=int(depth[s]),
            mean_wait_bins=(float(waits.mean()) if waits.size
                            else float("nan")),
            p99_wait_bins=(float(np.percentile(waits, 99)) if waits.size
                           else float("nan")),
            mean_util=float(util[s].mean()),
            p99_queue=float(np.percentile(queue[s], 99)),
            max_queue=int(queue[s].max()),
            unplaced_jobs=int(((start[s] < 0) & valid[s]).sum()),
            total_jobs=int(valid[s].sum()),
            energy_kwh=ekwh,
            mean_power_w=float(power[s].mean()),
            peak_power_w=float(power[s].max()),
            peak_demand_w=float(demand[s].max()),
            cpu_hours=ch,
            kwh_per_cpu_hour=(ekwh / ch) if ch > 0 else float("nan"),
            gco2=g,
            carbon_intensity_avg=(g / ekwh if np.isfinite(g) and ekwh > 0
                                  else float("nan")),
            shift_bins=int(shifts[s]),
            power_cap_w=None if np.isinf(cap[s]) else float(cap[s]),
            carbon_cap_base_w=(None if np.isinf(cbase[s])
                               else float(cbase[s])),
            carbon_cap_slope=float(cslope[s]),
            cap_exceeded_bins=int((demand[s] > cap_t).sum()),
            mean_pue=(float(pue[s].mean()) if pue is not None else None),
            energy_cost=(float(cost[s].sum()) if cost is not None else None),
            failure_events=int(fail_ct[s]),
        ))
    return out


def evaluate_scenarios(
    workload: Workload,
    dc: DatacenterConfig,
    scenarios: "list[Scenario] | tuple[Scenario, ...]",
    *,
    t_bins: int,
    base_params: PowerParams = PowerParams(),
    max_hosts: int | None = None,
    model: str = "opendc",
    max_starts_per_bin: int = 64,
    carbon_intensity: "Array | np.ndarray | None" = None,
    ambient_c: "Array | np.ndarray | None" = None,
    price: "Array | np.ndarray | None" = None,
    shard: bool = False,
    mesh=None,
    use_pallas: bool = False,
) -> tuple[ScenarioSet, SimOutput, Prediction, list[ScenarioSummary]]:
    """End-to-end what-if sweep: build, batch-simulate, summarize.

    Convenience wrapper over :func:`build_scenario_set` ->
    :func:`run_scenarios` -> :func:`summarize_scenarios`; returns all four
    artifacts (the device-side batch plus host-side summaries) so callers
    can both rank candidates and drill into per-bin fields.  ``scenarios``
    may sweep any :class:`Scenario` axis — topology, placement policy,
    backfill depth, power model, enforced (carbon-aware) caps, workload
    scaling and time-shifting — and the whole sweep still compiles once per
    ``(S, max_hosts, t_bins, J, max_backfill)`` shape.  Supplying
    ``carbon_intensity`` ([t_bins] gCO2/kWh) fills the ``gco2`` /
    ``carbon_intensity_avg`` summary fields; without it they are NaN and
    outputs match the pre-carbon engine bit for bit.
    """
    ss = build_scenario_set(workload, dc, scenarios, base_params,
                            max_hosts=max_hosts)
    sim, pred = run_scenarios(
        ss, max_hosts=ss.max_hosts, t_bins=t_bins,
        max_starts_per_bin=max_starts_per_bin, model=model,
        carbon_intensity=carbon_intensity, ambient_c=ambient_c, price=price,
        shard=shard, mesh=mesh, use_pallas=use_pallas,
    )
    return ss, sim, pred, summarize_scenarios(
        ss, sim, pred, carbon_intensity=carbon_intensity)

"""OpenDT core: the paper's contribution as a composable JAX library.

Continuous datacenter digital twinning (Fig. 1/2 of the paper):
telemetry ingestion -> vectorized discrete-event simulation ->
self-calibration -> SLO-aware, human-in-the-loop feedback.
"""

from repro.core.calibrate import (
    CalibrationResult,
    CalibrationSpec,
    SelfCalibrator,
    calibrate_window,
    candidate_grid,
)
from repro.core.desim import (
    Prediction,
    SimOutput,
    predict_metrics,
    simulate,
    simulate_utilization,
)
from repro.core.feedback import (
    HITLGate,
    Proposal,
    ProposalKind,
    propose_from_optimum,
    propose_from_scenario,
    propose_from_state,
)
from repro.core.optimize import (
    Candidate,
    ObjectiveSpec,
    OptimizeResult,
    OptimizerConfig,
    SearchSpace,
    optimize,
    score_batch,
)
from repro.core.orchestrator import (
    Clock,
    OptimizeWhatIfResult,
    Orchestrator,
    OrchestratorConfig,
    WhatIfResult,
    WindowRecord,
)
from repro.core.scenarios import (
    SCENARIO_AXIS,
    Scenario,
    ScenarioSet,
    ScenarioSummary,
    build_scenario_set,
    evaluate_scenarios,
    run_scenarios,
    scenario_mesh,
    summarize_scenarios,
)
from repro.core.power import (
    POWER_MODELS,
    PowerParams,
    carbon_gco2,
    datacenter_power,
    energy_kwh,
    linear_power,
    mape,
    opendc_power,
    validate_power_params,
)
from repro.core.slo import NFR1, SLO, BiasTracker, SLOMonitor
from repro.core.state import (
    SimSlice,
    TelemetrySlice,
    TwinConfig,
    TwinState,
    WindowOutput,
    empty_telemetry,
    init_twin_state,
    load_state,
    make_telemetry,
    save_state,
    twin_step,
    twin_step_jit,
)
from repro.core.telemetry import (
    AMBIENT_KEY,
    CARBON_INTENSITY_KEY,
    PRICE_KEY,
    TelemetryStore,
    TelemetryWindow,
    clip_to_window,
)
from repro.core.twin import (
    DigitalTwin,
    TraceGroundTruth,
    TwinRunResult,
    fleet_step,
    index_twin_state,
    run_fleet,
    run_surf_experiment,
    stack_twin_states,
)

__all__ = [
    "CalibrationResult", "CalibrationSpec", "SelfCalibrator",
    "calibrate_window", "candidate_grid",
    "Prediction", "SimOutput", "predict_metrics", "simulate",
    "simulate_utilization",
    "HITLGate", "Proposal", "ProposalKind",
    "propose_from_optimum", "propose_from_scenario", "propose_from_state",
    "Candidate", "ObjectiveSpec", "OptimizeResult", "OptimizerConfig",
    "SearchSpace", "optimize", "score_batch",
    "OptimizeWhatIfResult",
    "Clock", "Orchestrator", "OrchestratorConfig", "WhatIfResult",
    "WindowRecord",
    "SCENARIO_AXIS", "Scenario", "ScenarioSet", "ScenarioSummary",
    "build_scenario_set", "evaluate_scenarios", "run_scenarios",
    "scenario_mesh", "summarize_scenarios",
    "POWER_MODELS", "PowerParams", "carbon_gco2", "datacenter_power",
    "energy_kwh", "linear_power", "mape", "opendc_power",
    "validate_power_params",
    "NFR1", "SLO", "BiasTracker", "SLOMonitor",
    "SimSlice", "TelemetrySlice", "TwinConfig", "TwinState", "WindowOutput",
    "empty_telemetry", "init_twin_state", "load_state", "make_telemetry",
    "save_state", "twin_step", "twin_step_jit",
    "AMBIENT_KEY", "CARBON_INTENSITY_KEY", "PRICE_KEY", "TelemetryStore",
    "TelemetryWindow", "clip_to_window",
    "DigitalTwin", "TraceGroundTruth", "TwinRunResult", "run_surf_experiment",
    "fleet_step", "index_twin_state", "run_fleet", "stack_twin_states",
]

"""Pure functional twin core: pytree ``TwinState`` + ``twin_step``.

The paper's continuous integration cycle (§2.3) — predict the window with
the pipelined parameters, score it against telemetry, calibrate for the next
window, track SLO compliance and estimation bias — is a *state-transition
function*, not an object with side effects.  This module is that function:

    state', output = twin_step(state, telemetry, sim_slice)

``TwinState`` is a registered pytree holding everything the cycle carries
between windows (calibrated :class:`~repro.core.power.PowerParams`, the
fixed-shape calibration history buffers, SLO/bias accumulators, the window
index); ``twin_step`` is pure and shape-stable, so the whole cycle composes
with the JAX transformations the imperative ``Orchestrator`` loop blocked:

  * ``jax.jit(twin_step)`` — one compiled program per window (the
    :class:`~repro.core.orchestrator.Orchestrator` *shell* drives exactly
    this, keeping only I/O, wall-clock pacing, record-keeping and the HITL
    gate host-side);
  * ``jax.vmap(twin_step)`` — a *fleet of twins*: D independent datacenters
    twinned per window by one program (``repro.core.twin.run_fleet``);
  * ``jax.lax.scan`` over windows — a whole horizon in one compilation.

Everything here is deliberately replayable: checkpoint a ``TwinState``
(:func:`save_state` / :func:`load_state`, codec-tagged like every persisted
blob in this repo) and a resumed run reproduces the uninterrupted run's
outputs exactly.

Doctest-sized example (2 hosts, 4-bin windows)::

    >>> import numpy as np
    >>> from repro.traces.schema import DatacenterConfig
    >>> cfg = TwinConfig(bins_per_window=4,
    ...                  dc=DatacenterConfig(num_hosts=2, cores_per_host=4))
    >>> state = init_twin_state(cfg)
    >>> state.hist_u.shape            # [history_windows, bins, hosts]
    (4, 4, 2)
    >>> u = np.full((4, 2), 0.5, np.float32)
    >>> telem = make_telemetry(u, np.full((4,), 420.0, np.float32))
    >>> state2, out = twin_step(state, telem, SimSlice(u_th=u))
    >>> int(state2.window), int(state2.hist_n)
    (1, 1)
    >>> bool(out.mape >= 0)           # window scored against telemetry
    True
    >>> int(state.window)             # purity: the input state is untouched
    0
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.calibrate import CalibrationSpec, calibrate_traced, candidate_grid
from repro.core.desim import Prediction, predict_metrics
from repro.core.power import PowerParams, mape
from repro.core.slo import NFR1, SLO, observe_bias, observe_slos
from repro.traces.schema import DatacenterConfig
from repro.traces.thermal import PUEParams

Array = jax.Array

#: persisted-state format version (bumped on layout changes)
_STATE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TwinConfig:
    """Static configuration of the pure core (pytree *aux data*).

    Hashable — it rides the ``TwinState`` treedef, so it is part of the jit
    cache key and never traced.  Mirrors the twin-loop fields of
    :class:`~repro.core.orchestrator.OrchestratorConfig`; the shell-only
    knobs (acceleration pacing, proposal caps) stay in the shell.
    """

    bins_per_window: int = 36
    dc: DatacenterConfig = DatacenterConfig()
    calibration: CalibrationSpec = CalibrationSpec()
    calibrate: bool = True
    history_windows: int = 4
    power_model: str = "opendc"
    kernel_backend: str = "xla"
    slos: tuple[SLO, ...] = (NFR1,)
    #: dynamic-PUE model: when set, window predictions report *facility*
    #: power (IT draw x PUE(load, ambient)) — frozen/hashable, so it rides
    #: the jit cache key like every other static knob.
    pue: PUEParams | None = None
    #: full-horizon DES resident in the state: when positive, ``TwinState``
    #: carries a ``[sim_bins, H]`` utilization field (``sim_u``) and
    #: ``twin_step`` slices its own window from it whenever the caller
    #: passes ``SimSlice(u_th=None)`` — the topology-applying feedback loop
    #: (an accepted proposal re-simulates and swaps this field) needs the
    #: twin to own its simulation.  0 (the default) keeps the incumbent
    #: layout: no extra leaf, the shell feeds per-window slices.
    sim_bins: int = 0


@dataclasses.dataclass(frozen=True)
class TwinState:
    """Everything the windowed cycle carries between windows (pytree).

    Array children (all fixed-shape, so ``twin_step`` never retraces):

    ================  =====================  ===============================
    field             shape / dtype          meaning
    ================  =====================  ===============================
    ``params``        scalars, float32       pipelined power params: the
                                             calibration result C_{k-1} the
                                             next prediction S_k must use
    ``base_params``   scalars, float32       reset target when a calibration
                                             window is undefined (all-zero)
    ``cand``          leaves ``[C]``         the precomputed candidate grid
                                             (host-built, bitwise identical
                                             to ``candidate_grid``)
    ``hist_u``        ``[K, Tw, H]`` f32     calibration history: utilization
    ``hist_p``        ``[K, Tw]`` f32        calibration history: power
    ``hist_n``        int32                  filled history slots (<= K)
    ``window``        int32                  next window index
    ``slo_samples``   ``[n_slo]`` int32      SLO accumulator: observations
    ``slo_compliant`` ``[n_slo]`` int32      SLO accumulator: compliant
    ``bias_under``    int32                  bias split (paper Fig. 6)
    ``bias_over``     int32
    ``bias_ties``     int32
    ``sim_u``         ``[sim_bins, H]`` f32  full-horizon DES utilization
                                             (``None`` unless
                                             ``cfg.sim_bins > 0``)
    ================  =====================  ===============================

    With ``CalibrationSpec(per_host=True)`` the ``params`` / ``base_params``
    leaves are ``[H]`` rows instead of scalars — one calibrated power model
    per host, threaded straight into prediction (the power models broadcast
    trailing host-dim parameters).

    History buffers are chronological with zero-padding at the tail; padded
    bins have zero measured power, which the MAPE kernel already excludes,
    so a partially-filled buffer scores like the old variable-length
    concatenation.  ``cfg`` is aux data (static, hashable).  ``sim_u=None``
    is an empty pytree subtree, so the default layout's leaf list (and every
    existing golden/checkpoint) is unchanged.
    """

    params: PowerParams
    base_params: PowerParams
    cand: PowerParams
    hist_u: Array
    hist_p: Array
    hist_n: Array
    window: Array
    slo_samples: Array
    slo_compliant: Array
    bias_under: Array
    bias_over: Array
    bias_ties: Array
    sim_u: Array | None = None
    cfg: TwinConfig = TwinConfig()


jax.tree_util.register_pytree_node(
    TwinState,
    lambda s: ((s.params, s.base_params, s.cand, s.hist_u, s.hist_p,
                s.hist_n, s.window, s.slo_samples, s.slo_compliant,
                s.bias_under, s.bias_over, s.bias_ties, s.sim_u), s.cfg),
    lambda cfg, c: TwinState(*c, cfg=cfg),
)


@dataclasses.dataclass(frozen=True)
class TelemetrySlice:
    """One window of physical-twin telemetry as a device-ready pytree.

    ``valid`` masks the whole observation: with ``valid=False`` the step
    still predicts (the twin keeps running) but scores nothing, learns
    nothing and leaves every accumulator untouched — the pure-core encoding
    of "this window's telemetry has not landed".
    """

    u_th: Array      # [Tw, H] float32 measured utilization
    power_w: Array   # [Tw] float32 measured total power
    valid: Array     # bool scalar


jax.tree_util.register_pytree_node(
    TelemetrySlice,
    lambda t: ((t.u_th, t.power_w, t.valid), None),
    lambda _, c: TelemetrySlice(*c),
)


def make_telemetry(u_th, power_w, valid: bool = True) -> TelemetrySlice:
    """Build a :class:`TelemetrySlice` from host arrays (float32-cast)."""
    return TelemetrySlice(
        u_th=jnp.asarray(u_th, jnp.float32),
        power_w=jnp.asarray(power_w, jnp.float32),
        valid=jnp.asarray(valid, bool),
    )


def empty_telemetry(bins_per_window: int, num_hosts: int) -> TelemetrySlice:
    """The ``valid=False`` placeholder for a window with no telemetry."""
    return TelemetrySlice(
        u_th=jnp.zeros((bins_per_window, num_hosts), jnp.float32),
        power_w=jnp.zeros((bins_per_window,), jnp.float32),
        valid=jnp.asarray(False),
    )


@dataclasses.dataclass(frozen=True)
class SimSlice:
    """The simulation engine's window slice the core predicts from.

    ``u_th`` is the window's ``[Tw, H]`` slice of the full-horizon DES
    utilization field (the DES itself is power-parameter independent and
    stays outside the per-window step — see ``Orchestrator._ensure_sim``).
    With ``TwinConfig.sim_bins > 0`` the state owns the full horizon and
    ``u_th`` may be ``None``: ``twin_step`` then slices the window from
    ``state.sim_u`` itself.  ``carbon_intensity`` / ``ambient_c`` /
    ``price`` are the optional ``[Tw]`` forecast slices (gCO2/kWh, deg C,
    $/kWh) the read-out folds into gCO2, dynamic PUE and energy cost.
    """

    u_th: Array | None = None
    carbon_intensity: Array | None = None
    ambient_c: Array | None = None
    price: Array | None = None


jax.tree_util.register_pytree_node(
    SimSlice,
    lambda s: ((s.u_th, s.carbon_intensity, s.ambient_c, s.price), None),
    lambda _, c: SimSlice(*c),
)


@dataclasses.dataclass(frozen=True)
class WindowOutput:
    """Per-window read-out of one ``twin_step`` (pytree).

    ``mape`` and ``calib_mape`` are NaN when the window had no (valid)
    telemetry; ``params_used`` are the pipelined parameters the prediction
    ran with, ``params_next`` the calibration result shipped to the next
    window (equal to ``params_used`` when nothing was learned).
    """

    prediction: Prediction
    mape: Array            # f32 scalar, % (NaN without telemetry)
    calib_mape: Array      # f32 scalar, best candidate's history MAPE
    params_used: PowerParams
    params_next: PowerParams
    window: Array          # int32 scalar


jax.tree_util.register_pytree_node(
    WindowOutput,
    lambda o: ((o.prediction, o.mape, o.calib_mape, o.params_used,
                o.params_next, o.window), None),
    lambda _, c: WindowOutput(*c),
)


def _scalar_param(x, name: str, hosts: int | None = None) -> Array:
    """Base-parameter leaf: scalar, or a ``[hosts]`` row in per-host mode."""
    a = jnp.asarray(x, jnp.float32)
    if hosts is not None:
        if a.ndim == 0 or a.size == 1:
            return jnp.full((hosts,), a.reshape(()), jnp.float32)
        if a.shape != (hosts,):
            raise ValueError(
                f"per-host base params must be scalar or [{hosts}]; "
                f"{name} has shape {a.shape}")
        return a
    if a.ndim != 0 and a.size != 1:
        raise ValueError(
            f"pure-core base params must be scalar; {name} has shape "
            f"{a.shape}.  Per-host parameters need "
            "CalibrationSpec(per_host=True), which carries [H] rows; the "
            "fleet-level calibrator output is scalar by construction.")
    return a.reshape(())


def init_twin_state(cfg: TwinConfig,
                    base_params: PowerParams = PowerParams(),
                    sim_u=None) -> TwinState:
    """Fresh ``TwinState``: base parameters, empty history, zero counters.

    The candidate grid is precomputed host-side here (one
    :func:`~repro.core.calibrate.candidate_grid` call) and carried as state
    leaves, so every subsequent ``twin_step`` is pure array math.

    With ``cfg.sim_bins > 0`` the state carries the full-horizon DES
    utilization field: pass ``sim_u`` (``[sim_bins, H]``) to seed it, or
    leave it ``None`` for a zero field the shell fills in later.  With
    ``cfg.calibration.per_host`` the parameter leaves are ``[H]`` rows
    (scalar bases broadcast; length-``H`` vectors pass through).
    """
    k, tw, h = cfg.history_windows, cfg.bins_per_window, cfg.dc.num_hosts
    hosts = h if cfg.calibration.per_host else None
    base = PowerParams(
        p_idle=_scalar_param(base_params.p_idle, "p_idle", hosts),
        p_max=_scalar_param(base_params.p_max, "p_max", hosts),
        r=_scalar_param(base_params.r, "r", hosts))
    if cfg.sim_bins > 0:
        if sim_u is None:
            sim_u = jnp.zeros((cfg.sim_bins, h), jnp.float32)
        else:
            sim_u = jnp.asarray(sim_u, jnp.float32)
            if sim_u.shape != (cfg.sim_bins, h):
                raise ValueError(
                    f"sim_u must be [{cfg.sim_bins}, {h}] "
                    f"(cfg.sim_bins x num_hosts); got {sim_u.shape}")
    elif sim_u is not None:
        raise ValueError("sim_u given but cfg.sim_bins == 0")
    state = TwinState(
        sim_u=sim_u,
        params=base,
        base_params=base,
        cand=candidate_grid(cfg.calibration, base),
        hist_u=jnp.zeros((k, tw, h), jnp.float32),
        hist_p=jnp.zeros((k, tw), jnp.float32),
        hist_n=jnp.asarray(0, jnp.int32),
        window=jnp.asarray(0, jnp.int32),
        slo_samples=jnp.zeros((len(cfg.slos),), jnp.int32),
        slo_compliant=jnp.zeros((len(cfg.slos),), jnp.int32),
        bias_under=jnp.asarray(0, jnp.int32),
        bias_over=jnp.asarray(0, jnp.int32),
        bias_ties=jnp.asarray(0, jnp.int32),
        cfg=cfg,
    )
    # de-alias the leaves: params/base_params start as the *same* arrays
    # (and scalar constants may share cached buffers), but twin_step_jit
    # donates the state — XLA rejects the same buffer donated twice
    return jax.tree.map(lambda x: jnp.array(x), state)


def _push(buf: Array, new: Array, n: Array) -> Array:
    """Append ``new`` to a chronological ``[K, ...]`` buffer.

    Writes at slot ``n`` while the buffer is filling (padding stays at the
    tail) and shifts left once full — the buffer always reads oldest →
    newest, like the imperative calibrator's ``history[-K:]`` concat.
    """
    k = buf.shape[0]
    shifted = jnp.concatenate([buf[1:], new[None]], axis=0)
    written = jax.lax.dynamic_update_slice_in_dim(
        buf, new[None], jnp.minimum(n, k - 1), axis=0)
    return jnp.where(n >= k, shifted, written)


def _where_tree(cond, a, b):
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


def twin_step(state: TwinState, telemetry: TelemetrySlice,
              sim_slice: SimSlice) -> tuple[TwinState, WindowOutput]:
    """One pure window of the continuous twinning cycle (paper Fig. 3).

    S_k: predict the window from ``sim_slice`` with the *pipelined*
    parameters (``state.params`` — the C_{k-1} result).  Then, when the
    telemetry is valid: score the prediction (MAPE), update the SLO and
    bias accumulators, push the observation into the history buffers and
    run C_k (grid-search calibration over the history) so S_{k+1} predicts
    with fresh parameters.  Pure and fixed-shape: compose freely with
    ``jit``, ``vmap`` (fleets of twins) and ``scan`` (whole horizons).
    """
    cfg = state.cfg
    params = state.params

    # S_k — prediction with the pipelined parameters.  When the state owns
    # the full-horizon DES (cfg.sim_bins > 0) and the caller passes no
    # window slice, slice it here: the twin simulates from *its own* field,
    # which topology-applying feedback may have re-simulated.
    u_win = sim_slice.u_th
    if u_win is None:
        if state.sim_u is None:
            raise ValueError(
                "SimSlice.u_th is None but the state carries no sim_u "
                "(TwinConfig.sim_bins == 0)")
        u_win = jax.lax.dynamic_slice_in_dim(
            state.sim_u, state.window * cfg.bins_per_window,
            cfg.bins_per_window, axis=0)
    pred = predict_metrics(u_win, params, cfg.dc,
                           model=cfg.power_model,
                           carbon_intensity=sim_slice.carbon_intensity,
                           ambient_c=sim_slice.ambient_c,
                           price=sim_slice.price,
                           pue=cfg.pue,
                           backend=cfg.kernel_backend)

    # Scoring: window MAPE against measured power (NaN without telemetry).
    valid = telemetry.valid
    m = jnp.where(valid, mape(telemetry.power_w, pred.power_w), jnp.nan)

    slo_samples, slo_compliant = observe_slos(
        cfg.slos, state.slo_samples, state.slo_compliant, m, valid,
        metric="mape")
    under, over, ties = observe_bias(
        state.bias_under, state.bias_over, state.bias_ties,
        telemetry.power_w, pred.power_w, valid)

    hist_u, hist_p, hist_n = state.hist_u, state.hist_p, state.hist_n
    params_next = params
    calib_mape = jnp.asarray(jnp.nan, jnp.float32)
    if cfg.calibrate:
        # C_k — masked history push + grid search for S_{k+1}.
        hist_u = jnp.where(valid, _push(state.hist_u, telemetry.u_th,
                                        state.hist_n), state.hist_u)
        hist_p = jnp.where(valid, _push(state.hist_p, telemetry.power_w,
                                        state.hist_n), state.hist_p)
        hist_n = jnp.where(valid,
                           jnp.minimum(state.hist_n + 1,
                                       cfg.history_windows), state.hist_n)
        k, tw, h = hist_u.shape
        new_params, best_mape = calibrate_traced(
            hist_u.reshape(k * tw, h), hist_p.reshape(k * tw),
            state.cand, cfg.calibration, state.base_params,
            backend=cfg.kernel_backend)
        params_next = _where_tree(valid, new_params, params)
        calib_mape = jnp.where(valid, best_mape, jnp.nan)

    new_state = TwinState(
        params=params_next,
        base_params=state.base_params,
        cand=state.cand,
        hist_u=hist_u,
        hist_p=hist_p,
        hist_n=hist_n,
        window=state.window + 1,
        slo_samples=slo_samples,
        slo_compliant=slo_compliant,
        bias_under=under,
        bias_over=over,
        bias_ties=ties,
        sim_u=state.sim_u,
        cfg=cfg,
    )
    out = WindowOutput(prediction=pred, mape=m, calib_mape=calib_mape,
                       params_used=params, params_next=params_next,
                       window=state.window)
    return new_state, out


#: the shared jitted step the imperative shell (and simple callers) drive —
#: one compilation per (shapes, cfg) combination, shared across instances.
#: The window carry is donated: every caller rebinds ``state, out =
#: twin_step_jit(state, ...)``, so the incoming TwinState's buffers (the
#: [K, Tw, H] history above all) are dead after the call and XLA reuses
#: them for the outgoing state instead of double-buffering.  Reading a
#: donated input afterwards raises — keep a reference to the *new* state
#: (or use ``jax.jit(twin_step)`` for a non-donating step).
twin_step_jit = jax.jit(twin_step, donate_argnums=(0,))


# -- checkpoint / resume ------------------------------------------------------

def state_to_bytes(state: TwinState) -> bytes:
    """Encode a ``TwinState`` as a codec-tagged compressed msgpack blob.

    Same optional-dependency story as every persisted blob in this repo
    (:mod:`repro.core.codec`): zstd when available, stdlib zlib otherwise,
    one codec-id byte so either reader opens either blob.  The byte form is
    what checkpoints (:func:`save_state`), the streaming service's session
    store (:mod:`repro.serve.sessions`) and its result cache
    (:mod:`repro.serve.cache`) all share.
    """
    leaves, treedef = jax.tree_util.tree_flatten(state)
    del treedef  # reconstructed from cfg on load
    cfg = state.cfg
    payload = {
        "version": _STATE_VERSION,
        "cfg": {
            "bins_per_window": cfg.bins_per_window,
            "dc": dataclasses.asdict(cfg.dc),
            "calibration": dataclasses.asdict(cfg.calibration),
            "calibrate": cfg.calibrate,
            "history_windows": cfg.history_windows,
            "power_model": cfg.power_model,
            "kernel_backend": cfg.kernel_backend,
            "slos": [dataclasses.asdict(s) for s in cfg.slos],
            # None when dynamic PUE is off; old readers ignore the key,
            # old files load with pue=None (tolerant .get on load).
            "pue": (dataclasses.asdict(cfg.pue)
                    if cfg.pue is not None else None),
            # 0 when the shell owns the DES; old files load with 0
            # (tolerant .get on load), so the leaf lists line up.
            "sim_bins": cfg.sim_bins,
        },
        "leaves": [codec.pack_array(x) for x in leaves],
    }
    return codec.dumps(payload)


def state_from_bytes(blob: bytes) -> TwinState:
    """Decode a ``TwinState`` from :func:`state_to_bytes` (bit-identical)."""
    payload = codec.loads(blob)
    if payload["version"] != _STATE_VERSION:
        raise ValueError(
            f"unsupported TwinState version {payload['version']} "
            f"(this build reads {_STATE_VERSION})")
    c = payload["cfg"]
    cfg = TwinConfig(
        bins_per_window=c["bins_per_window"],
        dc=DatacenterConfig(**c["dc"]),
        calibration=CalibrationSpec(**c["calibration"]),
        calibrate=c["calibrate"],
        history_windows=c["history_windows"],
        power_model=c["power_model"],
        kernel_backend=c["kernel_backend"],
        slos=tuple(SLO(**s) for s in c["slos"]),
        pue=(PUEParams(**c["pue"]) if c.get("pue") is not None else None),
        sim_bins=c.get("sim_bins", 0),
    )
    template = init_twin_state(cfg)
    treedef = jax.tree_util.tree_structure(template)
    leaves = [jnp.asarray(codec.unpack_array(rec))
              for rec in payload["leaves"]]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_state(state: TwinState, path: str) -> None:
    """Persist a ``TwinState`` (:func:`state_to_bytes`) to ``path``."""
    with open(path, "wb") as f:
        f.write(state_to_bytes(state))


def load_state(path: str) -> TwinState:
    """Load a ``TwinState`` written by :func:`save_state`.

    The resumed state is bit-identical to the saved one, so a resumed run
    reproduces the uninterrupted run exactly (pinned by
    ``tests/test_twin_core.py``).
    """
    with open(path, "rb") as f:
        return state_from_bytes(f.read())

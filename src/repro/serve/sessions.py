"""Per-tenant twin sessions: checkpoint and restore through the codec.

A *session* is the durable identity of one tenant's twin mid-stream: the
calibrated :class:`~repro.core.state.TwinState`, the next window its
stream expects, and the rolling digest the result cache keys on.  The
:class:`SessionStore` writes each as one codec blob
(:func:`repro.core.codec.dumps` — same one-byte-id envelope as every
other artifact in the repo), so killing a :class:`~repro.serve.service.
TwinService` and restoring it resumes **bit-for-bit**: the restored twin
replays exactly where the uninterrupted one would be, which
``tests/test_serve.py`` pins.

Writes are atomic (tempfile + ``os.replace``) like
:meth:`repro.core.telemetry.TelemetryStore.flush` — a crash mid-
checkpoint leaves the previous consistent snapshot.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import tempfile

from repro.core import codec
from repro.core.state import TwinState, state_from_bytes, state_to_bytes

_SESSION_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Session:
    """One tenant's durable stream position."""

    tenant: str
    state: TwinState
    next_window: int
    digest: str


def _filename(tenant: str) -> str:
    # tenant names come from config files and tests; keep the mapping
    # readable but filesystem-safe (and collision-free via a suffix hash)
    safe = "".join(c if c.isalnum() or c in "-_." else "_" for c in tenant)
    tag = hashlib.sha256(tenant.encode()).hexdigest()[:8]
    return f"{safe}.{tag}.session"


class SessionStore:
    """Directory of per-tenant session blobs."""

    def __init__(self, root):
        self.root = os.fspath(root)
        os.makedirs(self.root, exist_ok=True)

    def _path(self, tenant: str) -> str:
        return os.path.join(self.root, _filename(tenant))

    def save(self, session: Session) -> None:
        payload = {
            "version": _SESSION_VERSION,
            "tenant": session.tenant,
            "next_window": int(session.next_window),
            "digest": session.digest,
            "state": state_to_bytes(session.state),
        }
        blob = codec.dumps(payload)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(blob)
            os.replace(tmp, self._path(session.tenant))
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def load(self, tenant: str) -> Session:
        with open(self._path(tenant), "rb") as f:
            payload = codec.loads(f.read())
        if payload.get("version") != _SESSION_VERSION:
            raise ValueError(
                f"session blob for {tenant!r} has version "
                f"{payload.get('version')}, expected {_SESSION_VERSION}")
        return Session(
            tenant=payload["tenant"],
            state=state_from_bytes(payload["state"]),
            next_window=int(payload["next_window"]),
            digest=payload["digest"],
        )

    def __contains__(self, tenant: str) -> bool:
        return os.path.exists(self._path(tenant))

    @property
    def tenants(self) -> "list[str]":
        """Tenants with a saved session, sorted by name."""
        names = []
        for fn in os.listdir(self.root):
            if not fn.endswith(".session"):
                continue
            with open(os.path.join(self.root, fn), "rb") as f:
                names.append(codec.loads(f.read())["tenant"])
        return sorted(names)

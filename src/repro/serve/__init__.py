"""Streaming twin service: many live tenant twins, one compiled program.

Upstream OpenDT serves its twin as a Kafka microservice mesh — ``dc-mock``
telemetry producers, a sim-worker window manager and a result cache.  This
package is that serving story on the pure functional core: replayable
producers (:mod:`repro.serve.producers`), a dynamic batcher that packs
ready ``(tenant, window)`` pairs onto the fixed fleet axis
(:mod:`repro.serve.batching`), a digest-keyed result cache of codec blobs
(:mod:`repro.serve.cache`), per-tenant checkpoint/restore sessions
(:mod:`repro.serve.sessions`) and the bounded-queue ingestion loop that
ties them together (:mod:`repro.serve.service`).

Everything host-side is deterministic by construction (the injectable
``Clock`` from :mod:`repro.core.orchestrator`, seeded RNGs — enforced by
tracecheck TC007); everything device-side is ONE jitted program
(:func:`repro.core.twin.fleet_step_masked`) shared by every tenant mix.
"""

from repro.serve.batching import LaneMap, WindowManager, build_fleet_inputs
from repro.serve.cache import ResultCache, decode_result, encode_result
from repro.serve.producers import (
    SyntheticProducer,
    TraceReplayProducer,
    WindowEvent,
)
from repro.serve.sessions import Session, SessionStore
from repro.serve.service import (
    ServeConfig,
    ServeStats,
    TwinService,
    WindowResult,
)

__all__ = [
    "LaneMap",
    "ResultCache",
    "ServeConfig",
    "ServeStats",
    "Session",
    "SessionStore",
    "SyntheticProducer",
    "TraceReplayProducer",
    "TwinService",
    "WindowEvent",
    "WindowManager",
    "WindowResult",
    "build_fleet_inputs",
    "decode_result",
    "encode_result",
]

"""Replayable streaming telemetry producers (the dc-mock role).

Upstream OpenDT's ``dc-mock`` service replays a recorded trace onto Kafka at
a configurable rate; these producers play that part for the
:class:`~repro.serve.service.TwinService`.  A producer owns one tenant's
telemetry stream and answers :meth:`poll(now) <Producer.poll>` with every
window whose (jittered) due time has passed — *time is an argument*, never
an ambient clock, so the same producer runs frozen-time in tests and
wall-clock in the live service loop (tracecheck TC007).

Two flavors ship:

  * :class:`TraceReplayProducer` — replays a
    :class:`~repro.core.twin.TraceGroundTruth` (or any precomputed
    ``u_th``/``power`` pair, e.g. a SURF-like trace) window by window;
  * :class:`SyntheticProducer` — generates jittered synthetic telemetry
    from a seeded RNG and a hidden power model, deterministic per
    ``(seed, window)`` regardless of poll pattern.

Both are **replayable**: :meth:`Producer.rewind` moves the cursor back, so
backpressure (a full service queue) and crash recovery (a restored session
asking for older windows again) are lossless — the stream is re-emitted,
not re-recorded.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.power import PowerParams, opendc_power


@dataclasses.dataclass(frozen=True)
class WindowEvent:
    """One tenant-window of streamed telemetry, ready for ingestion.

    ``u_th``/``power_w`` are the *measured* window (``power_w=None`` marks a
    telemetry gap — the twin still predicts, learns nothing); ``sim_u`` is
    the DES utilization slice the twin predicts from.  The optional
    ``[Tw]`` forecast columns must match the service's configured columns
    (:class:`~repro.serve.service.ServeConfig`) so the compiled program's
    input structure never changes mid-stream.
    """

    tenant: str
    window: int
    u_th: np.ndarray                      # [Tw, H] measured utilization
    power_w: "np.ndarray | None"          # [Tw] measured power (None = gap)
    sim_u: np.ndarray                     # [Tw, H] DES slice to predict from
    carbon_intensity: "np.ndarray | None" = None   # [Tw] gCO2/kWh forecast
    ambient_c: "np.ndarray | None" = None          # [Tw] deg C forecast
    price: "np.ndarray | None" = None              # [Tw] $/kWh forecast


class Producer:
    """Protocol: a replayable, clock-driven stream of one tenant's windows."""

    tenant: str

    def poll(self, now: float) -> "list[WindowEvent]":
        """Every not-yet-emitted window due at or before ``now``, in order."""
        raise NotImplementedError

    @property
    def exhausted(self) -> bool:
        """True once every window has been emitted (cursor at the end)."""
        raise NotImplementedError

    def rewind(self, window: int) -> None:
        """Move the cursor back so ``window`` is the next emission."""
        raise NotImplementedError


class _ScheduledProducer(Producer):
    """Shared machinery: a jittered due-time schedule over W windows.

    Window ``w`` becomes due at ``start + (w + 1) * period_s + jitter_w``
    with ``jitter_w ~ U[0, jitter_s)`` drawn from a seeded RNG — the
    schedule is a pure function of the constructor arguments, so two
    identically-configured producers emit identically (determinism the
    service tests lean on).
    """

    def __init__(self, tenant: str, num_windows: int, *, start: float = 0.0,
                 period_s: float = 0.0, jitter_s: float = 0.0, seed: int = 0):
        self.tenant = tenant
        self.num_windows = int(num_windows)
        rng = np.random.default_rng([seed, 0xD0])
        self._due = (start + period_s * (np.arange(self.num_windows) + 1)
                     + rng.uniform(0.0, jitter_s or 0.0, self.num_windows))
        self._cursor = 0

    def _window_event(self, window: int) -> WindowEvent:
        raise NotImplementedError

    def poll(self, now: float) -> "list[WindowEvent]":
        events: list[WindowEvent] = []
        while (self._cursor < self.num_windows
               and self._due[self._cursor] <= now):
            events.append(self._window_event(self._cursor))
            self._cursor += 1
        return events

    @property
    def exhausted(self) -> bool:
        return self._cursor >= self.num_windows

    def rewind(self, window: int) -> None:
        if not 0 <= window <= self.num_windows:
            raise ValueError(
                f"rewind target {window} outside [0, {self.num_windows}]")
        self._cursor = min(self._cursor, int(window))


class TraceReplayProducer(_ScheduledProducer):
    """Replays a recorded trace window by window (dc-mock style).

    ``truth`` is anything exposing ``u_th`` (``[T, H]`` utilization, the DES
    field doubling as measured utilization) and ``power`` (``[T]`` measured
    watts) — :class:`~repro.core.twin.TraceGroundTruth` fits directly.
    Forecast columns (full-horizon ``[T]`` arrays) are sliced per window.
    """

    def __init__(self, tenant: str, truth, bins_per_window: int, *,
                 start: float = 0.0, period_s: float = 0.0,
                 jitter_s: float = 0.0, seed: int = 0,
                 carbon_intensity: "np.ndarray | None" = None,
                 ambient_c: "np.ndarray | None" = None,
                 price: "np.ndarray | None" = None):
        self.u_th = np.asarray(truth.u_th)
        self.power = np.asarray(truth.power)
        self.bins_per_window = int(bins_per_window)
        self.carbon_intensity = carbon_intensity
        self.ambient_c = ambient_c
        self.price = price
        super().__init__(
            tenant, self.u_th.shape[0] // self.bins_per_window,
            start=start, period_s=period_s, jitter_s=jitter_s, seed=seed)

    def _window_event(self, window: int) -> WindowEvent:
        sl = slice(window * self.bins_per_window,
                   (window + 1) * self.bins_per_window)

        def col(x):
            return None if x is None else np.asarray(x[sl], np.float32)

        return WindowEvent(
            tenant=self.tenant, window=window,
            u_th=np.asarray(self.u_th[sl], np.float32),
            power_w=np.asarray(self.power[sl], np.float32),
            sim_u=np.asarray(self.u_th[sl], np.float32),
            carbon_intensity=col(self.carbon_intensity),
            ambient_c=col(self.ambient_c),
            price=col(self.price),
        )


class SyntheticProducer(_ScheduledProducer):
    """Jittered synthetic telemetry from a hidden power model.

    Per window the utilization field is drawn from a seeded per-window RNG
    (``default_rng([seed, window])`` — the data is a pure function of
    ``(seed, window)``, independent of poll order) and the measured power is
    the *hidden* model's response plus meter noise: the live-stream analog
    of :func:`repro.traces.surf.synthesize_ground_truth`, sized for a
    service test rather than a full trace.
    """

    def __init__(self, tenant: str, *, hosts: int, bins_per_window: int,
                 num_windows: int, seed: int = 0, util_mean: float = 0.4,
                 hidden: PowerParams = PowerParams(p_idle=72.0, p_max=365.0,
                                                   r=2.4),
                 noise: float = 0.01, start: float = 0.0,
                 period_s: float = 0.0, jitter_s: float = 0.0):
        self.hosts = int(hosts)
        self.bins_per_window = int(bins_per_window)
        self.util_mean = float(util_mean)
        self.hidden = hidden
        self.noise = float(noise)
        self.seed = int(seed)
        super().__init__(tenant, num_windows, start=start, period_s=period_s,
                         jitter_s=jitter_s, seed=seed)

    def _window_event(self, window: int) -> WindowEvent:
        rng = np.random.default_rng([self.seed, window])
        u = np.clip(rng.normal(self.util_mean, 0.15,
                               (self.bins_per_window, self.hosts)),
                    0.0, 1.0).astype(np.float32)
        p = np.asarray(opendc_power(u, self.hidden)).sum(axis=-1)
        p = (p * (1.0 + rng.normal(0.0, self.noise, p.shape))).astype(
            np.float32)
        return WindowEvent(tenant=self.tenant, window=window, u_th=u,
                           power_w=p, sim_u=u)

"""Digest-keyed result cache of codec blobs (the sim-worker role).

Upstream OpenDT's sim-worker keeps a ``result_cache.py`` so re-simulating
an already-seen (window, parameters, scenario) triple is a lookup, not a
run.  The twin's analog: ``twin_step`` is deterministic, so a tenant
window's *entire* outcome — the :class:`~repro.core.state.WindowOutput`
**and** the successor :class:`~repro.core.state.TwinState` — is a pure
function of ``(window, params_digest, scenario_digest)``, where

  * ``params_digest`` is the tenant's rolling stream digest: seeded from
    the admitted ``TwinState`` bytes and folded forward with every served
    window's input digest, it identifies the exact calibrated state the
    step would run from **without touching the device** (the property the
    double-buffered service loop needs — a cache probe never forces a
    host sync);
  * ``scenario_digest`` hashes the window's telemetry + sim inputs.

Entries are codec blobs (:func:`repro.core.codec.dumps` — one-byte codec
id, optional-zstd policy) holding the output leaves plus the successor
state, so a hit replays **bit-for-bit** what the compiled program would
have produced.  The cache is LRU-bounded and counts hits/misses — the
``cache_hit_rate`` line in ``BENCH_serve.json``.
"""

from __future__ import annotations

import collections
import hashlib

import numpy as np

from repro.core import codec
from repro.core.desim import Prediction
from repro.core.power import PowerParams
from repro.core.state import (
    TwinState,
    WindowOutput,
    state_from_bytes,
    state_to_bytes,
)

#: Prediction's named leaves, in dataclass order (optional ones may be None)
_PRED_FIELDS = ("power_w", "energy_kwh", "tflops", "utilization",
                "efficiency", "gco2", "power_demand_w", "pue", "energy_cost")


def digest_bytes(*parts: bytes) -> str:
    """Hex digest over a byte sequence (the cache-key hash)."""
    h = hashlib.sha256()
    for p in parts:
        h.update(p)
    return h.hexdigest()


def digest_arrays(*arrays) -> str:
    """Digest over arrays (None allowed — a gap is part of the identity)."""
    h = hashlib.sha256()
    for a in arrays:
        if a is None:
            h.update(b"\x00none")
            continue
        a = np.asarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def encode_result(out: WindowOutput, next_state: TwinState) -> bytes:
    """Pack one served window — output + successor state — as a codec blob."""

    def arr(x):
        return None if x is None else codec.pack_array(x)

    payload = {
        "pred": {f: arr(getattr(out.prediction, f)) for f in _PRED_FIELDS},
        "mape": codec.pack_array(out.mape),
        "calib_mape": codec.pack_array(out.calib_mape),
        "params_used": [codec.pack_array(x) for x in
                        (out.params_used.p_idle, out.params_used.p_max,
                         out.params_used.r)],
        "params_next": [codec.pack_array(x) for x in
                        (out.params_next.p_idle, out.params_next.p_max,
                         out.params_next.r)],
        "window": codec.pack_array(out.window),
        "state": state_to_bytes(next_state),
    }
    return codec.dumps(payload)


def decode_result(blob: bytes) -> "tuple[WindowOutput, TwinState]":
    """Inverse of :func:`encode_result` (host-array leaves, bit-identical)."""
    payload = codec.loads(blob)

    def arr(rec):
        return None if rec is None else codec.unpack_array(rec)

    def params(recs):
        return PowerParams(*(codec.unpack_array(r) for r in recs))

    out = WindowOutput(
        prediction=Prediction(**{f: arr(payload["pred"][f])
                                 for f in _PRED_FIELDS}),
        mape=codec.unpack_array(payload["mape"]),
        calib_mape=codec.unpack_array(payload["calib_mape"]),
        params_used=params(payload["params_used"]),
        params_next=params(payload["params_next"]),
        window=codec.unpack_array(payload["window"]),
    )
    return out, state_from_bytes(payload["state"])


class ResultCache:
    """LRU-bounded blob cache with hit/miss counters.

    Keys are the ``(window, params_digest, scenario_digest)`` triples the
    service derives; values are :func:`encode_result` blobs.  ``get`` on a
    present key refreshes recency; ``put`` evicts the least recently used
    entry beyond ``capacity``.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._entries: "collections.OrderedDict[tuple, bytes]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple) -> "bytes | None":
        blob = self._entries.get(key)
        if blob is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return blob

    def put(self, key: tuple, blob: bytes) -> None:
        self._entries[key] = blob
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
